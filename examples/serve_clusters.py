"""Clustering-as-a-service walkthrough (DESIGN.md §8): run the
ClusterServeEngine over a stream of graph requests and watch the three
serve mechanisms pay off:

  1. shape-bucketed batching — ten differently-weighted community
     graphs land in one power-of-two bucket, solve as two vmapped
     batches, and compile exactly ONE trace;
  2. warm-start cache — re-submitting a served graph hits the exact
     tier and re-enters the solver at the schedule tail (one Newton
     step instead of the whole p-continuation);
  3. incremental re-clustering — an EdgeDelta against a served graph
     rides the churn path: with_vals weight reuse + warm solve, no
     p=2 eigensolve, labels still match a from-scratch solve.

    PYTHONPATH=src python examples/serve_clusters.py
"""
import numpy as np

from repro.core import PSCConfig
from repro.graphs import sbm_graph
from repro.serve import ClusterServeEngine, EdgeDelta

cfg = PSCConfig(k=4, reorder="none", newton_iters=20, tcg_iters=12,
                kmeans_restarts=4)
engine = ClusterServeEngine(cfg, max_batch=8, cache_capacity=32)

# ---- 1. a stream of requests: same community structure, ten tenants
graphs = [sbm_graph([32] * 4, 0.3, 0.01, seed=s)[0] for s in range(10)]
results = engine.serve(graphs)
for r in results[:3]:
    s = r.stats
    print(f"req {s.req_id}: n={s.n} lane={s.lane} mode={s.mode} "
          f"bucket={s.bucket} batch={s.batch_size} rcut={r.rcut:.3f}")
print(f"-> {engine.stats.n_batches} batches, "
      f"{engine.stats.traces} compiled trace(s) for {len(graphs)} graphs\n")

# ---- 2. repeat tenant: exact-tier warm hit, schedule-tail re-entry
engine.serve([graphs[1]])          # first warm request compiles the trace
warm = engine.serve([graphs[0]])[0]
print(f"warm replay: tier={warm.stats.cache_tier} mode={warm.stats.mode} "
      f"labels unchanged="
      f"{bool(np.array_equal(warm.labels, results[0].labels))} "
      f"solve {warm.stats.solve_s * 1e3:.0f} ms vs cold "
      f"{results[0].stats.solve_s / results[0].stats.batch_size * 1e3:.0f}"
      f" ms/graph\n")

# ---- 3. churn tick: down-weight 1% of the edges, re-cluster in place
W = graphs[0]
rng = np.random.default_rng(7)
und = np.flatnonzero(np.asarray(W.rows) < np.asarray(W.cols))
pick = rng.choice(und, len(und) // 100, replace=False)
delta = EdgeDelta(np.asarray(W.rows)[pick], np.asarray(W.cols)[pick],
                  np.full(len(pick), 0.25))
rid = engine.update(W, delta)
res = engine.flush()[rid]
print(f"churn tick: mode={res.stats.mode} edges_edited={len(pick)} "
      f"rcut={res.rcut:.3f} solve {res.stats.solve_s * 1e3:.0f} ms")
print(f"\nengine totals: {engine.stats.as_dict()}")
print(f"cache: {engine.cache.stats()}")
