"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps on synthetic data with the full production loop (checkpointing,
preemption guard, watchdog).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The ~100M config is the gemma family at width 512 — same code path as
the full 2B/398B/671B configs, scaled to run on CPU in minutes.
"""
import argparse
import dataclasses
import sys
import time

import jax

from repro.configs import get_config
from repro.models import model as M
from repro.data import SyntheticTokens
from repro.train import (TrainConfig, make_train_step, make_optimizer,
                         CheckpointManager, PreemptionGuard, StepWatchdog)


def make_100m():
    base = get_config("gemma-2b")
    return dataclasses.replace(
        base, n_layers=8, d_model=512, n_heads=8, n_kv_heads=1, head_dim=64,
        d_ff=2048, vocab=32768, params_dtype="float32",
        compute_dtype="float32", remat="none", max_position=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = make_100m()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params")

    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3,
                     warmup_steps=20, total_steps=args.steps)
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, tc, opt=opt), donate_argnums=(0, 1))
    data = SyntheticTokens(cfg, batch=args.batch, seq=args.seq)
    mgr = CheckpointManager("checkpoints/example_100m", keep=2)
    guard, wd = PreemptionGuard(), StepWatchdog()

    first_loss = None
    for step in range(args.steps):
        t0 = time.time()
        params, opt_state, m = step_fn(params, opt_state, data.batch_at(step))
        wd.record(step, time.time() - t0)
        if step % 25 == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            first_loss = first_loss if first_loss is not None else loss
            print(f"step {step:4d} loss {loss:.4f} "
                  f"({args.batch*args.seq/(time.time()-t0):,.0f} tok/s)")
        if (step + 1) % 100 == 0 or guard.should_stop:
            mgr.save(step + 1, (params, opt_state))
        if guard.should_stop:
            print("preempted — checkpoint saved")
            sys.exit(0)

    final = float(m["loss"])
    print(f"loss {first_loss:.3f} -> {final:.3f}; "
          f"stragglers flagged: {len(wd.straggler_steps)}")
    assert final < first_loss, "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
