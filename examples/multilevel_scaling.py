"""Multilevel V-cycle vs flat GrB-pGrass on a mid-size Delaunay graph —
the DESIGN.md §6 scaling story in miniature.

The flat solver pays O(nnz) per Newton iteration on the fine graph; the
V-cycle coarsens with heavy-edge matching (Galerkin Pᵀ W P through
grblas.api.mxm's spgemm backend), runs the whole p-continuation on the
coarsest graph, and walks back up with prolong → re-orthonormalize →
a few refinement Newton steps.  Same labels contract, same metrics,
several times faster — and the gap widens with graph size
(BENCH_multilevel.json has the 131k/524k-node numbers).

    PYTHONPATH=src python examples/multilevel_scaling.py
"""
import dataclasses
import time

from repro.core import PSCConfig, p_spectral_cluster
from repro.graphs import delaunay_graph
from repro.multilevel import MultilevelConfig, build_hierarchy


def main():
    W, _ = delaunay_graph(15, seed=0)       # 32768 nodes, ~196k nnz
    print(f"graph: n={W.n_rows} nnz={W.nnz}")

    # the hierarchy alone: heavy-edge matching halves the graph per level
    h = build_hierarchy(W, coarse_size=2048)
    sizes = " -> ".join(str(l.W.n_rows) for l in h.levels)
    print(f"hierarchy ({h.n_levels} levels): {sizes}")

    cfg = PSCConfig(k=4, p_target=1.4, newton_iters=15, tcg_iters=12,
                    kmeans_restarts=4, seed=0)

    t0 = time.time()
    res_ml = p_spectral_cluster(
        W, dataclasses.replace(cfg, multilevel=MultilevelConfig()))
    t_ml = time.time() - t0

    t0 = time.time()
    res_flat = p_spectral_cluster(W, cfg)
    t_flat = time.time() - t0

    print(f"{'solver':<10} {'RCut':>10} {'wall':>8}")
    print(f"{'flat':<10} {res_flat.rcut:10.5f} {t_flat:7.1f}s")
    print(f"{'V-cycle':<10} {res_ml.rcut:10.5f} {t_ml:7.1f}s")
    print(f"speedup: {t_flat / t_ml:.2f}x, "
          f"RCut gap: {(res_ml.rcut - res_flat.rcut) / res_flat.rcut * 100:+.2f}%")
    n_ref = len(res_ml.levels or [])
    print(f"per-level refinements recorded: {n_ref} "
          f"(levels {sorted({r['level'] for r in res_ml.levels})})")
    assert res_ml.rcut <= res_flat.rcut * 1.02, "V-cycle lost >2% quality"
    print("OK: hierarchical solve matches flat quality at a fraction of "
          "the cost")


if __name__ == "__main__":
    main()
