"""Telemetry walkthrough (DESIGN.md §10): trace a 65k-node multilevel
p-spectral solve end to end and leave a Perfetto-openable timeline
behind.

One flag — ``PSCConfig(trace=True)`` — buys the whole story: nested
spans over coarsening, the coarse solve's per-p continuation levels,
per-level refinement, kmeans, and every eager GraphBLAS SpMM
underneath, all host-clocked with ``block_until_ready`` fencing so a
span's duration is the work it encloses, not dispatch latency.  The
resulting ``PSCResult.telemetry`` exports Chrome trace-event JSON:
load ``trace_psc.json`` at https://ui.perfetto.dev (or
chrome://tracing) to inspect it visually.

The script asserts the ISSUE-9 acceptance bound: the root span's
direct children must account for >= 90% of its wall clock — if the
pipeline ever grows an untraced phase, this example fails before the
trace is written.

    PYTHONPATH=src python examples/trace_psc.py
"""
from pathlib import Path

from repro.core import PSCConfig, p_spectral_cluster
from repro.graphs import delaunay_graph
from repro.multilevel import MultilevelConfig

OUT = Path(__file__).resolve().parent.parent / "trace_psc.json"

# delaunay_graph(16) is a 65,536-vertex triangulation — big enough that
# the multilevel V-cycle (coarsen -> coarse continuation -> refine) is
# the honest serving path, small enough to rerun casually
print("building delaunay_r16 (65k vertices) ...")
W, _ = delaunay_graph(16, seed=0)
cfg = PSCConfig(k=4, p_target=1.4, newton_iters=12, tcg_iters=10,
                kmeans_restarts=4, seed=0,
                multilevel=MultilevelConfig(),
                trace=True)

print(f"clustering n={W.n_rows} nnz={W.nnz} with trace=True ...")
res = p_spectral_cluster(W, cfg)
tel = res.telemetry

print(f"\nrcut={res.rcut:.5f}  total={tel.total_s():.2f}s  "
      f"spans={len(tel.spans)}  events={len(tel.events)}  "
      f"dropped={tel.dropped}")
print("\nphase breakdown (depth-1 spans under the root):")
for name, sec in sorted(tel.phase_breakdown().items(),
                        key=lambda kv: -kv[1]):
    print(f"  {name:<28s} {sec:8.3f}s  "
          f"{100 * sec / tel.total_s():5.1f}%")

cov = tel.coverage()
print(f"\ncoverage: {100 * cov:.1f}% of the root span's wall clock is "
      f"accounted for by its direct children")
assert cov >= 0.9, (
    f"trace coverage {cov:.3f} < 0.9 — a pipeline phase is running "
    f"untraced")

tel.write_chrome(OUT)
print(f"\nwrote {OUT} ({OUT.stat().st_size // 1024} KiB) — open it at "
      f"https://ui.perfetto.dev")
