"""Framework-level integration of the paper's technique: cluster the
MoE expert co-activation graph with GrB-pGrass to derive expert->device
placement groups that minimize cross-group routing (an RCut objective!).

Experts that co-fire for the same tokens want to live on the same
device: a token routed to experts on 2 devices pays 2 partial outputs
into the psum instead of 1.  The co-activation graph (experts = nodes,
co-routing counts = weights) is exactly the balanced-min-cut input the
paper's algorithm optimizes.

    PYTHONPATH=src python examples/expert_affinity.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core import PSCConfig, p_spectral_cluster, metrics
from repro.grblas import SparseMatrix
from repro.models import model as M
from repro.models.moe import _router
from repro.data import SyntheticTokens


def co_activation_graph(cfg, params, n_batches=8, batch=8, seq=64):
    """Run the router over synthetic batches; count expert co-routing."""
    E = cfg.moe.n_experts
    counts = np.zeros((E, E))
    data = SyntheticTokens(cfg, batch=batch, seq=seq, seed=0)
    router_w = params["blocks"]["ffn"]["router"][0]     # first MoE layer
    embed = params["embed"]
    for b in range(n_batches):
        toks = data.batch_at(b)["tokens"]
        x = embed["table"][toks].reshape(-1, cfg.d_model)
        _, ids, _ = _router(cfg, router_w, x)
        ids = np.asarray(ids)                            # (T, top_k)
        for k1 in range(ids.shape[1]):
            for k2 in range(k1 + 1, ids.shape[1]):
                np.add.at(counts, (ids[:, k1], ids[:, k2]), 1.0)
    counts = counts + counts.T
    np.fill_diagonal(counts, 0.0)
    r, c = np.nonzero(counts)
    return SparseMatrix.from_coo(r, c, counts[r, c], (E, E))


def main():
    # a reduced MoE config (mixtral family, 4 experts) for CPU speed;
    # the same pipeline runs on the full 256-expert deepseek graph
    import dataclasses
    from repro.models.config import MoEConfig
    cfg = get_reduced_config("mixtral-8x22b")
    cfg = dataclasses.replace(cfg, moe=MoEConfig(
        n_experts=8, top_k=2, d_expert=64))
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    W = co_activation_graph(cfg, params)
    print(f"expert co-activation graph: {W.n_rows} experts, "
          f"{W.nnz} weighted edges")

    n_groups = 2
    res = p_spectral_cluster(W, PSCConfig(
        k=n_groups, p_target=1.4, newton_iters=10, tcg_iters=8,
        kmeans_restarts=4, seed=0))
    print(f"placement groups (expert -> device group): "
          f"{res.labels.tolist()}")
    rcut_p = res.rcut

    # compare against the naive contiguous placement [0,0,0,0,1,1,1,1]
    naive = np.repeat(np.arange(n_groups), W.n_rows // n_groups)
    rcut_naive = float(metrics.rcut(W, naive, n_groups))
    print(f"cross-group routing cost (RCut): "
          f"pGrass {rcut_p:.2f} vs contiguous {rcut_naive:.2f}")
    if rcut_p <= rcut_naive:
        print("OK: p-spectral placement does not lose to contiguous")
    else:
        print("note: random router => placements statistically equivalent")


if __name__ == "__main__":
    main()
