"""Quickstart: p-spectral clustering (GrB-pGrass) on a planted-partition
graph, compared against classical spectral clustering — the paper's
Table I in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import PSCConfig, p_spectral_cluster, spectral_cluster, metrics
from repro.graphs import gaussian_blobs_knn


def main():
    # 4 overlapping gaussian blobs, gaussian-weighted kNN graph (hard
    # enough that the linear p=2 relaxation makes mistakes)
    W, truth = gaussian_blobs_knn(n_per=50, k_blobs=4, knn=10,
                                  sigma=0.9, spread=2.0, seed=0)
    print(f"graph: n={W.n_rows} nnz={W.nnz}")

    # classical spectral clustering (the 'Spec' baseline)
    labels_spec, rcut_spec = spectral_cluster(W, k=4, seed=0)
    acc_spec = metrics.clustering_accuracy(labels_spec, truth, 4)

    # GrB-pGrass: p-continuation 2.0 -> 1.2 on the Grassmann manifold.
    # backend="auto" routes every SpMM-shaped op through the unified
    # grblas execution API: ELL/COO gather paths here on CPU, the fused
    # Pallas BSR kernels on TPU, "dist" once a mesh is supplied.
    cfg = PSCConfig(k=4, p_target=1.2, hvp_mode="graphblas", seed=0,
                    backend="auto")
    res = p_spectral_cluster(W, cfg)
    acc_p = metrics.clustering_accuracy(res.labels, truth, 4)

    print(f"{'method':<12} {'RCut':>8} {'accuracy':>9}")
    print(f"{'Spec':<12} {rcut_spec:8.4f} {acc_spec:9.3f}")
    print(f"{'GrB-pGrass':<12} {res.rcut:8.4f} {acc_p:9.3f}")
    print(f"p path: {[round(p, 3) for p in res.p_path]}")
    print(f"F_p per level: {[round(v, 5) for v in res.fvals]}")
    print(f"Hessian applies per level: {res.hvp_counts}")
    assert acc_p >= acc_spec, (acc_p, acc_spec)
    print("OK: nonlinear eigenvectors recover the planted clusters better")


if __name__ == "__main__":
    main()
