"""Pallas sparse kernels (interpret mode) vs jnp oracles, shape sweeps.

Exercised through the unified API: Descriptor(backend="bsr_pallas"/
"edge_pallas", interpret=True) is the numerics pin of the TPU kernels.
"""
import numpy as np
import scipy.sparse as sp
import jax.numpy as jnp
import pytest

from repro.grblas import SparseMatrix, Descriptor, mxm
from repro.grblas.semiring import plap_edge_semiring, plap_hvp_edge_semiring
from repro.core import plap


def _mat(n, bs, density=0.08, seed=0, dtype=jnp.float32):
    A = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="coo")
    A = A + A.T  # symmetric like graph matrices
    return SparseMatrix.from_scipy(A, build_bsr=True, block_size=bs,
                                   dtype=dtype)


@pytest.mark.parametrize("n,bs,k", [(64, 16, 4), (100, 32, 3), (256, 128, 8),
                                    (130, 64, 1), (96, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_bsr_spmm_matches_dense(n, bs, k, dtype):
    M = _mat(n, bs, dtype=dtype)
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.standard_normal((n, k)), dtype)
    got = mxm(M, X, desc=Descriptor(backend="bsr_pallas", interpret=True))
    want = np.asarray(M.to_dense()) @ np.asarray(X)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol)
    # and the blocked jnp ref agrees through the backend's CPU path
    got_ref = mxm(M, X, desc=Descriptor(backend="bsr_pallas"))
    np.testing.assert_allclose(np.asarray(got_ref), want, rtol=tol, atol=tol)


@pytest.mark.parametrize("n,bs,k", [(64, 16, 4), (96, 32, 2), (256, 128, 6)])
@pytest.mark.parametrize("p", [2.0, 1.5, 1.2])
def test_plap_apply_kernel(n, bs, k, p):
    M = _mat(n, bs)
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    ring = plap_edge_semiring(p, eps=1e-6)
    got = mxm(M, X, ring, desc=Descriptor(backend="edge_pallas",
                                          interpret=True))
    # oracle: COO edge-semiring from grblas
    want = mxm(M, X, ring, desc=Descriptor(backend="coo"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("n,bs,k", [(64, 16, 3), (256, 128, 4)])
@pytest.mark.parametrize("p", [1.8, 1.3])
def test_plap_hvp_kernel(n, bs, k, p):
    M = _mat(n, bs)
    rng = np.random.default_rng(3)
    U = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0], jnp.float32)
    Eta = jnp.asarray(rng.standard_normal((n, k)) * 0.1, jnp.float32)
    got = mxm(M, (U, Eta), plap_hvp_edge_semiring(p, eps=1e-6),
              desc=Descriptor(backend="edge_pallas", interpret=True))
    # oracle: the HessA part computed by hand in numpy
    d = np.asarray(U)[np.asarray(M.rows)] - np.asarray(U)[np.asarray(M.cols)]
    from repro.core import phi as PHI
    what = np.asarray(M.vals)[:, None] * np.asarray(
        PHI.phi_prime(jnp.asarray(d), p, 1e-6))
    de = np.asarray(Eta)[np.asarray(M.rows)] - np.asarray(Eta)[np.asarray(M.cols)]
    want = np.zeros((n, k))
    np.add.at(want, np.asarray(M.rows), what * de)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_bsr_fill_ratio_reported():
    M = _mat(256, 64)
    assert np.isfinite(M.bsr_fill_ratio()) and M.bsr_fill_ratio() >= 1.0
