"""ClusterServeEngine (DESIGN.md §8): bucketing invariants, padded-batch
solve == flat pipeline (label-exact), one compiled trace per bucket
across a mixed request stream, admission/deadline/lane behavior, and the
hierarchy-patch invariants behind incremental re-clustering."""
import dataclasses
import time

import numpy as np
import jax
import pytest

from repro.core import PSCConfig, metrics, p_spectral_cluster
from repro.core.solvers import registry
from repro.grblas.containers import SparseMatrix
from repro.graphs import delaunay_graph, ring_of_cliques, sbm_graph
from repro.serve import (BucketSpec, ClusterServeEngine, assemble_batch,
                         bucket_for, next_pow2)
from repro.serve.bucketing import pad_embeddings


def _cfg(**kw):
    kw.setdefault("k", 4)
    kw.setdefault("reorder", "none")
    kw.setdefault("newton_iters", 20)
    kw.setdefault("tcg_iters", 12)
    kw.setdefault("kmeans_restarts", 4)
    return PSCConfig(**kw)


def _reweighted(W, scale):
    """Same pattern, distinct quantized weights (a fresh fingerprint)."""
    return W.with_vals(np.asarray(W.vals) * scale)


# ------------------------------------------------------------ bucketing unit

def test_next_pow2():
    assert next_pow2(1) == 1
    assert next_pow2(2) == 2
    assert next_pow2(3) == 4
    assert next_pow2(1025) == 2048
    assert next_pow2(3, floor=64) == 64
    assert next_pow2(0) == 1


def test_bucket_for_lattice_and_floors():
    W, _ = ring_of_cliques(4, 10)          # n=40, nnz=368
    spec = bucket_for(W, 4, "cold")
    assert spec == BucketSpec(n=64, nnz=512, k=4, mode="cold")
    assert spec.key == ("serve", "cold", 64, 512, 4)
    # floors dominate tiny graphs
    tiny = SparseMatrix.from_coo([0, 1], [1, 0], [1.0, 1.0], (2, 2))
    spec = bucket_for(tiny, 2, "warm")
    assert (spec.n, spec.nnz) == (64, 128)
    rect = SparseMatrix.from_coo([0], [1], [1.0], (2, 3))
    with pytest.raises(ValueError, match="square"):
        bucket_for(rect, 2, "cold")


def test_padded_coo_contract():
    W, _ = ring_of_cliques(4, 10)
    r, c, v = W.padded_coo(64, 512)
    assert r.shape == c.shape == v.shape == (512,)
    # real prefix is the graph's own COO
    np.testing.assert_array_equal(r[:W.nnz], np.asarray(W.rows))
    np.testing.assert_array_equal(v[:W.nnz], np.asarray(W.vals))
    # pads are exactly (0, 0, 0.0) — the PR-5 soundness contract
    assert (r[W.nnz:] == 0).all() and (c[W.nnz:] == 0).all()
    assert (v[W.nnz:] == 0.0).all()
    with pytest.raises(ValueError):
        W.padded_coo(32, 512)              # n does not fit
    with pytest.raises(ValueError):
        W.padded_coo(64, 256)              # nnz does not fit


def test_assemble_batch_shapes_and_mask():
    Wa, _ = ring_of_cliques(4, 10)         # n=40
    Wb, _ = ring_of_cliques(4, 6)          # n=24
    spec = BucketSpec(n=64, nnz=512, k=4, mode="cold")
    batch = assemble_batch([Wa, Wb], spec)
    assert batch.rows.shape == batch.cols.shape == batch.vals.shape \
        == (2, 512)
    assert batch.mask.shape == (2, 64)
    assert batch.n_real == (40, 24)
    np.testing.assert_array_equal(batch.mask[0], (np.arange(64) < 40))
    np.testing.assert_array_equal(batch.mask[1], (np.arange(64) < 24))


def test_pad_embeddings_validation():
    spec = BucketSpec(n=64, nnz=128, k=4, mode="warm")
    U = np.ones((40, 4))
    out = pad_embeddings([U], spec)
    assert out.shape == (1, 64, 4)
    assert (out[0, 40:] == 0.0).all()
    with pytest.raises(ValueError):
        pad_embeddings([np.ones((40, 3))], spec)      # wrong k
    with pytest.raises(ValueError):
        pad_embeddings([np.ones((100, 4))], spec)     # does not fit n


# --------------------------------------------------- pad invariance vs flat

@pytest.mark.parametrize("solver", ["newton", "scf"])
@pytest.mark.parametrize("flat_backend", ["coo", "sellcs"])
def test_bucketed_solve_matches_flat(solver, flat_backend):
    """A padded, vmapped bucket solve returns the SAME labels and RCut
    as the flat pipeline on the bare graph — across both bucketable
    drivers and against flat solves on both the coo and the SELL-C-σ
    backend (the latter exercising the Alg-1 (nnz, k) multivalue path
    under the default hvp_mode="graphblas")."""
    W, _ = ring_of_cliques(4, 10)
    if flat_backend == "sellcs":
        W = SparseMatrix.from_coo(W.rows, W.cols, W.vals,
                                  (W.n_rows, W.n_cols), build_sellcs=True)
    cfg = _cfg(solver=solver, backend=flat_backend)
    flat = p_spectral_cluster(W, cfg)

    eng = ClusterServeEngine(dataclasses.replace(cfg, backend="auto"))
    res = eng.serve([W])[0]
    np.testing.assert_array_equal(res.labels, np.asarray(flat.labels))
    assert res.rcut == pytest.approx(flat.rcut, rel=1e-9)
    assert res.stats.lane == "bucket"
    assert res.stats.mode == "cold"
    assert res.stats.bucket == ("serve", "cold", 64, 512, 4)


def test_solo_lane_matches_flat_exactly():
    """Below-threshold bucket cap forces the solo lane, which IS the
    flat pipeline — bit-identical result."""
    W, _ = ring_of_cliques(4, 10)
    cfg = _cfg()
    flat = p_spectral_cluster(W, cfg)
    eng = ClusterServeEngine(cfg, max_bucket_n=16)
    res = eng.serve([W])[0]
    assert res.stats.lane == "solo"
    np.testing.assert_array_equal(res.labels, np.asarray(flat.labels))
    assert res.rcut == flat.rcut
    assert eng.stats.n_solo == 1


def test_unbucketable_solver_routes_solo():
    W, _ = ring_of_cliques(4, 10)
    cfg = _cfg(solver="inverse_power", p_target=1.2, ipm_iters=40)
    eng = ClusterServeEngine(cfg)
    res = eng.serve([W])[0]
    assert res.stats.lane == "solo"
    acc = len(np.unique(res.labels))
    assert acc == 4


# --------------------------------------------------------- trace accounting

def test_one_trace_per_bucket_mixed_stream():
    """>= 20 mixed-size cold requests over two buckets compile exactly
    two serve traces (one per bucket), observable both through the
    registry trace log and EngineStats."""
    Wa, _ = ring_of_cliques(4, 10)         # bucket (64, 512)
    Wb, _ = ring_of_cliques(4, 6)          # bucket (64, 128)
    # unique solver signature so this test owns its trace keys
    cfg = _cfg(solver="scf", scf_sweeps=7, grad_tol=1.07e-5)
    eng = ClusterServeEngine(cfg, max_batch=8)
    rids = []
    for i in range(12):
        rids.append(eng.submit(_reweighted(Wa, 1.0 + 0.01 * i)))
    for i in range(8):
        rids.append(eng.submit(_reweighted(Wb, 1.0 + 0.01 * i)))
    assert len(rids) == 20

    def serve_traces():
        return sum(1 for t in registry.SOLVER_TRACES
                   if t and t[0] == "serve" and 1.07e-5 in t)

    before = serve_traces()
    done = eng.flush()
    assert len(done) == 20
    assert serve_traces() - before == 2     # one per bucket, ever
    assert eng.stats.traces == 2
    assert eng.stats.n_batches == 3         # ceil(12/8) + ceil(8/8)
    # only the compiling batch of each bucket reports trace_new
    new_flags = [done[r].stats.trace_new for r in rids]
    assert sum(new_flags) == 8 + 8          # first batch of each bucket
    # a second wave on fresh weights warm-hits the pattern tier: the
    # only new compile is the warm-mode signature, once
    more = [eng.submit(_reweighted(Wa, 2.0 + 0.01 * i)) for i in range(8)]
    done = eng.flush()
    assert serve_traces() - before == 3
    assert eng.stats.traces == 3
    assert all(done[r].stats.mode == "warm" for r in more)


# ----------------------------------------------------------- warm-start path

def test_warm_exact_hit_reproduces_labels():
    W, _ = ring_of_cliques(4, 10)
    cfg = _cfg()
    eng = ClusterServeEngine(cfg)
    cold = eng.serve([W])[0]
    assert cold.stats.mode == "cold" and cold.stats.cache_tier is None
    warm = eng.serve([W])[0]
    assert warm.stats.mode == "warm"
    assert warm.stats.cache_tier == "exact"
    assert warm.stats.bucket[1] == "warm"   # separate trace signature
    np.testing.assert_array_equal(warm.labels, cold.labels)
    assert warm.rcut == pytest.approx(cold.rcut, rel=1e-9)
    assert eng.cache.hits_exact == 1


def test_warm_pattern_tier_on_reweighted_graph():
    W, _ = ring_of_cliques(4, 10)
    eng = ClusterServeEngine(_cfg())
    cold = eng.serve([W])[0]
    res = eng.serve([_reweighted(W, 1.5)])[0]
    assert res.stats.mode == "warm"
    assert res.stats.cache_tier == "pattern"
    # uniform scaling preserves the optimal partition
    np.testing.assert_array_equal(res.labels, cold.labels)
    assert eng.cache.hits_pattern == 1


# ----------------------------------------------------- queueing + admission

def test_poll_respects_deadline_and_batch_trigger():
    W, _ = ring_of_cliques(4, 10)
    eng = ClusterServeEngine(_cfg(), max_batch=4, max_wait_s=3600.0)
    rid = eng.submit(W)
    assert eng.poll() == {}                 # not due: queue open
    # a full bucket launches regardless of the deadline
    more = [eng.submit(_reweighted(W, 1.0 + 0.01 * i)) for i in range(3)]
    done = eng.poll()
    assert set(done) == {rid, *more}
    assert done[rid].stats.batch_size == 4
    # deadline expiry launches a partial batch
    late = eng.submit(_reweighted(W, 9.0))
    assert late not in eng.poll()
    done = eng.poll(now=time.monotonic() + 3601.0)
    assert late in done and done[late].stats.batch_size == 1


def test_flush_drains_and_take_pops():
    W, _ = ring_of_cliques(4, 10)
    eng = ClusterServeEngine(_cfg(), max_batch=8, max_wait_s=3600.0)
    rids = [eng.submit(_reweighted(W, 1.0 + 0.01 * i)) for i in range(3)]
    done = eng.flush()
    assert set(done) == set(rids)
    first = eng.take(rids[0])
    assert first.req_id == rids[0]
    with pytest.raises(KeyError):
        eng.take(rids[0])
    assert eng.stats.n_requests == 3 and eng.stats.n_results == 3


def test_serve_returns_submission_order():
    Wa, _ = ring_of_cliques(4, 10)
    Wb, _ = ring_of_cliques(4, 6)
    eng = ClusterServeEngine(_cfg())
    out = eng.serve([Wa, Wb, _reweighted(Wa, 1.1)])
    assert [r.stats.n for r in out] == [40, 24, 40]
    assert [r.req_id for r in out] == sorted(r.req_id for r in out)


def test_engine_rejects_reordering_config():
    with pytest.raises(ValueError, match="reorder"):
        ClusterServeEngine(_cfg(reorder="rcm"))


# ----------------------------------------- hierarchy patching (churn lane)

def test_patch_hierarchy_invariants():
    """Patching after a localized edge edit keeps the multilevel
    invariants — partition of unity per prolongator, finest volume/count
    conservation per level — and reuses aggregates away from the edit."""
    from repro.multilevel import build_hierarchy, patch_hierarchy
    from repro.serve import EdgeDelta, apply_edge_delta

    W, _ = delaunay_graph(9, seed=3)                 # n=512, local edits
    hier = build_hierarchy(W, coarse_size=64, max_levels=4)
    assert hier.n_levels >= 3

    rng = np.random.default_rng(0)
    i = rng.integers(0, W.n_rows, 3)
    j = (i + 1 + rng.integers(0, W.n_rows - 1, 3)) % W.n_rows
    delta = EdgeDelta(i, j, np.full(3, 2.0))         # mostly insertions
    d = apply_edge_delta(W, delta)
    assert d.pattern_changed

    patched, records = patch_hierarchy(hier, d.W, d.touched)
    assert patched.n_levels == hier.n_levels
    assert len(records) == hier.n_levels - 1
    total_vol = float(np.sum(np.asarray(patched.levels[0].vol)))
    n0 = W.n_rows
    for lvl in range(patched.n_levels - 1):
        P = patched.prolongators[lvl]
        fine, coarse = patched.levels[lvl], patched.levels[lvl + 1]
        assert P.n_rows == fine.W.n_rows and P.n_cols == coarse.W.n_rows
        # partition of unity: every fine vertex in exactly one aggregate
        rows = np.asarray(P.rows)
        assert len(rows) == fine.W.n_rows
        np.testing.assert_array_equal(np.sort(rows), np.arange(P.n_rows))
        assert np.all(np.asarray(P.vals) == 1.0)
        # conservation of finest mass
        assert float(np.sum(np.asarray(coarse.vol))) \
            == pytest.approx(total_vol, rel=1e-9)
        assert int(np.sum(np.asarray(coarse.counts))) == n0
        assert records[lvl]["n_dirty"] <= fine.W.n_rows
    # locality at the finest level: 3 edited edges dissolve only the
    # distance-1 aggregates (the fraction shrinks as graphs grow; at
    # coarser levels the closure legitimately covers more of the graph)
    assert records[0]["n_kept_aggregates"] >= 0.8 * records[0]["n_coarse"]
    assert records[0]["n_dirty"] < 0.2 * W.n_rows


def test_patch_hierarchy_empty_seed_reuses_everything():
    """A weights-only delta (empty dirty seed) keeps every aggregate:
    only the Galerkin products rebuild."""
    from repro.multilevel import build_hierarchy, patch_hierarchy

    W, _ = delaunay_graph(9, seed=3)
    hier = build_hierarchy(W, coarse_size=64, max_levels=4)
    W2 = W.with_vals(np.asarray(W.vals) * 1.7)
    patched, records = patch_hierarchy(hier, W2, np.empty(0, np.int64))
    for lvl, rec in enumerate(records):
        assert rec["n_rematched"] == 0
        assert rec["n_kept_aggregates"] == rec["n_coarse"]
        np.testing.assert_array_equal(
            np.asarray(patched.prolongators[lvl].rows),
            np.asarray(hier.prolongators[lvl].rows))
        np.testing.assert_array_equal(
            np.asarray(patched.prolongators[lvl].cols),
            np.asarray(hier.prolongators[lvl].cols))
    # Galerkin weights track the rescaling
    assert float(np.sum(np.asarray(patched.coarsest.W.vals))) \
        == pytest.approx(1.7 * float(np.sum(np.asarray(hier.coarsest.W.vals))),
                         rel=1e-6)


def test_engine_update_churn_close_to_scratch():
    """engine.update() on a previously served graph takes the churn
    path and lands within 2% RCut of a from-scratch solve of the edited
    graph (the serve_bench acceptance bound)."""
    from repro.serve import EdgeDelta, apply_edge_delta

    W, _ = sbm_graph([40, 40, 40, 40], 0.25, 0.02, seed=0)
    cfg = _cfg()
    eng = ClusterServeEngine(cfg)
    eng.serve([W])                                    # prime the cache

    rng = np.random.default_rng(1)
    und = np.asarray(W.rows) < np.asarray(W.cols)
    ei = np.flatnonzero(und)
    pick = rng.choice(ei, max(1, int(0.01 * len(ei))), replace=False)
    delta = EdgeDelta(np.asarray(W.rows)[pick], np.asarray(W.cols)[pick],
                      np.zeros(len(pick)))            # 1% edge knockouts
    rid = eng.update(W, delta)
    res = eng.flush()[rid]
    assert res.stats.mode == "churn"
    assert eng.stats.n_churn == 1

    W_new = apply_edge_delta(W, delta).W
    scratch = p_spectral_cluster(W_new, cfg)
    assert res.rcut <= scratch.rcut * 1.02 + 1e-12
