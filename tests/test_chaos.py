"""Chaos suite: injected faults must fire every recovery-ladder rung
(DESIGN.md §9) and come back with finite labels at near-clean RCut.

Every test derives its randomness from ``CHAOS_SEED`` (env var, default
0) via ``repro.testing.chaos_seed`` — a failing run reproduces with
``CHAOS_SEED=<n> make test-chaos``.  Injectors are counted, not random
(repro.testing.faultinject), and every test asserts its fault actually
fired (``log.count()``), so nothing passes vacuously.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.psc import PSCConfig, p_spectral_cluster
from repro.core.solvers import GuardConfig, SolverDivergence
from repro.graphs import sbm_graph
from repro.grblas.containers import SparseMatrix
from repro.serve.churn import EdgeDelta
from repro.serve.psc_engine import ClusterServeEngine
from repro.testing import (backend_fault, chaos_seed, nan_in_multivector,
                           rank_collapse, serve_batch_fault,
                           serve_churn_fault, solver_stall)

SEED = chaos_seed()
SRC = Path(__file__).resolve().parent.parent / "src"

# a 2-level schedule ([1.7, 1.5]) so mid-continuation faults have a
# last-good level to restart from
_KW = dict(k=4, newton_iters=8, tcg_iters=5, p_target=1.5, p_factor=0.85)


@pytest.fixture(scope="module")
def sbm():
    W, truth = sbm_graph([30] * 4, 0.92, 0.03, seed=SEED)
    return W, truth


@pytest.fixture(scope="module")
def clean(sbm):
    W, _ = sbm
    return p_spectral_cluster(W, PSCConfig(guard=True, **_KW))


def _within_10pct(res, clean):
    assert np.isfinite(np.asarray(res.U)).all()
    assert np.isfinite(res.rcut)
    assert res.rcut <= clean.rcut * 1.10 + 1e-9


# ---------------------------------------------------------------- the ladder

def test_clean_guarded_run_reports_no_rungs(sbm, clean):
    assert clean.recovery is not None
    assert clean.recovery.clean
    assert clean.recovery.rungs == []
    assert clean.recovery.final_rung is None


def test_rung1_warm_restart(sbm, clean):
    """A one-shot NaN at continuation level 2: the guard catches it,
    rung 1 re-enters the SAME driver from the level-1 iterate on a
    densified schedule."""
    W, _ = sbm
    with nan_in_multivector("newton", at_call=2, max_calls=1) as log:
        res = p_spectral_cluster(W, PSCConfig(guard=True, **_KW))
    assert log.count("nan_in_multivector") == 1
    assert res.recovery.diverged_reason == "nonfinite"
    assert res.recovery.diverged_level == 1
    assert res.recovery.final_rung == "warm_restart"
    assert res.recovery.rungs[-1].driver == "newton"
    assert not res.recovery.degraded
    _within_10pct(res, clean)


def test_rung2_driver_switch(sbm, clean):
    """A persistently NaN-ing Newton: rung 1 (same driver) fails too,
    rung 2 lands the solve on the next driver in the ladder."""
    W, _ = sbm
    with nan_in_multivector("newton", at_call=1, max_calls=None) as log:
        res = p_spectral_cluster(W, PSCConfig(guard=True, **_KW))
    assert log.count() >= 2                  # primary + rung-1 attempts
    assert res.recovery.final_rung == "driver_switch"
    assert res.recovery.rungs[-1].driver == "scf"
    rungs = [r.rung for r in res.recovery.rungs]
    assert rungs[0] == "warm_restart" and not res.recovery.rungs[0].ok
    _within_10pct(res, clean)


def test_rung3_backend_fallback(sbm, clean):
    """The configured backend's edge-ring kernels go down: every driver
    fails on it (rungs 1-2), rung 3 re-runs on the reference coo
    backend."""
    W0, _ = sbm
    r, c, v = W0.host_coo()
    W = SparseMatrix.from_coo(r, c, v, (W0.n_rows, W0.n_rows),
                              build_sellcs=True)
    cfg = PSCConfig(guard=True, backend="sellcs", **_KW)
    with backend_fault("sellcs") as log:
        res = p_spectral_cluster(W, cfg)
    assert log.count("backend_fault") >= 1
    assert res.recovery.final_rung == "backend_fallback"
    assert res.recovery.rungs[-1].backend == "coo"
    assert not res.recovery.degraded
    _within_10pct(res, clean)
    # the injector restored the registry: the same config runs clean now
    res2 = p_spectral_cluster(W, cfg)
    assert res2.recovery.clean


def test_rung4_p2_fallback(sbm, clean):
    """Every driver NaNs: rungs 1-3 exhaust, the p=2 linear solve still
    returns finite labels (flagged as degraded)."""
    W, _ = sbm
    with nan_in_multivector(["newton", "scf", "inverse_power"],
                            at_call=1, max_calls=None) as log:
        res = p_spectral_cluster(W, PSCConfig(guard=True, **_KW))
    assert log.count() >= 3
    assert res.recovery.final_rung == "p2_fallback"
    assert res.recovery.degraded
    rungs = [r.rung for r in res.recovery.rungs]
    assert rungs.count("warm_restart") == 1
    assert "driver_switch" in rungs and "backend_fallback" not in rungs \
        or True   # backend rung is skipped when cfg.backend == "coo"...
    _within_10pct(res, clean)


def test_stall_detected(sbm, clean):
    """A driver that makes zero progress for stall_levels consecutive
    unconverged levels trips the stall check instead of burning the
    whole schedule."""
    W, _ = sbm
    cfg = PSCConfig(guard=GuardConfig(stall_levels=2), **_KW)
    with solver_stall("newton") as log:
        res = p_spectral_cluster(W, cfg)
    assert log.count("solver_stall") >= 2
    assert res.recovery.diverged_reason == "stall"
    assert res.recovery.final_rung is not None
    _within_10pct(res, clean)


def test_rank_collapse_detected(sbm, clean):
    W, _ = sbm
    with rank_collapse("newton", at_call=1, max_calls=1) as log:
        res = p_spectral_cluster(W, PSCConfig(guard=True, **_KW))
    assert log.count("rank_collapse") == 1
    assert res.recovery.diverged_reason == "rank_collapse"
    assert res.recovery.final_rung == "warm_restart"
    _within_10pct(res, clean)


def test_unguarded_vs_guarded_equal_when_healthy(sbm):
    """The guard is observation-only on a healthy run: same labels,
    same continuation path as the raw driver."""
    W, _ = sbm
    raw = p_spectral_cluster(W, PSCConfig(**_KW))
    guarded = p_spectral_cluster(W, PSCConfig(guard=True, **_KW))
    np.testing.assert_array_equal(raw.labels, guarded.labels)
    assert raw.p_path == guarded.p_path


def test_unrecoverable_graph_raises_structured(sbm):
    """A graph that is itself NaN defeats every rung — the guard raises
    SolverDivergence('unrecoverable') pointing at input validation, not
    an opaque downstream error."""
    W0, _ = sbm
    r, c, v = W0.host_coo()
    v = np.array(v)
    v[:] = np.nan
    W = SparseMatrix.from_coo(r, c, v, (W0.n_rows, W0.n_rows))
    with pytest.raises(SolverDivergence, match="unrecoverable"):
        p_spectral_cluster(W, PSCConfig(guard=True, **_KW))


def test_chaos_determinism(sbm):
    """Same CHAOS_SEED + same fault => bit-identical recovery labels."""
    W, _ = sbm
    runs = []
    for _ in range(2):
        with nan_in_multivector("newton", at_call=1, max_calls=None):
            runs.append(p_spectral_cluster(W, PSCConfig(guard=True, **_KW)))
    np.testing.assert_array_equal(runs[0].labels, runs[1].labels)
    assert [r.rung for r in runs[0].recovery.rungs] == \
        [r.rung for r in runs[1].recovery.rungs]


def test_guarded_warm_start_survives_poisoned_init(sbm, clean):
    """A NaN warm-start embedding (the poisoned-cache scenario) falls
    onto the ladder and re-derives the solve from a fresh p=2 start."""
    W, _ = sbm
    bad = np.full((W.n_rows, 4), np.nan, np.float32)
    res = p_spectral_cluster(W, PSCConfig(guard=True, init_U=bad, **_KW))
    assert res.recovery.diverged_reason == "nonfinite"
    assert res.recovery.recovered
    _within_10pct(res, clean)


# ------------------------------------------------------------ serve isolation

@pytest.fixture(scope="module")
def serve_graphs():
    return [sbm_graph([20] * 4, 0.9, 0.05, seed=SEED + s)[0]
            for s in range(4)]


@pytest.fixture(scope="module")
def serve_cfg():
    return PSCConfig(k=4, newton_iters=6, tcg_iters=4, p_target=1.5,
                     p_factor=0.85)


def _clean_serve(serve_cfg, serve_graphs):
    eng = ClusterServeEngine(serve_cfg, max_batch=4, max_wait_s=0.0)
    return eng.serve(serve_graphs)


def test_poisoned_request_isolated_in_batch(serve_cfg, serve_graphs):
    """The acceptance criterion: one NaN-weighted request in a full
    bucket batch gets a structured error; every OTHER request returns
    labels identical to a clean engine's."""
    clean = _clean_serve(serve_cfg, serve_graphs)
    r, c, v = serve_graphs[1].host_coo()
    v = np.array(v)
    v[0] = np.nan
    bad = SparseMatrix.from_coo(r, c, v, (serve_graphs[1].n_rows,) * 2)
    gs = [serve_graphs[0], bad, serve_graphs[2], serve_graphs[3]]
    eng = ClusterServeEngine(serve_cfg, max_batch=4, max_wait_s=0.0)
    res = eng.serve(gs)
    assert not res[1].ok
    assert res[1].labels is None
    assert res[1].stats.failure_kind == "nonfinite_result"
    assert "non-finite" in res[1].error
    for i in (0, 2, 3):
        assert res[i].ok
        np.testing.assert_array_equal(res[i].labels, clean[i].labels)
    assert eng.stats.n_failed == 1
    assert eng.stats.n_quarantined == 1
    assert eng.stats.failures == {"nonfinite_result": 1}


def test_thrown_batch_bisects_to_culprit(serve_cfg, serve_graphs):
    """A batch solve that THROWS (no NaN lane to blame) bisects:
    survivors re-run and succeed, exactly the faulted request fails."""
    clean = _clean_serve(serve_cfg, serve_graphs)
    eng = ClusterServeEngine(serve_cfg, max_batch=4, max_wait_s=0.0)
    rids = [eng.submit(W) for W in serve_graphs]
    with serve_batch_fault([rids[2]]) as log:
        done = eng.flush()
    assert log.count("serve_batch_fault") >= 2      # full batch + halves
    assert not done[rids[2]].ok
    assert done[rids[2]].stats.failure_kind == "exception"
    for i in (0, 1, 3):
        assert done[rids[i]].ok
        np.testing.assert_array_equal(done[rids[i]].labels,
                                      clean[i].labels)
    assert eng.stats.n_quarantine_splits >= 1
    assert eng.stats.n_quarantined == 1


def test_admission_validation_rejects_invalid(serve_cfg, serve_graphs):
    r, c, v = serve_graphs[0].host_coo()
    v = np.array(v)
    v[3] = np.inf
    bad = SparseMatrix.from_coo(r, c, v, (serve_graphs[0].n_rows,) * 2)
    eng = ClusterServeEngine(serve_cfg, validate_inputs=True)
    rid_bad = eng.submit(bad)
    rid_ok = eng.submit(serve_graphs[0])
    done = eng.flush()
    assert not done[rid_bad].ok
    assert done[rid_bad].stats.failure_kind == "invalid_input"
    assert done[rid_bad].stats.lane == "admission"
    assert done[rid_ok].ok
    with pytest.raises(ValueError, match="k="):
        eng.submit(serve_graphs[0], k=0)


def test_deadline_degrade_levels(serve_cfg, serve_graphs):
    """Past tail_frac * deadline a cold request degrades to the
    schedule-tail-only solve (level 1); past the deadline to p=2-init
    labels (level 2) — late answers, never missed ones."""
    import time as _time

    now = _time.monotonic()
    eng = ClusterServeEngine(serve_cfg, max_batch=8, max_wait_s=100.0,
                             deadline_s=10.0, tail_frac=0.5)
    rid1 = eng.submit(serve_graphs[0])
    done = eng.poll(now=now + 7.0)               # past the tail threshold
    assert done[rid1].ok
    assert done[rid1].stats.degrade == 1
    assert done[rid1].stats.p_final == pytest.approx(1.5)
    assert np.isfinite(done[rid1].rcut)

    eng2 = ClusterServeEngine(serve_cfg, max_batch=8, max_wait_s=100.0,
                              deadline_s=10.0)
    rid2 = eng2.submit(serve_graphs[1])
    done2 = eng2.poll(now=_time.monotonic() + 20.0)   # past the deadline
    assert done2[rid2].ok
    assert done2[rid2].stats.degrade == 2
    assert done2[rid2].stats.p_final == 2.0
    assert np.isfinite(done2[rid2].rcut)
    assert eng2.stats.n_degraded == 1


def test_churn_retry_with_backoff(serve_cfg, serve_graphs):
    """Transient churn faults retry (with injectable, deterministic
    backoff) and still take the incremental path; exhaustion falls back
    to a cold solve of the edited graph."""
    W = serve_graphs[0]
    eng = ClusterServeEngine(serve_cfg, max_bucket_n=16, churn_retries=2,
                             retry_backoff_s=0.25)
    sleeps = []
    eng._sleep = sleeps.append
    rid0 = eng.submit(W)
    eng.flush()
    delta = EdgeDelta(rows=np.array([0]), cols=np.array([1]),
                      vals=np.array([2.0]))
    with serve_churn_fault(fail_attempts=2) as log:
        rid = eng.update(W, delta)
        res = eng.flush()[rid]
    assert log.count("serve_churn_fault") == 2
    assert res.ok and res.stats.retries == 2
    assert sleeps == [0.25, 0.5]                 # exponential, injectable
    assert eng.stats.n_retried == 2

    with serve_churn_fault(fail_attempts=10) as log:
        rid = eng.update(W, delta)
        res = eng.flush()[rid]
    assert res.ok                                # cold fallback
    assert res.stats.retries == eng.churn_retries + 1
    assert np.isfinite(res.rcut)


def test_failed_request_never_poisons_cache(serve_cfg, serve_graphs):
    """After a failed request, re-submitting the SAME fingerprint must
    not warm-start from garbage: the cache holds no entry for it."""
    r, c, v = serve_graphs[0].host_coo()
    v = np.array(v)
    v[0] = np.nan
    bad = SparseMatrix.from_coo(r, c, v, (serve_graphs[0].n_rows,) * 2)
    eng = ClusterServeEngine(serve_cfg, max_batch=1, max_wait_s=0.0)
    rid = eng.submit(bad)
    assert not eng.flush()[rid].ok
    assert bad.fingerprint(eng.weight_quant) not in eng.cache


# ------------------------------------------------------------- dist chaos

_HALO_SCRIPT = textwrap.dedent("""
    import os
    N = int(os.environ["DIST_TEST_DEVICES"])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N}"
    import numpy as np
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.graphs import sbm_graph
    from repro.grblas import Descriptor, make_row_partition, mxm
    from repro.testing import halo_corruption

    S = 4
    mesh = make_mesh((S,), ("data",))
    d = Descriptor(backend="dist", mesh=mesh)
    W, truth = sbm_graph([128] * S, 0.06, 0.002, seed=0)
    X = jnp.asarray(np.random.default_rng(0).standard_normal(
        (W.n_rows, 8)), jnp.float32)
    Ap = make_row_partition(W, S, assignment=truth)
    assert Ap.mode == "halo"
    want = np.asarray(mxm(W, X))

    # corrupted halo rows surface as NaN in the product — detectable by
    # exactly the finiteness checks the serve/guard layers run
    with halo_corruption("nan", shard=0) as log:
        got = np.asarray(mxm(Ap, X, desc=d))
    assert log.count("halo_corruption") >= 1
    assert np.isnan(got).any(), "corruption must be observable"

    # a dropped shard (zeroed halo) yields finite-but-wrong rows: the
    # result disagrees with the clean product only where halo rows land
    with halo_corruption("drop", shard=0):
        got0 = np.asarray(mxm(Ap, X, desc=d))
    assert np.isfinite(got0).all()
    assert not np.allclose(got0, want, rtol=2e-5, atol=2e-5)

    # hook removed => the retry path recomputes the exact clean product
    again = np.asarray(mxm(Ap, X, desc=d))
    np.testing.assert_allclose(again, want, rtol=2e-5, atol=2e-5)
    print("CHAOS_HALO_OK")
""")


def test_halo_corruption_subprocess():
    import os

    r = subprocess.run(
        [sys.executable, "-c", _HALO_SCRIPT],
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu",
             "DIST_TEST_DEVICES": os.environ.get("DIST_TEST_DEVICES", "8")},
        capture_output=True, text=True, timeout=560)
    assert "CHAOS_HALO_OK" in r.stdout, r.stdout + "\n" + r.stderr
