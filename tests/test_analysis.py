"""Self-tests for pscheck (repro.analysis) — DESIGN.md §11.

Every shipped rule gets one *positive* fixture (a minimal snippet that
violates the invariant and must be flagged) and one *negative* fixture
(the compliant counterpart that must stay silent).  Contexts are built
with synthetic ``repro``-relative paths so the scope tables in
``analysis/profile.py`` apply without touching the real tree; the
end-to-end channels (suppressions, meta-rules, baseline, fixers, CLI)
run against real temp files.  The final test pins ``src/repro`` clean
modulo the committed baseline — the same gate ``make lint`` and CI run.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro import analysis
from repro.analysis.core import ModuleContext, ProjectContext

REPO = Path(__file__).resolve().parent.parent


def _ctx(rel: str, source: str) -> ModuleContext:
    """A parsed module at a synthetic repro-relative path (never read
    from disk — source is given)."""
    return ModuleContext(Path("/fx/repro") / rel,
                         source=textwrap.dedent(source))


def _findings(rule_id: str, *ctxs):
    rule = analysis.registered_rules()[rule_id]
    out = []
    for ctx in ctxs:
        if rule.check is not None:
            out.extend(rule.check(ctx))
    if rule.project_check is not None:
        out.extend(rule.project_check(ProjectContext(list(ctxs))))
    return [f for f in out if f.rule == rule_id]


def _rules_of(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------- hot-purity

def test_hot_purity_positive():
    bad = _ctx("core/solvers/newtonish.py", """
        import numpy as np
        import jax, jax.numpy as jnp
        from scipy.sparse.linalg import eigsh

        @jax.jit
        def run(x):
            return jnp.asarray(np.sum(x))
    """)
    fs = _findings("hot-purity", bad)
    msgs = " ".join(f.message for f in fs)
    assert "scipy import" in msgs          # banned outright in core/solvers/
    assert "traced scope" in msgs          # np.sum inside the jitted body
    assert any(f.symbol == "run" for f in fs)


def test_hot_purity_negative():
    # jnp-only solver code, and *host-side* numpy in an unscoped module
    good = _ctx("core/solvers/ok.py", """
        import jax, jax.numpy as jnp

        @jax.jit
        def run(x):
            return jnp.sum(x * x)
    """)
    host = _ctx("serve/queue.py", """
        import numpy as np

        def enqueue(items):
            return np.asarray(items)      # host assembly: legitimate
    """)
    assert _findings("hot-purity", good, host) == []


def test_hot_purity_fixer_rewrites_np_to_jnp(tmp_path):
    f = tmp_path / "repro" / "core" / "plap.py"
    f.parent.mkdir(parents=True)
    f.write_text(textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np

        def norm(x):
            return np.sqrt(np.sum(x * x))
    """))
    changed = analysis.apply_fixes([f], rules=["hot-purity"])
    assert f in changed
    src = f.read_text()
    assert "return jnp.sqrt(jnp.sum(x * x))" in src


# ----------------------------------------------------------- dense-matmul

def test_dense_matmul_positive():
    bad = _ctx("multilevel/galerkin.py", """
        import jax.numpy as jnp

        def coarse(P, A):
            dense = A.toarray()
            return P.T @ jnp.einsum('ij,jk->ik', dense, P)
    """)
    msgs = " ".join(f.message for f in _findings("dense-matmul", bad))
    assert "'@'" in msgs and "einsum" in msgs and "toarray" in msgs


def test_dense_matmul_negative():
    # api.mxm routing in multilevel is the contract; '@' outside the
    # multilevel package (scf's small V.T @ U) is not this rule's scope
    good = _ctx("multilevel/galerkin.py", """
        from repro.grblas import api

        def coarse(P, W, desc):
            WP = api.mxm(W, P.dense, desc=desc)
            return api.mxm(P.transpose(), WP, desc=desc)
    """)
    elsewhere = _ctx("core/solvers/scf.py", """
        def rayleigh(V, U):
            return V.T @ U
    """)
    assert _findings("dense-matmul", good, elsewhere) == []


# -------------------------------------------------------------- host-sync

def test_host_sync_positive():
    bad = _ctx("serve/lane.py", """
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            r = x * 2
            a = float(r)
            b = np.asarray(r)
            c = r.item()
            return a, b, c
    """)
    fs = _findings("host-sync", bad)
    msgs = " ".join(f.message for f in fs)
    assert "float() concretizes" in msgs
    assert "np.asarray" in msgs
    assert ".item()" in msgs


def test_host_sync_negative():
    good = _ctx("serve/lane.py", """
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            n = float(x.shape[0])      # static metadata: fine
            return x * n

        def host_read(res):
            return float(res.fval)     # outside any trace: fine
    """)
    assert _findings("host-sync", good) == []


# ---------------------------------------------------------- traced-branch

def test_traced_branch_positive():
    bad = _ctx("serve/lane.py", """
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x):
            if jnp.sum(x) > 0:
                x = x + 1
            return x
    """)
    fs = _findings("traced-branch", bad)
    assert len(fs) == 1 and "lax.cond" in fs[0].message


def test_traced_branch_negative():
    good = _ctx("serve/lane.py", """
        import jax, jax.numpy as jnp

        @jax.jit
        def step(x, mode="fast"):
            if mode == "fast":         # static closure compare: fine
                return x * 2
            return x

        def host(x):
            if jnp.sum(x) > 0:         # eager host code: fine
                return 1
            return 0
    """)
    assert _findings("traced-branch", good) == []


# --------------------------------------------------------- retrace-static

def test_retrace_static_positive():
    bad = _ctx("core/solvers/driver.py", """
        import jax

        @jax.jit
        def step(x, cfg):
            return x * cfg.scale

        def build(desc):
            def body(x, desc):
                return x
            return jax.jit(body)
    """)
    fs = _findings("retrace-static", bad)
    assert len(fs) == 2
    assert any("cfg" in f.message for f in fs)
    assert any("desc" in f.message for f in fs)


def test_retrace_static_negative():
    good = _ctx("core/solvers/driver.py", """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("cfg",))
        def step(x, cfg):
            return x * cfg.scale

        @jax.jit
        def plain(x, y):
            return x + y
    """)
    assert _findings("retrace-static", good) == []


# -------------------------------------------------------- retrace-loop-jit

def test_retrace_loop_jit_positive():
    bad = _ctx("serve/engine.py", """
        import jax

        def sweep(fns, x):
            out = []
            for fn in fns:
                out.append(jax.jit(fn)(x))
            return out
    """)
    fs = _findings("retrace-loop-jit", bad)
    assert len(fs) == 1 and "memoized" in fs[0].message


def test_retrace_loop_jit_negative():
    good = _ctx("serve/engine.py", """
        import jax
        from repro.core.solvers import registry

        def sweep(fn, xs):
            jfn = jax.jit(fn)                    # hoisted: one trace
            return [jfn(x) for x in xs]

        def memo_sweep(keys, build):
            out = []
            for k in keys:
                out.append(registry.memoized(k, lambda: jax.jit(build)))
            return out
    """)
    assert _findings("retrace-loop-jit", good) == []


# -------------------------------------------------- retrace-mutable-default

def test_retrace_mutable_default_positive():
    bad = _ctx("serve/engine.py", """
        import jax

        @jax.jit
        def step(x, opts={}):
            return x
    """)
    fs = _findings("retrace-mutable-default", bad)
    assert len(fs) == 1 and "opts={}" in fs[0].message


def test_retrace_mutable_default_negative():
    good = _ctx("serve/engine.py", """
        import jax

        @jax.jit
        def step(x, opts=None):
            return x

        def host_helper(x, acc=[]):    # untraced def: not this rule's job
            acc.append(x)
            return acc
    """)
    assert _findings("retrace-mutable-default", good) == []


def test_retrace_mutable_default_fixer(tmp_path):
    f = tmp_path / "repro" / "serve" / "engine.py"
    f.parent.mkdir(parents=True)
    f.write_text(textwrap.dedent("""
        import jax

        @jax.jit
        def step(x, opts={}):
            \"\"\"Doc.\"\"\"
            return x
    """))
    changed = analysis.apply_fixes([f], rules=["retrace-mutable-default"])
    assert f in changed
    src = f.read_text()
    assert "opts=None" in src
    assert "if opts is None:" in src
    # the guard lands after the docstring and the repaired module is clean
    assert src.index('"""Doc."""') < src.index("if opts is None:")
    assert _findings("retrace-mutable-default",
                     ModuleContext(f, source=src)) == []


# ------------------------------------------------------------ api-boundary

def test_api_boundary_positive():
    bad = _ctx("core/aggregate.py", """
        import jax
        from repro.kernels.sellcs_spmm.ref import sellcs_spmm_ref
        from repro.grblas import backends as _backends

        def fold(x, ids):
            orig = _backends._REGISTRY["coo"]
            return jax.ops.segment_sum(x, ids), orig
    """)
    fs = _findings("api-boundary", bad)
    msgs = " ".join(f.message for f in fs)
    assert "segment_sum" in msgs
    assert "sparse kernel" in msgs
    assert "private registry" in msgs


def test_api_boundary_negative():
    # the same shapes inside grblas/ are the implementation itself
    good = _ctx("grblas/api.py", """
        import jax
        from repro.kernels.sellcs_spmm.ref import sellcs_spmm_ref
        from repro.grblas import backends as _backends

        def execute(x, ids):
            _ = _backends._REGISTRY
            return jax.ops.segment_sum(x, ids)
    """)
    assert _findings("api-boundary", good) == []


# ---------------------------------------------------------------- pad-fold

def test_pad_fold_positive():
    bad = _ctx("grblas/semiring.py", """
        import jax.numpy as jnp

        def fold_rows(padded_vals):
            return jnp.sum(padded_vals, axis=1)
    """)
    fs = _findings("pad-fold", bad)
    assert len(fs) == 1 and "pad slots" in fs[0].message


def test_pad_fold_negative_masked_and_registered():
    good = _ctx("grblas/semiring.py", """
        import jax.numpy as jnp

        def fold_rows(vals, cols, n):
            valid = jnp.where(cols < n, vals, 0.0)
            return jnp.sum(valid, axis=1)

        register_ring_fast_paths(
            "plus_times",
            padded=lambda vals: jnp.sum(vals, axis=1),
        )
    """)
    assert _findings("pad-fold", good) == []


def test_pad_fold_negative_capability_gated_kernel():
    # a kernel entry point imported by grblas/backends.py runs only
    # behind a supports gate — its internal folds are claimed
    backends = _ctx("grblas/backends.py", """
        from repro.kernels.sellcs_spmm import sellcs_spmm_ref
    """)
    kernel = _ctx("kernels/sellcs_spmm/ref.py", """
        import jax.numpy as jnp

        def sellcs_spmm_ref(vals, gathered):
            return _fold(vals * gathered)

        def _fold(contrib):
            return jnp.sum(contrib, axis=1)
    """)
    assert _findings("pad-fold", backends, kernel) == []


# ------------------------------------------------------------ dtype-hygiene

def test_dtype_hygiene_positive():
    bad = _ctx("core/phi.py", """
        import numpy as np
        import jax.numpy as jnp

        def widen(x, n):
            a = jnp.zeros(n, dtype=jnp.float64)
            b = jnp.asarray(x, np.int64)
            return a, b
    """)
    builder = _ctx("grblas/containers.py", """
        import numpy as np
        import jax.numpy as jnp

        def _build_ell(self, cols):
            self.ell_cols = jnp.asarray(cols)     # unpinned boundary
    """)
    fs = _findings("dtype-hygiene", bad, builder)
    msgs = " ".join(f.message for f in fs)
    assert "jnp.float64" in msgs
    assert "np.int64" in msgs
    assert "layout builder" in msgs


def test_dtype_hygiene_negative():
    # host-side 64-bit staging is the intended architecture: numpy fold
    # keys etc. are pinned down to 32-bit at the jnp boundary
    host = _ctx("multilevel/coarsen.py", """
        import numpy as np

        def match(rows, cols):
            key = rows.astype(np.int64) * (1 << 32) + cols
            return np.unique(key)
    """)
    builder = _ctx("grblas/containers.py", """
        import numpy as np
        import jax.numpy as jnp

        def _build_ell(self, n, w, dtype):
            cols = np.empty((n, w), np.int32)
            vals = np.zeros((n, w), np.dtype(dtype))
            self.ell_cols = jnp.asarray(cols)     # host array already pinned
            self.ell_vals = jnp.asarray(vals)
    """)
    assert _findings("dtype-hygiene", host, builder) == []


# ------------------------------------------------------------ registry-span

def test_registry_span_positive():
    backends = _ctx("grblas/backends.py", """
        @register_backend("coo", cpu_priority=10)
        def _coo():
            pass
    """)
    fs = _findings("registry-span", backends)
    assert len(fs) == 1 and "'coo'" in fs[0].message


def test_registry_span_negative_dynamic_chokepoint():
    backends = _ctx("grblas/backends.py", """
        @register_backend("coo", cpu_priority=10)
        def _coo():
            pass

        @register_backend("ell", cpu_priority=20)
        def _ell():
            pass
    """)
    api = _ctx("grblas/api.py", """
        def mxm(A, X, be, tele):
            with tele.span("grblas.mxm", backend=be.name):
                return be.execute(A, X)
    """)
    assert _findings("registry-span", backends, api) == []


def test_registry_span_guards_registry_relocation():
    # backends.py with zero register_backend calls: the rule proves
    # nothing and says so rather than passing vacuously
    moved = _ctx("grblas/backends.py", """
        def nothing_here():
            pass
    """)
    fs = _findings("registry-span", moved)
    assert len(fs) == 1 and "registry moved" in fs[0].message


# -------------------------------------------- suppressions and meta-rules

def _write_module(tmp_path, rel, source):
    f = tmp_path / "repro" / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    return f


def test_suppression_with_reason_silences(tmp_path):
    f = _write_module(tmp_path, "multilevel/probe.py", """
        def probe(A, B):
            # pscheck: disable=dense-matmul (3x3 diagnostic block, not a coarse operator)
            return A @ B
    """)
    assert analysis.run([f], rules=["dense-matmul"]) == []


def test_suppression_same_line_form(tmp_path):
    f = _write_module(tmp_path, "multilevel/probe.py", """
        def probe(A, B):
            return A @ B  # pscheck: disable=dense-matmul (tiny diagnostic)
    """)
    assert analysis.run([f], rules=["dense-matmul"]) == []


def test_suppression_without_reason_is_flagged(tmp_path):
    f = _write_module(tmp_path, "multilevel/probe.py", """
        def probe(A, B):
            return A @ B  # pscheck: disable=dense-matmul
    """)
    rules = _rules_of(analysis.run([f], rules=["dense-matmul"]))
    assert rules == ["suppression-reason"]


def test_unused_suppression_is_flagged(tmp_path):
    f = _write_module(tmp_path, "multilevel/probe.py", """
        def probe(A, B):
            # pscheck: disable=dense-matmul (left over after the fix)
            return A + B
    """)
    fs = analysis.run([f], rules=["dense-matmul"])
    assert _rules_of(fs) == ["unused-suppression"]
    assert "delete the directive" in fs[0].message


def test_parse_error_is_a_finding(tmp_path):
    f = _write_module(tmp_path, "multilevel/broken.py", """
        def probe(A, B:
            return A
    """)
    fs = analysis.run([f])
    assert _rules_of(fs) == ["parse-error"]


# ----------------------------------------------------------------- baseline

def _mk_finding(**kw):
    base = dict(rule="dense-matmul", path="multilevel/x.py", line=3, col=4,
                message="dense '@' product", symbol="probe")
    base.update(kw)
    return analysis.Finding(**base)


def test_baseline_round_trip_and_split(tmp_path):
    bl = tmp_path / "baseline.json"
    known = _mk_finding()
    analysis.write_baseline([known], bl)
    data = json.loads(bl.read_text())
    assert data["version"] == 1 and len(data["entries"]) == 1
    # key is (rule, path, symbol, message) — line moves are invisible
    moved = _mk_finding(line=99)
    fresh = _mk_finding(path="multilevel/y.py")
    new, stale = analysis.apply_baseline([moved, fresh],
                                         analysis.load_baseline(bl))
    assert new == [fresh] and stale == []


def test_baseline_is_shrink_only(tmp_path):
    bl = tmp_path / "baseline.json"
    analysis.write_baseline([_mk_finding()], bl)
    # the violation is gone but the ledger entry remains: stale -> error
    new, stale = analysis.apply_baseline([], analysis.load_baseline(bl))
    assert new == [] and len(stale) == 1
    with pytest.raises(AssertionError, match="shrink the ledger"):
        analysis.assert_clean([], baseline=bl)


def test_assert_clean_reports_findings(tmp_path):
    f = _write_module(tmp_path, "multilevel/probe.py", """
        def probe(A, B):
            return A @ B
    """)
    with pytest.raises(AssertionError, match="dense-matmul"):
        analysis.assert_clean([f], rules=["dense-matmul"])


# ---------------------------------------------------------------------- CLI

def _cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd or REPO)


def test_cli_list_rules():
    res = _cli("--list-rules")
    assert res.returncode == 0
    for rid in ("hot-purity", "host-sync", "retrace-static", "api-boundary",
                "pad-fold", "dtype-hygiene", "registry-span"):
        assert rid in res.stdout


def test_cli_exit_codes_and_json(tmp_path):
    bad = _write_module(tmp_path, "multilevel/probe.py", """
        def probe(A, B):
            return A @ B
    """)
    res = _cli(str(bad), "--rules", "dense-matmul", "--json")
    assert res.returncode == 1
    payload = json.loads(res.stdout)
    assert payload["findings"][0]["rule"] == "dense-matmul"
    good = _write_module(tmp_path, "multilevel/ok.py", """
        def probe(A, B):
            return A
    """)
    assert _cli(str(good)).returncode == 0


# -------------------------------------------------------------- repo gate

def test_every_rule_has_invariant_and_fixture_coverage():
    """Structural pin: each registered rule documents its invariant, and
    this module carries a positive + negative fixture for it (grep our
    own test names — adding a rule without fixtures fails here)."""
    here = Path(__file__).read_text()
    for rid, rule in analysis.registered_rules().items():
        assert rule.invariant and rule.summary, rid
        slug = rid.replace("-", "_")
        assert f"test_{slug}_positive" in here or f"_{slug}_" in here, (
            f"rule {rid} has no fixture tests in tests/test_analysis.py")


def test_src_repro_is_clean_modulo_baseline():
    """The make-lint/CI gate, as a tier-1 test: zero unbaselined pscheck
    findings in src/repro and zero stale ledger entries."""
    analysis.assert_clean([REPO / "src" / "repro"],
                          baseline=REPO / "pscheck_baseline.json")
