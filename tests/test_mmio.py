"""MatrixMarket I/O: chunked streaming parse, pattern/symmetric header
handling, committed fixtures, and write/read round-trips."""
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import (gaussian_blobs_knn, read_matrix_market,
                          ring_of_cliques, write_matrix_market)

DATA = Path(__file__).resolve().parent / "data"


def test_committed_weighted_gz_fixture():
    """ring3x4.mtx.gz: general real, gzipped — regenerable from
    ring_of_cliques(3, 4, bridge_w=0.25)."""
    R = read_matrix_market(DATA / "ring3x4.mtx.gz")
    W, _ = ring_of_cliques(3, 4, bridge_w=0.25)
    assert (R.n_rows, R.n_cols, R.nnz) == (W.n_rows, W.n_cols, W.nnz)
    np.testing.assert_allclose(np.asarray(R.to_dense()),
                               np.asarray(W.to_dense()))


def test_committed_pattern_symmetric_fixture():
    """cycle6.mtx: coordinate *pattern symmetric* — no value column,
    lower triangle stored, mirrored on load with unit weights."""
    P = read_matrix_market(DATA / "cycle6.mtx")
    d = np.asarray(P.to_dense())
    assert P.nnz == 14                       # 7 stored entries mirrored
    np.testing.assert_array_equal(d, d.T)
    assert set(np.unique(d).tolist()) == {0.0, 1.0}
    assert (d.diagonal() == 0).all()


def test_chunked_parse_equals_slurp():
    """Any chunk size must yield the identical matrix (the streaming
    parse is a pure memory optimization)."""
    base = read_matrix_market(DATA / "ring3x4.mtx.gz")
    for chunk in (1, 2, 5, 1000):
        R = read_matrix_market(DATA / "ring3x4.mtx.gz", chunk=chunk)
        assert R.nnz == base.nnz
        np.testing.assert_allclose(np.asarray(R.to_dense()),
                                   np.asarray(base.to_dense()))


def test_round_trip_weighted(tmp_path):
    W, _ = gaussian_blobs_knn(12, 3, knn=4, seed=0)
    for name in ("w.mtx", "w.mtx.gz"):
        p = tmp_path / name
        write_matrix_market(p, W)
        R = read_matrix_market(p, chunk=17)
        assert (R.n_rows, R.n_cols, R.nnz) == (W.n_rows, W.n_cols, W.nnz)
        np.testing.assert_allclose(np.asarray(R.to_dense()),
                                   np.asarray(W.to_dense()), rtol=1e-12)


def test_round_trip_pattern(tmp_path):
    W, _ = ring_of_cliques(3, 5)
    p = tmp_path / "p.mtx"
    write_matrix_market(p, W, pattern=True, comment="pattern round trip")
    R = read_matrix_market(p, chunk=3)
    assert R.nnz == W.nnz
    np.testing.assert_allclose(
        np.asarray(R.to_dense()),
        (np.asarray(W.to_dense()) != 0).astype(np.float64))


def test_truncated_file_raises(tmp_path):
    p = tmp_path / "t.mtx"
    p.write_text("%%MatrixMarket matrix coordinate real general\n"
                 "4 4 3\n1 2 1.0\n2 3 2.0\n")   # header claims 3 entries
    with pytest.raises(ValueError, match="truncated"):
        read_matrix_market(p, chunk=2)


def test_non_mm_header_raises(tmp_path):
    p = tmp_path / "x.mtx"
    p.write_text("4 4 0\n")
    with pytest.raises(ValueError, match="MatrixMarket"):
        read_matrix_market(p)


def test_layout_kwargs_passthrough():
    R = read_matrix_market(DATA / "ring3x4.mtx.gz", build_sellcs=True,
                           sell_c=4)
    assert R.sell_cols is not None and R.sell_c == 4
