import os

# Tests run single-device (the dry-run sets its own 512-device env in a
# subprocess).  Force float64 availability for oracle comparisons.
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_graphs():
    """A couple of small graphs shared across tests."""
    from repro.graphs import ring_of_cliques, gaussian_blobs_knn, grid_graph

    roc, roc_truth = ring_of_cliques(4, 10)
    blobs, blobs_truth = gaussian_blobs_knn(30, 4, seed=1)
    grid = grid_graph(8, 8)
    return {
        "roc": (roc, roc_truth),
        "blobs": (blobs, blobs_truth),
        "grid": (grid, None),
    }


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)
