"""Optimizers, train loop, checkpointing, fault tolerance, data pipeline."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.models import model as M
from repro.train import (TrainConfig, make_train_step, make_optimizer,
                         CheckpointManager, StepWatchdog, run_with_restarts)
from repro.train.optimizer import adamw, adafactor, global_norm
from repro.data import SyntheticTokens


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("gemma-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, batch=4, seq=32, seed=0)
    return cfg, params, data


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(setup, opt_name):
    cfg, params, data = setup
    tc = TrainConfig(optimizer=opt_name, learning_rate=5e-3, warmup_steps=2,
                     total_steps=40, clip_norm=1.0)
    opt = make_optimizer(tc)
    step = jax.jit(make_train_step(cfg, tc, opt=opt))
    opt_state = opt.init(params)
    p = params
    losses = []
    for i in range(25):
        p, opt_state, m = step(p, opt_state, data.batch_at(i % 4))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
    assert np.isfinite(losses).all()


def test_microbatch_equals_full_batch(setup):
    """Grad accumulation must match the single-shot gradient step."""
    cfg, params, data = setup
    batch = data.batch_at(0)
    outs = {}
    for mb in (1, 2):
        tc = TrainConfig(optimizer="adamw", learning_rate=1e-3,
                         microbatch=mb, warmup_steps=1)
        opt = make_optimizer(tc)
        step = jax.jit(make_train_step(cfg, tc, opt=opt))
        p, _, m = step(params, opt.init(params), batch)
        outs[mb] = (p, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     outs[1][0], outs[2][0])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_adafactor_memory_is_factored(setup):
    cfg, params, _ = setup
    opt = adafactor()
    st = opt.init(params)
    n_par = sum(x.size for x in jax.tree.leaves(params))
    n_opt = sum(x.size for x in jax.tree.leaves(st))
    assert n_opt < 0.2 * n_par, (n_opt, n_par)  # vs 2x for adam


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, params, _ = setup
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(7, params, extra={"note": "x"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored, extra = mgr.restore(7, like)
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path, setup):
    cfg, params, _ = setup
    small = {"w": jnp.ones((3,))}
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, small)
    assert mgr.latest() == 4
    assert mgr.steps() == [3, 4]          # older GC'd


def test_run_with_restarts_recovers(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    crashes = {"left": 2}

    def body(step, state):
        if step == 5 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise RuntimeError("simulated node failure")
        return {"x": state["x"] + 1}

    final_step, state, report = run_with_restarts(
        body, {"x": jnp.zeros(())}, mgr, start_step=0, end_step=10,
        save_every=2, max_restarts=5)
    assert final_step == 10
    assert report["restarts"] == 2
    assert float(state["x"]) == 10.0      # no lost or repeated increments


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0)
    for i in range(10):
        wd.record(i, 0.1)
    assert wd.record(10, 0.5)
    assert not wd.record(11, 0.12)
    assert len(wd.straggler_steps) == 1


def test_data_deterministic_and_elastic(setup):
    cfg, _, _ = setup
    a = SyntheticTokens(cfg, batch=4, seq=32, seed=1).batch_at(17)
    b = SyntheticTokens(cfg, batch=4, seq=32, seed=1).batch_at(17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = SyntheticTokens(cfg, batch=4, seq=32, seed=1).batch_at(18)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_gradient_compression_error_feedback():
    """Compressed psum over a 1-device mesh == quantized value; error
    feedback carries the residual so the MEAN over steps converges."""
    from repro.dist.compression import (quantize_int8, dequantize_int8,
                                        compressed_psum_tree,
                                        init_error_feedback)
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32)}
    err = init_error_feedback(g)
    acc = jnp.zeros_like(g["w"])
    for _ in range(32):
        out, err = compressed_psum_tree(g, err, mesh, "data")
        acc = acc + out["w"]
    # time-averaged compressed stream ~= true gradient (error feedback)
    np.testing.assert_allclose(np.asarray(acc / 32), np.asarray(g["w"]),
                               atol=2e-3)
    q, s = quantize_int8(g["w"])
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s)),
                               np.asarray(g["w"]), atol=float(s) + 1e-6)


def test_run_with_restarts_resets_to_initial_without_checkpoint(tmp_path):
    """A crash before the first save must rewind to the CALLER's
    (start_step, state), not continue from the half-advanced loop state
    — and the report must surface every exception."""
    mgr = CheckpointManager(tmp_path, keep=3)
    crashes = {"left": 2}
    starts = []

    def body(step, state):
        if step == 0:
            starts.append(float(state["x"]))
        if step == 1 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise RuntimeError("boom before any checkpoint")
        return {"x": state["x"] + 1}

    sleeps = []
    final_step, state, report = run_with_restarts(
        body, {"x": jnp.zeros(())}, mgr, start_step=0, end_step=4,
        save_every=100, max_restarts=5, sleep_fn=sleeps.append)
    assert final_step == 4 and float(state["x"]) == 4.0
    assert starts == [0.0, 0.0, 0.0]        # every retry from the initial
    assert report["restored_from"] == ["initial", "initial"]
    assert len(report["errors"]) == 2
    assert all("RuntimeError: boom" in e for e in report["errors"])
    assert isinstance(report["last_error"], RuntimeError)
    assert sleeps == [0.02, 0.04]           # base * 2^restarts, injectable


def test_run_with_restarts_backoff_is_capped(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    crashes = {"left": 4}

    def body(step, state):
        if crashes["left"] > 0:
            crashes["left"] -= 1
            raise ValueError("flaky")
        return {"x": state["x"] + 1}

    sleeps = []
    _, _, report = run_with_restarts(
        body, {"x": jnp.zeros(())}, mgr, start_step=0, end_step=1,
        max_restarts=10, backoff_base=0.5, backoff_cap=1.0,
        sleep_fn=sleeps.append)
    assert sleeps == [1.0, 1.0, 1.0, 1.0]   # capped
    assert report["restarts"] == 4 and report["last_error"] is not None


def test_run_with_restarts_exhaustion_reraises(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)

    def body(step, state):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError, match="permanent failure"):
        run_with_restarts(body, {"x": jnp.zeros(())}, mgr,
                          start_step=0, end_step=4, max_restarts=2,
                          sleep_fn=lambda s: None)
