"""Unit tests for the repro.dist subsystem: logical-axis rule
resolution (full / partial / replicated, divisibility fallback, axis
reuse) and int8 gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (AxisRules, DEFAULT_RULES, DP_RULES,
                                 active_rules, constrain, logical_to_mesh,
                                 resolve_spec, rules_for, set_active_rules,
                                 use_rules)
from repro.dist.compression import (compressed_psum_tree, dequantize_int8,
                                    init_error_feedback, quantize_int8)
from jax.sharding import AbstractMesh


def single_pod():
    # shape-only stand-in for make_production_mesh(multi_pod=False):
    # resolve_spec reads mesh.shape, never device placement
    return AbstractMesh((("data", 16), ("model", 16)))


def multi_pod():
    return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


# ------------------------------------------------------------ resolve_spec

def test_fully_sharded_param():
    spec = resolve_spec((4096, 16384), ("embed", "mlp"), single_pod(),
                        DEFAULT_RULES)
    assert spec == P(None, "model")


def test_replicated_axes_trim():
    spec = resolve_spec((64, 64), ("latent", None), single_pod(),
                        DEFAULT_RULES)
    assert spec == P()


def test_divisibility_fallback_replicates():
    # 6 kv heads don't divide the 16-wide model axis -> replicate them;
    # batch=2 doesn't divide data=16 either -> whole spec degrades
    spec = resolve_spec((32, 6, 128, 64), ("batch", "kv", "seq", None),
                        single_pod(), DEFAULT_RULES)
    assert spec == P("data")
    spec = resolve_spec((2, 6, 128, 64), ("batch", "kv", "seq", None),
                        single_pod(), DEFAULT_RULES)
    assert spec == P()


def test_partial_candidate_list():
    # batch: ("pod", "data") — pod absent on a single pod, data applies
    spec = resolve_spec((32, 1024), ("batch", "seq"), single_pod(),
                        DEFAULT_RULES)
    assert spec == P("data")
    spec = resolve_spec((32, 1024), ("batch", "seq"), multi_pod(),
                        DEFAULT_RULES)
    assert spec == P(("pod", "data"))


def test_axis_consumed_once():
    # pure-DP batch takes data AND model; seq_sp then finds model used
    spec = resolve_spec((256, 512, 64), ("batch", "seq_sp", "embed"),
                        single_pod(),
                        DP_RULES.extend(seq_sp=("model",)))
    assert spec == P(("data", "model"))


def test_attn_batch_spreads_over_model():
    spec = resolve_spec((256, 8, 128, 64),
                        ("attn_batch", None, "seq", None),
                        single_pod(), DEFAULT_RULES)
    assert spec == P(("data", "model"))


def test_extend_overrides():
    rules = DEFAULT_RULES.extend(embed=("model",))
    assert resolve_spec((4096,), ("embed",), single_pod(), rules) \
        == P("model")
    # the base table is untouched
    assert resolve_spec((4096,), ("embed",), single_pod(), DEFAULT_RULES) \
        == P()


def test_logical_to_mesh_ignores_shape():
    out = logical_to_mesh(("batch", "mlp", None), single_pod(),
                          DEFAULT_RULES)
    assert out == ("data", "model", None)


# ------------------------------------------- factored optimizer moments

def test_factored_moment_specs_reresolve_not_slice():
    """Dropping a dim frees its mesh axis: the col moment of a
    ("heads", "mlp") param — both logical names candidate for "model",
    heads wins on the full param — must shard over "model" once heads
    is gone.  Hand-slicing the param's PartitionSpec (the old
    launch/dryrun.py::opt_state_shardings) replicated it."""
    from repro.dist.sharding import factored_moment_specs

    mesh = single_pod()
    full = resolve_spec((32, 16384), ("heads", "mlp"), mesh, DEFAULT_RULES)
    assert full == P("model")                  # mlp lost the greedy race
    row, col = factored_moment_specs((32, 16384), ("heads", "mlp"), mesh,
                                     DEFAULT_RULES)
    assert row == P("model")                   # (32,) heads keeps model
    assert col == P("model")                   # (16384,) mlp now gets it
    # hand-slicing operated on the trimmed param spec (trailing Nones
    # dropped, so entries don't even align with dims): parts[:-1] here
    # replicated the row moment the param itself shards
    assert P(*tuple(full)[:-1]) == P()


def test_factored_moment_specs_divisibility_rechecked():
    """Divisibility is checked against the MOMENT's extents: a (48, 6)
    ("mlp", "kv") param replicates kv (6 % 16 != 0); the row moment
    (48,) still shards over model because 48 divides 16."""
    from repro.dist.sharding import factored_moment_specs

    mesh = single_pod()
    row, col = factored_moment_specs((48, 6), ("mlp", "kv"), mesh,
                                     DEFAULT_RULES)
    assert row == P("model") and col == P()


def test_opt_state_shardings_use_factored_specs():
    """dryrun.opt_state_shardings derives adafactor moments through
    factored_moment_specs (ROADMAP AxisRules follow-up): every moment's
    spec equals a fresh resolve on its own (shape, logical)."""
    from repro.dist.sharding import factored_moment_specs
    from repro.launch import dryrun
    from repro.models import model as M
    from repro.models.layers import is_pab
    from repro.configs import get_config

    cfg = get_config("gemma-2b")
    mesh = jax.make_mesh((1,), ("model",))
    state = dryrun.opt_state_shardings("adafactor", cfg, mesh)
    ab_leaves = jax.tree.leaves(M.abstract_params(cfg), is_leaf=is_pab)
    mo_leaves = jax.tree.leaves(
        state.moments,
        is_leaf=lambda x: type(x).__name__ == "FactoredMoment")
    assert len(ab_leaves) == len(mo_leaves) > 0
    for a, m in zip(ab_leaves, mo_leaves):
        if len(a.shape) >= 2:
            row, col = factored_moment_specs(a.shape, a.logical, mesh)
            assert m.row.spec == row and m.col.spec == col
        else:
            assert m.spec == resolve_spec(a.shape, a.logical, mesh)


# --------------------------------------------------- active rules registry

def test_rules_for_thresholds():
    assert rules_for(2e9) is DP_RULES
    assert rules_for(400e9) is DEFAULT_RULES


def test_set_active_rules_roundtrip():
    prev = set_active_rules(DP_RULES)
    try:
        assert active_rules() is DP_RULES
    finally:
        set_active_rules(prev)
    assert active_rules() is prev


def test_use_rules_scopes():
    base = active_rules()
    with use_rules(DP_RULES):
        assert active_rules() is DP_RULES
    assert active_rules() is base


def test_constrain_none_mesh_identity():
    x = jnp.ones((4, 4))
    assert constrain(x, None, ("batch", None)) is x


def test_constrain_resolves_under_jit():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.ones((4, 8))
    y = jax.jit(lambda v: constrain(v, mesh, ("batch", "embed")))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ------------------------------------------------------------- compression

def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) <= 127
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) / 2 + 1e-7       # round-to-nearest bound


def test_quantize_zero_input():
    q, s = quantize_int8(jnp.zeros((8,)))
    np.testing.assert_array_equal(np.asarray(q), 0)
    assert np.isfinite(float(s))


def test_error_feedback_accumulates_residual():
    g = {"a": jnp.asarray([[0.3, -1.7, 0.002]], jnp.float32)}
    err = init_error_feedback(g)
    mesh = jax.make_mesh((1,), ("data",))
    out, err2 = compressed_psum_tree(g, err, mesh, "data")
    q, s = quantize_int8(g["a"])
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(dequantize_int8(q, s)))
    np.testing.assert_allclose(np.asarray(err2["a"]),
                               np.asarray(g["a"] - out["a"]), atol=1e-7)


def test_compressed_train_step_converges():
    """make_train_step(grad_compression='int8') threads the residual and
    still drives the loss down."""
    from repro.configs import get_reduced_config
    from repro.data import SyntheticTokens
    from repro.models import model as M
    from repro.train import (TrainConfig, init_compression_state,
                             make_optimizer, make_train_step)

    cfg = get_reduced_config("gemma-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, batch=4, seq=32, seed=0)
    mesh = jax.make_mesh((1,), ("data",))
    tc = TrainConfig(optimizer="adamw", learning_rate=5e-3, warmup_steps=2,
                     total_steps=40, clip_norm=1.0, grad_compression="int8")
    opt = make_optimizer(tc)
    step = jax.jit(make_train_step(cfg, tc, mesh=mesh, opt=opt))
    opt_state = opt.init(params)
    err = init_compression_state(params)
    losses = []
    for i in range(20):
        params, opt_state, err, m = step(params, opt_state, err,
                                         data.batch_at(i % 4))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::5]
    assert np.isfinite(losses).all()
