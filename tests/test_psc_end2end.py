"""End-to-end GrB-pGrass: recovers planted clusters and improves RCut
over the p=2 baseline (the paper's Table I claim, on small graphs)."""
import numpy as np
import pytest

from repro.core import PSCConfig, p_spectral_cluster, spectral_cluster, metrics
from repro.graphs import ring_of_cliques, gaussian_blobs_knn, sbm_graph


def test_ring_of_cliques_perfect_recovery():
    W, truth = ring_of_cliques(4, 10)
    cfg = PSCConfig(k=4, p_target=1.4, newton_iters=15, tcg_iters=10,
                    kmeans_restarts=4, seed=0)
    res = p_spectral_cluster(W, cfg)
    acc = metrics.clustering_accuracy(res.labels, truth, 4)
    assert acc == 1.0, f"accuracy {acc}"


def test_blobs_high_accuracy():
    W, truth = gaussian_blobs_knn(25, 4, seed=2)
    cfg = PSCConfig(k=4, p_target=1.3, newton_iters=15, tcg_iters=10, seed=1)
    res = p_spectral_cluster(W, cfg)
    acc = metrics.clustering_accuracy(res.labels, truth, 4)
    assert acc >= 0.95, f"accuracy {acc}"


def test_pgrass_rcut_not_worse_than_spec():
    """Table I analog: GrB-pGrass RCut <= Spec RCut (it minimizes it)."""
    W, _ = sbm_graph([30, 30, 30, 30], p_in=0.5, p_out=0.03, seed=5)
    cfg = PSCConfig(k=4, p_target=1.2, newton_iters=20, tcg_iters=15, seed=0)
    res = p_spectral_cluster(W, cfg)
    assert np.isfinite(res.rcut)
    # continuation starts exactly from the Spec solution; the nonlinear
    # refinement must not lose quality
    assert res.rcut <= res.init_rcut * 1.01 + 1e-9, \
        f"pGrass {res.rcut} vs Spec {res.init_rcut}"


def test_fp_decreases_along_continuation():
    W, _ = ring_of_cliques(3, 8)
    cfg = PSCConfig(k=3, p_target=1.5, newton_iters=10, tcg_iters=8, seed=0)
    res = p_spectral_cluster(W, cfg)
    assert len(res.p_path) >= 2
    assert all(np.isfinite(v) for v in res.fvals)
    assert all(h > 0 for h in res.hvp_counts)


def test_orthonormality_preserved():
    W, _ = ring_of_cliques(3, 8)
    cfg = PSCConfig(k=3, p_target=1.5, newton_iters=10, tcg_iters=8, seed=0)
    res = p_spectral_cluster(W, cfg)
    G = np.asarray(res.U.T @ res.U)
    np.testing.assert_allclose(G, np.eye(3), atol=1e-5)  # f32 QR precision
