"""Telemetry-layer tests (DESIGN.md §10): span recorder semantics and
export round-trips, metrics registry snapshot/delta/exposition, the
retrace detector, the disabled-tracing overhead bound, the serve
engine's registry-backed stat views, and the exactly-once contract
between recovery-ladder rungs and their counters/trace events.

The overhead test is deterministic by design: instead of racing two
timed solves (noisy on shared CI), it counts the instrument sites a
traced solve actually hits, microbenches the disabled-path cost of one
site (an ``ACTIVE`` lookup + the shared no-op span), and bounds their
product against the solve's wall clock.
"""
import dataclasses
import json
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.psc import PSCConfig, p_spectral_cluster
from repro.graphs import ring_of_cliques, sbm_graph
from repro.grblas import mxm
from repro.obs import (DEFAULT, MetricsRegistry, NULL, TraceConfig, Tracer,
                       roofline_summary, use)
from repro.obs import trace as obs_trace
from repro.obs.retrace import (RetraceDetector, RetraceError,
                               assert_no_retrace)
from repro.serve.psc_engine import ClusterServeEngine
from repro.testing import nan_in_multivector

K = 4
# 2-level continuation ([1.7, 1.5]) — same recipe as tests/test_chaos.py
_KW = dict(k=K, newton_iters=8, tcg_iters=5, p_target=1.5, p_factor=0.85)


@pytest.fixture(scope="module")
def sbm():
    return sbm_graph([30] * K, 0.92, 0.03, seed=0)[0]


# ------------------------------------------------------------ span recorder

def test_span_nesting_and_chrome_round_trip():
    t = {"now": 0.0}
    tr = Tracer(TraceConfig(fence=False, clock=lambda: t["now"]))
    with use(tr):
        with tr.span("root", cat="test", n=4):
            t["now"] += 1.0
            with tr.span("child_a"):
                t["now"] += 0.25
            tr.instant("ping", x=1)
            with tr.span("child_b", note="b"):
                t["now"] += 0.5
            t["now"] += 0.25

    # spans land in exit order; nesting is reconstructed via parent/sid
    assert [s.name for s in tr.spans] == ["child_a", "child_b", "root"]
    root = tr.roots()[0]
    assert root.name == "root" and root.t0 == 0.0 and root.dur == 2.0
    kids = tr.children(root)
    assert [s.name for s in kids] == ["child_a", "child_b"]
    for s in kids:
        assert s.depth == 1 and s.parent == root.sid
        assert root.t0 <= s.t0
        assert s.t0 + s.dur <= root.t0 + root.dur
    assert kids[0].dur == 0.25 and kids[1].dur == 0.5

    # Chrome trace-event JSON: valid (json round-trip), "X" complete
    # events in microseconds, "i" instants, attrs under args
    doc = json.loads(json.dumps(tr.export_chrome()))
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"root", "child_a", "child_b"}
    rx = next(e for e in xs if e["name"] == "root")
    assert rx["ts"] == 0.0 and rx["dur"] == 2.0e6
    assert rx["args"] == {"n": 4}
    inst = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert len(inst) == 1
    assert inst[0]["name"] == "ping" and inst[0]["args"] == {"x": 1}
    assert inst[0]["ts"] == 1.25e6          # stamped after child_a closed
    assert doc["otherData"]["dropped"] == 0

    # JSONL: one object per line, spans then events
    lines = [json.loads(ln) for ln in tr.export_jsonl().splitlines()]
    assert [ln["kind"] for ln in lines] == ["span"] * 3 + ["event"]
    assert lines[-1]["parent"] == root.sid


def test_bounded_buffer_drops_past_capacity():
    tr = Tracer(TraceConfig(capacity=4, fence=False))
    with use(tr):
        for i in range(10):
            with tr.span(f"s{i}"):
                pass
        for i in range(6):
            tr.instant(f"e{i}")
    assert len(tr.spans) == 4
    assert len(tr.events) == 4
    assert tr.dropped == 6 + 2


def test_null_tracer_is_the_default_and_free():
    assert obs_trace.ACTIVE is NULL
    assert not NULL.enabled
    sp = obs_trace.ACTIVE.span("anything", cat="x", big=1)
    assert sp is obs_trace.NULL_SPAN
    with sp as s:
        assert s.set(a=1) is s
        assert s.fence(42) == 42


def test_session_ownership_nested_calls_share_the_outer_tracer():
    with obs_trace.session(True) as owner:
        assert owner is not None and obs_trace.ACTIVE is owner
        with obs_trace.session(True) as inner:       # nested: reuse outer
            assert inner is None
        with obs_trace.session(None) as off:
            assert off is None
    assert obs_trace.ACTIVE is NULL
    with obs_trace.session(False) as off:
        assert off is None and obs_trace.ACTIVE is NULL


# --------------------------------------------------------- traced pipeline

def test_traced_flat_pipeline_telemetry(sbm):
    cfg = PSCConfig(trace=True, **_KW)
    res = p_spectral_cluster(sbm, cfg)
    tel = res.telemetry
    assert tel is not None and tel.dropped == 0
    assert tel.root().name == "psc"
    ph = tel.phase_breakdown()
    assert {"init", "continuation", "kmeans"} <= set(ph)
    assert tel.coverage() >= 0.8
    # per-p solver levels carry the SolverReport facts
    levels = [s for s in tel.spans if s.name == "solver.level"]
    assert len(levels) == 2                  # the 2-level schedule
    assert all("n_apply" in s.attrs and "fval" in s.attrs for s in levels)
    # untraced run: telemetry is None, result identical
    res2 = p_spectral_cluster(sbm, dataclasses.replace(cfg, trace=None))
    assert res2.telemetry is None
    assert res2.rcut == res.rcut
    assert np.array_equal(np.asarray(res2.labels), np.asarray(res.labels))


def test_disabled_tracing_overhead_within_2pct(sbm):
    """ISSUE-9 acceptance: tracing off must cost the Newton hot loop
    <= 2%.  Deterministic form: (instrument sites a traced solve hits)
    x (measured disabled-path cost per site) <= 2% of the solve."""
    cfg = PSCConfig(trace=True, **_KW)
    t0 = time.perf_counter()
    res = p_spectral_cluster(sbm, cfg)
    wall = time.perf_counter() - t0
    n_sites = len(res.telemetry.spans) + len(res.telemetry.events)
    assert n_sites > 0

    assert obs_trace.ACTIVE is NULL
    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        with obs_trace.ACTIVE.span("x", cat="t", a=1) as sp:
            sp.fence(None)
    null_cost = (time.perf_counter() - t0) / reps

    budget = 0.02 * wall
    spent = n_sites * null_cost
    assert spent <= budget, (
        f"disabled-path overhead {spent * 1e6:.1f}us "
        f"({n_sites} sites x {null_cost * 1e9:.0f}ns) exceeds 2% of the "
        f"{wall:.2f}s solve ({budget * 1e6:.0f}us)")


def test_roofline_summary_from_mxm_spans():
    W, _ = ring_of_cliques(4, 8)
    X = jnp.asarray(np.random.default_rng(0).standard_normal(
        (W.n_rows, K)), jnp.float32)
    tr = Tracer(TraceConfig())
    with use(tr):
        mxm(W, X)                            # eager: emits grblas.mxm
    spans = [s for s in tr.spans if s.name == "grblas.mxm"]
    assert spans
    s0 = spans[0]
    assert s0.attrs["bytes"] > 0 and s0.attrs["nnz"] == W.nnz
    summ = roofline_summary(spans, peak_gbs=100.0)
    row = summ[s0.attrs["backend"]]
    assert row["calls"] == len(spans)
    assert row["gb_s"] > 0
    assert row["frac_of_peak"] == pytest.approx(row["gb_s"] / 100.0)


# --------------------------------------------------------- metrics registry

def test_metrics_snapshot_delta_and_exposition():
    reg = MetricsRegistry()
    reg.counter("req_total", lane="bucket").inc()
    reg.counter("req_total", lane="solo").inc(2)
    reg.gauge("depth").set(3)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)

    snap = reg.snapshot()
    assert snap['req_total{lane="bucket"}'] == 1.0
    assert snap['req_total{lane="solo"}'] == 2.0
    assert snap["depth"] == 3.0
    assert snap["lat_s_count"] == 3.0
    assert snap["lat_s_sum"] == pytest.approx(5.55)
    assert snap['lat_s_bucket{le="0.1"}'] == 1.0
    assert snap['lat_s_bucket{le="1.0"}'] == 2.0
    assert snap['lat_s_bucket{le="+Inf"}'] == 3.0

    assert reg.total("req_total") == 3.0
    assert reg.labeled_values("req_total", "lane") == {"bucket": 1.0,
                                                       "solo": 2.0}

    prev = snap
    reg.counter("req_total", lane="solo").inc()
    assert reg.delta(prev) == {'req_total{lane="solo"}': 1.0}

    text = reg.exposition()
    assert "# TYPE req_total counter" in text
    assert "# TYPE depth gauge" in text
    assert "# TYPE lat_s histogram" in text
    assert 'req_total{lane="bucket"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 3' in text
    assert text.endswith("\n")

    with pytest.raises(TypeError):
        reg.gauge("req_total")               # type conflict is an error
    with pytest.raises(ValueError):
        reg.counter("req_total", lane="bucket").inc(-1)


# ---------------------------------------------------------- serve stat views

def test_engine_stats_and_cache_share_one_registry():
    cfg = PSCConfig(k=K, reorder="none", newton_iters=6, tcg_iters=4)
    eng = ClusterServeEngine(cfg, max_batch=4)
    W, _ = ring_of_cliques(4, 10)
    eng.serve([W])
    eng.serve([W])                           # exact-tier warm hit

    assert eng.cache.metrics is eng.metrics
    assert eng.stats.registry is eng.metrics
    assert eng.stats.n_requests == 2
    assert eng.metrics.value("serve_requests_total") == 2
    assert eng.cache.hits_exact == 1
    assert eng.metrics.value("warm_cache_hits_total", tier="exact") == 1
    assert eng.cache.stats()["misses"] == 1

    # back-compat mutation still lands on the counter
    eng.stats.n_churn += 1
    assert eng.metrics.value("serve_churn_total") == 1

    # failure taxonomy: one family, two views
    eng.stats.record_failure("exception")
    assert eng.stats.n_failed == 1
    assert eng.stats.failures == {"exception": 1}
    d = eng.stats.as_dict()
    assert d["n_failed"] == 1 and d["failures"] == {"exception": 1}
    assert list(d)[:3] == ["n_requests", "n_results", "n_batches"]

    snap = eng.metrics.snapshot()
    assert snap["serve_queue_depth"] == 0.0
    assert snap["serve_batch_occupancy_count"] == 2.0
    text = eng.exposition()
    assert "serve_requests_total 2" in text
    assert 'warm_cache_hits_total{tier="exact"} 1' in text


# ----------------------------------------------------------- retrace detector

def test_retrace_detector_catches_a_bucket_buster():
    # a solver signature no other test uses: the serve memo is global,
    # so this test's compiles must be its own
    cfg = PSCConfig(k=K, reorder="none", newton_iters=5, tcg_iters=3)
    eng = ClusterServeEngine(cfg, max_batch=4)
    Wa, _ = ring_of_cliques(4, 10)           # bucket (64, 512)

    det = RetraceDetector()
    eng.serve([Wa])                          # cold trace
    eng.serve([Wa])                          # warm trace (exact-tier hit)
    per_key = det.serve_buckets()
    assert len(per_key) == 2 and all(v == 1 for v in per_key.values())
    det.assert_at_most(1)

    # steady state: an exact replay compiles nothing
    with assert_no_retrace():
        eng.serve([Wa])

    # the buster: a different (n, nnz) lands in a NEW bucket — that
    # compile is exactly what the steady-state guard must catch
    Wb, _ = ring_of_cliques(4, 6)            # bucket (64, 128)
    with pytest.raises(RetraceError, match="retrace detected"):
        with assert_no_retrace():
            eng.serve([Wb])

    # compiles_total{site=} on DEFAULT moved with the detector
    assert DEFAULT.value("compiles_total", site="serve") >= 3


# --------------------------------------- recovery rungs: exactly-once + ids

def test_rung_counters_fire_exactly_once_and_correlate(sbm):
    """Every RungRecord the ladder produces increments
    ``recovery_rungs_total{rung=}`` exactly once, and the rung's trace
    instant carries the injection id of the fault that triggered it."""
    before = DEFAULT.snapshot()
    tr = Tracer(TraceConfig())
    with use(tr):
        with nan_in_multivector("newton", at_call=1,
                                max_calls=None) as log:
            res = p_spectral_cluster(sbm, PSCConfig(guard=True, **_KW))
    assert res.recovery is not None
    assert res.recovery.final_rung == "driver_switch"
    assert log.count() >= 2 and log.ids == sorted(log.ids)

    fired = {}
    for r in res.recovery.rungs:
        fired[r.rung] = fired.get(r.rung, 0) + 1
    assert fired                             # the ladder actually ran

    d = DEFAULT.delta(before)
    for rung, n in fired.items():
        key = f'recovery_rungs_total{{rung="{rung}"}}'
        assert d.get(key, 0.0) == n, (key, d)
    moved = {k for k in d if k.startswith("recovery_rungs_total")}
    assert moved == {f'recovery_rungs_total{{rung="{r}"}}' for r in fired}

    # fault instants and rung instants share the injection-id timeline
    faults = [e for e in tr.events
              if e["name"] == "fault.nan_in_multivector"]
    assert [e["attrs"]["injection_id"] for e in faults] == log.ids
    assert d.get('fault_injections_total{site="nan_in_multivector"}') \
        == len(log.ids)
    rung_evs = [e for e in tr.events if e["name"] == "recovery.rung"]
    assert len(rung_evs) == len(res.recovery.rungs)
    assert all(e["attrs"]["injection_id"] in log.ids for e in rung_evs)
    # the divergence that started the ladder is on the same timeline
    div = [e for e in tr.events if e["name"] == "solver.divergence"]
    assert div and div[0]["attrs"]["injection_id"] in log.ids
