"""Halo-exchange plan construction + the dist_sellcs sharded layout.

Two layers:

* host-side plan tests run in the main process (make_row_partition is
  host numpy; no mesh needed) — plan invariants, the halo/gather
  fallback boundary, the edge-ring square gate, wire-byte accounting;
* a subprocess test under a forced multi-device host platform proves
  the plans compose under a real mesh: halo == gather == coo across
  rings and k, cluster-aligned placement beats shuffled placement in
  wire bytes on a 2-cluster SBM, and the per-shard SELL-C-σ layout
  matches everything else on a skewed-degree graph.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.graphs import delaunay_graph, sbm_graph
from repro.grblas import (Descriptor, HALO_FALLBACK_FRAC, SparseMatrix,
                          available_backends, make_row_partition, mxm)
from repro.grblas.semiring import plap_edge_semiring, reals_ring

SRC = Path(__file__).resolve().parent.parent / "src"
N_DEV = os.environ.get("DIST_TEST_DEVICES", "8")


def _graph(r=8, seed=0):
    W, _ = delaunay_graph(r, seed=seed)
    return W


# ------------------------------------------------------- host-side plan

def test_halo_plan_covers_every_remote_column():
    W = _graph()
    S = 4
    Ap = make_row_partition(W, S)
    assert Ap.mode == "halo"
    R, H = Ap.rows_per_shard, Ap.halo_width
    cols = np.asarray(Ap.ell_cols)       # extended-local ids
    send = np.asarray(Ap.send_idx)
    x = np.random.default_rng(0).standard_normal(S * R)
    # simulate the exchange with numpy: shard d's extended vector is its
    # locals plus, at R + s*H + h, row send[s, d*H + h] of shard s
    for d in range(S):
        x_ext = np.concatenate(
            [x[d * R:(d + 1) * R]]
            + [x[s * R + send[s, d * H:(d + 1) * H]] for s in range(S)])
        assert cols[d].max() < R + S * H
        # the remap must deliver exactly the global column's value
        glob = np.asarray(
            make_row_partition(W, S, mode="gather").ell_cols)[d]
        np.testing.assert_array_equal(x_ext[cols[d]], x[glob])


def test_halo_fallback_boundary():
    W = _graph()
    S = 4
    R = -(-W.n_rows // S)
    Ap = make_row_partition(W, S)
    assert Ap.mode == "halo"
    assert Ap.halo_width <= HALO_FALLBACK_FRAC * R
    # scrambled placement destroys locality -> halo denser than the
    # gather it would replace -> the plan falls back at build time,
    # keeping the computed width so wire_bytes explains the decision
    rng = np.random.default_rng(1)
    asg = rng.permutation(W.n_rows)
    Apx = make_row_partition(W, S, assignment=asg)
    assert Apx.mode == "gather" and Apx.send_idx is None
    assert Apx.halo_width > HALO_FALLBACK_FRAC * R
    # forcing halo on the EXACT placement the auto rule rejected still
    # builds a valid (if wasteful) plan with the same width
    Apf = make_row_partition(W, S, assignment=asg, mode="halo")
    assert Apf.mode == "halo"
    assert Apf.halo_width == Apx.halo_width
    assert Apf.wire_bytes(1)["halo"] >= Ap.wire_bytes(1)["halo"]


def test_wire_bytes_accounting():
    W = _graph()
    S = 4
    Ap = make_row_partition(W, S)
    wb = Ap.wire_bytes(k=8)
    assert wb["halo"] == S * (S - 1) * Ap.halo_width * 8 * 4
    assert wb["gather"] == S * (S - 1) * Ap.rows_per_shard * 8 * 4
    assert wb["halo"] < wb["gather"]
    assert wb["halo_rows_true"] <= S * (S - 1) * Ap.halo_width


def test_edge_ring_square_gate_routes_rectangular_away_from_dist():
    """Regression (satellite): _dist_supports admitted edge rings on
    rectangular operators, and the shard body then read misaligned
    x_i rows.  The gate must exclude dist (and dist_sellcs) exactly
    like every other edge-ring backend excludes itself."""
    W = _graph()
    n = W.n_rows
    r, c, v = W.host_coo()
    Wrect = SparseMatrix.from_coo(r, c, v, (n, n + 32), build_ell=True)
    mesh = make_mesh((1,), ("data",))
    d = Descriptor(mesh=mesh)
    ring = plap_edge_semiring(1.5, eps=1e-8)
    X = jnp.ones((n + 32, 2), jnp.float32)
    names = available_backends(Wrect, X, ring, desc=d)
    assert "dist" not in names and "dist_sellcs" not in names
    # naming the backend anyway fails loudly
    from repro.grblas import BackendUnavailableError
    with pytest.raises(BackendUnavailableError):
        mxm(Wrect, X, ring, desc=Descriptor(backend="dist", mesh=mesh))
    # square operators still route to dist first
    Xsq = jnp.ones((n, 2), jnp.float32)
    assert available_backends(W, Xsq, ring, desc=d)[0] == "dist"


def test_assignment_requires_square():
    W = _graph()
    r, c, v = W.host_coo()
    Wrect = SparseMatrix.from_coo(r, c, v, (W.n_rows, W.n_rows + 8),
                                  build_ell=True)
    with pytest.raises(ValueError, match="square"):
        make_row_partition(Wrect, 4, assignment=np.zeros(W.n_rows, int))
    with pytest.raises(ValueError, match="square|n_shards"):
        make_row_partition(Wrect, 4, mode="halo")


def test_dist_sellcs_requires_layout_on_prebuilt_partition():
    W = _graph()
    mesh = make_mesh((1,), ("data",))
    Ap = make_row_partition(W, 1)               # no sellcs slicing
    X = jnp.ones((W.n_rows, 2), jnp.float32)
    d = Descriptor(backend="dist_sellcs", mesh=mesh)
    from repro.grblas import BackendUnavailableError
    with pytest.raises(BackendUnavailableError):
        mxm(Ap, X, desc=d)
    Aps = make_row_partition(W, 1, sellcs=True)
    got = np.asarray(mxm(Aps, X, desc=d))
    np.testing.assert_allclose(got, np.asarray(mxm(W, X)),
                               rtol=2e-5, atol=2e-5)


def test_sellcs_plan_is_spmd_uniform():
    """Every width run must have identical shapes on all shards — the
    shard_map body is one program."""
    W, _ = sbm_graph([60, 60, 60, 60], 0.3, 0.02, seed=0)
    Ap = make_row_partition(W, 4, sellcs=True, sell_c=8)
    sell = Ap.sell
    S = Ap.n_shards
    for cols, vals, own in zip(sell.run_cols, sell.run_vals, sell.run_own):
        assert cols.shape[0] == S and vals.shape == cols.shape
        assert own.shape == cols.shape[:2]
        assert cols.shape[1] % sell.sell_c == 0
    assert sell.inv.shape == (S, Ap.rows_per_shard)
    # widths strictly decrease across runs (descending degree sort)
    widths = [c.shape[2] for c in sell.run_cols]
    assert widths == sorted(widths, reverse=True)


# ------------------------------------------------- mesh composition test

SCRIPT = textwrap.dedent("""
    import os
    N = int(os.environ["DIST_TEST_DEVICES"])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N}"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.graphs import sbm_graph
    from repro.grblas import (Descriptor, device_mesh, init_distributed,
                              make_row_partition, mxm)
    from repro.grblas.semiring import plap_edge_semiring

    # the launch path: single-process init is a guarded no-op, the mesh
    # spans the forced host devices
    assert init_distributed() is False
    mesh_all = device_mesh()
    assert int(mesh_all.shape["data"]) == N
    S = 4
    mesh = make_mesh((S,), ("data",))        # 4-shard submesh
    d = Descriptor(backend="dist", mesh=mesh)
    ds = Descriptor(backend="dist_sellcs", mesh=mesh)
    rng = np.random.default_rng(0)
    ring = plap_edge_semiring(1.4, eps=1e-8)

    # 4-cluster SBM, one cluster per shard: the halo carries only cut
    # rows and beats the all-gather in wire bytes (Bernoulli blocks are
    # expanders — only cluster:shard-aligned placement has a small cut)
    W, truth = sbm_graph([128] * S, 0.06, 0.002, seed=0)
    X = jnp.asarray(rng.standard_normal((W.n_rows, 16)), jnp.float32)
    want = np.asarray(mxm(W, X))
    wante = np.asarray(mxm(W, X, ring))
    Ap = make_row_partition(W, S, assignment=truth)
    assert Ap.mode == "halo", Ap.mode
    wb = Ap.wire_bytes(k=16)
    assert wb["halo"] < wb["gather"], wb
    got = np.asarray(mxm(Ap, X, desc=d))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    gote = np.asarray(mxm(Ap, X, ring, desc=d))
    np.testing.assert_allclose(gote, wante, rtol=2e-4, atol=2e-5)

    # shuffled placement pays a bigger halo than the aligned one
    shuf = rng.permutation(W.n_rows)
    Apx = make_row_partition(W, S, assignment=shuf, mode="halo")
    assert Apx.halo_width >= Ap.halo_width
    np.testing.assert_allclose(np.asarray(mxm(Apx, X, desc=d)), want,
                               rtol=2e-5, atol=2e-5)

    # the literal satellite criterion: 2-cluster SBM, cluster-aligned
    # (one cluster per shard on a 2-shard submesh), halo < gather bytes
    W2, truth2 = sbm_graph([256, 256], 0.04, 0.001, seed=0)
    Ap2 = make_row_partition(W2, 2, assignment=truth2)
    assert Ap2.mode == "halo"
    wb2 = Ap2.wire_bytes(k=16)
    assert wb2["halo"] < wb2["gather"], wb2
    d2 = Descriptor(backend="dist", mesh=make_mesh((2,), ("data",)))
    X2 = jnp.asarray(rng.standard_normal((W2.n_rows, 16)), jnp.float32)
    np.testing.assert_allclose(np.asarray(mxm(Ap2, X2, desc=d2)),
                               np.asarray(mxm(W2, X2)),
                               rtol=2e-5, atol=2e-5)

    # halo == forced gather == coo, and the per-shard SELL-C-σ layout
    # agrees for both ring kinds
    Apg = make_row_partition(W, S, assignment=truth, mode="gather")
    np.testing.assert_allclose(np.asarray(mxm(Apg, X, desc=d)), want,
                               rtol=2e-5, atol=2e-5)
    Aps = make_row_partition(W, S, assignment=truth, sellcs=True, sell_c=8)
    np.testing.assert_allclose(np.asarray(mxm(Aps, X, desc=ds)), want,
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(mxm(Aps, X, ring, desc=ds)), wante,
                               rtol=2e-4, atol=2e-5)

    # k sweep through the sellcs shard layout too
    for k in (1, 8, 32):
        Xk = jnp.asarray(rng.standard_normal(
            (W.n_rows,) if k == 1 else (W.n_rows, k)), jnp.float32)
        np.testing.assert_allclose(np.asarray(mxm(Aps, Xk, desc=ds)),
                                   np.asarray(mxm(W, Xk)),
                                   rtol=2e-5, atol=2e-5)
    print("DIST_HALO_OK")
""")


def test_dist_halo_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu",
                            "DIST_TEST_DEVICES": N_DEV},
                       capture_output=True, text=True, timeout=560)
    assert "DIST_HALO_OK" in r.stdout, r.stdout + "\n" + r.stderr
