"""Backend equivalence: every backend the Descriptor can name must agree
with the COO reference — across rings, p values, and asymmetric as well
as symmetric matrices.  This is the numerics contract of the dispatch
table: "auto" may pick any capable backend, so they must all be
interchangeable to tolerance (1e-5 for f32 kernel paths)."""
import numpy as np
import scipy.sparse as sp
import jax.numpy as jnp
import pytest

from repro.grblas import (
    Descriptor,
    EdgeSemiring,
    SparseMatrix,
    boolean_ring,
    max_times_ring,
    min_plus_ring,
    mxm,
    mxv,
    plap_edge_semiring,
    plap_hvp_edge_semiring,
    reals_ring,
)

BS = 16
PS = [1.2, 1.5, 2.0]


def _graph(symmetric: bool, n=96, density=0.08, seed=0, dtype=jnp.float32):
    A = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="coo")
    if symmetric:
        A = A + A.T
    return SparseMatrix.from_scipy(A, build_bsr=True, block_size=BS,
                                   dtype=dtype, build_sellcs=True,
                                   sell_c=8, sell_sigma=32)


def _X(M, k=4, seed=1, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((M.n_rows, k)), dtype)


REALS_DESCRIPTORS = [
    Descriptor(backend="coo"),
    Descriptor(backend="ell"),
    Descriptor(backend="sellcs"),                      # sliced gather (CPU)
    Descriptor(backend="sellcs", interpret=True),      # Pallas interpreter
    Descriptor(backend="bsr_pallas"),                  # jnp blocked ref (CPU)
    Descriptor(backend="bsr_pallas", interpret=True),  # Pallas interpreter
]

EDGE_DESCRIPTORS = [
    Descriptor(backend="edge_pallas"),
    Descriptor(backend="edge_pallas", interpret=True),
    Descriptor(backend="sellcs"),
    Descriptor(backend="sellcs", interpret=True),
]


@pytest.mark.parametrize("symmetric", [True, False],
                         ids=["symmetric", "asymmetric"])
def test_reals_ring_backends_agree(symmetric):
    M = _graph(symmetric)
    X = _X(M)
    want = np.asarray(M.to_dense()) @ np.asarray(X)     # dense oracle
    for desc in REALS_DESCRIPTORS:
        got = np.asarray(mxm(M, X, desc=desc))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"backend={desc.backend} "
                                           f"interpret={desc.interpret}")


@pytest.mark.parametrize("symmetric", [True, False],
                         ids=["symmetric", "asymmetric"])
def test_reals_ring_as_edge_semiring(symmetric):
    """A generic edge-semiring that ignores the destination endpoint must
    reproduce the plain ring on the COO path (the ring-extension is
    conservative)."""
    M = _graph(symmetric)
    X = _X(M)
    ring = EdgeSemiring(base=reals_ring,
                        edge_mul=lambda w, x_src, x_dst: w * x_src,
                        name="reals_as_edge")
    got = np.asarray(mxm(M, X, ring))
    want = np.asarray(mxm(M, X, desc=Descriptor(backend="coo")))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("symmetric", [True, False],
                         ids=["symmetric", "asymmetric"])
def test_plap_apply_backends_agree(symmetric, p):
    M = _graph(symmetric)
    X = _X(M)
    ring = plap_edge_semiring(p, eps=1e-6)
    want = np.asarray(mxm(M, X, ring, desc=Descriptor(backend="coo")))
    for desc in EDGE_DESCRIPTORS:
        got = np.asarray(mxm(M, X, ring, desc=desc))
        np.testing.assert_allclose(
            got, want, rtol=2e-4, atol=1e-5,
            err_msg=f"p={p} backend={desc.backend} "
                    f"interpret={desc.interpret}")


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("symmetric", [True, False],
                         ids=["symmetric", "asymmetric"])
def test_plap_hvp_backends_agree(symmetric, p):
    M = _graph(symmetric)
    rng = np.random.default_rng(2)
    U = jnp.asarray(np.linalg.qr(rng.standard_normal((M.n_rows, 3)))[0],
                    jnp.float32)
    Eta = jnp.asarray(rng.standard_normal((M.n_rows, 3)) * 0.1, jnp.float32)
    ring = plap_hvp_edge_semiring(p, eps=1e-6)
    want = np.asarray(mxm(M, (U, Eta), ring, desc=Descriptor(backend="coo")))
    for desc in EDGE_DESCRIPTORS:
        got = np.asarray(mxm(M, (U, Eta), ring, desc=desc))
        np.testing.assert_allclose(
            got, want, rtol=2e-4, atol=1e-5,
            err_msg=f"p={p} backend={desc.backend} "
                    f"interpret={desc.interpret}")


@pytest.mark.parametrize("symmetric", [True, False],
                         ids=["symmetric", "asymmetric"])
def test_generic_rings_match_dense_oracle(symmetric):
    """(min,+), (max,*), boolean: COO (the only capable layout) vs dense."""
    M = _graph(symmetric, dtype=jnp.float64)
    dense = np.asarray(M.to_dense())
    rng = np.random.default_rng(3)
    x = np.abs(rng.standard_normal(M.n_rows)) + 0.1

    got = np.asarray(mxv(M, jnp.asarray(x), min_plus_ring))
    want = np.full(M.n_rows, np.inf)
    for i in range(M.n_rows):
        nz = dense[i] != 0
        if nz.any():
            want[i] = np.min(dense[i][nz] + x[nz])
    np.testing.assert_allclose(got, want, rtol=1e-10)

    got = np.asarray(mxv(M, jnp.asarray(x), max_times_ring))
    want = np.full(M.n_rows, -np.inf)
    for i in range(M.n_rows):
        nz = dense[i] != 0
        if nz.any():
            want[i] = np.max(dense[i][nz] * x[nz])
    np.testing.assert_allclose(got, want, rtol=1e-10)

    xb = x > 1.0
    got = np.asarray(mxv(M, jnp.asarray(xb), boolean_ring))
    np.testing.assert_array_equal(got, (dense != 0) @ xb)


@pytest.mark.parametrize("symmetric", [True, False],
                         ids=["symmetric", "asymmetric"])
def test_with_vals_multivalues_on_sellcs(symmetric):
    """Alg-1's materialized W-hat ((nnz, k) multivalues on the fixed
    pattern) must execute identically on the sliced layout: with_vals
    re-scatters the packed slice values on-device."""
    M = _graph(symmetric)
    X = _X(M)
    rng = np.random.default_rng(7)
    mv = jnp.asarray(rng.standard_normal((M.nnz, X.shape[1])), jnp.float32)
    Wv = M.with_vals(mv)
    assert Wv.sell_cols is not None
    want = np.asarray(mxm(Wv, X, desc=Descriptor(backend="coo")))
    got = np.asarray(mxm(Wv, X, desc=Descriptor(backend="sellcs")))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", ["rcm", "degree"])
def test_reorder_round_trip_labels_invariant(method):
    """PSCConfig.reorder must be invisible to callers: identical labels
    (same vertex ids), identical cut metrics."""
    from repro.core import metrics
    from repro.core.psc import PSCConfig, p_spectral_cluster
    from repro.graphs import ring_of_cliques

    W, _ = ring_of_cliques(4, 12)
    kw = dict(k=4, p_target=1.6, newton_iters=4, tcg_iters=5,
              kmeans_restarts=3, kmeans_iters=20, seed=0)
    base = p_spectral_cluster(W, PSCConfig(**kw))
    perm = p_spectral_cluster(W, PSCConfig(reorder=method, **kw))
    assert metrics.clustering_accuracy(base.labels, perm.labels, 4) == 1.0
    np.testing.assert_allclose(perm.rcut, base.rcut, rtol=1e-4)
    np.testing.assert_allclose(perm.ncut, base.ncut, rtol=1e-4)


def test_plap_hot_loop_matches_through_bsr_descriptor():
    """Acceptance pin: the Newton hot-loop ops under
    Descriptor(backend=..., interpret=True) match the COO reference to
    1e-5 when driven through core.plap."""
    from repro.core import plap

    M = _graph(True)
    rng = np.random.default_rng(5)
    U = jnp.asarray(np.linalg.qr(rng.standard_normal((M.n_rows, 3)))[0],
                    jnp.float32)
    Eta = jnp.asarray(rng.standard_normal((M.n_rows, 3)) * 0.1, jnp.float32)
    kernel_desc = Descriptor(backend="edge_pallas", interpret=True)
    coo = Descriptor(backend="coo")
    for p in PS:
        g0 = np.asarray(plap.euc_grad(M, U, p, 1e-6, desc=coo))
        g1 = np.asarray(plap.euc_grad(M, U, p, 1e-6, desc=kernel_desc))
        np.testing.assert_allclose(g1, g0, rtol=2e-4, atol=1e-5)
        h0 = np.asarray(plap.hess_eta_matrix_free(M, U, Eta, p, 1e-6,
                                                  desc=coo))
        h1 = np.asarray(plap.hess_eta_matrix_free(M, U, Eta, p, 1e-6,
                                                  desc=kernel_desc))
        np.testing.assert_allclose(h1, h0, rtol=2e-4, atol=1e-5)
