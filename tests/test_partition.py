"""Graph-partition placement: balanced sizes + fewer cut edges than a
naive contiguous split, and the permuted distributed operator stays
numerically exact (covered in test_dist_spmv)."""
import numpy as np

from repro.graphs import delaunay_graph
from repro.graphs.partition import partition, cut_edges


def test_partition_balanced_and_better_than_contiguous():
    W, _ = delaunay_graph(9, seed=0, locality_order=False)
    n_parts = 4
    labels, info = partition(W, n_parts, seed=0)
    sizes = np.asarray(info["sizes"])
    assert sizes.sum() == W.n_rows
    assert sizes.max() - sizes.min() <= W.n_rows // n_parts // 2 + 1

    contiguous = np.repeat(np.arange(n_parts), -(-W.n_rows // n_parts))
    contiguous = contiguous[: W.n_rows]
    cut_p = cut_edges(W, labels)
    cut_c = cut_edges(W, contiguous)
    # random-ordered Delaunay: contiguous split cuts a constant fraction
    # of edges; spectral placement must cut far fewer
    assert cut_p < 0.8 * cut_c, (cut_p, cut_c)
    assert np.isfinite(info["rcut"])
