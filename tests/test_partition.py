"""Graph-partition placement: balanced sizes + fewer cut edges than a
naive contiguous split, and the permuted distributed operator stays
numerically exact (covered in test_dist_spmv)."""
import numpy as np

from repro.graphs import delaunay_graph
from repro.graphs.partition import partition, cut_edges


def test_partition_balanced_and_better_than_contiguous():
    W, _ = delaunay_graph(9, seed=0, locality_order=False)
    n_parts = 4
    labels, info = partition(W, n_parts, seed=0)
    sizes = np.asarray(info["sizes"])
    assert sizes.sum() == W.n_rows
    assert sizes.max() - sizes.min() <= W.n_rows // n_parts // 2 + 1

    contiguous = np.repeat(np.arange(n_parts), -(-W.n_rows // n_parts))
    contiguous = contiguous[: W.n_rows]
    cut_p = cut_edges(W, labels)
    cut_c = cut_edges(W, contiguous)
    # random-ordered Delaunay: contiguous split cuts a constant fraction
    # of edges; spectral placement must cut far fewer
    assert cut_p < 0.8 * cut_c, (cut_p, cut_c)
    assert np.isfinite(info["rcut"])


def test_partition_for_mesh_builds_halo_partition():
    """End-to-end placement: PSC assignment -> halo row partition whose
    wire volume reflects the (small) spectral cut, not O(n)."""
    from repro.graphs.partition import partition_for_mesh

    W, _ = delaunay_graph(9, seed=0, locality_order=False)
    Ap, labels, info = partition_for_mesh(W, 4, seed=0)
    assert Ap.n_shards == 4 and Ap.perm is not None
    assert info["halo"]["mode"] == "halo"
    assert info["halo"]["halo"] < info["halo"]["gather"]
    # the un-permuted labels must land each row's cluster on one shard:
    # shard of row i == shard holding position inv_perm[i]
    shard_of = np.asarray(Ap.inv_perm) // Ap.rows_per_shard
    # rows sharing a cluster overwhelmingly share a shard (balanced
    # rebalancing may move a few rows across)
    agree = sum(np.bincount(shard_of[labels == c]).max()
                for c in range(labels.max() + 1))
    assert agree >= 0.9 * W.n_rows
