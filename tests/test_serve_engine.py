"""ServeEngine: batched generation is finite, deterministic (greedy)
and respects the KV-cache semantics (engine output == step-by-step)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.models import model as M
from repro.serve import ServeEngine, GenerationConfig


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-780m"])
def test_generate_greedy_deterministic(arch):
    cfg = get_reduced_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=48)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)).astype(np.int32)
    gen = GenerationConfig(max_new_tokens=6, temperature=0.0)
    a = engine.generate(prompts, gen)
    b = engine.generate(prompts, gen)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)
    assert (a >= 0).all() and (a < cfg.vocab).all()


def test_generate_matches_teacher_forcing():
    """Greedy engine tokens == argmax of the parallel forward, step by
    step (validates cache reuse through the engine path)."""
    cfg = get_reduced_config("gemma-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    engine = ServeEngine(cfg, params, max_len=32)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32)
    out = engine.generate(prompt, GenerationConfig(max_new_tokens=4))

    from repro.models import layers as L
    seq = prompt.copy()
    for i in range(4):
        x, _ = M.forward_train(cfg, params, jnp.asarray(seq))
        logits = L.unembed_logits(params["embed"], x[:, -1:],
                                  real_vocab=cfg.vocab)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == int(out[0, i]), f"step {i}"
        seq = np.concatenate([seq, [[nxt]]], axis=1)
