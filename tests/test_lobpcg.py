"""LOBPCG iterative path vs scipy's sparse eigensolver (n > 1024 so the
dense-eigh fallback is NOT taken)."""
import numpy as np
import scipy.sparse as sp
import scipy.sparse.linalg as spla
import pytest

from repro.core import lobpcg
from repro.graphs import delaunay_graph


def test_smallest_eigvecs_match_scipy():
    W, _ = delaunay_graph(11, seed=0)          # n=2048 -> iterative path
    assert W.n_rows > 1024
    k = 4
    evals, evecs = lobpcg.smallest_eigvecs(W, k, seed=0, max_iters=300,
                                           tol=1e-7)
    # scipy reference on the same Laplacian
    rows = np.asarray(W.rows); cols = np.asarray(W.cols)
    vals = np.asarray(W.vals)
    A = sp.coo_matrix((vals, (rows, cols)), shape=(W.n_rows, W.n_rows))
    L = sp.diags(np.asarray(A.sum(axis=1)).ravel()) - A.tocsr()
    ref = np.sort(spla.eigsh(L, k=k, sigma=-1e-3, which="LM",
                             return_eigenvectors=False))
    np.testing.assert_allclose(np.asarray(evals), ref, atol=1e-4)
    # residuals small: ||L v - lambda v||
    V = np.asarray(evecs)
    R = L @ V - V * np.asarray(evals)[None, :]
    assert np.linalg.norm(R, axis=0).max() < 1e-3


def test_eigvec_orthonormal():
    W, _ = delaunay_graph(11, seed=1)
    _, evecs = lobpcg.smallest_eigvecs(W, 3, seed=1, max_iters=200)
    G = np.asarray(evecs.T @ evecs)
    np.testing.assert_allclose(G, np.eye(3), atol=1e-5)
