"""Multilevel subsystem: hierarchy invariants, Galerkin-via-mxm purity,
and V-cycle end-to-end quality (DESIGN.md §6).

The hierarchy invariants pinned here are the contract the V-cycle
relies on:
  * partition of unity — every fine vertex sits in exactly one
    aggregate with weight 1;
  * volume preservation — Galerkin with self-loops kept preserves
    weighted degrees exactly, level to level, so NCut volumes are
    consistent at every level;
  * fine-level label consistency — labels prolonged from any level are
    constant on aggregates.
"""
import dataclasses
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro._vendor.minihypothesis import given, settings, strategies as st

from repro.grblas import SparseMatrix, api
from repro.grblas.api import Descriptor
from repro.core import PSCConfig, p_spectral_cluster, metrics
from repro.graphs import delaunay_graph, ring_of_cliques, sbm_graph
from repro.multilevel import (MultilevelConfig, build_hierarchy,
                              coarsen_graph, heavy_edge_matching,
                              prolongator_from_aggregates)

_T = Descriptor(transpose=True)


def _rand_sym(n, density, seed, weighted=True):
    import scipy.sparse as sp
    A = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed))
    A = A + A.T
    A.setdiag(0)
    A.eliminate_zeros()
    if not weighted:
        A.data[:] = 1.0
    return SparseMatrix.from_scipy(A, dtype=jnp.float64)


# ----------------------------------------------------------- source purity

def test_no_scipy_or_np_matmul_in_multilevel_sources():
    """The acceptance contract: coarse operators are built exclusively
    through grblas.api.mxm — no scipy and no dense matrix products
    anywhere in repro/multilevel/.  Enforced by the pscheck hot-purity /
    dense-matmul rules (repro.analysis, DESIGN.md §11)."""
    from repro import analysis

    pkg = Path(__file__).resolve().parent.parent / "src/repro/multilevel"
    analysis.assert_clean([pkg], rules=["hot-purity", "dense-matmul"])
    # the triple product must actually route through the api
    assert "api.mxm" in (pkg / "coarsen.py").read_text()


# ------------------------------------------------------ matching + P shape

def test_heavy_edge_matching_is_valid_aggregation():
    W = _rand_sym(60, 0.1, seed=0)
    agg = heavy_edge_matching(W)
    n_coarse = agg.max() + 1
    sizes = np.bincount(agg, minlength=n_coarse)
    # pairs from the handshake + leaf joins, capped at max_agg
    assert (sizes >= 1).all() and (sizes <= 4).all()
    assert n_coarse < W.n_rows                         # something contracted
    # every non-singleton member reached its aggregate through an edge:
    # some neighbour shares the aggregate id
    rows, cols = np.asarray(W.rows), np.asarray(W.cols)
    for i in range(W.n_rows):
        if sizes[agg[i]] == 1:
            continue
        nbrs = cols[rows == i]
        assert (agg[nbrs] == agg[i]).any(), f"vertex {i} stranded"


def test_prolongator_partition_of_unity():
    W = _rand_sym(50, 0.12, seed=1)
    agg = heavy_edge_matching(W)
    P = prolongator_from_aggregates(agg, agg.max() + 1, dtype=jnp.float64)
    # exactly one stored entry of weight 1 per fine row
    assert P.nnz == W.n_rows
    np.testing.assert_array_equal(np.asarray(P.rows), np.arange(W.n_rows))
    np.testing.assert_allclose(np.asarray(P.vals), 1.0)
    # P @ 1_c == 1_f through the api itself
    ones_c = jnp.ones(P.n_cols, jnp.float64)
    np.testing.assert_allclose(np.asarray(api.mxm(P, ones_c)), 1.0)
    # column sums == aggregate sizes
    sizes = np.asarray(api.mxm(P, jnp.ones(P.n_rows, jnp.float64), desc=_T))
    np.testing.assert_allclose(sizes, np.bincount(agg, minlength=P.n_cols))


# ------------------------------------------------------- Galerkin operator

def test_galerkin_matches_dense_oracle():
    W = _rand_sym(40, 0.15, seed=2)
    P, Wc, info = coarsen_graph(W)
    Pd = np.zeros((W.n_rows, info.n_coarse))
    Pd[np.arange(W.n_rows), info.agg] = 1.0
    want = Pd.T @ np.asarray(W.to_dense()) @ Pd        # oracle (test-only)
    np.testing.assert_allclose(np.asarray(Wc.to_dense()), want,
                               rtol=1e-10, atol=1e-12)


def test_volume_preservation_chain():
    W, _ = delaunay_graph(10, seed=0)
    h = build_hierarchy(W, coarse_size=64)
    assert h.n_levels >= 3
    total = float(jnp.sum(h.levels[0].vol))
    n_fine = W.n_rows
    for lev, P in enumerate(h.prolongators):
        fine, coarse = h.levels[lev], h.levels[lev + 1]
        # total volume constant level to level
        np.testing.assert_allclose(float(jnp.sum(coarse.vol)), total,
                                   rtol=1e-6)
        # Galerkin with self-loops kept preserves weighted degrees:
        # W_c.row_sums() == Pᵀ W_f.row_sums()
        np.testing.assert_allclose(
            np.asarray(coarse.W.row_sums()),
            np.asarray(api.mxm(P, fine.W.row_sums(), desc=_T)), rtol=1e-5)
        # node mass: counts sum to the finest vertex count
        np.testing.assert_allclose(float(jnp.sum(coarse.counts)), n_fine,
                                   rtol=1e-6)


def test_hierarchy_caps_and_reduction():
    W, _ = delaunay_graph(10, seed=1)
    h = build_hierarchy(W, coarse_size=100, max_levels=4)
    assert h.n_levels <= 4
    sizes = [l.W.n_rows for l in h.levels]
    assert all(b < a for a, b in zip(sizes, sizes[1:]))
    h2 = build_hierarchy(W, coarse_size=100, max_levels=30)
    assert h2.coarsest.W.n_rows <= 2 * 100   # one matching step ~halves


def test_label_consistency_through_prolongation():
    W, _ = delaunay_graph(9, seed=2)
    h = build_hierarchy(W, coarse_size=40)
    rng = np.random.default_rng(0)
    labels_c = rng.integers(0, 4, h.coarsest.W.n_rows)
    fine = h.prolong_labels(labels_c)
    agg = h.aggregate_of_finest(h.n_levels - 1)
    # constant on aggregates, by construction of the composed map
    for a in np.unique(agg)[:50]:
        assert len(set(fine[agg == a].tolist())) == 1
    np.testing.assert_array_equal(fine, labels_c[agg])


def test_sparsify_false_means_off():
    """sparsify=False must DISABLE sparsification (like multilevel=False
    elsewhere), not act as cap=0 and delete every off-diagonal edge."""
    W, _ = delaunay_graph(9, seed=0)
    h_off = build_hierarchy(W, coarse_size=64, sparsify=False)
    h_none = build_hierarchy(W, coarse_size=64, sparsify=None)
    assert [l.W.nnz for l in h_off.levels] == [l.W.nnz for l in h_none.levels]
    W1 = h_off.levels[1].W
    rows, cols = np.asarray(W1.rows), np.asarray(W1.cols)
    assert (rows != cols).sum() > 0          # off-diagonals survived
    with pytest.raises(ValueError):
        build_hierarchy(W, coarse_size=64, sparsify=0)


def test_sparsify_rowcap_volume_preserving():
    """The coarse-level degree cap lumps dropped weight onto the
    diagonal: row sums (volumes) must match the exact Galerkin operator
    entry for entry, and off-diagonal degrees must be bounded."""
    W = _rand_sym(80, 0.5, seed=9)          # dense enough for the cap to bite
    cap = 6
    P, Wc_exact, info = coarsen_graph(W)
    P2, Wc_cap, info2 = coarsen_graph(W, sparsify_cap=cap)
    np.testing.assert_array_equal(info.agg, info2.agg)   # same matching
    np.testing.assert_allclose(np.asarray(Wc_cap.row_sums()),
                               np.asarray(Wc_exact.row_sums()),
                               rtol=1e-10)
    rows = np.asarray(Wc_cap.rows)
    cols = np.asarray(Wc_cap.cols)
    offdeg = np.bincount(rows[rows != cols], minlength=Wc_cap.n_rows)
    assert offdeg.max() <= 2 * cap           # union keep-rule bound
    assert Wc_cap.nnz < Wc_exact.nnz         # it actually dropped edges
    # kept off-diagonal entries are a subset of the exact operator's
    exact = np.asarray(Wc_exact.to_dense())
    capd = np.asarray(Wc_cap.to_dense())
    off = ~np.eye(Wc_cap.n_rows, dtype=bool)
    mask = (capd != 0) & off
    np.testing.assert_allclose(capd[mask], exact[mask], rtol=1e-12)


# ------------------------------------------------------------- V-cycle e2e

def test_multilevel_recovers_planted_partition():
    W, truth = sbm_graph([80] * 4, p_in=0.25, p_out=0.01, seed=3)
    cfg = PSCConfig(k=4, p_target=1.4, newton_iters=10, tcg_iters=8,
                    kmeans_restarts=4, seed=0,
                    multilevel=MultilevelConfig(coarse_size=48))
    res = p_spectral_cluster(W, cfg)
    assert metrics.clustering_accuracy(res.labels, truth, 4) >= 0.95
    assert len(res.labels) == W.n_rows          # fine-graph outputs
    assert res.U.shape == (W.n_rows, 4)
    G = np.asarray(res.U.T @ res.U)
    np.testing.assert_allclose(G, np.eye(4), atol=1e-4)
    assert res.levels, "V-cycle must record per-level refinements"
    assert res.init_labels is not None and np.isfinite(res.init_rcut)
    # bookkeeping stays aligned like the flat result's
    assert len(res.p_path) == len(res.fvals) == len(res.hvp_counts)


def test_multilevel_rcut_close_to_flat():
    W, _ = sbm_graph([70] * 4, p_in=0.3, p_out=0.02, seed=5)
    flat = PSCConfig(k=4, p_target=1.4, newton_iters=12, tcg_iters=10,
                     kmeans_restarts=4, seed=0)
    rf = p_spectral_cluster(W, flat)
    rm = p_spectral_cluster(W, dataclasses.replace(
        flat, multilevel=MultilevelConfig(coarse_size=64)))
    assert rm.rcut <= rf.rcut * 1.1 + 1e-9, (rm.rcut, rf.rcut)


def test_multilevel_true_uses_default_config():
    W, truth = ring_of_cliques(4, 12)
    cfg = PSCConfig(k=4, p_target=1.5, newton_iters=8, tcg_iters=6,
                    kmeans_restarts=4, seed=0, multilevel=True)
    res = p_spectral_cluster(W, cfg)       # graph < coarse_size: flat path
    assert metrics.clustering_accuracy(res.labels, truth, 4) == 1.0


def test_partition_multilevel_fast_path():
    from repro.graphs import partition as graph_partition

    W, _ = sbm_graph([90, 90], p_in=0.25, p_out=0.02, seed=7)
    cfg = PSCConfig(k=2, p_target=1.5, newton_iters=8, tcg_iters=6,
                    kmeans_restarts=4, seed=0,
                    multilevel=MultilevelConfig(coarse_size=32))
    labels, info = graph_partition(W, 2, cfg=cfg)
    sizes = np.bincount(labels, minlength=2)
    assert abs(int(sizes[0]) - int(sizes[1])) <= 4
    assert np.isfinite(info["rcut"])
    # and the multilevel="auto" knob leaves small graphs on the flat path
    labels2, _ = graph_partition(W, 2, multilevel=False, seed=0)
    assert len(labels2) == W.n_rows


# ----------------------------------------------------- property invariants

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       weighted=st.sampled_from([True, False]))
def test_coarsen_invariants_random_graphs(seed, weighted):
    """Partition of unity + exact Galerkin + volume preservation on
    arbitrary random symmetric graphs."""
    W = _rand_sym(36 + seed % 17, 0.18, seed % 9973, weighted=weighted)
    if W.nnz == 0:
        return
    P, Wc, info = coarsen_graph(W)
    n = W.n_rows
    assert P.nnz == n
    np.testing.assert_allclose(np.asarray(api.mxm(
        P, jnp.ones(info.n_coarse, jnp.float64))), 1.0)
    Pd = np.zeros((n, info.n_coarse))
    Pd[np.arange(n), info.agg] = 1.0
    np.testing.assert_allclose(
        np.asarray(Wc.to_dense()),
        Pd.T @ np.asarray(W.to_dense()) @ Pd, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(
        np.asarray(Wc.row_sums()),
        np.asarray(api.mxm(P, W.row_sums(), desc=_T)), rtol=1e-9)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_matching_deterministic(seed):
    W = _rand_sym(40, 0.15, seed % 7919)
    a1 = heavy_edge_matching(W)
    a2 = heavy_edge_matching(W)
    np.testing.assert_array_equal(a1, a2)
