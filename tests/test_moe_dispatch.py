"""MoE dispatch equivalence: the a2a-EP and psum-EP shard_map schedules
must produce the same numbers as the meshless reference (§Perf E3b).

Runs in a subprocess with 8 host devices: mesh (data=2, model=4)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_reduced_config
    from repro.models.config import MoEConfig
    from repro.models import model as M
    from repro.models import moe as MOE

    # 8 experts over model=4 (EP, divisible); huge capacity => no drops
    cfg = get_reduced_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32", params_dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                      capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    blk = jax.tree.map(lambda x: x[0], params["blocks"]["ffn"])

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)

    # S=8 divisible by model=4 -> a2a path
    x8 = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)), jnp.float32)
    ref8, aux_ref8 = MOE.moe_block(cfg, blk, x8, mesh=None)
    with mesh:
        got8, aux8 = jax.jit(
            lambda p, x: MOE.moe_block(cfg, p, x, mesh=mesh))(blk, x8)
    np.testing.assert_allclose(np.asarray(got8), np.asarray(ref8),
                               rtol=2e-4, atol=2e-5)
    # aux is a mean of shard-local load-balance estimators: same scale,
    # not bit-equal to the global estimator
    assert abs(float(aux8) - float(aux_ref8)) < 0.5 * float(aux_ref8) + 0.1

    # S=6 NOT divisible by model=4 -> replicated-x psum path
    x6 = jnp.asarray(rng.standard_normal((4, 6, cfg.d_model)), jnp.float32)
    ref6, _ = MOE.moe_block(cfg, blk, x6, mesh=None)
    with mesh:
        got6, _ = jax.jit(
            lambda p, x: MOE.moe_block(cfg, p, x, mesh=mesh))(blk, x6)
    np.testing.assert_allclose(np.asarray(got6), np.asarray(ref6),
                               rtol=2e-4, atol=2e-5)
    print("MOE_DISPATCH_OK")
""")


def test_moe_a2a_and_psum_match_reference():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=560)
    assert "MOE_DISPATCH_OK" in r.stdout, r.stdout[-500:] + r.stderr[-2000:]
