"""kmeans_assign + flash_attention kernels (interpret) vs oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("n,d,kc", [(100, 4, 3), (256, 8, 16), (500, 2, 7),
                                    (64, 16, 2)])
def test_kmeans_assign_matches_ref(n, d, kc):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((kc, d)), jnp.float32)
    lab, dist = kmeans_assign(X, C, interpret=True, block_m=64)
    lab_r, dist_r = kmeans_assign_ref(X, C)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_r))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 2, 128, 32),     # MHA
    (1, 4, 2, 128, 32),     # GQA 2:1
    (2, 8, 1, 256, 16),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, Hq, Hkv, S, D, causal):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_sliding_window():
    rng = np.random.default_rng(2)
    B, Hq, Hkv, S, D = 1, 2, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=128, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_gradient_flows():
    """custom_vjp backward (ref recompute) produces finite grads == ref's."""
    rng = np.random.default_rng(3)
    B, Hq, Hkv, S, D = 1, 2, 1, 128, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)

    g1 = jax.grad(lambda q: flash_attention(q, k, v, interpret=True).sum())(q)
    g2 = jax.grad(lambda q: attention_ref(q, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-5)
