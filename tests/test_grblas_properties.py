"""Hypothesis property tests for the algebraic layer + system invariants.

Runs under the real ``hypothesis`` when installed (CI); the pinned
local image falls back to the vendored minimal generator
(repro._vendor.minihypothesis — same decorator surface, deterministic
seeded search) so the algebraic property suite gates locally too."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro._vendor.minihypothesis import given, settings, strategies as st

from repro.grblas import (SparseMatrix, mxv, reals_ring, min_plus_ring,
                          boolean_ring, max_times_ring)
from repro.grblas.semiring import phi_p
from repro.core import phi as PHI
from repro.core import metrics
from repro.graphs import ring_of_cliques


finite = st.floats(min_value=-100, max_value=100, allow_nan=False,
                   width=32)


@settings(max_examples=50, deadline=None)
@given(a=finite, b=finite, c=finite)
def test_semiring_laws_reals(a, b, c):
    for ring in (reals_ring, min_plus_ring, max_times_ring):
        A, B, C = jnp.float32(a), jnp.float32(b), jnp.float32(c)
        # add associativity + commutativity
        l = ring.add(ring.add(A, B), C)
        r = ring.add(A, ring.add(B, C))
        np.testing.assert_allclose(float(l), float(r), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(ring.add(A, B)),
                                   float(ring.add(B, A)), rtol=1e-6)
        # identities
        np.testing.assert_allclose(float(ring.add(A, ring.zero)), a,
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(float(ring.mul(A, ring.one)), a,
                                   rtol=1e-6, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(x=finite, p=st.floats(min_value=1.05, max_value=2.0))
def test_phi_p_odd_and_monotone(x, p):
    f = float(phi_p(jnp.float64(x), p))
    f_neg = float(phi_p(jnp.float64(-x), p))
    np.testing.assert_allclose(f, -f_neg, rtol=1e-8, atol=1e-12)
    if abs(x) > 1e-3:
        g = float(phi_p(jnp.float64(x * 1.1), p))
        assert (g - f) * np.sign(x) >= -1e-9    # monotone increasing


@settings(max_examples=30, deadline=None)
@given(p=st.floats(min_value=1.05, max_value=2.0),
       eps=st.floats(min_value=1e-12, max_value=1e-4))
def test_phi_prime_nonnegative(p, eps):
    xs = jnp.linspace(-5, 5, 101, dtype=jnp.float64)
    d = PHI.phi_prime(xs, p, eps)
    assert float(jnp.min(d)) >= 0.0             # smoothed phi' must be >= 0


@settings(max_examples=25, deadline=None)
@given(perm_seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_rcut_invariant_under_label_permutation(perm_seed):
    W, truth = ring_of_cliques(4, 6)
    rng = np.random.default_rng(perm_seed)
    perm = rng.permutation(4)
    relabeled = perm[truth]
    a = float(metrics.rcut(W, truth, 4))
    b = float(metrics.rcut(W, relabeled, 4))
    np.testing.assert_allclose(a, b, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_spmv_linearity(seed):
    import scipy.sparse as sp
    rng = np.random.default_rng(seed)
    A = sp.random(24, 24, density=0.2,
                  random_state=np.random.RandomState(seed % 1000))
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    x = rng.standard_normal(24)
    y = rng.standard_normal(24)
    a, b = rng.standard_normal(2)
    lhs = np.asarray(mxv(M, jnp.asarray(a * x + b * y)))
    rhs = a * np.asarray(mxv(M, jnp.asarray(x))) \
        + b * np.asarray(mxv(M, jnp.asarray(y)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_boolean_ring_is_reachability(seed):
    import scipy.sparse as sp
    A = sp.random(16, 16, density=0.15,
                  random_state=np.random.RandomState(seed % 997))
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    x = np.zeros(16, bool)
    x[seed % 16] = True
    got = np.asarray(mxv(M, jnp.asarray(x), boolean_ring))
    want = (A.toarray() != 0) @ x
    np.testing.assert_array_equal(got, want.astype(bool))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       p=st.sampled_from([1.2, 1.5, 2.0]),
       C=st.sampled_from([4, 8, 16]),
       sigma=st.sampled_from([8, 32, None]))
def test_sellcs_equals_coo_across_rings(seed, p, C, sigma):
    """The sliced layout is a pure execution detail: sellcs == coo for
    the reals ring (1-D and multivector), the p-Laplacian apply, and the
    Newton-HVP pair ring, on arbitrary symmetric patterns x (C, σ)."""
    import scipy.sparse as sp
    from repro.grblas import (Descriptor, mxm, plap_edge_semiring,
                              plap_hvp_edge_semiring)

    A = sp.random(48, 48, density=0.12,
                  random_state=np.random.RandomState(seed % 9973))
    A = A + A.T
    M = SparseMatrix.from_scipy(A, build_sellcs=True, sell_c=C,
                                sell_sigma=sigma)
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((48, 3)), jnp.float32)
    coo, sell = Descriptor(backend="coo"), Descriptor(backend="sellcs")

    got = np.asarray(mxm(M, X, desc=sell))
    np.testing.assert_allclose(got, np.asarray(mxm(M, X, desc=coo)),
                               rtol=1e-4, atol=1e-5)
    ring = plap_edge_semiring(p, eps=1e-6)
    np.testing.assert_allclose(np.asarray(mxm(M, X, ring, desc=sell)),
                               np.asarray(mxm(M, X, ring, desc=coo)),
                               rtol=1e-4, atol=1e-5)
    Eta = jnp.asarray(rng.standard_normal((48, 3)) * 0.1, jnp.float32)
    hring = plap_hvp_edge_semiring(p, eps=1e-6)
    np.testing.assert_allclose(np.asarray(mxm(M, (X, Eta), hring, desc=sell)),
                               np.asarray(mxm(M, (X, Eta), hring, desc=coo)),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       method=st.sampled_from(["rcm", "degree"]))
def test_reorder_leaves_cut_metrics_invariant(seed, method):
    """Graph relabeling under graphs.reorder must not move RCut/NCut:
    metrics on (W2, labels[perm]) equal metrics on (W, labels)."""
    from repro.graphs import reorder

    W, truth = ring_of_cliques(4, 6)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, W.n_rows)
    W2, perm, _ = reorder(W, method)
    a = float(metrics.rcut(W, labels, 4))
    b = float(metrics.rcut(W2, labels[perm], 4))
    np.testing.assert_allclose(a, b, rtol=1e-5)
    an = float(metrics.ncut(W, labels, 4))
    bn = float(metrics.ncut(W2, labels[perm], 4))
    np.testing.assert_allclose(an, bn, rtol=1e-5)


def test_kmeans_inertia_decreases():
    from repro.core.kmeans import lloyd, pairwise_sqdist
    import jax
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((120, 3)), jnp.float32)
    C0 = X[:4]
    i_prev = None
    for iters in (1, 3, 10, 30):
        _, C, inertia = lloyd(X, C0, iters=iters)
        if i_prev is not None:
            assert float(inertia) <= i_prev + 1e-5
        i_prev = float(inertia)
