"""grblas container + ops correctness vs scipy/dense oracles."""
import numpy as np
import scipy.sparse as sp
import jax.numpy as jnp
import pytest

from repro.grblas import (
    SparseMatrix, mxv, vxm, mxm, Descriptor, reals_ring, min_plus_ring,
    boolean_ring, plap_edge_semiring,
)


def _rand_sparse(rng, n, m, density=0.1):
    A = sp.random(n, m, density=density, random_state=np.random.RandomState(0),
                  format="coo")
    return A


@pytest.mark.parametrize("n,m", [(17, 17), (64, 64), (50, 30)])
def test_mxv_matches_scipy(rng, n, m):
    A = _rand_sparse(rng, n, m)
    x = rng.standard_normal(m)
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    got = mxv(M, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), A @ x, rtol=1e-10)


def test_spmm_multivector(rng):
    A = _rand_sparse(rng, 40, 40)
    X = rng.standard_normal((40, 5))
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    got = mxm(M, jnp.asarray(X))
    np.testing.assert_allclose(np.asarray(got), A @ X, rtol=1e-10)
    # COO path agrees with ELL path
    got_coo = mxm(M, jnp.asarray(X), desc=Descriptor(backend="coo"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(got_coo), rtol=1e-10)


def test_vxm_transposes(rng):
    A = _rand_sparse(rng, 30, 50)
    x = rng.standard_normal(30)
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    got = vxm(jnp.asarray(x), M)
    np.testing.assert_allclose(np.asarray(got), x @ A, rtol=1e-10)


def test_min_plus_ring(rng):
    """One SpMV under (min,+) = one relaxation step of shortest paths."""
    A = _rand_sparse(rng, 25, 25, 0.2)
    A.data = np.abs(A.data) + 0.1
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    x = np.abs(rng.standard_normal(25))
    got = np.asarray(mxv(M, jnp.asarray(x), min_plus_ring))
    dense = A.toarray()
    want = np.full(25, np.inf)
    for i in range(25):
        nz = dense[i] != 0
        if nz.any():
            want[i] = np.min(dense[i][nz] + x[nz])
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_edge_semiring_plap(rng):
    """Edge-semiring SpMV == explicit p-Laplacian apply."""
    from repro.graphs import ring_of_cliques
    W, _ = ring_of_cliques(3, 6)
    x = jnp.asarray(rng.standard_normal(W.n_rows))
    p = 1.5
    got = np.asarray(mxm(W, x, plap_edge_semiring(p, eps=0.0)))
    Wd = np.asarray(W.to_dense())
    xd = np.asarray(x)
    want = np.zeros(W.n_rows)
    for i in range(W.n_rows):
        d = xd[i] - xd
        want[i] = np.sum(Wd[i] * np.abs(d) ** (p - 1) * np.sign(d))
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-10)


def test_bsr_layout_roundtrip(rng):
    A = _rand_sparse(rng, 100, 100, 0.05)
    M = SparseMatrix.from_scipy(A, build_bsr=True, block_size=16,
                                dtype=jnp.float64)
    # reconstruct dense from BSR blocks
    bs = M.block_size
    n_rb = -(-M.n_rows // bs)
    dense = np.zeros((n_rb * bs, n_rb * bs))
    rb = np.asarray(M.bsr_row_ids)
    cb = np.asarray(M.bsr_indices)
    blocks = np.asarray(M.bsr_blocks)
    for b in range(len(rb)):
        dense[rb[b]*bs:(rb[b]+1)*bs, cb[b]*bs:(cb[b]+1)*bs] = blocks[b]
    np.testing.assert_allclose(dense[:100, :100], A.toarray(), rtol=1e-10)
    assert M.bsr_fill_ratio() >= 1.0


def test_row_degrees_and_sums(rng):
    A = _rand_sparse(rng, 33, 33, 0.15)
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(M.row_sums()),
                               np.asarray(A.sum(axis=1)).ravel(), rtol=1e-10)
