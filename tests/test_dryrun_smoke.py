"""Dry-run smoke: one cheap cell per step-kind compiles on the
production mesh in a subprocess (512 host devices)."""
import json
import subprocess
import sys
import tempfile
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"


def _run_cell(arch, shape, tmp):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp)],
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=560)
    tag = f"{arch}__{shape}__single"
    out = json.loads((Path(tmp) / f"{tag}.json").read_text())
    assert out["status"] == "ok", (out["status"], r.stdout[-800:],
                                   r.stderr[-800:])
    roof = out["roofline"]
    assert roof["flops"] > 0 and roof["wire_bytes_per_dev"] >= 0
    assert out["bytes_per_device"] > 0
    return out


def test_dryrun_decode_cell():
    with tempfile.TemporaryDirectory() as tmp:
        out = _run_cell("internvl2-1b", "decode_32k", tmp)
        assert out["roofline"]["bottleneck"] in ("compute", "memory",
                                                 "collective")


def test_dryrun_prefill_cell():
    with tempfile.TemporaryDirectory() as tmp:
        _run_cell("chatglm3-6b", "prefill_32k", tmp)
