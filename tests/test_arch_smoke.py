"""Per-architecture smoke tests: REDUCED config of the same family runs
one forward + train-grad step and one prefill->decode step on CPU,
asserting output shapes and no NaNs.  (Full configs are exercised only
via the dry-run: ShapeDtypeStruct, no allocation.)"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import model as M


def _inputs(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    labels = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    extra = {}
    if cfg.family == "encdec":
        extra["enc_frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["extra_embeds"] = jax.random.normal(
            ks[2], (B, cfg.vis_seq, cfg.d_model), jnp.float32)
    return tokens, labels, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tokens, labels, extra = _inputs(cfg, key)

    loss, (nll, aux) = M.loss_fn(cfg, params, tokens, labels, **extra)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    # one grad step
    g = jax.grad(lambda p: M.loss_fn(cfg, p, tokens, labels, **extra)[0])(params)
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: float(jnp.sum(x * x)), g))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = get_reduced_config(arch)
    if not cfg.has_decoder:
        pytest.skip("encoder-only")
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S, max_len = 2, 16, 32
    tokens, _, extra = _inputs(cfg, key, B=B, S=S)

    logits, cache, pos = M.prefill(cfg, params, tokens, max_len, **extra)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    positions = jnp.full((B, 1), pos, jnp.int32)
    logits2, cache2 = M.decode_step(cfg, params, cache, nxt, positions)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["gemma-2b", "chatglm3-6b", "mamba2-780m",
                                  "deepseek-v3-671b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits (stringent
    correctness: cache path must equal the parallel path)."""
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, S = 1, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # teacher-forced logits at the last position
    x, _ = M.forward_train(cfg, params, tokens)
    from repro.models import layers as L
    full_logits = L.unembed_logits(params["embed"], x)        # (B,S,V)

    # prefill on S-1 tokens then decode the S-th
    logits_p, cache, pos = M.prefill(cfg, params, tokens[:, :-1], max_len=S)
    positions = jnp.full((B, 1), S - 1, jnp.int32)
    logits_d, _ = M.decode_step(cfg, params, cache, tokens[:, -1:], positions)

    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)
    # prefill's own last logits match the forward at position S-2
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, -2], np.float32), rtol=2e-3, atol=2e-3)
