"""SELL-C-σ layout invariants, auto-dispatch policy, reorder metrics,
and the memoized Newton-step trace contract (psc continuation must not
re-trace per p level)."""
import numpy as np
import scipy.sparse as sp
import jax.numpy as jnp
import pytest

from repro.grblas import (
    Descriptor,
    BackendUnavailableError,
    SELLCS_AUTO_THRESHOLD,
    SparseMatrix,
    mxm,
    reals_ring,
)
from repro.grblas import api


def _rand(n=120, density=0.06, seed=0, **kw):
    A = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="coo")
    A = A + A.T
    return SparseMatrix.from_scipy(A, **kw)


def _skewed(n=400, hub_deg=60, seed=0, **kw):
    """Background degree ~4 plus a few hub rows — ELL fill blows past the
    auto threshold."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, 2 * n)
    cols = rng.integers(0, n, 2 * n)
    hub_cols = rng.integers(0, n, 3 * hub_deg)
    hub_rows = np.repeat(np.arange(3), hub_deg)
    r = np.concatenate([rows, cols, hub_rows, hub_cols])
    c = np.concatenate([cols, rows, hub_cols, hub_rows])
    keep = r != c
    v = np.ones(keep.sum())
    return SparseMatrix.from_coo(r[keep], c[keep], v, (n, n), **kw)


@pytest.mark.parametrize("n,C,sigma", [(120, 8, 16), (97, 16, None),
                                       (33, 8, 8), (8, 32, None)])
def test_layout_shape_invariants(n, C, sigma):
    # build_ell forced: the fill-ratio invariant below compares against it
    M = _rand(n=n, build_ell=True, build_sellcs=True, sell_c=C,
              sell_sigma=sigma)
    assert M.sell_n_pad % M.sell_c == 0 and M.sell_n_pad >= n
    stored = 0
    for r, cols_r in enumerate(M.sell_cols):
        rows_r, w = cols_r.shape
        assert rows_r % M.sell_c == 0 and w >= 1
        assert M.sell_vals[r].shape == (rows_r, w)
        assert M.sell_row0[r] == (0 if r == 0 else
                                  M.sell_row0[r - 1]
                                  + M.sell_cols[r - 1].shape[0])
        stored += rows_r * w
    # per-slice padding can never store more than global-max padding
    # would over the same n_pad rows (phantom rows are the C-alignment)
    assert (M.sellcs_fill_ratio()
            <= M.ell_fill_ratio() * M.sell_n_pad / n + 1e-9)
    assert stored == round(M.sellcs_fill_ratio() * M.nnz)
    # the permutation round-trips: perm[inv[o]] == o for every row
    perm, inv = np.asarray(M.sell_perm), np.asarray(M.sell_inv)
    assert (perm[inv] == np.arange(n)).all()


def test_sigma_windows_sort_locally_only():
    """σ bounds how far a row may travel: with σ == C == n/4 each window
    permutes internally, so permuted position // σ == original // σ."""
    M = _rand(n=128, build_sellcs=True, sell_c=32, sell_sigma=32)
    inv = np.asarray(M.sell_inv)
    assert (inv // 32 == np.arange(128) // 32).all()


def test_w_align_merges_runs_and_stays_equivalent():
    """sell_w_align > 1 rounds slice widths up: no more runs than the
    tight build, every width a multiple of the alignment, same result."""
    tight = _skewed(build_sellcs=True, sell_c=8)
    merged = _skewed(build_sellcs=True, sell_c=8, sell_w_align=4)
    assert merged.sell_w_align == 4
    assert len(merged.sell_cols) <= len(tight.sell_cols)
    assert all(c.shape[1] % 4 == 0 for c in merged.sell_cols)
    X = jnp.ones((merged.n_rows, 3), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(mxm(merged, X, desc=Descriptor(backend="sellcs"))),
        np.asarray(mxm(merged, X, desc=Descriptor(backend="coo"))),
        rtol=1e-4, atol=1e-4)
    # reorder preserves the alignment parameter with the rest
    from repro.graphs import reorder
    assert reorder(merged, "degree")[0].sell_w_align == 4


def test_auto_build_and_auto_dispatch_on_skew():
    W = _skewed(build_ell=True)          # build_sellcs unset -> auto
    assert W.ell_fill_ratio() > SELLCS_AUTO_THRESHOLD
    assert W.sell_cols is not None, "auto-build should trigger on skew"
    X = jnp.ones((W.n_rows, 4), jnp.float32)
    assert api.available_backends(W, X)[0] == "sellcs"
    want = np.asarray(W.to_dense()) @ np.asarray(X)
    np.testing.assert_allclose(np.asarray(mxm(W, X)), want,
                               rtol=1e-4, atol=1e-4)


def test_auto_build_skips_dead_ell_on_skew():
    """With build_ell unset, the skew regime must not allocate the
    (n, hub_degree) ELL blocks that auto-dispatch would never use —
    it builds the sliced layout instead.  build_ell=True forces ELL."""
    W = _skewed()                        # both build flags on auto
    assert W.ell_cols is None and W.sell_cols is not None
    X = jnp.ones((W.n_rows, 4), jnp.float32)
    np.testing.assert_allclose(np.asarray(mxm(W, X)),
                               np.asarray(W.to_dense()) @ np.asarray(X),
                               rtol=1e-4, atol=1e-4)
    assert _skewed(build_ell=True).ell_cols is not None
    # low-skew graphs keep ELL under the same auto default
    assert _rand().ell_cols is not None


def test_auto_defers_to_ell_on_low_fill():
    M = _rand(build_sellcs=True)         # uniform degrees: low ELL fill
    assert M.ell_fill_ratio() <= SELLCS_AUTO_THRESHOLD
    X = jnp.ones((M.n_rows, 4), jnp.float32)
    order = api.available_backends(M, X)
    assert "sellcs" not in order and order[0] == "ell"
    # ...but naming it explicitly always executes
    got = mxm(M, X, desc=Descriptor(backend="sellcs"))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(M.to_dense()) @ np.asarray(X),
                               rtol=1e-4, atol=1e-4)


def test_rectangular_matrices_never_build_sellcs():
    """The layout shares one permutation across row and column space, so
    it is square-only: explicit build raises, auto skips silently."""
    with pytest.raises(ValueError, match="square"):
        SparseMatrix.from_coo([0, 1], [2, 0], [1.0, 1.0], (2, 4),
                              build_sellcs=True)
    # wide matrix with a hub row: auto must not trip the skew trigger
    r = np.zeros(40, np.int64)
    c = np.arange(40, dtype=np.int64)
    M = SparseMatrix.from_coo(r, c, np.ones(40), (8, 40))
    assert M.sell_cols is None
    x = jnp.ones(40, jnp.float32)
    np.testing.assert_allclose(np.asarray(mxm(M, x)),
                               np.asarray(M.to_dense()) @ np.asarray(x))


def test_empty_matrix_supports_named_sellcs():
    M = SparseMatrix.from_coo([], [], [], (4, 4), build_sellcs=True)
    assert M.sell_cols is not None
    X = jnp.ones((4, 3), jnp.float32)
    got = mxm(M, X, desc=Descriptor(backend="sellcs"))
    np.testing.assert_allclose(np.asarray(got), np.zeros((4, 3)))
    got1 = mxm(M.with_vals(M.vals), X, desc=Descriptor(backend="sellcs"))
    np.testing.assert_allclose(np.asarray(got1), np.zeros((4, 3)))


def test_named_sellcs_without_layout_raises():
    M = _rand(build_sellcs=False)
    X = jnp.ones((M.n_rows, 4), jnp.float32)
    with pytest.raises(BackendUnavailableError):
        mxm(M, X, desc=Descriptor(backend="sellcs"))


def test_with_vals_scalar_and_1d_inputs():
    M = _rand(build_sellcs=True, sell_c=8)
    rng = np.random.default_rng(1)
    newv = jnp.asarray(rng.standard_normal(M.nnz), jnp.float32)
    Wv = M.with_vals(newv)
    x = jnp.asarray(rng.standard_normal(M.n_rows), jnp.float32)
    want = np.asarray(Wv.to_dense()) @ np.asarray(x)
    got = np.asarray(mxm(Wv, x, reals_ring, desc=Descriptor(backend="sellcs")))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ell_builds_in_target_dtype():
    M = _rand(build_ell=True, dtype=jnp.float64)
    assert M.ell_vals.dtype == jnp.float64
    M32 = _rand(build_ell=True, dtype=jnp.float32)
    assert M32.ell_vals.dtype == jnp.float32
    assert M32.ell_cols.dtype == jnp.int32


def test_fill_ratio_accessors_per_layout():
    M = _rand(build_ell=True, build_bsr=True, block_size=16,
              build_sellcs=True)
    assert M.ell_fill_ratio() >= 1.0
    assert M.bsr_fill_ratio() >= 1.0
    assert 1.0 <= M.sellcs_fill_ratio() <= M.ell_fill_ratio()
    assert np.isnan(_rand(build_ell=False).ell_fill_ratio())
    # deprecated alias still reports the BSR number
    assert M.fill_ratio == M.bsr_fill_ratio()


def test_reorder_reduces_bandwidth_and_preserves_matrix():
    from repro.graphs import bandwidth, delaunay_graph, reorder

    W, _ = delaunay_graph(8, seed=0, locality_order=False)
    W2, perm, inv = reorder(W, "rcm")
    assert bandwidth(W2) < bandwidth(W)
    assert (perm[inv] == np.arange(W.n_rows)).all()
    D, D2 = np.asarray(W.to_dense()), np.asarray(W2.to_dense())
    np.testing.assert_allclose(D2, D[np.ix_(perm, perm)], rtol=1e-6)


def test_reorder_preserves_built_layouts():
    from repro.graphs import reorder

    M = _rand(build_ell=True, build_bsr=True, block_size=16,
              build_sellcs=True, sell_c=8, sell_sigma=16)
    M2, _, _ = reorder(M, "degree")
    assert M2.ell_cols is not None and M2.bsr_blocks is not None
    assert M2.sell_cols is not None
    assert (M2.sell_c, M2.sell_sigma) == (M.sell_c, M.sell_sigma)
    assert M2.block_size == M.block_size


def test_newton_continuation_traces_once():
    """The memoized jitted Newton step must serve every p level of the
    continuation (and repeat runs) from ONE trace on the jnp paths."""
    from repro.core import psc
    from repro.graphs import ring_of_cliques

    W, _ = ring_of_cliques(3, 8)
    cfg = psc.PSCConfig(k=3, p_target=1.4, newton_iters=3, tcg_iters=4,
                        kmeans_restarts=2, kmeans_iters=10, seed=1)
    before = len(psc._NEWTON_TRACES)
    res = psc.p_spectral_cluster(W, cfg)
    assert len(res.p_path) >= 3          # several continuation levels...
    traced = len(psc._NEWTON_TRACES) - before
    assert traced <= 1                   # ...but at most one fresh trace
    psc.p_spectral_cluster(W, cfg)       # repeat run: fully cached
    assert len(psc._NEWTON_TRACES) - before == traced
    fn, _ = psc._jitted_minimize(cfg, 1.4, W,
                                 jnp.zeros((W.n_rows, cfg.k), jnp.float32))
    cache_size = getattr(fn, "_cache_size", lambda: None)()
    if cache_size is not None:           # jax.jit cache stats, if exposed
        assert cache_size == 1
