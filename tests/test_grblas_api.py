"""Unified execution API: Descriptor dispatch, write semantics, fast-path
registry, generic monoid folds, and the deprecated-shim contract."""
import numpy as np
import scipy.sparse as sp
import jax
import jax.numpy as jnp
import pytest

from repro.grblas import (
    BackendUnavailableError,
    Descriptor,
    SparseMatrix,
    available_backends,
    boolean_ring,
    fast_paths,
    min_plus_ring,
    mxm,
    mxv,
    plap_edge_semiring,
    plap_hvp_edge_semiring,
    reals_ring,
    vxm,
)
from repro.grblas.semiring import Semiring


def _sym(n=40, bs=16, density=0.1, dtype=jnp.float64, seed=0):
    A = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="coo")
    A = A + A.T
    return A, SparseMatrix.from_scipy(A, build_bsr=True, block_size=bs,
                                      dtype=dtype)


# ------------------------------------------------------------ dispatch rules

@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="auto priority order is platform-specific")
def test_auto_prefers_ell_on_cpu():
    _, M = _sym()
    X = jnp.ones((M.n_rows, 3))
    assert available_backends(M, X)[0] == "ell"


def test_auto_falls_back_to_coo_without_ell():
    A, _ = _sym()
    M = SparseMatrix.from_scipy(A, build_ell=False, dtype=jnp.float64)
    X = jnp.ones((M.n_rows, 3))
    assert available_backends(M, X)[0] == "coo"


def test_generic_monoid_never_rides_ell():
    """ELL pads are only add-identities for the reals ring."""
    _, M = _sym()
    x = jnp.ones(M.n_rows)
    names = available_backends(M, x, min_plus_ring)
    assert "ell" not in names
    with pytest.raises(BackendUnavailableError):
        mxv(M, x, min_plus_ring, desc=Descriptor(backend="ell"))


def test_unknown_backend_raises():
    _, M = _sym()
    with pytest.raises(BackendUnavailableError, match="unknown backend"):
        mxv(M, jnp.ones(M.n_rows), desc=Descriptor(backend="csr_gpu"))


def test_named_backend_validates_layout():
    A, _ = _sym()
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)  # no BSR built
    with pytest.raises(BackendUnavailableError, match="bsr_pallas"):
        mxm(M, jnp.ones((M.n_rows, 2)),
            desc=Descriptor(backend="bsr_pallas"))


def test_dist_requires_mesh():
    _, M = _sym()
    with pytest.raises(BackendUnavailableError):
        mxm(M, jnp.ones((M.n_rows, 2)), desc=Descriptor(backend="dist"))


def test_edge_ring_dispatch_by_kind():
    _, M = _sym(dtype=jnp.float32)
    X = jnp.ones((M.n_rows, 2), jnp.float32)
    ring = plap_edge_semiring(1.5, 1e-6)
    assert "edge_pallas" in available_backends(M, X, ring)
    pair = plap_hvp_edge_semiring(1.5, 1e-6)
    assert "edge_pallas" in available_backends(M, (X, X), pair)
    # a pair ring needs a pair input
    with pytest.raises(BackendUnavailableError):
        mxm(M, X, pair)


# -------------------------------------------------- vxm / transpose semantics

def test_vxm_edge_semiring_multivector_regression():
    """ops.py:82 used `cond and a or b` on arrays -> truth-value crash for
    any 2-D multivector under an edge ring.  The API must broadcast."""
    A, M = _sym()
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.standard_normal((M.n_rows, 4)))
    ring = plap_edge_semiring(1.5, eps=0.0)
    got = vxm(X, M, ring)                       # crashed before the redesign
    # oracle per column: y_j = sum_i w_ij phi(x_j - x_i)
    Wd = np.asarray(M.to_dense())
    xd = np.asarray(X)
    p = 1.5
    want = np.zeros_like(xd)
    for col in range(xd.shape[1]):
        for j in range(M.n_rows):
            d = xd[j, col] - xd[:, col]
            want[j, col] = np.sum(Wd[:, j] * np.abs(d) ** (p - 1) * np.sign(d))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-8, atol=1e-10)


def test_vxm_is_transposed_mxm():
    A = sp.random(30, 50, density=0.1,
                  random_state=np.random.RandomState(3), format="coo")
    M = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(30))
    got = vxm(x, M)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ A.toarray(),
                               rtol=1e-10)
    # vxm flips the descriptor's transpose bit: flipping it twice on a
    # square matrix lands back on plain mxv
    Asq = sp.random(30, 30, density=0.1,
                    random_state=np.random.RandomState(4), format="coo")
    Msq = SparseMatrix.from_scipy(Asq, dtype=jnp.float64)
    got2 = vxm(x, Msq, desc=Descriptor(transpose=True))
    np.testing.assert_allclose(np.asarray(got2), np.asarray(mxv(Msq, x)),
                               rtol=1e-12)


# ------------------------------------------------------------ write semantics

def test_mask_writes_add_identity():
    _, M = _sym()
    x = jnp.asarray(np.random.default_rng(0).standard_normal(M.n_rows))
    keep = np.arange(M.n_rows) % 2 == 0
    y = mxv(M, x, mask=keep)
    full = np.asarray(mxv(M, x))
    np.testing.assert_allclose(np.asarray(y)[keep], full[keep], rtol=1e-12)
    assert np.all(np.asarray(y)[~keep] == 0.0)
    # min-plus identity is +inf, not 0
    ym = mxv(M, jnp.abs(x), min_plus_ring, mask=keep)
    assert np.all(np.isinf(np.asarray(ym)[~keep]))


def test_accum_and_masked_accum():
    _, M = _sym()
    x = jnp.asarray(np.random.default_rng(0).standard_normal(M.n_rows))
    C = jnp.ones(M.n_rows)
    T = np.asarray(mxv(M, x))
    got = np.asarray(mxv(M, x, accum=(jnp.add, C)))
    np.testing.assert_allclose(got, 1.0 + T, rtol=1e-12)
    keep = np.arange(M.n_rows) % 3 == 0
    got2 = np.asarray(mxv(M, x, mask=keep, accum=(jnp.add, C)))
    np.testing.assert_allclose(got2[keep], 1.0 + T[keep], rtol=1e-12)
    np.testing.assert_allclose(got2[~keep], 1.0)   # C kept where masked out


def test_row_mask_broadcasts_over_multivector():
    _, M = _sym()
    X = jnp.asarray(np.random.default_rng(0).standard_normal((M.n_rows, 3)))
    keep = np.arange(M.n_rows) < 10
    Y = np.asarray(mxm(M, X, mask=keep))
    assert np.all(Y[10:] == 0.0) and np.any(Y[:10] != 0.0)


# ----------------------------------------------- fast paths + generic folds

def test_segment_reduce_generic_fold_is_correct():
    """Unregistered monoid: the fold must honour (add, zero) — the old
    code silently used segment_sum."""
    custom = Semiring(add=jnp.minimum, mul=lambda a, b: a + b,
                      zero=jnp.inf, one=0.0, name="unregistered_min_+")
    assert fast_paths(custom).segment is None
    vals = jnp.asarray([3.0, 1.0, 2.0, 5.0])
    segs = jnp.asarray([0, 0, 2, 2])
    got = np.asarray(custom.segment_reduce(vals, segs, 3))
    np.testing.assert_allclose(got, [1.0, np.inf, 2.0])
    # and end-to-end through mxv it matches the registered twin
    _, M = _sym()
    x = jnp.abs(jnp.asarray(np.random.default_rng(0).standard_normal(M.n_rows)))
    got = mxv(M, x, custom, desc=Descriptor(backend="coo"))
    want = mxv(M, x, min_plus_ring, desc=Descriptor(backend="coo"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_reduce_uses_registry_and_generic_fold():
    from repro.grblas import grb_reduce
    a = jnp.asarray(np.random.default_rng(0).standard_normal((6, 4)))
    np.testing.assert_allclose(np.asarray(grb_reduce(a, reals_ring, axis=0)),
                               np.asarray(a).sum(axis=0), rtol=1e-12)
    np.testing.assert_allclose(float(grb_reduce(a, min_plus_ring)),
                               np.asarray(a).min(), rtol=1e-12)
    assert bool(grb_reduce(a > 0, boolean_ring)) == bool((np.asarray(a) > 0).any())
    custom = Semiring(add=jnp.maximum, mul=lambda x, y: x * y,
                      zero=-jnp.inf, one=1.0, name="unregistered_max_x")
    np.testing.assert_allclose(float(grb_reduce(a, custom)),
                               np.asarray(a).max(), rtol=1e-12)


# ------------------------------------------------- multivals + shim contract

def test_with_vals_multivalues_spmm():
    """Alg-1's W-hat: per-column values on the fixed pattern."""
    _, M = _sym()
    rng = np.random.default_rng(4)
    what = jnp.asarray(rng.standard_normal((M.nnz, 3)))
    eta = jnp.asarray(rng.standard_normal((M.n_rows, 3)))
    got = np.asarray(mxm(M.with_vals(what), eta))
    want = np.zeros((M.n_rows, 3))
    np.add.at(want, np.asarray(M.rows),
              np.asarray(what) * np.asarray(eta)[np.asarray(M.cols)])
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)
    # derived layouts are dropped -> COO is the only capable backend
    assert available_backends(M.with_vals(what), eta) == ["coo"]
    # multivalues against a 1-D vector is a dispatch error, not a
    # broadcast crash deep inside the ring
    with pytest.raises(BackendUnavailableError):
        mxv(M.with_vals(what), jnp.ones(M.n_rows))


def test_spgemm_sparse_sparse_mxm():
    """GraphBLAS' general mxm: a SparseMatrix multiplicand dispatches to
    the spgemm backend and the product is a SparseMatrix."""
    rng = np.random.RandomState(11)
    A = sp.random(24, 30, density=0.15, random_state=rng)
    B = sp.random(30, 18, density=0.2, random_state=rng)
    Ma = SparseMatrix.from_scipy(A, dtype=jnp.float64)
    Mb = SparseMatrix.from_scipy(B, dtype=jnp.float64)
    assert available_backends(Ma, Mb) == ["spgemm"]
    C = mxm(Ma, Mb)
    assert isinstance(C, SparseMatrix)
    np.testing.assert_allclose(np.asarray(C.to_dense()), (A @ B).toarray(),
                               rtol=1e-10, atol=1e-12)
    # transpose descriptor: Aᵀ B
    B2 = sp.random(24, 9, density=0.2, random_state=rng)
    Mb2 = SparseMatrix.from_scipy(B2, dtype=jnp.float64)
    Ct = mxm(Ma, Mb2, desc=Descriptor(backend="spgemm", transpose=True))
    np.testing.assert_allclose(np.asarray(Ct.to_dense()),
                               (A.T @ B2).toarray(), rtol=1e-10, atol=1e-12)


def test_spgemm_rejects_nonreals_and_write_semantics():
    rng = np.random.RandomState(12)
    Ma = SparseMatrix.from_scipy(sp.random(10, 10, density=0.3,
                                           random_state=rng))
    Mb = SparseMatrix.from_scipy(sp.random(10, 10, density=0.3,
                                           random_state=rng))
    with pytest.raises(BackendUnavailableError):
        mxm(Ma, Mb, min_plus_ring)
    with pytest.raises(NotImplementedError):
        mxm(Ma, Mb, mask=np.ones(10, bool))
    # dense backends never claim a sparse multiplicand
    names = available_backends(Ma, Mb)
    assert names == ["spgemm"]


def test_deprecated_shims_deleted():
    """The one-release migration window (DESIGN.md §3) is over: the old
    flag-style entry points must be gone, so stale callers fail loudly
    at import instead of silently warning forever."""
    import repro.grblas.ops as grb_ops
    import repro.grblas.dist as grb_dist
    import repro.kernels.bsr_spmm as kb
    import repro.kernels.plap_edge as kp

    for mod, name in ((grb_ops, "mxm"), (grb_ops, "mxv"), (grb_ops, "vxm"),
                      (grb_dist, "dist_mxm"),
                      (kp, "plap_apply"), (kp, "plap_hvp_edge")):
        assert not callable(getattr(mod, name, None)), \
            f"{mod.__name__}.{name} should be deleted"
    # the bsr_spmm package attribute is the impl *module* now, never the
    # deleted shim function
    assert not callable(getattr(kb, "bsr_spmm", None)) or \
        getattr(kb, "bsr_spmm").__class__.__name__ == "module"
    # the replacements exist
    from repro.grblas.api import mxm as api_mxm  # noqa: F401
    assert callable(kb.bsr_spmm_pallas) and callable(kp.plap_apply_pallas)


def test_psc_backend_validated_up_front():
    """A PSCConfig backend that can never serve the edge-ring hot loop
    fails before any eigensolver work, not mid-Newton-iteration."""
    from repro.core.psc import PSCConfig, p_spectral_cluster
    from repro.graphs import ring_of_cliques

    W, _ = ring_of_cliques(3, 6)
    for bad in ("ell", "bsr_pallas", "dist"):
        with pytest.raises(BackendUnavailableError):
            p_spectral_cluster(W, PSCConfig(k=2, backend=bad))
    # "coo" passes validation (full run exercised elsewhere)
    PSCConfig(k=2, backend="coo").validate_backend(W)


def test_dist_rejects_traced_matrix_with_clear_error():
    """Auto-partitioning is host-side numpy; a matrix passed as a jit
    argument must raise an actionable error, not a TracerArrayConversion
    crash deep inside make_row_partition."""
    import jax
    from repro.grblas import backends as _backends

    _, M = _sym()
    X = jnp.ones((M.n_rows, 2))

    class _FakeMesh:
        shape = {"data": 1}

    desc = Descriptor(backend="dist", mesh=_FakeMesh())

    def f(W, X):
        return _backends._REGISTRY["dist"].execute(W, X, reals_ring, desc)

    with pytest.raises(Exception, match="traced SparseMatrix"):
        jax.jit(f)(M, X)


def test_dist_rejects_pad_unsound_edge_rings():
    """The dist path folds the padded-ELL axis with a plain sum, so only
    edge rings whose multiply annihilates pad zeros may ride it; generic
    edge closures must stay on COO even when a mesh is present."""
    from repro.grblas import EdgeSemiring, plap_edge_semiring
    from repro.grblas import backends as _backends

    _, M = _sym()
    X = jnp.ones((M.n_rows, 2))

    class _FakeMesh:
        shape = {"data": 2}

    desc = Descriptor(backend="dist", mesh=_FakeMesh())
    unsound = EdgeSemiring(base=reals_ring,
                           edge_mul=lambda w, xs, xd: jnp.where(w != 0, xs, 1.0),
                           name="pad_unsound_edge")
    assert not _backends._REGISTRY["dist"].supports(M, X, unsound, desc)
    assert _backends._REGISTRY["dist"].supports(
        M, X, plap_edge_semiring(1.5, 1e-8), desc)


def test_plap_hot_path_has_no_raw_segment_sum():
    """Acceptance pin: core/plap.py routes every SpMM-shaped reduction
    through grblas.api — no direct jax.ops.segment_sum in the hot path.
    Enforced by the pscheck api-boundary rule (repro.analysis)."""
    from pathlib import Path

    from repro import analysis
    from repro.core import plap

    analysis.assert_clean([Path(plap.__file__)],
                          rules=["api-boundary", "hot-purity"])
    assert "api.mxm" in Path(plap.__file__).read_text()
