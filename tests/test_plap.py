"""p-Laplacian functional: closed-form grad/HVP vs jax autodiff oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import plap
from repro.graphs import ring_of_cliques, gaussian_blobs_knn

PS = [2.0, 1.7, 1.3, 1.1]


@pytest.fixture(scope="module")
def setup():
    W, _ = gaussian_blobs_knn(15, 3, seed=3)
    rng = np.random.default_rng(0)
    n, k = W.n_rows, 3
    U = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0])
    eta = jnp.asarray(rng.standard_normal((n, k)) * 0.1)
    return W, U, eta


@pytest.mark.parametrize("p", PS)
def test_grad_matches_autodiff(setup, p):
    W, U, _ = setup
    eps = 1e-6
    f = plap.autodiff_value(W, p, eps)
    want = jax.grad(f)(U)
    got = plap.euc_grad(W, U, p, eps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("mode", ["graphblas", "matrix_free"])
def test_hvp_matches_autodiff(setup, p, mode):
    W, U, eta = setup
    eps = 1e-6
    want = plap.autodiff_hvp(W, U, eta, p, eps)
    fn = (plap.hess_eta_graphblas if mode == "graphblas"
          else plap.hess_eta_matrix_free)
    got = fn(W, U, eta, p, eps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-8)


def test_hvp_paths_agree(setup):
    W, U, eta = setup
    a = plap.hess_eta_graphblas(W, U, eta, 1.4, 1e-7)
    b = plap.hess_eta_matrix_free(W, U, eta, 1.4, 1e-7)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-9)


def test_p2_recovers_linear_rayleigh(setup):
    """At p=2 (eps=0), F_2(u) = u^T L u / (2... ) — check against dense L."""
    W, U, _ = setup
    L = np.diag(np.asarray(W.row_sums())) - np.asarray(W.to_dense())
    val = float(plap.value(W, U, 2.0, 0.0))
    Un = np.asarray(U)
    want = sum(Un[:, l] @ L @ Un[:, l] / (Un[:, l] @ Un[:, l])
               for l in range(U.shape[1]))
    np.testing.assert_allclose(val, want, rtol=1e-8)


def test_constant_vector_is_nullvector(setup):
    W, _, _ = setup
    ones = jnp.ones((W.n_rows, 1)) / np.sqrt(W.n_rows)
    for p in PS:
        assert float(plap.value(W, ones, p, 0.0)) < 1e-12
        # eps-smoothing leaves an O(eps^{p/2} * sum(w))-scale bias; shrink
        # eps (x64 active in tests) and allow the residual scale
        g = plap.euc_grad(W, ones, p, 1e-12)
        assert float(jnp.linalg.norm(g)) < 1e-5
