"""Distributed SpMM (shard_map + halo exchange) == single-device result.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N
so the main test process keeps its single-device view.  N defaults to 8;
CI additionally runs the suite with DIST_TEST_DEVICES=4 (the forced
4-device platform) to prove the plans are shard-count agnostic.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
N_DEV = os.environ.get("DIST_TEST_DEVICES", "8")

SCRIPT = textwrap.dedent("""
    import os
    N = int(os.environ["DIST_TEST_DEVICES"])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N}"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.graphs import delaunay_graph
    from repro.grblas import (Descriptor, SparseMatrix, mxm,
                              make_row_partition)
    from repro.grblas.semiring import plap_edge_semiring
    ring = plap_edge_semiring(1.5, eps=1e-8)

    W, _ = delaunay_graph(9, seed=0)
    mesh = jax.make_mesh((N,), ("data",))
    d = Descriptor(backend="dist", mesh=mesh)
    rng = np.random.default_rng(0)

    # k sweep: multivectors through the halo plan == coo, reals + edge
    Ap = make_row_partition(W, N)
    assert Ap.mode == "halo", Ap.mode
    for k in (1, 8, 32):
        shape = (W.n_rows,) if k == 1 else (W.n_rows, k)
        X = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        want = np.asarray(mxm(W, X))
        got = np.asarray(mxm(Ap, X, desc=d))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        wante = np.asarray(mxm(W, X, ring))
        gote = np.asarray(mxm(Ap, X, ring, desc=d))
        np.testing.assert_allclose(gote, wante, rtol=2e-4, atol=2e-5)

    # graph-aware placement is TRANSPARENT: X in, Y out, original row
    # space — the layout permutes internally (regression: the pre-halo
    # code returned Y in permuted space and never applied perm back)
    X = jnp.asarray(rng.standard_normal((W.n_rows, 3)), jnp.float32)
    want = np.asarray(mxm(W, X))
    labels = (np.arange(W.n_rows) * 7) % 4
    Ap2 = make_row_partition(W, N, assignment=labels, mode="gather")
    assert Ap2.perm is not None
    got2 = np.asarray(mxm(Ap2, X, desc=d))
    np.testing.assert_allclose(got2, want, rtol=2e-5, atol=2e-5)
    wante = np.asarray(mxm(W, X, ring))
    # edge ring on the gather schedule (the auto fallback keeps it
    # production-reachable for dense cuts / bad placement)
    got2g = np.asarray(mxm(Ap2, X, ring, desc=d))
    np.testing.assert_allclose(got2g, wante, rtol=2e-4, atol=2e-5)
    # same contract on a halo plan (force past the density fallback)
    Ap2h = make_row_partition(W, N, assignment=labels, mode="halo")
    got2h = np.asarray(mxm(Ap2h, X, desc=d))
    np.testing.assert_allclose(got2h, want, rtol=2e-5, atol=2e-5)
    got2e = np.asarray(mxm(Ap2h, X, ring, desc=d))
    np.testing.assert_allclose(got2e, wante, rtol=2e-4, atol=2e-5)

    # a raw SparseMatrix auto-partitions + memoizes on the container,
    # keyed on (shards, vals buffer, layout) — swapping the value
    # buffers on the same pattern must NOT reuse the stale partition
    got5 = np.asarray(mxm(W, X, desc=d))
    np.testing.assert_allclose(got5, want, rtol=2e-5, atol=2e-5)
    stale_key = (N, id(W.ell_vals), False)
    assert stale_key in W._dist_partitions
    n_keys = len(W._dist_partitions)
    W.vals, W.ell_vals = W.vals * 2.0, W.ell_vals * 2.0
    got5b = np.asarray(mxm(W, X, desc=d))
    np.testing.assert_allclose(got5b, 2.0 * want, rtol=2e-5, atol=2e-5)
    # re-partitioned AND the superseded entry was evicted (no growth)
    assert (N, id(W.ell_vals), False) in W._dist_partitions
    assert stale_key not in W._dist_partitions
    assert len(W._dist_partitions) == n_keys
    W.vals, W.ell_vals = W.vals / 2.0, W.ell_vals / 2.0

    # auto backend picks dist once a mesh is in the descriptor
    from repro.grblas import available_backends
    assert available_backends(W, X, desc=d)[0] == "dist"

    # rectangular reals ride the gather fallback (regression: the old
    # path sliced the output to n_cols rows and mis-padded X)
    n = W.n_rows
    r, c, v = W.host_coo()
    c2 = np.where(np.arange(len(c)) % 2 == 0, c, c + n)  # spill into cols >= n
    Wrect = SparseMatrix.from_coo(r, c2, v, (n, 2 * n), build_ell=True)
    Xr = jnp.asarray(rng.standard_normal((2 * n, 3)), jnp.float32)
    wantr = np.asarray(mxm(Wrect, Xr))
    gotr = np.asarray(mxm(Wrect, Xr, desc=d))
    assert gotr.shape == (n, 3)
    np.testing.assert_allclose(gotr, wantr, rtol=2e-5, atol=2e-5)

    print("DIST_SPMV_OK")
""")


def test_dist_spmv_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu",
                            "DIST_TEST_DEVICES": N_DEV},
                       capture_output=True, text=True, timeout=560)
    assert "DIST_SPMV_OK" in r.stdout, r.stdout + "\n" + r.stderr
