"""Distributed SpMV (shard_map) == single-device result.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps its single-device view.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.graphs import delaunay_graph
    from repro.grblas import Descriptor, mxm, make_row_partition
    from repro.grblas.semiring import plap_edge_semiring

    W, _ = delaunay_graph(9, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    Ap = make_row_partition(W, 8)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((W.n_rows, 3)), jnp.float32)
    d = Descriptor(backend="dist", mesh=mesh)

    # reals ring, pre-built partition through the unified API
    want = np.asarray(mxm(W, X))
    got = np.asarray(mxm(Ap, X, desc=d))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    # graph-aware placement permutation preserves the product
    labels = (np.arange(W.n_rows) * 7) % 4
    Ap2 = make_row_partition(W, 8, assignment=labels)
    Xp = X[Ap2.perm]
    got2 = np.asarray(mxm(Ap2, Xp, desc=d))
    want2 = np.asarray(mxm(W, X))[Ap2.perm]
    np.testing.assert_allclose(got2, want2, rtol=2e-5, atol=2e-5)

    # edge semiring (p-Laplacian apply), distributed
    ring = plap_edge_semiring(1.5, eps=1e-8)
    want3 = np.asarray(mxm(W, X, ring))
    got3 = np.asarray(mxm(Ap, X, ring, desc=d))
    np.testing.assert_allclose(got3, want3, rtol=2e-4, atol=2e-5)

    # a raw SparseMatrix auto-partitions + memoizes on the container
    got5 = np.asarray(mxm(W, X, desc=d))
    np.testing.assert_allclose(got5, want, rtol=2e-5, atol=2e-5)
    assert 8 in W._dist_partitions          # partition memoized
    got6 = np.asarray(mxm(W, X, ring, desc=d))
    np.testing.assert_allclose(got6, want3, rtol=2e-4, atol=2e-5)
    # auto backend picks dist once a mesh is in the descriptor
    from repro.grblas import available_backends
    assert available_backends(W, X, desc=d)[0] == "dist"
    print("DIST_SPMV_OK")
""")


def test_dist_spmv_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=560)
    assert "DIST_SPMV_OK" in r.stdout, r.stdout + "\n" + r.stderr
