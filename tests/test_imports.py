"""Import health: every module under src/repro must import cleanly.

One bad import used to poison collection of all 11 tier-1 test modules
(jax-0.4.37 API drift in grblas/dist.py plus a missing repro.dist
package); this walk makes any regression show up as exactly one
parametrized failure naming the broken module.
"""
import importlib
import pkgutil

import jax
import pytest

import repro

# Initialize the backend before importing modules that append XLA_FLAGS
# for subprocess use (repro.launch.dryrun): once the backend exists,
# later env mutations cannot re-shape this process's device set.
jax.devices()

ALL_MODULES = sorted(
    m.name for m in pkgutil.walk_packages(repro.__path__, prefix="repro."))


def test_walk_found_the_tree():
    assert len(ALL_MODULES) > 50, ALL_MODULES
    for expected in ("repro.dist.sharding", "repro.dist.compression",
                     "repro.grblas.dist", "repro.models.layers",
                     "repro.launch.dryrun", "repro.compat"):
        assert expected in ALL_MODULES


@pytest.mark.parametrize("name", ALL_MODULES)
def test_import(name):
    importlib.import_module(name)
