"""Warm-start cache + churn semantics (DESIGN.md §8): fingerprint
identity, LRU hit/miss/evict accounting, pattern-tier lookup, EdgeDelta
validation, the with_vals weight-only fast path, and incremental
re-clustering correctness against a from-scratch solve."""
import numpy as np
import pytest

from repro.core import PSCConfig, p_spectral_cluster
from repro.graphs import ring_of_cliques, sbm_graph
from repro.grblas.containers import SparseMatrix
from repro.serve import (CacheEntry, EdgeDelta, WarmCache, apply_edge_delta,
                         incremental_recluster)


def _entry(fp, tag=0.0):
    n, k = fp.n, 3
    return CacheEntry(U=np.full((n, k), tag), labels=np.zeros(n, np.int64),
                      p_final=1.2, rcut=1.0, fingerprint=fp)


def _graph(scale=1.0, n=12):
    i = np.arange(n - 1)
    W = SparseMatrix.from_coo(np.r_[i, i + 1], np.r_[i + 1, i],
                              np.full(2 * (n - 1), scale), (n, n))
    return W


# ------------------------------------------------------------- fingerprints

def test_fingerprint_identity_and_quantization():
    W = _graph()
    fp = W.fingerprint()
    assert (fp.n, fp.nnz) == (12, 22)
    assert fp == W.fingerprint()                       # deterministic
    # same pattern, different weights: pattern_key equal, key not
    fp2 = _graph(scale=2.0).fingerprint()
    assert fp2.pattern_key == fp.pattern_key
    assert fp2.key != fp.key
    assert fp2.weights != fp.weights
    # sub-quantum weight jitter does not change the fingerprint
    Wj = W.with_vals(np.asarray(W.vals) + 1e-10)
    assert Wj.fingerprint(weight_quant=1e-6).key == \
        W.fingerprint(weight_quant=1e-6).key
    # different pattern, same weights: pattern_key differs
    i = np.arange(10)
    Wp = SparseMatrix.from_coo(np.r_[i, i + 2], np.r_[i + 2, i],
                               np.ones(20), (12, 12))
    assert Wp.fingerprint().pattern_key != fp.pattern_key


# --------------------------------------------------------------- cache core

def test_cache_hit_miss_evict_lru():
    cache = WarmCache(capacity=2)
    fa, fb, fc = (_graph(s, n).fingerprint()
                  for s, n in [(1.0, 12), (1.0, 16), (1.0, 20)])
    assert cache.lookup(fa) == (None, None)
    assert cache.misses == 1
    cache.store(_entry(fa, 1.0))
    cache.store(_entry(fb, 2.0))
    ea, tier = cache.lookup(fa)                        # refresh fa's recency
    assert tier == "exact" and ea.U[0, 0] == 1.0
    assert cache.hits_exact == 1
    cache.store(_entry(fc, 3.0))                       # evicts fb (LRU)
    assert cache.evictions == 1 and len(cache) == 2
    assert fa in cache and fc in cache and fb not in cache
    assert cache.lookup(fb) == (None, None)
    st = cache.stats()
    assert st == {"size": 2, "capacity": 2, "hits_exact": 1,
                  "hits_pattern": 0, "misses": 2, "evictions": 1,
                  "rejects": 0}


def test_cache_pattern_tier_and_stale_index():
    cache = WarmCache(capacity=1)
    W = _graph(1.0)
    cache.store(_entry(W.fingerprint(), 7.0))
    entry, tier = cache.lookup(_graph(3.0).fingerprint())
    assert tier == "pattern" and entry.U[0, 0] == 7.0
    assert cache.hits_pattern == 1
    # evict the only entry; the pattern index must repair itself
    other = _graph(1.0, n=16).fingerprint()
    cache.store(_entry(other))
    entry, tier = cache.lookup(_graph(3.0).fingerprint())
    assert entry is None and tier is None


def test_cache_peek_does_no_accounting():
    cache = WarmCache(capacity=4)
    fp = _graph().fingerprint()
    assert cache.peek(fp) is None
    cache.store(_entry(fp))
    assert cache.peek(fp) is not None
    assert cache.misses == 0 and cache.hits_exact == 0


def test_cache_capacity_validated():
    with pytest.raises(ValueError):
        WarmCache(capacity=0)


# ---------------------------------------------------------------- EdgeDelta

def test_edge_delta_validation():
    with pytest.raises(ValueError, match="self-loops"):
        EdgeDelta(np.array([1]), np.array([1]), np.array([1.0]))
    with pytest.raises(ValueError, match="equal length"):
        EdgeDelta(np.array([1]), np.array([2, 3]), np.array([1.0]))
    d = EdgeDelta([0, 5], [3, 2], [1.0, 0.0])
    np.testing.assert_array_equal(d.touched, [0, 2, 3, 5])


def test_apply_edge_delta_out_of_range():
    W = _graph()
    with pytest.raises(ValueError, match="out of range"):
        apply_edge_delta(W, EdgeDelta([0], [99], [1.0]))


def test_apply_edge_delta_weights_only_fast_path():
    W = _graph()
    d = apply_edge_delta(W, EdgeDelta([0, 5], [1, 6], [4.0, 0.0]))
    assert not d.pattern_changed
    W2 = d.W
    # layout shared: identical pattern arrays, same nnz
    assert W2.nnz == W.nnz
    np.testing.assert_array_equal(np.asarray(W2.rows), np.asarray(W.rows))
    np.testing.assert_array_equal(np.asarray(W2.cols), np.asarray(W.cols))
    dense, dense2 = np.asarray(W.to_dense()), np.asarray(W2.to_dense())
    assert dense2[0, 1] == 4.0 and dense2[1, 0] == 4.0   # both directions
    assert dense2[5, 6] == 0.0 and dense2[6, 5] == 0.0   # explicit zero
    # untouched entries identical
    m = np.ones_like(dense, bool)
    m[[0, 1, 5, 6], [1, 0, 6, 5]] = False
    np.testing.assert_array_equal(dense2[m], dense[m])
    # pattern digest unchanged -> warm cache sees the pattern tier
    assert W2.fingerprint().pattern_key == W.fingerprint().pattern_key


def test_apply_edge_delta_pattern_paths():
    W = _graph()
    # insertion: new pair forces a rebuild with nnz + 2
    d = apply_edge_delta(W, EdgeDelta([0], [7], [2.5]))
    assert d.pattern_changed and d.W.nnz == W.nnz + 2
    assert np.asarray(d.W.to_dense())[7, 0] == 2.5
    # removing a missing pair inserts nothing (conservatively reported
    # as a pattern event: the rebuild ran, even though nnz is unchanged)
    d0 = apply_edge_delta(W, EdgeDelta([0], [7], [0.0]))
    assert d0.W.nnz == W.nnz
    assert d0.W.fingerprint().pattern_key == W.fingerprint().pattern_key
    # hard removal drops the stored entries entirely
    dr = apply_edge_delta(W, EdgeDelta([3], [4], [0.0]), drop_removed=True)
    assert dr.pattern_changed and dr.W.nnz == W.nnz - 2
    assert dr.W.fingerprint().pattern_key != W.fingerprint().pattern_key


# ------------------------------------------------------- churn correctness

def _flip_edges(W, frac, seed):
    """Down-weight ``frac`` of the undirected edges to zero (weight-only
    churn) — the serve_bench SBM scenario."""
    rng = np.random.default_rng(seed)
    und = np.flatnonzero(np.asarray(W.rows) < np.asarray(W.cols))
    pick = rng.choice(und, max(1, int(frac * len(und))), replace=False)
    return EdgeDelta(np.asarray(W.rows)[pick], np.asarray(W.cols)[pick],
                     np.zeros(len(pick)))


def test_incremental_recluster_flat_matches_scratch():
    """1% SBM edge churn: warm re-entry from the cached embedding lands
    within 2% RCut of a cold solve of the edited graph (the bench
    acceptance bound), and reuses the pattern via with_vals."""
    W, _ = sbm_graph([40, 40, 40, 40], 0.25, 0.02, seed=2)
    cfg = PSCConfig(k=4, reorder="none", newton_iters=20, tcg_iters=12,
                    kmeans_restarts=4)
    base = p_spectral_cluster(W, cfg)

    d = apply_edge_delta(W, _flip_edges(W, 0.01, seed=3))
    assert not d.pattern_changed
    res, hier, records = incremental_recluster(
        d.W, d.touched, d.pattern_changed, np.asarray(base.U), cfg)
    assert hier is None and records == []
    scratch = p_spectral_cluster(d.W, cfg)
    assert res.rcut <= scratch.rcut * 1.02 + 1e-12
    # the warm path entered at the schedule tail, not at p=2
    assert len(res.p_path) <= cfg.warm_p_steps
    assert res.p_path[-1] == pytest.approx(scratch.p_path[-1])


def test_incremental_recluster_multilevel_patches_hierarchy():
    """Pattern churn on the multilevel lane: the cached hierarchy is
    patched (records returned) and the refined result stays within 2%
    RCut of a from-scratch multilevel solve."""
    from repro.multilevel import MultilevelConfig, build_hierarchy

    W, _ = sbm_graph([300] * 4, 0.06, 0.004, seed=1)
    ml = MultilevelConfig(coarse_size=120)
    cfg = PSCConfig(k=4, reorder="none", multilevel=ml)
    base = p_spectral_cluster(W, cfg)
    hier = build_hierarchy(W, coarse_size=ml.coarse_size)

    rng = np.random.default_rng(5)
    i = rng.integers(0, 600, 6)
    j = rng.integers(600, 1200, 6)
    d = apply_edge_delta(W, EdgeDelta(i, j, np.full(6, 0.5)))
    assert d.pattern_changed
    res, hier2, records = incremental_recluster(
        d.W, d.touched, d.pattern_changed, np.asarray(base.U), cfg,
        ml=ml, hierarchy=hier)
    assert hier2 is not None and len(records) == hier.n_levels - 1
    scratch = p_spectral_cluster(d.W, cfg)
    assert res.rcut <= scratch.rcut * 1.02 + 1e-12


def test_warm_start_config_on_flat_pipeline():
    """PSCConfig.init_U warm entry reproduces the cold solve's labels on
    an unchanged graph and skips the continuation (p_path is the tail)."""
    W, _ = ring_of_cliques(4, 10)
    cfg = PSCConfig(k=4, reorder="none", newton_iters=20, tcg_iters=12,
                    kmeans_restarts=4)
    import dataclasses
    cold = p_spectral_cluster(W, cfg)
    warm = p_spectral_cluster(W, dataclasses.replace(
        cfg, init_U=np.asarray(cold.U)))
    np.testing.assert_array_equal(np.asarray(warm.labels),
                                  np.asarray(cold.labels))
    assert warm.rcut == pytest.approx(cold.rcut, rel=1e-9)
    assert len(warm.p_path) == cfg.warm_p_steps
    assert warm.init_labels is None and np.isnan(warm.init_rcut)
    # reports thread through (satellite b)
    assert warm.reports is not None and len(warm.reports) >= 1
    assert cold.reports is not None and len(cold.reports) == \
        len(cold.p_path)


def test_store_rejects_poisoned_entry():
    """Poisoning guard (DESIGN.md §9): a NaN/Inf embedding never enters
    the cache — the prior healthy entry for the fingerprint survives."""
    cache = WarmCache(capacity=4)
    fp = _graph().fingerprint(1e-6)
    good = _entry(fp, tag=1.0)
    cache.store(good)
    cache.store(_entry(fp, tag=np.nan))
    cache.store(_entry(fp, tag=np.inf))
    cache.store(CacheEntry(U=None, labels=np.zeros(12, np.int64),
                           p_final=1.2, rcut=1.0, fingerprint=fp))
    assert cache.stats()["rejects"] == 3
    assert fp in cache
    np.testing.assert_array_equal(cache.peek(fp).U, good.U)
    # a fresh healthy entry still replaces normally
    cache.store(_entry(fp, tag=2.0))
    assert float(cache.peek(fp).U[0, 0]) == 2.0
