"""The solver-driver registry (core.solvers, DESIGN.md §7): dispatch +
config-time validation rules, newton ≡ scf ≡ inverse_power cluster
equivalence where all drivers converge, per-level V-cycle solver choice,
the pmulti-removal absence pin, and driver source purity (no scipy, no
raw segment_sum — every driver consumes the same api.mxm rings)."""
import warnings
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import PSCConfig, metrics, p_spectral_cluster, solvers
from repro.core.solvers import (SolverReport, SolverState,
                                SolverUnavailableError)
from repro.graphs import (delaunay_graph, gaussian_blobs_knn,
                          ring_of_cliques, sbm_graph)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro._vendor.minihypothesis import given, settings, strategies as st

SOLVERS = ("newton", "scf", "inverse_power")


def _cfg(solver, **kw):
    base = dict(k=4, p_target=1.4, newton_iters=15, tcg_iters=10,
                kmeans_restarts=4, seed=0, scf_sweeps=10, ipm_iters=100)
    base.update(kw)
    return PSCConfig(solver=solver, **base)


# ----------------------------------------------------------- dispatch rules

def test_registry_has_all_three_drivers():
    reg = solvers.registered_solvers()
    assert set(SOLVERS) <= set(reg)
    for name in SOLVERS:
        s = solvers.resolve_solver(name)
        assert s.name == name and callable(s.minimize_at_p)


def test_unknown_solver_raises_loudly():
    with pytest.raises(SolverUnavailableError, match="registered"):
        solvers.resolve_solver("does_not_exist")
    # SolverUnavailableError IS a ValueError: config-time validation
    # surfaces it through the same except clause
    assert issubclass(SolverUnavailableError, ValueError)
    with pytest.raises(SolverUnavailableError):
        PSCConfig(solver="does_not_exist")


def test_p_range_validation_at_config_time():
    # p outside (1, 2] used to produce NaNs deep in the Newton loop —
    # now a clear ValueError at construction
    with pytest.raises(ValueError, match="supported range"):
        PSCConfig(p_target=2.5)
    with pytest.raises(ValueError, match="supported range"):
        PSCConfig(p_target=1.0)            # newton's range is OPEN at 1
    with pytest.raises(ValueError, match="supported range"):
        PSCConfig(p_target=0.5, solver="inverse_power")
    with pytest.raises(ValueError, match="p_factor"):
        PSCConfig(p_factor=1.0)            # schedule would never descend
    # the inverse-power driver registers the wider CLOSED range [1, 2]:
    # the p → 1 sparsest-cut end is reachable
    assert PSCConfig(p_target=1.0, solver="inverse_power").p_target == 1.0
    ipm = solvers.resolve_solver("inverse_power")
    newton = solvers.resolve_solver("newton")
    assert ipm.supports_p(1.0) and not newton.supports_p(1.0)
    assert all(solvers.resolve_solver(s).supports_p(1.4) for s in SOLVERS)


def test_driver_contract_report_fields():
    W, _ = ring_of_cliques(3, 8)
    U0 = jnp.linalg.qr(jnp.ones((W.n_rows, 3)) +
                       jnp.arange(W.n_rows * 3.).reshape(W.n_rows, 3))[0]
    for name in SOLVERS:
        cfg = _cfg(name, k=3, ipm_iters=30, scf_sweeps=4)
        rep = solvers.minimize_at_p(W, U0, 1.5, cfg)
        assert isinstance(rep, SolverReport)
        assert rep.U.shape == (W.n_rows, 3)
        assert np.isfinite(rep.fval)
        assert rep.n_apply > 0 and rep.iters > 0
        assert rep.n_hvp == rep.n_apply    # back-compat alias


# ------------------------------------------------- solver equivalence suite

def test_equivalence_planted_sbm():
    """All drivers land the SAME clusters on a planted SBM (and all
    recover the planted partition exactly)."""
    W, truth = sbm_graph([30, 30, 30, 30], p_in=0.5, p_out=0.03, seed=5)
    labels = {}
    for name in SOLVERS:
        res = p_spectral_cluster(W, _cfg(name))
        labels[name] = res.labels
        assert metrics.clustering_accuracy(res.labels, truth, 4) == 1.0, name
    for name in ("scf", "inverse_power"):
        assert metrics.clustering_accuracy(
            labels[name], labels["newton"], 4) == 1.0, name


def test_equivalence_ring_of_cliques():
    W, truth = ring_of_cliques(4, 10)
    for name in SOLVERS:
        res = p_spectral_cluster(W, _cfg(name, ipm_iters=80))
        acc = metrics.clustering_accuracy(res.labels, truth, 4)
        assert acc == 1.0, f"{name}: accuracy {acc}"


def test_equivalence_delaunay():
    """No planted truth: drivers must agree on the overwhelming majority
    of nodes and land comparable RCut (boundary nodes of a mesh
    partition legitimately wiggle between near-degenerate optima)."""
    W, _ = delaunay_graph(8, seed=0)
    res = {name: p_spectral_cluster(W, _cfg(name)) for name in SOLVERS}
    r_newton = res["newton"].rcut
    for name in ("scf", "inverse_power"):
        agree = metrics.clustering_accuracy(
            res[name].labels, np.asarray(res["newton"].labels), 4)
        assert agree >= 0.85, f"{name}: agreement {agree}"
        assert res[name].rcut <= r_newton * 1.15 + 1e-9, \
            f"{name}: rcut {res[name].rcut} vs newton {r_newton}"


def test_inverse_power_reaches_p_one():
    """The regime Newton cannot reach: a full continuation down to the
    sparsest-cut limit p = 1 still recovers the planted clusters."""
    W, truth = ring_of_cliques(4, 10)
    res = p_spectral_cluster(W, _cfg("inverse_power", p_target=1.0,
                                     ipm_iters=80))
    assert res.p_path[-1] == 1.0
    assert metrics.clustering_accuracy(res.labels, truth, 4) == 1.0
    assert all(np.isfinite(v) for v in res.fvals)


# ------------------------------------------------------ pipeline threading

def test_vcycle_per_level_solver_choice():
    """Cheap SCF sweeps on the coarse level, Newton refinement on top —
    the per-level split the V-cycle exists for."""
    from repro.multilevel import MultilevelConfig

    W, truth = gaussian_blobs_knn(120, 4, seed=1)   # 480 nodes: coarsens
    ml = MultilevelConfig(coarse_size=64, max_levels=6, coarse_solver="scf")
    res = p_spectral_cluster(W, _cfg("newton", newton_iters=10, tcg_iters=8,
                                     multilevel=ml, scf_sweeps=8))
    assert metrics.clustering_accuracy(res.labels, truth, 4) >= 0.95
    assert res.levels and all(r["solver"] == "newton" for r in res.levels)
    # refinement can take its own driver too
    ml2 = MultilevelConfig(coarse_size=64, max_levels=6,
                           coarse_solver="scf", refine_solver="scf")
    res2 = p_spectral_cluster(W, _cfg("newton", multilevel=ml2, scf_sweeps=8))
    assert metrics.clustering_accuracy(res2.labels, truth, 4) >= 0.95
    assert res2.levels and all(r["solver"] == "scf" for r in res2.levels)


def test_partition_threads_solver():
    from repro.graphs.partition import partition

    W, _ = gaussian_blobs_knn(40, 2, seed=3)
    labels, info = partition(W, 2, solver="scf", multilevel=False)
    sizes = info["sizes"]
    assert sum(sizes) == W.n_rows and min(sizes) > 0
    assert np.isfinite(info["rcut"])


def test_pmulti_shim_is_gone():
    """The one-release deprecation window closed: core.pmulti no longer
    exists, and its replacement — the registry's inverse_power driver
    entered at a single p — covers the historical behavior (pinned in
    DESIGN.md §3's migration table)."""
    with pytest.raises(ImportError):
        from repro.core import pmulti  # noqa: F401
    import repro.core as core

    assert not hasattr(core, "p_multi")
    # the replacement path delivers the same clusters the shim did
    W, truth = ring_of_cliques(4, 10)
    cfg = PSCConfig(k=4, p_target=1.2, seed=0, solver="inverse_power",
                    ipm_iters=60)
    from repro.core import lobpcg

    _, U2 = lobpcg.smallest_eigvecs(W, 4, seed=0)
    rep = solvers.minimize_at_p(W, U2, 1.2, cfg)
    from repro.core.psc import discretize

    import jax

    labels = np.asarray(discretize(rep.U, 4, jax.random.PRNGKey(0)))
    assert metrics.clustering_accuracy(labels, truth, 4) == 1.0


def test_scf_continuation_hits_one_trace():
    """PR-3's one-trace-per-schedule contract, for free via the registry
    memo: the SCF reweighting jit serves every p level (and repeat
    runs) from one trace."""
    W, _ = ring_of_cliques(3, 8)
    cfg = _cfg("scf", k=3, scf_sweeps=4, kmeans_iters=10, kmeans_restarts=2)

    def scf_traces():
        return sum(1 for k_ in solvers.SOLVER_TRACES if k_[0] == "scf")

    p_spectral_cluster(W, cfg)          # warm the memo
    before = scf_traces()
    res = p_spectral_cluster(W, cfg)
    assert len(res.p_path) >= 3
    assert scf_traces() == before       # fully cached across the schedule


# --------------------------------------------------------- property checks

@given(seed=st.integers(min_value=0, max_value=10_000),
       p=st.floats(min_value=1.05, max_value=2.0, width=32))
@settings(max_examples=8, deadline=None)
def test_property_scf_driver_well_posed(seed, p):
    """Over random planted patterns and random p: the SCF driver returns
    finite, orthonormal iterates and does not increase the functional
    recorded by the newton driver's own evaluation."""
    from repro.core import plap

    W, _ = sbm_graph([12, 12, 12], p_in=0.6, p_out=0.08, seed=seed)
    rng = np.random.default_rng(seed)
    U0 = jnp.linalg.qr(jnp.asarray(
        rng.standard_normal((W.n_rows, 3)), jnp.float32))[0]
    cfg = _cfg("scf", k=3, scf_sweeps=6)
    rep = solvers.minimize_at_p(W, U0, float(p), cfg)
    U = np.asarray(rep.U)
    assert np.isfinite(U).all() and np.isfinite(rep.fval)
    np.testing.assert_allclose(U.T @ U, np.eye(3), atol=1e-4)
    f0 = float(plap.value(W, U0, float(p), cfg.eps))
    assert rep.fval <= f0 * 1.05 + 1e-6


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=5, deadline=None)
def test_property_drivers_agree_on_planted_blobs(seed):
    W, truth = gaussian_blobs_knn(18, 3, seed=seed)
    res_n = p_spectral_cluster(W, _cfg("newton", k=3, newton_iters=10,
                                       tcg_iters=8, seed=seed))
    res_s = p_spectral_cluster(W, _cfg("scf", k=3, seed=seed))
    acc_n = metrics.clustering_accuracy(res_n.labels, truth, 3)
    acc_s = metrics.clustering_accuracy(res_s.labels, truth, 3)
    # well-separated blobs: both drivers recover the planted structure
    assert acc_n >= 0.9 and acc_s >= 0.9


# ------------------------------------------------------------ source purity

def test_no_scipy_or_raw_segment_sum_in_drivers():
    """Every driver consumes the unified api.mxm rings: no scipy and no
    raw segment_sum anywhere in core/solvers/ — enforced by the pscheck
    hot-purity / api-boundary rules (repro.analysis, DESIGN.md §11)."""
    from repro import analysis

    pkg = Path(__file__).resolve().parent.parent / "src/repro/core/solvers"
    assert len(sorted(pkg.glob("*.py"))) >= 5   # __init__, registry, 3 drivers
    analysis.assert_clean([pkg], rules=["hot-purity", "api-boundary"])
    # the drivers reach the algebra through the plap/lobpcg layers (which
    # route api.mxm), never a private reduction
    assert "plap" in (pkg / "newton.py").read_text()
    assert "lobpcg" in (pkg / "scf.py").read_text()
    assert "plap" in (pkg / "inverse_power.py").read_text()
