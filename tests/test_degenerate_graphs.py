"""Degenerate inputs end to end: empty/tiny/disconnected/duplicated
graphs and degenerate k, through the flat, multilevel and serve paths,
plus the graphs.validate admission layer (DESIGN.md §9)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.psc import PSCConfig, p_spectral_cluster
from repro.graphs import (GraphValidationError, ValidateConfig, allocate_k,
                          connected_components, isolated_vertices,
                          quick_check, ring_of_cliques, validate_graph)
from repro.grblas.containers import SparseMatrix
from repro.multilevel.vcycle import MultilevelConfig
from repro.serve.psc_engine import ClusterServeEngine


def _sym(pairs, n, w=1.0):
    r = [a for a, b in pairs] + [b for a, b in pairs]
    c = [b for a, b in pairs] + [a for a, b in pairs]
    return SparseMatrix.from_coo(np.array(r), np.array(c),
                                 np.full(len(r), w), (n, n))


def _clique(lo, hi):
    return [(i, j) for i in range(lo, hi) for j in range(i + 1, hi)]


def _same_partition(a, b):
    """Label arrays agree up to renaming of cluster ids."""
    a, b = np.asarray(a), np.asarray(b)
    return len(set(zip(a.tolist(), b.tolist()))) == len(set(a.tolist())) \
        == len(set(b.tolist()))


EMPTY = dict(rows=np.array([], np.int64), cols=np.array([], np.int64),
             vals=np.array([], np.float64))


# ---------------------------------------------------------------- tiny / k

def test_empty_graph_raises_actionable():
    W = SparseMatrix.from_coo(shape=(0, 0), **EMPTY)
    with pytest.raises(ValueError, match="empty graph"):
        p_spectral_cluster(W, PSCConfig(k=1))


def test_k_out_of_range():
    W = _sym([(0, 1)], 2)
    with pytest.raises(ValueError, match="k="):
        PSCConfig(k=0)
    with pytest.raises(ValueError, match="exceeds the number of vertices"):
        p_spectral_cluster(W, PSCConfig(k=3))


def test_single_edge_graph():
    W = _sym([(0, 1)], 2)
    r1 = p_spectral_cluster(W, PSCConfig(k=1))
    np.testing.assert_array_equal(r1.labels, [0, 0])
    assert r1.rcut == 0.0
    r2 = p_spectral_cluster(W, PSCConfig(k=2))       # k == n
    assert sorted(r2.labels.tolist()) == [0, 1]
    assert r2.rcut == pytest.approx(2.0)
    np.testing.assert_array_equal(np.asarray(r2.U), np.eye(2))


def test_k_equals_one_is_closed_form():
    W, _ = _two_cliques()
    res = p_spectral_cluster(W, PSCConfig(k=1))
    assert (res.labels == 0).all()
    assert res.rcut == 0.0
    assert res.p_path == [] and res.reports == []
    np.testing.assert_allclose(np.asarray(res.U),
                               1.0 / np.sqrt(W.n_rows), rtol=1e-6)


def test_k_equals_n_is_closed_form():
    W = _sym(_clique(0, 5), 5)
    res = p_spectral_cluster(W, PSCConfig(k=5))
    np.testing.assert_array_equal(res.labels, np.arange(5))
    assert np.isfinite(res.rcut)


def test_star_graph_flat_and_guarded():
    n = 9
    W = _sym([(0, i) for i in range(1, n)], n)
    for guard in (None, True):
        res = p_spectral_cluster(W, PSCConfig(
            k=2, guard=guard, newton_iters=6, tcg_iters=4))
        assert np.isfinite(res.rcut)
        assert len(set(res.labels.tolist())) == 2
        if guard:
            assert res.recovery.clean


# ------------------------------------------------------------- disconnected

def _two_cliques():
    """10-clique + 14-clique, no edges between them."""
    return _sym(_clique(0, 10) + _clique(10, 24), 24), (10, 14)


def test_disconnected_components_detected():
    W, sizes = _two_cliques()
    comps = connected_components(W)
    assert comps.n_components == 2
    assert sorted(comps.sizes.tolist()) == sorted(sizes)
    assert isolated_vertices(W).size == 0


def test_disconnected_cliques_cluster_per_component():
    W, _ = _two_cliques()
    res = p_spectral_cluster(W, PSCConfig(k=2, validate=True))
    # each clique is one cluster: a disconnected graph's optimal 2-cut
    # cuts nothing
    assert res.rcut == 0.0
    assert len(res.components) == 2
    labels = np.asarray(res.labels)
    assert len(set(labels[:10].tolist())) == 1
    assert len(set(labels[10:].tolist())) == 1
    assert labels[0] != labels[10]


def test_disconnected_cliques_k4_allocates_proportionally():
    W, _ = _two_cliques()
    res = p_spectral_cluster(W, PSCConfig(
        k=4, validate=True, newton_iters=6, tcg_iters=4))
    assert len(set(res.labels.tolist())) == 4
    assert np.isfinite(res.rcut)
    assert [c["k"] for c in res.components] == [2, 2]
    # no cluster spans components
    labels = np.asarray(res.labels)
    assert not (set(labels[:10].tolist()) & set(labels[10:].tolist()))


def test_k_below_component_count_is_actionable():
    W = _sym(_clique(0, 4) + _clique(4, 8) + _clique(8, 12), 12)
    with pytest.raises(ValueError, match="raise k"):
        p_spectral_cluster(W, PSCConfig(k=2, validate=True))


def test_self_loops_only_graph():
    n = 4
    W = SparseMatrix.from_coo(np.arange(n), np.arange(n),
                              np.ones(n), (n, n))
    assert isolated_vertices(W).size == n
    assert connected_components(W).n_components == n
    with pytest.raises(ValueError, match="isolated"):
        p_spectral_cluster(W, PSCConfig(k=2, validate=True))
    # k == n still answers in closed form
    res = p_spectral_cluster(W, PSCConfig(k=n, validate=True))
    np.testing.assert_array_equal(res.labels, np.arange(n))


def test_allocate_k_proportional_with_floor_and_cap():
    np.testing.assert_array_equal(allocate_k(np.array([10, 14]), 4), [2, 2])
    np.testing.assert_array_equal(allocate_k(np.array([30, 3]), 4), [3, 1])
    np.testing.assert_array_equal(allocate_k(np.array([5, 1]), 4), [3, 1])
    np.testing.assert_array_equal(allocate_k(np.array([2, 2]), 4), [2, 2])
    with pytest.raises(ValueError, match="raise k"):
        allocate_k(np.array([3, 3, 3]), 2)
    with pytest.raises(ValueError):
        allocate_k(np.array([2, 2]), 5)


# ---------------------------------------------------------- duplicate edges

def test_duplicate_coo_entries_flat_and_multilevel():
    """Duplicate COO entries accumulate in the SpMV — the graph behaves
    as the summed-weight graph, and every path returns the same
    partition as the deduplicated build."""
    W1, truth = ring_of_cliques(4, 6)
    r, c, v = W1.host_coo()
    Wdup = SparseMatrix.from_coo(np.concatenate([r, r]),
                                 np.concatenate([c, c]),
                                 np.concatenate([v, v]),
                                 (W1.n_rows, W1.n_rows))
    assert Wdup.nnz == 2 * W1.nnz
    cfg = PSCConfig(k=4, newton_iters=6, tcg_iters=4)
    ref = p_spectral_cluster(W1, cfg)
    dup = p_spectral_cluster(Wdup, cfg)
    assert _same_partition(ref.labels, dup.labels)
    ml = p_spectral_cluster(Wdup, PSCConfig(
        k=4, newton_iters=6, tcg_iters=4,
        multilevel=MultilevelConfig(coarse_size=12)))
    assert np.isfinite(ml.rcut)
    assert len(set(ml.labels.tolist())) == 4


def test_trivial_k_short_circuits_multilevel():
    W, _ = ring_of_cliques(4, 6)
    res = p_spectral_cluster(W, PSCConfig(
        k=1, multilevel=MultilevelConfig(coarse_size=8)))
    assert (res.labels == 0).all()
    assert res.levels is None and res.p_path == []


# ------------------------------------------------------------- validate unit

def test_validate_rejects_nonfinite_with_hint():
    W, _ = _two_cliques()
    r, c, v = W.host_coo()
    v = np.array(v)
    v[5] = np.nan
    bad = SparseMatrix.from_coo(r, c, v, (24, 24))
    assert quick_check(bad) is not None
    with pytest.raises(GraphValidationError, match="repair=True") as ei:
        validate_graph(bad)
    assert any("non-finite" in i for i in ei.value.issues)


def test_validate_repairs_nonfinite_and_negative():
    W, _ = _two_cliques()
    r, c, v = W.host_coo()
    v = np.array(v)
    v[5] = np.inf
    v[7] = -3.0
    bad = SparseMatrix.from_coo(r, c, v, (24, 24))
    fixed = validate_graph(bad, ValidateConfig(repair=True))
    fv = np.asarray(fixed.vals)
    assert np.isfinite(fv).all() and (fv > 0).all()
    # repair re-symmetrizes: dropping one direction of an edge must not
    # leave its mirror behind
    rr, cc, _ = fixed.host_coo()
    assert set(zip(rr.tolist(), cc.tolist())) == \
        set(zip(cc.tolist(), rr.tolist()))


def test_validate_repairs_asymmetry():
    W = SparseMatrix.from_coo(np.array([0, 1, 2]), np.array([1, 2, 0]),
                              np.array([1.0, 2.0, 3.0]), (3, 3))
    with pytest.raises(GraphValidationError, match="asym"):
        validate_graph(W)
    fixed = validate_graph(W, ValidateConfig(repair=True))
    assert fixed.nnz == 6
    rr, cc, vv = fixed.host_coo()
    d = {(int(a), int(b)): float(x) for a, b, x in zip(rr, cc, vv)}
    assert d[(0, 1)] == d[(1, 0)] == 1.0


# -------------------------------------------------------------------- serve

def test_serve_tiny_and_degenerate_k():
    cfg = PSCConfig(k=2, newton_iters=6, tcg_iters=4)
    eng = ClusterServeEngine(cfg)
    W2 = _sym([(0, 1)], 2)
    Wstar = _sym([(0, i) for i in range(1, 9)], 9)
    rid_edge = eng.submit(W2)                        # k == n -> solo lane
    rid_one = eng.submit(Wstar, k=1)
    rid_star = eng.submit(Wstar)
    done = eng.flush()
    assert done[rid_edge].ok
    assert sorted(done[rid_edge].labels.tolist()) == [0, 1]
    assert done[rid_edge].stats.lane == "solo"
    assert done[rid_one].ok and (done[rid_one].labels == 0).all()
    assert done[rid_star].ok
    assert len(set(done[rid_star].labels.tolist())) == 2
    with pytest.raises(ValueError, match="k="):
        eng.submit(W2, k=5)
    assert eng.stats.n_failed == 0
