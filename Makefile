PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify imports lint lint-fix test test-dist test-serve test-chaos \
	test-obs dryrun-smoke bench-kernels bench-multilevel bench-dist \
	bench-solvers bench-serve

# Mirrors .github/workflows/ci.yml: import health, the pscheck invariant
# analyzer, then the tier-1 suite.
verify: imports lint test

imports:
	$(PY) -m pytest -x -q tests/test_imports.py

# pscheck (repro.analysis, DESIGN.md §11): AST invariant analysis over
# src/repro.  Fails on any unbaselined finding AND on stale baseline
# entries (the ledger is shrink-only — fix a violation, shrink the file).
lint:
	$(PY) -m repro.analysis src/repro --baseline pscheck_baseline.json

# Apply the mechanical per-rule fixers (np->jnp, mutable defaults) in
# place, then report what is left.
lint-fix:
	$(PY) -m repro.analysis src/repro --fix \
		--baseline pscheck_baseline.json

test:
	$(PY) -m pytest -x -q

dryrun-smoke:
	$(PY) -m pytest -x -q tests/test_dryrun_smoke.py

# Regenerates the committed BENCH_backends.json + BENCH_sellcs.json +
# BENCH_multilevel.json (backend-descriptor sweep, the SELL-C-σ
# C x sigma x reorder sweep, and the flat-vs-V-cycle sweep — the last
# one solves 131k-524k-node graphs end to end, budget ~20-30 min on CPU;
# use bench-multilevel to rerun just that piece).
bench-kernels:
	$(PY) benchmarks/kernels_bench.py

bench-multilevel:
	$(PY) -c "from pathlib import Path; \
	import benchmarks.kernels_bench as b; \
	b.sweep_multilevel(out_path=Path('BENCH_multilevel.json'))"

# Solver-driver sweep (graph x p x {newton, scf, inverse_power},
# DESIGN.md §7); commits driver equivalence + cost to BENCH_solvers.json.
bench-solvers:
	$(PY) -c "from pathlib import Path; \
	import benchmarks.kernels_bench as b; \
	b.sweep_solvers(out_path=Path('BENCH_solvers.json'))"

# Halo-exchange vs all-gather distributed SpMM (shards x k x placement
# on SBM + delaunay) over a forced 8-device host platform; commits the
# wire-byte + wall-clock evidence for DESIGN.md §4 to BENCH_dist.json.
bench-dist:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	JAX_PLATFORMS=cpu $(PY) -c "from pathlib import Path; \
	import benchmarks.kernels_bench as b; \
	b.sweep_dist(out_path=Path('BENCH_dist.json'))"

# The dist subprocess suites under a forced 4-device host platform
# (CI runs this in addition to the default 8-device run inside `test`).
test-dist:
	DIST_TEST_DEVICES=4 $(PY) -m pytest -x -q \
	tests/test_dist_spmv.py tests/test_dist_halo.py

# The clustering serve engine by name: bucketed-batch == flat pad
# invariance, one-trace-per-bucket accounting, warm-cache + churn
# semantics (DESIGN.md §8).
test-serve:
	$(PY) -m pytest -x -q tests/test_psc_serve.py tests/test_warm_cache.py

# Chaos / resilience suite (DESIGN.md §9): injected faults must fire
# every recovery-ladder rung and the serve isolation paths, plus the
# degenerate-graph admission tests.  Faults are deterministic;
# `CHAOS_SEED=<n> make test-chaos` replays a specific draw.
test-chaos:
	$(PY) -m pytest -x -q tests/test_chaos.py tests/test_degenerate_graphs.py

# Telemetry layer by name (DESIGN.md §10): span recorder semantics +
# Chrome/JSONL export round-trips, metrics snapshot/delta/exposition,
# the retrace detector, the <=2% disabled-tracing overhead bound, and
# the rung-counter exactly-once contract.
test-obs:
	$(PY) -m pytest -x -q tests/test_obs.py

# Regenerates the committed BENCH_serve.json: one trace per bucket over
# a mixed stream, warm >= 3x cold at equal RCut, incremental churn
# re-cluster >= 2x from-scratch within 2% RCut.  Asserts all three.
bench-serve:
	$(PY) benchmarks/serve_bench.py
