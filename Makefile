PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify imports test dryrun-smoke bench-kernels bench-multilevel

# Mirrors .github/workflows/ci.yml: import health, then the tier-1 suite.
verify: imports test

imports:
	$(PY) -m pytest -x -q tests/test_imports.py

test:
	$(PY) -m pytest -x -q

dryrun-smoke:
	$(PY) -m pytest -x -q tests/test_dryrun_smoke.py

# Regenerates the committed BENCH_backends.json + BENCH_sellcs.json +
# BENCH_multilevel.json (backend-descriptor sweep, the SELL-C-σ
# C x sigma x reorder sweep, and the flat-vs-V-cycle sweep — the last
# one solves 131k-524k-node graphs end to end, budget ~20-30 min on CPU;
# use bench-multilevel to rerun just that piece).
bench-kernels:
	$(PY) benchmarks/kernels_bench.py

bench-multilevel:
	$(PY) -c "from pathlib import Path; \
	import benchmarks.kernels_bench as b; \
	b.sweep_multilevel(out_path=Path('BENCH_multilevel.json'))"
