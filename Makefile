PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify imports test dryrun-smoke

# Mirrors .github/workflows/ci.yml: import health, then the tier-1 suite.
verify: imports test

imports:
	$(PY) -m pytest -x -q tests/test_imports.py

test:
	$(PY) -m pytest -x -q

dryrun-smoke:
	$(PY) -m pytest -x -q tests/test_dryrun_smoke.py
