PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify imports test dryrun-smoke bench-kernels

# Mirrors .github/workflows/ci.yml: import health, then the tier-1 suite.
verify: imports test

imports:
	$(PY) -m pytest -x -q tests/test_imports.py

test:
	$(PY) -m pytest -x -q

dryrun-smoke:
	$(PY) -m pytest -x -q tests/test_dryrun_smoke.py

# Regenerates the committed BENCH_backends.json + BENCH_sellcs.json
# (backend-descriptor sweep and the SELL-C-σ C x sigma x reorder sweep).
bench-kernels:
	$(PY) benchmarks/kernels_bench.py
