"""§III-B analog: per-stage runtime breakdown of the GrB-pGrass
pipeline — p=2 eigenvectors (LOBPCG SpMM-bound), Grassmann continuation
(Hessian-apply bound = the paper's GraphBLAS component), kmeans.

The paper reports that only the GraphBLAS components scale; this
breakdown shows where the time goes so Fig-1's scaling projection can
be applied per component."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import lobpcg, kmeans as km, metrics, solvers
from repro.core.psc import PSCConfig
from repro.graphs import delaunay_graph

K = 4


def run(r=11):
    W, _ = delaunay_graph(r, seed=0)
    cfg = PSCConfig(k=K, p_target=1.3, newton_iters=15, tcg_iters=10,
                    kmeans_restarts=4, seed=0)

    t0 = time.time()
    _, U = lobpcg.smallest_eigvecs(W, K, seed=0)
    U = jnp.linalg.qr(U)[0]
    jax.block_until_ready(U)
    t_eig = time.time() - t0

    t0 = time.time()
    n_hvp = 0
    for p in solvers.p_schedule(cfg):
        res = solvers.minimize_at_p(W, U, p, cfg)
        U = res.U
        n_hvp += int(res.n_apply)
    jax.block_until_ready(U)
    t_cont = time.time() - t0

    t0 = time.time()
    Xn = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True), 1e-12)
    labels, _ = km.kmeans(jax.random.PRNGKey(0), Xn, K,
                          restarts=cfg.kmeans_restarts)
    jax.block_until_ready(labels)
    t_km = time.time() - t0

    total = t_eig + t_cont + t_km
    return {"r": r, "total_s": total, "t_eig_s": t_eig, "t_cont_s": t_cont,
            "t_kmeans_s": t_km, "n_hvp": n_hvp,
            "grb_pct": 100 * (t_eig + t_cont) / total,
            "rcut": float(metrics.rcut(W, labels, K))}


def main(csv=True):
    row = run()
    lines = [
        f"breakdown_del{row['r']}_eig,{row['t_eig_s']*1e6:.0f},"
        f"share={100*row['t_eig_s']/row['total_s']:.0f}%",
        f"breakdown_del{row['r']}_continuation,{row['t_cont_s']*1e6:.0f},"
        f"share={100*row['t_cont_s']/row['total_s']:.0f}%_hvps={row['n_hvp']}",
        f"breakdown_del{row['r']}_kmeans,{row['t_kmeans_s']*1e6:.0f},"
        f"share={100*row['t_kmeans_s']/row['total_s']:.0f}%",
        f"breakdown_del{row['r']}_total,{row['total_s']*1e6:.0f},"
        f"grb_components={row['grb_pct']:.0f}%",
    ]
    if csv:
        for line in lines:
            print(line)
    return row


if __name__ == "__main__":
    main()
