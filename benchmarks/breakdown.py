"""§III-B analog: per-stage runtime breakdown of the GrB-pGrass
pipeline — p=2 eigenvectors (LOBPCG SpMM-bound), Grassmann continuation
(Hessian-apply bound = the paper's GraphBLAS component), kmeans.

The paper reports that only the GraphBLAS components scale; this
breakdown shows where the time goes so Fig-1's scaling projection can
be applied per component.

Since the telemetry layer (DESIGN.md §10) the numbers come straight
from the pipeline's own spans: one traced ``p_spectral_cluster`` call,
then ``PSCResult.telemetry.phase_breakdown()`` — no hand-rolled timers
re-implementing the pipeline stage by stage, so the breakdown can never
drift from what the production path actually runs.
"""
from __future__ import annotations

from repro.core.psc import PSCConfig, p_spectral_cluster
from repro.graphs import delaunay_graph

K = 4


def run(r=11):
    W, _ = delaunay_graph(r, seed=0)
    cfg = PSCConfig(k=K, p_target=1.3, newton_iters=15, tcg_iters=10,
                    kmeans_restarts=4, seed=0, trace=True)
    res = p_spectral_cluster(W, cfg)
    tel = res.telemetry
    phases = tel.phase_breakdown()          # {"init", "continuation", "kmeans"}
    total = tel.total_s()
    n_hvp = sum(int(s.attrs.get("n_apply", 0))
                for s in tel.spans if s.name == "solver.level")
    t_eig = phases.get("init", 0.0)
    t_cont = phases.get("continuation", 0.0)
    t_km = phases.get("kmeans", 0.0)
    return {"r": r, "total_s": total, "t_eig_s": t_eig, "t_cont_s": t_cont,
            "t_kmeans_s": t_km, "n_hvp": n_hvp,
            "grb_pct": 100 * (t_eig + t_cont) / total,
            "coverage": tel.coverage(),
            "rcut": res.rcut}


def main(csv=True):
    row = run()
    lines = [
        f"breakdown_del{row['r']}_eig,{row['t_eig_s']*1e6:.0f},"
        f"share={100*row['t_eig_s']/row['total_s']:.0f}%",
        f"breakdown_del{row['r']}_continuation,{row['t_cont_s']*1e6:.0f},"
        f"share={100*row['t_cont_s']/row['total_s']:.0f}%_hvps={row['n_hvp']}",
        f"breakdown_del{row['r']}_kmeans,{row['t_kmeans_s']*1e6:.0f},"
        f"share={100*row['t_kmeans_s']/row['total_s']:.0f}%",
        f"breakdown_del{row['r']}_total,{row['total_s']*1e6:.0f},"
        f"grb_components={row['grb_pct']:.0f}%_coverage="
        f"{100*row['coverage']:.0f}%",
    ]
    if csv:
        for line in lines:
            print(line)
    return row


if __name__ == "__main__":
    main()
