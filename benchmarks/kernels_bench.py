"""Kernel microbenchmarks: jnp/XLA-CPU wall time of each kernel's ref
path (us/call) + the BSR fill ratio the TPU kernel would pay.
(Pallas interpret-mode timing is not meaningful as a device proxy; the
bsr-interpret row below is recorded only so the backend-descriptor
trajectory has every dispatch path on it.  TPU wall time comes from the
roofline analysis.)

Also sweeps the unified-API backend descriptor (coo / ell / sellcs /
bsr_pallas-ref / bsr_pallas-interpret / edge coo vs ref) on one
synthetic graph and emits BENCH_backends.json at the repo root so later
PRs have a perf trajectory for the dispatch table, plus the SELL-C-σ
sweep (C x sigma x reorder vs coo/ell, skewed-degree + delaunay) into
BENCH_sellcs.json, plus the flat-vs-multilevel V-cycle sweep
(131k-524k-node graphs, DESIGN.md §6) into BENCH_multilevel.json, plus
the solver-driver sweep (graph × p × {newton, scf, inverse_power},
DESIGN.md §7) into BENCH_solvers.json.  ``make bench-kernels``
regenerates all of them; ``make bench-multilevel`` / ``make
bench-solvers`` rerun just their own sweep (the multilevel one solves
big graphs end to end — the long pole).

The distributed sweep (halo exchange vs all-gather, shards × k ×
placement, DESIGN.md §4) lives in ``sweep_dist`` and emits
BENCH_dist.json; it needs a multi-device platform, so it has its own
entry point: ``make bench-dist`` (forces 8 host devices).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import delaunay_graph, reorder, sbm_graph
from repro.grblas import Descriptor, SparseMatrix, mxm, plap_edge_semiring
from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.flash_attention import flash_attention

_ROOT = Path(__file__).resolve().parent.parent


def _time(f, *a, reps=5):
    r = f(*a)
    jax.block_until_ready(r)
    t0 = time.time()
    for _ in range(reps):
        r = f(*a)
    jax.block_until_ready(r)
    return (time.time() - t0) / reps * 1e6


def sweep_backends(r=10, k=4, out_path=None):
    """Time one SpMM per backend descriptor on a delaunay graph."""
    W, _ = delaunay_graph(r, seed=0, build_bsr=True, block_size=128,
                          build_sellcs=True)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((W.n_rows, k)), jnp.float32)
    ring = plap_edge_semiring(1.4, 1e-8)

    cases = [
        ("reals", "coo", Descriptor(backend="coo")),
        ("reals", "ell", Descriptor(backend="ell")),
        ("reals", "sellcs", Descriptor(backend="sellcs")),
        ("reals", "bsr_ref", Descriptor(backend="bsr_pallas")),
        ("reals", "bsr_interpret",
         Descriptor(backend="bsr_pallas", interpret=True)),
        ("plap_edge", "coo", Descriptor(backend="coo")),
        ("plap_edge", "sellcs", Descriptor(backend="sellcs")),
        ("plap_edge", "edge_ref", Descriptor(backend="edge_pallas")),
    ]
    entries = []
    # minimum-traffic byte model of one SpMM (same model the grblas
    # dispatch spans attach — obs.trace.roofline_summary uses it): the
    # achieved-GB/s column turns wall_us into roofline fractions
    from repro.grblas.api import _traffic_bytes

    nbytes = _traffic_bytes(W, k)
    for ring_name, label, desc in cases:
        rg = ring if ring_name == "plap_edge" else None
        if rg is None:
            fn = jax.jit(lambda u, d=desc: mxm(W, u, desc=d))
        else:
            fn = jax.jit(lambda u, d=desc: mxm(W, u, rg, desc=d))
        reps = 2 if "interpret" in label else 5
        us = _time(fn, X, reps=reps)
        entries.append({"ring": ring_name, "backend": label,
                        "wall_us": round(us, 1),
                        "achieved_gb_s": round(nbytes / (us * 1e-6) / 1e9,
                                               3)})
    payload = {
        "schema": 2,
        "graph": f"delaunay_r{r}", "n": W.n_rows, "nnz": W.nnz, "k": k,
        "traffic_bytes_per_spmm": int(nbytes),
        "bsr_fill_ratio": round(W.bsr_fill_ratio(), 2),
        "ell_fill_ratio": round(W.ell_fill_ratio(), 2),
        "sellcs_fill_ratio": round(W.sellcs_fill_ratio(), 2),
        "platform": jax.default_backend(),
        "entries": entries,
    }
    if out_path is not None:
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


# ------------------------------------------------------ SELL-C-σ sweep

def _skewed_sbm(seed=0, **kw):
    """SBM with a tiny hub block: ~16 rows of degree ~200 over a ~deg-8
    background — the power-law-ish regime where full ELL pads every row
    to the hub width (fill >> 4x)."""
    W, _ = sbm_graph([4000, 16], p_in=0.002, p_out=0.05, seed=seed,
                     build_ell=True, **kw)   # force ELL: it IS the baseline
    return W


def _rebuild(W: SparseMatrix, C, sigma, method):
    """Build the sweep variant: same graph, explicit SELL params, then an
    optional bandwidth-reducing relabel (which preserves the params)."""
    W2 = SparseMatrix.from_coo(
        np.asarray(W.rows), np.asarray(W.cols), np.asarray(W.vals),
        (W.n_rows, W.n_cols), build_ell=True, build_sellcs=True,
        sell_c=C, sell_sigma=sigma)
    if method != "none":
        W2, _, _ = reorder(W2, method=method)
    return W2


def sweep_sellcs(k=4, out_path=None, reps=20):
    """sellcs x {C, sigma, reorder} against coo / full-ELL, on a
    skewed-degree SBM and a delaunay triangulation (reals ring — the
    layout-bound op; the edge kinds share the same gather pattern)."""
    rng = np.random.default_rng(0)
    graphs = [
        ("sbm_skew", _skewed_sbm(seed=0)),
        ("delaunay_r13", delaunay_graph(13, seed=0)[0]),
    ]
    payload = {"schema": 2, "platform": jax.default_backend(), "k": k,
               "graphs": []}
    for name, W in graphs:
        X = jnp.asarray(rng.standard_normal((W.n_rows, k)), jnp.float32)
        entry = {
            "graph": name, "n": W.n_rows, "nnz": W.nnz,
            "ell_fill_ratio": round(W.ell_fill_ratio(), 2),
            "baselines": [], "sellcs": [],
        }
        for label, desc in (("coo", Descriptor(backend="coo")),
                            ("ell", Descriptor(backend="ell"))):
            us = _time(jax.jit(lambda u, d=desc: mxm(W, u, desc=d)), X,
                       reps=reps)
            entry["baselines"].append({"backend": label,
                                       "wall_us": round(us, 1)})
        sell_desc = Descriptor(backend="sellcs")
        for C in (16, 32, 64):
            for sigma_name, sigma in (("C", C), ("8C", 8 * C), ("n", None)):
                for method in ("none", "rcm"):
                    Ws = _rebuild(W, C, sigma, method)
                    us = _time(
                        jax.jit(lambda u, M=Ws: mxm(M, u, desc=sell_desc)),
                        X, reps=reps)
                    entry["sellcs"].append({
                        "C": C, "sigma": sigma_name, "reorder": method,
                        "wall_us": round(us, 1),
                        "fill_ratio": round(Ws.sellcs_fill_ratio(), 3),
                    })
        best = min(entry["sellcs"], key=lambda e: e["wall_us"])
        ell_us = next(b["wall_us"] for b in entry["baselines"]
                      if b["backend"] == "ell")
        entry["best_sellcs"] = best
        entry["speedup_vs_ell"] = round(ell_us / best["wall_us"], 2)
        payload["graphs"].append(entry)
    if out_path is not None:
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


# --------------------------------------------------- distributed SpMM sweep

def sweep_dist(out_path=None, shards=(4, 8), ks=(1, 8, 16, 32), reps=16):
    """Halo-exchange vs all-gather distributed SpMM (grblas.dist):
    shards × k × placement on a cluster-aligned SBM and a delaunay
    triangulation, plus the per-shard SELL-C-σ layout on the same plan.

    Wire bytes are the analytic per-call volumes of the static plans
    (RowPartitionedMatrix.wire_bytes — the collectives move exactly the
    planned rows); wall clock is measured over the forced host-device
    mesh, and every path is pinned against the coo result.  Needs a
    multi-device platform: ``make bench-dist`` forces 8 host devices.
    """
    from repro.compat import make_mesh
    from repro.graphs import sbm_graph_sparse
    from repro.grblas import HALO_FALLBACK_FRAC, make_row_partition

    n_dev = len(jax.devices())
    if n_dev < max(shards):
        raise RuntimeError(
            f"sweep_dist needs >= {max(shards)} devices, found {n_dev}: run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "(`make bench-dist`)")

    def _tmed(f, X, reps=reps):
        """Median-of-reps: the host-device collectives are noisy."""
        r = f(X)
        jax.block_until_ready(r)
        ts = []
        for _ in range(reps):
            t0 = time.time()
            r = f(X)
            jax.block_until_ready(r)
            ts.append(time.time() - t0)
        return float(np.median(ts) * 1e6)

    rng = np.random.default_rng(0)
    # the communication term dominates when avg degree is small relative
    # to the shard count (per-shard flops ~ (nnz/S)·k vs gather copy
    # n·k), so the sweep uses the sparse-degree regime the halo targets
    Wsbm, truth = sbm_graph_sparse([16384] * 4, deg_in=8.0, deg_out=0.8,
                                   seed=0, build_ell=True)
    Wdel, _ = delaunay_graph(15, seed=0)
    graphs = [
        # aligned = the planted clusters; delaunay's natural order is
        # its own locality-aligned placement (contiguous row blocks)
        ("sbm4_65k", Wsbm, truth),
        ("delaunay_r15", Wdel, None),
    ]
    payload = {"schema": 2,
               "platform": jax.default_backend(), "n_devices": n_dev,
               "halo_note": "wire bytes analytic per call; self-chunks and "
                            "own shards excluded on both schedules",
               "graphs": []}
    for name, W, aligned in graphs:
        entry = {"graph": name, "n": W.n_rows, "nnz": W.nnz, "entries": []}
        for S in shards:
            mesh = make_mesh((int(S),), ("data",))
            d = Descriptor(backend="dist", mesh=mesh)
            ds = Descriptor(backend="dist_sellcs", mesh=mesh)
            for placement in ("aligned", "shuffled"):
                asg = aligned if placement == "aligned" else \
                    rng.permutation(W.n_rows)
                halo = make_row_partition(W, S, assignment=asg, mode="halo")
                gath = make_row_partition(W, S, assignment=asg,
                                          mode="gather")
                sell = make_row_partition(W, S, assignment=asg, mode="halo",
                                          sellcs=True)
                # what mode="auto" would have picked — the build-time
                # rule of make_row_partition, derived from the forced
                # halo plan instead of building a fourth partition
                mode_auto = ("halo" if halo.halo_width
                             <= HALO_FALLBACK_FRAC * halo.rows_per_shard
                             else "gather")
                for k in ks:
                    shape = (W.n_rows,) if k == 1 else (W.n_rows, k)
                    X = jnp.asarray(rng.standard_normal(shape), jnp.float32)
                    ref = np.asarray(mxm(W, X))
                    us_h = _tmed(jax.jit(lambda u: mxm(halo, u, desc=d)), X)
                    us_g = _tmed(jax.jit(lambda u: mxm(gath, u, desc=d)), X)
                    us_s = _tmed(jax.jit(lambda u: mxm(sell, u, desc=ds)), X)
                    err = max(
                        float(np.abs(np.asarray(mxm(p, X, desc=dd)) - ref).max())
                        for p, dd in ((halo, d), (gath, d), (sell, ds)))
                    wb = halo.wire_bytes(k=k)
                    entry["entries"].append({
                        "shards": int(S), "placement": placement, "k": k,
                        "mode_auto": mode_auto,
                        "halo_width": wb["halo_width"],
                        "halo_rows_true": wb["halo_rows_true"],
                        "wire_bytes_halo": wb["halo"],
                        "wire_bytes_gather": wb["gather"],
                        "wire_ratio": round(wb["halo"] / max(wb["gather"], 1),
                                            3),
                        "wall_us_halo": round(us_h, 1),
                        "wall_us_gather": round(us_g, 1),
                        "wall_us_dist_sellcs": round(us_s, 1),
                        "wall_speedup_halo_vs_gather": round(us_g / us_h, 2),
                        "wall_speedup_sellcs_vs_gather": round(us_g / us_s,
                                                               2),
                        "max_abs_err_vs_coo": err,
                    })
        payload["graphs"].append(entry)
    # headline: the acceptance configuration (aligned SBM, 4 shards);
    # both dist flavours ride the same halo plan — sellcs is the faster
    # execution of it (per-slice padding cuts the fold width too)
    head = [e for g in payload["graphs"] if g["graph"] == "sbm4_65k"
            for e in g["entries"]
            if e["shards"] == 4 and e["placement"] == "aligned"
            and e["k"] >= 16]
    payload["headline_sbm4_aligned_4shards"] = [
        {k: e[k] for k in ("k", "wire_ratio", "wall_speedup_halo_vs_gather",
                           "wall_speedup_sellcs_vs_gather")}
        for e in head]
    if out_path is not None:
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


# ------------------------------------------------- multilevel V-cycle sweep

def sweep_multilevel(out_path=None, k=4, seed=0):
    """Flat solver vs the multilevel V-cycle (repro.multilevel) across
    hierarchy depths × graph sizes, recording RCut + end-to-end wall
    clock.  Emits BENCH_multilevel.json — the committed evidence for the
    DESIGN.md §6 claim (≥3× end-to-end at ≥100k nodes within 1% RCut).

    Graph families mirror the paper's evaluation: delaunay
    triangulations (delaunay_nXX) and a planted-partition SBM in the
    sparse regime (sbm_graph_sparse — the dense generator is O(n²)).
    The 524k-node delaunay runs flat once for the scaling point; the
    depth sweep lives on the ~131k graphs to keep the bench re-runnable.
    """
    import dataclasses

    from repro.core import PSCConfig, p_spectral_cluster
    from repro.graphs import sbm_graph_sparse
    from repro.multilevel import MultilevelConfig

    base = PSCConfig(k=k, p_target=1.4, newton_iters=15, tcg_iters=12,
                     kmeans_restarts=4, seed=seed, trace=True)

    def _phases(res):
        tel = res.telemetry
        if tel is None:
            return None
        return {name: round(sec, 3)
                for name, sec in sorted(tel.phase_breakdown().items())}
    graphs = [
        ("delaunay_r17", lambda: delaunay_graph(17, seed=seed)[0], (3, 12)),
        # weighted planted partition (w_in > w_out, similarity-graph
        # style): degrees dense enough that no vertex is isolated (an
        # isolated vertex makes RCut trivially 0) and the planted cut is
        # the unambiguous optimum — in the *unit-weight* sparse regime
        # the blocks are locally invisible (no triangles, equal
        # degrees), so any locality-based coarsening — ours or
        # Metis-style — loses them while global eigenvectors keep them;
        # that regime measures generator degeneracy, not solver quality
        ("sbm_131k", lambda: sbm_graph_sparse(
            [32768] * k, deg_in=16.0, deg_out=4.0, w_in=2.0, w_out=1.0,
            seed=seed)[0], (3, 12)),
        ("delaunay_r19", lambda: delaunay_graph(19, seed=seed)[0], (12,)),
    ]
    payload = {"schema": 2, "platform": jax.default_backend(), "k": k,
               "config": {"p_target": base.p_target,
                          "newton_iters": base.newton_iters,
                          "tcg_iters": base.tcg_iters}, "graphs": []}
    for name, make, depths in graphs:
        W = make()
        t0 = time.time()
        rf = p_spectral_cluster(W, base)
        t_flat = time.time() - t0
        entry = {
            "graph": name, "n": W.n_rows, "nnz": W.nnz,
            "flat": {"rcut": float(rf.rcut), "wall_s": round(t_flat, 2),
                     "init_rcut": float(rf.init_rcut),
                     "phase_s": _phases(rf)},
            "vcycle": [],
        }
        for depth in depths:
            cfg = dataclasses.replace(
                base, multilevel=MultilevelConfig(max_levels=depth))
            t0 = time.time()
            rm = p_spectral_cluster(W, cfg)
            t_ml = time.time() - t0
            recs = rm.levels or []
            n_levels = recs[0]["n_levels"] if recs else 1
            entry["vcycle"].append({
                "max_levels": depth, "hierarchy_levels": n_levels,
                "levels_refined": len({r["level"] for r in recs}),
                "phase_s": _phases(rm),
                "rcut": float(rm.rcut), "wall_s": round(t_ml, 2),
                "speedup_vs_flat": round(t_flat / t_ml, 2),
                "rcut_gap_pct": round(
                    (float(rm.rcut) - float(rf.rcut))
                    / max(float(rf.rcut), 1e-12) * 100.0, 3),
            })
        best = max(entry["vcycle"], key=lambda e: e["speedup_vs_flat"])
        entry["best_vcycle"] = best
        payload["graphs"].append(entry)
        print(f"[multilevel] {name}: flat {t_flat:.1f}s rcut={rf.rcut:.5f}; "
              f"best vcycle {best['wall_s']}s ({best['speedup_vs_flat']}x, "
              f"gap {best['rcut_gap_pct']}%)")
    if out_path is not None:
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


# --------------------------------------------------- solver-driver sweep

def sweep_solvers(out_path=None, k=4, seed=0):
    """Registry-driver sweep (DESIGN.md §7): graph family × p × solver,
    recording wall clock, RCut and (where a planted truth exists)
    clustering accuracy.  Emits BENCH_solvers.json — the committed
    evidence that the three continuation drivers land equivalent cuts
    and what each costs, plus the p=1.0 sparsest-cut row only the
    inverse-power driver can serve.  ``make bench-solvers`` regenerates.
    """
    from repro.core import PSCConfig, metrics, p_spectral_cluster
    from repro.graphs import gaussian_blobs_knn

    graphs = [
        # second element: planted labels where the family has them
        # (delaunay's is vertex coordinates — no planted truth)
        ("sbm4_120", lambda: sbm_graph([30] * k, p_in=0.5, p_out=0.03,
                                       seed=5)[:2]),
        ("blobs4_480", lambda: gaussian_blobs_knn(120, k, seed=1)[:2]),
        ("delaunay_r10", lambda: (delaunay_graph(10, seed=seed)[0], None)),
    ]
    payload = {"schema": 2, "platform": jax.default_backend(), "k": k,
               "entries": []}
    for name, make in graphs:
        W, truth = make()
        for p_target in (1.4, 1.1, 1.0):
            for solver in ("newton", "scf", "inverse_power"):
                if p_target == 1.0 and solver != "inverse_power":
                    continue        # p=1 is outside newton/scf's open range
                cfg = PSCConfig(k=k, p_target=p_target, newton_iters=15,
                                tcg_iters=10, kmeans_restarts=4, seed=seed,
                                solver=solver, scf_sweeps=10, ipm_iters=100,
                                trace=True)
                t0 = time.time()
                res = p_spectral_cluster(W, cfg)
                wall = time.time() - t0
                tel = res.telemetry
                row = {"graph": name, "n": W.n_rows, "nnz": W.nnz,
                       "p_target": p_target, "solver": solver,
                       "wall_s": round(wall, 2),
                       "phase_s": None if tel is None else
                       {ph: round(sec, 3) for ph, sec
                        in sorted(tel.phase_breakdown().items())},
                       "rcut": round(float(res.rcut), 5),
                       "n_apply": int(sum(res.hvp_counts))}
                if truth is not None:
                    row["accuracy"] = round(float(
                        metrics.clustering_accuracy(res.labels, truth, k)), 4)
                payload["entries"].append(row)
                print(f"[solvers] {name} p={p_target} {solver}: "
                      f"{wall:.1f}s rcut={row['rcut']}"
                      + (f" acc={row.get('accuracy')}" if truth is not None
                         else ""))
    # headline: per (graph, p) the cheapest driver within 2% RCut of the
    # best — what the registry buys over newton-everywhere
    head = []
    seen = {(e["graph"], e["p_target"]) for e in payload["entries"]}
    for g, p in sorted(seen):
        rows = [e for e in payload["entries"]
                if e["graph"] == g and e["p_target"] == p]
        best_rcut = min(e["rcut"] for e in rows)
        ok = [e for e in rows if e["rcut"] <= best_rcut * 1.02 + 1e-9]
        w = min(ok, key=lambda e: e["wall_s"])
        head.append({"graph": g, "p_target": p, "winner": w["solver"],
                     "wall_s": w["wall_s"], "rcut": w["rcut"]})
    payload["headline_cheapest_within_2pct_rcut"] = head
    if out_path is not None:
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main(csv=True):
    lines = []
    W, _ = delaunay_graph(12, seed=0, build_bsr=True, block_size=128)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((W.n_rows, 4)), jnp.float32)
    bsr_ref = Descriptor(backend="bsr_pallas")      # jnp blocked ref on CPU

    lines.append(f"kernel_bsr_spmm_del12,"
                 f"{_time(lambda x: mxm(W, x, desc=bsr_ref), X):.0f},"
                 f"fill_ratio={W.bsr_fill_ratio():.1f}")
    # BSR block-size sweep (EXPERIMENTS.md §Perf-kernels): fill ratio is
    # the HBM-roofline cost multiplier of the MXU-native layout
    for bs in (8, 16, 32, 64):
        Wb, _ = delaunay_graph(12, seed=0, build_bsr=True, block_size=bs)
        lines.append(f"kernel_bsr_fill_bs{bs},0,"
                     f"fill_ratio={Wb.bsr_fill_ratio():.1f}")
    lines.append(
        f"kernel_plap_edge_del12,"
        f"{_time(lambda x: mxm(W, x, plap_edge_semiring(1.4, 1e-9), desc=Descriptor(backend='edge_pallas')), X):.0f},"
        f"nnz={W.nnz}")
    C = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    lines.append(f"kernel_kmeans_assign_n{W.n_rows},"
                 f"{_time(lambda: kmeans_assign(X, C, use_pallas=False)):.0f},"
                 f"kc=16")
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), jnp.float32)
    lines.append(f"kernel_flash_gqa_s1024,"
                 f"{_time(lambda: flash_attention(q, k, k, use_pallas=False)):.0f},"
                 f"hq=8_hkv=2")

    bench = sweep_backends(out_path=_ROOT / "BENCH_backends.json")
    for e in bench["entries"]:
        lines.append(f"backend_{e['ring']}_{e['backend']}_del10,"
                     f"{e['wall_us']:.0f},n={bench['n']}")
    sell = sweep_sellcs(out_path=_ROOT / "BENCH_sellcs.json")
    for g in sell["graphs"]:
        b = g["best_sellcs"]
        lines.append(f"sellcs_best_{g['graph']},{b['wall_us']:.0f},"
                     f"C={b['C']}_sigma={b['sigma']}_reorder={b['reorder']}"
                     f"_fill={b['fill_ratio']}"
                     f"_speedup_vs_ell={g['speedup_vs_ell']}")
    ml = sweep_multilevel(out_path=_ROOT / "BENCH_multilevel.json")
    for g in ml["graphs"]:
        b = g["best_vcycle"]
        lines.append(f"multilevel_{g['graph']},{b['wall_s']},"
                     f"levels={b['hierarchy_levels']}"
                     f"_speedup_vs_flat={b['speedup_vs_flat']}"
                     f"_rcut_gap_pct={b['rcut_gap_pct']}")
    sol = sweep_solvers(out_path=_ROOT / "BENCH_solvers.json")
    for h in sol["headline_cheapest_within_2pct_rcut"]:
        lines.append(f"solver_winner_{h['graph']}_p{h['p_target']},"
                     f"{h['wall_s']},solver={h['winner']}_rcut={h['rcut']}")
    if csv:
        for line in lines:
            print(line)
    return lines


if __name__ == "__main__":
    main()
