"""Kernel microbenchmarks: jnp/XLA-CPU wall time of each kernel's ref
path (us/call) + the BSR fill ratio the TPU kernel would pay.
(Pallas interpret-mode timing is not meaningful; TPU wall time comes
from the roofline analysis.)"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import delaunay_graph
from repro.kernels.bsr_spmm import bsr_spmm
from repro.kernels.plap_edge import plap_apply
from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.flash_attention import flash_attention


def _time(f, *a, reps=5):
    r = f(*a)
    jax.block_until_ready(r)
    t0 = time.time()
    for _ in range(reps):
        r = f(*a)
    jax.block_until_ready(r)
    return (time.time() - t0) / reps * 1e6


def main(csv=True):
    lines = []
    W, _ = delaunay_graph(12, seed=0, build_bsr=True, block_size=128)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((W.n_rows, 4)), jnp.float32)

    lines.append(f"kernel_bsr_spmm_del12,"
                 f"{_time(lambda x: bsr_spmm(W, x, use_pallas=False), X):.0f},"
                 f"fill_ratio={W.fill_ratio:.1f}")
    # BSR block-size sweep (EXPERIMENTS.md §Perf-kernels): fill ratio is
    # the HBM-roofline cost multiplier of the MXU-native layout
    for bs in (8, 16, 32, 64):
        Wb, _ = delaunay_graph(12, seed=0, build_bsr=True, block_size=bs)
        lines.append(f"kernel_bsr_fill_bs{bs},0,fill_ratio={Wb.fill_ratio:.1f}")
    lines.append(f"kernel_plap_edge_del12,"
                 f"{_time(lambda x: plap_apply(W, x, 1.4, use_pallas=False), X):.0f},"
                 f"nnz={W.nnz}")
    C = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    lines.append(f"kernel_kmeans_assign_n{W.n_rows},"
                 f"{_time(lambda: kmeans_assign(X, C, use_pallas=False)):.0f},"
                 f"kc=16")
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), jnp.float32)
    lines.append(f"kernel_flash_gqa_s1024,"
                 f"{_time(lambda: flash_attention(q, k, k, use_pallas=False)):.0f},"
                 f"hq=8_hkv=2")
    if csv:
        for line in lines:
            print(line)
    return lines


if __name__ == "__main__":
    main()
