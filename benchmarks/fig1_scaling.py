"""Fig. 1 analog: scaling of the GraphBLAS components.

The paper measures strong scaling over CPU threads (1..32 / 1..88).
This container has one core, so we report:
  (a) measured single-core wall time of each GraphBLAS component
      (SpMM, p-Laplacian apply, Hessian apply, kmeans assign) across
      graph sizes r — the weak-scaling profile of the op costs, and
  (b) the projected strong scaling on the TPU mesh from the dry-run
      roofline: t(chips) = max(compute/chips, memory/chips, collective)
      for the distributed SpMM schedule (row-block + all-gather),
      chips in {1..256} — labeled as projection, not measurement.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import delaunay_graph
from repro.grblas import mxm, Descriptor, plap_edge_semiring
from repro.core import plap
from repro.core.kmeans import assign as km_assign

K = 4
_DESC = Descriptor(backend="auto")


def _time(f, *args, reps=5):
    f(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / reps


def run(rs=(10, 12, 14)):
    rows = []
    for r in rs:
        W, _ = delaunay_graph(r, seed=0)
        n = W.n_rows
        rng = np.random.default_rng(0)
        U = jnp.asarray(rng.standard_normal((n, K)), jnp.float32)
        eta = jnp.asarray(rng.standard_normal((n, K)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((K, K)), jnp.float32)

        spmm = jax.jit(lambda u: mxm(W, u, desc=_DESC))
        plap_f = jax.jit(lambda u: mxm(W, u, plap_edge_semiring(1.4, 1e-8),
                                       desc=_DESC))
        hvp = jax.jit(lambda u, e: plap.hess_eta_matrix_free(W, u, e, 1.4,
                                                             desc=_DESC))
        kma = jax.jit(lambda u, c: km_assign(u, c))

        rows.append({
            "r": r, "n": n, "nnz": W.nnz,
            "t_spmm_us": _time(spmm, U) * 1e6,
            "t_plap_us": _time(plap_f, U) * 1e6,
            "t_hvp_us": _time(hvp, U, eta) * 1e6,
            "t_kmeans_us": _time(kma, U, C) * 1e6,
        })
    return rows


def projection(nnz, k=K, chips_list=(1, 2, 4, 8, 16, 32, 64, 128, 256)):
    """Roofline projection of distributed SpMM strong scaling on v5e.

    Two schedules:
      naive       — row blocks + FULL multivector all-gather (vector
                    bytes rival matrix bytes => does NOT strong-scale;
                    the honest transfer of the paper's 1-D scheme).
      partitioned — rows placed by min-cut clustering (the paper's OWN
                    algorithm, repro.graphs/dist integration): only the
                    ~O(sqrt(n c)) boundary columns are exchanged.
    """
    from repro.launch.hlo_analysis import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
    n = nnz // 6
    out = []
    for c in chips_list:
        t_comp = 2.0 * nnz * k / c / PEAK_FLOPS_BF16
        t_mem = (nnz * (4 + 4) + nnz * k * 4) / c / HBM_BW
        t_naive = 0.0 if c == 1 else (n * k * 4) * (c - 1) / c / ICI_BW
        halo = 0.0 if c == 1 else 4.0 * (n / c) ** 0.5 * k * 4 / ICI_BW
        out.append((c, max(t_comp, t_mem, t_naive),
                    max(t_comp, t_mem, halo)))
    return out


def main(csv=True):
    rows = run()
    lines = []
    for row in rows:
        for op in ("spmm", "plap", "hvp", "kmeans"):
            lines.append(f"fig1_{op}_del{row['r']},"
                         f"{row[f't_{op}_us']:.0f},n={row['n']}")
    proj = projection(6 * 2 ** 20)
    t1 = proj[0][1]
    for c, t_naive, t_part in proj:
        lines.append(f"fig1_proj_spmm_del20_c{c},{t_part*1e6:.2f},"
                     f"naive={t1/t_naive:.1f}x_partitioned={t1/t_part:.1f}x")
    if csv:
        for line in lines:
            print(line)
    return rows


if __name__ == "__main__":
    main()
