"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import table1_rcut, fig1_scaling, breakdown, \
        kernels_bench, roofline_report

    suites = [
        ("table1_rcut (paper Table I)", table1_rcut.main),
        ("fig1_scaling (paper Fig. 1)", fig1_scaling.main),
        ("breakdown (paper §III-B)", breakdown.main),
        ("kernels_bench", kernels_bench.main),
        ("roofline_report (§Roofline)", roofline_report.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn(csv=True)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"# {name} FAILED: {e}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
