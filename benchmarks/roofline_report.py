"""Reads experiments/dryrun/*.json and prints the §Roofline table
(one row per arch x shape x mesh): three terms, bottleneck, MFU-at-
bottleneck, useful-flops ratio, bytes/device."""
from __future__ import annotations

import glob
import json
from pathlib import Path


def load(out_dir="experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(f"{out_dir}/*.json")):
        r = json.loads(Path(f).read_text())
        rows.append(r)
    return rows


def fraction_of_roofline(roof):
    """model_flops-time / dominant-term time: how close the step is to
    the ideal 'useful flops at peak' bound."""
    t_ideal = roof["model_flops"] / (roof["n_chips"] * 197e12)
    t_dom = max(roof["t_compute_s"], roof["t_memory_s"],
                roof["t_collective_s"])
    return t_ideal / t_dom if t_dom else float("nan")


def main(csv=True, mesh="single"):
    rows = load()
    lines = []
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        tag = f"{r['arch']}__{r['shape']}"
        if r["status"] != "ok":
            if str(r["status"]).startswith("skip"):
                lines.append(f"roofline_{tag},0,SKIP")
            else:
                lines.append(f"roofline_{tag},0,FAIL")
            continue
        roof = r["roofline"]
        t_dom = max(roof["t_compute_s"], roof["t_memory_s"],
                    roof["t_collective_s"])
        lines.append(
            f"roofline_{tag},{t_dom*1e6:.0f},"
            f"bottleneck={roof['bottleneck']}"
            f"_rooflinefrac={fraction_of_roofline(roof):.3f}"
            f"_useful={roof['useful_flops_ratio']:.2f}"
            f"_gbdev={r.get('bytes_per_device', 0)/1e9:.1f}")
    if csv:
        for line in lines:
            print(line)
    return lines


if __name__ == "__main__":
    main()
