"""Clustering-as-a-service bench (DESIGN.md §8): the committed evidence
for the serve engine's three contracts, written to BENCH_serve.json at
the repo root by ``make bench-serve``.

  1. trace economy — a mixed-size stream of >= 20 requests compiles
     exactly one trace per (bucket, mode) signature, asserted through
     ``repro.obs.retrace.RetraceDetector`` (which reads the solver
     registry's trace log), with the stream's wall clock broken down
     by serve-layer spans;
  2. warm >= 3x cold — an exact-tier cache hit (solver re-entry at the
     schedule tail) beats the full cold continuation by >= 3x wall
     clock at equal RCut (within 1%), measured steady-state (traces
     primed on separate graphs, per-request time = batch solve time /
     batch size);
  3. churn >= 2x scratch — an ``engine.update`` incremental re-cluster
     of a 1%-edge-churned graph beats a from-scratch cold solve of the
     edited graph by >= 2x within 2% RCut.

Every section raises on a violated bound, so a regression fails the
bench run rather than silently committing worse numbers.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.core import PSCConfig
from repro.core.solvers import registry
from repro.graphs import ring_of_cliques, sbm_graph
from repro.obs import TraceConfig, Tracer, use as use_tracer
from repro.obs.retrace import RetraceDetector
from repro.serve import ClusterServeEngine, EdgeDelta, apply_edge_delta, \
    bucket_for

K = 4


def _cfg(**kw):
    kw.setdefault("k", K)
    kw.setdefault("reorder", "none")
    kw.setdefault("newton_iters", 20)
    kw.setdefault("tcg_iters", 12)
    kw.setdefault("kmeans_restarts", 4)
    return PSCConfig(**kw)


def _reweighted(W, scale):
    return W.with_vals(np.asarray(W.vals) * scale)


def _serve_traces():
    return sum(1 for t in registry.SOLVER_TRACES if t and t[0] == "serve")


# --------------------------------------------------------------- section 1

def bench_stream(n_requests=24):
    """Mixed-size stream: one compiled trace per bucket, counted."""
    cfg = _cfg()
    Wa, _ = ring_of_cliques(4, 10)                   # bucket (64, 512)
    Wb, _ = ring_of_cliques(4, 6)                    # bucket (64, 128)
    stream = [_reweighted(Wa, 1.0 + 0.01 * i) for i in range(12)]
    stream += [_reweighted(Wb, 1.0 + 0.01 * i) for i in range(8)]
    stream += [sbm_graph([16] * 4, 0.25, 0.02, seed=i)[0] for i in range(4)]
    stream = stream[:n_requests]
    expected = {bucket_for(W, K, "cold").key for W in stream}

    eng = ClusterServeEngine(cfg, max_batch=8)
    det = RetraceDetector()
    tr = Tracer(TraceConfig())
    with use_tracer(tr):
        results = eng.serve(stream)
    # acceptance: exactly one compile per (bucket, solver) memo key —
    # a second compile of ANY serve key is a retrace and raises
    per_key = det.serve_buckets()
    det.assert_at_most(1)
    traces = sum(per_key.values())

    row = {
        "n_requests": len(stream),
        "n_buckets": len(expected),
        "buckets": sorted(str(k) for k in expected),
        "traces_compiled": traces,
        "compiles_per_bucket": {str(k): v for k, v in per_key.items()},
        "engine_traces": eng.stats.traces,
        "n_batches": eng.stats.n_batches,
        "graphs_per_s": round(eng.stats.graphs_per_s, 2),
        "mean_rcut": round(float(np.mean([r.rcut for r in results])), 4),
        "span_s": {name: round(sec, 4)
                   for name, sec in sorted(tr.by_name().items())},
        "one_trace_per_bucket": traces == len(expected)
        and all(v == 1 for v in per_key.values()),
    }
    assert row["one_trace_per_bucket"], row
    return row


# --------------------------------------------------------------- section 2

def bench_warm_vs_cold(n_measure=12, batch=4):
    """Steady-state per-request time: cold continuation vs exact-tier
    warm re-entry, same bucket, traces primed out-of-band."""
    cfg = _cfg()
    eng = ClusterServeEngine(cfg, max_batch=batch)
    primers = [sbm_graph([32] * 4, 0.3, 0.01, seed=100 + i)[0]
               for i in range(batch)]
    measured = [sbm_graph([32] * 4, 0.3, 0.01, seed=i)[0]
                for i in range(n_measure)]
    specs = {bucket_for(W, K, "cold").key for W in primers + measured}
    assert len(specs) == 1, f"measurement must stay in one bucket: {specs}"

    eng.serve(primers)                               # compile cold trace
    cold = eng.serve(measured)
    assert all(r.stats.mode == "cold" and not r.stats.trace_new
               for r in cold)
    eng.serve(primers)                               # compile warm trace
    warm = eng.serve(measured)
    assert all(r.stats.mode == "warm" and r.stats.cache_tier == "exact"
               and not r.stats.trace_new for r in warm)

    cold_s = float(np.mean([r.stats.solve_s / r.stats.batch_size
                            for r in cold]))
    warm_s = float(np.mean([r.stats.solve_s / r.stats.batch_size
                            for r in warm]))
    rel = [abs(w.rcut - c.rcut) / max(c.rcut, 1e-12)
           for c, w in zip(cold, warm)]
    row = {
        "n_measured": n_measure, "batch": batch,
        "bucket": str(next(iter(specs))),
        "cold_s_per_graph": round(cold_s, 4),
        "warm_s_per_graph": round(warm_s, 4),
        "speedup": round(cold_s / warm_s, 2),
        "rcut_rel_diff_max": round(max(rel), 5),
        "warm_ge_3x_at_equal_rcut": cold_s / warm_s >= 3.0
        and max(rel) <= 0.01,
    }
    assert row["warm_ge_3x_at_equal_rcut"], row
    return row


# --------------------------------------------------------------- section 3

def _flip_delta(W, frac, seed):
    rng = np.random.default_rng(seed)
    und = np.flatnonzero(np.asarray(W.rows) < np.asarray(W.cols))
    pick = rng.choice(und, max(1, int(frac * len(und))), replace=False)
    return EdgeDelta(np.asarray(W.rows)[pick], np.asarray(W.cols)[pick],
                     np.zeros(len(pick)))


def bench_churn(frac=0.01):
    """1% edge knockouts on a served SBM: engine.update's incremental
    re-cluster vs a from-scratch cold solve of the edited graph."""
    cfg = _cfg()
    W, _ = sbm_graph([40] * 4, 0.25, 0.02, seed=0)

    eng = ClusterServeEngine(cfg, max_batch=1)
    eng.serve([W])                                   # prime cold + cache
    rid = eng.update(W, _flip_delta(W, frac, seed=1))
    eng.flush().pop(rid)                             # prime the warm trace
    delta = _flip_delta(W, frac, seed=2)
    rid = eng.update(W, delta)
    churn = eng.flush()[rid]
    assert churn.stats.mode == "churn"

    W_new = apply_edge_delta(W, delta).W
    scratch_eng = ClusterServeEngine(cfg, max_batch=1)
    scratch = scratch_eng.serve([W_new])[0]
    assert scratch.stats.mode == "cold" and not scratch.stats.trace_new

    row = {
        "n": W.n_rows, "nnz": W.nnz,
        "edges_flipped": len(delta.rows),
        "churn_s": round(churn.stats.solve_s, 4),
        "scratch_s": round(scratch.stats.solve_s, 4),
        "speedup": round(scratch.stats.solve_s / churn.stats.solve_s, 2),
        "rcut_churn": round(churn.rcut, 4),
        "rcut_scratch": round(scratch.rcut, 4),
        "churn_ge_2x_within_2pct": scratch.stats.solve_s
        >= 2.0 * churn.stats.solve_s
        and churn.rcut <= scratch.rcut * 1.02 + 1e-12,
    }
    assert row["churn_ge_2x_within_2pct"], row
    return row


# ------------------------------------------------------------------- driver

def main(out_path=Path("BENCH_serve.json")):
    payload = {
        "bench": "psc_serve_engine",
        "schema": 2,
        "config": {"k": K, "solver": "newton", "newton_iters": 20,
                   "tcg_iters": 12, "p_target": 1.2},
        "stream": bench_stream(),
        "warm_vs_cold": bench_warm_vs_cold(),
        "churn": bench_churn(),
    }
    if out_path is not None:
        Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(json.dumps(payload, indent=2))
    return payload


if __name__ == "__main__":
    main()
