"""Generates the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json.  §Perf (the hillclimb log) is maintained by
hand in EXPERIMENTS.md between the AUTOGEN markers."""
from __future__ import annotations

import glob
import json
from pathlib import Path

from repro.launch.hlo_analysis import PEAK_FLOPS_BF16

V5E_HBM_GB = 16.0


def _advice(arch, shape, roof, bneck):
    if bneck == "collective":
        if "moe" in arch or "deepseek" in arch or "mixtral" in arch \
                or "jamba" in arch:
            return ("force bf16 activation/grad collectives + a2a expert "
                    "dispatch instead of replicated-x EP psum")
        return "cast-before-gather (bf16 FSDP all-gathers) + bf16 grad RS"
    if bneck == "memory":
        return ("shard attention/logits work over the idle model axis; "
                "bf16 intermediates in attention + chunked xent")
    return "increase per-chip batch or shrink the mesh (compute-saturated)"


def cell_rows(mesh="single"):
    rows = []
    for f in sorted(glob.glob(f"experiments/dryrun/*__{mesh}.json")):
        r = json.loads(Path(f).read_text())
        rows.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def dryrun_section(mesh="single"):
    out = [f"### Dry-run grid — {mesh} mesh "
           f"({'16x16=256' if mesh == 'single' else '2x16x16=512'} chips)",
           "",
           "| arch | shape | status | compile s | GB/device | fits v5e? | collective ops (AG/AR/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|"]
    for r in cell_rows(mesh):
        if r["status"] != "ok":
            tag = "SKIP" if str(r["status"]).startswith("skip") else "FAIL"
            reason = str(r["status"]).split(":", 1)[-1].strip()[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {tag}: {reason} | — | — | — | — |")
            continue
        gb = r.get("bytes_per_device", 0) / 1e9
        cc = r["collectives"]["op_counts"]
        ops = "/".join(str(cc.get(k, 0)) for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
        fits = "yes" if gb <= V5E_HBM_GB else f"NO ({gb:.0f} GB)"
        out.append(f"| {r['arch']} | {r['shape']} | ok | "
                   f"{r.get('compile_s', 0):.1f} | {gb:.1f} | {fits} | {ops} |")
    return "\n".join(out)


def roofline_section(mesh="single"):
    out = ["### Roofline terms — single-pod (256 chips), per step",
           "",
           "| arch | shape | t_compute s | t_memory s | t_collective s | bottleneck | roofline frac | useful FLOPs ratio | next move |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in cell_rows(mesh):
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        t_dom = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        t_ideal = ro["model_flops"] / (ro["n_chips"] * PEAK_FLOPS_BF16)
        frac = t_ideal / t_dom if t_dom else float("nan")
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.4f} | "
            f"{ro['t_memory_s']:.4f} | {ro['t_collective_s']:.4f} | "
            f"{ro['bottleneck']} | {frac:.3f} | "
            f"{ro['useful_flops_ratio']:.2f} | "
            f"{_advice(r['arch'], r['shape'], ro, ro['bottleneck'])} |")
    return "\n".join(out)


AUTOGEN_BEGIN = "<!-- AUTOGEN:BEGIN (benchmarks/experiments_md.py) -->"
AUTOGEN_END = "<!-- AUTOGEN:END -->"


def main():
    body = "\n\n".join([
        AUTOGEN_BEGIN,
        dryrun_section("single"),
        dryrun_section("multi"),
        roofline_section("single"),
        AUTOGEN_END,
    ])
    path = Path("EXPERIMENTS.md")
    if path.exists():
        text = path.read_text()
        if AUTOGEN_BEGIN in text and AUTOGEN_END in text:
            pre = text.split(AUTOGEN_BEGIN)[0]
            post = text.split(AUTOGEN_END)[1]
            path.write_text(pre + body + post)
            print("EXPERIMENTS.md autogen sections refreshed")
            return
        print("EXPERIMENTS.md exists without markers; printing to stdout")
        print(body)
        return
    path.write_text(body + "\n")
    print("EXPERIMENTS.md written (markers only)")


if __name__ == "__main__":
    main()
