"""Table I analog: RCut quality of Spec vs pMulti vs GrB-pGrass on
Delaunay graphs (same SuiteSparse family, reduced r for CPU walltime).

Paper reports RCut reduction (%) of pMulti and GrB-pGrass vs the Spec
baseline on delaunay_n16..n19; we reproduce the regime at r=9..11.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PSCConfig, p_spectral_cluster, spectral_cluster, solvers
from repro.graphs import delaunay_graph

K = 4


def p_multi_baseline(W, k, p=1.2, seed=0, iters=100):
    """The historical pMulti recipe (Luo et al. 2010) via the registry:
    p=2 LOBPCG start, ONE deflated inverse-power minimization at ``p``
    (no continuation), kmeans.  Replaces the deleted core.pmulti shim —
    same semantics, no private loop (DESIGN.md §3 migration table)."""
    import jax

    from repro.core import lobpcg, metrics
    from repro.core.psc import discretize

    cfg = PSCConfig(k=k, p_target=p, seed=seed, solver="inverse_power",
                    ipm_iters=iters)
    _, U2 = lobpcg.smallest_eigvecs(W, k, seed=seed)
    rep = solvers.minimize_at_p(W, U2, p, cfg)
    labels = discretize(rep.U, k, jax.random.PRNGKey(seed))
    return np.asarray(labels), float(metrics.rcut(W, labels, k))


def run(rs=(9, 10, 11), with_pmulti=True):
    rows = []
    for r in rs:
        W, _ = delaunay_graph(r, seed=0)
        t0 = time.time()
        _, rcut_spec = spectral_cluster(W, K, seed=0)
        t_spec = time.time() - t0

        t0 = time.time()
        res = p_spectral_cluster(W, PSCConfig(
            k=K, p_target=1.2, newton_iters=20, tcg_iters=12,
            kmeans_restarts=4, seed=0))
        t_pg = time.time() - t0

        rcut_pm, t_pm = float("nan"), float("nan")
        if with_pmulti:
            t0 = time.time()
            _, rcut_pm = p_multi_baseline(W, K, p=1.2, seed=0, iters=100)
            t_pm = time.time() - t0

        rows.append({
            "r": r, "n": W.n_rows, "nnz": W.nnz,
            "rcut_spec": rcut_spec, "rcut_pmulti": rcut_pm,
            "rcut_pgrass": res.rcut,
            "red_pmulti_pct": 100.0 * (rcut_pm - rcut_spec) / rcut_spec,
            "red_pgrass_pct": 100.0 * (res.rcut - rcut_spec) / rcut_spec,
            "t_spec_s": t_spec, "t_pmulti_s": t_pm, "t_pgrass_s": t_pg,
        })
    return rows


def main(csv=True):
    rows = run()
    out = []
    for row in rows:
        out.append(f"table1_rcut_del{row['r']}_spec,"
                   f"{row['t_spec_s']*1e6:.0f},rcut={row['rcut_spec']:.4f}")
        out.append(f"table1_rcut_del{row['r']}_pmulti,"
                   f"{row['t_pmulti_s']*1e6:.0f},"
                   f"rcut_delta={row['red_pmulti_pct']:+.2f}%")
        out.append(f"table1_rcut_del{row['r']}_pgrass,"
                   f"{row['t_pgrass_s']*1e6:.0f},"
                   f"rcut_delta={row['red_pgrass_pct']:+.2f}%")
    if csv:
        for line in out:
            print(line)
    return rows


if __name__ == "__main__":
    main()
