"""Batched serving engine: prefill once, decode step-by-step with a
static-shape KV cache; greedy or temperature sampling; per-request stop.

The decode step is one jit'd function reused every token (no
recompilation: positions is a traced input, the cache has static
max_len).  On a mesh, the same engine drives the sharded decode_step
lowered by the dry-run (sequence-sharded caches etc.).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    eos_id: Optional[int] = None
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 256,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos, mesh=mesh))
        self._prefill = jax.jit(
            lambda p, t, **kw: M.prefill(cfg, p, t, max_len, mesh=mesh, **kw),
            static_argnames=())

    def generate(self, tokens: np.ndarray, gen: GenerationConfig,
                 enc_frames=None, extra_embeds=None):
        """tokens: (B, S) prompt. Returns (B, max_new_tokens) int32."""
        B, S = tokens.shape
        assert S + gen.max_new_tokens <= self.max_len
        kw = {}
        if enc_frames is not None:
            kw["enc_frames"] = enc_frames
        if extra_embeds is not None:
            kw["extra_embeds"] = extra_embeds
        logits, cache, pos = self._prefill(self.params,
                                           jnp.asarray(tokens), **kw)
        key = jax.random.PRNGKey(gen.seed)
        out = []
        done = np.zeros(B, bool)
        cur = self._sample(logits[:, -1], gen, key)
        for i in range(gen.max_new_tokens):
            out.append(np.asarray(cur))
            if gen.eos_id is not None:
                done |= out[-1][:, 0] == gen.eos_id
                if done.all():
                    break
            positions = jnp.full((B, 1), pos + i, jnp.int32)
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur), positions)
            key, sub = jax.random.split(key)
            cur = self._sample(logits[:, -1], gen, sub)
        return np.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, gen: GenerationConfig, key):
        if gen.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        probs_logits = logits / gen.temperature
        return jax.random.categorical(key, probs_logits, axis=-1)[:, None] \
            .astype(jnp.int32)
