"""Shape-bucketed batching for the clustering serve engine (DESIGN.md §8).

jit compiles one executable per input *shape*, so a stream of
arbitrarily-sized graphs would retrace per request — the exact failure
mode the LLM serve engine avoids with its static-shape decode step.
The clustering analogue: quantize every request onto a small lattice of
(n, nnz, k) *buckets* (powers of two, floored), pad each graph's COO
triple up to its bucket, and vmap the whole SCF/Newton continuation
across a bucket so each bucket compiles exactly one trace no matter how
many requests it serves.

Padding is sound by the PR-5 contract: pad entries are (0, 0, 0.0) —
they self-reference an existing row with zero weight, so every segment
fold and every edge semiring contribution they generate is an exact
float zero (adding 0.0 to a float sum is bitwise exact).  Pad *rows*
(vertices n..n_b) are isolated: no edge touches them, embeddings keep
exact-zero rows through QR and Newton (reflector entries at zero rows
are 0), and the dense-eigh init pushes their Laplacian null-space to
the top of the spectrum with a large pad-diagonal shift so the
smallest-k selection never sees it.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import numpy as np

from repro.grblas.containers import SparseMatrix


def next_pow2(x: int, floor: int = 1) -> int:
    """Smallest power of two >= max(x, floor)."""
    v = max(int(x), int(floor), 1)
    return 1 << (v - 1).bit_length()


class BucketSpec(NamedTuple):
    """One compiled-trace signature of the batched solve: every graph
    padded to (n, nnz) with ``k`` clusters and ``mode`` ("cold" = full
    continuation from the p=2 init, "warm" = schedule tail from a cached
    embedding — separate trace signatures, separate lanes)."""

    n: int
    nnz: int
    k: int
    mode: str

    @property
    def key(self) -> tuple:
        return ("serve", self.mode, self.n, self.nnz, self.k)


def bucket_for(W: SparseMatrix, k: int, mode: str, min_n: int = 64,
               min_nnz: int = 128) -> BucketSpec:
    """The bucket a graph pads into: power-of-two (n, nnz) with floors,
    so the trace lattice stays logarithmic in graph size."""
    if W.n_rows != W.n_cols:
        raise ValueError("serve buckets hold square (graph) matrices")
    return BucketSpec(n=next_pow2(W.n_rows, min_n),
                      nnz=next_pow2(W.nnz, min_nnz), k=int(k), mode=mode)


class BucketBatch(NamedTuple):
    """Stacked padded COO triples for one bucket solve: everything the
    jitted batched step consumes, all static-shaped for the spec."""

    rows: np.ndarray      # (B, nnz_b) int32
    cols: np.ndarray      # (B, nnz_b) int32
    vals: np.ndarray      # (B, nnz_b) float
    mask: np.ndarray      # (B, n_b) 1.0 on real vertices, 0.0 on pads
    n_real: Tuple[int, ...]


def assemble_batch(graphs: Sequence[SparseMatrix], spec: BucketSpec
                   ) -> BucketBatch:
    """Pad every graph to the bucket and stack along a batch axis."""
    rows: List[np.ndarray] = []
    cols: List[np.ndarray] = []
    vals: List[np.ndarray] = []
    mask = np.zeros((len(graphs), spec.n), np.float32)
    for b, W in enumerate(graphs):
        r, c, v = W.padded_coo(spec.n, spec.nnz)
        rows.append(r)
        cols.append(c)
        vals.append(v)
        mask[b, :W.n_rows] = 1.0
    return BucketBatch(rows=np.stack(rows), cols=np.stack(cols),
                       vals=np.stack(vals).astype(np.float32), mask=mask,
                       n_real=tuple(W.n_rows for W in graphs))


def pad_embeddings(Us: Sequence[np.ndarray], spec: BucketSpec) -> np.ndarray:
    """Stack cached (n_i, k) embeddings into the bucket's (B, n_b, k)
    warm-start tensor, zero on pad rows (the exact-zero invariant the
    batched solve preserves)."""
    out = np.zeros((len(Us), spec.n, spec.k), np.float32)
    for b, U in enumerate(Us):
        U = np.asarray(U, np.float32)
        if U.shape[1] != spec.k or U.shape[0] > spec.n:
            raise ValueError(f"embedding {U.shape} does not fit bucket "
                             f"{(spec.n, spec.k)}")
        out[b, :U.shape[0]] = U
    return out
