from repro.serve.engine import ServeEngine, GenerationConfig

__all__ = ["ServeEngine", "GenerationConfig"]
