"""Serving layer: the LLM decode engine (``serve.engine``) and the
clustering-as-a-service PSC engine built on the same one-trace,
static-shape discipline (``serve.psc_engine``, DESIGN.md §8)."""
from repro.serve.engine import ServeEngine, GenerationConfig
from repro.serve.bucketing import (BucketSpec, assemble_batch, bucket_for,
                                   next_pow2)
from repro.serve.churn import EdgeDelta, apply_edge_delta, \
    incremental_recluster
from repro.serve.psc_engine import (ClusterServeEngine, EngineStats,
                                    ServeResult, ServeStats)
from repro.serve.warm_cache import CacheEntry, WarmCache

__all__ = [
    "ServeEngine", "GenerationConfig",
    "BucketSpec", "assemble_batch", "bucket_for", "next_pow2",
    "EdgeDelta", "apply_edge_delta", "incremental_recluster",
    "ClusterServeEngine", "EngineStats", "ServeResult", "ServeStats",
    "CacheEntry", "WarmCache",
]
