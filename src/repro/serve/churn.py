"""Incremental re-clustering under edge churn (DESIGN.md §8).

A deployed clustering index does not see fresh graphs — it sees the
*same* graph drifting: edges re-weighted, a few inserted or deleted.
Re-running the full pipeline per tick wastes everything the previous
solve learned.  This module turns a delta into the cheapest valid
re-solve:

  * weight-only deltas (every edited pair already in the pattern,
    including down-weighting to an explicit zero) — the pattern is
    untouched, so ``SparseMatrix.with_vals`` rebuilds the graph with
    zero host layout work and the cached embedding warm-starts the
    solver at the schedule tail;
  * pattern deltas (inserted pairs, or hard removals) — the graph is
    rebuilt, and on the multilevel path the cached hierarchy is
    *patched* (``coarsen.patch_hierarchy``: only vertices within
    distance 1 of a touched edge are re-matched, aggregates elsewhere
    are reused) before a refine-only V-cycle from the cached U
    (``vcycle.refine_cluster``).

The churn path never calls LOBPCG and never descends the p schedule
from 2 — that is where its speedup over from-scratch comes from.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.grblas.containers import SparseMatrix


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """A batch of undirected edge edits: pair (rows[i], cols[i]) gets
    weight ``vals[i]`` (0.0 = remove).  Each pair is applied to both
    directed copies; self-loops are rejected."""

    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray

    def __post_init__(self):
        r = np.asarray(self.rows, np.int64)
        c = np.asarray(self.cols, np.int64)
        v = np.asarray(self.vals, np.float64)
        if not (len(r) == len(c) == len(v)):
            raise ValueError("EdgeDelta arrays must have equal length")
        if (r == c).any():
            raise ValueError("EdgeDelta does not accept self-loops")
        object.__setattr__(self, "rows", r)
        object.__setattr__(self, "cols", c)
        object.__setattr__(self, "vals", v)

    @property
    def touched(self) -> np.ndarray:
        """Vertices incident to any edited pair (the patch_hierarchy
        dirty seed)."""
        return np.unique(np.concatenate([self.rows, self.cols]))


class DeltaResult(NamedTuple):
    W: SparseMatrix              # the edited graph
    touched: np.ndarray          # vertices incident to edits
    pattern_changed: bool        # False => with_vals fast path was taken


def _directed_keys(rows, cols, n_cols: int) -> np.ndarray:
    return rows.astype(np.int64) * n_cols + cols.astype(np.int64)


def apply_edge_delta(W: SparseMatrix, delta: EdgeDelta,
                     drop_removed: bool = False) -> DeltaResult:
    """Apply ``delta`` to ``W``.

    If every edited pair already exists in W's pattern and
    ``drop_removed`` is False, the edit is weights-only: the new graph
    shares every layout of W via ``with_vals`` (removals become explicit
    zeros — pad-sound by construction, and the pattern digest is
    unchanged so the warm cache sees a pattern-tier hit).  Otherwise the
    graph is rebuilt from the merged COO (insertions appended, removals
    dropped when ``drop_removed``).
    """
    if (delta.rows >= W.n_rows).any() or (delta.cols >= W.n_cols).any() \
            or (delta.rows < 0).any() or (delta.cols < 0).any():
        raise ValueError("EdgeDelta indices out of range")
    rows = np.asarray(W.rows, np.int64)
    cols = np.asarray(W.cols, np.int64)
    vals = np.asarray(W.vals).copy()
    # both directed copies of each undirected edit
    dr = np.concatenate([delta.rows, delta.cols])
    dc = np.concatenate([delta.cols, delta.rows])
    dv = np.concatenate([delta.vals, delta.vals])
    keys = _directed_keys(rows, cols, W.n_cols)        # sorted (from_coo)
    dkeys = _directed_keys(dr, dc, W.n_cols)
    pos = np.searchsorted(keys, dkeys)
    pos_c = np.minimum(pos, len(keys) - 1) if len(keys) else pos
    hit = np.zeros(len(dkeys), bool) if not len(keys) else \
        keys[pos_c] == dkeys
    touched = delta.touched
    removing = dv == 0.0

    if hit.all() and not (drop_removed and removing.any()):
        # -- weights-only fast path: same pattern, every layout reused.
        # Later edits of the same directed pair win (np scatter order).
        vals[pos_c[hit]] = dv[hit]
        return DeltaResult(W=W.with_vals(vals.astype(vals.dtype)),
                           touched=touched, pattern_changed=False)

    # -- pattern path: merge and rebuild.  Updates overwrite, inserts
    # append, removals drop their stored entries entirely.
    vals[pos_c[hit]] = dv[hit]
    keep = np.ones(len(keys), bool)
    if drop_removed:
        keep[pos_c[hit & removing]] = False
    ins = ~hit & ~removing
    r2 = np.concatenate([rows[keep], dr[ins]])
    c2 = np.concatenate([cols[keep], dc[ins]])
    v2 = np.concatenate([vals[keep], dv[ins]])
    W2 = SparseMatrix.from_coo(r2, c2, v2, (W.n_rows, W.n_cols),
                               dtype=W.vals.dtype)
    return DeltaResult(W=W2, touched=touched, pattern_changed=True)


def incremental_recluster(W_new: SparseMatrix, touched: np.ndarray,
                          pattern_changed: bool, U0: np.ndarray, cfg,
                          ml=None, hierarchy=None
                          ) -> Tuple[object, Optional[object], list]:
    """Re-cluster the edited graph from the cached embedding ``U0``.

    Flat path (``ml`` is None): warm re-entry of the solver registry at
    the schedule tail via ``PSCConfig.init_U``.  Multilevel path: patch
    the cached hierarchy against ``W_new`` — the dirty seed is empty for
    weight-only deltas, so every aggregate is reused and only the
    Galerkin products rebuild — then run the refine-only V-cycle.

    Returns (PSCResult, new hierarchy or None, patch records).
    """
    import dataclasses as _dc

    from repro.core import psc as _psc

    if ml is None:
        warm_cfg = _dc.replace(cfg, init_U=np.asarray(U0), multilevel=None)
        return _psc.p_spectral_cluster(W_new, warm_cfg), None, []

    from repro.multilevel import (build_hierarchy, patch_hierarchy,
                                  refine_cluster)
    from repro.multilevel.vcycle import _layout_kwargs

    records: list = []
    if hierarchy is None:
        hierarchy = build_hierarchy(
            W_new, coarse_size=ml.coarse_size, max_levels=ml.max_levels,
            min_reduction=ml.min_reduction, rounds=ml.match_rounds,
            layout_kwargs=_layout_kwargs(cfg), sparsify=ml.sparsify,
            max_agg=ml.match_max_agg)
    else:
        seed = touched if pattern_changed else np.empty(0, np.int64)
        hierarchy, records = patch_hierarchy(
            hierarchy, W_new, seed, rounds=ml.match_rounds,
            max_agg=ml.match_max_agg, layout_kwargs=_layout_kwargs(cfg),
            sparsify=ml.sparsify)
    flat_cfg = _dc.replace(cfg, multilevel=None)
    res = refine_cluster(W_new, flat_cfg, ml, hierarchy, U0)
    return res, hierarchy, records
