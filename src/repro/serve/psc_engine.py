"""Clustering-as-a-service: the batched, warm-started PSC serve engine
(DESIGN.md §8).

``serve/engine.py`` serves an LLM by compiling ONE static-shape decode
step and reusing it for every token of every request.  This module is
the clustering analogue for a stream of graph requests:

  * **shape-bucketed batching** — requests pad onto a power-of-two
    (n, nnz, k) bucket lattice (``serve.bucketing``) and the whole
    SCF/Newton p-continuation runs ``jax.vmap``-ed across a bucket, so
    each bucket compiles exactly one trace no matter how many requests
    it serves.  The per-bucket jitted solve is memoized through the
    solver registry's trace scaffolding (``registry.memoized`` /
    ``mark_trace``), so retraces are observable the same way the Newton
    driver's are.
  * **warm-start cache** — an LRU on graph fingerprints
    (``serve.warm_cache``).  A hit skips the p=2 eigensolve and the
    continuation descent entirely: the cached embedding re-enters the
    registry at the END of the p schedule (``solvers.warm_start`` — the
    nonlinear lift of ``lobpcg.smallest_eigvecs``' X0 substrate).
  * **incremental re-clustering** — ``update()`` takes an
    :class:`~repro.serve.churn.EdgeDelta` against a previously served
    graph: weight-only deltas ride ``with_vals`` + a warm solve;
    pattern deltas patch the cached multilevel hierarchy and run a
    refine-only V-cycle (``serve.churn``).
  * **admission + metrics** — a request queue with per-bucket batch
    assembly under a max-wait deadline, per-request :class:`ServeStats`
    (queue time, solve time, cache tier, trace reuse) and engine-level
    throughput counters.

Graphs larger than the bucket lattice (``max_bucket_n``) take the
*solo* lane: the flat (or multilevel) pipeline per request — the same
warm-start and churn machinery applies, only unbatched.

Determinism contract: a bucketed solve discretizes with the flat
pipeline's exact stage-3 key (``psc.stage_keys`` / ``psc.discretize``)
and computes RCut on the caller's ORIGINAL graph, so a padded, batched
request returns the same labels as ``p_spectral_cluster`` on the bare
graph (pinned by tests/test_psc_serve.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import metrics, plap
from repro.core import psc as _psc
from repro.core.grassmann import rtr_minimize
from repro.core.psc import PSCConfig
from repro.core.solvers import registry
from repro.core.solvers.guard import SolverDivergence
from repro.grblas.api import Descriptor
from repro.grblas.backends import BackendUnavailableError
from repro.grblas.containers import GraphFingerprint, SparseMatrix
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.serve.bucketing import (BucketBatch, BucketSpec, assemble_batch,
                                   bucket_for, pad_embeddings)
from repro.serve.churn import EdgeDelta, apply_edge_delta, \
    incremental_recluster
from repro.serve.warm_cache import CacheEntry, WarmCache

# Spectral shift applied to pad-vertex diagonals in the batched dense
# eigensolves: isolated pad rows contribute extra Laplacian null-space,
# and this pushes it far above any graph eigenvalue so the smallest-k
# Ritz selection only ever sees the real spectrum.
_PAD_SHIFT = 1.0e6

_COO = Descriptor(backend="coo")

# Fault-injection seams (repro.testing.faultinject, DESIGN.md §9): when
# set, called right before a bucket batch solve / a churn re-solve.
# Raising from them exercises the quarantine-bisect and retry paths
# deterministically; production leaves them None.
_SOLVE_FAULT = None     # fn(pends: List[_Pending]) -> None
_CHURN_FAULT = None     # fn(pend: _Pending, attempt: int) -> None


# --------------------------------------------------------------- stats types

@dataclasses.dataclass
class ServeStats:
    """Per-request accounting, returned alongside every result."""

    req_id: int
    n: int
    nnz: int
    k: int
    lane: str                    # "bucket" | "solo"
    mode: str                    # "cold" | "warm" | "churn"
    cache_tier: Optional[str]    # None | "exact" | "pattern"
    bucket: Optional[tuple]      # BucketSpec key (bucket lane only)
    batch_size: int
    queue_s: float
    solve_s: float
    trace_new: bool              # this request's batch compiled a new trace
    p_final: float
    # resilience accounting (DESIGN.md §9) — defaulted for back-compat
    degrade: int = 0             # 0 none | 1 schedule-tail-only | 2 p=2-init
    retries: int = 0             # churn-path retry count before success
    failure_kind: Optional[str] = None   # taxonomy key (failed requests)
    error: Optional[str] = None          # human-readable failure detail


@dataclasses.dataclass
class ServeResult:
    req_id: int
    labels: np.ndarray
    U: np.ndarray
    rcut: float
    ncut: float
    stats: ServeStats
    # failed requests carry the structured error here (labels/U None,
    # rcut/ncut NaN); healthy requests leave it None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class _Pending:
    req_id: int
    W: SparseMatrix
    k: int
    fp: GraphFingerprint
    spec: Optional[BucketSpec]
    mode: str                       # "cold" | "warm"
    cache_tier: Optional[str]
    warm_U: Optional[np.ndarray]
    arrival: float
    churn: bool = False
    touched: Optional[np.ndarray] = None
    pattern_changed: bool = False
    hierarchy: object = None
    degrade: int = 0                # deadline degradation level (0/1/2)


# ------------------------------------------------------ batched solver build

def _dense_smallest(L: jnp.ndarray, mask: jnp.ndarray, k: int):
    """Smallest-k eigenvectors of a padded dense operator: pad diagonals
    get the ``_PAD_SHIFT`` so the isolated-vertex null-space sorts above
    every real eigenvalue; pad rows of the result are re-zeroed (eigh
    leaves only FP dust there) to restore the exact-zero invariant."""
    L = L + jnp.diag((1.0 - mask) * _PAD_SHIFT)
    _, evecs = jnp.linalg.eigh(L)
    return evecs[:, :k] * mask[:, None]


def _batched_init(W: SparseMatrix, mask: jnp.ndarray, k: int, cfg):
    """Stage 1 of the flat pipeline, batched: the dense-eigh path of
    ``lobpcg.smallest_eigvecs`` (buckets are capped at the same n where
    the flat solver itself goes dense, so the two paths mirror)."""
    dense = W.to_dense()
    deg = jnp.sum(dense, axis=1)
    L = jnp.diag(deg) - dense
    if cfg.normalized_init:
        dih = jax.lax.rsqrt(jnp.maximum(deg, 1e-12))
        L = dih[:, None] * L * dih[None, :]
    U = _dense_smallest(L, mask, k)
    return jnp.linalg.qr(U)[0]


def _make_level_step(cfg):
    """One continuation level of the batched solve: (W, mask, U, p) ->
    (U', fval), traceable end to end (vmap/scan-safe).

    newton: ``rtr_minimize`` verbatim — its lax.while_loop batches with
    per-element semantics, so each graph in the bucket keeps its own
    trust-region trajectory.  scf: fixed-sweep IRLS with a per-element
    convergence freeze (a converged element stops updating, matching the
    host driver's early exit) and the dense eigensolve of the flat
    ≤1024-vertex path."""
    eps = cfg.eps
    if cfg.solver == "newton":
        hvp = (plap.hess_eta_graphblas if cfg.hvp_mode == "graphblas"
               else plap.hess_eta_matrix_free)

        def step(W, mask, U, p):
            f = lambda V: plap.value(W, V, p, eps, desc=_COO)
            g = lambda V: plap.euc_grad(W, V, p, eps, desc=_COO)
            h = lambda V, eta: hvp(W, V, eta, p, eps, desc=_COO)
            res = rtr_minimize(f, g, h, U, max_iters=cfg.newton_iters,
                               tcg_iters=cfg.tcg_iters,
                               grad_tol=cfg.grad_tol)
            return res.U, res.fval

        return step

    if cfg.solver == "scf":
        sweeps, tol = max(int(cfg.scf_sweeps), 1), cfg.scf_tol

        def step(W, mask, U, p):
            k = U.shape[-1]

            def sweep(carry, _):
                U, done = carry
                d = U[W.rows] - U[W.cols]
                g2 = jnp.sum(d * d, axis=-1)
                what = W.vals * (g2 + eps) ** ((p - 2.0) / 2.0)
                dense = jnp.zeros((W.n_rows, W.n_rows), U.dtype
                                  ).at[W.rows, W.cols].add(what)
                L = jnp.diag(jnp.sum(dense, axis=1)) - dense
                V = jnp.linalg.qr(_dense_smallest(L, mask, k))[0]
                drift = k - jnp.sum((V.T @ U) ** 2)
                U = jnp.where(done, U, V)
                return (U, done | (drift < tol)), None

            (U, _), _ = jax.lax.scan(sweep, (U, False), None, length=sweeps)
            return U, plap.value(W, U, p, eps, desc=_COO)

        return step

    raise ValueError(
        f"bucket lane supports solvers 'newton' and 'scf', not "
        f"{cfg.solver!r} (route larger drivers through the solo lane)")


def _solver_sig(cfg) -> tuple:
    return (cfg.solver, cfg.hvp_mode, cfg.eps, cfg.newton_iters,
            cfg.tcg_iters, cfg.grad_tol, cfg.scf_sweeps, cfg.scf_tol,
            cfg.normalized_init, cfg.p_target, cfg.p_factor,
            cfg.warm_p_steps)


def _bucket_solver(spec: BucketSpec, cfg):
    """The memoized jitted batched solve for one bucket spec.

    Cold: dense p=2 init + lax.scan over the full continuation schedule
    (p traced per scan step, static length).  Warm: scan over the last
    ``cfg.warm_p_steps`` schedule values from the supplied embeddings.
    Exactly one trace per (spec, solver signature) — ``mark_trace``
    lands the key in ``registry.SOLVER_TRACES`` so tests and the bench
    can assert trace reuse across a mixed request stream."""
    key = spec.key + _solver_sig(cfg)

    def build():
        if spec.mode == "cold":
            ps = jnp.asarray(registry.p_schedule(cfg), jnp.float32)
        else:
            tail = registry.p_schedule(cfg)[-max(int(cfg.warm_p_steps), 1):]
            ps = jnp.asarray(tail, jnp.float32)
        step = _make_level_step(cfg)
        n_b, nnz_b, k = spec.n, spec.nnz, spec.k

        def one(rows, cols, vals, mask, U0):
            W = SparseMatrix(n_rows=n_b, n_cols=n_b, nnz=nnz_b,
                             rows=rows, cols=cols, vals=vals)
            if spec.mode == "cold":
                U = _batched_init(W, mask, k, cfg)
            else:
                U = jnp.linalg.qr(U0 * mask[:, None])[0]

            def body(U, p):
                U2, fv = step(W, mask, U, p)
                return U2, fv

            U, fvals = jax.lax.scan(body, U, ps)
            return U, fvals

        def solve(rows, cols, vals, mask, U0):
            registry.mark_trace(key)
            return jax.vmap(one)(rows, cols, vals, mask, U0)

        return jax.jit(solve)

    return registry.memoized(key, build), key


# ------------------------------------------------------------------- engine

class EngineStats:
    """Engine-level counters — live *views* over the engine's
    :class:`~repro.obs.metrics.MetricsRegistry` (DESIGN.md §10).

    Historically a dataclass of plain ints incremented beside the
    cache's own counters (two sets of books).  Every counter attribute
    now reads through to one metric family, and ``stats.field += 1``
    still works — the property setter forwards the delta to the
    underlying monotonic counter — so call sites and external readers
    are unchanged.  ``n_failed`` / ``failures`` both derive from the
    single labeled ``serve_failed_total`` family and can never
    disagree.  ``solve_s`` / ``graphs_per_s`` stay plain floats (they
    are derived timings, not monotonic counts).
    """

    # attribute -> counter family backing it
    _VIEWS = {
        "n_requests": "serve_requests_total",
        "n_results": "serve_results_total",
        "n_batches": "serve_batches_total",
        "n_solo": "serve_solo_total",
        "n_churn": "serve_churn_total",
        "traces": "serve_traces_total",          # serve-lane compiles
        "n_degraded": "serve_degraded_total",    # served at degrade >= 1
        "n_retried": "serve_churn_retries_total",
        "n_quarantined": "serve_quarantined_total",
        "n_quarantine_splits": "serve_quarantine_splits_total",
    }

    def __init__(self, registry: "_obs_metrics.MetricsRegistry" = None):
        self.registry = registry if registry is not None \
            else _obs_metrics.MetricsRegistry()
        self.solve_s = 0.0
        self.graphs_per_s = 0.0

    def record_failure(self, kind: str) -> None:
        """The one write path for the failure taxonomy."""
        self.registry.counter("serve_failed_total", kind=kind).inc()

    @property
    def n_failed(self) -> int:
        """Requests that returned a structured error (any kind)."""
        return int(self.registry.total("serve_failed_total"))

    @property
    def failures(self) -> Dict[str, int]:
        """Failure-taxonomy histogram (DESIGN.md §9), reconstructed
        from the ``kind`` label of ``serve_failed_total``."""
        vals = self.registry.labeled_values("serve_failed_total", "kind")
        return {k: int(v) for k, v in vals.items()}

    def as_dict(self) -> dict:
        out = {name: getattr(self, name)
               for name in ("n_requests", "n_results", "n_batches",
                            "n_solo", "n_churn", "traces")}
        out["solve_s"] = self.solve_s
        out["graphs_per_s"] = self.graphs_per_s
        for name in ("n_failed", "n_degraded", "n_retried",
                     "n_quarantined", "n_quarantine_splits"):
            out[name] = getattr(self, name)
        out["failures"] = self.failures
        return out

    def exposition(self) -> str:
        """Prometheus text exposition of the whole engine registry."""
        return self.registry.exposition()


def _stat_view(metric: str) -> property:
    def fget(self):
        return int(self.registry.value(metric))

    def fset(self, value):
        self.registry.counter(metric).inc(value - self.registry.value(metric))

    return property(fget, fset)


for _field, _metric in EngineStats._VIEWS.items():
    setattr(EngineStats, _field, _stat_view(_metric))
del _field, _metric


def _classify(err) -> str:
    """Failure-taxonomy key of an exception (DESIGN.md §9)."""
    if isinstance(err, BackendUnavailableError):
        return "backend_error"
    if isinstance(err, SolverDivergence):
        return "solver_divergence"
    from repro.graphs.validate import GraphValidationError

    if isinstance(err, GraphValidationError):
        return "invalid_input"
    if isinstance(err, BaseException):
        return "exception"
    return "nonfinite_result"


class ClusterServeEngine:
    """Batched, warm-started p-spectral clustering server.

    >>> eng = ClusterServeEngine(PSCConfig(k=4))
    >>> rid = eng.submit(W)
    >>> res = eng.flush()[rid]           # labels, rcut, ServeStats

    ``submit`` enqueues; batches launch when a bucket fills to
    ``max_batch`` or its oldest request has waited ``max_wait_s``
    (``poll`` drives the clock; ``flush`` drains everything).  Requests
    above ``max_bucket_n`` vertices run the solo lane — the flat
    pipeline, or the multilevel V-cycle when ``ml`` is given, with the
    same cache semantics.
    """

    def __init__(self, cfg: Optional[PSCConfig] = None, *,
                 cache_capacity: int = 64, max_batch: int = 8,
                 max_wait_s: float = 0.05, max_bucket_n: int = 1024,
                 min_bucket_n: int = 64, min_bucket_nnz: int = 128,
                 ml=None, weight_quant: float = 1e-6,
                 deadline_s: Optional[float] = None,
                 tail_frac: float = 0.5, churn_retries: int = 2,
                 retry_backoff_s: float = 0.01,
                 validate_inputs: bool = False):
        self.cfg = cfg if cfg is not None else PSCConfig()
        if self.cfg.reorder != "none":
            raise ValueError("the serve engine owns vertex order; use "
                             "reorder='none' in the template config")
        # one registry for engine + cache: EngineStats and
        # WarmCache.stats() are views over it, never separate books
        self.metrics = _obs_metrics.MetricsRegistry()
        self.cache = WarmCache(cache_capacity, metrics=self.metrics)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.max_bucket_n = int(max_bucket_n)
        self.min_bucket_n = int(min_bucket_n)
        self.min_bucket_nnz = int(min_bucket_nnz)
        self.ml = ml
        self.weight_quant = float(weight_quant)
        # resilience knobs (DESIGN.md §9): a request older than
        # ``tail_frac * deadline_s`` degrades to a schedule-tail-only
        # solve (level 1); older than ``deadline_s`` to p=2-init labels
        # (level 2).  Churn re-solves retry ``churn_retries`` times with
        # exponential backoff before falling back to a cold solve.
        self.deadline_s = None if deadline_s is None else float(deadline_s)
        self.tail_frac = float(tail_frac)
        self.churn_retries = int(churn_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.validate_inputs = bool(validate_inputs)
        self._sleep = time.sleep          # test seam (no real sleeps)
        self._buckets: Dict[tuple, List[_Pending]] = {}
        self._solo: List[_Pending] = []
        self._results: Dict[int, ServeResult] = {}
        self._next_id = 0
        self.stats = EngineStats(self.metrics)
        self._bucketable = self.cfg.solver in ("newton", "scf")

    def exposition(self) -> str:
        """Prometheus text exposition of the engine's registry (engine
        counters + warm-cache counters + queue/occupancy instruments)."""
        return self.metrics.exposition()

    def _note_queue(self) -> None:
        depth = sum(len(q) for q in self._buckets.values()) + len(self._solo)
        self.metrics.gauge("serve_queue_depth").set(depth)

    # ------------------------------------------------------------ admission

    def submit(self, W: SparseMatrix, k: Optional[int] = None) -> int:
        """Enqueue a clustering request; returns its request id."""
        return self._admit(W, k=k)

    def update(self, base: SparseMatrix, delta: EdgeDelta,
               k: Optional[int] = None) -> int:
        """Enqueue an incremental re-cluster of ``base`` under ``delta``.

        With a cached solve of ``base`` this is the churn fast path
        (warm solve on the edited weights; hierarchy patch + refine-only
        V-cycle on the solo/multilevel lane).  Without one it degrades
        to a cold solve of the edited graph."""
        d = apply_edge_delta(base, delta)
        base_fp = base.fingerprint(self.weight_quant)
        entry = self.cache.peek(base_fp)
        return self._admit(d.W, k=k, churn=True, churn_entry=entry,
                           touched=d.touched,
                           pattern_changed=d.pattern_changed)

    def _admit(self, W: SparseMatrix, k: Optional[int], churn: bool = False,
               churn_entry: Optional[CacheEntry] = None,
               touched=None, pattern_changed: bool = False) -> int:
        k = int(k) if k is not None else self.cfg.k
        if k < 1 or k > max(W.n_rows, 1):
            raise ValueError(f"k={k} invalid for an n={W.n_rows} graph "
                             f"(need 1 <= k <= n)")
        rid = self._next_id
        self._next_id += 1
        self.stats.n_requests += 1
        if self.validate_inputs:
            from repro.graphs.validate import quick_check

            issue = quick_check(W)
            if issue is not None:
                # reject at admission: the request gets its structured
                # error immediately and never reaches a batch
                pend = _Pending(req_id=rid, W=W, k=k, fp=None, spec=None,
                                mode="cold", cache_tier=None, warm_U=None,
                                arrival=time.monotonic(), churn=churn)
                self._fail(pend, issue, kind="invalid_input",
                           lane="admission")
                return rid
        fp = W.fingerprint(self.weight_quant)

        if churn:
            tier, warm_U, hier = None, None, None
            if churn_entry is not None and len(churn_entry.labels) == W.n_rows:
                tier, warm_U = "exact", churn_entry.U
                hier = churn_entry.hierarchy
            mode = "warm" if warm_U is not None else "cold"
        else:
            entry, tier = self.cache.lookup(fp)
            warm_U = entry.U if entry is not None else None
            hier = entry.hierarchy if entry is not None else None
            if warm_U is not None and len(warm_U) != W.n_rows:
                warm_U, tier, hier = None, None, None   # size collision
            mode = "warm" if warm_U is not None else "cold"

        pend = _Pending(req_id=rid, W=W, k=k, fp=fp, spec=None, mode=mode,
                        cache_tier=tier, warm_U=warm_U,
                        arrival=time.monotonic(), churn=churn,
                        touched=touched, pattern_changed=pattern_changed,
                        hierarchy=hier)
        # k == 1 / k == n requests ride the solo lane: the pipeline
        # answers them in closed form there, while the batched bucket
        # solve assumes a proper 1 < k < n eigenproblem
        if self._bucketable and W.n_rows <= self.max_bucket_n \
                and 1 < k < W.n_rows \
                and not (churn and self.ml is not None):
            spec = bucket_for(W, k, mode, self.min_bucket_n,
                              self.min_bucket_nnz)
            pend.spec = spec
            self._buckets.setdefault(spec.key, []).append(pend)
        else:
            self._solo.append(pend)
        self._note_queue()
        return rid

    # ------------------------------------------------------------- draining

    def poll(self, now: Optional[float] = None) -> Dict[int, ServeResult]:
        """Launch every due batch (bucket full, or oldest request past
        the max-wait deadline) and all solo requests; return results
        completed so far (cumulative)."""
        now = time.monotonic() if now is None else now
        self._apply_deadlines(now)
        for bkey in list(self._buckets):
            q = self._buckets[bkey]
            while q and (len(q) >= self.max_batch
                         or now - q[0].arrival >= self.max_wait_s):
                take, self._buckets[bkey] = q[:self.max_batch], \
                    q[self.max_batch:]
                q = self._buckets[bkey]
                self._run_bucket(take)
            if not q:
                del self._buckets[bkey]
        while self._solo:
            self._run_solo(self._solo.pop(0))
        self._note_queue()
        return dict(self._results)

    def flush(self) -> Dict[int, ServeResult]:
        """Drain every queued request regardless of deadlines."""
        self._apply_deadlines(time.monotonic())
        for bkey in list(self._buckets):
            q = self._buckets.pop(bkey)
            for i in range(0, len(q), self.max_batch):
                self._run_bucket(q[i:i + self.max_batch])
        while self._solo:
            self._run_solo(self._solo.pop(0))
        self._note_queue()
        return dict(self._results)

    def serve(self, graphs, k: Optional[int] = None) -> List[ServeResult]:
        """Convenience batch API: submit everything, flush, return
        results in submission order."""
        rids = [self.submit(W, k=k) for W in graphs]
        done = self.flush()
        return [done[r] for r in rids]

    def take(self, req_id: int) -> ServeResult:
        return self._results.pop(req_id)

    # ------------------------------------------------------------ deadlines

    def _degrade_level(self, elapsed: float) -> int:
        """0 = full solve, 1 = schedule-tail-only (p=2 eigensolve + one
        tail step), 2 = p=2-init labels (classical spectral, no
        continuation) — degrade instead of missing the deadline."""
        if self.deadline_s is None:
            return 0
        if elapsed >= self.deadline_s:
            return 2
        if elapsed >= self.tail_frac * self.deadline_s:
            return 1
        return 0

    def _apply_deadlines(self, now: float) -> None:
        """Move deadline-pressed cold bucket requests to the solo lane
        with their degrade level pinned (a degraded solve has a
        different schedule, so it can't share the bucket's trace)."""
        if self.deadline_s is None:
            return
        for bkey in list(self._buckets):
            keep: List[_Pending] = []
            for pend in self._buckets[bkey]:
                lvl = self._degrade_level(now - pend.arrival)
                if lvl > 0 and pend.mode == "cold" and not pend.churn:
                    pend.degrade = lvl
                    pend.spec = None
                    self._solo.append(pend)
                else:
                    keep.append(pend)
            if keep:
                self._buckets[bkey] = keep
            else:
                del self._buckets[bkey]

    # ------------------------------------------------------------ execution

    def _fail(self, pend: _Pending, err, *, kind: str, lane: str) -> None:
        """Record a structured per-request failure: the request resolves
        (poll/flush/take all see it) with ``error`` set and no labels —
        it never poisons its batch neighbors and never enters the
        cache."""
        msg = f"{type(err).__name__}: {err}" if isinstance(
            err, BaseException) else str(err)
        st = ServeStats(
            req_id=pend.req_id, n=pend.W.n_rows, nnz=pend.W.nnz, k=pend.k,
            lane=lane, mode="churn" if pend.churn else pend.mode,
            cache_tier=pend.cache_tier,
            bucket=pend.spec.key if pend.spec else None, batch_size=0,
            queue_s=time.monotonic() - pend.arrival, solve_s=0.0,
            trace_new=False, p_final=float("nan"), degrade=pend.degrade,
            failure_kind=kind, error=msg)
        self._results[pend.req_id] = ServeResult(
            req_id=pend.req_id, labels=None, U=None, rcut=float("nan"),
            ncut=float("nan"), stats=st, error=msg)
        self.stats.n_results += 1
        self.stats.record_failure(kind)
        _obs_trace.ACTIVE.instant("serve.fail", cat="serve",
                                  req_id=pend.req_id, kind=kind, lane=lane)

    def _solve_bucket(self, pends: List[_Pending], spec) -> tuple:
        """The batched solve itself (no per-request error handling —
        ``_run_bucket`` owns quarantine)."""
        t0 = time.monotonic()
        solver, key = _bucket_solver(spec, self.cfg)
        n_traces0 = sum(1 for t in registry.SOLVER_TRACES if t == key)
        if _SOLVE_FAULT is not None:
            _SOLVE_FAULT(pends)
        batch: BucketBatch = assemble_batch([p.W for p in pends], spec)
        if spec.mode == "warm":
            U0 = pad_embeddings([p.warm_U for p in pends], spec)
        else:
            U0 = np.zeros((len(pends), spec.n, spec.k), np.float32)
        # pad the batch axis to max_batch (replicating the last request's
        # lanes) so a partial batch reuses the full batch's trace — the
        # one-trace-per-bucket guarantee holds for deadline launches too
        fill = self.max_batch - len(pends)

        def _fill(a):
            return a if fill <= 0 else \
                np.concatenate([a, np.repeat(a[-1:], fill, axis=0)])

        with _obs_trace.ACTIVE.span("serve.bucket_solve", cat="serve",
                                    bucket=str(spec.key), mode=spec.mode,
                                    batch=len(pends), n=spec.n,
                                    nnz=spec.nnz, k=spec.k) as sp:
            U, fvals = solver(jnp.asarray(_fill(batch.rows)),
                              jnp.asarray(_fill(batch.cols)),
                              jnp.asarray(_fill(batch.vals)),
                              jnp.asarray(_fill(batch.mask)),
                              jnp.asarray(_fill(U0)))
            sp.fence(U)
            trace_new = sum(1 for t in registry.SOLVER_TRACES if t == key) \
                > n_traces0
            sp.set(trace_new=trace_new)
        U = np.asarray(U)
        return U, trace_new, time.monotonic() - t0

    def _run_bucket(self, pends: List[_Pending]) -> None:
        spec = pends[0].spec
        self.metrics.histogram("serve_batch_occupancy",
                               buckets=(1, 2, 4, 8, 16, 32)
                               ).observe(len(pends))
        try:
            U, trace_new, solve_s = self._solve_bucket(pends, spec)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as exc:            # noqa: BLE001 — quarantined
            if len(pends) == 1:
                # bisection bottomed out: THIS request is the poison
                self.stats.n_quarantined += 1
                self._fail(pends[0], exc, kind=_classify(exc),
                           lane="bucket")
                return
            # a thrown batch solve names no culprit: bisect — survivors
            # re-run, the poisoned half recurses down to one request
            self.stats.n_quarantine_splits += 1
            _obs_trace.ACTIVE.instant("serve.quarantine_split", cat="serve",
                                      batch=len(pends),
                                      bucket=str(spec.key))
            mid = len(pends) // 2
            self._run_bucket(pends[:mid])
            self._run_bucket(pends[mid:])
            return
        if trace_new:
            self.stats.traces += 1
        self.stats.n_batches += 1
        self.stats.solve_s += solve_s
        p_final = float(registry.p_schedule(self.cfg)[-1])
        for b, pend in enumerate(pends):
            Ub = U[b, :pend.W.n_rows]
            if not np.isfinite(Ub).all():
                # vmap lanes are numerically independent, so a NaN here
                # is THIS request's own divergence (bad weights, solver
                # blow-up) — quarantine it, neighbors are untouched
                self.stats.n_quarantined += 1
                self._fail(pend, "non-finite embedding from the batched "
                                 "solve (request-local divergence)",
                           kind="nonfinite_result", lane="bucket")
                continue
            self._finish(pend, Ub, lane="bucket", batch_size=len(pends),
                         solve_s=solve_s, trace_new=trace_new,
                         p_final=p_final, hierarchy=None)

    def _churn_solve(self, pend: _Pending, cfg) -> tuple:
        """The churn re-solve with retry-with-backoff: transient faults
        (a flaky backend, a mid-flight divergence) retry up to
        ``churn_retries`` times; exhaustion falls back to a cold solve
        of the edited graph (correct, just slower)."""
        last = None
        for attempt in range(self.churn_retries + 1):
            try:
                if _CHURN_FAULT is not None:
                    _CHURN_FAULT(pend, attempt)
                res, hierarchy, _ = incremental_recluster(
                    pend.W, pend.touched, pend.pattern_changed,
                    pend.warm_U, cfg, ml=self.ml,
                    hierarchy=pend.hierarchy)
                return res, hierarchy, attempt
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as exc:        # noqa: BLE001 — retried
                last = exc
                if attempt < self.churn_retries:
                    self.stats.n_retried += 1
                    _obs_trace.ACTIVE.instant(
                        "serve.retry", cat="serve", req_id=pend.req_id,
                        attempt=attempt, error=type(exc).__name__)
                    self._sleep(self.retry_backoff_s * (2.0 ** attempt))
        # retries exhausted: cold-solve the edited graph from scratch
        cold = dataclasses.replace(cfg, init_U=None,
                                   multilevel=self.ml)
        try:
            res = _psc.p_spectral_cluster(pend.W, cold)
        except Exception:
            raise last if last is not None else RuntimeError(
                "churn fallback failed")
        return res, None, self.churn_retries + 1

    def _run_solo(self, pend: _Pending) -> None:
        with _obs_trace.ACTIVE.span(
                "serve.solo_solve", cat="serve", req_id=pend.req_id,
                n=pend.W.n_rows, nnz=pend.W.nnz, k=pend.k,
                mode="churn" if pend.churn else pend.mode) as sp:
            self._run_solo_impl(pend, sp)

    def _run_solo_impl(self, pend: _Pending, sp) -> None:
        t0 = time.monotonic()
        self.stats.n_solo += 1
        cfg = dataclasses.replace(self.cfg, k=pend.k)
        hierarchy = None
        retries = 0
        if self.deadline_s is not None and not pend.churn \
                and pend.mode == "cold":
            pend.degrade = max(pend.degrade,
                               self._degrade_level(t0 - pend.arrival))
        sp.set(degrade=pend.degrade)
        try:
            if pend.churn and pend.warm_U is not None:
                res, hierarchy, retries = self._churn_solve(pend, cfg)
            elif pend.degrade == 2:
                # level 2: p=2-init labels — one eigensolve, no descent
                from repro.core import lobpcg

                _, U0 = lobpcg.smallest_eigvecs(
                    pend.W, pend.k, normalized=cfg.normalized_init,
                    seed=cfg.seed)
                self.stats.n_degraded += 1
                _obs_trace.ACTIVE.instant("serve.degrade", cat="serve",
                                          req_id=pend.req_id, level=2)
                solve_s = time.monotonic() - t0
                self.stats.solve_s += solve_s
                self._finish(pend, np.asarray(jnp.linalg.qr(U0)[0]),
                             lane="solo", batch_size=1, solve_s=solve_s,
                             trace_new=False, p_final=2.0, hierarchy=None)
                return
            else:
                if pend.degrade == 1:
                    # level 1: schedule tail only — p=2 eigensolve in,
                    # one warm step at p_target out
                    from repro.core import lobpcg

                    _, U0 = lobpcg.smallest_eigvecs(
                        pend.W, pend.k, normalized=cfg.normalized_init,
                        seed=cfg.seed)
                    cfg = dataclasses.replace(
                        cfg, init_U=np.asarray(jnp.linalg.qr(U0)[0]),
                        warm_p_steps=1, multilevel=None)
                    self.stats.n_degraded += 1
                    _obs_trace.ACTIVE.instant("serve.degrade", cat="serve",
                                              req_id=pend.req_id, level=1)
                elif pend.warm_U is not None:
                    cfg = dataclasses.replace(cfg, init_U=pend.warm_U,
                                              multilevel=None)
                elif self.ml is not None:
                    cfg = dataclasses.replace(cfg, multilevel=self.ml)
                res = _psc.p_spectral_cluster(pend.W, cfg)
                if self.ml is not None and pend.warm_U is None \
                        and pend.degrade == 0:
                    # keep the hierarchy for future churn ticks
                    from repro.multilevel import build_hierarchy
                    from repro.multilevel.vcycle import _layout_kwargs
                    hierarchy = build_hierarchy(
                        pend.W, coarse_size=self.ml.coarse_size,
                        max_levels=self.ml.max_levels,
                        min_reduction=self.ml.min_reduction,
                        rounds=self.ml.match_rounds,
                        layout_kwargs=_layout_kwargs(cfg),
                        sparsify=self.ml.sparsify,
                        max_agg=self.ml.match_max_agg)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as exc:            # noqa: BLE001 — isolated
            self._fail(pend, exc, kind=_classify(exc), lane="solo")
            return
        if not np.isfinite(np.asarray(res.U)).all():
            self._fail(pend, "non-finite embedding from the solo solve",
                       kind="nonfinite_result", lane="solo")
            return
        solve_s = time.monotonic() - t0
        self.stats.solve_s += solve_s
        sp.set(retries=retries)
        p_final = res.p_path[-1] if res.p_path else \
            float(registry.p_schedule(self.cfg)[-1])
        self._finish(pend, np.asarray(res.U), lane="solo", batch_size=1,
                     solve_s=solve_s, trace_new=False, p_final=p_final,
                     hierarchy=hierarchy, precomputed=res, retries=retries)

    def _finish(self, pend: _Pending, U: np.ndarray, *, lane: str,
                batch_size: int, solve_s: float, trace_new: bool,
                p_final: float, hierarchy, precomputed=None,
                retries: int = 0) -> None:
        """Stage 3 + metrics on the caller's original graph, cache
        store, stats."""
        W, k = pend.W, pend.k
        if precomputed is not None:
            labels = np.asarray(precomputed.labels)
            rcut, ncut = precomputed.rcut, precomputed.ncut
        else:
            _, k_final = _psc.stage_keys(self.cfg.seed)
            labels = np.asarray(_psc.discretize(
                jnp.asarray(U), k, k_final,
                restarts=self.cfg.kmeans_restarts,
                iters=self.cfg.kmeans_iters))
            rcut = float(metrics.rcut(W, labels, k))
            ncut = float(metrics.ncut(W, labels, k))
        self.cache.store(CacheEntry(
            U=np.asarray(U), labels=labels, p_final=p_final, rcut=rcut,
            fingerprint=pend.fp, hierarchy=hierarchy))
        done = time.monotonic()
        st = ServeStats(
            req_id=pend.req_id, n=W.n_rows, nnz=W.nnz, k=k, lane=lane,
            mode="churn" if pend.churn else pend.mode,
            cache_tier=pend.cache_tier,
            bucket=pend.spec.key if pend.spec else None,
            batch_size=batch_size, queue_s=done - pend.arrival - solve_s,
            solve_s=solve_s, trace_new=trace_new, p_final=p_final,
            degrade=pend.degrade, retries=retries)
        self._results[pend.req_id] = ServeResult(
            req_id=pend.req_id, labels=labels, U=np.asarray(U), rcut=rcut,
            ncut=ncut, stats=st)
        self.stats.n_results += 1
        if pend.churn:
            self.stats.n_churn += 1
        if self.stats.solve_s > 0:
            self.stats.graphs_per_s = self.stats.n_results / \
                self.stats.solve_s
