"""Warm-start cache for the clustering serve engine (DESIGN.md §8).

An LRU keyed on the graph's :class:`~repro.grblas.containers.
GraphFingerprint` — (n, nnz, pattern digest, quantized-weight digest).
Three hit tiers:

  * ``exact``   — same pattern AND same (quantized) weights: the cached
    labels are directly valid; the engine still re-enters the solver at
    the schedule tail from the cached U (one cheap step) so the returned
    embedding is a certified stationary point, but the p=2 eigensolve
    and the p descent are skipped entirely.
  * ``pattern`` — same pattern, different weights (the re-weighted-graph
    tenant: affinity refresh, time-decayed edges).  The cached U is a
    valid Grassmann warm start on the new weights — exactly the
    ``lobpcg.smallest_eigvecs`` X0 substrate, lifted to the nonlinear
    solve — but the cached labels are NOT reused.
  * miss        — full cold solve.

Entries may carry the multilevel hierarchy of large (solo-lane) graphs,
which the churn path patches instead of rebuilding
(``multilevel.coarsen.patch_hierarchy``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from repro.grblas.containers import GraphFingerprint
from repro.obs import metrics as _obs_metrics


@dataclasses.dataclass
class CacheEntry:
    """What a finished solve leaves behind for the next tenant."""

    U: np.ndarray                    # (n, k) final embedding
    labels: np.ndarray               # (n,) discretized clusters
    p_final: float                   # where the continuation ended
    rcut: float
    fingerprint: GraphFingerprint
    hierarchy: object = None         # multilevel Hierarchy (solo lane)


class WarmCache:
    """LRU over full fingerprints with a pattern-key secondary index.

    The secondary index maps ``fingerprint.pattern_key`` → the most
    recently *stored* full key with that pattern, so a same-pattern /
    different-weights request finds a warm start in O(1) without
    scanning.  Eviction is strict LRU on the primary map; the pattern
    index never pins an entry alive (it is repaired lazily on lookup).

    Counters live in a :class:`~repro.obs.metrics.MetricsRegistry`
    (the serve engine passes its own, so engine + cache share one set
    of books — DESIGN.md §10); ``hits_exact`` & friends remain as
    read-only views for back compat and ``stats()`` keeps its exact
    key set.
    """

    def __init__(self, capacity: int = 64, *,
                 metrics: "_obs_metrics.MetricsRegistry" = None):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = int(capacity)
        self.metrics = metrics if metrics is not None \
            else _obs_metrics.MetricsRegistry()
        self._lru: "OrderedDict[tuple, CacheEntry]" = OrderedDict()
        self._by_pattern: Dict[tuple, tuple] = {}

    # counter views (the instruments are the source of truth)

    @property
    def hits_exact(self) -> int:
        return int(self.metrics.value("warm_cache_hits_total", tier="exact"))

    @property
    def hits_pattern(self) -> int:
        return int(self.metrics.value("warm_cache_hits_total",
                                      tier="pattern"))

    @property
    def misses(self) -> int:
        return int(self.metrics.value("warm_cache_misses_total"))

    @property
    def evictions(self) -> int:
        return int(self.metrics.value("warm_cache_evictions_total"))

    @property
    def rejects(self) -> int:
        """Poisoned entries refused on insert."""
        return int(self.metrics.value("warm_cache_rejects_total"))

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, fp: GraphFingerprint) -> bool:
        return fp.key in self._lru

    def peek(self, fp: GraphFingerprint) -> Optional[CacheEntry]:
        """Exact-key lookup with no LRU refresh and no hit/miss
        accounting (the churn path's base-graph probe)."""
        return self._lru.get(fp.key)

    def lookup(self, fp: GraphFingerprint
               ) -> Tuple[Optional[CacheEntry], Optional[str]]:
        """(entry, tier) — tier "exact" | "pattern" | None.  Counts the
        hit/miss and refreshes LRU recency on exact hits."""
        entry = self._lru.get(fp.key)
        if entry is not None:
            self._lru.move_to_end(fp.key)
            self.metrics.counter("warm_cache_hits_total",
                                 tier="exact").inc()
            return entry, "exact"
        pkey = self._by_pattern.get(fp.pattern_key)
        if pkey is not None:
            entry = self._lru.get(pkey)
            if entry is None:                 # stale index (evicted)
                del self._by_pattern[fp.pattern_key]
            else:
                self._lru.move_to_end(pkey)
                self.metrics.counter("warm_cache_hits_total",
                                     tier="pattern").inc()
                return entry, "pattern"
        self.metrics.counter("warm_cache_misses_total").inc()
        return None, None

    def store(self, entry: CacheEntry) -> None:
        # poisoning guard (DESIGN.md §9): a NaN/Inf embedding — e.g.
        # from a diverged solve — must never be handed out as a warm
        # start; it would NaN the warm step of every future tenant of
        # this fingerprint.  Refuse the insert, keep any prior healthy
        # entry.
        if entry.U is None or not np.isfinite(entry.U).all():
            self.metrics.counter("warm_cache_rejects_total").inc()
            return
        fp = entry.fingerprint
        self._lru[fp.key] = entry
        self._lru.move_to_end(fp.key)
        self._by_pattern[fp.pattern_key] = fp.key
        while len(self._lru) > self.capacity:
            old_key, old = self._lru.popitem(last=False)
            self.metrics.counter("warm_cache_evictions_total").inc()
            pk = old.fingerprint.pattern_key
            if self._by_pattern.get(pk) == old_key:
                del self._by_pattern[pk]
        self.metrics.gauge("warm_cache_size").set(len(self._lru))

    def stats(self) -> dict:
        return {"size": len(self._lru), "capacity": self.capacity,
                "hits_exact": self.hits_exact,
                "hits_pattern": self.hits_pattern,
                "misses": self.misses, "evictions": self.evictions,
                "rejects": self.rejects}
