"""Architecture registry: one module per assigned arch (+ paper graphs).

get_config(arch_id)          -> full ArchConfig (dry-run / production)
get_reduced_config(arch_id)  -> tiny same-family config (CPU smoke tests)
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "jamba-1.5-large-398b",
    "gemma-2b",
    "internlm2-20b",
    "granite-8b",
    "chatglm3-6b",
    "whisper-small",
    "deepseek-v3-671b",
    "mixtral-8x22b",
    "mamba2-780m",
    "internvl2-1b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_reduced_config(arch_id: str):
    """Tiny same-family config: same code paths, laptop-size shapes."""
    from repro.models.config import MoEConfig, MLAConfig, SSMConfig

    cfg = get_config(arch_id)
    kw = dict(
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        max_position=256,
        params_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.family == "hybrid":
        kw["n_layers"] = len(cfg.hybrid_group)
    elif cfg.family == "moe" and cfg.moe.first_dense:
        kw["n_layers"] = 3
    else:
        kw["n_layers"] = 2
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 4), top_k=min(cfg.moe.top_k, 2),
            d_expert=64, n_shared=min(cfg.moe.n_shared, 1),
            every=cfg.moe.every,
            first_dense=1 if cfg.moe.first_dense else 0,
            capacity_factor=cfg.moe.capacity_factor)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_dim=8, nope_dim=16, v_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                              n_groups=1, chunk=32)
    if cfg.enc_layers:
        kw["enc_layers"] = 2
        kw["enc_seq"] = 16
    if cfg.family == "vlm":
        kw["vis_seq"] = 8
    return dataclasses.replace(cfg, **kw)
