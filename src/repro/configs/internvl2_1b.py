"""InternVL2-1B: InternViT STUB (input_specs provides 256 patch
embeddings) + 24L text backbone.  [arXiv:2404.16821; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    vis_seq=256,
    tie_embeddings=True,
    rope_theta=1000000.0,
)
