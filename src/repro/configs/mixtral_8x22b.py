"""Mixtral-8x22B: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    window=4096,             # SWA per the assignment
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384, every=1),
    tie_embeddings=False,
    rope_theta=1000000.0,
    sub_quadratic=True,      # SWA bounds the decode working set
    params_dtype="bfloat16",
)
