"""Granite-8B (code): llama-arch, GQA kv=8. [arXiv:2405.04324; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=49152,
    tie_embeddings=False,
    rope_theta=10000000.0,
)
