"""Whisper-small: enc-dec, conv frontend STUB (input_specs provides
precomputed 1500-frame embeddings).  [arXiv:2212.04356]

Backbone only per the assignment: 12L encoder + 12L decoder, d=768,
12H, layernorm, non-gated GELU, learned positions (no RoPE).
long_500k is skipped (full attention; decoder max position << 500k)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,             # decoder layers
    enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    act="gelu",
    gated=False,
    norm="layernorm",
    norm_eps=1e-5,
    pos_embedding="learned",
    rope_fraction=0.0,       # no rotary anywhere
    max_position=32768 + 8,  # sized for the assigned decode_32k shape
    tie_embeddings=True,
)
