"""DeepSeek-V3 (671B): MLA, 1 shared + 256 routed experts top-8,
3 leading dense layers.  [arXiv:2412.19437; hf]

The assignment's d_ff=2048 is the per-expert hidden size; the three
leading dense layers use the model's dense FFN width 18432.
MTP (multi-token prediction) heads are a training-objective add-on;
mtp_depth=1 is recorded but the auxiliary head is not lowered in the
dry-run step (noted in DESIGN.md)."""
from repro.models.config import ArchConfig, MoEConfig, MLAConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense layers (first 3)
    vocab=129280,
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  every=1, first_dense=3, capacity_factor=1.25),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_dim=64,
                  nope_dim=128, v_dim=128),
    mtp_depth=1,
    tie_embeddings=False,
    rope_theta=10000.0,
    params_dtype="bfloat16",
)
