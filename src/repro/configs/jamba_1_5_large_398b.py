"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer.  [arXiv:2403.19887; hf]

NOTE (hardware adaptation): Jamba's SSM layers are Mamba-1; this
framework implements the SSD (Mamba-2) formulation for all SSM blocks —
TPU-friendlier (chunked matmul form feeds the MXU).  Recorded in
DESIGN.md §8.
"""
from repro.models.config import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    # 8-layer group, one attention layer (index 4): 1:7 attn:mamba
    hybrid_group=("m", "m", "m", "m", "a", "m", "m", "m"),
    rope_theta=10000.0,
    tie_embeddings=False,
    sub_quadratic=True,      # mamba O(1) decode state; attn KV sharded
    params_dtype="bfloat16",  # 398B: fp32 master impossible on v5e pods
)
