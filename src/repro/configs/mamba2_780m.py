"""Mamba2-780m: attention-free SSD. [arXiv:2405.21060]"""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
    sub_quadratic=True,      # O(1) decode state
)
