"""ChatGLM3-6B: 2d-RoPE (half head dim rotated), GQA kv=2.
[arXiv:2406.12793; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65024,
    rope_fraction=0.5,       # 2d rope: rotate half the head dim
    tie_embeddings=False,
    rope_theta=10000.0,
)
