# Import the impl module FIRST so the submodule attribute is bound before
# the function names below (same ordering contract as kernels/bsr_spmm).
import repro.kernels.sellcs_spmm.sellcs_spmm  # noqa: F401
from repro.kernels.sellcs_spmm.sellcs_spmm import (
    sellcs_spmm_pallas,
    sellcs_plap_apply_pallas,
    sellcs_plap_hvp_pallas,
)
from repro.kernels.sellcs_spmm.ref import (
    sellcs_spmm_ref,
    sellcs_plap_apply_ref,
    sellcs_plap_hvp_ref,
    sellcs_shard_spmm_ref,
    sellcs_shard_plap_apply_ref,
)

__all__ = [
    "sellcs_spmm_pallas", "sellcs_plap_apply_pallas", "sellcs_plap_hvp_pallas",
    "sellcs_spmm_ref", "sellcs_plap_apply_ref", "sellcs_plap_hvp_ref",
    "sellcs_shard_spmm_ref", "sellcs_shard_plap_apply_ref",
]
