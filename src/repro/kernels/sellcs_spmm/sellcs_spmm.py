"""SELL-C-σ SpMM Pallas kernels — sliced-ELLPACK with per-slice padding.

One ``pallas_call`` per *width run* (consecutive slices of equal padded
width w — contiguous after the σ-window degree sort), grid = one step
per slice.  Per grid step the kernel sees:

    cols  (C, w) int32   slice column indices, PERMUTED row space
    vals  (C, w) dtype   slice stored values (pads are 0)
    Xp    (n_pad, k)     the σ-permuted multivector, whole, VMEM-resident
    own   (C, k)         the slice's own rows of Xp (edge-semiring kinds)

and writes the slice's (C, k) output block.  The neighbour gather is a
``jnp.take`` along the sublane axis of the VMEM-resident Xp (Mosaic
dynamic gather; exact in interpret mode).  C should be a multiple of the
f32 sublane (8) and ideally the 128 lane width on real TPUs so the
output block tiles cleanly.

Keeping Xp whole in VMEM bounds this kernel to n_pad * k * 4 bytes of
VMEM (~0.5 MB at n=32k, k=4); beyond that the production path is the
same kernel over row-partitioned shards (the "dist" backend composes),
or an HBM-resident Xp with per-slice DMA gathers.

Three ring kinds, mirroring the ELL/edge capability split:

    sellcs_spmm_pallas       y_i = sum_j a_ij x_j            (reals ring)
    sellcs_plap_apply_pallas y_i = sum_j w_ij phi_p(x_i-x_j) (gradient op)
    sellcs_plap_hvp_pallas   y_i = sum_j w_ij phi'(u_i-u_j)(e_i-e_j)

Pad entries store col=self, val=0: each kind's multiply annihilates on
w=0, so the pad contributes the add-identity (the ELL pad-soundness
contract, DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.core import phi as PHI


def _gather(x, idx):
    """(C*w,) row gather from the VMEM-resident (n_pad, k) multivector."""
    C, w = idx.shape
    return jnp.take(x, idx.reshape(-1), axis=0).reshape(C, w, x.shape[-1])


def _reals_kernel(cols_ref, vals_ref, x_ref, y_ref):
    g = _gather(x_ref[...], cols_ref[...])             # (C, w, k)
    y_ref[...] = jnp.sum(vals_ref[...][..., None] * g, axis=1)


def _apply_kernel(p, eps, cols_ref, vals_ref, x_ref, xo_ref, y_ref):
    g = _gather(x_ref[...], cols_ref[...])             # x_j  (C, w, k)
    x_i = xo_ref[...][:, None, :]                      # own rows
    contrib = vals_ref[...][..., None] * PHI.phi(x_i - g, p, eps)
    y_ref[...] = jnp.sum(contrib, axis=1)


def _hvp_kernel(p, eps, cols_ref, vals_ref, u_ref, uo_ref, e_ref, eo_ref,
                y_ref):
    idx = cols_ref[...]
    du = uo_ref[...][:, None, :] - _gather(u_ref[...], idx)
    de = eo_ref[...][:, None, :] - _gather(e_ref[...], idx)
    contrib = vals_ref[...][..., None] * PHI.phi_prime(du, p, eps) * de
    y_ref[...] = jnp.sum(contrib, axis=1)


def _run_specs(C, w, n_pad, k, slice0):
    slc = pl.BlockSpec((C, w), lambda s: (s, 0))       # cols / vals
    full = pl.BlockSpec((n_pad, k), lambda s: (0, 0))  # whole Xp resident
    own = pl.BlockSpec((C, k), lambda s: (s + slice0, 0))
    out = pl.BlockSpec((C, k), lambda s: (s, 0))
    return slc, full, own, out


def _call(kernel, n_slices, in_specs, out_spec, rows_r, k, dtype, interpret,
          args):
    return pl.pallas_call(
        kernel,
        grid=(n_slices,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows_r, k), dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
    )(*args)


@functools.partial(jax.jit, static_argnames=("slice_c", "slice0", "interpret"))
def sellcs_spmm_pallas(cols, vals, Xp, slice_c: int, slice0: int = 0,
                       interpret: bool = False) -> jnp.ndarray:
    """Reals-ring SpMM over one width run.  cols/vals: (rows_r, w);
    Xp: (n_pad, k) permuted multivector.  Returns (rows_r, k)."""
    rows_r, w = cols.shape
    n_pad, k = Xp.shape
    n_slices = rows_r // slice_c
    slc, full, _, out = _run_specs(slice_c, w, n_pad, k, slice0)
    return _call(_reals_kernel, n_slices, [slc, slc, full], out,
                 rows_r, k, Xp.dtype, interpret, (cols, vals, Xp))


@functools.partial(jax.jit, static_argnames=("slice_c", "slice0", "p", "eps",
                                             "interpret"))
def sellcs_plap_apply_pallas(cols, vals, Xp, slice_c: int, slice0: int = 0,
                             p: float = 1.5, eps: float = 1e-9,
                             interpret: bool = False) -> jnp.ndarray:
    """p-Laplacian apply over one width run (edge kind "plap_apply")."""
    rows_r, w = cols.shape
    n_pad, k = Xp.shape
    n_slices = rows_r // slice_c
    slc, full, own, out = _run_specs(slice_c, w, n_pad, k, slice0)
    return _call(functools.partial(_apply_kernel, p, eps), n_slices,
                 [slc, slc, full, own], out, rows_r, k, Xp.dtype, interpret,
                 (cols, vals, Xp, Xp))


@functools.partial(jax.jit, static_argnames=("slice_c", "slice0", "p", "eps",
                                             "interpret"))
def sellcs_plap_hvp_pallas(cols, vals, Up, Ep, slice_c: int, slice0: int = 0,
                           p: float = 1.5, eps: float = 1e-9,
                           interpret: bool = False) -> jnp.ndarray:
    """Newton HVP (pair-edge kind "plap_hvp") over one width run."""
    rows_r, w = cols.shape
    n_pad, k = Up.shape
    n_slices = rows_r // slice_c
    slc, full, own, out = _run_specs(slice_c, w, n_pad, k, slice0)
    return _call(functools.partial(_hvp_kernel, p, eps), n_slices,
                 [slc, slc, full, own, full, own], out, rows_r, k, Up.dtype,
                 interpret, (cols, vals, Up, Up, Ep, Ep))
