"""Pure-jnp oracles for the SELL-C-σ SpMM kernels.

Operate on one width run of the sliced layout (containers._build_sellcs):
cols/vals (rows_r, w) in the PERMUTED row space, multivectors already
σ-permuted to (n_pad, k).  These are also the vectorized CPU execution
path of the "sellcs" backend — per-run gather + ring fold, the sliced
analogue of the full-ELL gather path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import phi as PHI


def sellcs_spmm_ref(cols, vals, Xp):
    """Reals-ring run: y = sum_w vals * Xp[cols].  vals may be (rows, w)
    or (rows, w, k) multivalues (with_vals' Alg-1 W-hat)."""
    g = Xp[cols]                                   # (rows, w, k)
    v = vals[..., None] if vals.ndim == 2 else vals
    return jnp.sum(v * g, axis=1)


def sellcs_plap_apply_ref(cols, vals, Xp, row0: int, p: float, eps: float):
    """p-Laplacian apply run: y_i = sum_j w_ij phi_p(x_i - x_j)."""
    g = Xp[cols]                                   # x_j  (rows, w, k)
    x_i = Xp[row0:row0 + cols.shape[0]][:, None, :]
    return jnp.sum(vals[..., None] * PHI.phi(x_i - g, p, eps), axis=1)


def sellcs_plap_hvp_ref(cols, vals, Up, Ep, row0: int, p: float, eps: float):
    """Newton HVP run: y_i = sum_j w_ij phi'(u_i-u_j)(e_i-e_j)."""
    rows = cols.shape[0]
    du = Up[row0:row0 + rows][:, None, :] - Up[cols]
    de = Ep[row0:row0 + rows][:, None, :] - Ep[cols]
    return jnp.sum(vals[..., None] * PHI.phi_prime(du, p, eps) * de, axis=1)


# --- shard-local variants (the "dist_sellcs" backend, grblas.dist) ---
# Same per-run gather+fold, but the column ids index a shard's
# extended-local vector (locals then halo slots) and the own rows are an
# explicit gather (the σ-sort is per shard, so own rows aren't a
# contiguous row0 slice of the source vector).

def sellcs_shard_spmm_ref(cols, vals, x_src):
    """Reals-ring run of one shard: y = sum_w vals * x_src[cols]."""
    return jnp.sum(vals[..., None] * x_src[cols], axis=1)


def sellcs_shard_plap_apply_ref(cols, vals, x_src, x_own, p: float,
                                eps: float):
    """p-Laplacian apply run of one shard; x_own: (rows, k) the packed
    rows' own entries (gathered from the shard-local vector)."""
    g = x_src[cols]                                # x_j  (rows, w, k)
    return jnp.sum(vals[..., None] * PHI.phi(x_own[:, None, :] - g, p, eps),
                   axis=1)
