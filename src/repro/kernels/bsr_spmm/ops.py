"""jit'd public wrapper: dispatches SparseMatrix -> Pallas BSR kernel
(TPU) or the jnp oracle (CPU / no-BSR fallback)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_pallas
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref


def bsr_spmm(A: SparseMatrix, X: jnp.ndarray, use_pallas: bool | None = None,
             interpret: bool = False) -> jnp.ndarray:
    """Y = A @ X using the BSR layout. X: (n, k). Returns (n, k)."""
    assert A.bsr_blocks is not None, "build_bsr=True required"
    bs = A.block_size
    n_rb = len(A.bsr_indptr) - 1
    pad_rows = n_rb * bs - X.shape[0]
    Xp = jnp.pad(X, ((0, pad_rows), (0, 0))) if pad_rows else X
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        Y = bsr_spmm_pallas(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids, Xp,
                            n_row_blocks=n_rb, block_size=bs,
                            interpret=interpret)
    else:
        Y = bsr_spmm_ref(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids, Xp,
                         n_row_blocks=n_rb, block_size=bs)
    return Y[: A.n_rows]
