"""Deprecated shim — the BSR SpMM is now the "bsr_pallas" backend of the
unified API: ``grblas.api.mxm(A, X, desc=Descriptor(backend="bsr_pallas",
interpret=...))`` (auto-selected on TPU).  Kept one release; see
DESIGN.md §3."""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix


def bsr_spmm(A: SparseMatrix, X: jnp.ndarray, use_pallas: bool | None = None,
             interpret: bool = False) -> jnp.ndarray:
    """Y = A @ X using the BSR layout. X: (n, k). Returns (n, k)."""
    warnings.warn(
        "kernels.bsr_spmm.bsr_spmm is deprecated; use grblas.api.mxm with "
        "Descriptor(backend='bsr_pallas') — DESIGN.md §3",
        DeprecationWarning, stacklevel=2)
    assert A.bsr_blocks is not None, "build_bsr=True required"
    from repro.grblas.backends import bsr_spmm_run

    return bsr_spmm_run(A, X, interpret=interpret, use_pallas=use_pallas)
