from repro.kernels.bsr_spmm.ops import bsr_spmm
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref

__all__ = ["bsr_spmm", "bsr_spmm_ref"]
