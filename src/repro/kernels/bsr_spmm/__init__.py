"""BSR SpMM Pallas kernel package.

The public entry point is the unified API: ``grblas.api.mxm(A, X,
desc=Descriptor(backend="bsr_pallas", interpret=...))`` (auto-selected
on TPU).  The one-release deprecated wrapper ``ops.bsr_spmm`` is gone;
DESIGN.md §3 keeps the migration table.  This package only exposes the
raw kernel + reference for the backend registry and the kernel tests.
"""
from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_pallas
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref

__all__ = ["bsr_spmm_pallas", "bsr_spmm_ref"]
