# Import the impl module FIRST: the first import of the submodule
# `repro.kernels.bsr_spmm.bsr_spmm` sets the package attribute
# ``bsr_spmm`` to the module object.  Doing it eagerly here means the
# function binding below wins, and later lazy imports of the submodule
# (grblas.backends) hit the sys.modules cache without re-clobbering.
import repro.kernels.bsr_spmm.bsr_spmm  # noqa: F401
from repro.kernels.bsr_spmm.ops import bsr_spmm
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref

__all__ = ["bsr_spmm", "bsr_spmm_ref"]
