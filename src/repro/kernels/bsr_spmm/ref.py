"""Pure-jnp oracle for the BSR SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bsr_spmm_ref(blocks: jnp.ndarray, indices: jnp.ndarray,
                 row_ids: jnp.ndarray, X: jnp.ndarray,
                 n_row_blocks: int, block_size: int = 128) -> jnp.ndarray:
    """Y[rb] = sum_b [row_ids[b]==rb] blocks[b] @ X[indices[b]]."""
    bs = block_size
    k = X.shape[1]
    Xb = X.reshape(-1, bs, k)                         # (n_col_blocks, bs, k)
    prod = jnp.einsum("bij,bjk->bik", blocks, Xb[indices])
    out = jnp.zeros((n_row_blocks, bs, k), X.dtype)
    out = out.at[row_ids].add(prod)
    return out.reshape(n_row_blocks * bs, k)
