"""Block-sparse (BSR) SpMM Pallas kernel — the MXU-native SpMV of the
paper's grb::vxm (DESIGN.md §2: CRS gather -> 128x128 dense tiles).

Layout: the matrix is a list of dense (bs, bs) tiles, sorted by
row-block id; ``indices[b]`` is the column-block, ``row_ids[b]`` the
row-block of stored tile b.  The multivector X is (n_cols_pad, k).

Grid = (n_blocks,): one program per stored tile.  Tiles of one row-block
are consecutive, so the output tile (selected by row_ids via scalar
prefetch) stays resident in VMEM across those grid steps — the classic
Pallas reduction-revisiting pattern.  First visit zero-inits.

VMEM per step: bs*bs*4 (tile) + 2*bs*k*4 (X in, Y out) ~= 66 KB at
bs=128, k=16 — far under the ~16 MB v5e VMEM budget; the MXU sees a
(128,128)x(128,k) matmul per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(indices_ref, row_ids_ref, blocks_ref, x_ref, y_ref):
    b = pl.program_id(0)
    row = row_ids_ref[b]
    prev_row = row_ids_ref[jnp.maximum(b - 1, 0)]
    is_first = jnp.logical_or(b == 0, row != prev_row)

    @pl.when(is_first)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0]                      # (bs, bs)
    x = x_ref[...]                           # (bs, k)
    y_ref[...] += jnp.dot(blk, x, preferred_element_type=y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_row_blocks", "block_size",
                                              "interpret"))
def bsr_spmm_pallas(blocks: jnp.ndarray, indices: jnp.ndarray,
                    row_ids: jnp.ndarray, X: jnp.ndarray,
                    n_row_blocks: int, block_size: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """Y = A @ X for BSR A. X: (n_col_blocks*bs, k) -> Y: (n_row_blocks*bs, k).

    Requires tiles sorted by row_ids (SparseMatrix._build_bsr guarantees).
    """
    n_blocks, bs, _ = blocks.shape
    k = X.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda b, idx, rid: (b, 0, 0)),
            pl.BlockSpec((bs, k), lambda b, idx, rid: (idx[b], 0)),
        ],
        out_specs=pl.BlockSpec((bs, k), lambda b, idx, rid: (rid[b], 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_row_blocks * bs, k), X.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),  # revisits output: sequential
    )(indices, row_ids, blocks, X)
