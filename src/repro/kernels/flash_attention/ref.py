"""jnp oracle: causal (optionally sliding-window) GQA attention.

Two implementations:
  attention_ref         — materializes (Sq,Sk) scores; oracle for tests.
  attention_ref_chunked — lax.scan over query chunks with a remat'd
    body: peak memory O(q_chunk * Sk) instead of O(Sq * Sk).  This is
    what the model stack lowers on non-TPU backends (and what the
    dry-run memory analysis reflects); on TPU the Pallas flash kernel
    replaces it.  Exact same math — pinned by tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, window: int | None = None):
    """q: (B, Hq, S, D), k/v: (B, Hkv, S, D); Hq % Hkv == 0.
    Returns (B, Hq, S, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk) / jnp.sqrt(D).astype(q.dtype)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    w = jnp.nan_to_num(jnp.exp(scores - jnp.max(scores, -1, keepdims=True)))
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), vv)


def attention_ref_chunked(q, k, v, causal: bool = True,
                          window: int | None = None, q_chunk: int = 512):
    """Query-chunked attention: scan over q blocks, remat'd body."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                  # MLA: value dim != qk dim
    group = Hq // Hkv
    nc = max(Sq // q_chunk, 1)
    qc = Sq // nc
    scale = 1.0 / jnp.sqrt(D).astype(q.dtype)

    kk = k.reshape(B, Hkv, 1, Sk, D)
    vv = v.reshape(B, Hkv, 1, Sk, Dv)
    qs = q.reshape(B, Hkv, group, nc, qc, D).transpose(3, 0, 1, 2, 4, 5)

    ki = jnp.arange(Sk)

    @jax.checkpoint
    def body(_, inp):
        qi_blk, q_blk = inp                       # (B,Hkv,g,qc,D)
        s = jnp.einsum("bhgqd,bhzkd->bhgqk", q_blk, kk) * scale
        qi = qi_blk[:, None]                      # (qc,1)
        mask = jnp.ones((qc, Sk), bool)
        if causal:
            mask &= ki[None, :] <= qi
        if window is not None:
            mask &= ki[None, :] > qi - window
        s = jnp.where(mask[None, None, None], s.astype(jnp.float32), -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgqk,bhzkd->bhgqd", p, vv)
        return None, o

    qi_all = jnp.arange(Sq).reshape(nc, qc)
    _, out = jax.lax.scan(body, None, (qi_all, qs))
    # (nc,B,Hkv,g,qc,Dv) -> (B,Hq,Sq,Dv)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq, Dv)
    return out
