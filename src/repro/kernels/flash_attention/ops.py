"""Public attention entry point: Pallas flash kernel on TPU, jnp ref
elsewhere.  Differentiable everywhere: the Pallas forward is wrapped in
jax.custom_vjp whose backward recomputes with the jnp reference
(flash-style recompute; exact same math, so gradients match the ref)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref, attention_ref_chunked


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, window, interpret):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=interpret)


def _fwd(q, k, v, causal, window, interpret):
    return _flash(q, k, v, causal, window, interpret), (q, k, v)


def _bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: attention_ref(q, k, v, causal, window),
                     q, k, v)
    return vjp(g)


_flash.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    use_pallas: bool | None = None, interpret: bool = False):
    """q: (B,Hq,S,D), k/v: (B,Hkv,S,D) -> (B,Hq,S,D)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return _flash(q, k, v, causal, window, interpret)
    # jnp path: q-chunked flash (bounded memory) once S is non-trivial
    if q.shape[2] > 1024:
        return attention_ref_chunked(q, k, v, causal=causal, window=window)
    return attention_ref(q, k, v, causal=causal, window=window)
