"""Causal GQA flash attention (forward) — Pallas TPU kernel.

Grid: (B*Hq, n_q_blocks).  Each program streams K/V blocks for one
(block_q, D) query tile with the online-softmax recurrence, skipping
fully-masked K blocks (causal upper triangle / outside the sliding
window) via the grid dimension trick: the fori_loop upper bound is the
last visible K block for this Q tile.

VMEM at block_q=block_k=128, D=128: q 64 KB + k/v 128 KB + acc 64 KB +
m/l 1 KB ~= 0.26 MB.  MXU does (128,D)x(D,128) + (128,128)x(128,D) per
K step.  GQA: the q-head -> kv-head map happens in the BlockSpec
index_map (h // group), so no K/V repeat is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(group, scale, causal, window, block_q, block_k, seq_k,
            q_ref, k_ref, v_ref, o_ref):
    qb = pl.program_id(1)
    q = q_ref[0]                                     # (bq, D)
    D = q.shape[-1]

    q_start = qb * block_q
    n_kb = seq_k // block_k
    if causal:
        last_kb = jnp.minimum((q_start + block_q - 1) // block_k + 1, n_kb)
    else:
        last_kb = n_kb
    if window is not None:
        first_kb = jnp.maximum((q_start - window) // block_k, 0)
    else:
        first_kb = 0

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :]
        v = v_ref[0, pl.ds(kb * block_k, block_k), :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ki = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= ki > qi - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, D), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(first_kb, last_kb, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, causal=True, window=None,
                           block_q=128, block_k=128, interpret=False):
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    grid = (B * Hq, Sq // block_q)

    q_spec = pl.BlockSpec((1, block_q, D),
                          lambda bh, qb: (bh, qb, 0))
    kv_spec = pl.BlockSpec((1, Sk, D),
                           lambda bh, qb: (bh // group, 0, 0))
    o_spec = pl.BlockSpec((1, block_q, D), lambda bh, qb: (bh, qb, 0))

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)
    out = pl.pallas_call(
        functools.partial(_kernel, group, scale, causal, window,
                          block_q, block_k, Sk),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D)
