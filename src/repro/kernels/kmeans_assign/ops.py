"""Public wrapper for the fused kmeans assignment."""
from __future__ import annotations

import jax

from repro.kernels.kmeans_assign.kmeans_assign import kmeans_assign_pallas
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref


def kmeans_assign(X, C, use_pallas: bool | None = None,
                  interpret: bool = False, block_m: int = 256):
    """(labels (n,), min_sqdist (n,))."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        return kmeans_assign_pallas(X, C, block_m=block_m, interpret=interpret)
    return kmeans_assign_ref(X, C)
