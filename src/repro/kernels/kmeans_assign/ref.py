"""jnp oracle for the fused distance+argmin kmeans assignment."""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(X: jnp.ndarray, C: jnp.ndarray):
    """Returns (labels int32 (n,), min_sqdist (n,))."""
    xx = jnp.sum(X * X, axis=1, keepdims=True)
    cc = jnp.sum(C * C, axis=1)[None, :]
    d2 = jnp.maximum(xx + cc - 2.0 * (X @ C.T), 0.0)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
