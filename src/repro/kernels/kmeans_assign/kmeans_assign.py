"""Fused distance + argmin kmeans-assignment Pallas kernel.

Grid over row tiles of X; centroids (kc <= 128, padded to a lane
multiple) stay VMEM-resident across all grid steps (constant index_map).
Per step: one (bm, d) x (d, kc) MXU matmul + VPU argmin via the
iota/min-select idiom (TPU has no native argmin over lanes).

Outputs are (bm, 1)-shaped tiles (TPU wants >=2D); the wrapper squeezes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, cc_ref, lab_ref, dist_ref):
    x = x_ref[...]                                    # (bm, d)
    c = c_ref[...]                                    # (kc, d)
    cc = cc_ref[...]                                  # (1, kc) |c|^2
    xx = jnp.sum(x * x, axis=1, keepdims=True)        # (bm, 1)
    d2 = xx + cc - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(d2, 0.0)
    dmin = jnp.min(d2, axis=1, keepdims=True)         # (bm,1)
    iota = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    big = jnp.iinfo(jnp.int32).max
    lab = jnp.min(jnp.where(d2 <= dmin, iota, big), axis=1, keepdims=True)
    lab_ref[...] = lab.astype(jnp.int32)
    dist_ref[...] = dmin


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def kmeans_assign_pallas(X: jnp.ndarray, C: jnp.ndarray,
                         block_m: int = 256, interpret: bool = False):
    n, d = X.shape
    kc = C.shape[0]
    pad = (-n) % block_m
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    cc = jnp.sum(C * C, axis=1)[None, :]              # (1, kc)
    grid = (Xp.shape[0] // block_m,)
    lab, dist = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((kc, d), lambda i: (0, 0)),
            pl.BlockSpec((1, kc), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((Xp.shape[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(Xp, C, cc)
    return lab[:n, 0], dist[:n, 0]
