"""Deprecated shims — the fused p-Laplacian kernels are now the
"edge_pallas" backend of the unified API (auto-selected on TPU when the
BSR layout is built):

    api.mxm(A, X, plap_edge_semiring(p, eps), desc=Descriptor(...))
    api.mxm(A, (U, Eta), plap_hvp_edge_semiring(p, eps), desc=...)

Kept one release; see DESIGN.md §3."""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix


def plap_apply(A: SparseMatrix, X: jnp.ndarray, p: float, eps: float = 1e-9,
               use_pallas: bool | None = None, interpret: bool = False):
    """(Delta_p X) via the fused BSR kernel. X: (n,k)."""
    warnings.warn(
        "kernels.plap_edge.plap_apply is deprecated; use grblas.api.mxm "
        "with plap_edge_semiring(p, eps) — DESIGN.md §3",
        DeprecationWarning, stacklevel=2)
    assert A.bsr_blocks is not None, "build_bsr=True required"
    from repro.grblas.backends import edge_pallas_run
    from repro.grblas.semiring import plap_edge_semiring

    return edge_pallas_run(A, X, plap_edge_semiring(p, eps),
                           interpret=interpret, use_pallas=use_pallas)


def plap_hvp_edge(A: SparseMatrix, U: jnp.ndarray, Eta: jnp.ndarray,
                  p: float, eps: float = 1e-9,
                  use_pallas: bool | None = None, interpret: bool = False):
    """HessA-part HVP via the fused BSR kernel. U, Eta: (n,k)."""
    warnings.warn(
        "kernels.plap_edge.plap_hvp_edge is deprecated; use grblas.api.mxm "
        "with plap_hvp_edge_semiring(p, eps) and X=(U, Eta) — DESIGN.md §3",
        DeprecationWarning, stacklevel=2)
    assert A.bsr_blocks is not None, "build_bsr=True required"
    from repro.grblas.backends import edge_pallas_run
    from repro.grblas.semiring import plap_hvp_edge_semiring

    return edge_pallas_run(A, (U, Eta), plap_hvp_edge_semiring(p, eps),
                           interpret=interpret, use_pallas=use_pallas)
