"""Public wrappers for the fused p-Laplacian kernels (TPU Pallas or jnp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.kernels.plap_edge.plap_edge import plap_apply_pallas, plap_hvp_pallas
from repro.kernels.plap_edge.ref import plap_apply_ref, plap_hvp_edge_ref


def _prep(A: SparseMatrix, *Xs):
    bs = A.block_size
    n_rb = len(A.bsr_indptr) - 1
    pad = n_rb * bs - Xs[0].shape[0]
    return bs, n_rb, [jnp.pad(X, ((0, pad), (0, 0))) if pad else X for X in Xs]


def plap_apply(A: SparseMatrix, X: jnp.ndarray, p: float, eps: float = 1e-9,
               use_pallas: bool | None = None, interpret: bool = False):
    """(Delta_p X) via the fused BSR kernel. X: (n,k)."""
    assert A.bsr_blocks is not None, "build_bsr=True required"
    bs, n_rb, (Xp,) = _prep(A, X)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        Y = plap_apply_pallas(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids, Xp,
                              n_row_blocks=n_rb, block_size=bs, p=p, eps=eps,
                              interpret=interpret)
    else:
        Y = plap_apply_ref(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids, Xp,
                           n_rb, bs, p, eps)
    return Y[: A.n_rows]


def plap_hvp_edge(A: SparseMatrix, U: jnp.ndarray, Eta: jnp.ndarray,
                  p: float, eps: float = 1e-9,
                  use_pallas: bool | None = None, interpret: bool = False):
    """HessA-part HVP via the fused BSR kernel. U, Eta: (n,k)."""
    assert A.bsr_blocks is not None, "build_bsr=True required"
    bs, n_rb, (Up, Ep) = _prep(A, U, Eta)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        Y = plap_hvp_pallas(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids,
                            Up, Ep, n_row_blocks=n_rb, block_size=bs,
                            p=p, eps=eps, interpret=interpret)
    else:
        Y = plap_hvp_edge_ref(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids,
                              Up, Ep, n_rb, bs, p, eps)
    return Y[: A.n_rows]
