"""Pure-jnp oracles for the fused p-Laplacian edge-semiring kernels.

Operates on the same BSR tile layout as the Pallas kernel so the two are
bit-comparable: dense (bs,bs) weight tiles, multivector X (n,k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import phi as PHI


def plap_apply_ref(blocks, indices, row_ids, X, n_row_blocks,
                   block_size=128, p=1.5, eps=1e-9):
    """(Delta_p X)_i = sum_j w_ij phi_p(x_i - x_j), per column of X."""
    bs = block_size
    Xb = X.reshape(-1, bs, X.shape[1])
    x_cols = Xb[indices]                     # (nb, bs, k)   x_j
    x_rows = Xb[row_ids]                     # (nb, bs, k)   x_i
    diff = x_rows[:, :, None, :] - x_cols[:, None, :, :]   # (nb,bs,bs,k)
    contrib = blocks[..., None] * PHI.phi(diff, p, eps)
    tile_out = jnp.sum(contrib, axis=2)                    # (nb, bs, k)
    out = jnp.zeros((n_row_blocks, bs, X.shape[1]), X.dtype)
    out = out.at[row_ids].add(tile_out)
    return out.reshape(n_row_blocks * bs, -1)


def plap_hvp_edge_ref(blocks, indices, row_ids, U, Eta, n_row_blocks,
                      block_size=128, p=1.5, eps=1e-9):
    """HessA-part apply: sum_j w_ij phi'(u_i-u_j) (eta_i - eta_j)."""
    bs = block_size
    Ub = U.reshape(-1, bs, U.shape[1])
    Eb = Eta.reshape(-1, bs, Eta.shape[1])
    du = Ub[row_ids][:, :, None, :] - Ub[indices][:, None, :, :]
    de = Eb[row_ids][:, :, None, :] - Eb[indices][:, None, :, :]
    contrib = blocks[..., None] * PHI.phi_prime(du, p, eps) * de
    tile_out = jnp.sum(contrib, axis=2)
    out = jnp.zeros((n_row_blocks, bs, U.shape[1]), U.dtype)
    out = out.at[row_ids].add(tile_out)
    return out.reshape(n_row_blocks * bs, -1)
