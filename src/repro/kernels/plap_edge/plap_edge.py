"""Fused p-Laplacian edge-semiring SpMM — the paper's semiring-
parameterized grb::vxm as a TPU Pallas kernel.

Two variants over the same BSR tile layout as bsr_spmm:

  plap_apply_pallas : y_i += sum_j w_ij phi_p(x_i - x_j)       (gradient op)
  plap_hvp_pallas   : y_i += sum_j w_ij phi'(u_i-u_j)(e_i-e_j)  (Newton HVP)

The nonlinearity runs on the VPU over a (bs, bs, k_tile) broadcast in
VMEM; nothing (W-hat, differences) is materialized in HBM — this is the
matrix-free adaptation of Algorithm 1 (DESIGN.md §2, item 4).

VMEM at bs=128, k_tile=4: tile 64 KB + 3 vectors 6 KB + broadcast
(bs,bs,k) 256 KB ~= 0.33 MB.  Arithmetic intensity ~ bs*k flops/byte of
tile traffic — compute-dense enough to hide the HBM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.core import phi as PHI


def _apply_kernel(p, eps, indices_ref, row_ids_ref, blocks_ref,
                  xc_ref, xr_ref, y_ref):
    b = pl.program_id(0)
    row = row_ids_ref[b]
    prev_row = row_ids_ref[jnp.maximum(b - 1, 0)]

    @pl.when(jnp.logical_or(b == 0, row != prev_row))
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = blocks_ref[0]                                  # (bs, bs)
    x_j = xc_ref[...]                                  # (bs, k)  neighbours
    x_i = xr_ref[...]                                  # (bs, k)  own rows
    diff = x_i[:, None, :] - x_j[None, :, :]           # (bs, bs, k)
    contrib = w[:, :, None] * PHI.phi(diff, p, eps)
    y_ref[...] += jnp.sum(contrib, axis=1)


def _hvp_kernel(p, eps, indices_ref, row_ids_ref, blocks_ref,
                uc_ref, ur_ref, ec_ref, er_ref, y_ref):
    b = pl.program_id(0)
    row = row_ids_ref[b]
    prev_row = row_ids_ref[jnp.maximum(b - 1, 0)]

    @pl.when(jnp.logical_or(b == 0, row != prev_row))
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = blocks_ref[0]
    du = ur_ref[...][:, None, :] - uc_ref[...][None, :, :]
    de = er_ref[...][:, None, :] - ec_ref[...][None, :, :]
    contrib = w[:, :, None] * PHI.phi_prime(du, p, eps) * de
    y_ref[...] += jnp.sum(contrib, axis=1)


def _common_specs(bs, k):
    col_spec = pl.BlockSpec((bs, k), lambda b, idx, rid: (idx[b], 0))
    row_spec = pl.BlockSpec((bs, k), lambda b, idx, rid: (rid[b], 0))
    blk_spec = pl.BlockSpec((1, bs, bs), lambda b, idx, rid: (b, 0, 0))
    out_spec = pl.BlockSpec((bs, k), lambda b, idx, rid: (rid[b], 0))
    return blk_spec, col_spec, row_spec, out_spec


@functools.partial(jax.jit, static_argnames=("n_row_blocks", "block_size",
                                              "p", "eps", "interpret"))
def plap_apply_pallas(blocks, indices, row_ids, X, n_row_blocks,
                      block_size=128, p=1.5, eps=1e-9, interpret=False):
    n_blocks, bs, _ = blocks.shape
    k = X.shape[1]
    blk, colv, rowv, out = _common_specs(bs, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(n_blocks,),
        in_specs=[blk, colv, rowv], out_specs=out)
    return pl.pallas_call(
        functools.partial(_apply_kernel, p, eps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_row_blocks * bs, k), X.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
    )(indices, row_ids, blocks, X, X)


@functools.partial(jax.jit, static_argnames=("n_row_blocks", "block_size",
                                              "p", "eps", "interpret"))
def plap_hvp_pallas(blocks, indices, row_ids, U, Eta, n_row_blocks,
                    block_size=128, p=1.5, eps=1e-9, interpret=False):
    n_blocks, bs, _ = blocks.shape
    k = U.shape[1]
    blk, colv, rowv, out = _common_specs(bs, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(n_blocks,),
        in_specs=[blk, colv, rowv, colv, rowv], out_specs=out)
    return pl.pallas_call(
        functools.partial(_hvp_kernel, p, eps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_row_blocks * bs, k), U.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
    )(indices, row_ids, blocks, U, U, Eta, Eta)
