from repro.kernels.plap_edge.ops import plap_apply, plap_hvp_edge
from repro.kernels.plap_edge.ref import plap_apply_ref, plap_hvp_edge_ref

__all__ = ["plap_apply", "plap_hvp_edge", "plap_apply_ref", "plap_hvp_edge_ref"]
