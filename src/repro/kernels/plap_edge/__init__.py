"""Fused p-Laplacian edge-semiring Pallas kernels.

The public entry point is the unified API:

    api.mxm(A, X, plap_edge_semiring(p, eps), desc=Descriptor(...))
    api.mxm(A, (U, Eta), plap_hvp_edge_semiring(p, eps), desc=...)

(the "edge_pallas" backend, auto-selected on TPU when the BSR layout is
built).  The one-release deprecated wrappers ``ops.plap_apply`` /
``ops.plap_hvp_edge`` are gone; DESIGN.md §3 keeps the migration table.
"""
from repro.kernels.plap_edge.plap_edge import plap_apply_pallas, plap_hvp_pallas
from repro.kernels.plap_edge.ref import plap_apply_ref, plap_hvp_edge_ref

__all__ = ["plap_apply_pallas", "plap_hvp_pallas",
           "plap_apply_ref", "plap_hvp_edge_ref"]
