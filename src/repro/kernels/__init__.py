"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package has: <name>.py (pl.pallas_call + BlockSpec) and
ref.py (pure-jnp oracle); kernels that are not grblas backends
(kmeans_assign, flash_attention) also keep an ops.py dispatching
wrapper.  The grblas-served kernels (bsr_spmm, plap_edge, sellcs_spmm)
are reached through ``grblas.api.mxm`` + Descriptor — their deprecated
ops.py wrappers are deleted (DESIGN.md §3).  All kernels are validated
in interpret=True mode against their oracle over shape/dtype sweeps in
tests/test_kernels_*.py.
"""
from repro.kernels.bsr_spmm import bsr_spmm_pallas, bsr_spmm_ref
from repro.kernels.plap_edge import (
    plap_apply_pallas, plap_hvp_pallas, plap_apply_ref, plap_hvp_edge_ref)
from repro.kernels.sellcs_spmm import (
    sellcs_spmm_pallas, sellcs_spmm_ref,
    sellcs_plap_apply_pallas, sellcs_plap_apply_ref,
    sellcs_plap_hvp_pallas, sellcs_plap_hvp_ref)
from repro.kernels.kmeans_assign import kmeans_assign, kmeans_assign_ref
from repro.kernels.flash_attention import flash_attention, attention_ref

__all__ = [
    "bsr_spmm_pallas", "bsr_spmm_ref",
    "plap_apply_pallas", "plap_hvp_pallas",
    "plap_apply_ref", "plap_hvp_edge_ref",
    "sellcs_spmm_pallas", "sellcs_spmm_ref",
    "sellcs_plap_apply_pallas", "sellcs_plap_apply_ref",
    "sellcs_plap_hvp_pallas", "sellcs_plap_hvp_ref",
    "kmeans_assign", "kmeans_assign_ref",
    "flash_attention", "attention_ref",
]
