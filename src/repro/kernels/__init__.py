"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package has: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd dispatching wrapper) and ref.py (pure-jnp oracle).
All kernels are validated in interpret=True mode against their oracle
over shape/dtype sweeps in tests/test_kernels_*.py.
"""
from repro.kernels.bsr_spmm import bsr_spmm, bsr_spmm_ref
from repro.kernels.plap_edge import (
    plap_apply, plap_hvp_edge, plap_apply_ref, plap_hvp_edge_ref)
from repro.kernels.sellcs_spmm import (
    sellcs_spmm_pallas, sellcs_spmm_ref,
    sellcs_plap_apply_pallas, sellcs_plap_apply_ref,
    sellcs_plap_hvp_pallas, sellcs_plap_hvp_ref)
from repro.kernels.kmeans_assign import kmeans_assign, kmeans_assign_ref
from repro.kernels.flash_attention import flash_attention, attention_ref

__all__ = [
    "bsr_spmm", "bsr_spmm_ref", "plap_apply", "plap_hvp_edge",
    "plap_apply_ref", "plap_hvp_edge_ref",
    "sellcs_spmm_pallas", "sellcs_spmm_ref",
    "sellcs_plap_apply_pallas", "sellcs_plap_apply_ref",
    "sellcs_plap_hvp_pallas", "sellcs_plap_hvp_ref",
    "kmeans_assign", "kmeans_assign_ref",
    "flash_attention", "attention_ref",
]
