"""Version-tolerant wrappers over jax APIs that moved between releases.

The CI image pins jax 0.4.37; newer trees expose ``jax.shard_map`` /
``check_vma`` while 0.4.x has ``jax.experimental.shard_map.shard_map``
/ ``check_rep``.  Every call site in this repo imports from here so a
jax upgrade (or downgrade) is a one-file change.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):                        # jax >= 0.6
    _shard_map = jax.shard_map
else:                                                # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg normalised.

    ``check_vma`` (new name) and ``check_rep`` (0.4.x name) control the
    same static replication check; pass ``check_vma`` here and it is
    forwarded under whichever spelling the installed jax accepts.
    """
    if check_vma is not None:
        key = "check_vma" if "check_vma" in _SM_PARAMS else "check_rep"
        kwargs.setdefault(key, check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` (>= 0.4.38) / tree_util fallback."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new name) / ``TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` (>= 0.4.35) with a manual fallback."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    import numpy as np
    from jax.sharding import Mesh
    n = int(np.prod(axis_shapes))
    devices = np.asarray(jax.devices()[:n]).reshape(tuple(axis_shapes))
    return Mesh(devices, tuple(axis_names))
