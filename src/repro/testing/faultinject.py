"""Deterministic fault injection for the resilience layer (DESIGN.md §9).

Every injector is a context manager that patches ONE well-defined seam —
a registered solver driver, a registered grblas backend, the serve
engine's solve/churn hooks, or the dist halo exchange — and restores it
on exit.  Faults are counted, not random: ``at_call`` / ``max_calls``
select exactly which invocations fail, so a chaos test asserts a
specific recovery-ladder rung fires, not "something eventually broke".
``CHAOS_SEED`` (env var, see ``chaos_seed``) seeds whatever randomness
a test adds on top (graph draws, fault placement), keeping the whole
suite replayable.

Solver injectors patch ``registry._REGISTRY`` entries, which every
execution path resolves by name at call time (``p_continuation``,
``warm_start``, the guard's ``_run_levels``), so injected drivers are
seen by flat, guarded, multilevel and serve paths alike.  The backend
injector also snapshots and clears the jit trace-memo
(``registry._TRACE_CACHE``): cached compiled callables would otherwise
skip dispatch entirely and mask the fault (and entries compiled while
faulted would bake the failure in), so the cache is emptied on entry
and the pre-fault snapshot restored on exit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Iterable, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.solvers import registry
from repro.core.solvers.registry import SolverReport, SolverState
from repro.grblas import backends as _backends
from repro.grblas.backends import BackendUnavailableError
from repro.grblas.semiring import EdgeSemiring, PairEdgeSemiring
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace


def chaos_seed(default: int = 0) -> int:
    """The suite-wide seed: ``CHAOS_SEED`` env var, else ``default``.
    Chaos tests derive every random draw from it so a failing run
    reproduces with ``CHAOS_SEED=<n> make test-chaos``."""
    return int(os.environ.get("CHAOS_SEED", default))


@dataclasses.dataclass
class InjectionLog:
    """What actually fired: (site, detail) per injected fault.  Tests
    assert on it so a chaos test that silently injected nothing fails
    loudly instead of vacuously passing.

    Each ``record`` also draws a fresh injection id from
    ``obs.trace.begin_injection`` (stamping a ``fault.<site>`` instant
    on any active tracer) and bumps ``fault_injections_total{site=}`` on
    the DEFAULT metrics registry; the recovery ladder's trace events
    carry the same id (``obs.trace.current_injection``), so a chaos-run
    timeline reads fault → divergence → rungs as one correlated story."""

    events: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    ids: List[int] = dataclasses.field(default_factory=list)

    def record(self, site: str, detail: str = "") -> None:
        self.ids.append(_obs_trace.begin_injection(site, detail))
        _obs_metrics.DEFAULT.counter("fault_injections_total",
                                     site=site).inc()
        self.events.append((site, detail))

    def count(self, site: Optional[str] = None) -> int:
        if site is None:
            return len(self.events)
        return sum(1 for s, _ in self.events if s == site)


# ------------------------------------------------------------- solver seams

def _names(solvers) -> List[str]:
    if isinstance(solvers, str):
        return [solvers]
    return list(solvers)


@contextlib.contextmanager
def _patched_solvers(names: Iterable[str], wrap):
    """Swap each named registry entry for ``wrap(original_entry)`` —
    a (SolverState, call_index) -> SolverReport hook with a per-entry
    call counter — restoring the originals on exit."""
    saved = {}
    counters = {}
    try:
        for name in names:
            orig = registry.resolve_solver(name)
            saved[name] = orig
            counters[name] = 0

            def make(orig):
                def minimize(state: SolverState) -> SolverReport:
                    counters[orig.name] += 1
                    return wrap(orig, state, counters[orig.name])

                return minimize

            registry._REGISTRY[name] = dataclasses.replace(
                orig, minimize_at_p=make(orig))
        yield
    finally:
        for name, orig in saved.items():
            registry._REGISTRY[name] = orig


@contextlib.contextmanager
def nan_in_multivector(solvers="newton", *, at_call: int = 1,
                       max_calls: Optional[int] = 1,
                       log: Optional[InjectionLog] = None):
    """The named driver(s) return a NaN-poisoned multivector (and NaN
    fval) starting at their ``at_call``-th invocation, for ``max_calls``
    invocations (None = forever) — the blown-up-iterate failure mode.
    Calls outside the window run the real driver."""
    log = log if log is not None else InjectionLog()

    def wrap(orig, state, call):
        if call >= at_call and (max_calls is None
                                or call < at_call + max_calls):
            log.record("nan_in_multivector", f"{orig.name}@call{call}")
            U = jnp.full_like(jnp.asarray(state.U), jnp.nan)
            return SolverReport(U=U, fval=float("nan"), n_apply=0,
                                iters=0, converged=False)
        return orig.minimize_at_p(state)

    with _patched_solvers(_names(solvers), wrap):
        yield log


@contextlib.contextmanager
def solver_stall(solvers="newton", *, at_call: int = 1,
                 max_calls: Optional[int] = None,
                 log: Optional[InjectionLog] = None):
    """The named driver(s) return their input unchanged, unconverged —
    zero functional progress, the stall failure mode the guard's
    ``stall_levels`` counter exists for."""
    from repro.core import plap

    log = log if log is not None else InjectionLog()

    def wrap(orig, state, call):
        if call >= at_call and (max_calls is None
                                or call < at_call + max_calls):
            log.record("solver_stall", f"{orig.name}@call{call}")
            f = float(plap.value(state.W, jnp.asarray(state.U),
                                 float(state.p), state.cfg.eps,
                                 desc=state.cfg.descriptor()))
            return SolverReport(U=jnp.asarray(state.U), fval=f, n_apply=0,
                                iters=0, converged=False)
        return orig.minimize_at_p(state)

    with _patched_solvers(_names(solvers), wrap):
        yield log


@contextlib.contextmanager
def rank_collapse(solvers="newton", *, at_call: int = 1,
                  max_calls: Optional[int] = 1,
                  log: Optional[InjectionLog] = None):
    """The named driver(s) return an embedding whose last column
    duplicates the first — numerically rank-deficient, the
    left-the-Grassmann-chart failure mode."""
    log = log if log is not None else InjectionLog()

    def wrap(orig, state, call):
        rep = orig.minimize_at_p(state)
        if call >= at_call and (max_calls is None
                                or call < at_call + max_calls):
            log.record("rank_collapse", f"{orig.name}@call{call}")
            U = jnp.asarray(rep.U)
            U = U.at[:, -1].set(U[:, 0])
            return dataclasses.replace(rep, U=U)
        return rep

    with _patched_solvers(_names(solvers), wrap):
        yield log


# ------------------------------------------------------------ backend seams

@contextlib.contextmanager
def backend_fault(backend: str = "sellcs", *, edge_rings_only: bool = True,
                  log: Optional[InjectionLog] = None):
    """The named grblas backend raises ``BackendUnavailableError`` from
    its execute hook — the kernel-went-down failure mode.  With
    ``edge_rings_only`` (default) plain-semiring ops (the p=2 stage-1
    matvecs) still work and only the hot loop's edge-semiring ops fail,
    mirroring a broken Pallas kernel rather than a missing layout.

    The solver trace-memo is cleared for the duration (cached jitted
    callables would replay around dispatch and mask the fault) and the
    pre-fault snapshot is restored on exit, discarding anything compiled
    while the fault was live."""
    log = log if log is not None else InjectionLog()
    # pscheck: disable=api-boundary (fault injection swaps a backend's execute hook in place; the public registry API is read-only by design)
    orig = _backends._REGISTRY[backend]
    cache_snapshot = dict(registry._TRACE_CACHE)
    registry._TRACE_CACHE.clear()

    def execute(A, X, ring, desc):
        if not edge_rings_only or isinstance(ring, (EdgeSemiring,
                                                    PairEdgeSemiring)):
            log.record("backend_fault", f"{backend}:{ring.name}")
            raise BackendUnavailableError(
                f"injected fault: backend {backend!r} is down "
                f"(repro.testing.faultinject)")
        return orig.execute(A, X, ring, desc)

    # pscheck: disable=api-boundary (install the faulted hook; restored in the finally below)
    _backends._REGISTRY[backend] = dataclasses.replace(orig,
                                                       execute=execute)
    try:
        yield log
    finally:
        # pscheck: disable=api-boundary (restore the pre-fault backend record)
        _backends._REGISTRY[backend] = orig
        registry._TRACE_CACHE.clear()
        registry._TRACE_CACHE.update(cache_snapshot)


# -------------------------------------------------------------- serve seams

@contextlib.contextmanager
def serve_batch_fault(req_ids, *, exc: Optional[Exception] = None,
                      log: Optional[InjectionLog] = None):
    """The serve engine's batched bucket solve raises whenever the batch
    contains any of ``req_ids`` — the thrown-batch failure mode that
    exercises quarantine bisection (a NaN lane, by contrast, is caught
    by the per-lane finiteness check without a throw)."""
    from repro.serve import psc_engine as _eng

    log = log if log is not None else InjectionLog()
    bad = set(int(r) for r in np.atleast_1d(req_ids))

    def fault(pends):
        hit = [p.req_id for p in pends if p.req_id in bad]
        if hit:
            log.record("serve_batch_fault", f"req{hit}")
            raise (exc if exc is not None else
                   RuntimeError(f"injected batch fault (requests {hit})"))

    prev = _eng._SOLVE_FAULT
    _eng._SOLVE_FAULT = fault
    try:
        yield log
    finally:
        _eng._SOLVE_FAULT = prev


@contextlib.contextmanager
def serve_churn_fault(*, fail_attempts: int = 1,
                      exc: Optional[Exception] = None,
                      log: Optional[InjectionLog] = None):
    """The churn re-solve raises on its first ``fail_attempts`` attempts
    per request — the transient-fault mode the retry-with-backoff path
    exists for (``fail_attempts > churn_retries`` forces the cold-solve
    fallback)."""
    from repro.serve import psc_engine as _eng

    log = log if log is not None else InjectionLog()

    def fault(pend, attempt):
        if attempt < fail_attempts:
            log.record("serve_churn_fault",
                       f"req{pend.req_id}@attempt{attempt}")
            raise (exc if exc is not None else
                   RuntimeError(f"injected churn fault (attempt {attempt})"))

    prev = _eng._CHURN_FAULT
    _eng._CHURN_FAULT = fault
    try:
        yield log
    finally:
        _eng._CHURN_FAULT = prev


# --------------------------------------------------------------- dist seams

@contextlib.contextmanager
def halo_corruption(mode: str = "nan", *, shard: int = 0,
                    log: Optional[InjectionLog] = None):
    """Corrupt the received halo block inside the dist backend's
    shard-mapped exchange: ``mode="nan"`` poisons the rows received from
    ``shard`` (a corrupted wire payload), ``mode="drop"`` zeroes them (a
    dropped shard — the peer never answered).  jnp ops only: the hook
    runs traced inside shard_map."""
    from repro.grblas import dist as _dist

    if mode not in ("nan", "drop"):
        raise ValueError(f"mode must be 'nan' or 'drop', got {mode!r}")
    log = log if log is not None else InjectionLog()
    fill = jnp.nan if mode == "nan" else 0.0

    def hook(recv, Ap):
        log.record("halo_corruption", f"{mode}@shard{shard}")
        H = Ap.halo_width
        block = jnp.arange(recv.shape[0]) // max(H, 1)
        mask = (block == shard)
        return jnp.where(mask.reshape((-1,) + (1,) * (recv.ndim - 1)),
                         fill, recv)

    _dist.set_halo_fault_hook(hook)
    try:
        yield log
    finally:
        _dist.set_halo_fault_hook(None)
