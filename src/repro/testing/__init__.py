"""repro.testing — deterministic fault injection for the chaos suite
(DESIGN.md §9).  Production code never imports this package."""
from repro.testing.faultinject import (
    InjectionLog,
    backend_fault,
    chaos_seed,
    halo_corruption,
    nan_in_multivector,
    rank_collapse,
    serve_batch_fault,
    serve_churn_fault,
    solver_stall,
)

__all__ = [
    "InjectionLog", "backend_fault", "chaos_seed", "halo_corruption",
    "nan_in_multivector", "rank_collapse", "serve_batch_fault",
    "serve_churn_fault", "solver_stall",
]
