"""Hierarchical p-spectral solve: coarsest-level continuation + prolong
/ re-orthonormalize / refine up the hierarchy (DESIGN.md §6).

The flat solver's cost profile is (LOBPCG p=2 init) + (full Newton
continuation), both O(nnz) per iteration on the *fine* graph.  The
V-cycle moves both to the coarsest graph:

  1. run the complete flat pipeline (p=2 eigenvectors + the whole
     p-continuation down to p_target) on the coarsest level — the
     expensive small-p trust-region steps cost O(nnz_coarsest);
  2. walking back up, prolong U through the partition-of-unity
     prolongator (one ``api.mxm``), re-orthonormalize with thin QR (the
     Grassmann retraction of the prolonged subspace), and run a *few*
     refinement Newton steps — the tail of the p schedule, nested so
     each finer level only re-runs the last ``refine_p_steps`` p values
     it inherited already-converged iterates for;
  3. discretize + score on the finest graph exactly like the flat
     solver (labels, U, RCut/NCut all live on the caller's graph).

Entry point: ``PSCConfig(multilevel=MultilevelConfig(...))`` — routing
lives in ``core.psc.p_spectral_cluster``; this module never needs to be
imported directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.grblas import api
from repro.grblas.api import Descriptor
from repro.grblas.containers import SparseMatrix
from repro.multilevel.coarsen import build_hierarchy
from repro.obs import trace as _obs_trace

_T = Descriptor(transpose=True)


@dataclasses.dataclass(frozen=True)
class MultilevelConfig:
    """V-cycle shape: hierarchy caps + per-level refinement budget."""

    coarse_size: int = 2048         # stop coarsening at this many vertices
    max_levels: int = 12            # hierarchy depth cap (incl. finest)
    min_reduction: float = 0.9      # stagnation guard: stop when a step
                                    # keeps > this fraction of vertices
    match_rounds: int = 8           # handshake-HEM rounds per level
    match_max_agg: int = 4          # leaf-joining aggregate size cap
                                    # (coarsen.heavy_edge_matching)
    refine_newton_iters: int = 5    # RTR iterations per refined level
    refine_tcg_iters: int = 8       # inner tCG budget during refinement
    refine_p_steps: int = 2         # tail of the p schedule re-run per
                                    # refined level (1 = p_target only;
                                    # 2+ eases the prolonged iterate
                                    # back in through the last
                                    # continuation steps — measurably
                                    # closes the RCut gap to flat on
                                    # noisy graphs)
    coarse_solver: Optional[str] = None
                                    # solver driver for the coarsest-level
                                    # full continuation (core.solvers
                                    # registry name; None = the flat
                                    # config's own solver).  "scf" makes
                                    # the coarse solve a sequence of
                                    # cheap linear eigenproblems — the
                                    # intended per-level split: SCF
                                    # sweeps at the bottom, Newton
                                    # refinement at the top
    refine_solver: Optional[str] = None
                                    # solver driver for the per-level
                                    # refinement walking up (None = the
                                    # flat config's own solver)
    refine_top_frac: float = 0.25   # refine only levels with
                                    # n ≥ frac × n_finest (the finest
                                    # level always qualifies).  Deep
                                    # levels cost almost nothing to
                                    # refine in FLOPs but each pays a
                                    # full jit trace+compile for its
                                    # shapes — measured, the compile tax
                                    # dwarfed their compute; prolonging
                                    # straight through them loses no
                                    # measurable quality once the top
                                    # levels re-run the p tail
    sparsify: Any = "auto"          # coarse-level degree cap ("auto" |
                                    # None | int): volume-preserving
                                    # diagonal lumping that keeps
                                    # nnz_ℓ ∝ n_ℓ on expander-like
                                    # graphs that densify under
                                    # contraction (coarsen.py)


def _layout_kwargs(cfg) -> Optional[dict]:
    """Coarse graphs must carry whatever layout the pinned backend
    needs; "auto" relies on the from_coo auto policy (PR-3)."""
    if cfg.backend == "sellcs":
        return {"build_sellcs": True}
    if cfg.backend in ("bsr_pallas", "edge_pallas"):
        return {"build_bsr": True}
    if cfg.backend in ("ell", "dist"):
        return {"build_ell": True}
    return None


def _refine_cfg(cfg, ml: MultilevelConfig):
    return dataclasses.replace(
        cfg, multilevel=None, newton_iters=ml.refine_newton_iters,
        tcg_iters=ml.refine_tcg_iters, reorder="none",
        solver=ml.refine_solver or cfg.solver)


def _walk_up(hier, U, cfg, ml: MultilevelConfig, rec: dict):
    """Shared V-cycle ascent: from the coarsest-level iterate ``U``,
    prolong through every level and — on levels with
    n ≥ refine_top_frac × n_finest — re-orthonormalize (Grassmann
    retraction) and re-run the tail of the p schedule.  Deep levels are
    prolonged straight through: their refinement FLOPs are negligible
    but each distinct level shape pays a full jit trace+compile — the
    measured tax dwarfed the compute.

    ``rec`` accumulates p_path / fvals / hvps / reports / levels lists
    in place; returns the finest-level orthonormal U."""
    from repro.core import psc as _psc, solvers

    tail = _psc.p_schedule(cfg)[-max(int(ml.refine_p_steps), 1):]
    refine_cfg = _refine_cfg(cfg, ml)
    n_fine = hier.levels[0].W.n_rows
    for lev in range(hier.n_levels - 2, -1, -1):
        P = hier.prolongators[lev]
        Wl = hier.levels[lev].W
        refined = Wl.n_rows >= ml.refine_top_frac * n_fine
        with _obs_trace.ACTIVE.span("multilevel.refine", cat="multilevel",
                                    level=lev, n=Wl.n_rows, nnz=Wl.nnz,
                                    refined=refined,
                                    solver=refine_cfg.solver) as sp:
            U = api.mxm(P, U)                   # prolong: (n_lev, k)
            if not refined:
                continue
            refine_cfg.validate_backend(Wl)
            U = jnp.linalg.qr(U)[0]             # Grassmann retraction
            for p in tail:
                res = solvers.minimize_at_p(Wl, U, p, refine_cfg)
                U = res.U
                rec["p_path"].append(p)
                rec["fvals"].append(float(res.fval))
                rec["hvps"].append(int(res.n_apply))
                rec["reports"].append(res)
                rec["levels"].append({
                    "level": lev, "n_levels": hier.n_levels,
                    "n": Wl.n_rows, "nnz": Wl.nnz, "p": p,
                    "fval": float(res.fval), "n_hvp": int(res.n_apply),
                    "iters": int(res.iters), "solver": refine_cfg.solver})
            sp.fence(U)
    return jnp.linalg.qr(U)[0]


def _finalize(W: SparseMatrix, U, cfg, rec: dict, init_labels, init_rcut):
    """Finest-level discretization + metrics (identical to the flat
    solver's stage 3: metrics unchanged, permutation-free)."""
    from repro.core import kmeans as km, metrics
    from repro.core import psc as _psc

    key = jax.random.PRNGKey(cfg.seed)
    _, sub = jax.random.split(key)
    with _obs_trace.ACTIVE.span("kmeans", cat="psc", n=W.n_rows,
                                k=cfg.k) as sp:
        Xn = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True),
                             1e-12)
        labels, _ = km.kmeans(sub, Xn, cfg.k, restarts=cfg.kmeans_restarts,
                              iters=cfg.kmeans_iters)
        sp.fence(labels)
        rcut = float(metrics.rcut(W, labels, cfg.k))
        ncut = float(metrics.ncut(W, labels, cfg.k))
    return _psc.PSCResult(
        labels=np.asarray(labels), U=U, rcut=rcut, ncut=ncut,
        p_path=rec["p_path"], fvals=rec["fvals"], hvp_counts=rec["hvps"],
        init_labels=init_labels, init_rcut=init_rcut,
        levels=rec["levels"], reports=rec["reports"])


def multilevel_cluster(W: SparseMatrix, cfg, ml: MultilevelConfig
                       ) -> "Any":
    """Run the V-cycle under flat-config ``cfg`` (a PSCConfig whose
    ``multilevel`` field routed here).  Returns a PSCResult on the fine
    graph — same fields, same metrics, plus per-level refinement
    records in ``result.levels``."""
    from repro.core import metrics
    from repro.core import psc as _psc

    hier = build_hierarchy(W, coarse_size=ml.coarse_size,
                           max_levels=ml.max_levels,
                           min_reduction=ml.min_reduction,
                           rounds=ml.match_rounds,
                           layout_kwargs=_layout_kwargs(cfg),
                           sparsify=ml.sparsify,
                           max_agg=ml.match_max_agg)
    # per-level solver choice (DESIGN.md §7): the coarsest full solve
    # and the walk-up refinement each take their own registry driver
    flat_cfg = dataclasses.replace(
        cfg, multilevel=None, solver=ml.coarse_solver or cfg.solver)
    if hier.n_levels == 1:          # nothing to coarsen: flat solve
        return _psc.p_spectral_cluster(
            W, dataclasses.replace(cfg, multilevel=None))

    # -- coarsest level: the whole flat pipeline (p=2 LOBPCG init + full
    # p-continuation).  Its labels seed init_labels on the fine graph.
    with _obs_trace.ACTIVE.span("multilevel.coarse_solve", cat="multilevel",
                                n=hier.coarsest.W.n_rows,
                                nnz=hier.coarsest.W.nnz,
                                solver=flat_cfg.solver):
        res_c = _psc.p_spectral_cluster(hier.coarsest.W, flat_cfg)
    rec = {"p_path": list(res_c.p_path), "fvals": list(res_c.fvals),
           "hvps": list(res_c.hvp_counts),
           "reports": list(res_c.reports or []), "levels": []}

    U = _walk_up(hier, res_c.U, cfg, ml, rec)

    init_labels = hier.prolong_labels(np.asarray(res_c.labels))
    init_rcut = float(metrics.rcut(W, init_labels, cfg.k))
    return _finalize(W, U, cfg, rec, init_labels, init_rcut)


def refine_cluster(W: SparseMatrix, cfg, ml: MultilevelConfig,
                   hier: "Any", U0) -> "Any":
    """Refine-only V-cycle (DESIGN.md §8): re-cluster ``W`` starting
    from a previous solve's finest-level embedding ``U0`` instead of the
    coarsest-level flat pipeline.

    This is the incremental re-clustering path under edge churn: the
    serve layer patches the cached hierarchy against the edited graph
    (``coarsen.patch_hierarchy``), restricts the cached U down to the
    coarsest level (Pᵀ U — aggregate sums, one ``api.mxm`` per level),
    warm-enters the coarse driver at the END of the p schedule, and
    walks back up with the usual prolong + refine ascent.  The p=2
    LOBPCG init and the descent from p=2 are skipped entirely — the
    cached subspace already encodes the global structure, the V-cycle
    only has to relax it against the edited edges.

    ``hier`` must be a hierarchy of ``W`` itself (patched or freshly
    built); ``U0`` is (n, k) on the finest level.  Returns a PSCResult
    with ``init_labels=None`` (there is no linear init on this path).
    """
    from repro.core import psc as _psc, solvers

    U = jnp.asarray(U0)
    if U.shape != (W.n_rows, cfg.k):
        raise ValueError(
            f"refine_cluster: U0 shape {U.shape} != ({W.n_rows}, {cfg.k})")
    if hier.levels[0].W.n_rows != W.n_rows:
        raise ValueError("refine_cluster: hierarchy does not match W")
    rec = {"p_path": [], "fvals": [], "hvps": [], "reports": [],
           "levels": []}

    # -- restrict the cached embedding to the coarsest level: Pᵀ U is
    # the aggregate-sum restriction (partition-of-unity columns), the
    # subspace analogue of prolong_labels' constant-on-aggregates map.
    with _obs_trace.ACTIVE.span("multilevel.restrict", cat="multilevel",
                                n_levels=hier.n_levels) as sp:
        for P in hier.prolongators:
            U = api.mxm(P, U, desc=_T)
        U = jnp.linalg.qr(U)[0]
        sp.fence(U)

    # -- coarsest level: warm entry at the end of the p schedule under
    # the coarse driver (no LOBPCG, no continuation descent)
    coarse_cfg = dataclasses.replace(
        cfg, multilevel=None, reorder="none",
        solver=ml.coarse_solver or cfg.solver)
    coarse_cfg.validate_backend(hier.coarsest.W)
    with _obs_trace.ACTIVE.span("multilevel.coarse_solve", cat="multilevel",
                                n=hier.coarsest.W.n_rows,
                                nnz=hier.coarsest.W.nnz, warm=True,
                                solver=coarse_cfg.solver):
        U, p_path, fvals, hvps, reports = solvers.warm_start(
            hier.coarsest.W, U, coarse_cfg,
            steps=max(int(ml.refine_p_steps), 1))
    rec["p_path"] += p_path
    rec["fvals"] += fvals
    rec["hvps"] += hvps
    rec["reports"] += reports

    U = _walk_up(hier, U, cfg, ml, rec)
    return _finalize(W, U, cfg, rec, init_labels=None,
                     init_rcut=float("nan"))
