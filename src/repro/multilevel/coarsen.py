"""Graph coarsening: heavy-edge matching + Galerkin triple products.

One coarsening step contracts a matching M of the graph: matched pairs
(and unmatched singletons) become the coarse vertices, and the coarse
operator is the Galerkin triple product

    W_c = Pᵀ W P

with P the (n_fine × n_coarse) *partition-of-unity* prolongator —
exactly one entry of value 1 per fine row, column a = indicator of
aggregate a.  Both products are ``grblas.api.mxm`` calls through the
"spgemm" backend (DESIGN.md §6): no host linear-algebra library touches
the pipeline anywhere in this package, which a unit test asserts, because routing
the construction through the same execution API that serves the solve
is the point — a future distributed spgemm entry accelerates coarsening
with zero changes here.

Invariants (pinned in tests/test_multilevel.py):

  * partition of unity: every fine vertex belongs to exactly one
    aggregate with weight 1 (P · 1_c = 1_f);
  * volume preservation: self-loops created by contraction are KEPT, so
    Galerkin preserves weighted degrees exactly — ``W_c.row_sums() ==
    Pᵀ W.row_sums()`` and total volume is constant across levels (NCut
    volumes stay consistent); the p-Laplacian never sees the loops
    because φ_p(u_a - u_a) = 0;
  * node mass: ``counts`` (finest vertices per aggregate) is carried as
    Pᵀ 1 per level, so coarse balance terms can reproduce fine RCut
    denominators.

Matching: multi-round mutual-preference ("handshake") heavy-edge
matching — each live vertex prefers its heaviest incident edge, ties
broken degree-ordered (lower-degree neighbour first, then lower id);
mutual preferences contract, and the rounds repeat on the remainder.
This is the vectorizable formulation of greedy HEM used by parallel
multigrid codes; leftover vertices stay singletons.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.grblas import api
from repro.grblas.api import Descriptor
from repro.grblas.containers import SparseMatrix
from repro.obs import trace as _obs_trace

_T = Descriptor(transpose=True)


def heavy_edge_matching(W: SparseMatrix, rounds: int = 8,
                        max_agg: int = 4) -> np.ndarray:
    """Aggregate ids from handshake heavy-edge matching + leaf joining.

    Returns ``agg`` (n,) int64 with agg[i] ∈ [0, n_coarse).  Two phases,
    both vectorized and deterministic:

    1. *handshake HEM* (``rounds``×): every live vertex prefers its
       heaviest incident edge (ties: lower neighbour degree, then lower
       id); mutual preferences contract into pairs.
    2. *leaf joining*: vertices the handshake left single join the
       aggregate of their heaviest neighbour, capped at ``max_agg``
       members per aggregate (accepted heaviest-first).  Without this
       pass matching-resistant graphs (expanders, stars) shrink by only
       ~25% per level and the hierarchy never amortizes.
    """
    n = W.n_rows
    rows = np.asarray(W.rows, np.int64)
    cols = np.asarray(W.cols, np.int64)
    vals = np.asarray(W.vals)
    if vals.ndim != 1:
        raise ValueError("heavy_edge_matching needs scalar edge weights")
    off = rows != cols
    rows, cols, vals = rows[off], cols[off], vals[off]
    deg = np.bincount(rows, minlength=n)

    match = np.full(n, -1, np.int64)
    ids = np.arange(n, dtype=np.int64)
    for _ in range(max(int(rounds), 1)):
        live = (match[rows] < 0) & (match[cols] < 0)
        if not live.any():
            break
        r_l, c_l, v_l = rows[live], cols[live], vals[live]
        # per-row argmax by (weight desc, neighbour degree asc, id asc):
        # lexsort is keyed last-first, so rows is the primary key and the
        # best edge of each row lands first in its segment
        order = np.lexsort((c_l, deg[c_l], -v_l, r_l))
        r_s = r_l[order]
        uniq_rows, first = np.unique(r_s, return_index=True)
        pref = np.full(n, -1, np.int64)
        pref[uniq_rows] = c_l[order[first]]
        ok = pref >= 0
        mutual = ids[ok][pref[pref[ok]] == ids[ok]]
        lo = mutual[mutual < pref[mutual]]     # each pair once, from its
        hi = pref[lo]                          # lower endpoint
        match[lo] = hi
        match[hi] = lo
    rep = np.where((match >= 0) & (match < ids), match, ids)

    # -- phase 2: singletons join their heaviest neighbour's aggregate
    single = match < 0
    if single.any() and max_agg > 2:
        cand = single[rows] & ~single[cols]    # edges singleton -> matched
        if cand.any():
            r_c, c_c, v_c = rows[cand], cols[cand], vals[cand]
            order = np.lexsort((c_c, -v_c, r_c))
            r_s = r_c[order]
            uniq_rows, first = np.unique(r_s, return_index=True)
            target = rep[c_c[order[first]]]    # aggregate representative
            # size cap: accept heaviest joiners first per aggregate
            sizes = np.bincount(rep, minlength=n)   # current agg sizes
            w_best = v_c[order[first]]
            by_tgt = np.lexsort((uniq_rows, -w_best, target))
            tgt_s = target[by_tgt]
            t_counts = np.bincount(tgt_s, minlength=n)
            t_starts = np.concatenate([[0], np.cumsum(t_counts)[:-1]])
            rank = np.arange(len(tgt_s)) - np.repeat(
                t_starts[np.unique(tgt_s)],
                t_counts[np.unique(tgt_s)])
            slack = (max_agg - sizes)[tgt_s]
            accept = rank < slack
            rep[uniq_rows[by_tgt][accept]] = tgt_s[accept]

    # compact representative ids to [0, n_coarse)
    uniq_rep, agg = np.unique(rep, return_inverse=True)
    return agg


def prolongator_from_aggregates(agg: np.ndarray, n_coarse: int,
                                dtype=jnp.float32) -> SparseMatrix:
    """The partition-of-unity prolongator P (n_fine × n_coarse):
    P[i, agg[i]] = 1.  One entry per row, so SpMM through P is a pure
    gather and Pᵀ a segment fold — both served by the existing
    coo/ell backends; spgemm against it is linear time."""
    n = len(agg)
    return SparseMatrix.from_coo(np.arange(n), np.asarray(agg, np.int64),
                                 np.ones(n), (n, int(n_coarse)), dtype=dtype)


@dataclasses.dataclass
class CoarsenInfo:
    n_fine: int
    n_coarse: int
    agg: np.ndarray            # fine vertex -> aggregate id


@dataclasses.dataclass
class Level:
    W: SparseMatrix            # graph at this level (finest = level 0)
    vol: jnp.ndarray           # finest weighted-degree mass per vertex
    counts: jnp.ndarray        # finest vertices per vertex


@dataclasses.dataclass
class Hierarchy:
    levels: List[Level]                  # levels[0] is the finest
    prolongators: List[SparseMatrix]     # P[l]: level l+1 -> level l
    infos: List[CoarsenInfo]

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def coarsest(self) -> Level:
        return self.levels[-1]

    def aggregate_of_finest(self, level: int) -> np.ndarray:
        """Composed map: finest vertex -> its aggregate at ``level``."""
        agg = np.arange(self.levels[0].W.n_rows, dtype=np.int64)
        for info in self.infos[:level]:
            agg = info.agg[agg]
        return agg

    def prolong_labels(self, labels: np.ndarray) -> np.ndarray:
        """Coarsest labels -> finest labels (constant on aggregates —
        the fine-level label-consistency invariant)."""
        return np.asarray(labels)[self.aggregate_of_finest(self.n_levels - 1)]


def _sparsify_rowcap(rows, cols, vals, n, cap):
    """Per-row top-``cap`` edge filter with *diagonal compensation*.

    Mesh-like graphs keep nnz ∝ n under contraction, but expander-like
    graphs (SBM, social) densify: nodes halve, stored edges barely
    shrink, and the V-cycle stops paying off.  The multigrid remedy is
    to lump weak coarse edges onto the diagonal: each row keeps its
    ``cap`` heaviest off-diagonal entries (union over both endpoint
    rows, so symmetry survives) and every dropped entry's weight moves
    to that row's self-loop.  Row sums — the volume invariant — are
    preserved EXACTLY; the p-Laplacian ignores self-loops (φ_p(0) = 0),
    so only the weakest difference penalties are approximated, and the
    per-level fine refinement corrects the error.  Deterministic:
    ranking ties break by column id.
    """
    off = rows != cols
    ro, co, vo = rows[off], cols[off], vals[off]
    # rank each row's off-diag entries by (weight desc, col asc)
    order = np.lexsort((co, -vo, ro))
    ro_s = ro[order]
    counts = np.bincount(ro_s, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(len(ro_s)) - np.repeat(starts, counts)
    keep_dir = np.empty(len(ro), bool)
    keep_dir[order] = rank < cap
    # symmetric union: an edge survives if either endpoint ranks it
    lo = np.minimum(ro, co)
    hi = np.maximum(ro, co)
    key = lo * n + hi
    uniq, inv = np.unique(key, return_inverse=True)
    kept_pair = np.zeros(len(uniq), bool)
    np.logical_or.at(kept_pair, inv, keep_dir)
    keep = kept_pair[inv]
    # lump dropped weight onto each directed copy's own row diagonal
    lump = np.bincount(ro[~keep], weights=vo[~keep], minlength=n)
    diag_rows = rows[~off]
    diag_vals = np.bincount(diag_rows, weights=vals[~off], minlength=n) + lump
    dnz = np.nonzero(diag_vals)[0]
    return (np.concatenate([ro[keep], dnz]),
            np.concatenate([co[keep], dnz]),
            np.concatenate([vo[keep], diag_vals[dnz]]))


def coarsen_graph(W: SparseMatrix, rounds: int = 8,
                  layout_kwargs: Optional[dict] = None,
                  sparsify_cap: Optional[int] = None,
                  max_agg: int = 4,
                  ) -> Tuple[SparseMatrix, SparseMatrix, CoarsenInfo]:
    """One coarsening step: (P, W_c, info).

    W_c = Pᵀ (W P), both factors through ``api.mxm`` (spgemm backend);
    the product is then rebuilt through ``from_coo`` so the coarse graph
    auto-builds the same derived layouts a fine graph would (ELL, and
    SELL-C-σ once contraction skews the degree distribution past the
    auto threshold — the PR-3 policy).

    ``sparsify_cap``: keep at most this many off-diagonal entries per
    coarse row (volume-preserving diagonal lumping, see
    ``_sparsify_rowcap``); None = exact Galerkin operator.
    """
    agg = heavy_edge_matching(W, rounds=rounds, max_agg=max_agg)
    n_coarse = int(agg.max()) + 1 if len(agg) else 0
    P = prolongator_from_aggregates(agg, n_coarse, dtype=W.vals.dtype)
    WP = api.mxm(W, P)                          # spgemm: (n_f × n_c)
    Wc = api.mxm(P, WP, desc=_T)                # spgemm: Pᵀ (W P)
    rows = np.asarray(Wc.rows, np.int64)
    cols = np.asarray(Wc.cols, np.int64)
    vals = np.asarray(Wc.vals)
    if sparsify_cap is not None:
        rows, cols, vals = _sparsify_rowcap(rows, cols, vals, n_coarse,
                                            int(sparsify_cap))
    kw = dict(layout_kwargs or {})
    kw.setdefault("dtype", W.vals.dtype)
    Wc = SparseMatrix.from_coo(rows, cols, vals, (n_coarse, n_coarse), **kw)
    return P, Wc, CoarsenInfo(n_fine=W.n_rows, n_coarse=n_coarse, agg=agg)


def auto_sparsify_cap(W: SparseMatrix) -> int:
    """Degree cap for coarse-level sparsification: the finest graph's
    mean stored degree, floored at 12.  Mesh-like graphs (coarse degree
    ≈ fine degree ≈ 6-9) sit under the floor and never get filtered;
    expander-like graphs that densify under contraction get nnz_ℓ ∝ n_ℓ
    back (the union keep-rule lands the realized degree near 2× cap)."""
    mean_deg = W.nnz / max(W.n_rows, 1)
    return max(int(np.ceil(mean_deg)), 12)


def patch_hierarchy(hier: Hierarchy, W_new: SparseMatrix,
                    touched: np.ndarray, rounds: int = 8,
                    max_agg: int = 4,
                    layout_kwargs: Optional[dict] = None,
                    sparsify="auto") -> Tuple[Hierarchy, List[dict]]:
    """Rebuild a hierarchy for an *edited* graph by reusing the old
    matching everywhere the edit cannot have reached (DESIGN.md §8).

    ``touched`` lists the finest-level vertices incident to pattern
    deltas (added or removed edges).  At every level only vertices
    within graph distance 1 of a touched vertex are re-matched; every
    aggregate containing none of them keeps its old membership — its
    prolongator rows are bit-identical up to the id compaction.  The
    Galerkin products Pᵀ W P are recomputed at every level (the edge
    *weights* changed, so they must be), but those are linear-time
    spgemms; what this function avoids re-running is the multi-round
    handshake matching, which is the host-side cost of
    ``build_hierarchy`` — and, more importantly downstream, a patched
    hierarchy keeps aggregate ids stable on the untouched region so the
    cached embedding restricts onto it coherently.

    New aggregates born from a local re-match are marked touched at the
    next level up (their coarse pattern is new), so the dirty set
    contracts with the graph instead of spreading.

    Returns (hierarchy, records): one record per level with the dirty /
    re-matched counts, for ServeStats and the churn benchmark.
    """
    if sparsify == "auto":
        cap = auto_sparsify_cap(W_new)
    elif sparsify is None or sparsify is False:
        cap = None
    else:
        cap = int(sparsify)
        if cap < 1:
            raise ValueError(f"sparsify cap must be >= 1, got {cap}")
    if W_new.n_rows != hier.levels[0].W.n_rows:
        raise ValueError("patch_hierarchy: vertex count changed; rebuild "
                         "the hierarchy instead")
    W = W_new
    vol = W.row_sums()
    counts = jnp.ones(W.n_rows, W.vals.dtype)
    levels = [Level(W=W, vol=vol, counts=counts)]
    prolongators: List[SparseMatrix] = []
    infos: List[CoarsenInfo] = []
    records: List[dict] = []
    kw = dict(layout_kwargs or {})

    touched = np.unique(np.asarray(touched, np.int64))
    new2old = np.arange(W.n_rows, dtype=np.int64)   # level-l new -> old id
    for info in hier.infos:
        n = W.n_rows
        rows = np.asarray(W.rows, np.int64)
        cols = np.asarray(W.cols, np.int64)
        dirty = np.zeros(n, bool)
        dirty[touched] = True
        dirty[cols[dirty[rows]]] = True             # distance-1 closure
        dirty |= new2old < 0                        # freshly born vertices

        # dissolve every old aggregate with a dirty (or vanished) member
        old_agg = info.agg
        bad = np.zeros(info.n_coarse, bool)
        present = np.zeros(info.n_fine, bool)
        present[new2old[new2old >= 0]] = True
        bad[old_agg[~present]] = True
        bad[old_agg[new2old[dirty & (new2old >= 0)]]] = True
        has_old = new2old >= 0
        dirty[has_old] |= bad[old_agg[new2old[has_old]]]

        # clean vertices keep their old aggregate (compacted ids first)
        kept_old = np.unique(old_agg[new2old[~dirty]]) if (~dirty).any() \
            else np.empty(0, np.int64)
        remap = np.full(info.n_coarse, -1, np.int64)
        remap[kept_old] = np.arange(len(kept_old))
        agg = np.empty(n, np.int64)
        agg[~dirty] = remap[old_agg[new2old[~dirty]]]

        # dirty vertices re-match on their induced subgraph
        d_ids = np.nonzero(dirty)[0]
        n_new_aggs = 0
        if len(d_ids):
            sub_id = np.full(n, -1, np.int64)
            sub_id[d_ids] = np.arange(len(d_ids))
            both = dirty[rows] & dirty[cols]
            Wsub = SparseMatrix.from_coo(
                sub_id[rows[both]], sub_id[cols[both]],
                np.asarray(W.vals)[both], (len(d_ids), len(d_ids)),
                dtype=W.vals.dtype)
            agg_sub = heavy_edge_matching(Wsub, rounds=rounds,
                                          max_agg=max_agg)
            n_new_aggs = int(agg_sub.max()) + 1 if len(agg_sub) else 0
            agg[d_ids] = len(kept_old) + agg_sub
        n_coarse = len(kept_old) + n_new_aggs

        P = prolongator_from_aggregates(agg, n_coarse, dtype=W.vals.dtype)
        WP = api.mxm(W, P)
        Wc = api.mxm(P, WP, desc=_T)
        r2, c2, v2 = (np.asarray(Wc.rows, np.int64),
                      np.asarray(Wc.cols, np.int64), np.asarray(Wc.vals))
        if cap is not None:
            r2, c2, v2 = _sparsify_rowcap(r2, c2, v2, n_coarse, cap)
        kw2 = dict(kw)
        kw2.setdefault("dtype", W.vals.dtype)
        Wc = SparseMatrix.from_coo(r2, c2, v2, (n_coarse, n_coarse), **kw2)
        cur = levels[-1]
        vol_c = api.mxm(P, cur.vol, desc=_T)
        cnt_c = api.mxm(P, cur.counts, desc=_T)
        levels.append(Level(W=Wc, vol=vol_c, counts=cnt_c))
        prolongators.append(P)
        infos.append(CoarsenInfo(n_fine=n, n_coarse=n_coarse, agg=agg))
        records.append({"n": n, "n_coarse": n_coarse,
                        "n_dirty": int(dirty.sum()),
                        "n_rematched": len(d_ids),
                        "n_kept_aggregates": len(kept_old)})

        # next level: kept aggregates correspond to old coarse ids,
        # re-matched ones are new pattern -> touched above
        new2old = np.concatenate(
            [kept_old, np.full(n_new_aggs, -1, np.int64)])
        touched = np.arange(len(kept_old), n_coarse, dtype=np.int64)
        W = Wc
    return Hierarchy(levels=levels, prolongators=prolongators,
                     infos=infos), records


def build_hierarchy(W: SparseMatrix, coarse_size: int = 2048,
                    max_levels: int = 12, min_reduction: float = 0.9,
                    rounds: int = 8,
                    layout_kwargs: Optional[dict] = None,
                    sparsify="auto", max_agg: int = 4) -> Hierarchy:
    """Coarsen repeatedly until ≤ ``coarse_size`` vertices, ``max_levels``
    levels, or a step shrinks the graph by less than ``1 -
    min_reduction`` (stagnation guard for matching-resistant graphs).

    ``sparsify``: "auto" caps coarse row degrees at
    ``auto_sparsify_cap(W)`` via volume-preserving diagonal lumping;
    None disables (exact Galerkin at every level); an int is an
    explicit cap.

    Volumes and node counts are carried through every level as Pᵀ v —
    mxm calls like everything else — so the invariant chain
    vol_L = Pᵀ_{L-1} … Pᵀ_0 vol_0 holds by construction (sparsification
    preserves row sums exactly, so it never breaks the chain).
    """
    if sparsify == "auto":
        cap = auto_sparsify_cap(W)
    elif sparsify is None or sparsify is False:   # off (NOT int 0 — that
        cap = None                                # would silently mean
    else:                                         # "drop every edge")
        cap = int(sparsify)
        if cap < 1:
            raise ValueError(f"sparsify cap must be >= 1, got {cap}")
    vol = W.row_sums()
    counts = jnp.ones(W.n_rows, W.vals.dtype)
    levels = [Level(W=W, vol=vol, counts=counts)]
    prolongators: List[SparseMatrix] = []
    infos: List[CoarsenInfo] = []
    with _obs_trace.ACTIVE.span("multilevel.coarsen", cat="multilevel",
                                n=W.n_rows, nnz=W.nnz) as outer:
        while (levels[-1].W.n_rows > coarse_size
               and len(levels) < max(int(max_levels), 1)):
            cur = levels[-1]
            with _obs_trace.ACTIVE.span(
                    "multilevel.coarsen_level", cat="multilevel",
                    level=len(levels) - 1, n=cur.W.n_rows,
                    nnz=cur.W.nnz) as sp:
                P, Wc, info = coarsen_graph(cur.W, rounds=rounds,
                                            layout_kwargs=layout_kwargs,
                                            sparsify_cap=cap,
                                            max_agg=max_agg)
                if info.n_coarse >= min_reduction * info.n_fine:
                    break                        # matching stagnated
                vol_c = api.mxm(P, cur.vol, desc=_T)  # Pᵀ vol (restriction)
                cnt_c = api.mxm(P, cur.counts, desc=_T)
                sp.set(n_coarse=int(info.n_coarse))
            levels.append(Level(W=Wc, vol=vol_c, counts=cnt_c))
            prolongators.append(P)
            infos.append(info)
        outer.set(n_levels=len(levels))
    return Hierarchy(levels=levels, prolongators=prolongators, infos=infos)
