"""Multilevel coarsening + hierarchical p-spectral solve (DESIGN.md §6).

The flat solver touches the full graph every Newton iteration, so
wall-clock grows linearly in nnz no matter how fast the SpMM kernels
get.  This subsystem makes the paper's 8M-node regime tractable on one
host the way the multigrid/p-spectral literature does (Pasadakis et
al.; Hein & Bühler): coarsen the graph with heavy-edge matching, run
the expensive small-p continuation on the coarsest graph, then prolong
the eigenvectors level-by-level with a few cheap refinement Newton
steps per level.

Coarsening is itself a GraphBLAS computation — the Galerkin coarse
operator is the triple product Pᵀ W P, two ``grblas.api.mxm`` calls
through the spgemm backend — so every coarse graph inherits the full
layout/backend machinery (SELL-C-σ auto-build, descriptor dispatch)
for free.
"""
from repro.multilevel.coarsen import (
    CoarsenInfo,
    Hierarchy,
    Level,
    build_hierarchy,
    coarsen_graph,
    heavy_edge_matching,
    patch_hierarchy,
    prolongator_from_aggregates,
)
from repro.multilevel.vcycle import (MultilevelConfig, multilevel_cluster,
                                     refine_cluster)

__all__ = [
    "CoarsenInfo", "Hierarchy", "Level", "build_hierarchy", "coarsen_graph",
    "heavy_edge_matching", "patch_hierarchy", "prolongator_from_aggregates",
    "MultilevelConfig", "multilevel_cluster", "refine_cluster",
]
