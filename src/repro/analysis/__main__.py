"""pscheck CLI: ``python -m repro.analysis [paths] [options]``.

Exit status: 0 clean, 1 unbaselined findings or stale baseline entries
(shrink-only: a fixed violation whose ledger entry remains is an error
too), 2 usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro import analysis


def _default_paths():
    here = Path(__file__).resolve()
    return [str(here.parents[1])]       # src/repro


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="pscheck: AST invariant analysis for the GraphBLAS "
                    "stack (DESIGN.md §11)")
    ap.add_argument("paths", nargs="*", help="files/dirs (default: the "
                                             "repro package)")
    ap.add_argument("--rules", help="comma-separated rule ids (default: all)")
    ap.add_argument("--baseline", type=Path,
                    help="baseline JSON; findings in it pass, stale "
                         "entries fail (shrink-only)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline with the current findings")
    ap.add_argument("--fix", action="store_true",
                    help="apply per-rule fixers in place, then re-analyze")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in sorted(analysis.registered_rules().items()):
            fx = "  [has fixer]" if rule.fix else ""
            print(f"{rid:24s} {rule.summary}{fx}")
        return 0

    paths = args.paths or _default_paths()
    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)

    if args.fix:
        changed = analysis.apply_fixes(paths, rules)
        for p in changed:
            print(f"fixed: {p}", file=sys.stderr)

    findings = analysis.run(paths, rules)

    if args.update_baseline:
        if args.baseline is None:
            ap.error("--update-baseline requires --baseline")
        analysis.write_baseline(findings, args.baseline)
        print(f"baseline written: {args.baseline} "
              f"({len(findings)} entries)", file=sys.stderr)
        return 0

    stale = []
    if args.baseline is not None and args.baseline.exists():
        findings, stale = analysis.apply_baseline(
            findings, analysis.load_baseline(args.baseline))

    if args.as_json:
        print(json.dumps({
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "col": f.col, "message": f.message,
                 "severity": f.severity, "symbol": f.symbol}
                for f in findings],
            "stale_baseline": [list(k) for k in stale]}, indent=2))
    else:
        for f in findings:
            print(f.format())
        for k in stale:
            print(f"stale baseline entry (shrink the ledger): "
                  f"[{k[0]}] {k[1]}: {k[3]}")
        n = len(findings) + len(stale)
        print(f"pscheck: {len(findings)} finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'}"
              if n else "pscheck: clean", file=sys.stderr)

    return 1 if (findings or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
