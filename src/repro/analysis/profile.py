"""The repo profile: which invariant applies where.

Rules are generic AST checks; this module pins them to the repo's
actual architecture (DESIGN.md §3-§10).  Paths are module-relative
("core/plap.py" — see ``core.module_rel``), matched by prefix, so the
tables read like the package tree.  Fixture files used by the
self-tests fall outside every scope and get the permissive default —
scoped rules are exercised there by naming paths that *look* scoped
(tests construct ModuleContexts with synthetic paths).
"""
from __future__ import annotations

from typing import Iterable

# ---------------------------------------------------------- purity scopes
# Modules forming the solver/kernel hot path: everything here executes
# (or is traced into) the Newton/Grassmann continuation, so host math
# libraries are banned outright — not just inside traced scopes.
SCIPY_BAN = (
    "core/solvers/",
    "core/plap.py",
    "core/grassmann.py",
    "core/lobpcg.py",
    "core/kmeans.py",
    "core/phi.py",
    "multilevel/",
    "kernels/",
    "grblas/semiring.py",
    "serve/bucketing.py",
    "serve/psc_engine.py",
)

# Pure-device modules: numpy itself is banned (jnp only).  Host-side
# assembly modules (containers, coarsen, serve queueing) legitimately
# use numpy and are NOT listed — there the traced-scope check applies.
NUMPY_BAN = (
    "core/plap.py",
    "core/grassmann.py",
    "core/lobpcg.py",
    "core/kmeans.py",
    "core/phi.py",
    "kernels/",
)

# Galerkin products must route api.mxm: no dense matrix products.
DENSE_MATMUL_BAN = ("multilevel/",)

# ------------------------------------------------------- boundary scopes
# Raw jax.ops.segment_sum is the algebra's private reduction: only the
# grblas package may touch it.
SEGMENT_SUM_ALLOWED = ("grblas/",)

# The sparse kernel packages are grblas implementation detail — callers
# go through api.mxm/mxv/vxm.  (flash_attention / kmeans_assign are
# dense model kernels outside the GraphBLAS boundary.)
SPARSE_KERNEL_PKGS = ("bsr_spmm", "plap_edge", "sellcs_spmm")
KERNEL_IMPORT_ALLOWED = ("grblas/", "kernels/")

# Backend registry internals (grblas.backends._REGISTRY et al.) are
# private to the package.
BACKEND_PRIVATE_ALLOWED = ("grblas/",)

# ------------------------------------------------------ pad-fold scopes
# Modules that handle padded sparse layouts (ELL / SELL-C-σ / halo):
# raw reductions over a pad axis here must be masked, registered as a
# ring fast path, or capability-gated (inline-suppressed with the gate
# named).
PAD_FOLD_SCOPE = (
    "grblas/backends.py",
    "grblas/dist.py",
    "grblas/semiring.py",
    "kernels/bsr_spmm/",
    "kernels/plap_edge/",
    "kernels/sellcs_spmm/",
)

# ----------------------------------------------------------- dtype scopes
# Device-feeding subsystems: 64-bit dtypes silently double memory and
# defeat the int32 index layout (PR-3) when x64 is enabled, so any
# float64/int64 mention here is explicit debt.
DTYPE_SCOPE = (
    "grblas/",
    "kernels/",
    "core/",
    "multilevel/",
    "serve/psc_engine.py",
    "serve/bucketing.py",
)

# Layout-build functions must pin dtypes on every array constructor
# (np default int64/float64 is exactly the silent promotion).
LAYOUT_BUILD_PREFIXES = ("_build_",)
LAYOUT_BUILD_MODULES = ("grblas/containers.py",)

# ----------------------------------------------------- registry locations
BACKEND_REGISTRY_MODULE = "grblas/backends.py"
SOLVER_REGISTRY_MODULE = "core/solvers/registry.py"
SOLVER_PKG = "core/solvers/"


def in_scope(rel: str, prefixes: Iterable[str]) -> bool:
    return any(rel.startswith(p) for p in prefixes)


def is_sparse_kernel_module(rel: str) -> bool:
    return (rel.startswith("kernels/")
            and len(rel.split("/")) > 1
            and rel.split("/")[1] in SPARSE_KERNEL_PKGS)
