"""pscheck — AST-based invariant analysis for the jax_pallas GraphBLAS
stack (DESIGN.md §11).

Library::

    from repro import analysis
    findings = analysis.run(["src/repro"])            # every rule
    analysis.assert_clean(paths, rules=["hot-purity"])  # pytest facing

CLI::

    python -m repro.analysis src/repro --baseline pscheck_baseline.json

The rule catalogue, suppression syntax (``# pscheck: disable=<rule>
(reason)``) and the shrink-only baseline contract are documented in
DESIGN.md §11; per-rule invariants live on the Rule objects
(``registered_rules()[id].invariant``).
"""
from repro.analysis.core import (  # noqa: F401
    Finding,
    ModuleContext,
    ProjectContext,
    Rule,
    apply_baseline,
    apply_fixes,
    assert_clean,
    collect_files,
    load_baseline,
    module_rel,
    register_rule,
    registered_rules,
    resolve_rules,
    run,
    write_baseline,
)
