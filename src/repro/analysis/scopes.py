"""Traced-scope detection: which function bodies run under a JAX trace.

The host-sync, retrace, and purity rules all need the same structural
fact — "this statement executes inside jit/vmap/scan/pallas_call", i.e.
its values are tracers, not numbers.  ``ScopeInfo`` computes a
conservative per-module approximation once, shared via
``ModuleContext.scopes``:

1. a def/lambda is traced when it is decorated with a tracing transform
   (``@jax.jit``, ``@partial(jax.jit, ...)``), or passed to one
   (``jax.jit(run)``, ``lax.scan(body, ...)``, ``pl.pallas_call(kernel,
   ...)``, incl. through ``functools.partial``);
2. a def nested inside a traced def is traced;
3. a module-level def *called* from a traced body is traced (same-module
   call-graph closure — cross-module closure is deliberately out of
   scope, the callee module is scanned on its own).

The approximation is conservative in the safe direction: code we cannot
prove traced is treated as host code, so every flag the dependent rules
raise is on a line that genuinely executes under a trace.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

# call targets whose callable arguments are traced
TRACING_CALLS = frozenset({
    "jax.jit", "jit",
    "jax.vmap", "vmap", "jax.pmap", "pmap",
    "jax.lax.scan", "lax.scan",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.switch", "lax.switch",
    "jax.lax.map", "lax.map",
    "jax.lax.associative_scan", "lax.associative_scan",
    "pl.pallas_call", "pallas_call",
    "jax.checkpoint", "jax.remat",
    "jax.grad", "jax.value_and_grad", "jax.jacfwd", "jax.jacrev",
    "jax.vjp", "jax.jvp", "jax.linearize",
    "shard_map", "jax.experimental.shard_map.shard_map",
})

# the subset that compiles a fresh executable per *callable object*
JIT_CALLS = frozenset({"jax.jit", "jit", "pl.pallas_call", "pallas_call"})

PALLAS_CALLS = frozenset({"pl.pallas_call", "pallas_call"})

PARTIAL_CALLS = frozenset({"partial", "functools.partial", "ft.partial"})


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def unwrap_partial(node: ast.AST) -> ast.AST:
    """partial(f, ...) -> f (recursively)."""
    while (isinstance(node, ast.Call)
           and dotted_name(node.func) in PARTIAL_CALLS and node.args):
        node = node.args[0]
    return node


def is_tracing_decorator(dec: ast.AST) -> bool:
    name = dotted_name(dec)
    if name in TRACING_CALLS:
        return True
    if isinstance(dec, ast.Call):
        fn = dotted_name(dec.func)
        if fn in TRACING_CALLS:
            return True
        if fn in PARTIAL_CALLS and dec.args:
            return dotted_name(dec.args[0]) in TRACING_CALLS
    return False


_DEF_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


class ScopeInfo:
    """Per-module traced-scope map (see module docstring for the rules)."""

    def __init__(self, ctx):
        self.ctx = ctx
        self.defs: List[ast.AST] = [
            n for n in ast.walk(ctx.tree) if isinstance(n, _DEF_NODES)]
        self._by_name: Dict[str, List[ast.AST]] = {}
        for d in self.defs:
            if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._by_name.setdefault(d.name, []).append(d)
        # lambdas bound to a simple name participate in name lookup too
        for n in ast.walk(ctx.tree):
            if (isinstance(n, ast.Assign) and len(n.targets) == 1
                    and isinstance(n.targets[0], ast.Name)
                    and isinstance(n.value, ast.Lambda)):
                self._by_name.setdefault(n.targets[0].id, []).append(n.value)
        self.traced: Set[int] = set()
        self.pallas: Set[int] = set()
        self._locals: Dict[int, Set[str]] = {}
        self._build()

    # ------------------------------------------------------------- build
    def _mark(self, node: ast.AST, pallas: bool = False) -> bool:
        node = unwrap_partial(node)
        changed = False
        if isinstance(node, _DEF_NODES):
            if id(node) not in self.traced:
                self.traced.add(id(node))
                changed = True
            if pallas and id(node) not in self.pallas:
                self.pallas.add(id(node))
                changed = True
        elif isinstance(node, ast.Name):
            for d in self._by_name.get(node.id, []):
                if id(d) not in self.traced:
                    self.traced.add(id(d))
                    changed = True
                if pallas:
                    self.pallas.add(id(d))
        return changed

    def _build(self) -> None:
        # seeds: decorators and callable args of tracing entry points
        for d in self.defs:
            for dec in getattr(d, "decorator_list", []):
                if is_tracing_decorator(dec):
                    self._mark(d)
        for n in ast.walk(self.ctx.tree):
            if not isinstance(n, ast.Call):
                continue
            fn = dotted_name(n.func)
            if fn not in TRACING_CALLS:
                continue
            pallas = fn in PALLAS_CALLS
            for arg in list(n.args) + [kw.value for kw in n.keywords]:
                self._mark(arg, pallas=pallas)
        # closure: nested defs + same-module callees of traced bodies
        changed = True
        while changed:
            changed = False
            for d in self.defs:
                if id(d) not in self.traced:
                    continue
                for sub in ast.walk(d):
                    if isinstance(sub, _DEF_NODES) and sub is not d:
                        if id(sub) not in self.traced:
                            self.traced.add(id(sub))
                            changed = True
                    if isinstance(sub, ast.Call):
                        callee = sub.func
                        if (isinstance(callee, ast.Name)
                                and callee.id in self._by_name):
                            for cd in self._by_name[callee.id]:
                                if id(cd) not in self.traced:
                                    self.traced.add(id(cd))
                                    changed = True

    # ------------------------------------------------------------ queries
    def is_traced_def(self, node: ast.AST) -> bool:
        return id(node) in self.traced

    def is_pallas_def(self, node: ast.AST) -> bool:
        return id(node) in self.pallas

    def enclosing_traced(self, node: ast.AST) -> Optional[ast.AST]:
        """The nearest enclosing *traced* def of ``node``, or None when
        the statement runs on the host."""
        d = self.ctx.enclosing_def(node)
        while d is not None:
            if self.is_traced_def(d):
                return d
            d = self.ctx.enclosing_def(d)
        return None

    def locals_of(self, d: ast.AST) -> Set[str]:
        """Names bound inside def ``d`` (params + assignments + loop
        targets).  Values these names carry are tracers when ``d`` is
        traced; names *not* in this set are closure constants."""
        cached = self._locals.get(id(d))
        if cached is not None:
            return cached
        names: Set[str] = set()
        args = getattr(d, "args", None)
        if args is not None:
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                names.add(a.arg)
            for a in (args.vararg, args.kwarg):
                if a is not None:
                    names.add(a.arg)

        def collect_target(t):
            for sub in ast.walk(t):
                if isinstance(sub, ast.Name):
                    names.add(sub.id)

        body = d.body if isinstance(d.body, list) else [d.body]
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        collect_target(t)
                elif isinstance(sub, (ast.AugAssign, ast.AnnAssign,
                                      ast.For, ast.AsyncFor)):
                    collect_target(sub.target)
                elif isinstance(sub, ast.withitem):
                    if sub.optional_vars is not None:
                        collect_target(sub.optional_vars)
                elif isinstance(sub, ast.comprehension):
                    collect_target(sub.target)
        self._locals[id(d)] = names
        return names
