"""API-boundary enforcement (DESIGN.md §3).

Every SpMM-shaped operation goes through ``api.mxm/mxv/vxm`` under a
``Descriptor`` — that is the whole point of the unified execution API
(PR-2) and the reason new layouts are one ``register_backend`` call.
Three leak shapes are flagged:

* raw ``jax.ops.segment_sum`` outside ``grblas/`` — the algebra's
  private reduction; outside the package it bypasses ring dispatch
  (and was PR-2's original bug: silent segment_sum for non-additive
  monoids);
* importing the sparse kernel packages (``kernels/bsr_spmm``,
  ``plap_edge``, ``sellcs_spmm``) outside ``grblas/`` — kernels are
  backend implementation detail, reachable only via Descriptor;
* touching ``grblas.backends`` privates (``_REGISTRY``) outside the
  package.
"""
from __future__ import annotations

import ast

from repro.analysis import profile
from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import dotted_name


def _check_boundary(ctx):
    rel = ctx.rel
    in_grblas = profile.in_scope(rel, profile.SEGMENT_SUM_ALLOWED)
    kernels_ok = profile.in_scope(rel, profile.KERNEL_IMPORT_ALLOWED)

    for n in ast.walk(ctx.tree):
        # raw segment reduction outside the algebra package
        if not in_grblas and isinstance(n, ast.Attribute) \
                and n.attr == "segment_sum":
            yield ctx.finding(
                "api-boundary", n,
                "raw jax.ops.segment_sum outside grblas/ — SpMM-shaped "
                "reductions go through api.mxm under a ring (PR-2 "
                "contract; raw segment_sum is wrong for non-additive "
                "monoids)")
        # sparse kernel imports outside grblas/
        if not kernels_ok and isinstance(n, (ast.Import, ast.ImportFrom)):
            mods = ([a.name for a in n.names] if isinstance(n, ast.Import)
                    else [n.module or ""])
            for mod in mods:
                parts = mod.split(".")
                if (len(parts) >= 3 and parts[0] == "repro"
                        and parts[1] == "kernels"
                        and parts[2] in profile.SPARSE_KERNEL_PKGS):
                    yield ctx.finding(
                        "api-boundary", n,
                        f"direct import of sparse kernel package "
                        f"{mod} — kernels are backend implementation "
                        f"detail; dispatch via api.mxm with a Descriptor")
                elif (parts[:2] == ["repro", "kernels"] and len(parts) == 2
                      and isinstance(n, ast.ImportFrom)):
                    names = {a.name for a in n.names}
                    leaked = {nm for nm in names
                              for pkg in profile.SPARSE_KERNEL_PKGS
                              if nm.startswith(pkg.split("_")[0])
                              or nm.startswith("plap") or nm.startswith(
                                  "sellcs") or nm.startswith("bsr")}
                    if leaked:
                        yield ctx.finding(
                            "api-boundary", n,
                            f"sparse kernel entry point(s) "
                            f"{sorted(leaked)} imported from repro.kernels "
                            f"— dispatch via api.mxm with a Descriptor")
        # backend-registry privates outside grblas/
        if not in_grblas and isinstance(n, ast.Attribute) \
                and n.attr.startswith("_") and n.attr in ("_REGISTRY",):
            base = dotted_name(n.value) or ""
            if base.endswith("backends") or base in ("_backends",):
                yield ctx.finding(
                    "api-boundary", n,
                    "grblas.backends private registry touched outside "
                    "the package — use registered_backends()/"
                    "available_backends()")


register_rule(Rule(
    id="api-boundary",
    summary="SpMM goes through api.mxm; kernels/ and raw segment_sum are "
            "grblas-private",
    invariant="No raw jax.ops.segment_sum and no direct sparse-kernel "
              "imports outside grblas/: the unified API's capability "
              "checks (ring kind, layout availability, pad soundness) "
              "only protect call sites that actually dispatch through "
              "it (DESIGN.md §3).",
    check=_check_boundary,
))
