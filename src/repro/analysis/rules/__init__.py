"""Rule modules register themselves on import (same pattern as the
backend/solver registries: one module per rule family, one
``register_rule`` call per invariant)."""
from repro.analysis.rules import (  # noqa: F401
    boundary,
    dtypes,
    hostsync,
    padsound,
    purity,
    registries,
    retrace,
)
