"""Pad-soundness rule (DESIGN.md §5, the capability gates of PR-2/3/5).

The padded layouts (ELL, SELL-C-σ, the halo exchange's padded pair
slots) store explicit pad entries — (col=row, val=0) self-references —
and fold them together with real entries.  That is sound exactly when
the ring annihilates the pads: registered ``padded`` fast paths declare
it per ring, and the backend capability gates (``_ell_supports``,
``_sellcs_supports``, ``_dist_supports``) refuse rings that don't.

``pad-fold`` is the static face of those gates: inside the padded-
layout modules, a raw reduction carrying an ``axis=`` argument (the
pad-axis fold shape) must be one of

* a ``padded=``/``dense=`` fast path *registered* on a ring
  (``register_ring_fast_paths`` — the ring declares its own soundness),
* inside a kernel function *claimed* by a capability-gated backend
  (imported from ``repro.kernels.*`` by ``grblas/backends.py`` or
  ``grblas/dist.py`` — reachability includes same-module helpers and
  pallas kernel bodies),
* visibly masked (the enclosing function applies ``jnp.where``/a
  ``*mask*`` name before or around the fold), or
* inline-suppressed naming the gate that makes it sound.

Anything else is a reduction that will silently include pad slots the
day someone feeds it a ring without a registered fast path.
"""
from __future__ import annotations

import ast
from typing import Set

from repro.analysis import profile
from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import dotted_name

_FOLD_FNS = frozenset({"sum", "max", "min", "prod", "mean", "amax", "amin",
                       "nansum", "logsumexp"})


def _is_fold_call(n: ast.Call):
    """(is_fold, fn_name) for jnp.sum(x, axis=..) / x.sum(axis=..)."""
    has_axis = any(kw.arg == "axis" for kw in n.keywords)
    name = dotted_name(n.func)
    if name:
        head, _, fn = name.rpartition(".")
        if fn in _FOLD_FNS and head in ("jnp", "jax.numpy", "np", "numpy"):
            # positional axis: jnp.sum(x, 1)
            return (has_axis or len(n.args) >= 2), name
    if isinstance(n.func, ast.Attribute) and n.func.attr in _FOLD_FNS:
        return (has_axis or len(n.args) >= 1), f".{n.func.attr}"
    return False, ""


def _inside_ring_registration(ctx, node) -> bool:
    for anc in ctx.ancestors(node):
        if isinstance(anc, ast.Call):
            nm = dotted_name(anc.func) or ""
            if nm.endswith("register_ring_fast_paths") or \
                    nm.endswith("RingFastPaths"):
                return True
    return False


def _masked(ctx, node) -> bool:
    """Masking evidence in the enclosing def: a jnp.where call or a
    *mask* name anywhere in its body."""
    d = ctx.enclosing_def(node)
    scope = d if d is not None else ctx.tree
    for sub in ast.walk(scope):
        if isinstance(sub, ast.Call):
            nm = dotted_name(sub.func) or ""
            if nm.endswith(".where"):
                return True
        if isinstance(sub, ast.Name) and "mask" in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and "mask" in sub.attr.lower():
            return True
    return False


def _claimed_kernel_names(project) -> Set[str]:
    """Kernel entry points imported from repro.kernels.* by the
    capability-gated dispatch modules (grblas/backends.py, grblas/dist.py)
    — these run only behind a ``supports`` gate."""
    claimed: Set[str] = set()
    for rel in (profile.BACKEND_REGISTRY_MODULE, "grblas/dist.py"):
        m = project.get(rel)
        if m is None:
            continue
        for n in ast.walk(m.tree):
            if isinstance(n, ast.ImportFrom) and n.module \
                    and n.module.startswith("repro.kernels"):
                claimed.update(a.name for a in n.names)
    return claimed


def _reachable_from(ctx, roots: Set[str]) -> Set[int]:
    """ids of defs reachable (same module) from any def named in roots:
    direct calls, partial refs, pallas_call kernel args, plain name
    references (grid/spec closures)."""
    by_name = {}
    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(n.name, []).append(n)
    reach: Set[int] = set()
    work = [d for nm in roots for d in by_name.get(nm, [])]
    while work:
        d = work.pop()
        if id(d) in reach:
            continue
        reach.add(id(d))
        for sub in ast.walk(d):
            if isinstance(sub, ast.Name) and sub.id in by_name:
                work.extend(by_name[sub.id])
    return reach


def _project_check(project):
    claimed = _claimed_kernel_names(project)
    for ctx in project.modules:
        rel = ctx.rel
        if not profile.in_scope(rel, profile.PAD_FOLD_SCOPE):
            continue
        exempt_defs: Set[int] = set()
        if profile.is_sparse_kernel_module(rel):
            # package __init__ re-exports: a name claimed from the
            # package claims the def in whichever module defines it
            exempt_defs = _reachable_from(ctx, claimed)
        for n in ast.walk(ctx.tree):
            if not isinstance(n, ast.Call):
                continue
            is_fold, name = _is_fold_call(n)
            if not is_fold:
                continue
            d = ctx.enclosing_def(n)
            if d is not None and id(d) in exempt_defs:
                continue
            # defs nested in an exempt def (kernel bodies, local helpers)
            anc_exempt = any(
                id(a) in exempt_defs for a in ctx.ancestors(n)
                if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)))
            if anc_exempt:
                continue
            if _inside_ring_registration(ctx, n):
                continue
            if _masked(ctx, n):
                continue
            yield ctx.finding(
                "pad-fold", n,
                f"raw reduction {name}(axis=...) in a padded-layout "
                f"module — pad slots fold in unless the ring "
                f"annihilates them; mask it, register it as a ring "
                f"fast path, or suppress naming the capability gate "
                f"that makes it sound")


register_rule(Rule(
    id="pad-fold",
    summary="pad-axis reductions are masked, ring-registered, or "
            "capability-gated",
    invariant="In the padded-layout modules (ELL/SELL-C-σ/halo), any raw "
              "axis reduction must be provably pad-sound: registered as "
              "a ring fast path, reachable only through backend "
              "capability gates, or explicitly masked.  Cross-references "
              "the grblas/backends.py supports predicates — the runtime "
              "half of the same invariant.",
    project_check=_project_check,
))
