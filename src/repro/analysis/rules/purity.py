"""Hot-path purity rules.

``hot-purity`` generalizes the three ad-hoc ``read_text()`` scans the
repo grew (tests/test_solver_registry.py, tests/test_multilevel.py,
tests/test_grblas_api.py): the continuation hot loop — solver drivers,
the p-Laplacian operator stack, Pallas kernel bodies, the serve bucket
lane — must stay on the jnp/grblas algebra.  A numpy or scipy call
there is either a silent host sync (inside a trace) or a dense
formulation the paper's GraphBLAS claim forbids.

``dense-matmul`` is the multilevel acceptance contract from PR-4:
Galerkin coarse operators are built exclusively through ``api.mxm`` —
no ``@``, no einsum, no ``.toarray()`` densification.
"""
from __future__ import annotations

import ast

from repro.analysis import profile
from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import dotted_name

_HOST_MODULES = ("np", "numpy", "scipy", "sp")

# np.<fn> -> jnp.<fn> rewrites that are drop-in on array math (the jnp
# API is a superset with identical semantics for these); used by the
# hot-purity fixer.
_SAFE_NP_TO_JNP = frozenset({
    "abs", "sum", "maximum", "minimum", "sqrt", "exp", "log", "where",
    "clip", "stack", "concatenate", "zeros_like", "ones_like", "sign",
    "argmin", "argmax", "mean", "dot", "square", "tanh", "floor", "ceil",
})


def _module_of(call_name: str) -> str:
    head = call_name.split(".", 1)[0]
    if head in ("np", "numpy"):
        return "numpy"
    if head in ("scipy", "sp"):
        return "scipy"
    return ""


def _imports(ctx):
    """Imported top-level module names -> canonical library name."""
    out = {}
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.Import):
            for a in n.names:
                root = a.name.split(".")[0]
                if root in ("numpy", "scipy"):
                    out[a.asname or root] = root
        elif isinstance(n, ast.ImportFrom) and n.module:
            root = n.module.split(".")[0]
            if root in ("numpy", "scipy"):
                out.setdefault(root, root)
    return out


def _check_purity(ctx):
    rel = ctx.rel
    ban_scipy = profile.in_scope(rel, profile.SCIPY_BAN)
    ban_numpy = profile.in_scope(rel, profile.NUMPY_BAN)
    imported = _imports(ctx)

    # import statements in banned modules fail at the import line — the
    # clearest possible location for "this package must not know scipy"
    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.Import, ast.ImportFrom)):
            names = ([a.name for a in n.names] if isinstance(n, ast.Import)
                     else [n.module or ""])
            for name in names:
                root = name.split(".")[0]
                if root == "scipy" and ban_scipy:
                    yield ctx.finding(
                        "hot-purity", n,
                        "scipy import in a hot-path module — the solver/"
                        "kernel stack runs on the grblas algebra only")
                elif root == "numpy" and ban_numpy:
                    yield ctx.finding(
                        "hot-purity", n,
                        "numpy import in a pure-device module — use jnp")

    # calls: banned-module calls anywhere in scoped files, and numpy/
    # scipy calls inside *traced* scopes everywhere (the serve bucket
    # lane, driver jit bodies, scan/vmap closures)
    for n in ast.walk(ctx.tree):
        if not isinstance(n, ast.Call):
            continue
        name = dotted_name(n.func)
        if not name:
            continue
        lib = _module_of(name)
        if not lib or name.split(".", 1)[0] not in (
                set(imported) | {"np", "scipy"}):
            continue
        if lib == "scipy" and ban_scipy:
            yield ctx.finding(
                "hot-purity", n,
                f"scipy call {name}() in a hot-path module")
        elif lib == "numpy" and ban_numpy:
            yield ctx.finding(
                "hot-purity", n,
                f"numpy call {name}() in a pure-device module — use jnp")
        elif ctx.scopes.enclosing_traced(n) is not None:
            yield ctx.finding(
                "hot-purity", n,
                f"{lib} call {name}() inside a traced scope — this "
                f"executes at trace time on the host (silent sync or "
                f"baked constant), not in the compiled computation")


def _fix_purity(ctx, findings):
    """Rewrite np.<fn> -> jnp.<fn> for the whitelisted drop-in subset,
    provided the module already imports jax.numpy as jnp.  Non-math
    violations (scipy, np.asarray, layout construction) are left for a
    human — they change where data lives, not just which library runs
    the arithmetic."""
    if "import jax.numpy as jnp" not in ctx.source:
        return None
    lines = ctx.source.splitlines(keepends=True)
    flagged = {f.line for f in findings}
    changed = False
    for n in ast.walk(ctx.tree):
        if not (isinstance(n, ast.Call) and n.lineno in flagged):
            continue
        name = dotted_name(n.func)
        if not name or "." not in name:
            continue
        head, _, fn = name.partition(".")
        if head not in ("np", "numpy") or fn not in _SAFE_NP_TO_JNP:
            continue
        i = n.func.lineno - 1
        old = f"{head}.{fn}"
        if old in lines[i]:
            lines[i] = lines[i].replace(old, f"jnp.{fn}", 1)
            changed = True
    return "".join(lines) if changed else None


register_rule(Rule(
    id="hot-purity",
    summary="no numpy/scipy reachable from the solver/kernel hot path",
    invariant="Solver drivers, the plap/grassmann/lobpcg stack, Pallas "
              "kernel bodies and the serve bucket lane consume the grblas "
              "algebra (api.mxm rings) only; numpy/scipy there is host "
              "math the paper's GraphBLAS claim forbids, and inside any "
              "traced scope it executes at trace time instead of in the "
              "compiled computation.",
    check=_check_purity,
    fix=_fix_purity,
))


_DENSE_CALLS = frozenset({
    "matmul", "dot", "einsum", "tensordot", "vdot", "inner", "outer",
})


def _check_dense(ctx):
    if not profile.in_scope(ctx.rel, profile.DENSE_MATMUL_BAN):
        return
    for n in ast.walk(ctx.tree):
        if (isinstance(n, ast.BinOp) and isinstance(n.op, ast.MatMult)):
            yield ctx.finding(
                "dense-matmul", n,
                "dense '@' product — Galerkin/coarse operators route "
                "through api.mxm (spgemm backend)")
        elif isinstance(n, ast.Call):
            name = dotted_name(n.func) or ""
            head, _, fn = name.rpartition(".")
            if fn in _DENSE_CALLS and head in ("np", "numpy", "jnp",
                                               "jax.numpy"):
                yield ctx.finding(
                    "dense-matmul", n,
                    f"dense product {name}() — route through api.mxm")
            elif fn == "toarray" or (name == "toarray"):
                yield ctx.finding(
                    "dense-matmul", n,
                    "sparse->dense densification (.toarray()) in the "
                    "multilevel package")


register_rule(Rule(
    id="dense-matmul",
    summary="multilevel coarse operators are built via api.mxm only",
    invariant="The Galerkin triple product P^T (W P) and every other "
              "coarse-operator construction goes through the spgemm "
              "backend of api.mxm — no dense '@'/matmul/einsum/"
              "tensordot and no .toarray() densification in "
              "repro/multilevel/.",
    check=_check_dense,
))
