"""Dtype-hygiene rule.

The repo's device containers are float32 values + int32 indices by
construction (PR-3 rebuilt the layout builders to allocate target-dtype
and int32 directly); float64 pipelines opt in *per call* by passing a
dtype.  The invariant is about what crosses the device boundary — host
numpy staging code routinely (and correctly) uses int64 fold keys and
is not this rule's business.

``dtype-hygiene`` flags, inside the device-feeding subsystems
(``profile.DTYPE_SCOPE``):

* 64-bit dtype references on the **jnp** namespace (``jnp.float64``,
  ``jax.numpy.int64``) anywhere — device code never hardcodes width; it
  takes the caller's dtype;
* ``np.int64``-style or ``"int64"``-string dtypes **fed to a jnp call**
  (``dtype=`` kwarg or the positional dtype slot) — same hazard spelled
  through numpy;
* device-boundary constructors (``jnp.asarray``/``jnp.zeros``/...)
  with no explicit dtype in the layout-build functions (``_build_*`` in
  ``grblas/containers.py``), unless the operand is a host array the
  builder already pinned — under ``jax_enable_x64`` an un-pinned
  boundary crossing silently doubles index/value memory, and at the
  8M-node capstone that is gigabytes.
"""
from __future__ import annotations

import ast

from repro.analysis import profile
from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import dotted_name

_WIDE = ("int64", "float64", "uint64", "complex128")
_JNP = ("jnp", "jax.numpy")
_NP = ("np", "numpy")
_CONSTRUCTORS = frozenset({"zeros", "ones", "empty", "full", "arange",
                           "asarray", "array"})
# (fn -> n_positional_args) at which a positional dtype is present
_POSITIONAL_DTYPE_AT = {"zeros": 2, "ones": 2, "empty": 2, "full": 3,
                        "asarray": 2, "array": 2, "arange": 4}


def _wide_ref(node) -> str:
    """'jnp.float64' / "'int64'" for a 64-bit dtype expression, '' else."""
    if isinstance(node, ast.Attribute) and node.attr in _WIDE:
        base = dotted_name(node.value) or ""
        if base in _JNP + _NP:
            return f"{base}.{node.attr}"
    if isinstance(node, ast.Constant) and node.value in _WIDE:
        return repr(node.value)
    return ""


def _split_api(call: ast.Call):
    """('jnp'|'np'|'', fn_name) for a np/jnp module-level call."""
    name = dotted_name(call.func) or ""
    head, _, fn = name.rpartition(".")
    if head in _JNP:
        return "jnp", fn
    if head in _NP:
        return "np", fn
    return "", fn


def _dtype_operand(call: ast.Call):
    """The expression occupying the dtype slot of a constructor call."""
    for kw in call.keywords:
        if kw.arg == "dtype":
            return kw.value
    _, fn = _split_api(call)
    at = _POSITIONAL_DTYPE_AT.get(fn)
    if at is not None and len(call.args) >= at:
        return call.args[at - 1]
    return None


def _check_wide(ctx):
    """64-bit hardcodes that reach the device."""
    # jnp-namespace 64-bit literal anywhere in scope
    for n in ast.walk(ctx.tree):
        wide = _wide_ref(n)
        if not wide:
            continue
        if wide.split(".", 1)[0] in _JNP:
            yield ctx.finding(
                "dtype-hygiene", n,
                f"64-bit device dtype {wide} hardcoded — hot-path code "
                f"takes the caller's dtype; widen per call, not in the "
                f"module (or suppress naming why 64-bit is structural)")
    # np 64-bit / "int64" string in the dtype slot of a jnp call
    for n in ast.walk(ctx.tree):
        if not isinstance(n, ast.Call):
            continue
        api, _ = _split_api(n)
        if api != "jnp":
            continue
        dt = _dtype_operand(n)
        if dt is None:
            continue
        wide = _wide_ref(dt)
        if wide and wide.split(".", 1)[0] not in _JNP:
            yield ctx.finding(
                "dtype-hygiene", dt,
                f"64-bit dtype {wide} fed to a jnp constructor — device "
                f"arrays take the caller's dtype (or suppress naming "
                f"why 64-bit is structural)")


def _pinned_locals(fn: ast.AST) -> set:
    """Names bound in ``fn`` by expressions with a pinned dtype: a
    constructor carrying an explicit dtype (kwarg or positional slot)
    or an ``.astype(...)`` result."""
    pinned = set()
    for n in ast.walk(fn):
        if not isinstance(n, ast.Assign) or len(n.targets) != 1:
            continue
        tgt = n.targets[0]
        if not isinstance(tgt, ast.Name):
            continue
        v = n.value
        if not isinstance(v, ast.Call):
            continue
        if isinstance(v.func, ast.Attribute) and v.func.attr == "astype":
            pinned.add(tgt.id)
        elif _dtype_operand(v) is not None:
            pinned.add(tgt.id)
    return pinned


def _check_builders(ctx):
    """Layout builders pin dtype on every device-boundary constructor."""
    if ctx.rel not in profile.LAYOUT_BUILD_MODULES:
        return
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not fn.name.startswith(profile.LAYOUT_BUILD_PREFIXES):
            continue
        pinned = _pinned_locals(fn)
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Call):
                continue
            api, f = _split_api(sub)
            if api != "jnp" or f not in _CONSTRUCTORS:
                continue
            if _dtype_operand(sub) is not None:
                continue
            if (sub.args and isinstance(sub.args[0], ast.Name)
                    and sub.args[0].id in pinned):
                continue        # host array already pinned; jnp preserves it
            yield ctx.finding(
                "dtype-hygiene", sub,
                f"jnp.{f}() without an explicit dtype at the device "
                f"boundary of a layout builder — under x64 this "
                f"silently widens the layout to int64/float64; pin "
                f"int32 for indices / the target dtype for values "
                f"(PR-3 invariant)")


def _check(ctx):
    if not profile.in_scope(ctx.rel, profile.DTYPE_SCOPE):
        return
    yield from _check_wide(ctx)
    yield from _check_builders(ctx)


register_rule(Rule(
    id="dtype-hygiene",
    summary="no hardcoded 64-bit device dtypes; layout builders pin "
            "every boundary constructor",
    invariant="Device containers are caller-dtype values + int32 indices; "
              "device code never hardcodes jnp 64-bit dtypes (or feeds np "
              "64-bit into jnp constructors) and layout builders pin dtype "
              "on every device-boundary constructor, so enabling x64 "
              "cannot silently double index/value memory.",
    check=_check,
))
