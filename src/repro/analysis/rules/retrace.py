"""Retrace-hazard rules — the compile-time complement of the runtime
detector in ``obs/retrace.py``.

The repo's one-trace-per-bucket / one-trace-per-schedule contracts
(PR-3, PR-7) die by a thousand cuts: a fresh ``jax.jit`` object per
loop iteration, an unhashable config object passed positionally, a
mutable default argument changing identity per call.  The runtime
detector sees the recompiles after they happen; these rules flag the
shapes of code that cause them before anything runs.

* ``retrace-static`` — a jitted function whose signature takes a
  config/descriptor/ring object with no ``static_argnames``: every call
  with a fresh instance retraces (or fails to hash).
* ``retrace-loop-jit`` — ``jax.jit(...)``/``pl.pallas_call`` executed
  inside a ``for``/``while`` body: a new callable per iteration means a
  new trace per iteration.  Route through ``registry.memoized``.
* ``retrace-mutable-default`` — ``def f(x, opts={})`` in a traced or
  jit-wrapped function: the default's identity is fresh per process and
  its mutation invisible to the trace cache.  Fixed mechanically by the
  shipped fixer (``opts=None`` + a guard line).
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import (JIT_CALLS, dotted_name, is_tracing_decorator,
                                   unwrap_partial)

_CONFIG_PARAMS = frozenset({
    "cfg", "config", "desc", "descriptor", "ring", "semiring", "state",
})

# memoization shims that make a loop-local jit safe
_MEMO_CALLS = frozenset({"memoized", "registry.memoized"})


def _jit_kwargs(call: ast.Call):
    return {kw.arg for kw in call.keywords if kw.arg}


def _has_statics(call: ast.Call) -> bool:
    return bool(_jit_kwargs(call) & {"static_argnames", "static_argnums"})


def _decorator_has_statics(dec: ast.AST) -> bool:
    return isinstance(dec, ast.Call) and _has_statics(dec)


def _def_config_params(d) -> list:
    args = d.args
    names = [a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)]
    return [nm for nm in names if nm in _CONFIG_PARAMS]


def _check_static(ctx):
    # decorated defs
    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in n.decorator_list:
                if not is_tracing_decorator(dec):
                    continue
                name = dotted_name(dec if not isinstance(dec, ast.Call)
                                   else dec.func)
                target = dec
                if (isinstance(dec, ast.Call)
                        and dotted_name(dec.func) not in JIT_CALLS):
                    # partial(jax.jit, ...) form
                    if not (dec.args
                            and dotted_name(dec.args[0]) in JIT_CALLS):
                        continue
                if name not in JIT_CALLS and not (
                        isinstance(dec, ast.Call) and dec.args
                        and dotted_name(dec.args[0]) in JIT_CALLS):
                    continue
                bad = _def_config_params(n)
                if bad and not (isinstance(target, ast.Call)
                                and _has_statics(target)):
                    yield ctx.finding(
                        "retrace-static", n,
                        f"jitted def takes config-like parameter(s) "
                        f"{', '.join(bad)} without static_argnames — an "
                        f"unhashable instance fails to trace, a fresh "
                        f"frozen instance retraces per call; mark static "
                        f"or close over it")
        if isinstance(n, ast.Call) and dotted_name(n.func) in JIT_CALLS:
            if _has_statics(n):
                continue
            tgt = unwrap_partial(n.args[0]) if n.args else None
            d = None
            if isinstance(tgt, (ast.Lambda,)):
                d = tgt
            elif isinstance(tgt, ast.Name):
                for cand in ctx.scopes._by_name.get(tgt.id, []):
                    d = cand
            if d is None:
                continue
            bad = _def_config_params(d)
            if bad:
                yield ctx.finding(
                    "retrace-static", n,
                    f"jax.jit over a callable taking config-like "
                    f"parameter(s) {', '.join(bad)} without "
                    f"static_argnames — close over the config or mark it "
                    f"static")


register_rule(Rule(
    id="retrace-static",
    summary="jitted signatures taking config objects declare them static",
    invariant="A function jitted with a PSCConfig/Descriptor/ring-shaped "
              "parameter must name it in static_argnames (or close over "
              "it): config objects are not pytrees of arrays, so passing "
              "them traced either fails to hash or silently retraces on "
              "every fresh instance — the compile-time face of "
              "obs/retrace.py's runtime detector.",
    check=_check_static,
))


def _enclosing_loop(ctx, node):
    for anc in ctx.ancestors(node):
        if isinstance(anc, (ast.For, ast.While, ast.AsyncFor)):
            return anc
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            # a def inside the loop is a fresh scope: creating a jit
            # inside a *function defined in* a loop is that function's
            # problem at its own call sites
            return None
    return None


def _under_memo(ctx, node) -> bool:
    """Is this jit creation inside a build-callable handed to the
    registry memo (``registry.memoized(key, build)``) — or inside a def
    whose result feeds it?"""
    for anc in ctx.ancestors(node):
        if isinstance(anc, ast.Call):
            nm = dotted_name(anc.func) or ""
            if nm in _MEMO_CALLS or nm.endswith(".memoized"):
                return True
    return False


def _check_loop_jit(ctx):
    for n in ast.walk(ctx.tree):
        if not (isinstance(n, ast.Call) and dotted_name(n.func) in JIT_CALLS):
            continue
        loop = _enclosing_loop(ctx, n)
        if loop is None or _under_memo(ctx, n):
            continue
        yield ctx.finding(
            "retrace-loop-jit", n,
            "jit/pallas_call constructed inside a loop body — a fresh "
            "callable per iteration traces per iteration; hoist it or "
            "route through registry.memoized")


register_rule(Rule(
    id="retrace-loop-jit",
    summary="no fresh jit/pallas callables constructed per loop iteration",
    invariant="The p-continuation and serve lanes hold one compiled "
              "callable per execution signature (registry.memoized / "
              "SOLVER_TRACES); constructing jax.jit or pl.pallas_call "
              "inside a for/while body defeats the cache because the "
              "callable's identity is fresh each pass.",
    check=_check_loop_jit,
))


def _mutable_defaults(d):
    args = d.args
    out = []
    for a, default in zip(
            (args.posonlyargs + args.args)[-len(args.defaults):]
            if args.defaults else [], args.defaults):
        if _is_mutable(default):
            out.append((a.arg, default))
    for a, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None and _is_mutable(default):
            out.append((a.arg, default))
    return out


def _is_mutable(node) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call):
        return dotted_name(node.func) in ("list", "dict", "set")
    return False


def _check_mutable_default(ctx):
    for n in ast.walk(ctx.tree):
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not ctx.scopes.is_traced_def(n):
            continue
        for name, default in _mutable_defaults(n):
            yield ctx.finding(
                "retrace-mutable-default", default,
                f"mutable default {name}={ast.unparse(default)} on a "
                f"traced def — default identity/content changes escape "
                f"the trace cache; default to None and guard in the body")


def _fix_mutable_default(ctx, findings):
    """Mechanical B006-style repair: ``opts={}`` becomes ``opts=None``
    plus an ``if opts is None: opts = {}`` guard as the first body
    statement.  Only fires on single-line defs whose default literal is
    textually unambiguous on its line."""
    lines = ctx.source.splitlines()
    edits = []     # (def node, param name, default node)
    for n in ast.walk(ctx.tree):
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for name, default in _mutable_defaults(n):
            if any(f.line == default.lineno for f in findings):
                edits.append((n, name, default))
    if not edits:
        return None
    changed = False
    # textual edits bottom-up so line numbers stay valid
    for d, name, default in sorted(edits, key=lambda e: -e[2].lineno):
        i = default.lineno - 1
        literal = ast.unparse(default)
        frag = f"{name}={literal}"
        if frag not in lines[i]:
            continue
        lines[i] = lines[i].replace(frag, f"{name}=None", 1)
        body_line = d.body[0].lineno - 1
        indent = " " * (len(lines[body_line])
                        - len(lines[body_line].lstrip()))
        guard = f"{indent}if {name} is None:\n{indent}    {name} = {literal}"
        # insert after a docstring, before the first real statement
        insert_at = body_line
        first = d.body[0]
        if (isinstance(first, ast.Expr)
                and isinstance(first.value, ast.Constant)
                and isinstance(first.value.value, str) and len(d.body) > 1):
            insert_at = d.body[1].lineno - 1
        lines.insert(insert_at, guard)
        changed = True
    return "\n".join(lines) + "\n" if changed else None


register_rule(Rule(
    id="retrace-mutable-default",
    summary="no mutable default arguments on traced defs",
    invariant="Defaults on jitted/traced signatures must be hashable "
              "constants: a {}/[] default is one shared mutable object "
              "whose content changes invisibly to the trace cache (and "
              "whose identity differs across processes, breaking "
              "persistent-cache keys).",
    check=_check_mutable_default,
    fix=_fix_mutable_default,
))
