"""Host-sync / concretization-hazard rules.

Inside a traced scope (jit/vmap/scan/pallas_call body — see
``analysis.scopes``), pulling a value out of the trace blocks on the
device and usually poisons the compiled artifact:

* ``host-sync`` — ``.item()``, ``float()/int()/bool()/complex()`` on a
  traced value, ``np.asarray``/``jax.device_get`` of a tracer.  At
  8M-node scale (ROADMAP capstone) one hidden sync per Newton step is a
  100x regression, not a test failure.
* ``traced-branch`` — a Python ``if``/``while``/``assert`` whose test
  calls into jnp: data-dependent control flow cannot trace
  (ConcretizationTypeError at best, silently-baked branch at worst);
  use ``lax.cond``/``jnp.where``.

Both rules key off names *bound in the traced scope* (params, locals):
closure constants (cfg fields, static python ints) concretize fine and
are not flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import dotted_name

_CONCRETIZERS = ("float", "int", "bool", "complex")
_PULL_CALLS = frozenset({
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get",
})
# attribute/call names that yield static python values even on tracers
_STATIC_ATTRS = frozenset({"ndim", "shape", "dtype", "size"})


def _static_only(node: ast.AST, local_names) -> bool:
    """True when every local-name read in ``node`` goes through a static
    attribute (shape/ndim/dtype/size) or len()."""
    class V(ast.NodeVisitor):
        dynamic = False

        def visit_Attribute(self, a):
            if a.attr in _STATIC_ATTRS:
                return          # don't descend: x.shape is static
            self.generic_visit(a)

        def visit_Call(self, c):
            if isinstance(c.func, ast.Name) and c.func.id == "len":
                return          # len(static tuple) — don't descend
            self.generic_visit(c)

        def visit_Name(self, nm):
            if nm.id in local_names:
                self.dynamic = True

    v = V()
    v.visit(node)
    return not v.dynamic


def _check_hostsync(ctx):
    scopes = ctx.scopes
    for n in ast.walk(ctx.tree):
        if not isinstance(n, ast.Call):
            continue
        traced = scopes.enclosing_traced(n)
        if traced is None:
            continue
        local_names = scopes.locals_of(traced)
        name = dotted_name(n.func)
        # .item() on anything inside a trace
        if (isinstance(n.func, ast.Attribute) and n.func.attr == "item"
                and not n.args):
            yield ctx.finding(
                "host-sync", n,
                ".item() inside a traced scope — device sync per call; "
                "keep the value on device or hoist to the host caller")
            continue
        if name in _PULL_CALLS:
            if n.args and _static_only(n.args[0], local_names):
                continue
            yield ctx.finding(
                "host-sync", n,
                f"{name}() inside a traced scope pulls the operand off "
                f"the trace — use jnp (stays traced) or hoist to host")
            continue
        if (isinstance(n.func, ast.Name) and n.func.id in _CONCRETIZERS
                and n.args):
            arg = n.args[0]
            if isinstance(arg, ast.Constant):
                continue
            if _static_only(arg, local_names):
                continue
            yield ctx.finding(
                "host-sync", n,
                f"{n.func.id}() concretizes a traced value — "
                f"ConcretizationTypeError under jit, silent device sync "
                f"under eager; use jnp casts (.astype) or hoist")


register_rule(Rule(
    id="host-sync",
    summary="no concretization of traced values inside jit/vmap/pallas "
            "scopes",
    invariant="Code inside a traced scope never calls .item(), "
              "float()/int()/bool() on traced values, np.asarray/"
              "jax.device_get on tracers — each is a host round-trip "
              "(or trace-time constant) invisible to benchmarks until "
              "it is a 100x regression at paper scale.",
    check=_check_hostsync,
))


def _test_calls_jnp(test: ast.AST) -> bool:
    for sub in ast.walk(test):
        if isinstance(sub, ast.Call):
            name = dotted_name(sub.func) or ""
            head = name.split(".", 1)[0]
            if head in ("jnp", "jax", "lax"):
                return True
    return False


def _check_traced_branch(ctx):
    scopes = ctx.scopes
    for n in ast.walk(ctx.tree):
        if not isinstance(n, (ast.If, ast.While, ast.Assert, ast.IfExp)):
            continue
        if scopes.enclosing_traced(n) is None:
            continue
        if _test_calls_jnp(n.test):
            kind = {"If": "if", "While": "while", "Assert": "assert",
                    "IfExp": "conditional expression"}[type(n).__name__]
            yield ctx.finding(
                "traced-branch", n,
                f"python {kind} on a jnp expression inside a traced "
                f"scope — data-dependent control flow cannot trace; use "
                f"lax.cond / jnp.where / checkify")


register_rule(Rule(
    id="traced-branch",
    summary="no python control flow on jnp values inside traced scopes",
    invariant="Branch decisions inside jit/vmap/scan bodies are made "
              "with lax.cond/lax.while_loop/jnp.where, never python "
              "if/while/assert on a traced expression — those either "
              "raise ConcretizationTypeError or silently bake one "
              "branch at trace time.",
    check=_check_traced_branch,
))
