"""Cross-registry consistency: telemetry coverage of the two dispatch
registries (the PR-9 coverage claim, kept true as registrants land).

``registry-span`` cross-references three module sets:

* every ``register_backend("<name>", ...)`` in ``grblas/backends.py``,
* every ``register_solver("<name>", ...)`` under ``core/solvers/``,
* every ``span(...)``/``instant(...)`` call site in the scanned tree,
  collecting which ``backend=``/``solver=`` attributes they carry.

A registrant is covered when some span site labels it — either
*dynamically* (the attribute value is an expression like ``be.name`` /
``solver.name`` at a dispatch chokepoint, which covers every current
and future registrant that flows through it) or *literally* (a span
hardcoding the name).  An uncovered registrant means a backend or
driver whose executions are invisible to the §10 telemetry — exactly
the regression this rule exists to catch: deleting the ``grblas.mxm``
span or adding a driver that bypasses ``p_continuation`` silently
un-instruments the stack.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from repro.analysis import profile
from repro.analysis.core import Rule, register_rule
from repro.analysis.scopes import dotted_name


def _registrations(project, module_prefixes: Tuple[str, ...],
                   reg_call: str) -> List[Tuple]:
    """(name, ctx, node) for every reg_call("name", ...) — call or
    decorator form — in modules under the given prefixes."""
    out = []
    for ctx in project.modules:
        if not profile.in_scope(ctx.rel, module_prefixes):
            continue
        for n in ast.walk(ctx.tree):
            if not isinstance(n, ast.Call):
                continue
            nm = dotted_name(n.func) or ""
            if not (nm == reg_call or nm.endswith("." + reg_call)):
                continue
            if n.args and isinstance(n.args[0], ast.Constant) \
                    and isinstance(n.args[0].value, str):
                out.append((n.args[0].value, ctx, n))
    return out


def _span_labels(project, attr: str) -> Tuple[bool, Set[str]]:
    """(has_dynamic_site, literal_names) across every ``span``/
    ``instant`` call site carrying keyword ``attr``."""
    dynamic = False
    literals: Set[str] = set()
    for ctx in project.modules:
        for n in ast.walk(ctx.tree):
            if not isinstance(n, ast.Call):
                continue
            if not (isinstance(n.func, ast.Attribute)
                    and n.func.attr in ("span", "instant")):
                continue
            for kw in n.keywords:
                if kw.arg != attr:
                    continue
                if isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, str):
                    literals.add(kw.value.value)
                else:
                    dynamic = True
    return dynamic, literals


def _project_check(project):
    backends = _registrations(
        project, (profile.BACKEND_REGISTRY_MODULE,), "register_backend")
    solvers = _registrations(
        project, (profile.SOLVER_PKG,), "register_solver")
    be_dyn, be_lit = _span_labels(project, "backend")
    so_dyn, so_lit = _span_labels(project, "solver")

    for name, ctx, node in backends:
        if not (be_dyn or name in be_lit):
            yield ctx.finding(
                "registry-span", node,
                f"backend {name!r} has no obs span coverage: no span/"
                f"instant site carries backend=<name> (the grblas.mxm "
                f"dispatch span is gone or bypassed) — §10 telemetry "
                f"would not see its executions")
    for name, ctx, node in solvers:
        if not (so_dyn or name in so_lit):
            yield ctx.finding(
                "registry-span", node,
                f"solver driver {name!r} has no obs span coverage: no "
                f"span/instant site carries solver=<name> (the "
                f"solver.level span is gone or bypassed) — §10 "
                f"telemetry would not see its levels")
    # the rule is only meaningful if it actually sees the registries —
    # guard against a scan scoped so narrowly it proves nothing
    if not backends and project.get(profile.BACKEND_REGISTRY_MODULE):
        m = project.get(profile.BACKEND_REGISTRY_MODULE)
        yield m.finding(
            "registry-span", m.tree,
            "grblas/backends.py contains no register_backend calls — "
            "registry moved? update repro/analysis/profile.py")


register_rule(Rule(
    id="registry-span",
    summary="every registered backend/driver is visible to obs spans",
    invariant="Each name registered via register_backend (grblas/"
              "backends.py) or register_solver (core/solvers/) is "
              "covered by a span/instant site labelling backend=/"
              "solver= — dynamically at the dispatch chokepoints "
              "(grblas.mxm, solver.level) or literally — so the PR-9 "
              "telemetry coverage claim stays true as registrants land.",
    project_check=_project_check,
))
