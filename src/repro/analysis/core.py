"""pscheck core: findings, suppression directives, rule registry, runner.

The analyzer is a library first (``repro.analysis.run(paths, rules) ->
list[Finding]``), a CLI second (``python -m repro.analysis``), and a
pytest assertion third (``assert_clean``).  Every invariant the repo
used to enforce with ad-hoc ``read_text()`` scans is a registered
``Rule`` here: one id, one docstring stating the invariant, one AST
check, and (where a rewrite is mechanical) one fixer.

Three enforcement channels, strictest first:

* a violation with no escape hatch is an **error** — CI fails;
* an *intentional* violation carries an inline directive on its line
  (or the line above)::

      # pscheck: disable=rule-id (reason the invariant does not apply)

  the reason string is mandatory (``suppression-reason``) and a
  directive that stops matching anything is itself an error
  (``unused-suppression``) — suppressions cannot rot;
* a *known* violation that predates the analyzer lives in the committed
  baseline file (``pscheck_baseline.json``).  The baseline is
  shrink-only: a baselined finding that disappears while its entry
  remains fails the run, so the debt ledger can only go down.

Baseline entries are keyed on (rule, module path, enclosing symbol,
message) — never on line numbers — so unrelated edits don't churn the
ledger.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------- findings

@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str                   # module-relative path ("core/plap.py")
    line: int
    col: int
    message: str
    severity: str = "error"     # "error" | "warning"
    symbol: str = "<module>"    # enclosing def qualname

    def baseline_key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.symbol, self.message)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.severity}: "
                f"[{self.rule}] {self.message} (in {self.symbol})")


# ------------------------------------------------------------- suppressions

_DIRECTIVE = re.compile(
    r"#\s*pscheck:\s*disable=(?P<rules>[A-Za-z0-9_,\- ]+?)"
    r"(?:\s*\((?P<reason>[^)]*)\))?\s*$")


@dataclasses.dataclass
class Suppression:
    line: int                   # 1-based line the directive sits on
    rules: Tuple[str, ...]
    reason: str
    used_by: set = dataclasses.field(default_factory=set)

    def covers(self, rule: str, line: int) -> bool:
        """A directive covers its own line and the line directly below
        (standalone-comment form)."""
        return rule in self.rules and line in (self.line, self.line + 1)


def parse_suppressions(source: str) -> List[Suppression]:
    out = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _DIRECTIVE.search(text)
        if m:
            rules = tuple(r.strip() for r in m.group("rules").split(",")
                          if r.strip())
            out.append(Suppression(line=i, rules=rules,
                                   reason=(m.group("reason") or "").strip()))
    return out


# ---------------------------------------------------------------- contexts

def module_rel(path: Path) -> str:
    """Stable display/baseline path: the part under the ``repro``
    package when there is one (checkout-root independent), else the
    file name."""
    parts = Path(path).parts
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i + 1:])
    return Path(path).name


class ModuleContext:
    """One parsed module: source, AST with parent links, suppressions,
    and the lazily-built traced-scope map rules share."""

    def __init__(self, path: Path, source: Optional[str] = None):
        self.path = Path(path)
        self.rel = module_rel(self.path)
        self.source = self.path.read_text() if source is None else source
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(self.path))
        self.suppressions = parse_suppressions(self.source)
        self._parents: Dict[int, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent
        self._scopes = None

    # -- structure -------------------------------------------------------
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def ancestors(self, node: ast.AST):
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_def(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return anc
        return None

    def qualname(self, node: ast.AST) -> str:
        names = []
        cur = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                names.append(cur.name)
            elif isinstance(cur, ast.Lambda):
                names.append("<lambda>")
            elif isinstance(cur, ast.ClassDef):
                names.append(cur.name)
            cur = self.parent(cur)
        return ".".join(reversed(names)) or "<module>"

    @property
    def scopes(self):
        if self._scopes is None:
            from repro.analysis.scopes import ScopeInfo
            self._scopes = ScopeInfo(self)
        return self._scopes

    # -- findings --------------------------------------------------------
    def finding(self, rule: str, node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        return Finding(rule=rule, path=self.rel,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0),
                       message=message, severity=severity,
                       symbol=self.qualname(node))


class ProjectContext:
    """The whole scanned file set — what cross-file rules see."""

    def __init__(self, modules: Sequence[ModuleContext]):
        self.modules = list(modules)
        self._by_rel = {m.rel: m for m in self.modules}

    def get(self, rel: str) -> Optional[ModuleContext]:
        return self._by_rel.get(rel)


# ------------------------------------------------------------ rule registry

@dataclasses.dataclass(frozen=True)
class Rule:
    """One machine-checked invariant.

    ``check`` runs per module; ``project_check`` runs once over the
    whole file set (cross-registry rules).  ``fix`` — present only
    where the rewrite is mechanical and safe — takes (ctx, findings)
    and returns the repaired source, or None to decline.
    """

    id: str
    summary: str                # one line, for --list-rules
    invariant: str              # the invariant this encodes (DESIGN §11)
    check: Optional[Callable[[ModuleContext], Iterable[Finding]]] = None
    project_check: Optional[
        Callable[[ProjectContext], Iterable[Finding]]] = None
    fix: Optional[Callable[[ModuleContext, List[Finding]],
                           Optional[str]]] = None


_RULES: Dict[str, Rule] = {}

# meta-rules: emitted by the runner itself, always on, never selectable off
META_RULES = ("unused-suppression", "suppression-reason", "parse-error")


def register_rule(rule: Rule) -> Rule:
    if rule.id in _RULES:
        raise ValueError(f"duplicate rule id {rule.id!r}")
    _RULES[rule.id] = rule
    return rule


def registered_rules() -> Dict[str, Rule]:
    _load_rules()
    return dict(_RULES)


def resolve_rules(rules=None) -> List[Rule]:
    table = registered_rules()
    if rules is None:
        return list(table.values())
    out = []
    for r in rules:
        if isinstance(r, Rule):
            out.append(r)
            continue
        if r not in table:
            raise ValueError(
                f"unknown rule {r!r}; registered: {sorted(table)}")
        out.append(table[r])
    return out


_LOADED = False


def _load_rules():
    global _LOADED
    if not _LOADED:
        _LOADED = True
        import repro.analysis.rules  # noqa: F401  (registers on import)


# ----------------------------------------------------------------- running

def collect_files(paths) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py")
                if "__pycache__" not in f.parts and "_vendor" not in f.parts))
        else:
            files.append(p)
    seen, uniq = set(), []
    for f in files:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def _parse_modules(files) -> Tuple[List[ModuleContext], List[Finding]]:
    mods, findings = [], []
    for f in files:
        try:
            mods.append(ModuleContext(f))
        except SyntaxError as e:
            findings.append(Finding(
                rule="parse-error", path=module_rel(f),
                line=e.lineno or 1, col=e.offset or 0,
                message=f"syntax error: {e.msg}"))
    return mods, findings


def run(paths, rules=None, *, meta: bool = True) -> List[Finding]:
    """Analyze ``paths`` (files or directories) under ``rules`` (default:
    every registered rule).  Returns unsuppressed findings; inline
    ``# pscheck: disable=`` directives filter matching findings and are
    themselves checked (mandatory reason, no dead directives) when
    ``meta`` is on."""
    selected = resolve_rules(rules)
    mods, findings = _parse_modules(collect_files(paths))
    project = ProjectContext(mods)

    raw: List[Finding] = []
    for rule in selected:
        if rule.check is not None:
            for m in mods:
                raw.extend(rule.check(m))
        if rule.project_check is not None:
            raw.extend(rule.project_check(project))

    selected_ids = {r.id for r in selected}
    by_rel = {m.rel: m for m in mods}
    for f in raw:
        m = by_rel.get(f.path)
        sup = _matching_suppression(m, f) if m is not None else None
        if sup is not None:
            sup.used_by.add(f.rule)
        else:
            findings.append(f)

    if meta:
        for m in mods:
            for sup in m.suppressions:
                if not sup.reason:
                    findings.append(Finding(
                        rule="suppression-reason", path=m.rel,
                        line=sup.line, col=0,
                        message="disable directive needs a reason: "
                                "# pscheck: disable=<rule> (why)"))
                dead = [r for r in sup.rules
                        if r in selected_ids and r not in sup.used_by]
                if dead and not sup.used_by:
                    findings.append(Finding(
                        rule="unused-suppression", path=m.rel,
                        line=sup.line, col=0,
                        message=f"directive disables {', '.join(dead)} but "
                                f"suppresses nothing — fix is done, delete "
                                f"the directive"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def _matching_suppression(m: ModuleContext, f: Finding):
    for sup in m.suppressions:
        if sup.covers(f.rule, f.line):
            return sup
    return None


# ---------------------------------------------------------------- baseline

def load_baseline(path) -> Dict[Tuple[str, str, str, str], int]:
    """Baseline as {finding key: allowed count}."""
    data = json.loads(Path(path).read_text())
    out: Dict[Tuple[str, str, str, str], int] = {}
    for e in data.get("entries", []):
        key = (e["rule"], e["path"], e.get("symbol", "<module>"),
               e["message"])
        out[key] = out.get(key, 0) + int(e.get("count", 1))
    return out


def write_baseline(findings: Sequence[Finding], path) -> None:
    counts: Dict[Tuple[str, str, str, str], int] = {}
    for f in findings:
        counts[f.baseline_key()] = counts.get(f.baseline_key(), 0) + 1
    entries = [
        {"rule": k[0], "path": k[1], "symbol": k[2], "message": k[3],
         "count": n}
        for k, n in sorted(counts.items())]
    Path(path).write_text(json.dumps(
        {"version": 1,
         "comment": "pscheck debt ledger — shrink-only; regenerate with "
                    "python -m repro.analysis --update-baseline",
         "entries": entries}, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding], baseline
                   ) -> Tuple[List[Finding], List[Tuple]]:
    """Split ``findings`` against a baseline mapping.  Returns
    (unbaselined findings, stale baseline keys) — stale = an entry whose
    violation no longer exists, which must be removed from the ledger
    (shrink-only enforcement)."""
    budget = dict(baseline)
    new: List[Finding] = []
    for f in findings:
        k = f.baseline_key()
        if budget.get(k, 0) > 0:
            budget[k] -= 1
        else:
            new.append(f)
    stale = [k for k, n in budget.items() if n > 0]
    return new, stale


# ------------------------------------------------------------------- fixes

def apply_fixes(paths, rules=None, *, write: bool = True
                ) -> Dict[Path, str]:
    """Run every selected rule that ships a fixer and apply the repairs.
    Returns {path: new source} for each changed file (written in place
    unless ``write=False``)."""
    selected = [r for r in resolve_rules(rules) if r.fix is not None]
    changed: Dict[Path, str] = {}
    for f in collect_files(paths):
        try:
            ctx = ModuleContext(f)
        except SyntaxError:
            continue
        src = ctx.source
        for rule in selected:
            if rule.check is None:
                continue
            findings = [x for x in rule.check(ctx)
                        if _matching_suppression(ctx, x) is None]
            if not findings:
                continue
            fixed = rule.fix(ctx, findings)
            if fixed is not None and fixed != ctx.source:
                ctx = ModuleContext(f, source=fixed)
        if ctx.source != src:
            changed[f] = ctx.source
            if write:
                f.write_text(ctx.source)
    return changed


# ------------------------------------------------------------ pytest facing

def assert_clean(paths, rules=None, *, baseline=None) -> None:
    """One-line invariant assertion for tests: raise AssertionError with
    the formatted findings unless ``paths`` is clean under ``rules``
    (modulo the baseline file, when given — stale baseline entries fail
    too)."""
    findings = run(paths, rules)
    stale: List[Tuple] = []
    if baseline is not None:
        findings, stale = apply_baseline(findings, load_baseline(baseline))
    msgs = [f.format() for f in findings]
    msgs += [f"stale baseline entry (violation fixed — shrink the ledger): "
             f"{k[0]} {k[1]} {k[3]}" for k in stale]
    assert not msgs, "pscheck violations:\n  " + "\n  ".join(msgs)
