"""pMulti baseline (Luo, Huang, Ding, Nie 2010): one-at-a-time full
eigenvector analysis of the p-Laplacian.

Eigenvectors are computed sequentially; each minimizes the single-column
p-Rayleigh quotient with a projected gradient method, kept orthogonal
(2-norm) to the previously found ones by Gram-Schmidt projection after
every step — the scheme the paper compares against in Table I.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas.api import Descriptor
from repro.core import plap, kmeans as km, metrics, lobpcg


def _minimize_single(W, u0, Uprev, p, eps, iters=300, lr0=0.5, desc=None):
    """Projected gradient descent with backtracking on one column."""

    def f(u):
        return plap.value(W, u[:, None], p, eps, desc=desc)

    def project(u):
        if Uprev.shape[1] > 0:
            u = u - Uprev @ (Uprev.T @ u)
        return u / jnp.maximum(jnp.linalg.norm(u), 1e-12)

    @jax.jit
    def step(u, lr):
        g = plap.euc_grad(W, u[:, None], p, eps, desc=desc)[:, 0]
        # project gradient to the feasible tangent (orthogonality + sphere)
        if Uprev.shape[1] > 0:
            g = g - Uprev @ (Uprev.T @ g)
        g = g - u * jnp.dot(u, g)
        u_try = project(u - lr * g)
        improved = f(u_try) < f(u)
        return jnp.where(improved, u_try, u), jnp.where(improved, lr * 1.1, lr * 0.5)

    u, lr = project(u0), jnp.array(lr0)
    for _ in range(iters):
        u, lr = step(u, lr)
    return u


def p_multi(W: SparseMatrix, k: int, p: float = 1.2, eps: float = 1e-8,
            seed: int = 0, iters: int = 200,
            desc: Descriptor | None = None) -> Tuple[np.ndarray, float]:
    """Sequential p-eigenvectors + kmeans. Returns (labels, rcut).

    ``desc`` selects the grblas backend for every inner SpMM (None =
    platform auto; the p=2 initialization falls back to auto if the
    named backend cannot run the reals ring)."""
    from repro.grblas import api as grb_api

    n = W.n_rows
    _, U2 = lobpcg.smallest_eigvecs(
        W, k, seed=seed, desc=grb_api.capable_desc(W, desc=desc, k=k))
    cols = []
    for l in range(k):
        Uprev = (jnp.stack(cols, axis=1) if cols
                 else jnp.zeros((n, 0), U2.dtype))
        u = _minimize_single(W, U2[:, l], Uprev, p, eps, iters=iters,
                             desc=desc)
        cols.append(u)
    U = jnp.stack(cols, axis=1)
    Xn = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True), 1e-12)
    labels, _ = km.kmeans(jax.random.PRNGKey(seed), Xn, k)
    return np.asarray(labels), float(metrics.rcut(W, labels, k))
