"""pMulti baseline (Luo, Huang, Ding, Nie 2010) — one-release shim.

The private projected-gradient loop that used to live here
(``_minimize_single``) is gone: it duplicated the inverse-power driver
while constructing its own jitted steps per column (k traces per call)
and did not thread descriptor routing through the same contract as the
rest of the pipeline.  ``p_multi`` now delegates to the registry's
"inverse_power" driver (core.solvers.inverse_power) — same sequential
deflated minimization, one memoized trace, every SpMM routed through
``api.mxm`` under the configured backend — and will be removed next
release; call ``p_spectral_cluster(W, PSCConfig(solver="inverse_power"))``
or ``core.solvers.minimize_at_p`` directly.
"""
from __future__ import annotations

import warnings
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas.api import Descriptor
from repro.core import kmeans as km, lobpcg, metrics, solvers


def p_multi(W: SparseMatrix, k: int, p: float = 1.2, eps: float = 1e-8,
            seed: int = 0, iters: int = 200,
            desc: Descriptor | None = None) -> Tuple[np.ndarray, float]:
    """Sequential p-eigenvectors + kmeans. Returns (labels, rcut).

    Deprecated shim over the "inverse_power" registry driver: the p=2
    LOBPCG start, then one deflated inverse-power minimization at ``p``
    directly (no continuation — the historical pMulti behavior).
    ``desc`` selects the grblas backend for every inner SpMM (None =
    platform auto); registry validation applies, so ``p`` outside
    [1, 2] raises ValueError."""
    from repro.core.psc import PSCConfig
    from repro.grblas import api as grb_api

    warnings.warn(
        "repro.core.pmulti.p_multi is deprecated: use "
        "p_spectral_cluster(W, PSCConfig(solver='inverse_power')) or "
        "core.solvers.minimize_at_p; this shim will be removed next "
        "release", DeprecationWarning, stacklevel=2)
    cfg = PSCConfig(k=k, p_target=p, eps=eps, seed=seed,
                    solver="inverse_power", ipm_iters=iters,
                    backend=(desc.backend if desc is not None else "auto"),
                    interpret=(desc.interpret if desc is not None else False))
    _, U2 = lobpcg.smallest_eigvecs(
        W, k, seed=seed, desc=grb_api.capable_desc(W, desc=desc, k=k))
    rep = solvers.minimize_at_p(W, U2, p, cfg)
    U = rep.U
    Xn = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True), 1e-12)
    labels, _ = km.kmeans(jax.random.PRNGKey(seed), Xn, k)
    return np.asarray(labels), float(metrics.rcut(W, labels, k))
