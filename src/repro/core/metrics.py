"""Balanced graph-cut metrics (Table I of the paper) + clustering accuracy.

All cut computations are expressed GraphBLAS-style:
  cut(C, C-bar) = 1_C^T W 1_{C-bar}   (one SpMM with the indicator matrix)
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas import api
from repro.grblas.api import Descriptor

# the indicator SpMM is tiny and COO-exact; keep the reference backend so
# cut metrics are bit-stable across layout availability
_COO = Descriptor(backend="coo")


def _indicator(labels: jnp.ndarray, k: int) -> jnp.ndarray:
    return jax.nn.one_hot(labels, k, dtype=jnp.float32)      # (n,k)


def cut_matrix(W: SparseMatrix, labels, k: int) -> jnp.ndarray:
    """M[a,b] = sum of edge weights between cluster a and b (directed nnz);
    one SpMM with the one-hot indicator multivector."""
    labels = jnp.asarray(labels)
    H = _indicator(labels, k)
    WH = api.mxm(W, H, desc=_COO)
    return H.T @ WH                                           # (k,k)


def rcut(W: SparseMatrix, labels, k: int) -> jnp.ndarray:
    """RCut = sum_i cut(C_i, C-bar_i) / |C_i|  (paper's quality metric)."""
    labels = jnp.asarray(labels)
    M = cut_matrix(W, labels, k)
    sizes = jnp.bincount(labels, length=k).astype(jnp.float32)
    cutv = jnp.sum(M, axis=1) - jnp.diag(M)
    return jnp.sum(jnp.where(sizes > 0, cutv / jnp.maximum(sizes, 1), 0.0))


def ncut(W: SparseMatrix, labels, k: int) -> jnp.ndarray:
    """NCut = sum_i cut(C_i, C-bar_i) / vol(C_i)."""
    labels = jnp.asarray(labels)
    M = cut_matrix(W, labels, k)
    vol = jnp.sum(M, axis=1)
    cutv = vol - jnp.diag(M)
    return jnp.sum(jnp.where(vol > 0, cutv / jnp.maximum(vol, 1e-12), 0.0))


def clustering_accuracy(pred, truth, k: int) -> float:
    """Best-permutation accuracy via Hungarian matching on the confusion
    matrix (scipy linear_sum_assignment)."""
    from scipy.optimize import linear_sum_assignment

    pred = np.asarray(pred)
    truth = np.asarray(truth)
    C = np.zeros((k, k), np.int64)
    np.add.at(C, (pred, truth), 1)
    r, c = linear_sum_assignment(-C)
    return float(C[r, c].sum()) / len(pred)
