"""GrB-pGrass: the paper's end-to-end p-spectral clustering pipeline.

  1. p=2 start: smallest-k eigenvectors of the graph Laplacian (LOBPCG,
     dense-eigh fallback) — classical spectral clustering coordinates.
  2. p-continuation: for p_t = max(p_target, 0.9^t * 2.0), minimize
     F_{p_t}(U) over Gr(k,n) with the driver ``PSCConfig.solver`` names
     (core.solvers registry, DESIGN.md §7): "newton" (trust-region
     Newton + tCG, the paper's driver), "scf" (linear eigenproblems on
     the IRLS-reweighted graph), or "inverse_power" (sequential
     deflated columns, the p → 1 end), warm-started from the previous p.
  3. Discretize the k nonlinear eigenvectors with kmeans++ (core.kmeans).

Hot loops are the SpMM-shaped ops from grblas (+ Pallas kernels on TPU);
every driver consumes the same ``api.mxm`` rings, so backend selection
(``PSCConfig.backend``) and solver selection compose freely.

Two execution-shaping knobs, both provably transparent to callers:

  * ``reorder`` ("rcm" | "degree") relabels the graph with a bandwidth-
    reducing permutation before stage 1 (graphs.reorder) — the SpMM
    gathers then walk the multivector near-sequentially — and every
    row-indexed output (labels, U, init_labels) is un-permuted before
    PSCResult is built.
  * Each driver's per-p minimization is one jitted function, memoized
    per execution signature with ``p`` as a *traced* scalar wherever
    the backend allows (every jnp path), so the p-continuation loop
    hits one trace for the whole schedule instead of re-tracing per
    level.  Pallas kernel paths bake (p, eps) into the kernel as static
    arguments, so there the memo key includes p (trace per level,
    cached across runs).  The memo scaffolding lives in
    core.solvers.registry; ``_NEWTON_TRACES``/``_jitted_minimize`` stay
    importable here as one-release aliases.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas import api as grb_api
from repro.grblas.api import Descriptor
from repro.core import plap, kmeans as km, lobpcg, metrics, solvers
from repro.core.solvers import p_schedule  # re-export (vcycle + benches)
from repro.obs import trace as _obs_trace


@dataclasses.dataclass
class PSCConfig:
    k: int = 4                      # number of clusters / eigenvectors
    p_target: float = 1.2           # final p (paper: p in (1,2]; the
                                    # inverse_power driver reaches p=1)
    p_factor: float = 0.9           # continuation ratio (paper follows [4])
    eps: float = 1e-8               # phi_p smoothing
    newton_iters: int = 30          # outer RTR iterations per p level
    tcg_iters: int = 20             # inner truncated-CG iterations
    grad_tol: float = 1e-5
    kmeans_restarts: int = 8
    kmeans_iters: int = 50
    hvp_mode: str = "graphblas"     # "graphblas" (Alg.1) | "matrix_free"
    normalized_init: bool = False
    seed: int = 0
    # solver driver for the per-p minimization (core.solvers registry):
    # "newton" | "scf" | "inverse_power".  Validated at construction —
    # an unknown name raises SolverUnavailableError, a p_target (or a
    # continuation schedule value) outside the driver's supported range
    # raises ValueError here instead of NaNs mid-loop.
    solver: str = "newton"
    # scf driver knobs: max reweight/eigensolve sweeps per p level and
    # the subspace-drift stopping tolerance (sum of squared principal
    # sines between consecutive sweeps)
    scf_sweeps: int = 12
    scf_tol: float = 1e-5
    # inverse_power driver knobs: projected-gradient steps per column
    # and the initial backtracking step size
    ipm_iters: int = 200
    ipm_lr0: float = 0.5
    # grblas execution backend for the hot loop.  The hot loop issues
    # edge-semiring ops, so the only named backends that can serve it are
    # "coo", (with the SELL-C-σ layout built) "sellcs", and (with the BSR
    # layout built) "edge_pallas"; "auto" picks per platform.  Validated
    # against the graph up front by p_spectral_cluster — a backend that
    # cannot execute raises BackendUnavailableError before any work is
    # done.
    backend: str = "auto"
    interpret: bool = False         # Pallas interpreter mode (numerics pin)
    # bandwidth-reducing vertex relabeling applied before stage 1:
    # "none" | "rcm" | "degree" (graphs.reorder).  Transparent: labels
    # and eigenvectors are un-permuted before PSCResult is returned.
    reorder: str = "none"
    # multilevel V-cycle routing (repro.multilevel, DESIGN.md §6):
    # None/False = flat solve; True = default MultilevelConfig; or a
    # MultilevelConfig instance.  Coarsen with heavy-edge matching, run
    # the continuation on the coarsest graph, prolong + refine back up;
    # labels/U/metrics are returned on THIS graph either way.
    multilevel: object = None
    # warm start (DESIGN.md §8): an (n, k) orthonormal-ish embedding
    # from a previous solve.  When set, the pipeline skips stage 1 (the
    # p=2 eigensolve) AND the p-continuation descent, entering the
    # driver directly at the last ``warm_p_steps`` schedule values via
    # ``solvers.warm_start`` — the repeat-tenant path the serve layer's
    # warm cache feeds.  init_labels/init_rcut are not computed.
    init_U: object = None
    warm_p_steps: int = 1
    # resilience (DESIGN.md §9): ``guard`` = None (off) | True (default
    # GuardConfig) | a solvers.GuardConfig — wraps the continuation in
    # per-level health checks and the recovery ladder
    # (solvers.resilient_continuation).  ``validate`` = None (off) |
    # True (strict) | a graphs.validate.ValidateConfig — input
    # validation + per-component clustering of disconnected graphs
    # before the solve.
    guard: object = None
    validate: object = None
    # telemetry (DESIGN.md §10): ``trace`` = None/False (off) | True
    # (default obs.TraceConfig) | an obs.TraceConfig | an obs.Tracer to
    # record into.  When set, p_spectral_cluster runs under a span
    # session and attaches an ``obs.Telemetry`` (spans, instants, export
    # + phase-breakdown helpers) to ``PSCResult.telemetry``.  If a
    # tracer is already active (an outer session owns the timeline) the
    # spans flow there instead and ``telemetry`` stays None.
    trace: object = None

    def __post_init__(self):
        if self.trace is not None \
                and not isinstance(self.trace, _obs_trace.Tracer):
            _obs_trace.coerce(self.trace)   # raises on bad values now
        # config-time applicability check: solver name resolves and the
        # whole continuation schedule sits in its supported p range
        solvers.validate_config(self)
        if self.k < 1:
            raise ValueError(f"k={self.k} must be >= 1")
        if self.guard or self.solver == "guarded":
            solvers.guard.validate_guard(self)
        if self.validate:
            from repro.graphs import validate as _validate

            _validate.coerce_validate(self.validate)

    def descriptor(self) -> Descriptor:
        return Descriptor(backend=self.backend, interpret=self.interpret)

    def validate_backend(self, W: SparseMatrix) -> None:
        """Shape-only capability probe: fail at config-application time,
        not mid-minimization."""
        desc = self.descriptor()
        if desc.backend == "auto":
            return
        from repro.grblas import backends as _backends
        from repro.grblas.semiring import (plap_edge_semiring,
                                           plap_hvp_edge_semiring)

        probe = jax.ShapeDtypeStruct((W.n_rows, self.k), jnp.float32)
        _backends.select_backend(W, probe,
                                 plap_edge_semiring(2.0, self.eps), desc)
        if self.solver == "newton" and self.hvp_mode == "matrix_free":
            _backends.select_backend(W, (probe, probe),
                                     plap_hvp_edge_semiring(2.0, self.eps),
                                     desc)


@dataclasses.dataclass
class PSCResult:
    labels: np.ndarray
    U: jnp.ndarray                  # final p-eigenvectors (n,k)
    rcut: float
    ncut: float
    p_path: list
    fvals: list                     # F_p at the end of each p level
    hvp_counts: list                # operator-apply count per level
    init_labels: Optional[np.ndarray] = None  # p=2 (Spec) labels
    init_rcut: float = float("nan")
    # multilevel runs only: per-level refinement records (level id, n,
    # nnz, p, fval, n_hvp) appended as the V-cycle walks up
    levels: Optional[list] = None
    # per-driver telemetry: the SolverReport of every minimization the
    # pipeline ran (continuation levels in order; for multilevel runs
    # the coarsest full solve's reports followed by the walk-up
    # refinements).  Optional for back-compat — the serve engine and
    # benchmarks meter convergence from it without re-running.
    reports: Optional[list] = None
    # guarded runs only (PSCConfig.guard / solver="guarded"): the
    # solvers.RecoveryReport — what diverged and which ladder rung
    # brought the solve home (DESIGN.md §9)
    recovery: Optional[object] = None
    # per-component runs only (PSCConfig.validate on a disconnected
    # graph): one summary dict per connected component
    # {"n", "k", "rcut"} in component order (graphs.validate)
    components: Optional[list] = None
    # traced runs only (PSCConfig.trace, DESIGN.md §10): the
    # obs.Telemetry of this solve — spans/instants with Chrome-trace and
    # JSONL export and the per-phase breakdown benchmarks/breakdown.py
    # renders.  None when tracing is off or an outer session owns it.
    telemetry: Optional[object] = None


def stage_keys(seed: int):
    """The pipeline's PRNG key discipline, shared with the serve engine
    (which discretizes batched-solve embeddings OUTSIDE this function
    but must land bit-identical labels): (init kmeans key, final kmeans
    key) in the exact split order p_spectral_cluster consumes them."""
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    _, k_final = jax.random.split(key)
    return k_init, k_final


def discretize(U: jnp.ndarray, k: int, key, restarts: int = 8,
               iters: int = 50):
    """Stage 3: row-normalize like [4] (scale-invariant coordinates) and
    kmeans++ the nonlinear eigenvectors.  Shared with the serve engine
    so bucketed solves label exactly like the flat pipeline."""
    Xn = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True), 1e-12)
    labels, _ = km.kmeans(key, Xn, k, restarts=restarts, iters=iters)
    return labels


def _trivial_result(W: SparseMatrix, cfg: PSCConfig) -> PSCResult:
    """Degenerate k handled in closed form: k=1 is the all-ones cluster
    (the Laplacian kernel — no eigensolve or kmeans needed), k=n puts
    every vertex in its own cluster (U = I is the only orthonormal
    basis of R^n up to rotation)."""
    n, k = W.n_rows, cfg.k
    if k == 1:
        labels = np.zeros(n, np.int64)
        U = jnp.full((n, 1), 1.0 / np.sqrt(max(n, 1)), jnp.float32)
    else:                                            # k == n
        labels = np.arange(n, dtype=np.int64)
        U = jnp.eye(n, dtype=jnp.float32)
    rcut = float(metrics.rcut(W, labels, k))
    ncut = float(metrics.ncut(W, labels, k))
    return PSCResult(labels=labels, U=U, rcut=rcut, ncut=ncut,
                     p_path=[], fvals=[], hvp_counts=[],
                     init_labels=labels.copy(), init_rcut=rcut, reports=[])


def p_spectral_cluster(W: SparseMatrix, cfg: PSCConfig) -> PSCResult:
    """Run the full GrB-pGrass pipeline on graph W.

    With ``cfg.trace`` set (and no outer tracer active) the whole solve
    runs under a span session rooted at "psc" and the result carries
    ``telemetry`` (obs.Telemetry).  The recursive coarse-level call of
    a multilevel solve reuses the outer session, so one timeline covers
    the whole V-cycle."""
    with _obs_trace.session(cfg.trace) as owner:
        with _obs_trace.ACTIVE.span("psc", cat="psc", n=W.n_rows,
                                    nnz=W.nnz, k=cfg.k, solver=cfg.solver,
                                    backend=cfg.backend,
                                    multilevel=bool(cfg.multilevel)):
            res = _cluster_impl(W, cfg)
        if owner is not None:
            res.telemetry = _obs_trace.Telemetry.from_tracer(owner)
    return res


def _cluster_impl(W: SparseMatrix, cfg: PSCConfig) -> PSCResult:
    n = W.n_rows
    if n == 0:
        raise ValueError("cannot cluster an empty graph (n_rows == 0): "
                         "build the SparseMatrix with at least one vertex")
    if cfg.k > n:
        raise ValueError(f"k={cfg.k} exceeds the number of vertices "
                         f"n={n}; every cluster needs at least one vertex")
    if cfg.validate:
        from repro.graphs import validate as _validate

        vcfg = _validate.coerce_validate(cfg.validate)
        W = _validate.validate_graph(W, vcfg)
        if 1 < cfg.k < n:
            comps = _validate.connected_components(W)
            if comps.n_components > 1:
                return _validate.cluster_components(W, cfg, comps)
    if cfg.k == 1 or cfg.k == n:
        return _trivial_result(W, cfg)
    if cfg.multilevel:
        from repro.multilevel.vcycle import (MultilevelConfig,
                                             multilevel_cluster)

        ml = (cfg.multilevel if isinstance(cfg.multilevel, MultilevelConfig)
              else MultilevelConfig())
        return multilevel_cluster(W, cfg, ml)
    inv = perm = None
    if cfg.reorder != "none":
        from repro.graphs.reorder import reorder as _reorder

        W, perm, inv = _reorder(W, method=cfg.reorder)
    cfg.validate_backend(W)
    k_init, k_final = stage_keys(cfg.seed)
    recovery = None

    if cfg.init_U is not None:
        # -- warm start (DESIGN.md §8): a previous embedding is a valid
        # Grassmann feasible point — skip stage 1 and the continuation
        # descent, enter the driver at the schedule tail.
        U = jnp.asarray(cfg.init_U)
        if U.shape != (W.n_rows, cfg.k):
            raise ValueError(f"init_U shape {U.shape} != ({W.n_rows}, "
                             f"{cfg.k})")
        if perm is not None:
            U = U[jnp.asarray(perm)]
        U = jnp.linalg.qr(U)[0]
        init_labels = None
        init_rcut = float("nan")
        with _obs_trace.ACTIVE.span("continuation", cat="psc", warm=True,
                                    solver=cfg.solver) as sp:
            if cfg.guard or cfg.solver == "guarded":
                U, p_path, fvals, hvps, reports, recovery = \
                    solvers.resilient_warm_start(W, U, cfg)
            else:
                U, p_path, fvals, hvps, reports = solvers.warm_start(
                    W, U, cfg, steps=cfg.warm_p_steps)
            sp.fence(U)
            sp.set(levels=len(p_path))
    else:
        # -- stage 1: linear (p=2) spectral start.  The stage-1 matvec
        # runs under the reals ring, so forward the configured
        # descriptor only when that backend can serve it (edge_pallas
        # is hot-loop-only).
        with _obs_trace.ACTIVE.span("init", cat="psc", n=W.n_rows,
                                    k=cfg.k) as sp:
            stage1_desc = grb_api.capable_desc(W, desc=cfg.descriptor(),
                                               k=cfg.k)
            _, U = lobpcg.smallest_eigvecs(W, cfg.k,
                                           normalized=cfg.normalized_init,
                                           seed=cfg.seed, desc=stage1_desc)
            U = jnp.linalg.qr(U)[0]
            init_labels, _ = km.kmeans(k_init, U, cfg.k,
                                       restarts=cfg.kmeans_restarts,
                                       iters=cfg.kmeans_iters)
            init_rcut = float(metrics.rcut(W, init_labels, cfg.k))
            sp.set(init_rcut=init_rcut)

        # -- stage 2: p-continuation under the registered driver (the
        # guarded path adds per-level health checks and the recovery
        # ladder — DESIGN.md §9)
        with _obs_trace.ACTIVE.span("continuation", cat="psc",
                                    solver=cfg.solver) as sp:
            if cfg.guard or cfg.solver == "guarded":
                U, p_path, fvals, hvps, reports, recovery = \
                    solvers.resilient_continuation(W, U, cfg)
            else:
                U, p_path, fvals, hvps, reports = solvers.p_continuation(
                    W, U, cfg)
            sp.fence(U)
            sp.set(levels=len(p_path))

    # -- stage 3: kmeans discretization of the nonlinear eigenvectors
    with _obs_trace.ACTIVE.span("kmeans", cat="psc", n=W.n_rows,
                                k=cfg.k) as sp:
        labels = discretize(U, cfg.k, k_final,
                            restarts=cfg.kmeans_restarts,
                            iters=cfg.kmeans_iters)
        sp.fence(labels)

        # cut metrics are computed in whichever labeling W currently
        # has — they are permutation-invariant — then every row-indexed
        # output is mapped back to the caller's vertex ids
        # (inv[old] = new).
        rcut = float(metrics.rcut(W, labels, cfg.k))
        ncut = float(metrics.ncut(W, labels, cfg.k))
        sp.set(rcut=rcut)
    labels = np.asarray(labels)
    if init_labels is not None:
        init_labels = np.asarray(init_labels)
    if inv is not None:
        labels = labels[inv]
        if init_labels is not None:
            init_labels = init_labels[inv]
        U = U[jnp.asarray(inv)]

    return PSCResult(
        labels=labels, U=U,
        rcut=rcut, ncut=ncut,
        p_path=p_path, fvals=fvals, hvp_counts=hvps,
        init_labels=init_labels, init_rcut=init_rcut,
        reports=reports, recovery=recovery)


def spectral_cluster(W: SparseMatrix, k: int, seed: int = 0,
                     normalized: bool = False) -> Tuple[np.ndarray, float]:
    """Baseline `Spec`: classical p=2 spectral clustering (Luxburg)."""
    _, U = lobpcg.smallest_eigvecs(W, k, normalized=normalized, seed=seed)
    labels, _ = km.kmeans(jax.random.PRNGKey(seed), U, k)
    return np.asarray(labels), float(metrics.rcut(W, labels, k))


# --- one-release aliases: the driver layer moved to core.solvers ----------
# (consumers: benchmarks/breakdown.py, the V-cycle pre-PR-6, tests that
# pin the no-retrace contract.  New code imports repro.core.solvers.)

_NEWTON_TRACES = solvers.SOLVER_TRACES          # same list object
_NEWTON_CACHE = solvers.registry._TRACE_CACHE   # same dict object
_needs_static_p = solvers.newton._needs_static_p
_jitted_minimize = solvers.newton._jitted_minimize


def _minimize_at_p(W: SparseMatrix, U0, p, cfg: PSCConfig):
    """Deprecated alias: one continuation level under cfg.solver
    (returns a SolverReport; ``n_hvp`` stays readable on it)."""
    return solvers.minimize_at_p(W, U0, p, cfg)
