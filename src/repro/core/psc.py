"""GrB-pGrass: the paper's end-to-end p-spectral clustering pipeline.

  1. p=2 start: smallest-k eigenvectors of the graph Laplacian (LOBPCG,
     dense-eigh fallback) — classical spectral clustering coordinates.
  2. p-continuation: for p_t = max(p_target, 0.9^t * 2.0), minimize
     F_{p_t}(U) over Gr(k,n) with trust-region Newton + truncated CG
     (core.grassmann), warm-started from the previous p.
  3. Discretize the k nonlinear eigenvectors with kmeans++ (core.kmeans).

Hot loops are the SpMM-shaped ops from grblas (+ Pallas kernels on TPU);
the HVP inside tCG is the paper's Algorithm 1 (or the fused matrix-free
variant — select with hvp_mode).

Two execution-shaping knobs, both provably transparent to callers:

  * ``reorder`` ("rcm" | "degree") relabels the graph with a bandwidth-
    reducing permutation before stage 1 (graphs.reorder) — the SpMM
    gathers then walk the multivector near-sequentially — and every
    row-indexed output (labels, U, init_labels) is un-permuted before
    PSCResult is built.
  * The per-p Newton minimization is one jitted function, memoized per
    execution signature with ``p`` as a *traced* scalar wherever the
    backend allows (every jnp path), so the p-continuation loop hits one
    trace for the whole schedule instead of re-tracing per level.
    Pallas kernel paths bake (p, eps) into the kernel as static
    arguments, so there the memo key includes p (trace per level, cached
    across runs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas import api as grb_api
from repro.grblas.api import Descriptor
from repro.core import plap, kmeans as km, lobpcg, metrics
from repro.core.grassmann import rtr_minimize, RTRResult


@dataclasses.dataclass
class PSCConfig:
    k: int = 4                      # number of clusters / eigenvectors
    p_target: float = 1.2           # final p (paper: p in (1,2])
    p_factor: float = 0.9           # continuation ratio (paper follows [4])
    eps: float = 1e-8               # phi_p smoothing
    newton_iters: int = 30          # outer RTR iterations per p level
    tcg_iters: int = 20             # inner truncated-CG iterations
    grad_tol: float = 1e-5
    kmeans_restarts: int = 8
    kmeans_iters: int = 50
    hvp_mode: str = "graphblas"     # "graphblas" (Alg.1) | "matrix_free"
    normalized_init: bool = False
    seed: int = 0
    # grblas execution backend for the hot loop.  The hot loop issues
    # edge-semiring ops, so the only named backends that can serve it are
    # "coo", (with the SELL-C-σ layout built) "sellcs", and (with the BSR
    # layout built) "edge_pallas"; "auto" picks per platform.  Validated
    # against the graph up front by p_spectral_cluster — a backend that
    # cannot execute raises BackendUnavailableError before any work is
    # done.
    backend: str = "auto"
    interpret: bool = False         # Pallas interpreter mode (numerics pin)
    # bandwidth-reducing vertex relabeling applied before stage 1:
    # "none" | "rcm" | "degree" (graphs.reorder).  Transparent: labels
    # and eigenvectors are un-permuted before PSCResult is returned.
    reorder: str = "none"
    # multilevel V-cycle routing (repro.multilevel, DESIGN.md §6):
    # None/False = flat solve; True = default MultilevelConfig; or a
    # MultilevelConfig instance.  Coarsen with heavy-edge matching, run
    # the continuation on the coarsest graph, prolong + refine back up;
    # labels/U/metrics are returned on THIS graph either way.
    multilevel: object = None

    def descriptor(self) -> Descriptor:
        return Descriptor(backend=self.backend, interpret=self.interpret)

    def validate_backend(self, W: SparseMatrix) -> None:
        """Shape-only capability probe: fail at config-application time,
        not mid-Newton-iteration."""
        desc = self.descriptor()
        if desc.backend == "auto":
            return
        from repro.grblas import backends as _backends
        from repro.grblas.semiring import (plap_edge_semiring,
                                           plap_hvp_edge_semiring)

        probe = jax.ShapeDtypeStruct((W.n_rows, self.k), jnp.float32)
        _backends.select_backend(W, probe,
                                 plap_edge_semiring(2.0, self.eps), desc)
        if self.hvp_mode == "matrix_free":
            _backends.select_backend(W, (probe, probe),
                                     plap_hvp_edge_semiring(2.0, self.eps),
                                     desc)


@dataclasses.dataclass
class PSCResult:
    labels: np.ndarray
    U: jnp.ndarray                  # final p-eigenvectors (n,k)
    rcut: float
    ncut: float
    p_path: list
    fvals: list                     # F_p at the end of each p level
    hvp_counts: list                # Hessian-apply count per level
    init_labels: Optional[np.ndarray] = None  # p=2 (Spec) labels
    init_rcut: float = float("nan")
    # multilevel runs only: per-level refinement records (level id, n,
    # nnz, p, fval, n_hvp) appended as the V-cycle walks up
    levels: Optional[list] = None


# --- memoized jitted Newton minimization (one trace per execution
# signature, not per continuation level) ----------------------------------

_NEWTON_CACHE: dict = {}
_NEWTON_TRACES: list = []   # one entry appended per *trace*; tests assert
                            # the continuation loop doesn't grow it


def _needs_static_p(cfg: PSCConfig, W: SparseMatrix, U0) -> bool:
    """Would the backend serving the hot loop bake (p, eps) into a
    Pallas kernel?  Then p cannot be a tracer.  The answer lives on the
    backend registry (Backend.static_ring_params) — this probes the same
    dispatch the hot loop will run (shape-only, like validate_backend)
    instead of duplicating the registry's capability rules here.  Pallas
    paths are only taken on TPU or under interpret; everywhere else the
    jnp paths keep the traced-p single trace."""
    if not (cfg.interpret or jax.default_backend() == "tpu"):
        return False
    from repro.grblas import backends as _backends
    from repro.grblas.semiring import (plap_edge_semiring,
                                       plap_hvp_edge_semiring)

    desc = cfg.descriptor()
    probe = jax.ShapeDtypeStruct((W.n_rows, U0.shape[-1]), U0.dtype)
    probes = [(plap_edge_semiring(2.0, cfg.eps), probe)]
    if cfg.hvp_mode == "matrix_free":
        probes.append((plap_hvp_edge_semiring(2.0, cfg.eps), (probe, probe)))
    for ring, X in probes:
        try:
            be = _backends.select_backend(W, X, ring, desc)
        except _backends.BackendUnavailableError:
            continue          # validate_backend already raised for real runs
        if be.static_ring_params:
            return True
    return False


def _jitted_minimize(cfg: PSCConfig, p: float, W: SparseMatrix, U0):
    """The jitted per-p trust-region minimization, memoized per
    (backend, interpret, hvp_mode, eps, iteration budget[, p]).  W rides
    along as a pytree argument, so one cached callable serves every
    graph of matching layout signature."""
    static_p = float(p) if _needs_static_p(cfg, W, U0) else None
    key = (cfg.backend, cfg.interpret, cfg.hvp_mode, cfg.eps,
           cfg.newton_iters, cfg.tcg_iters, cfg.grad_tol, static_p)
    fn = _NEWTON_CACHE.get(key)
    if fn is not None:
        return fn, static_p

    desc = cfg.descriptor()
    eps, hvp_mode = cfg.eps, cfg.hvp_mode
    newton_iters, tcg_iters, grad_tol = (cfg.newton_iters, cfg.tcg_iters,
                                         cfg.grad_tol)

    def run(W, U0, p_run):
        _NEWTON_TRACES.append(key)
        f = lambda U: plap.value(W, U, p_run, eps, desc=desc)
        g = lambda U: plap.euc_grad(W, U, p_run, eps, desc=desc)
        if hvp_mode == "graphblas":
            h = lambda U, eta: plap.hess_eta_graphblas(W, U, eta, p_run, eps,
                                                       desc=desc)
        else:
            h = lambda U, eta: plap.hess_eta_matrix_free(W, U, eta, p_run,
                                                         eps, desc=desc)
        return rtr_minimize(f, g, h, U0, max_iters=newton_iters,
                            tcg_iters=tcg_iters, grad_tol=grad_tol)

    if static_p is None:
        fn = jax.jit(run)
    else:
        fn = jax.jit(lambda W, U0: run(W, U0, static_p))
    _NEWTON_CACHE[key] = fn
    return fn, static_p


def _minimize_at_p(W: SparseMatrix, U0, p, cfg: PSCConfig) -> RTRResult:
    fn, static_p = _jitted_minimize(cfg, p, W, U0)
    if static_p is not None:
        return fn(W, U0)
    # p rides in U0's dtype so float64 pipelines keep the full-precision
    # continuation values the pre-memoized code passed as Python floats
    return fn(W, U0, jnp.asarray(p, U0.dtype))


def p_schedule(cfg: PSCConfig) -> list:
    """The continuation schedule p_t = max(p_target, 2.0 * factor^t),
    t >= 1 — shared by the flat loop below and the nested multilevel
    schedule (repro.multilevel.vcycle)."""
    ps, p = [], 2.0
    while True:
        p = max(cfg.p_target, p * cfg.p_factor)
        ps.append(p)
        if p <= cfg.p_target:
            return ps


def p_spectral_cluster(W: SparseMatrix, cfg: PSCConfig) -> PSCResult:
    """Run the full GrB-pGrass pipeline on graph W."""
    if cfg.multilevel:
        from repro.multilevel.vcycle import (MultilevelConfig,
                                             multilevel_cluster)

        ml = (cfg.multilevel if isinstance(cfg.multilevel, MultilevelConfig)
              else MultilevelConfig())
        return multilevel_cluster(W, cfg, ml)
    inv = None
    if cfg.reorder != "none":
        from repro.graphs.reorder import reorder as _reorder

        W, _, inv = _reorder(W, method=cfg.reorder)
    cfg.validate_backend(W)
    key = jax.random.PRNGKey(cfg.seed)

    # -- stage 1: linear (p=2) spectral start.  The stage-1 matvec runs
    # under the reals ring, so forward the configured descriptor only
    # when that backend can serve it (edge_pallas is hot-loop-only).
    stage1_desc = grb_api.capable_desc(W, desc=cfg.descriptor(), k=cfg.k)
    _, U = lobpcg.smallest_eigvecs(W, cfg.k, normalized=cfg.normalized_init,
                                   seed=cfg.seed, desc=stage1_desc)
    U = jnp.linalg.qr(U)[0]
    key, sub = jax.random.split(key)
    init_labels, _ = km.kmeans(sub, U, cfg.k, restarts=cfg.kmeans_restarts,
                               iters=cfg.kmeans_iters)
    init_rcut = float(metrics.rcut(W, init_labels, cfg.k))

    # -- stage 2: p-continuation on the Grassmann manifold
    p_path, fvals, hvps = [], [], []
    for p in p_schedule(cfg):
        res = _minimize_at_p(W, U, p, cfg)
        U = res.U
        p_path.append(p)
        fvals.append(float(res.fval))
        hvps.append(int(res.n_hvp))

    # -- stage 3: kmeans discretization of the nonlinear eigenvectors
    key, sub = jax.random.split(key)
    # normalize rows like [4] (scale-invariant coordinates)
    Xn = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True), 1e-12)
    labels, _ = km.kmeans(sub, Xn, cfg.k, restarts=cfg.kmeans_restarts,
                          iters=cfg.kmeans_iters)

    # cut metrics are computed in whichever labeling W currently has —
    # they are permutation-invariant — then every row-indexed output is
    # mapped back to the caller's vertex ids (inv[old] = new).
    rcut = float(metrics.rcut(W, labels, cfg.k))
    ncut = float(metrics.ncut(W, labels, cfg.k))
    labels = np.asarray(labels)
    init_labels = np.asarray(init_labels)
    if inv is not None:
        labels = labels[inv]
        init_labels = init_labels[inv]
        U = U[jnp.asarray(inv)]

    return PSCResult(
        labels=labels, U=U,
        rcut=rcut, ncut=ncut,
        p_path=p_path, fvals=fvals, hvp_counts=hvps,
        init_labels=init_labels, init_rcut=init_rcut)


def spectral_cluster(W: SparseMatrix, k: int, seed: int = 0,
                     normalized: bool = False) -> Tuple[np.ndarray, float]:
    """Baseline `Spec`: classical p=2 spectral clustering (Luxburg)."""
    _, U = lobpcg.smallest_eigvecs(W, k, normalized=normalized, seed=seed)
    labels, _ = km.kmeans(jax.random.PRNGKey(seed), U, k)
    return np.asarray(labels), float(metrics.rcut(W, labels, k))
