"""Riemannian trust-region Newton on the Grassmann manifold Gr(k, n).

This is the in-JAX replacement for the ROPTLIB subset the paper uses:
Newton's method on Gr(k,n) with a truncated conjugate-gradient (Steihaug
tCG) inner solver, under a trust region for global convergence
(Absil, Baker & Gallivan, "Trust-region methods on Riemannian
manifolds", 2007 — the solver ROPTLIB's RTRNewton implements).

Representation: a point is an orthonormal U in R^{n x k} (U^T U = I_k);
the tangent space is {xi : U^T xi = 0}.

  proj_U(Z)  = Z - U (U^T Z)               (Euclidean-metric projection)
  rgrad      = proj_U(egrad)
  rhess(eta) = proj_U( ehess(eta) - eta (U^T egrad) )   (Gr correction)
  retract    = qf(U + eta)                 (thin-QR retraction)

Everything is jit-able; the outer loop is lax.while_loop so the whole
optimizer runs on-device (and distributes when the callbacks shard).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def proj(U, Z):
    return Z - U @ (U.T @ Z)


def retract_qr(U, eta):
    Q, R = jnp.linalg.qr(U + eta)
    # fix sign so retraction is continuous (diag(R) > 0)
    sgn = jnp.sign(jnp.diag(R))
    sgn = jnp.where(sgn == 0, 1.0, sgn)
    return Q * sgn[None, :]


def inner(a, b):
    return jnp.sum(a * b)


class RTRState(NamedTuple):
    U: jnp.ndarray
    fval: jnp.ndarray
    grad: jnp.ndarray
    gradnorm: jnp.ndarray
    radius: jnp.ndarray
    it: jnp.ndarray
    n_hvp: jnp.ndarray  # Hessian-apply count (the paper's scaling unit)


class RTRResult(NamedTuple):
    U: jnp.ndarray
    fval: jnp.ndarray
    gradnorm: jnp.ndarray
    iters: jnp.ndarray
    n_hvp: jnp.ndarray


def _tcg(U, grad, hvp, radius, tcg_iters: int, kappa=0.1, theta=1.0):
    """Steihaug-Toint truncated CG for the trust-region subproblem.

    min_eta <grad,eta> + 1/2 <eta, H eta>   s.t. ||eta|| <= radius,
    eta in T_U.  Returns (eta, n_hvp_used).
    """
    eta0 = jnp.zeros_like(grad)
    r0 = grad
    d0 = -r0
    r0r0 = inner(r0, r0)
    norm_g = jnp.sqrt(r0r0)
    stop_tol = norm_g * jnp.minimum(kappa, norm_g ** theta)

    def boundary_point(eta, d):
        """tau >= 0 with ||eta + tau d|| = radius."""
        dd = inner(d, d)
        ed = inner(eta, d)
        ee = inner(eta, eta)
        disc = jnp.sqrt(jnp.maximum(ed * ed + dd * (radius ** 2 - ee), 0.0))
        tau = (-ed + disc) / jnp.maximum(dd, 1e-30)
        return eta + tau * d

    class C(NamedTuple):
        eta: jnp.ndarray
        r: jnp.ndarray
        d: jnp.ndarray
        rr: jnp.ndarray
        k: jnp.ndarray
        done: jnp.ndarray
        n_hvp: jnp.ndarray

    def cond(c: C):
        return jnp.logical_and(c.k < tcg_iters, jnp.logical_not(c.done))

    def body(c: C):
        Hd = proj(U, hvp(c.d))
        dHd = inner(c.d, Hd)
        alpha = c.rr / jnp.where(dHd == 0, 1e-30, dHd)
        eta_next = c.eta + alpha * c.d
        hit_boundary = jnp.logical_or(dHd <= 0,
                                      jnp.sqrt(inner(eta_next, eta_next)) >= radius)
        eta_b = boundary_point(c.eta, c.d)
        r_next = c.r + alpha * Hd
        rr_next = inner(r_next, r_next)
        small = jnp.sqrt(rr_next) <= stop_tol
        beta = rr_next / jnp.where(c.rr == 0, 1e-30, c.rr)
        d_next = -r_next + beta * c.d
        eta_out = jnp.where(hit_boundary, eta_b, eta_next)
        done = jnp.logical_or(hit_boundary, small)
        return C(eta=eta_out, r=r_next, d=d_next, rr=rr_next,
                 k=c.k + 1, done=done, n_hvp=c.n_hvp + 1)

    init = C(eta=eta0, r=r0, d=d0, rr=r0r0, k=jnp.array(0),
             done=jnp.array(False), n_hvp=jnp.array(0))
    out = jax.lax.while_loop(cond, body, init)
    return out.eta, out.n_hvp


def rtr_minimize(f: Callable, egrad: Callable, ehvp: Callable, U0: jnp.ndarray,
                 max_iters: int = 50, tcg_iters: int = 25,
                 grad_tol: float = 1e-6, radius0: float = 0.5,
                 radius_max: float = 4.0) -> RTRResult:
    """Trust-region Newton on Gr(k,n).

    f(U) -> scalar; egrad(U) -> (n,k); ehvp(U, eta) -> (n,k) Euclidean HVP.
    """

    def rgrad(U):
        return proj(U, egrad(U))

    def rhess(U, g_e, eta):
        # Grassmann Hessian: proj( ehvp - eta (U^T egrad) )
        return proj(U, ehvp(U, eta) - eta @ (U.T @ g_e))

    def cond(s: RTRState):
        return jnp.logical_and(s.it < max_iters, s.gradnorm > grad_tol)

    def body(s: RTRState):
        g_e = egrad(s.U)
        g = proj(s.U, g_e)
        hvp = lambda eta: rhess(s.U, g_e, eta)
        eta, used = _tcg(s.U, g, hvp, s.radius, tcg_iters)
        U_try = retract_qr(s.U, eta)
        f_try = f(U_try)
        # actual vs predicted reduction
        Heta = proj(s.U, hvp(eta))
        pred = -(inner(g, eta) + 0.5 * inner(eta, Heta))
        ared = s.fval - f_try
        rho = ared / jnp.where(jnp.abs(pred) < 1e-30, 1e-30, pred)
        accept = rho > 0.05
        U_new = jnp.where(accept, U_try, s.U)
        f_new = jnp.where(accept, f_try, s.fval)
        shrink = rho < 0.25
        grow = jnp.logical_and(rho > 0.75,
                               jnp.sqrt(inner(eta, eta)) > 0.9 * s.radius)
        radius = jnp.where(shrink, 0.25 * s.radius,
                           jnp.where(grow, jnp.minimum(2.0 * s.radius, radius_max),
                                     s.radius))
        g_new = proj(U_new, egrad(U_new))
        return RTRState(U=U_new, fval=f_new, grad=g_new,
                        gradnorm=jnp.linalg.norm(g_new),
                        radius=radius, it=s.it + 1,
                        n_hvp=s.n_hvp + used + 1)

    g0 = rgrad(U0)
    s0 = RTRState(U=U0, fval=f(U0), grad=g0, gradnorm=jnp.linalg.norm(g0),
                  radius=jnp.array(radius0), it=jnp.array(0),
                  n_hvp=jnp.array(0))
    out = jax.lax.while_loop(cond, body, s0)
    return RTRResult(U=out.U, fval=out.fval, gradnorm=out.gradnorm,
                     iters=out.it, n_hvp=out.n_hvp)
