"""Blocked LOBPCG for the smallest-k eigenpairs of the graph Laplacian.

Used for the p=2 starting point of the continuation (classical spectral
clustering): the paper initializes GrB-pGrass from the linear (p=2)
eigenvectors, then tracks them as p decreases.

Pure-JAX implementation: Rayleigh-Ritz over the [X, W, P] block with a
Jacobi (diagonal) preconditioner and Householder-QR orthonormalization.
A dense jnp.linalg.eigh fallback handles tiny graphs.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas import api
from repro.grblas.api import Descriptor


def laplacian_matvec(W: SparseMatrix, normalized: bool = False,
                     desc: Optional[Descriptor] = None) -> Callable:
    """Returns X -> L X with L = D - W (or I - D^-1/2 W D^-1/2).

    The inner SpMM routes through the unified API; ``desc`` selects the
    backend (auto: ELL/COO on CPU, Pallas BSR on TPU, dist with a mesh).
    """
    deg = W.row_sums()
    if normalized:
        dinv = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-12)), 0.0)

        def mv(X):
            DX = dinv[:, None] * X if X.ndim == 2 else dinv * X
            WX = api.mxm(W, DX, desc=desc)
            return X - (dinv[:, None] * WX if X.ndim == 2 else dinv * WX)
    else:
        def mv(X):
            WX = api.mxm(W, X, desc=desc)
            return (deg[:, None] * X if X.ndim == 2 else deg * X) - WX
    return mv


def _ortho(X):
    """Householder QR orthonormalization.

    (A Cholesky-QR variant with jitter silently turns rank-deficient
    blocks into zero columns whose Rayleigh quotient is a spurious 0,
    hijacking the smallest-k Ritz selection — caught by
    tests/test_lobpcg.py; plain QR keeps the basis full rank.)"""
    Q, _ = jnp.linalg.qr(X)
    return Q


def lobpcg(matvec: Callable, X0: jnp.ndarray, k: int,
           precond_diag: Optional[jnp.ndarray] = None,
           max_iters: int = 200, tol: float = 1e-6) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Smallest-k eigenpairs of the SPSD operator ``matvec``.

    X0: (n, m) initial block with m >= k.  Returns (evals (k,), evecs (n,k)).
    Host loop with jitted body (graph eigenproblems here are O(1e6) max
    on CPU; the TPU path distributes the inner SpMM via grblas.dist).
    """
    n, m = X0.shape
    X = _ortho(X0)
    P = jnp.zeros_like(X)
    pinv = None
    if precond_diag is not None:
        pinv = jnp.where(jnp.abs(precond_diag) > 1e-12, 1.0 / precond_diag, 1.0)

    @partial(jax.jit, static_argnames=("with_p",))
    def step(X, P, with_p):
        AX = matvec(X)
        rho = jnp.sum(X * AX, axis=0)          # Rayleigh quotients
        R = AX - X * rho
        resnorm = jnp.linalg.norm(R, axis=0)
        if pinv is not None:
            R = pinv[:, None] * R
        # basis: [X, R(, P)], orthonormalized jointly (first iteration
        # has no P block — a zero block degrades the Ritz basis)
        blocks = [X, R] + ([P] if with_p else [])
        S = _ortho(jnp.concatenate(blocks, axis=1))
        AS = matvec(S)
        T = S.T @ AS
        T = 0.5 * (T + T.T)
        evals, V = jnp.linalg.eigh(T)
        Xn = S @ V[:, :m]
        # P = component of the update living outside the X block
        Pn = S[:, m:] @ V[m:, :m]
        return Xn, Pn, evals[:m], resnorm

    evals = jnp.zeros(m)
    for it in range(max_iters):
        X, P, evals, resnorm = step(X, P, it > 0)
        if float(jnp.max(resnorm[:k])) < tol:
            break
    return evals[:k], X[:, :k]


def lobpcg_fixed(matvec: Callable, X0: jnp.ndarray, k: int,
                 iters: int = 20,
                 precond_diag: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-iteration LOBPCG: the fully traceable (jit/vmap-able)
    variant of :func:`lobpcg` — no host convergence loop, no float()
    synchronization, a static ``iters`` trip count.

    This is the serve engine's batched inner eigensolver (DESIGN.md §8):
    one bucket of padded graphs runs this under ``jax.vmap`` inside a
    single compiled trace, warm-started from the previous embedding.
    Exact-zero rows of ``X0`` stay exactly zero through every step
    (matvec on isolated pad rows is 0, Householder reflectors never mix
    exact-zero rows), which is what makes bucket padding sound for the
    whole eigensolve, not just the SpMM.

    Same Rayleigh-Ritz body as :func:`lobpcg`; the first iteration runs
    without the P block (a zero block degrades the Ritz basis), the
    remaining ``iters - 1`` run inside one ``lax.fori_loop``.
    """
    n, m = X0.shape
    X = _ortho(X0)
    pinv = None
    if precond_diag is not None:
        pinv = jnp.where(jnp.abs(precond_diag) > 1e-12,
                         1.0 / precond_diag, 1.0)

    def step(X, P, with_p: bool):
        AX = matvec(X)
        rho = jnp.sum(X * AX, axis=0)
        R = AX - X * rho
        if pinv is not None:
            R = pinv[:, None] * R
        blocks = [X, R] + ([P] if with_p else [])
        S = _ortho(jnp.concatenate(blocks, axis=1))
        AS = matvec(S)
        T = S.T @ AS
        T = 0.5 * (T + T.T)
        evals, V = jnp.linalg.eigh(T)
        return S @ V[:, :m], S[:, m:] @ V[m:, :m], evals[:m]

    X, P, evals = step(X, jnp.zeros_like(X), False)

    def body(_, carry):
        X, P, _ = carry
        return step(X, P, True)

    X, P, evals = jax.lax.fori_loop(0, max(int(iters) - 1, 0), body,
                                    (X, P, evals))
    return evals[:k], X[:, :k]


def smallest_eigvecs(W: SparseMatrix, k: int, normalized: bool = False,
                     seed: int = 0, max_iters: int = 200,
                     tol: float = 1e-6,
                     desc: Optional[Descriptor] = None,
                     X0: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Smallest-k eigenpairs of the graph Laplacian of W.

    ``desc`` steers the inner Laplacian SpMM (must be a backend capable
    of the reals ring; the tiny-graph dense-eigh path ignores it).
    ``X0`` (n, >=1) warm-starts the LOBPCG block: its columns seed the
    search subspace (padded to block width with random vectors) — the
    SCF driver restarts each reweighted eigensolve from the previous
    sweep's eigenvectors this way.  The dense exact path ignores it."""
    n = W.n_rows
    if n <= 1024:  # dense exact path for tiny graphs
        L = jnp.diag(W.row_sums()) - W.to_dense()
        if normalized:
            d = jnp.maximum(W.row_sums(), 1e-12)
            dih = jax.lax.rsqrt(d)
            L = dih[:, None] * L * dih[None, :]
        evals, evecs = jnp.linalg.eigh(L)
        return evals[:k], evecs[:, :k]
    mv = laplacian_matvec(W, normalized, desc=desc)
    m = min(max(2 * k, k + 4), n)
    key = jax.random.PRNGKey(seed)
    rand = jax.random.normal(key, (n, m), jnp.float32)
    if X0 is not None:
        warm = X0 if X0.ndim == 2 else X0[:, None]
        X0 = rand.astype(warm.dtype).at[:, : min(warm.shape[1], m)].set(
            warm[:, : min(warm.shape[1], m)])
    else:
        X0 = rand
    # seed the constant vector (known nullvector) for fast convergence
    X0 = X0.at[:, 0].set(1.0)
    deg = W.row_sums()
    return lobpcg(mv, X0, k, precond_diag=jnp.maximum(deg, 1e-6),
                  max_iters=max_iters, tol=tol)
