"""Inverse-power driver for the p → 1 end (Hein & Bühler, "An inverse
power method for nonlinear eigenproblems", NIPS 2010).

One nonlinear eigenvector at a time: column l minimizes the smoothed
single-column p-Rayleigh quotient with a projected-gradient descent
(backtracking step control), kept orthogonal to the l-1 columns already
found by Gram-Schmidt deflation after every accepted step — the
sequential scheme that stays well-posed as p → 1, where the joint
Grassmann trust-region model degenerates (the p-energy loses C^2
regularity at the sparsest-cut limit).  This driver therefore registers
the *closed* range [1, 2]: it is the one that reaches p = 1 exactly
(RatioCut / sparsest-cut relaxation; via the same IPM machinery, the
sparse-PCA workload of the source paper's related-work line).

It subsumes the private projected-gradient loop that used to live in
``core.pmulti._minimize_single`` — with two contract fixes: every
gradient/value evaluation routes through ``plap`` under the configured
``PSCConfig.backend`` descriptor (the old loop was constructed per call
site and could silently diverge from the pipeline's routing), and the
whole k-column sweep runs through ONE memoized jitted function (fixed
(n, k) deflation basis + column mask instead of per-column shapes), so
a continuation schedule costs one trace, not k × levels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import plap
from repro.core.solvers import registry
from repro.core.solvers.registry import SolverReport, register_solver


def _needs_static_p(cfg, W, U0) -> bool:
    """The column loop issues 1-column plap_edge SpMMs — static (p, eps)
    only where a Pallas kernel would serve them."""
    from repro.grblas.semiring import plap_edge_semiring

    probe = jax.ShapeDtypeStruct((W.n_rows, 1), U0.dtype)
    return registry.backend_bakes_ring_params(
        cfg, W, [(plap_edge_semiring(2.0, cfg.eps), probe)])


def _jitted_column(cfg, p, W, U0):
    """The jitted one-column minimization, memoized per (backend,
    interpret, eps, step budget[, p]).  Deflation rides on a fixed-shape
    (n, k) basis + (k,) 0/1 mask, so all k columns (and every p level on
    jnp paths) replay one trace."""
    static_p = float(p) if _needs_static_p(cfg, W, U0) else None
    key = ("inverse_power", cfg.backend, cfg.interpret, cfg.eps,
           cfg.ipm_iters, static_p)

    def build():
        desc = cfg.descriptor()
        eps, iters = cfg.eps, cfg.ipm_iters

        def run(W, Ufull, mask, u0, p_run, lr0):
            registry.mark_trace(key)

            def fval(u):
                return plap.value(W, u[:, None], p_run, eps, desc=desc)

            def deflate(x):
                return x - Ufull @ (mask * (Ufull.T @ x))

            def project(u):
                u = deflate(u)
                return u / jnp.maximum(jnp.linalg.norm(u), 1e-12)

            def step(carry, _):
                u, lr, f_u = carry
                g = plap.euc_grad(W, u[:, None], p_run, eps, desc=desc)[:, 0]
                # project to the feasible tangent (deflation + sphere)
                g = deflate(g)
                g = g - u * jnp.dot(u, g)
                u_try = project(u - lr * g)
                f_try = fval(u_try)
                better = f_try < f_u
                u = jnp.where(better, u_try, u)
                f_u = jnp.where(better, f_try, f_u)
                lr = jnp.where(better, lr * 1.1, lr * 0.5)
                return (u, lr, f_u), None

            u0 = project(u0)
            (u, _, f_u), _ = jax.lax.scan(step, (u0, lr0, fval(u0)), None,
                                          length=iters)
            return u, f_u

        if static_p is None:
            return jax.jit(run)
        return jax.jit(lambda W, Ufull, mask, u0, lr0:
                       run(W, Ufull, mask, u0, static_p, lr0))

    return registry.memoized(key, build), static_p


@register_solver("inverse_power", p_min=1.0, p_max=2.0, p_min_open=False,
                 description="sequential deflated inverse power method "
                             "(p → 1 / sparsest-cut end)")
def inverse_power_minimize_at_p(state) -> SolverReport:
    cfg, W = state.cfg, state.W
    U = state.U
    k = U.shape[-1]
    fn, static_p = _jitted_column(cfg, state.p, W, U)
    lr0 = jnp.asarray(cfg.ipm_lr0, U.dtype)
    mask = jnp.zeros((k,), U.dtype)
    f_cols = []
    for l in range(k):
        args = (W, U, mask, U[:, l], lr0)
        if static_p is None:
            args = args[:4] + (jnp.asarray(state.p, U.dtype), lr0)
        u, f_u = fn(*args)
        U = U.at[:, l].set(u)
        mask = mask.at[l].set(1.0)
        f_cols.append(f_u)
    fval = float(jnp.sum(jnp.stack(f_cols)))
    # one gradient + one value SpMM per step per column (the paper's
    # operator-apply accounting unit)
    n_apply = 2 * k * int(cfg.ipm_iters)
    return SolverReport(U=U, fval=fval, n_apply=n_apply,
                        iters=int(cfg.ipm_iters), converged=True)
