"""Solver-driver registry for the nonlinear eigenproblem (DESIGN.md §7).

The p-spectral pipeline factors into three layers: the algebra
(grblas.api.mxm under a Descriptor), the *driver* that minimizes the
p-Rayleigh functional at one continuation level, and the continuation /
discretization shell around it (core.psc).  This module owns the middle
layer's dispatch — the solver analogue of ``grblas/backends.py``:

  * ``register_solver`` / ``resolve_solver`` — a name-keyed registry of
    ``Solver`` entries; unknown names raise ``SolverUnavailableError``
    (a ValueError, so config-time validation surfaces it loudly).
  * the driver contract — ``SolverState`` in (graph, warm-start U, p,
    config), ``SolverReport`` out (U, fval, operator-apply count,
    iteration count, converged flag).  Every driver consumes the same
    ``api.mxm`` rings; where two drivers converge they must land the
    same clusters (pinned by tests/test_solver_registry.py).
  * per-driver applicability — each entry declares its supported p
    range; ``validate_config`` checks ``p_target`` AND every value of
    the continuation schedule against it at config-construction time,
    so a p outside the driver's regime is a clear ValueError instead of
    NaNs deep in a minimization loop.
  * the p-continuation loop (``p_continuation`` / ``p_schedule``) and
    the trace-memo scaffolding (``memoized`` / ``mark_trace`` /
    ``SOLVER_TRACES``), hoisted out of core.psc so every driver gets
    PR-3's one-trace-per-schedule behavior for free: a driver builds
    its jitted step once per execution signature (p traced on jnp
    backends, static only where a Pallas kernel bakes ring params) and
    the whole schedule replays the cached callable.

Registered drivers (imported by ``core.solvers.__init__``):

  name           p range    regime
  newton         (1, 2]     trust-region Newton + tCG on Gr(k,n) — the
                            paper's driver (moved from core.psc)
  scf            (1, 2]     self-consistent field: linear eigenproblems
                            on the IRLS-reweighted graph (Upadhyaya,
                            Jarlebring & Tudisco, arXiv:2111.09750)
  inverse_power  [1, 2]     one eigenvector at a time with deflation,
                            p → 1 sparsest-cut end (Hein & Bühler) —
                            subsumes the old core.pmulti loop

A new driver is one ``register_solver`` call, not another private loop
welded into the pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace


class SolverUnavailableError(ValueError):
    """The requested solver is not registered (or cannot run here)."""


@dataclasses.dataclass(frozen=True)
class SolverState:
    """Input contract of one per-p minimization: minimize F_p over
    Gr(k,n) starting from the warm-start iterate ``U`` (orthonormal
    columns), reading execution knobs (backend descriptor, iteration
    budgets, eps) from ``cfg`` (a PSCConfig-shaped object)."""

    W: object                   # SparseMatrix (duck-typed: no psc import)
    U: jnp.ndarray              # (n, k) warm start, orthonormal columns
    p: float
    cfg: object                 # PSCConfig


@dataclasses.dataclass(frozen=True)
class SolverReport:
    """Output contract: the minimizer plus the paper's accounting units."""

    U: jnp.ndarray              # (n, k) iterate (orthonormal columns)
    fval: float                 # F_p at U
    n_apply: int                # operator applies (HVPs / SpMM sweeps) —
                                # the paper's scaling unit
    iters: int                  # outer iterations the driver ran
    converged: bool

    @property
    def n_hvp(self):
        """Back-compat alias: pre-registry callers read RTRResult.n_hvp."""
        return self.n_apply


@dataclasses.dataclass(frozen=True)
class Solver:
    name: str
    minimize_at_p: Callable     # (SolverState) -> SolverReport
    p_min: float
    p_max: float
    p_min_open: bool = True     # True: p must be > p_min (Newton needs
                                # the C^2 interior); False: p_min itself
                                # is reachable (the p→1 driver)
    description: str = ""

    def supports_p(self, p: float) -> bool:
        lo_ok = (p > self.p_min) if self.p_min_open else (p >= self.p_min)
        return lo_ok and p <= self.p_max

    def p_range_str(self) -> str:
        return f"{'(' if self.p_min_open else '['}{self.p_min}, {self.p_max}]"


_REGISTRY: Dict[str, Solver] = {}


def register_solver(name: str, *, p_min: float, p_max: float,
                    p_min_open: bool = True, description: str = ""):
    """Decorator: register ``fn`` as the minimize_at_p hook of ``name``."""

    def deco(fn):
        _REGISTRY[name] = Solver(name=name, minimize_at_p=fn, p_min=p_min,
                                 p_max=p_max, p_min_open=p_min_open,
                                 description=description)
        return fn

    return deco


def registered_solvers() -> Dict[str, Solver]:
    return dict(_REGISTRY)


def resolve_solver(name: str) -> Solver:
    solver = _REGISTRY.get(name)
    if solver is None:
        raise SolverUnavailableError(
            f"unknown solver {name!r}; registered: {sorted(_REGISTRY)}")
    return solver


def validate_config(cfg) -> Solver:
    """Config-time applicability check (called from PSCConfig.__post_init__):
    resolve the named driver, then verify the continuation schedule —
    p_target and every p the schedule will visit — sits inside its
    supported range.  A violation is a clear ValueError here, not NaNs
    deep in the minimization loop."""
    solver = resolve_solver(cfg.solver)
    if not (0.0 < cfg.p_factor < 1.0):
        raise ValueError(
            f"p_factor={cfg.p_factor} must lie in (0, 1): the continuation "
            f"schedule p_t = max(p_target, 2.0 * factor^t) must descend")
    ranges = {s.name: s.p_range_str() for s in _REGISTRY.values()}
    if not solver.supports_p(cfg.p_target):
        raise ValueError(
            f"p_target={cfg.p_target} outside solver {solver.name!r} "
            f"supported range {solver.p_range_str()}; per-driver ranges: "
            f"{ranges}")
    for p in p_schedule(cfg):
        if not solver.supports_p(p):
            raise ValueError(
                f"continuation schedule visits p={p} outside solver "
                f"{solver.name!r} supported range {solver.p_range_str()}; "
                f"per-driver ranges: {ranges}")
    return solver


# --- continuation scaffolding (hoisted from core.psc) ---------------------

def p_schedule(cfg) -> list:
    """The continuation schedule p_t = max(p_target, 2.0 * factor^t),
    t >= 1 — shared by the flat pipeline, the multilevel V-cycle and
    config validation."""
    ps, p = [], 2.0
    while True:
        p = max(cfg.p_target, p * cfg.p_factor)
        ps.append(p)
        if p <= cfg.p_target:
            return ps


def minimize_at_p(W, U0, p, cfg) -> SolverReport:
    """One continuation level under the driver ``cfg.solver`` names."""
    solver = resolve_solver(cfg.solver)
    return solver.minimize_at_p(SolverState(W=W, U=U0, p=p, cfg=cfg))


def p_continuation(W, U0, cfg):
    """Run the whole p schedule, warm-starting each level from the last.

    Returns (U, p_path, fvals, applies, reports) — the per-level records
    the pipeline stores in PSCResult (``reports`` is the full
    SolverReport per level, threaded into ``PSCResult.reports`` so the
    serve engine and benchmarks can meter convergence without re-running
    the solve).  Drivers are resolved once; every level replays the
    driver's memoized jitted step (one trace per execution signature,
    not per level — see ``memoized``)."""
    solver = resolve_solver(cfg.solver)
    U = U0
    p_path: List[float] = []
    fvals: List[float] = []
    applies: List[int] = []
    reports: List[SolverReport] = []
    for p in p_schedule(cfg):
        with _obs_trace.ACTIVE.span("solver.level", cat="solver",
                                    solver=solver.name, p=float(p)) as sp:
            rep = solver.minimize_at_p(SolverState(W=W, U=U, p=p, cfg=cfg))
            sp.fence(rep.U)
            sp.set(fval=float(rep.fval), n_apply=int(rep.n_apply),
                   iters=int(rep.iters), converged=bool(rep.converged))
        U = rep.U
        p_path.append(p)
        fvals.append(float(rep.fval))
        applies.append(int(rep.n_apply))
        reports.append(rep)
    return U, p_path, fvals, applies, reports


def warm_start(W, U0, cfg, p_final: Optional[float] = None,
               steps: int = 1):
    """Warm entry point of the driver contract (DESIGN.md §8): enter the
    continuation at its END instead of replaying the whole p schedule.

    ``U0`` is a previous solve's embedding (the Grassmann formulation
    makes any orthonormal (n, k) a feasible restart point); the driver
    runs only the last ``steps`` schedule values, ending at ``p_final``
    (default ``cfg.p_target``).  This is the repeat-tenant path the
    serve layer's warm cache feeds: a good U converges in a few sweeps
    of SCF or a couple of Newton steps, skipping the p=2 eigensolve and
    the descent from p=2 entirely.

    Returns the same (U, p_path, fvals, applies, reports) tuple as
    ``p_continuation``."""
    solver = resolve_solver(cfg.solver)
    p_end = cfg.p_target if p_final is None else float(p_final)
    if not solver.supports_p(p_end):
        raise ValueError(
            f"warm start at p={p_end} outside solver {solver.name!r} "
            f"supported range {solver.p_range_str()}")
    tail = [p for p in p_schedule(cfg) if p >= p_end][-max(int(steps), 1):]
    if not tail or tail[-1] != p_end:
        tail = (tail + [p_end])[-max(int(steps), 1):]
    U = U0
    p_path: List[float] = []
    fvals: List[float] = []
    applies: List[int] = []
    reports: List[SolverReport] = []
    for p in tail:
        with _obs_trace.ACTIVE.span("solver.level", cat="solver",
                                    solver=solver.name, p=float(p),
                                    warm=True) as sp:
            rep = solver.minimize_at_p(SolverState(W=W, U=U, p=p, cfg=cfg))
            sp.fence(rep.U)
            sp.set(fval=float(rep.fval), n_apply=int(rep.n_apply),
                   iters=int(rep.iters), converged=bool(rep.converged))
        U = rep.U
        p_path.append(p)
        fvals.append(float(rep.fval))
        applies.append(int(rep.n_apply))
        reports.append(rep)
    return U, p_path, fvals, applies, reports


# --- trace-memo scaffolding (hoisted from core.psc, PR-3) ------------------

_TRACE_CACHE: Dict[tuple, Callable] = {}
SOLVER_TRACES: List[tuple] = []   # one entry appended per *trace*; tests
                                  # assert a continuation doesn't grow it
TRACE_LISTENERS: List[Callable] = []   # extra per-compile hooks (key) -> None


def memoized(key: tuple, build: Callable) -> Callable:
    """The compiled callable for ``key``, building on first use.

    ``build()`` returns the jitted callable; its traced body should call
    ``mark_trace(key)`` so retraces are observable.  Keys are
    per-driver execution signatures — (driver name, backend, interpret,
    eps, iteration budget[, static p]) — so one cached callable serves
    every graph of matching layout signature across the whole
    continuation schedule and across runs."""
    fn = _TRACE_CACHE.get(key)
    if fn is None:
        fn = build()
        _TRACE_CACHE[key] = fn
    return fn


def mark_trace(key: tuple) -> None:
    """Record a trace event (call from inside the traced function: jit
    replays are silent, only fresh traces append).  Each fresh trace
    also bumps ``compiles_total{site=<key head>}`` on the DEFAULT
    metrics registry and stamps a ``compile`` instant on the active
    span timeline — obs.retrace builds its detector on this."""
    SOLVER_TRACES.append(key)
    site = str(key[0]) if key else "?"
    _obs_metrics.DEFAULT.counter("compiles_total", site=site).inc()
    _obs_trace.ACTIVE.instant("compile", site=site, key=str(key))
    for fn in TRACE_LISTENERS:
        fn(key)


def backend_bakes_ring_params(cfg, W, probes) -> bool:
    """Would the backend serving these (ring, X-probe) combinations bake
    the ring's (p, eps) into a Pallas kernel as static arguments?  Then
    p cannot be a tracer and the driver's memo key must include it
    (trace per level, cached across runs).  Pallas paths are only taken
    on TPU or under interpret; everywhere else the jnp paths keep the
    traced-p single trace.  ``probes`` is a list of (ring, X) with X a
    ShapeDtypeStruct or a tuple of them (pair rings)."""
    if not (cfg.interpret or jax.default_backend() == "tpu"):
        return False
    from repro.grblas import backends as _backends

    desc = cfg.descriptor()
    for ring, X in probes:
        try:
            be = _backends.select_backend(W, X, ring, desc)
        except _backends.BackendUnavailableError:
            continue    # validate_backend already raised for real runs
        if be.static_ring_params:
            return True
    return False
