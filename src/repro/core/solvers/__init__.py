"""repro.core.solvers — the solver-driver registry (DESIGN.md §7).

Importing this package registers the three drivers (newton, scf,
inverse_power); ``PSCConfig(solver=...)`` threads selection through the
pipeline, the multilevel V-cycle takes per-level choices, and a new
driver is one ``register_solver`` call.
"""
from repro.core.solvers.registry import (
    SOLVER_TRACES,
    Solver,
    SolverReport,
    SolverState,
    SolverUnavailableError,
    backend_bakes_ring_params,
    memoized,
    mark_trace,
    minimize_at_p,
    p_continuation,
    p_schedule,
    register_solver,
    registered_solvers,
    resolve_solver,
    validate_config,
    warm_start,
)
from repro.core.solvers import newton, scf, inverse_power  # register drivers
from repro.core.solvers import guard  # registers "guarded" (DESIGN.md §9)
from repro.core.solvers.guard import (
    GuardConfig,
    RecoveryReport,
    RungRecord,
    SolverDivergence,
    resilient_continuation,
    resilient_warm_start,
)

__all__ = [
    "SOLVER_TRACES", "Solver", "SolverReport", "SolverState",
    "SolverUnavailableError", "backend_bakes_ring_params", "memoized",
    "mark_trace", "minimize_at_p", "p_continuation", "p_schedule",
    "register_solver", "registered_solvers", "resolve_solver",
    "validate_config", "warm_start", "newton", "scf", "inverse_power",
    "guard", "GuardConfig", "RecoveryReport", "RungRecord",
    "SolverDivergence", "resilient_continuation", "resilient_warm_start",
]
