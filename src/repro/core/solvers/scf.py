"""SCF driver: self-consistent field iteration for the p-Laplacian
eigenproblem (Upadhyaya, Jarlebring & Tudisco, arXiv:2111.09750).

The first-order condition of the p-Rayleigh functional reads
``Delta_p u = lambda * phi(u)`` — a *linear* eigenproblem in u once the
nonlinear edge response is frozen: with the secant (IRLS) weights

    w-hat_e = w_e * (||d_e||^2 + eps)^{(p-2)/2},   d_e = U[i] - U[j]

the p-Laplacian apply coincides with the ordinary graph Laplacian of
the reweighted graph W-hat at the linearization point (the group-IRLS
majorizer of the trace energy; for p < 2 it shrinks exactly the
across-cluster edges with large coordinate differences).  The SCF
iteration alternates

    1. freeze U, build W-hat on W's fixed pattern (``W.with_vals`` —
       the Algorithm-1 reweighting idiom, on-device, layout-preserving)
    2. smallest-k eigenvectors of L(W-hat) via ``lobpcg.smallest_eigvecs``
       (warm-started from U; every inner SpMM routes through
       ``api.mxm`` under the configured descriptor)

until the subspace stops moving (``scf_sweeps`` / ``scf_tol``).  Each
sweep is a sequence of *linear* eigenproblems — no Hessian machinery —
which is why the V-cycle uses SCF as its cheap coarse-level driver.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lobpcg, plap
from repro.core.solvers import registry
from repro.core.solvers.registry import SolverReport, register_solver
from repro.grblas import api as grb_api
from repro.grblas.semiring import reals_ring


def _reweight_fn(cfg):
    """Jitted secant reweighting, memoized with p traced — one trace
    serves the whole continuation schedule (the reweighted SpMMs run
    the reals ring, which no backend bakes params for)."""
    key = ("scf", "reweight", cfg.eps)

    def build():
        eps = cfg.eps

        def reweight(vals, d, p):
            registry.mark_trace(key)
            g2 = jnp.sum(d * d, axis=-1)            # (nnz,) group norm
            return vals * (g2 + eps) ** ((p - 2.0) / 2.0)

        return jax.jit(reweight)

    return registry.memoized(key, build)


@register_solver("scf", p_min=1.0, p_max=2.0, p_min_open=True,
                 description="self-consistent field: linear eigenproblems "
                             "on the IRLS-reweighted graph")
def scf_minimize_at_p(state) -> SolverReport:
    cfg, W, p = state.cfg, state.W, float(state.p)
    desc = cfg.descriptor()
    U = state.U
    k = U.shape[-1]
    reweight = _reweight_fn(cfg)
    p_dev = jnp.asarray(p, U.dtype)

    sweeps, drift = 0, float("inf")
    for _ in range(max(int(cfg.scf_sweeps), 1)):
        d = U[W.rows] - U[W.cols]                   # (nnz, k) edge diffs
        Wh = W.with_vals(reweight(W.vals, d, p_dev))
        # the reweighted eigensolve runs the reals ring: forward the
        # configured descriptor only where that backend can serve it
        # (hot-loop-only backends degrade to auto, same as stage 1)
        st_desc = grb_api.capable_desc(Wh, reals_ring, desc, k=k,
                                       dtype=U.dtype)
        _, V = lobpcg.smallest_eigvecs(Wh, k, seed=cfg.seed, desc=st_desc,
                                       X0=U)
        V = jnp.linalg.qr(V)[0]
        sweeps += 1
        # subspace drift: k - ||V^T U||_F^2 = sum of squared principal
        # sines between the old and new subspaces (0 at a fixed point)
        drift = float(k - jnp.sum((V.T @ U) ** 2))
        U = V
        if drift < cfg.scf_tol:
            break

    fval = float(plap.value(W, U, p, cfg.eps, desc=desc))
    return SolverReport(U=U, fval=fval, n_apply=sweeps, iters=sweeps,
                        converged=drift < cfg.scf_tol)
