"""Newton driver: trust-region Newton + truncated CG on Gr(k,n) — the
paper's solver, moved out of core.psc behind the registry contract.

The per-p minimization is one jitted function, memoized per execution
signature with ``p`` as a *traced* scalar wherever the backend allows
(every jnp path), so the p-continuation loop hits one trace for the
whole schedule instead of re-tracing per level.  Pallas kernel paths
bake (p, eps) into the kernel as static arguments, so there the memo
key includes p (trace per level, cached across runs) — the probe lives
on the backend registry (Backend.static_ring_params), surfaced here
through ``registry.backend_bakes_ring_params``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import plap
from repro.core.grassmann import rtr_minimize
from repro.core.solvers import registry
from repro.core.solvers.registry import SolverReport, register_solver


def _needs_static_p(cfg, W, U0) -> bool:
    """Would the backend serving the Newton hot loop (plap apply + the
    configured HVP ring) bake (p, eps) into a Pallas kernel?"""
    from repro.grblas.semiring import (plap_edge_semiring,
                                       plap_hvp_edge_semiring)

    probe = jax.ShapeDtypeStruct((W.n_rows, U0.shape[-1]), U0.dtype)
    probes = [(plap_edge_semiring(2.0, cfg.eps), probe)]
    if cfg.hvp_mode == "matrix_free":
        probes.append((plap_hvp_edge_semiring(2.0, cfg.eps), (probe, probe)))
    return registry.backend_bakes_ring_params(cfg, W, probes)


def _jitted_minimize(cfg, p, W, U0):
    """The jitted per-p trust-region minimization, memoized per
    (backend, interpret, hvp_mode, eps, iteration budget[, p]).  W rides
    along as a pytree argument, so one cached callable serves every
    graph of matching layout signature."""
    static_p = float(p) if _needs_static_p(cfg, W, U0) else None
    key = ("newton", cfg.backend, cfg.interpret, cfg.hvp_mode, cfg.eps,
           cfg.newton_iters, cfg.tcg_iters, cfg.grad_tol, static_p)

    def build():
        desc = cfg.descriptor()
        eps, hvp_mode = cfg.eps, cfg.hvp_mode
        newton_iters, tcg_iters, grad_tol = (cfg.newton_iters, cfg.tcg_iters,
                                             cfg.grad_tol)

        def run(W, U0, p_run):
            registry.mark_trace(key)
            f = lambda U: plap.value(W, U, p_run, eps, desc=desc)
            g = lambda U: plap.euc_grad(W, U, p_run, eps, desc=desc)
            if hvp_mode == "graphblas":
                h = lambda U, eta: plap.hess_eta_graphblas(W, U, eta, p_run,
                                                           eps, desc=desc)
            else:
                h = lambda U, eta: plap.hess_eta_matrix_free(W, U, eta, p_run,
                                                             eps, desc=desc)
            return rtr_minimize(f, g, h, U0, max_iters=newton_iters,
                                tcg_iters=tcg_iters, grad_tol=grad_tol)

        if static_p is None:
            return jax.jit(run)
        return jax.jit(lambda W, U0: run(W, U0, static_p))

    return registry.memoized(key, build), static_p


@register_solver("newton", p_min=1.0, p_max=2.0, p_min_open=True,
                 description="trust-region Newton + tCG on Gr(k,n) "
                             "(the paper's driver)")
def newton_minimize_at_p(state) -> SolverReport:
    cfg, W, U0 = state.cfg, state.W, state.U
    fn, static_p = _jitted_minimize(cfg, state.p, W, U0)
    if static_p is not None:
        res = fn(W, U0)
    else:
        # p rides in U0's dtype so float64 pipelines keep the
        # full-precision continuation values
        res = fn(W, U0, jnp.asarray(state.p, U0.dtype))
    return SolverReport(U=res.U, fval=float(res.fval),
                        n_apply=int(res.n_hvp), iters=int(res.iters),
                        converged=bool(res.gradnorm <= cfg.grad_tol))
