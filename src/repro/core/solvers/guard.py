"""Guarded solver execution + the recovery ladder (DESIGN.md §9).

The continuation runs Newton in the delicate p -> 1 regime where
iterates can stall, lose rank, or blow up — the IPM line of work
(Hein & Buhler 2010) and the SCF formulation (Upadhyaya, Jarlebring &
Tudisco 2021) both exist because naive descent on the p-Laplacian
functional is numerically fragile.  This module wraps any registered
driver with per-level health checks and, on divergence, walks a
configurable recovery ladder instead of returning garbage:

  checks (``check_report``, applied after every continuation level):
    * nonfinite   — NaN/Inf anywhere in the returned U or in F_p(U)
    * f_increase  — F_p(U_out) > F_p(U_in) beyond ``f_increase_tol``
                    (same-p comparison: F_p is re-evaluated at the
                    level's own p on the incoming iterate, so the check
                    is meaningful across the schedule)
    * rank_collapse — a QR diagonal of U below ``rank_tol`` (a column
                    went numerically dependent; Gr(k,n) left the chart)
    * stall       — ``stall_levels`` consecutive unconverged levels with
                    no functional progress
    * exception   — the driver (or its backend) raised

  ladder (``resilient_continuation``; each rung is recorded in a
  :class:`RecoveryReport` threaded into ``PSCResult.recovery``):
    1. warm_restart    — re-enter the SAME driver from the last-good U
                         with a denser p schedule (sqrt of p_factor by
                         default: half-size continuation steps)
    2. driver_switch   — walk ``driver_ladder`` (newton -> scf ->
                         inverse_power) via ``solvers.warm_start`` from
                         the last-good U
    3. backend_fallback— re-run the remaining schedule on the reference
                         ``coo`` backend (a Pallas/layout fault cannot
                         follow us there)
    4. p2_fallback     — the p=2 linear eigensolve (LOBPCG/eigh):
                         always defined, degrades gracefully to
                         classical spectral clustering

The wrapper is itself a registry entry (``solver="guarded"``) so every
registry consumer — flat pipeline, V-cycle coarse solve, serve engine
solo lane — can opt in without new plumbing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import plap
from repro.core.solvers import registry
from repro.core.solvers.registry import (SolverReport, SolverState,
                                         register_solver)
from repro.grblas.api import Descriptor
from repro.grblas.backends import BackendUnavailableError
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Thresholds of the per-level health checks and the ladder shape.

    ``PSCConfig.guard`` accepts True (defaults), a GuardConfig, or None
    (guard off unless ``solver="guarded"``)."""

    inner: Optional[str] = None       # guarded driver; None = cfg.solver
    f_increase_tol: float = 0.1       # relative F_p increase tolerated
    rank_tol: float = 1e-6            # min |QR diag| of a healthy U
    stall_levels: int = 3             # consecutive no-progress levels
    stall_tol: float = 1e-12          # relative progress below = none
    restart_p_factor: Optional[float] = None   # rung-1 densified ratio;
                                               # None = sqrt(cfg.p_factor)
    driver_ladder: tuple = ("newton", "scf", "inverse_power")
    fallback_backend: str = "coo"     # rung-3 reference backend


class SolverDivergence(RuntimeError):
    """A guarded continuation level failed a health check.  Carries the
    last state known good so recovery can resume instead of restart."""

    def __init__(self, reason: str, *, p: float, level: int,
                 last_good_U=None, last_good_p: Optional[float] = None,
                 report: Optional[SolverReport] = None, detail: str = ""):
        self.reason = reason
        self.p = float(p)
        self.level = int(level)
        self.last_good_U = last_good_U
        self.last_good_p = last_good_p
        self.report = report
        self.detail = detail
        msg = f"solver diverged at p={self.p:.4g} (level {level}): {reason}"
        if detail:
            msg += f" [{detail}]"
        super().__init__(msg)


@dataclasses.dataclass
class RungRecord:
    """One recovery attempt: which rung, with what driver/backend,
    resuming from which p, and whether it brought the solve home."""

    rung: str                   # warm_restart | driver_switch |
                                # backend_fallback | p2_fallback
    driver: str
    backend: str
    p_resume: float
    ok: bool
    detail: str = ""


@dataclasses.dataclass
class RecoveryReport:
    """What the guard saw and what it did about it — threaded into
    ``PSCResult.recovery`` so serve stats and tests can audit recovery
    without log scraping."""

    diverged_reason: Optional[str] = None
    diverged_p: Optional[float] = None
    diverged_level: Optional[int] = None
    rungs: List[RungRecord] = dataclasses.field(default_factory=list)
    recovered: bool = False
    degraded: bool = False      # True when rung 4 (p=2) produced the
                                # final embedding: labels are classical
                                # spectral, not p-spectral

    @property
    def clean(self) -> bool:
        """No divergence was ever observed (the common case)."""
        return self.recovered and self.diverged_reason is None

    @property
    def final_rung(self) -> Optional[str]:
        for rec in reversed(self.rungs):
            if rec.ok:
                return rec.rung
        return None


# ------------------------------------------------------------- health checks

def coerce_guard(guard) -> GuardConfig:
    if guard is None or guard is True:
        return GuardConfig()
    if isinstance(guard, GuardConfig):
        return guard
    raise TypeError(f"PSCConfig.guard must be None, True or a GuardConfig, "
                    f"got {type(guard).__name__}")


def _inner_name(cfg, gcfg: GuardConfig) -> str:
    if gcfg.inner is not None:
        return gcfg.inner
    return cfg.solver if cfg.solver != "guarded" else "newton"


def validate_guard(cfg) -> GuardConfig:
    """Config-time applicability of the guarded wrapper: the inner
    driver must exist and support the whole schedule; every ladder name
    must resolve (an unknown driver in the ladder is a config bug, not
    a runtime surprise)."""
    gcfg = coerce_guard(getattr(cfg, "guard", None))
    inner = registry.resolve_solver(_inner_name(cfg, gcfg))
    for p in registry.p_schedule(cfg):
        if not inner.supports_p(p):
            raise ValueError(
                f"guarded inner driver {inner.name!r} does not support "
                f"schedule value p={p} (range {inner.p_range_str()})")
    for name in gcfg.driver_ladder:
        registry.resolve_solver(name)
    if gcfg.restart_p_factor is not None \
            and not (0.0 < gcfg.restart_p_factor < 1.0):
        raise ValueError(f"restart_p_factor={gcfg.restart_p_factor} must "
                         f"lie in (0, 1)")
    if gcfg.stall_levels < 1:
        raise ValueError("stall_levels must be >= 1")
    return gcfg


def _finite(U) -> bool:
    return bool(jnp.isfinite(jnp.asarray(U)).all())


def _f_at(W, U, p: float, cfg) -> float:
    return float(plap.value(W, jnp.asarray(U), p, cfg.eps,
                            desc=cfg.descriptor()))


def check_report(f_in: float, rep: SolverReport,
                 gcfg: GuardConfig) -> Optional[str]:
    """The per-level health check.  Returns the failure reason, or None
    for a healthy report.  ``f_in`` is F_p at the level's own p on the
    INCOMING iterate (same-p comparison — F changes with p, so
    cross-level functional values are not comparable)."""
    if not math.isfinite(rep.fval) or not _finite(rep.U):
        return "nonfinite"
    if math.isfinite(f_in) \
            and rep.fval > f_in + gcfg.f_increase_tol * (abs(f_in) + 1e-12):
        return "f_increase"
    diag = jnp.abs(jnp.diagonal(jnp.linalg.qr(jnp.asarray(rep.U))[1]))
    if bool(jnp.min(diag) < gcfg.rank_tol):
        return "rank_collapse"
    return None


def checked_minimize(state: SolverState,
                     gcfg: Optional[GuardConfig] = None) -> SolverReport:
    """One guarded continuation level: run the inner driver, apply
    ``check_report``, raise :class:`SolverDivergence` on failure."""
    cfg = state.cfg
    gcfg = gcfg if gcfg is not None else coerce_guard(
        getattr(cfg, "guard", None))
    inner = registry.resolve_solver(_inner_name(cfg, gcfg))
    p = float(state.p)
    try:
        f_in = _f_at(state.W, state.U, p, cfg)
        rep = inner.minimize_at_p(state)
    except (KeyboardInterrupt, SystemExit):
        raise
    except SolverDivergence:
        raise
    except Exception as exc:                       # noqa: BLE001 — wrapped
        raise SolverDivergence(
            "exception", p=p, level=0, last_good_U=state.U,
            detail=f"{type(exc).__name__}: {exc}") from exc
    reason = check_report(f_in, rep, gcfg)
    if reason is not None:
        raise SolverDivergence(reason, p=p, level=0, last_good_U=state.U,
                               report=rep)
    return rep


@register_solver("guarded", p_min=1.0, p_max=2.0, p_min_open=False,
                 description="health-checked wrapper around any driver "
                             "(GuardConfig.inner); raises SolverDivergence "
                             "instead of returning NaN/garbage")
def guarded_minimize_at_p(state: SolverState) -> SolverReport:
    return checked_minimize(state)


# ------------------------------------------------------------- continuation

class _Records:
    """The (p_path, fvals, applies, reports) accumulator of the pipeline
    contract, mergeable across rungs."""

    def __init__(self):
        self.p_path: List[float] = []
        self.fvals: List[float] = []
        self.applies: List[int] = []
        self.reports: List[SolverReport] = []

    def append(self, p: float, rep: SolverReport):
        self.p_path.append(float(p))
        self.fvals.append(float(rep.fval))
        self.applies.append(int(rep.n_apply))
        self.reports.append(rep)

    def merge(self, other: "_Records"):
        self.p_path += other.p_path
        self.fvals += other.fvals
        self.applies += other.applies
        self.reports += other.reports

    def tuple(self, U):
        return U, self.p_path, self.fvals, self.applies, self.reports


def _run_levels(W, U0, ps, cfg, gcfg: GuardConfig, out: _Records):
    """Run schedule ``ps`` under ``cfg.solver`` with the per-level guard
    + stall tracking.  Appends healthy levels to ``out`` and returns the
    final U; raises SolverDivergence carrying the last-good state."""
    solver = registry.resolve_solver(cfg.solver)
    U = jnp.asarray(U0)
    last_good_p: Optional[float] = None
    stall = 0
    for i, p in enumerate(ps):
        p = float(p)
        try:
            with _obs_trace.ACTIVE.span("solver.level", cat="solver",
                                        solver=solver.name, p=p,
                                        guarded=True) as sp:
                f_in = _f_at(W, U, p, cfg)
                rep = solver.minimize_at_p(
                    SolverState(W=W, U=U, p=p, cfg=cfg))
                sp.fence(rep.U)
                sp.set(fval=float(rep.fval), n_apply=int(rep.n_apply),
                       iters=int(rep.iters), converged=bool(rep.converged))
        except (KeyboardInterrupt, SystemExit):
            raise
        except SolverDivergence as exc:
            raise SolverDivergence(
                exc.reason, p=p, level=i, last_good_U=U,
                last_good_p=last_good_p, report=exc.report,
                detail=exc.detail) from exc
        except Exception as exc:                   # noqa: BLE001 — wrapped
            raise SolverDivergence(
                "exception", p=p, level=i, last_good_U=U,
                last_good_p=last_good_p,
                detail=f"{type(exc).__name__}: {exc}") from exc
        reason = check_report(f_in, rep, gcfg)
        if reason is not None:
            raise SolverDivergence(reason, p=p, level=i, last_good_U=U,
                                   last_good_p=last_good_p, report=rep)
        no_progress = (not rep.converged
                       and f_in - rep.fval
                       <= gcfg.stall_tol * (abs(f_in) + 1e-12))
        stall = stall + 1 if no_progress else 0
        U = rep.U
        out.append(p, rep)
        last_good_p = p
        if stall >= gcfg.stall_levels:
            raise SolverDivergence("stall", p=p, level=i, last_good_U=U,
                                   last_good_p=last_good_p, report=rep)
    return U


def _densified_schedule(p_from: float, p_target: float,
                        factor: float) -> List[float]:
    """A geometric schedule from ``p_from`` down to ``p_target`` with
    ratio ``factor`` — rung 1's smaller continuation steps."""
    ps, p = [], p_from
    while True:
        p = max(p_target, p * factor)
        ps.append(p)
        if p <= p_target:
            return ps


def _qr(U) -> jnp.ndarray:
    return jnp.linalg.qr(jnp.asarray(U))[0]


def _emit_rung(rec: RungRecord) -> None:
    """One recovery-rung firing = exactly one counter increment + one
    trace instant, stamped with the active injection id so chaos-suite
    timelines correlate the fault with the recovery it triggered
    (tests/test_obs.py pins the exactly-once contract)."""
    _obs_metrics.DEFAULT.counter("recovery_rungs_total", rung=rec.rung).inc()
    _obs_trace.ACTIVE.instant(
        "recovery.rung", rung=rec.rung, driver=rec.driver,
        backend=rec.backend, ok=rec.ok, p_resume=rec.p_resume,
        injection_id=_obs_trace.current_injection())


def _emit_divergence(recovery: RecoveryReport) -> None:
    _obs_metrics.DEFAULT.counter(
        "solver_divergence_total",
        reason=str(recovery.diverged_reason)).inc()
    _obs_trace.ACTIVE.instant(
        "solver.divergence", reason=recovery.diverged_reason,
        p=recovery.diverged_p, level=recovery.diverged_level,
        injection_id=_obs_trace.current_injection())


def _ladder(W, U_lg, p_from: float, remaining: List[float], cfg,
            gcfg: GuardConfig, out: _Records, recovery: RecoveryReport):
    """Walk the recovery rungs from the last-good embedding ``U_lg``.
    ``remaining`` is the part of the schedule the primary run never
    finished (possibly the whole schedule).  On success the winning
    rung's records are merged into ``out`` and the final U returned;
    if every rung fails, raises SolverDivergence("unrecoverable")."""
    inner = _inner_name(cfg, gcfg)
    U_lg = _qr(U_lg)
    if not remaining:
        remaining = [float(cfg.p_target)]
    p_target = float(remaining[-1])

    def attempt(rung: str, driver: str, backend: str, fn):
        rec = RungRecord(rung=rung, driver=driver, backend=backend,
                         p_resume=p_from, ok=False)
        try:
            with _obs_trace.ACTIVE.span(f"recovery.{rung}", cat="recovery",
                                        driver=driver, backend=backend,
                                        p_resume=p_from):
                U, recs = fn()
            if not _finite(U):
                raise SolverDivergence("nonfinite", p=p_target, level=0,
                                       last_good_U=U_lg)
            rec.ok = True
            recovery.rungs.append(rec)
            _emit_rung(rec)
            out.merge(recs)
            return U
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as exc:                   # noqa: BLE001 — recorded
            rec.detail = f"{type(exc).__name__}: {exc}"
            recovery.rungs.append(rec)
            _emit_rung(rec)
            return None

    # -- rung 1: same driver, warm restart on a densified schedule
    def rung_warm_restart():
        factor = (gcfg.restart_p_factor if gcfg.restart_p_factor is not None
                  else round(math.sqrt(cfg.p_factor), 6))
        sched = _densified_schedule(p_from, p_target, factor)
        base = dataclasses.replace(cfg, solver=inner, p_factor=factor,
                                   init_U=None, multilevel=None)
        recs = _Records()
        U = _run_levels(W, U_lg, sched, base, gcfg, recs)
        return U, recs

    U = attempt("warm_restart", inner, cfg.backend, rung_warm_restart)
    if U is not None:
        recovery.recovered = True
        return U

    # -- rung 2: switch driver, warm-started at the remaining tail
    for cand in gcfg.driver_ladder:
        if cand == inner:
            continue
        solver = registry.resolve_solver(cand)
        if not all(solver.supports_p(float(p)) for p in remaining):
            continue

        def rung_switch(cand=cand):
            base = dataclasses.replace(cfg, solver=cand, init_U=None,
                                       multilevel=None)
            recs = _Records()
            U = _run_levels(W, U_lg, remaining, base, gcfg, recs)
            return U, recs

        U = attempt("driver_switch", cand, cfg.backend, rung_switch)
        if U is not None:
            recovery.recovered = True
            return U

    # -- rung 3: reference backend (a kernel/layout fault cannot follow)
    if cfg.backend != gcfg.fallback_backend:
        def rung_backend():
            base = dataclasses.replace(cfg, solver=inner,
                                       backend=gcfg.fallback_backend,
                                       interpret=False, init_U=None,
                                       multilevel=None)
            recs = _Records()
            U = _run_levels(W, U_lg, remaining, base, gcfg, recs)
            return U, recs

        U = attempt("backend_fallback", inner, gcfg.fallback_backend,
                    rung_backend)
        if U is not None:
            recovery.recovered = True
            return U

    # -- rung 4: the p=2 linear solve — classical spectral clustering,
    # always defined; degraded but finite
    def rung_p2():
        from repro.core import lobpcg

        desc = Descriptor(backend=gcfg.fallback_backend)
        _, U2 = lobpcg.smallest_eigvecs(W, cfg.k,
                                        normalized=cfg.normalized_init,
                                        seed=cfg.seed, desc=desc)
        U2 = _qr(U2)
        recs = _Records()
        recs.append(2.0, SolverReport(U=U2, fval=_f_at(W, U2, 2.0, cfg),
                                      n_apply=0, iters=0, converged=False))
        return U2, recs

    U = attempt("p2_fallback", "lobpcg", gcfg.fallback_backend, rung_p2)
    if U is not None:
        recovery.recovered = True
        recovery.degraded = True
        return U

    raise SolverDivergence(
        "unrecoverable", p=p_target, level=0, last_good_U=U_lg,
        detail="every recovery rung failed — the graph itself is likely "
               "corrupt (run graphs.validate.validate_graph) or every "
               "backend is down")


def resilient_continuation(W, U0, cfg):
    """The guarded replacement of ``solvers.p_continuation``: run the
    full schedule under the inner driver; on :class:`SolverDivergence`
    walk the recovery ladder from the last-good state.

    Returns (U, p_path, fvals, applies, reports, recovery) — the
    pipeline 5-tuple plus the :class:`RecoveryReport`."""
    gcfg = coerce_guard(getattr(cfg, "guard", None))
    inner = _inner_name(cfg, gcfg)
    base = dataclasses.replace(cfg, solver=inner, init_U=None,
                               multilevel=None)
    full = [float(p) for p in registry.p_schedule(cfg)]
    out = _Records()
    recovery = RecoveryReport()
    try:
        U = _run_levels(W, U0, full, base, gcfg, out)
        recovery.recovered = True
        return (*out.tuple(U), recovery)
    except SolverDivergence as exc:
        recovery.diverged_reason = exc.reason
        recovery.diverged_p = exc.p
        recovery.diverged_level = exc.level
        _emit_divergence(recovery)
        U_lg = exc.last_good_U if exc.last_good_U is not None else U0
        p_from = exc.last_good_p if exc.last_good_p is not None else 2.0
        remaining = full[len(out.p_path):]
    U = _ladder(W, U_lg, p_from, remaining, cfg, gcfg, out, recovery)
    return (*out.tuple(U), recovery)


def resilient_warm_start(W, U0, cfg):
    """The guarded replacement of ``solvers.warm_start`` (the serve
    engine's repeat-tenant path): run the schedule tail from ``U0``; a
    poisoned warm start (cached NaN, divergence at the tail) falls onto
    the same ladder, ultimately re-deriving the embedding from scratch
    rather than failing the request."""
    gcfg = coerce_guard(getattr(cfg, "guard", None))
    inner = _inner_name(cfg, gcfg)
    base = dataclasses.replace(cfg, solver=inner, init_U=None,
                               multilevel=None)
    full = [float(p) for p in registry.p_schedule(cfg)]
    tail = full[-max(int(cfg.warm_p_steps), 1):]
    out = _Records()
    recovery = RecoveryReport()
    U_start = jnp.asarray(U0)
    try:
        if not _finite(U_start):
            raise SolverDivergence("nonfinite", p=tail[0], level=0,
                                   last_good_U=None,
                                   detail="warm-start embedding is not "
                                          "finite (poisoned cache entry?)")
        U = _run_levels(W, U_start, tail, base, gcfg, out)
        recovery.recovered = True
        return (*out.tuple(U), recovery)
    except SolverDivergence as exc:
        recovery.diverged_reason = exc.reason
        recovery.diverged_p = exc.p
        recovery.diverged_level = exc.level
        _emit_divergence(recovery)
        if exc.last_good_U is not None:
            U_lg, p_from = exc.last_good_U, \
                (exc.last_good_p if exc.last_good_p is not None else 2.0)
        else:
            # the warm start itself was poisoned: restart from a fresh
            # p=2 eigensolve (rung 1 then walks the FULL schedule)
            from repro.core import lobpcg

            _, U_lg = lobpcg.smallest_eigvecs(
                W, cfg.k, normalized=cfg.normalized_init, seed=cfg.seed,
                desc=Descriptor(backend=gcfg.fallback_backend))
            p_from = 2.0
        remaining = tail[len(out.p_path):]
    U = _ladder(W, U_lg, p_from, remaining, cfg, gcfg, out, recovery)
    return (*out.tuple(U), recovery)
