"""The p-Laplacian functional F_p, its Euclidean gradient and Hessian apply.

For one eigenvector column u with graph weights W (symmetric):

    A(u) = 1/2 sum_ij w_ij s(u_i - u_j)       s(x) = (x^2+eps)^{p/2}
    B(u) = sum_i s(u_i)                        (= ||u||_p^p, smoothed)
    F(u) = A(u) / B(u)          F_p(U) = sum_l F(u^l)

Closed forms (derived; pinned to jax autodiff in tests/test_plap.py):

    grad A   = p * Delta_p u              (Delta_p u)_i = sum_j w_ij phi(u_i-u_j)
    grad B   = p * phi(u)
    grad F   = (p/B) [Delta_p u - F * phi(u)]

    Hess A   = p [diag(W-hat 1) - W-hat]   w-hat_ij = w_ij phi'(u_i-u_j)
    Hess B   = p diag(phi'(u))
    Hess F @ eta = (1/B) Hess A eta - (F/B) Hess B eta
                   - (1/B^2)[gA (gB.eta) + gB (gA.eta)] + (2F/B^2) gB (gB.eta)

Every SpMM-shaped reduction routes through the unified GraphBLAS API
(grblas.api.mxm) under a Descriptor — backend="auto" serves the Newton
hot loop from the fused Pallas kernels when the BSR layout is built (on
TPU), the SELL-C-σ sliced gather path when that layout is built (the
skewed-degree scaling regime, DESIGN.md §5), and the COO/ELL gather
paths otherwise; there are no raw jax.ops.segment_sum calls left in the
hot path.

Two HVP implementations:
  * hess_eta_graphblas  — Algorithm-1-faithful: materialize D[l] and the
    off-diagonal W-hat[l] (multivalues on W's fixed pattern, via
    W.with_vals), then mxm + eWiseApply per column (the paper's Alg. 1),
    plus the rank-one quotient corrections as dot/axpy vector ops.
  * hess_eta_matrix_free — TPU-adapted: one fused SpMM under the
    pair-edge-semiring, no W-hat materialization (DESIGN.md §2,
    adaptation 4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas import api
from repro.grblas import ops as grb
from repro.grblas.api import Descriptor
from repro.grblas.semiring import (plap_edge_semiring,
                                   plap_hvp_edge_semiring, reals_ring)
from repro.core import phi as PHI

_AUTO = Descriptor()


class PLapParts(NamedTuple):
    A: jnp.ndarray      # (k,) numerators
    B: jnp.ndarray      # (k,) denominators
    F: jnp.ndarray      # (k,) Rayleigh quotients
    dpu: jnp.ndarray    # (n,k) Delta_p u per column
    phi_u: jnp.ndarray  # (n,k)


def _edge_diffs(W: SparseMatrix, U: jnp.ndarray) -> jnp.ndarray:
    """d_e = u_i - u_j per nnz edge (directed; W stores both (i,j),(j,i))."""
    return U[W.rows] - U[W.cols]


def parts(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float,
          desc: Optional[Descriptor] = None) -> PLapParts:
    """All shared quantities for value/grad: one edge pass for the scalar
    energies + one edge-semiring SpMM for Delta_p u (the kernel-served op)."""
    d = _edge_diffs(W, U)                                    # (nnz, k)
    w = W.vals[:, None]
    A = 0.5 * jnp.sum(w * PHI.p_power(d, p, eps), axis=0)    # (k,)
    B = jnp.sum(PHI.p_power(U, p, eps), axis=0)              # (k,)
    dpu = api.mxm(W, U, plap_edge_semiring(p, eps), desc=desc or _AUTO)
    return PLapParts(A=A, B=B, F=A / B, dpu=dpu, phi_u=PHI.phi(U, p, eps))


def value(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float = 1e-9,
          desc: Optional[Descriptor] = None) -> jnp.ndarray:
    pr = parts(W, U, p, eps, desc)
    return jnp.sum(pr.F)


def euc_grad(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float = 1e-9,
             desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """EucGrad: (p/B)[Delta_p u - F phi(u)] columnwise. (n,k)."""
    pr = parts(W, U, p, eps, desc)
    return (p / pr.B) * (pr.dpu - pr.F * pr.phi_u)


def value_and_grad(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float = 1e-9,
                   desc: Optional[Descriptor] = None):
    pr = parts(W, U, p, eps, desc)
    g = (p / pr.B) * (pr.dpu - pr.F * pr.phi_u)
    return jnp.sum(pr.F), g


# ---------------------------------------------------------------- HVP paths

def hessian_weights(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float):
    """w-hat_e = w_e phi'(u_i - u_j) per edge and column. (nnz,k)."""
    d = _edge_diffs(W, U)
    return W.vals[:, None] * PHI.phi_prime(d, p, eps)


def build_alg1_operands(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float,
                        desc: Optional[Descriptor] = None):
    """The paper's Algorithm-1 inputs: per column l,
       D[l] = diag(Hess A^l) / p   (vector)  and
       H[l] = off-diagonal W-hat^l (multivalues on W's pattern).
    Returned stacked over columns: D (n,k), What_vals (nnz,k).
    D is the W-hat row sums — mxv with the ones multivector."""
    what = hessian_weights(W, U, p, eps)                     # (nnz,k)
    Wh = W.with_vals(what)
    D = api.mxm(Wh, jnp.ones_like(U), reals_ring,
                desc=_multival_desc(Wh, U, desc))
    return D, what


def _multival_desc(Wh: SparseMatrix, U, desc: Optional[Descriptor]):
    """The caller's descriptor for the materialized-multivalue SpMMs —
    degraded to auto when the named backend can't execute (nnz, k)
    multivalues (e.g. edge_pallas, which is hot-loop-only), so a pinned
    "coo"/"sellcs" really does control the whole Alg-1 HVP."""
    return api.capable_desc(Wh, reals_ring, desc, k=U.shape[-1],
                            dtype=U.dtype)


def hess_eta_graphblas(W: SparseMatrix, U: jnp.ndarray, eta: jnp.ndarray,
                       p: float, eps: float = 1e-9,
                       operands=None,
                       desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """Algorithm-1-faithful HVP (materialized W-hat), full quotient rule.

    Per column l (all fused):
      1. v  = mxm(What[l], eta, reals_ring)        [Alg.1 line 7]
      2. w  = eWiseApply(eta, D[l], mul)           [Alg.1 line 8]
      3. hA = p * (w - v)                          [Alg.1 line 9 + scale]
    then the rank-one quotient corrections (vector dots / axpys).
    The materialized multivalues run the COO backend — or the SELL-C-σ
    layout when built: with_vals re-scatters the packed slice values
    on-device, so Alg-1's W-hat SpMM stays on the sliced layout too.
    ``desc`` steers ``parts`` and, when its backend can execute
    multivalues (coo / sellcs), the W-hat SpMMs as well; hot-loop-only
    backends (edge_pallas) degrade those two ops to "auto".
    """
    pr = parts(W, U, p, eps, desc)
    if operands is None:
        operands = build_alg1_operands(W, U, p, eps, desc)
    D, what_vals = operands

    # lines 6-9 of Algorithm 1, k columns fused through one SpMM:
    Wh = W.with_vals(what_vals)
    v = api.mxm(Wh, eta, reals_ring, desc=_multival_desc(Wh, eta, desc))
    w = grb.e_wise_apply(eta, D, jnp.multiply)
    hA_eta = p * grb.e_wise_apply(w, v, jnp.subtract)        # Hess A @ eta

    return _quotient_correct(pr, U, eta, hA_eta, p, eps)


def hess_eta_matrix_free(W: SparseMatrix, U: jnp.ndarray, eta: jnp.ndarray,
                         p: float, eps: float = 1e-9,
                         desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """TPU-adapted HVP: one fused pair-edge-semiring SpMM, nothing
    materialized.  Hess A @ eta per column
        = p * sum_j w-hat_ij (eta_i - eta_j)
    with w-hat computed per edge inside the ring (Pallas kernel when the
    BSR layout is built on TPU; COO segment path otherwise)."""
    pr = parts(W, U, p, eps, desc)
    hA_eta = p * api.mxm(W, (U, eta), plap_hvp_edge_semiring(p, eps),
                         desc=desc or _AUTO)
    return _quotient_correct(pr, U, eta, hA_eta, p, eps)


def _quotient_correct(pr: PLapParts, U, eta, hA_eta, p, eps):
    """Assemble Hess F @ eta from Hess A @ eta + quotient-rule terms."""
    gA = p * pr.dpu                                   # grad A (n,k)
    gB = p * pr.phi_u                                 # grad B (n,k)
    hB_eta = p * PHI.phi_prime(U, p, eps) * eta       # Hess B diag apply
    gB_eta = jnp.sum(gB * eta, axis=0)                # (k,)
    gA_eta = jnp.sum(gA * eta, axis=0)
    B, F = pr.B, pr.F
    return (hA_eta / B
            - (F / B) * hB_eta
            - (gA * gB_eta + gB * gA_eta) / (B * B)
            + (2.0 * F / (B * B)) * gB * gB_eta)


# ------------------------------------------------------------- autodiff oracle

def autodiff_value(W: SparseMatrix, p: float, eps: float):
    """F_p as a closure for jax.grad / jvp-of-grad oracles in tests."""
    def f(U):
        d = U[W.rows] - U[W.cols]
        A = 0.5 * jnp.sum(W.vals[:, None] * PHI.p_power(d, p, eps), axis=0)
        B = jnp.sum(PHI.p_power(U, p, eps), axis=0)
        return jnp.sum(A / B)
    return f


def autodiff_hvp(W: SparseMatrix, U, eta, p: float, eps: float = 1e-9):
    import jax
    f = autodiff_value(W, p, eps)
    return jax.jvp(jax.grad(f), (U,), (eta,))[1]
