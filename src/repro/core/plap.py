"""The p-Laplacian functional F_p, its Euclidean gradient and Hessian apply.

For one eigenvector column u with graph weights W (symmetric):

    A(u) = 1/2 sum_ij w_ij s(u_i - u_j)       s(x) = (x^2+eps)^{p/2}
    B(u) = sum_i s(u_i)                        (= ||u||_p^p, smoothed)
    F(u) = A(u) / B(u)          F_p(U) = sum_l F(u^l)

Closed forms (derived; pinned to jax autodiff in tests/test_plap.py):

    grad A   = p * Delta_p u              (Delta_p u)_i = sum_j w_ij phi(u_i-u_j)
    grad B   = p * phi(u)
    grad F   = (p/B) [Delta_p u - F * phi(u)]

    Hess A   = p [diag(W-hat 1) - W-hat]   w-hat_ij = w_ij phi'(u_i-u_j)
    Hess B   = p diag(phi'(u))
    Hess F @ eta = (1/B) Hess A eta - (F/B) Hess B eta
                   - (1/B^2)[gA (gB.eta) + gB (gA.eta)] + (2F/B^2) gB (gB.eta)

Two HVP implementations:
  * hess_eta_graphblas  — Algorithm-1-faithful: materialize D[l] and the
    off-diagonal W-hat[l] (new vals on the fixed sparsity), then
    vxm + eWiseApply per column (the paper's Alg. 1), plus the rank-one
    quotient corrections as dot/axpy vector ops.
  * hess_eta_matrix_free — TPU-adapted: one fused edge-semiring SpMM, no
    W-hat materialization (DESIGN.md §2, adaptation 4).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas import ops as grb
from repro.grblas.semiring import reals_ring
from repro.core import phi as PHI


class PLapParts(NamedTuple):
    A: jnp.ndarray      # (k,) numerators
    B: jnp.ndarray      # (k,) denominators
    F: jnp.ndarray      # (k,) Rayleigh quotients
    dpu: jnp.ndarray    # (n,k) Delta_p u per column
    phi_u: jnp.ndarray  # (n,k)


def _edge_diffs(W: SparseMatrix, U: jnp.ndarray) -> jnp.ndarray:
    """d_e = u_i - u_j per nnz edge (directed; W stores both (i,j),(j,i))."""
    return U[W.rows] - U[W.cols]


def parts(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float) -> PLapParts:
    """All shared quantities for value/grad in one edge pass."""
    d = _edge_diffs(W, U)                                    # (nnz, k)
    w = W.vals[:, None]
    A = 0.5 * jnp.sum(w * PHI.p_power(d, p, eps), axis=0)    # (k,)
    B = jnp.sum(PHI.p_power(U, p, eps), axis=0)              # (k,)
    contrib = w * PHI.phi(d, p, eps)
    dpu = jax.ops.segment_sum(contrib, W.rows, W.n_rows)     # (n,k)
    return PLapParts(A=A, B=B, F=A / B, dpu=dpu, phi_u=PHI.phi(U, p, eps))


def value(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float = 1e-9) -> jnp.ndarray:
    pr = parts(W, U, p, eps)
    return jnp.sum(pr.F)


def euc_grad(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float = 1e-9) -> jnp.ndarray:
    """EucGrad: (p/B)[Delta_p u - F phi(u)] columnwise. (n,k)."""
    pr = parts(W, U, p, eps)
    return (p / pr.B) * (pr.dpu - pr.F * pr.phi_u)


def value_and_grad(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float = 1e-9):
    pr = parts(W, U, p, eps)
    g = (p / pr.B) * (pr.dpu - pr.F * pr.phi_u)
    return jnp.sum(pr.F), g


# ---------------------------------------------------------------- HVP paths

def hessian_weights(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float):
    """w-hat_e = w_e phi'(u_i - u_j) per edge and column. (nnz,k)."""
    d = _edge_diffs(W, U)
    return W.vals[:, None] * PHI.phi_prime(d, p, eps)


def build_alg1_operands(W: SparseMatrix, U: jnp.ndarray, p: float, eps: float):
    """The paper's Algorithm-1 inputs: per column l,
       D[l] = diag(Hess A^l) / p   (vector)  and
       H[l] = off-diagonal W-hat^l (SparseMatrix vals on W's pattern).
    Returned stacked over columns: D (n,k), What_vals (nnz,k)."""
    what = hessian_weights(W, U, p, eps)                     # (nnz,k)
    D = jax.ops.segment_sum(what, W.rows, W.n_rows)          # (n,k) row sums
    return D, what


def hess_eta_graphblas(W: SparseMatrix, U: jnp.ndarray, eta: jnp.ndarray,
                       p: float, eps: float = 1e-9,
                       operands=None) -> jnp.ndarray:
    """Algorithm-1-faithful HVP (materialized W-hat), full quotient rule.

    Per column l (all fused):
      1. v  = vxm(eta, What[l], reals_ring)        [Alg.1 line 7]
      2. w  = eWiseApply(eta, D[l], mul)           [Alg.1 line 8]
      3. hA = p * (w - v)                          [Alg.1 line 9 + scale]
    then the rank-one quotient corrections (vector dots / axpys).
    """
    pr = parts(W, U, p, eps)
    if operands is None:
        operands = build_alg1_operands(W, U, p, eps)
    D, what_vals = operands

    # lines 6-9 of Algorithm 1, k columns fused through one SpMM:
    v = jax.ops.segment_sum(what_vals * eta[W.cols], W.rows, W.n_rows)
    w = grb.e_wise_apply(eta, D, jnp.multiply)
    hA_eta = p * grb.e_wise_apply(w, v, jnp.subtract)        # Hess A @ eta

    return _quotient_correct(pr, U, eta, hA_eta, p, eps)


def hess_eta_matrix_free(W: SparseMatrix, U: jnp.ndarray, eta: jnp.ndarray,
                         p: float, eps: float = 1e-9) -> jnp.ndarray:
    """TPU-adapted HVP: fused edge pass, nothing materialized.

    Hess A @ eta per column = p * sum_j w-hat_ij (eta_i - eta_j)."""
    pr = parts(W, U, p, eps)
    d = _edge_diffs(W, U)
    what = W.vals[:, None] * PHI.phi_prime(d, p, eps)
    de = eta[W.rows] - eta[W.cols]
    hA_eta = p * jax.ops.segment_sum(what * de, W.rows, W.n_rows)
    return _quotient_correct(pr, U, eta, hA_eta, p, eps)


def _quotient_correct(pr: PLapParts, U, eta, hA_eta, p, eps):
    """Assemble Hess F @ eta from Hess A @ eta + quotient-rule terms."""
    gA = p * pr.dpu                                   # grad A (n,k)
    gB = p * pr.phi_u                                 # grad B (n,k)
    hB_eta = p * PHI.phi_prime(U, p, eps) * eta       # Hess B diag apply
    gB_eta = jnp.sum(gB * eta, axis=0)                # (k,)
    gA_eta = jnp.sum(gA * eta, axis=0)
    B, F = pr.B, pr.F
    return (hA_eta / B
            - (F / B) * hB_eta
            - (gA * gB_eta + gB * gA_eta) / (B * B)
            + (2.0 * F / (B * B)) * gB * gB_eta)


# ------------------------------------------------------------- autodiff oracle

def autodiff_value(W: SparseMatrix, p: float, eps: float):
    """F_p as a closure for jax.grad / jvp-of-grad oracles in tests."""
    def f(U):
        d = U[W.rows] - U[W.cols]
        A = 0.5 * jnp.sum(W.vals[:, None] * PHI.p_power(d, p, eps), axis=0)
        B = jnp.sum(PHI.p_power(U, p, eps), axis=0)
        return jnp.sum(A / B)
    return f


def autodiff_hvp(W: SparseMatrix, U, eta, p: float, eps: float = 1e-9):
    f = autodiff_value(W, p, eps)
    return jax.jvp(jax.grad(f), (U,), (eta,))[1]
