"""k-means discretization of the spectral coordinates, GraphBLAS style.

The distance computation is one dense matmul (MXU-bound on TPU):
  d(x, c) = ||x||^2 + ||c||^2 - 2 x.c
and the assignment an argmin reduce — exactly the shape the paper folds
into its GraphBLAS pipeline.  The fused Pallas kernel lives in
kernels/kmeans_assign; this module is the jnp implementation + Lloyd loop.

kmeans++ seeding, fixed-iteration Lloyd with empty-cluster re-seeding,
multiple restarts keeping the best inertia.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def pairwise_sqdist(X: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """(n,k_cent) squared distances via the matmul identity."""
    xx = jnp.sum(X * X, axis=1, keepdims=True)
    cc = jnp.sum(C * C, axis=1)[None, :]
    return jnp.maximum(xx + cc - 2.0 * (X @ C.T), 0.0)


def assign(X: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmin(pairwise_sqdist(X, C), axis=1)


def _plusplus_init(key, X: jnp.ndarray, k: int) -> jnp.ndarray:
    """kmeans++ seeding (sequential, k small)."""
    n = X.shape[0]
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n)
    C0 = jnp.tile(X[first], (k, 1))

    def body(i, carry):
        C, key = carry
        d2 = pairwise_sqdist(X, C)                        # (n,k)
        # distance to nearest chosen centroid (first i valid)
        mask = jnp.arange(k)[None, :] < i
        dmin = jnp.min(jnp.where(mask, d2, jnp.inf), axis=1)
        key, sub = jax.random.split(key)
        probs = dmin / jnp.maximum(jnp.sum(dmin), 1e-30)
        nxt = jax.random.choice(sub, n, p=probs)
        return C.at[i].set(X[nxt]), key

    C, _ = jax.lax.fori_loop(1, k, body, (C0, key))
    return C


def lloyd(X: jnp.ndarray, C0: jnp.ndarray, iters: int = 50) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fixed-iteration Lloyd; empty clusters re-seeded to farthest points."""
    k = C0.shape[0]

    def body(C, _):
        d2 = pairwise_sqdist(X, C)
        a = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(a, k, dtype=X.dtype)      # (n,k)
        counts = jnp.sum(onehot, axis=0)                  # (k,)
        sums = onehot.T @ X                               # (k,d)
        newC = sums / jnp.maximum(counts[:, None], 1.0)
        # re-seed empties at the globally farthest point
        far = X[jnp.argmax(jnp.min(d2, axis=1))]
        newC = jnp.where(counts[:, None] > 0, newC, far[None, :])
        return newC, None

    C, _ = jax.lax.scan(body, C0, None, length=iters)
    a = assign(X, C)
    inertia = jnp.sum(jnp.min(pairwise_sqdist(X, C), axis=1))
    return a, C, inertia


@partial(jax.jit, static_argnames=("k", "restarts", "iters"))
def kmeans(key, X: jnp.ndarray, k: int, restarts: int = 8,
           iters: int = 50) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-restart kmeans++: returns (labels (n,), centroids (k,d))."""
    keys = jax.random.split(key, restarts)

    def one(key):
        C0 = _plusplus_init(key, X, k)
        return lloyd(X, C0, iters)

    labels, Cs, inertias = jax.vmap(one)(keys)
    best = jnp.argmin(inertias)
    return labels[best], Cs[best]
