"""phi_p and smoothed p-powers — the scalar nonlinearity of the p-Laplacian.

For p<2, |x|^p is not C^2 at 0; Newton needs the eps-smoothed surrogate
   s_eps(x) = (x^2 + eps)^{p/2}
whose derivative is phi_eps(x) = p (x^2+eps)^{(p-2)/2} x, matching the
smoothing used in Pasadakis et al. 2022 [4].  eps=0 recovers the exact
p-power (used for function values / metrics; derivatives use eps>0).
"""
from __future__ import annotations

import jax.numpy as jnp


def p_power(x, p: float, eps: float = 0.0):
    """|x|^p (eps-smoothed: (x^2+eps)^{p/2})."""
    if eps == 0.0:
        return jnp.abs(x) ** p
    return (x * x + eps) ** (p / 2.0)


def phi(x, p: float, eps: float = 0.0):
    """d/dx of p_power / p: phi_p(x) = |x|^{p-1} sign(x) (smoothed)."""
    if eps == 0.0:
        return jnp.abs(x) ** (p - 1.0) * jnp.sign(x)
    return (x * x + eps) ** ((p - 2.0) / 2.0) * x


def phi_prime(x, p: float, eps: float = 0.0):
    """d/dx phi_p(x) = (p-1)|x|^{p-2} (smoothed: keeps >=0 for p>1)."""
    if eps == 0.0:
        return (p - 1.0) * jnp.abs(x) ** (p - 2.0)
    x2e = x * x + eps
    return x2e ** ((p - 2.0) / 2.0) + (p - 2.0) * x * x * x2e ** ((p - 4.0) / 2.0)


def p_norm_p(u, p: float, eps: float = 0.0, axis=0):
    """||u||_p^p along axis (smoothed)."""
    return jnp.sum(p_power(u, p, eps), axis=axis)
