"""repro.core — the paper's contribution: p-spectral clustering on the
Grassmann manifold, with GraphBLAS-style algebra underneath and a
registry of interchangeable solver drivers (core.solvers) on top."""
from repro.core.psc import PSCConfig, PSCResult, p_spectral_cluster, spectral_cluster
from repro.core import plap, metrics, kmeans, lobpcg, grassmann, phi, solvers

__all__ = [
    "PSCConfig", "PSCResult", "p_spectral_cluster", "spectral_cluster",
    "plap", "metrics", "kmeans", "lobpcg", "grassmann", "phi",
    "solvers",
]
