import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
and extract memory/cost/roofline artifacts.

THE two lines above must run before any other import (jax locks the
device count on first init).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, collective breakdown and roofline terms.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, input_specs, cell_status
from repro.launch import hlo_analysis as HA
from repro.models import model as M
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.train.loop import TrainConfig, make_train_step, make_optimizer
from repro.train.optimizer import AdamState, AdafactorState, FactoredMoment
from repro.dist.sharding import factored_moment_specs, resolve_spec


# ----------------------------------------------------------- shardings

def batch_shardings(cfg, mesh, specs):
    def tok(sd):
        ndim = len(sd.shape)
        return NamedSharding(mesh, resolve_spec(
            sd.shape, ("batch",) + (None,) * (ndim - 1), mesh))
    return jax.tree.map(tok, specs)


def opt_state_shardings(opt_name, cfg, mesh):
    """Optimizer-state shardings mirroring the param PartitionSpecs.

    Adafactor's factored moments are re-resolved from the *abstract*
    params' (shape, logical) through dist.sharding.factored_moment_specs
    — not sliced out of the param specs, which under-shards (see its
    docstring; unit-tested in tests/test_dist_sharding.py)."""
    ab = M.abstract_params(cfg)
    ns = lambda spec: NamedSharding(mesh, spec)
    rep = NamedSharding(mesh, P())
    if opt_name == "adamw":
        t = jax.tree.map(ns, L.pspec_tree(ab, mesh))
        return AdamState(mu=t, nu=t, count=rep)

    def fact(a):
        if len(a.shape) >= 2:
            row, col = factored_moment_specs(a.shape, a.logical, mesh)
            return FactoredMoment(row=ns(row), col=ns(col))
        return ns(resolve_spec(a.shape, a.logical, mesh))
    moments = jax.tree.map(fact, ab, is_leaf=L.is_pab)
    return AdafactorState(moments=moments, count=rep)


def opt_state_shapes(opt, cfg):
    return jax.eval_shape(opt.init, M.param_shapes(cfg))


def cache_shardings(cfg, mesh, batch, max_len, dtype=jnp.bfloat16):
    logical = M.cache_logical(cfg)
    abstract = M.cache_abstract(cfg, batch, max_len, dtype)
    is_ls = lambda v: isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v)
    flat_ab, treedef = jax.tree.flatten(abstract)
    flat_ls = treedef.flatten_up_to(logical)
    assert all(is_ls(v) for v in flat_ls)
    out = [NamedSharding(mesh, resolve_spec(ab.shape, ls, mesh))
           for ab, ls in zip(flat_ab, flat_ls)]
    return jax.tree.unflatten(treedef, out)


def pick_optimizer_name(cfg: ArchConfig) -> str:
    # fp32 Adam state for >=30B params cannot fit a 256-chip v5e pod;
    # use factored second moments (see DESIGN.md §5)
    return "adamw" if cfg.n_params() < 30e9 else "adafactor"


# ------------------------------------------------------------ lowering

def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_status(cfg, shape)
    if skip:
        return {"status": skip}

    # sharding profile: pure DP for small-model train/prefill; decode
    # always keeps the serving profile (sequence-sharded KV caches —
    # pure DP would replicate a 32k-deep cache per device)
    from repro.dist.sharding import (set_active_rules, rules_for,
                                     DEFAULT_RULES)
    set_active_rules(DEFAULT_RULES if shape.kind == "decode"
                     else rules_for(cfg.n_params()))

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    specs = input_specs(cfg, shape)
    param_sh = M.param_shardings(cfg, mesh)
    p_shapes = M.param_shapes(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)

    if shape.kind == "train":
        tc = TrainConfig(optimizer=pick_optimizer_name(cfg), microbatch=1)
        opt = make_optimizer(tc)
        step = make_train_step(cfg, tc, mesh=mesh, opt=opt)
        o_shapes = opt_state_shapes(opt, cfg)
        opt_sh = opt_state_shardings(tc.optimizer, cfg, mesh)
        b_sh = batch_shardings(cfg, mesh, specs["batch"])
        rep = NamedSharding(mesh, P())
        metrics_sh = {k: rep for k in ("loss", "nll", "aux", "grad_norm", "lr")}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, b_sh),
                out_shardings=(param_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            ).lower(p_shapes, o_shapes, specs["batch"])
        model_flops = 6.0 * cfg.n_active_params() * tokens
    elif shape.kind == "prefill":
        order = ["tokens"] + [k for k in ("enc_frames", "extra_embeds")
                              if k in specs]

        # vlm: the patch-embedding prefix occupies cache positions too
        max_len = shape.seq_len + (cfg.vis_seq if cfg.family == "vlm" else 0)

        def serve_prefill(params, *inputs):
            kw = dict(zip(order, inputs))
            return M.prefill(cfg, params, kw.pop("tokens"),
                             max_len=max_len, mesh=mesh, **kw)
        b_sh = batch_shardings(cfg, mesh, specs)
        with mesh:
            lowered = jax.jit(
                serve_prefill,
                in_shardings=(param_sh,) + tuple(b_sh[k] for k in order),
            ).lower(p_shapes, *[specs[k] for k in order])
        model_flops = 2.0 * cfg.n_active_params() * tokens
    else:  # decode
        def serve_step(params, cache, tokens, positions):
            return M.decode_step(cfg, params, cache, tokens, positions,
                                 mesh=mesh)
        cache_sh = cache_shardings(cfg, mesh, shape.global_batch,
                                   shape.seq_len)
        tok_sh = NamedSharding(mesh, resolve_spec(
            (shape.global_batch, 1), ("batch", None), mesh))
        logits_sh = NamedSharding(mesh, resolve_spec(
            (shape.global_batch, 1, cfg.vocab),
            ("batch", None, "vocab"), mesh))
        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(param_sh, cache_sh, tok_sh, tok_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(1,),
            ).lower(p_shapes, specs["cache"], specs["tokens"],
                    specs["positions"])
        model_flops = 2.0 * cfg.n_active_params() * tokens

    return {"status": "ok", "lowered": lowered, "n_chips": n_chips,
            "model_flops": model_flops, "cfg": cfg}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             save_hlo: bool = False):
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        cell = lower_cell(arch, shape_name, multi_pod)
        if cell["status"] != "ok":
            result["status"] = cell["status"]
            print(f"[dryrun] {tag}: {cell['status']}")
        else:
            lowered = cell["lowered"]
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            print(f"[dryrun] {tag} memory_analysis: {mem}")
            ca = compiled.cost_analysis()
            print(f"[dryrun] {tag} cost_analysis keys: "
                  f"{sorted(list(ca))[:8] if ca else None}")
            cfg = cell["cfg"]
            cap = max(jnp.dtype(cfg.params_dtype).itemsize,
                      jnp.dtype(cfg.compute_dtype).itemsize)
            roof, coll = HA.roofline_from_compiled(
                compiled, cell["n_chips"], cell["model_flops"],
                native_cap_bytes=cap)
            mem_fields = {}
            for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, f, None)
                if v is not None:
                    mem_fields[f] = int(v)
            result.update({
                "status": "ok",
                "lower_s": t1 - t0, "compile_s": t2 - t1,
                "memory_analysis": mem_fields,
                "bytes_per_device": int(
                    mem_fields.get("argument_size_in_bytes", 0)
                    + mem_fields.get("temp_size_in_bytes", 0)),
                "roofline": roof.as_dict(),
                "collectives": {"by_kind": coll.by_kind,
                                "op_counts": coll.op_counts},
            })
            if save_hlo:
                (out_dir / f"{tag}.hlo.txt").write_text(compiled.as_text())
            print(f"[dryrun] {tag}: OK lower={t1-t0:.1f}s "
                  f"compile={t2-t1:.1f}s bottleneck="
                  f"{result['roofline']['bottleneck']}")
    except Exception as e:
        result["status"] = f"FAIL: {type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()
        print(f"[dryrun] {tag}: FAIL {e}")
    result["total_s"] = time.time() - t0
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / f"{tag}.json", "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    n_fail = 0
    for a, s, mp in cells:
        tag = f"{a}__{s}__{'multi' if mp else 'single'}"
        if args.skip_existing and (out / f"{tag}.json").exists():
            prev = json.loads((out / f"{tag}.json").read_text())
            if str(prev.get("status", "")).startswith(("ok", "skip")):
                print(f"[dryrun] {tag}: cached ({prev['status'][:40]})")
                continue
        r = run_cell(a, s, mp, out, save_hlo=args.save_hlo)
        if str(r["status"]).startswith("FAIL"):
            n_fail += 1
    print(f"[dryrun] done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
