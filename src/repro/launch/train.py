"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train \
      --arch gemma-2b --reduced --steps 200 --batch 8 --seq 64

Runs the full production loop — data pipeline, jit'd train step,
checkpoint/restart, preemption guard, straggler watchdog — at whatever
scale the current devices allow (reduced configs on CPU; full configs
on a pod with the same code path).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import model as M
from repro.data import SyntheticTokens
from repro.train import (TrainConfig, make_train_step, make_optimizer,
                         CheckpointManager, PreemptionGuard, StepWatchdog)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    tc = TrainConfig(optimizer=args.optimizer, learning_rate=args.lr,
                     warmup_steps=max(args.steps // 20, 5),
                     total_steps=args.steps, microbatch=args.microbatch)
    opt = make_optimizer(tc)

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt_state = opt.init(params)
    n_par = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_par/1e6:.1f}M params, {len(jax.devices())} device(s)")

    data = SyntheticTokens(cfg, batch=args.batch, seq=args.seq)
    step_fn = jax.jit(make_train_step(cfg, tc, opt=opt), donate_argnums=(0, 1))

    mgr = CheckpointManager(Path(args.ckpt_dir) / cfg.name, keep=3)
    start = 0
    if args.resume:
        latest = mgr.latest()
        if latest is not None:
            (params, opt_state), _ = mgr.restore(
                latest, (params, opt_state))
            start = latest
            print(f"[train] resumed from step {latest}")

    guard = PreemptionGuard()
    watchdog = StepWatchdog()
    log = []
    t_start = time.time()
    for step in range(start, args.steps):
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state,
                                             data.batch_at(step))
        dt = time.time() - t0
        watchdog.record(step, dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            tokens_s = args.batch * args.seq / dt
            print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                  f"nll {m['nll']:.4f} gnorm {m['grad_norm']:.3f} "
                  f"lr {m['lr']:.2e} {tokens_s:,.0f} tok/s")
            log.append({"step": step, **m, "tokens_per_s": tokens_s})
        if (step + 1) % args.save_every == 0 or guard.should_stop:
            mgr.save(step + 1, (params, opt_state))
            if guard.should_stop:
                print("[train] preemption requested: checkpointed, exiting")
                break

    mgr.save(args.steps, (params, opt_state))
    out = {"config": cfg.name, "steps": args.steps,
           "wall_s": time.time() - t_start, "log": log,
           "stragglers": watchdog.straggler_steps}
    Path("experiments").mkdir(exist_ok=True)
    with open(f"experiments/train_{cfg.name}.json", "w") as f:
        json.dump(out, f, indent=1)
    print(f"[train] done in {out['wall_s']:.1f}s; "
          f"final loss {log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
