"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` carries DP/FSDP, ``model`` carries TP/EP/SP, ``pod``
    (multi-pod only) carries pure DP — only gradient reduction crosses
    the inter-pod DCI links.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
