"""Structural HLO parser: per-device FLOPs / dot-bytes / collective wire
bytes with while-loop (lax.scan) trip-count multipliers.

Why not compiled.cost_analysis()?  XLA's analysis counts each while body
ONCE, so an L-layer scanned transformer under-reports by ~L x.  This
parser walks the computation call graph (entry -> fusions/calls/whiles),
multiplies while bodies by their trip counts (parsed from the loop
condition's comparison constant), and sums:

  * dot FLOPs:  2 * prod(result_shape) * contracted_size
  * dot HBM bytes: lhs + rhs + out  (first-order TPU model: every large
    matmul round-trips HBM; elementwise ops ride fused into them)
  * collective wire bytes per device, ring model (see hlo_analysis)

Shapes in the post-partitioning module are per-device, so all outputs
are per-device numbers.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([\w\[\],\s]*?)\s*"
                     r"([\w\-]+)\(")
_SHAPE_ONE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_CALL_REFS_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")
_BODY_REF_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_REF_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(shape_str: str):
    total_b = 0
    total_e = 0
    for m in _SHAPE_ONE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_e, total_b


def _parse_dims(shape_str: str):
    m = _SHAPE_ONE_RE.search(shape_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    dot_bytes: float = 0.0
    wire: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # (callee, kind): kind "while" carries trips via cond lookup
    calls: list = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        h = _HDR_RE.match(line)
        if h and line.rstrip().endswith("{"):
            cur = h.group(2)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _cond_trips(comp_lines) -> int:
    """Trip count from the loop condition: the comparison constant."""
    consts = []
    for line in comp_lines:
        for c in _CONST_RE.finditer(line):
            consts.append(int(c.group(1)))
    return max(consts) if consts else 1


class HloProgram:
    def __init__(self, text: str, default_group: int = 16,
                 native_cap_bytes: Optional[int] = None):
        """native_cap_bytes: cap the per-element width of collective
        payloads (TPU-native estimate).  The CPU backend promotes all
        bf16 compute to f32, so the lowered module shows f32 collectives
        that a TPU build keeps in bf16; capping at the model's widest
        declared dtype (2 for bf16-param models) undoes that promotion
        without crediting precision we never declared."""
        self.comps = _split_computations(text)
        self.default_group = default_group
        self.native_cap = native_cap_bytes
        self.stats: Dict[str, CompStats] = {}
        self.trips: Dict[str, int] = {}
        for name, lines in self.comps.items():
            self.stats[name] = self._analyze(name, lines)
        self.entry = self._find_entry(text)

    def _find_entry(self, text) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _HDR_RE.match(line)
                if m:
                    return m.group(2)
        return next(iter(self.comps), "")

    # ------------------------------------------------------------- core
    def _analyze(self, name, lines) -> CompStats:
        st = CompStats()
        shapes: Dict[str, str] = {}
        # pass 1: symbol table (including params)
        for line in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([^=]+?)\s+[\w\-]+\(", line)
            if m:
                shapes[m.group(1)] = m.group(2)
        for line in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)", line)
            if not m:
                continue
            res_name, res_shape, op, rest = m.groups()
            base_op = op
            if base_op.endswith("-start") or base_op.endswith("-done"):
                base_op = base_op.rsplit("-", 1)[0]
            if base_op == "dot":
                self._dot(st, res_shape, rest, shapes)
            elif base_op in _COLL_KINDS and not op.endswith("-done"):
                self._collective(st, base_op, res_shape, line)
            elif base_op == "while":
                b = _BODY_REF_RE.search(line)
                c = _COND_REF_RE.search(line)
                if b:
                    trips = 1
                    if c and c.group(1) in self.comps:
                        trips = _cond_trips(self.comps[c.group(1)])
                    st.calls.append((b.group(1), trips))
            elif base_op in ("fusion", "call", "map", "reduce", "sort",
                             "reduce-window", "scatter", "select-and-scatter",
                             "custom-call", "conditional"):
                for ref in _CALL_REFS_RE.finditer(line):
                    st.calls.append((ref.group(1), 1))
        return st

    def _dot(self, st, res_shape, rest, shapes):
        res_dims = _parse_dims(res_shape)
        if res_dims is None:
            return
        # operand names
        ops = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        lhs_shape = shapes.get(ops[0]) if ops else None
        contracted = 1
        if lhs_shape is not None:
            lhs_dims = _parse_dims(lhs_shape)
            cm = _CONTRACT_RE.search(rest)
            if lhs_dims and cm and cm.group(1):
                for i in cm.group(1).split(","):
                    idx = int(i)
                    if idx < len(lhs_dims):
                        contracted *= lhs_dims[idx]
        out_elems, out_bytes = _shape_elems_bytes(res_shape)
        st.flops += 2.0 * out_elems * contracted
        in_bytes = 0
        for o in ops[:2]:
            if o in shapes:
                in_bytes += _shape_elems_bytes(shapes[o])[1]
        st.dot_bytes += out_bytes + in_bytes

    def _collective(self, st, kind, res_shape, line):
        out_elems, out_bytes = _shape_elems_bytes(res_shape)
        if self.native_cap is not None and out_elems:
            width = out_bytes / out_elems
            out_bytes = out_elems * min(width, self.native_cap)
        g = self.default_group
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm = _GROUPS_LIST_RE.search(line)
            if gm:
                g = gm.group(1).count(",") + 1
        g = max(g, 2)
        f = (g - 1) / g
        if kind == "all-gather":
            wire = out_bytes * f
        elif kind == "all-reduce":
            wire = 2.0 * out_bytes * f
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif kind == "all-to-all":
            wire = out_bytes * f
        else:
            wire = out_bytes
        st.wire += wire
        st.wire_by_kind[kind] = st.wire_by_kind.get(kind, 0.0) + wire
        st.coll_counts[kind] = st.coll_counts.get(kind, 0) + 1

    # ----------------------------------------------------------- totals
    def totals(self):
        memo: Dict[str, tuple] = {}

        def walk(name, depth=0):
            if name in memo:
                return memo[name]
            if name not in self.stats or depth > 64:
                return (0.0, 0.0, 0.0, {}, {})
            st = self.stats[name]
            memo[name] = (st.flops, st.dot_bytes, st.wire,
                          dict(st.wire_by_kind), dict(st.coll_counts))
            f, b, w = st.flops, st.dot_bytes, st.wire
            wk = dict(st.wire_by_kind)
            cc = dict(st.coll_counts)
            for callee, mult in st.calls:
                cf, cb, cw, cwk, ccc = walk(callee, depth + 1)
                f += cf * mult
                b += cb * mult
                w += cw * mult
                for k, v in cwk.items():
                    wk[k] = wk.get(k, 0.0) + v * mult
                for k, v in ccc.items():
                    cc[k] = cc.get(k, 0) + v * mult
            memo[name] = (f, b, w, wk, cc)
            return memo[name]

        return walk(self.entry)


def analyze_hlo(text: str, default_group: int = 16,
                native_cap_bytes: Optional[int] = None):
    """Returns dict with per-device flops, dot_bytes, wire_bytes.
    wire_bytes_raw is always the as-lowered (CPU-promoted) number;
    wire_bytes applies the native dtype cap when given."""
    raw = HloProgram(text, default_group).totals()
    if native_cap_bytes is None:
        f, b, w, wk, cc = raw
        return {"flops": f, "dot_bytes": b, "wire_bytes": w,
                "wire_bytes_raw": w, "wire_by_kind": wk, "coll_counts": cc}
    f, b, w, wk, cc = HloProgram(text, default_group,
                                 native_cap_bytes).totals()
    return {"flops": f, "dot_bytes": b, "wire_bytes": w,
            "wire_bytes_raw": raw[2], "wire_by_kind": wk, "coll_counts": cc}
