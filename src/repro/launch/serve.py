"""Serving driver: loads (or inits) a model and serves batched requests
through the ServeEngine (prefill + jit'd decode loop).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import model as M
from repro.serve import ServeEngine, GenerationConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if not cfg.has_decoder:
        raise SystemExit(f"{cfg.name} has no decoder")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_frames"] = rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        kw["extra_embeds"] = rng.standard_normal(
            (args.batch, cfg.vis_seq, cfg.d_model)).astype(np.float32)

    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.max_new + 8)
    gen = GenerationConfig(max_new_tokens=args.max_new,
                           temperature=args.temperature)
    t0 = time.time()
    out = engine.generate(prompts, gen, **kw)
    dt = time.time() - t0
    n_tok = out.size
    print(f"[serve] {cfg.name}: generated {n_tok} tokens for "
          f"{args.batch} requests in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    print("[serve] first request tokens:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
