"""Roofline-term extraction from compiled XLA artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOPs)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = per-device wire bytes / link_bw

FLOPs/bytes come from compiled.cost_analysis(); collective bytes are
parsed out of the post-partitioning HLO text (per-device shapes), with
ring-algorithm wire factors per op kind and participant count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# --- TPU v5e constants (per chip) ---
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
# matches e.g.:  %ag = bf16[2,128]{1,0} all-gather(...) ... replica_groups=...
_OP_RE = re.compile(
    r"=\s*((?:\(|\w+\[)[^)]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'bf16[2,16,128]' or a tuple
    '(bf16[2], f32[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                      # per device, ring model
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str, default_group: int = 16) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out_bytes = _shape_bytes(shape_str)
        # participant count
        g = default_group
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            g = int(gm.group(2))                  # [n_groups, group_size]
        else:
            gm = _GROUPS_LIST_RE.search(line)
            if gm:
                g = gm.group(1).count(",") + 1
        g = max(g, 2)
        f = (g - 1) / g
        if kind == "all-gather":
            wire = out_bytes * f                  # receive (g-1)/g of out
        elif kind == "all-reduce":
            wire = 2.0 * out_bytes * f            # reduce-scatter + gather
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)            # out is the scattered part
        elif kind == "all-to-all":
            wire = out_bytes * f
        else:                                     # collective-permute
            wire = out_bytes
        stats.wire_bytes += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.op_counts[kind] = stats.op_counts.get(kind, 0) + 1
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    n_chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW           # wire bytes are per-device

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else float("nan")

    def as_dict(self):
        d = {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes_per_dev": self.wire_bytes, "n_chips": self.n_chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }
        for k in ("ca_flops_raw", "ca_bytes_raw", "wire_bytes_raw"):
            if hasattr(self, k):
                d[k] = getattr(self, k)
        return d


def roofline_from_compiled(compiled, n_chips: int,
                           model_flops: float = 0.0,
                           native_cap_bytes=None) -> Roofline:
    """Build roofline terms from a jax compiled object.

    Primary source: the structural HLO parser (hlo_parse) — it applies
    while-loop trip counts, which compiled.cost_analysis() does NOT
    (scan bodies are counted once there, under-reporting an L-layer
    model by ~L x; both numbers are recorded in the artifact).
    Shapes in the partitioned module are per-device; global = x n_chips.
    """
    from repro.launch import hlo_parse

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca_flops = float(ca.get("flops", 0.0) or 0.0)
    ca_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
    hlo = compiled.as_text()
    parsed = hlo_parse.analyze_hlo(hlo, native_cap_bytes=native_cap_bytes)
    coll = CollectiveStats(wire_bytes=parsed["wire_bytes"],
                           by_kind=parsed["wire_by_kind"],
                           op_counts=parsed["coll_counts"])
    roof = Roofline(flops=parsed["flops"] * n_chips,
                    hbm_bytes=parsed["dot_bytes"] * n_chips,
                    wire_bytes=parsed["wire_bytes"], n_chips=n_chips,
                    model_flops=model_flops)
    roof.ca_flops_raw = ca_flops
    roof.ca_bytes_raw = ca_bytes
    roof.wire_bytes_raw = parsed["wire_bytes_raw"]
    return roof, coll
