"""The assigned input-shape grid and per-cell input_specs().

Every (arch x shape) pair — 40 cells — is defined here, including the
documented skips (long_500k for pure full-attention archs, per the
assignment; recorded as status="skip" with the reason).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.data.tokens import batch_specs


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """None if runnable, else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skip: full quadratic attention at 524288-token decode "
                "(assignment: run long-context only for SSM/hybrid/SWA)")
    if shape.kind == "decode" and not cfg.has_decoder:
        return "skip: encoder-only architecture has no decode step"
    return None


def input_specs(cfg: ArchConfig, shape: ShapeSpec, compute_dtype=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    cd = jnp.dtype(compute_dtype or cfg.compute_dtype)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, B, S, cd)}
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "encdec":
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), cd)
        if cfg.family == "vlm":
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vis_seq, cfg.d_model), cd)
        return out
    # decode: one new token against a seq_len-deep cache
    from repro.models import model as M
    out = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "positions": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": M.cache_abstract(cfg, B, S, cd),
    }
    return out
