"""Logical-axis sharding registry: the single source of truth mapping
logical tensor axes ("embed", "heads", "batch", ...) to physical mesh
axes ("pod", "data", "model").

Every PartitionSpec in the repo — param trees in models/layers.py,
activation constraints in models/model.py / attention.py, batch and
cache shardings in launch/dryrun.py — is derived from one ``AxisRules``
table through ``resolve_spec``, so a profile change (serving TP vs.
pure-DP training) is a one-table swap via ``set_active_rules`` and can
never leave two call sites disagreeing.

Resolution semantics (``resolve_spec``):
  * each logical name maps to an ordered tuple of *candidate* mesh axes;
  * candidates absent from the mesh are skipped (the same table works
    for single-pod ``(data, model)`` and multi-pod ``(pod, data, model)``
    meshes);
  * a mesh axis is consumed at most once per spec (PartitionSpec cannot
    repeat an axis), earlier dims win;
  * a candidate whose size does not divide the remaining dim extent is
    skipped — the divisibility fallback that degrades to partial or
    fully replicated layouts instead of erroring (e.g. 6 kv heads on a
    16-wide model axis stay replicated).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

AxisCandidates = Union[str, Sequence[str], None]


class AxisRules:
    """Immutable ordered table: logical axis name -> candidate mesh axes."""

    def __init__(self, rules: Mapping[str, AxisCandidates]):
        table = {}
        for name, cand in dict(rules).items():
            if cand is None:
                table[name] = ()
            elif isinstance(cand, str):
                table[name] = (cand,)
            else:
                table[name] = tuple(cand)
        self._table = table

    def get(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        return self._table.get(name, ())

    def extend(self, **updates: AxisCandidates) -> "AxisRules":
        """New table with ``updates`` merged over this one."""
        merged = dict(self._table)
        merged.update(updates)
        return AxisRules(merged)

    def items(self):
        return self._table.items()

    def __contains__(self, name):
        return name in self._table

    def __eq__(self, other):
        return isinstance(other, AxisRules) and self._table == other._table

    def __hash__(self):
        return hash(tuple(sorted((k, v) for k, v in self._table.items())))

    def __repr__(self):
        body = ", ".join(f"{k}={v}" for k, v in self._table.items())
        return f"AxisRules({body})"


# Serving / tensor-parallel profile: weights and caches split over
# ``model``, batch over ``data`` (and ``pod`` when present), sequence
# parallelism between blocks on ``model``.
DEFAULT_RULES = AxisRules({
    # activations
    "batch": ("pod", "data"),
    "attn_batch": ("pod", "data", "model"),   # heads not shardable: spread B
    "seq": None,
    "seq_sp": ("model",),                     # inter-block sequence parallel
    # params
    "embed": None,
    "mlp": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "latent": None,
    "experts": ("model",),
    "vocab": ("model",),
    "layers": None,                           # scan axis, never sharded
    "conv": None,
    # decode caches
    "cache_batch": ("pod", "data"),
    "cache_seq": ("model",),                  # flash-decoding seq shards
})

# Pure data-parallel profile for models small enough to replicate:
# params replicated, the batch spread over every mesh axis.  Used for
# small-model train/prefill cells where TP collectives would dominate.
DP_RULES = AxisRules({
    "batch": ("pod", "data", "model"),
    "attn_batch": ("pod", "data", "model"),
    "seq": None,
    "seq_sp": None,
    "embed": None,
    "mlp": None,
    "heads": None,
    "kv": None,
    "latent": None,
    "experts": ("model",),                    # EP stays: dispatch is local
    "vocab": None,
    "layers": None,
    "conv": None,
    "cache_batch": ("pod", "data"),
    "cache_seq": ("model",),
})

# Params above this count cannot replicate per device: use the TP table.
DP_PARAM_THRESHOLD = 10e9


def rules_for(n_params: float,
              threshold: float = DP_PARAM_THRESHOLD) -> AxisRules:
    """Train/prefill rule table by parameter count: small models take
    the pure-DP profile, large ones the tensor-parallel DEFAULT_RULES.
    (Decode keeps DEFAULT_RULES regardless — a replicated 32k-deep KV
    cache per device is never affordable; see launch/dryrun.py.)"""
    return DP_RULES if n_params < threshold else DEFAULT_RULES


_ACTIVE_RULES = DEFAULT_RULES


def active_rules() -> AxisRules:
    """The process-wide rule table used when no explicit table is passed."""
    return _ACTIVE_RULES


def set_active_rules(rules: AxisRules) -> AxisRules:
    """Install ``rules`` as the active table; returns the previous one."""
    global _ACTIVE_RULES
    assert isinstance(rules, AxisRules), rules
    prev = _ACTIVE_RULES
    _ACTIVE_RULES = rules
    return prev


class use_rules:
    """Context manager: ``with use_rules(DP_RULES): ...`` scopes a table."""

    def __init__(self, rules: AxisRules):
        self._rules = rules

    def __enter__(self):
        self._prev = set_active_rules(self._rules)
        return self._rules

    def __exit__(self, *exc):
        set_active_rules(self._prev)
        return False


def logical_to_mesh(logical: Sequence[Optional[str]], mesh,
                    rules: Optional[AxisRules] = None) -> Tuple:
    """Map logical names to mesh-axis assignments (no shape knowledge:
    divisibility is NOT checked — use resolve_spec for a final spec).

    Returns one entry per logical name: None, a mesh axis, or a tuple
    of mesh axes.  Mesh axes are consumed left-to-right at most once.
    """
    rules = rules or active_rules()
    mesh_axes = dict(mesh.shape)
    used = set()
    out = []
    for name in logical:
        picked = []
        for cand in rules.get(name):
            if cand in mesh_axes and cand not in used:
                picked.append(cand)
                used.add(cand)
        out.append(None if not picked
                   else (picked[0] if len(picked) == 1 else tuple(picked)))
    return tuple(out)


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh, rules: Optional[AxisRules] = None) -> P:
    """Resolve (shape, logical axes) to a PartitionSpec for ``mesh``.

    Greedy per-dim assignment with the divisibility fallback described
    in the module docstring; axes of size 1 are skipped (they partition
    nothing and would block reuse elsewhere).
    """
    assert len(shape) == len(logical), (shape, logical)
    rules = rules or active_rules()
    mesh_axes = dict(mesh.shape)
    used = set()
    entries = []
    for extent, name in zip(shape, logical):
        picked = []
        remaining = int(extent)
        for cand in rules.get(name):
            size = mesh_axes.get(cand)
            if size is None or size <= 1 or cand in used:
                continue
            if remaining % size != 0:
                continue                      # divisibility fallback
            picked.append(cand)
            used.add(cand)
            remaining //= size
        entries.append(None if not picked
                       else (picked[0] if len(picked) == 1
                             else tuple(picked)))
    while entries and entries[-1] is None:    # trim trailing replication
        entries.pop()
    return P(*entries)


def named_sharding(shape, logical, mesh,
                   rules: Optional[AxisRules] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical, mesh, rules))


def factored_moment_specs(shape: Sequence[int],
                          logical: Sequence[Optional[str]], mesh,
                          rules: Optional[AxisRules] = None
                          ) -> Tuple[P, P]:
    """(row, col) PartitionSpecs for Adafactor's factored second moments
    of a parameter with ``(shape, logical)``: row drops the last axis,
    col drops the second-to-last (train/optimizer.py's FactoredMoment).

    Each moment is re-resolved through ``resolve_spec`` on its OWN
    (shape, logical) — NOT sliced out of the parameter's resolved
    PartitionSpec.  Slicing under-shards: dropping a dim frees the mesh
    axis it consumed, so a remaining dim whose candidate lost the greedy
    race on the full parameter (e.g. ("heads", "mlp") both wanting
    "model") can shard in the moment; divisibility is also re-checked
    against the moment's extents, not the parameter's."""
    assert len(shape) == len(logical), (shape, logical)
    row = resolve_spec(tuple(shape[:-1]), tuple(logical[:-1]), mesh, rules)
    col = resolve_spec(tuple(shape[:-2]) + tuple(shape[-1:]),
                       tuple(logical[:-2]) + tuple(logical[-1:]),
                       mesh, rules)
    return row, col


def constrain(x, mesh, logical: Sequence[Optional[str]],
              rules: Optional[AxisRules] = None):
    """with_sharding_constraint under the logical-axis naming; identity
    when mesh is None (CPU / single-device tests)."""
    if mesh is None:
        return x
    spec = resolve_spec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
