"""Int8 gradient compression with error feedback for data-parallel
gradient reduction (1-bit-Adam / EF-SGD style).

On a pure-DP mesh the gradient all-reduce is the only inter-replica
traffic; shipping int8 instead of fp32 cuts it 4x.  Naive quantisation
biases the step, so the quantisation residual is carried forward and
added to the next step's gradient (*error feedback*): the running MEAN
of the compressed stream converges to the true gradient, which is the
contract tested in tests/test_train_substrate.py.

NOTE on what is modelled vs. realised: this module implements the
*numerics* of compressed reduction (quantise -> reduce -> residual
carry).  The psum here runs on the dequantised fp32 values, so under
GSPMD-jit the wire bytes are NOT yet reduced — realising the 4x needs
the explicit-SPMD train step that all-gathers (q, scale) pairs over
the axis (ROADMAP open item); the step-level contract and convergence
behaviour are identical, which is what callers depend on today.

API (leaf-wise over arbitrary pytrees):
  quantize_int8(x)            -> (int8 values, float32 scalar scale)
  dequantize_int8(q, scale)   -> float32 reconstruction
  init_error_feedback(tree)   -> zero residual tree
  compressed_psum_tree(grads, err, mesh, axis)
                              -> (reduced grads, new residual tree)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantisation.

    Returns (q, scale) with q in [-127, 127] and x ~= q * scale; the
    worst-case elementwise error is scale/2 (round-to-nearest).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, jnp.asarray(1e-30, jnp.float32)) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(tree):
    """Zero quantisation-residual state shaped like the gradient tree."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), tree)


def _pmean_tree(tree, mesh, axis):
    """Mean of per-device leaf values along ``axis`` (identity if the
    axis has one device — e.g. CPU tests)."""
    shape = dict(mesh.shape)
    if axis not in shape:
        raise ValueError(f"compression axis {axis!r} not in mesh axes "
                         f"{tuple(shape)}")
    size = shape[axis]
    if size <= 1:
        return tree

    def body(t):
        return jax.tree.map(lambda v: jax.lax.psum(v, axis) / size, t)

    fn = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_vma=False)
    return fn(tree)


def compressed_psum_tree(grads, err, mesh, axis: str = "data"):
    """Error-feedback-compensated compressed gradient reduction.

    Per leaf: c = g + err is quantised to int8, the dequantised value
    is mean-reduced over the ``axis`` replicas, and the local residual
    c - deq(c) becomes the next step's err.  Returns (reduced, new_err);
    thread new_err through successive steps (see train/loop.py).
    """
    comp = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err)
    deq = jax.tree.map(
        lambda c: dequantize_int8(*quantize_int8(c)), comp)
    new_err = jax.tree.map(jnp.subtract, comp, deq)
    return _pmean_tree(deq, mesh, axis), new_err
