"""Distributed substrate: logical-axis sharding rules and gradient
compression.  grblas/dist.py (shard_map SpMM) predates this package and
stays in repro.grblas; model/launch/train sharding lives here."""
from repro.dist.sharding import (AxisRules, DEFAULT_RULES, DP_RULES,
                                 active_rules, constrain, logical_to_mesh,
                                 named_sharding, resolve_spec, rules_for,
                                 set_active_rules, use_rules)
from repro.dist.compression import (compressed_psum_tree, dequantize_int8,
                                    init_error_feedback, quantize_int8)

__all__ = [
    "AxisRules", "DEFAULT_RULES", "DP_RULES", "active_rules", "constrain",
    "logical_to_mesh", "named_sharding", "resolve_spec", "rules_for",
    "set_active_rules", "use_rules",
    "compressed_psum_tree", "dequantize_int8", "init_error_feedback",
    "quantize_int8",
]
