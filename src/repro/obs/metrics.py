"""Labeled counter/gauge/histogram registry (DESIGN.md §10).

The metrics half of the telemetry substrate: spans answer *where did
this run's wall clock go*, metrics answer *what has the process done so
far* — requests served, compiles triggered, bytes moved, rungs fired.
Prometheus-shaped on purpose (monotonic counters, labeled families,
text exposition) so the serve engine's ``stats()`` can be scraped
without an adapter, but in-process and dependency-free.

Two usage patterns:

  * **library-wide** — module singleton :data:`DEFAULT`; low layers
    (grblas dispatch, solver registry compile marks, recovery rungs,
    fault injectors) increment it unconditionally.  A counter bump is a
    dict lookup + float add; there is no disabled/enabled switch to
    keep hot paths honest.
  * **per-component** — the serve engine owns a private
    ``MetricsRegistry`` shared with its ``WarmCache``, so per-engine
    tests see isolated counts and ``EngineStats`` fields become *views*
    over the registry instead of a second set of books.

``snapshot()`` flattens everything to ``{"name{k=v}": float}``;
``delta(prev)`` subtracts snapshots (counters/histograms subtract,
gauges report current) — the unit tests and the retrace accounting in
the benches are written against deltas, never absolute values.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Tuple

_DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonic counter.  ``inc`` only; negative increments raise."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters are monotonic; inc() must be >= 0")
        self.value += amount


class Gauge:
    """Point-in-time value (queue depth, cache size)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: bucket ``le``
    counts include everything below)."""

    __slots__ = ("bounds", "bucket_counts", "count", "sum")

    def __init__(self, bounds=_DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError("histogram bounds must be sorted ascending")
        self.bucket_counts = [0] * (len(self.bounds) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def cumulative(self) -> List[Tuple[float, int]]:
        out, running = [], 0
        for b, c in zip(self.bounds, self.bucket_counts):
            running += c
            out.append((b, running))
        out.append((math.inf, self.count))
        return out


class MetricsRegistry:
    """Get-or-create families of labeled instruments.

    A (name, labelset) pair maps to one instrument; asking for the same
    name with a different instrument type is a programming error and
    raises immediately rather than silently forking the family.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Dict[Tuple[Tuple[str, str], ...], object]] = {}
        self._types: Dict[str, type] = {}
        self._buckets: Dict[str, Tuple[float, ...]] = {}

    def _get(self, kind: type, name: str, labels: Dict[str, str],
             buckets=None):
        with self._lock:
            have = self._types.get(name)
            if have is None:
                self._types[name] = kind
                self._metrics[name] = {}
                if kind is Histogram:
                    self._buckets[name] = tuple(buckets or _DEFAULT_BUCKETS)
            elif have is not kind:
                raise TypeError(
                    f"metric {name!r} already registered as {have.__name__}, "
                    f"requested as {kind.__name__}")
            key = _label_key(labels)
            fam = self._metrics[name]
            inst = fam.get(key)
            if inst is None:
                inst = (Histogram(self._buckets[name]) if kind is Histogram
                        else kind())
                fam[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    # ------------------------------------------------------------- queries

    def family(self, name: str) -> Dict[Tuple[Tuple[str, str], ...], object]:
        """All instruments registered under ``name`` (empty if none)."""
        return dict(self._metrics.get(name, {}))

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge, 0.0 if never touched (so
        back-compat stat views don't materialize empty instruments)."""
        fam = self._metrics.get(name)
        if not fam:
            return 0.0
        inst = fam.get(_label_key(labels))
        return float(inst.value) if inst is not None else 0.0

    def total(self, name: str) -> float:
        """Sum over every labelset of a counter/gauge family."""
        return float(sum(i.value for i in self._metrics.get(name, {}).values()))

    def labeled_values(self, name: str, label: str) -> Dict[str, float]:
        """{label-value: metric-value} for one label dimension of a
        family — e.g. ``labeled_values("serve_failed_total", "kind")``
        reconstructs the old ``EngineStats.failures`` dict."""
        out: Dict[str, float] = {}
        for key, inst in self._metrics.get(name, {}).items():
            d = dict(key)
            if label in d:
                out[d[label]] = out.get(d[label], 0.0) + inst.value
        return out

    # ----------------------------------------------------- snapshot / delta

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{"name{k=v}": value}``; histograms expand to
        ``_count`` / ``_sum`` / ``_bucket{le=..}`` series."""
        out: Dict[str, float] = {}
        with self._lock:
            for name, fam in self._metrics.items():
                kind = self._types[name]
                for key, inst in fam.items():
                    ls = _label_str(key)
                    if kind is Histogram:
                        out[f"{name}_count{ls}"] = float(inst.count)
                        out[f"{name}_sum{ls}"] = float(inst.sum)
                        for le, c in inst.cumulative():
                            les = "+Inf" if math.isinf(le) else repr(le)
                            lk = _label_key(dict(key, le=les))
                            out[f"{name}_bucket{_label_str(lk)}"] = float(c)
                    else:
                        out[f"{name}{ls}"] = float(inst.value)
        return out

    def delta(self, prev: Dict[str, float]) -> Dict[str, float]:
        """Snapshot minus ``prev``, dropping zero entries: what happened
        since.  Gauges subtract too — a gauge delta reads as net
        movement, which is what the serve benches chart."""
        now = self.snapshot()
        out = {}
        for k, v in now.items():
            d = v - prev.get(k, 0.0)
            if d != 0.0:
                out[k] = d
        return out

    # ------------------------------------------------------------ exposition

    def exposition(self) -> str:
        """Prometheus text format (``# TYPE`` headers + one line per
        series), newline-terminated."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                kind = self._types[name]
                tname = {"Counter": "counter", "Gauge": "gauge",
                         "Histogram": "histogram"}[kind.__name__]
                lines.append(f"# TYPE {name} {tname}")
                for key in sorted(self._metrics[name]):
                    inst = self._metrics[name][key]
                    ls = _label_str(key)
                    if kind is Histogram:
                        for le, c in inst.cumulative():
                            les = "+Inf" if math.isinf(le) else repr(le)
                            lk = _label_key(dict(key, le=les))
                            lines.append(
                                f"{name}_bucket{_label_str(lk)} {c}")
                        lines.append(f"{name}_sum{ls} {inst.sum}")
                        lines.append(f"{name}_count{ls} {inst.count}")
                    else:
                        v = inst.value
                        sv = repr(int(v)) if float(v).is_integer() else repr(v)
                        lines.append(f"{name}{ls} {sv}")
        return "\n".join(lines) + ("\n" if lines else "")


# Library-wide registry: low-layer instruments (grblas dispatch, solver
# compiles, recovery rungs, fault injections) land here.
DEFAULT = MetricsRegistry()


def default() -> MetricsRegistry:
    return DEFAULT
