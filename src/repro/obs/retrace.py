"""Retrace / recompile detection over the solver-registry trace memo.

Every jitted driver in this repo marks its compiles through
``registry.mark_trace(key)`` — the Newton/SCF/inverse-power memos and
the serve engine's per-bucket vmapped solves all flow through it (the
serve keys are ``("serve", mode, n, nnz, k) + solver-sig``).  PR 7
asserted "one trace per bucket" by counting ``SOLVER_TRACES`` by hand
in the bench; this module turns that side channel into a first-class
detector:

  * :class:`RetraceDetector` — position-bookmark over ``SOLVER_TRACES``
    with per-key compile counts and bucket/solver groupings,
  * :func:`assert_no_retrace` — context manager for steady-state
    regions: any *new* compile inside the block raises
    :class:`RetraceError` naming the offending keys,
  * a ``compiles_total{site=...}`` counter on the DEFAULT metrics
    registry plus a ``compile`` instant on the active tracer — both
    emitted by ``registry.mark_trace`` itself (with
    ``registry.TRACE_LISTENERS`` for extra hooks), so compiles show up
    on the same timeline as the spans they stall.

The registry import is deferred to call time: obs.trace/metrics sit
*below* the solver stack (grblas imports them), this module sits above
it, and lazy import keeps the package cycle-free.
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Tuple


def _registry():
    from repro.core.solvers import registry
    return registry


class RetraceError(AssertionError):
    """A jitted region recompiled (or compiled more than allowed)."""


def _sitename(key) -> str:
    return str(key[0]) if isinstance(key, tuple) and key else str(key)


class RetraceDetector:
    """Bookmark ``SOLVER_TRACES`` at construction; everything appended
    after is 'ours'."""

    def __init__(self):
        self._base = len(_registry().SOLVER_TRACES)

    def traces(self) -> List[tuple]:
        """New trace keys since construction, in order."""
        return list(_registry().SOLVER_TRACES[self._base:])

    def compiles(self) -> Dict[tuple, int]:
        """Compile count per full memo key."""
        out: Dict[tuple, int] = {}
        for k in self.traces():
            out[k] = out.get(k, 0) + 1
        return out

    def by_site(self) -> Dict[str, int]:
        """Compile count per site (key head: "serve", "newton", ...)."""
        out: Dict[str, int] = {}
        for k in self.traces():
            s = _sitename(k)
            out[s] = out.get(s, 0) + 1
        return out

    def serve_buckets(self) -> Dict[Tuple, int]:
        """Compile count per serve (bucket, solver) memo key — the
        bench acceptance is every value here == 1."""
        return {k: v for k, v in self.compiles().items()
                if _sitename(k) == "serve"}

    def assert_at_most(self, max_per_key: int = 1) -> None:
        bad = {k: v for k, v in self.compiles().items() if v > max_per_key}
        if bad:
            lines = "\n".join(f"  {v}x {k}" for k, v in bad.items())
            raise RetraceError(
                f"retrace detected: {len(bad)} key(s) compiled more than "
                f"{max_per_key}x since detector start:\n{lines}")

    def assert_no_retrace(self) -> None:
        """No key compiled since construction."""
        fresh = self.compiles()
        if fresh:
            lines = "\n".join(f"  {v}x {k}" for k, v in fresh.items())
            raise RetraceError(
                f"retrace detected: {sum(fresh.values())} unexpected "
                f"compile(s):\n{lines}")


@contextlib.contextmanager
def assert_no_retrace():
    """Steady-state guard: the block must trigger zero new compiles.

    >>> eng.submit(...); eng.poll()        # warm every bucket first
    >>> with assert_no_retrace():
    ...     eng.submit(...); eng.poll()    # replays only, or RetraceError
    """
    det = RetraceDetector()
    yield det
    det.assert_no_retrace()
