"""Structured span tracing for the PSC stack (DESIGN.md §10).

One substrate for the question every bench and every scaling claim in
this repo keeps re-answering ad hoc: *where did the wall clock go?*  A
:class:`Tracer` records nested :class:`Span`s (context managers with
attributes) into a bounded in-memory buffer and exports them as
Chrome/Perfetto trace-event JSON or JSONL.  Three design rules:

  * **disabled tracing is (nearly) free** — the module-level ``ACTIVE``
    tracer defaults to the :data:`NULL` singleton; hot paths do one
    attribute lookup (``trace.ACTIVE.enabled``) and branch away, or call
    ``trace.ACTIVE.span(...)`` and get the shared no-op span.  Nothing
    allocates, nothing is buffered.  The jitted inner loops are never
    instrumented at all: spans live at the host-side driver layer, so a
    compiled replay carries zero tracing cost by construction.
  * **clocks are fenced** — jax dispatch is async, so a span that wraps
    a jitted region must call ``sp.fence(value)`` (block_until_ready)
    before its exit timestamp means anything.  Fencing is governed by
    ``TraceConfig.fence`` so the same instrumentation can run unfenced
    when the caller wants dispatch-side timing.
  * **clocks are injectable** — ``TraceConfig.clock`` replaces the
    monotonic clock for deterministic tests (export round-trips assert
    exact timestamps, not sleeps).

The buffer is bounded (``TraceConfig.capacity``): when full, new spans
are counted in ``Tracer.dropped`` instead of growing without limit — a
serve engine left tracing for a week degrades to counters, it does not
OOM.

Correlation ids: fault injectors (repro.testing.faultinject) call
``begin_injection`` which stamps a fresh id, and recovery-ladder events
(core.solvers.guard) read ``current_injection()`` — so a chaos-suite
timeline shows which injected fault caused which recovery rung without
log scraping.

This module imports nothing from the rest of ``repro`` (stdlib + jax
only) so the lowest layers (grblas.api, the solver registry) can import
it without cycles.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import time
from typing import Any, Callable, Dict, List, Optional

import jax


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Shape of one tracing session (``PSCConfig.trace`` accepts this)."""

    capacity: int = 65536        # span+event buffer bound (drop past it)
    fence: bool = True           # block_until_ready at span fences
    clock: Optional[Callable[[], float]] = None   # None = time.perf_counter


class Span:
    """One timed region.  Context manager; reopenable attributes via
    ``set(...)``; ``fence(x)`` blocks on jax values so the exit
    timestamp covers the device work the span claims."""

    __slots__ = ("name", "cat", "t0", "dur", "sid", "parent", "depth",
                 "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self.t0 = 0.0
        self.dur = 0.0
        self.sid = 0
        self.parent: Optional[int] = None
        self.depth = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def fence(self, value):
        """Block until ``value``'s device work is done (when the session
        fences), so the span's exit time includes it.  Returns value."""
        if self._tracer._fence:
            jax.block_until_ready(value)
        return value

    def event(self, name: str, **attrs) -> None:
        """An instant event stamped inside this span."""
        self._tracer.instant(name, **attrs)

    def __enter__(self) -> "Span":
        self._tracer._open(self)
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._close(self)
        return False


class _NullSpan:
    """The shared no-op span: every method is a cheap constant."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def fence(self, value):
        return value

    def event(self, name, **attrs):
        return None


NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracing: ``ACTIVE`` points here by default, so hot paths
    pay one attribute lookup (``.enabled``) or a no-op call."""

    enabled = False
    spans: List[Span] = []
    events: List[dict] = []
    dropped = 0

    def span(self, name, cat="", **attrs):
        return NULL_SPAN

    def instant(self, name, **attrs):
        return None

    def fence(self, value):
        return value


NULL = NullTracer()

# The module-level active tracer.  Hot paths read ``trace.ACTIVE``; the
# session machinery (``use`` / ``session``) swaps it.
ACTIVE = NULL


class Tracer:
    """A bounded in-memory span recorder (see module docstring)."""

    enabled = True

    def __init__(self, cfg: Optional[TraceConfig] = None):
        cfg = cfg if cfg is not None else TraceConfig()
        self.cfg = cfg
        self._clock = cfg.clock if cfg.clock is not None else time.perf_counter
        self._fence = cfg.fence
        self._capacity = int(cfg.capacity)
        self._stack: List[Span] = []
        self._seq = itertools.count(1)
        self.spans: List[Span] = []     # finished spans, exit order
        self.events: List[dict] = []    # instant events
        self.dropped = 0
        self.t_start = self._clock()

    # ------------------------------------------------------------- recording

    def span(self, name: str, cat: str = "", **attrs) -> Span:
        return Span(self, name, cat, attrs)

    def instant(self, name: str, **attrs) -> None:
        if len(self.events) >= self._capacity:
            self.dropped += 1
            return
        parent = self._stack[-1].sid if self._stack else None
        self.events.append({"name": name, "ts": self._clock() - self.t_start,
                            "parent": parent, "attrs": attrs})

    def fence(self, value):
        if self._fence:
            jax.block_until_ready(value)
        return value

    def _open(self, sp: Span) -> None:
        sp.sid = next(self._seq)
        sp.parent = self._stack[-1].sid if self._stack else None
        sp.depth = len(self._stack)
        self._stack.append(sp)
        sp.t0 = self._clock() - self.t_start

    def _close(self, sp: Span) -> None:
        sp.dur = (self._clock() - self.t_start) - sp.t0
        if self._stack and self._stack[-1] is sp:
            self._stack.pop()
        elif sp in self._stack:         # mis-nested exit: drop descendants
            while self._stack and self._stack[-1] is not sp:
                self._stack.pop()
            self._stack.pop()
        if len(self.spans) >= self._capacity:
            self.dropped += 1
            return
        self.spans.append(sp)

    # ----------------------------------------------------------- aggregation

    def roots(self) -> List[Span]:
        return [s for s in self.spans if s.depth == 0]

    def children(self, parent: Span) -> List[Span]:
        return [s for s in self.spans if s.parent == parent.sid]

    def by_name(self) -> Dict[str, float]:
        """Total seconds per span name (all depths)."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.dur
        return out

    # -------------------------------------------------------------- exporters

    def export_chrome(self) -> dict:
        """Chrome/Perfetto trace-event JSON (``chrome://tracing`` /
        ui.perfetto.dev openable): complete ("X") events for spans,
        instant ("i") events, timestamps in microseconds."""
        ev = []
        for s in self.spans:
            ev.append({"name": s.name, "cat": s.cat or "span", "ph": "X",
                       "ts": round(s.t0 * 1e6, 3),
                       "dur": round(s.dur * 1e6, 3),
                       "pid": 0, "tid": 0,
                       "args": _jsonable(s.attrs)})
        for e in self.events:
            ev.append({"name": e["name"], "cat": "event", "ph": "i",
                       "ts": round(e["ts"] * 1e6, 3), "pid": 0, "tid": 0,
                       "s": "t", "args": _jsonable(e["attrs"])})
        ev.sort(key=lambda d: d["ts"])
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": {"dropped": self.dropped}}

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.export_chrome(), f)

    def export_jsonl(self) -> str:
        """One JSON object per line: spans (kind="span") then instants
        (kind="event"), both with seconds-based timestamps."""
        lines = []
        for s in self.spans:
            lines.append(json.dumps(
                {"kind": "span", "name": s.name, "cat": s.cat,
                 "ts": s.t0, "dur": s.dur, "sid": s.sid,
                 "parent": s.parent, "depth": s.depth,
                 "attrs": _jsonable(s.attrs)}))
        for e in self.events:
            lines.append(json.dumps(
                {"kind": "event", "name": e["name"], "ts": e["ts"],
                 "parent": e["parent"], "attrs": _jsonable(e["attrs"])}))
        return "\n".join(lines) + ("\n" if lines else "")

    # --------------------------------------------------------------- session

    def activate(self):
        """``with tracer.activate():`` — install as the module ACTIVE."""
        return use(self)


def _jsonable(attrs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = str(v)
    return out


@contextlib.contextmanager
def use(tracer):
    """Install ``tracer`` as the module-level ACTIVE for the block."""
    global ACTIVE
    prev = ACTIVE
    ACTIVE = tracer
    try:
        yield tracer
    finally:
        ACTIVE = prev


def coerce(spec) -> Optional[TraceConfig]:
    """``PSCConfig.trace`` coercion: None/False = off, True = defaults,
    a TraceConfig passes through."""
    if not spec:
        return None
    if spec is True:
        return TraceConfig()
    if isinstance(spec, TraceConfig):
        return spec
    raise TypeError(f"trace must be None, True/False, or a TraceConfig, "
                    f"got {type(spec).__name__}")


@contextlib.contextmanager
def session(spec):
    """The pipeline's tracing entry: if ``spec`` asks for tracing and no
    real tracer is active, create one, install it, and yield it (the
    caller owns its telemetry).  If a tracer is already active — an
    outer session, an engine-level tracer — yield None and let spans
    flow to the owner."""
    cfg = coerce(spec) if not isinstance(spec, Tracer) else None
    if isinstance(spec, Tracer):
        if ACTIVE.enabled:
            yield None
            return
        with use(spec):
            yield spec
        return
    if cfg is None or ACTIVE.enabled:
        yield None
        return
    tracer = Tracer(cfg)
    with use(tracer):
        yield tracer


# ------------------------------------------------- fault/recovery correlation

_INJECTION_SEQ = itertools.count(1)
_CURRENT_INJECTION: Optional[int] = None


def begin_injection(site: str, detail: str = "") -> int:
    """Stamp a fresh injection id (fault injectors call this); emits a
    ``fault.<site>`` instant on the active tracer so the fault and any
    recovery it triggers share one correlatable id on the timeline."""
    global _CURRENT_INJECTION
    inj = next(_INJECTION_SEQ)
    _CURRENT_INJECTION = inj
    ACTIVE.instant(f"fault.{site}", injection_id=inj, detail=detail)
    return inj


def current_injection() -> Optional[int]:
    """The most recent injection id (None outside chaos runs) — recovery
    events attach it so failures read off one timeline."""
    return _CURRENT_INJECTION


# --------------------------------------------------------------- telemetry

@dataclasses.dataclass
class Telemetry:
    """What a traced pipeline run hands back (``PSCResult.telemetry``):
    the finished spans/events plus export + aggregation helpers."""

    spans: List[Span]
    events: List[dict]
    dropped: int
    metrics: Optional[dict] = None      # DEFAULT-registry snapshot

    @classmethod
    def from_tracer(cls, tracer: Tracer,
                    metrics: Optional[dict] = None) -> "Telemetry":
        return cls(spans=list(tracer.spans), events=list(tracer.events),
                   dropped=tracer.dropped, metrics=metrics)

    def _as_tracer(self) -> Tracer:
        t = Tracer(TraceConfig(fence=False))
        t.spans = self.spans
        t.events = self.events
        t.dropped = self.dropped
        return t

    def chrome(self) -> dict:
        return self._as_tracer().export_chrome()

    def write_chrome(self, path) -> None:
        self._as_tracer().write_chrome(path)

    def jsonl(self) -> str:
        return self._as_tracer().export_jsonl()

    def root(self) -> Optional[Span]:
        roots = [s for s in self.spans if s.depth == 0]
        return roots[0] if roots else None

    def phase_breakdown(self) -> Dict[str, float]:
        """Seconds per top-level phase: depth-1 spans under the root
        (init / continuation / kmeans on the flat path; coarsen /
        coarse_solve / refine / kmeans on the multilevel path), grouped
        by name."""
        root = self.root()
        if root is None:
            return {}
        out: Dict[str, float] = {}
        for s in self.spans:
            if s.parent == root.sid:
                out[s.name] = out.get(s.name, 0.0) + s.dur
        return out

    def coverage(self) -> float:
        """Fraction of the root span's wall clock accounted for by its
        direct children — the ≥0.9 bound trace_psc.py asserts."""
        root = self.root()
        if root is None or root.dur <= 0:
            return float("nan")
        return sum(self.phase_breakdown().values()) / root.dur

    def total_s(self) -> float:
        root = self.root()
        return root.dur if root is not None else float("nan")

    def by_name(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.dur
        return out


# ------------------------------------------------------------------ helpers

def under_trace(*values) -> bool:
    """True when called during jit tracing (wall-clock spans would time
    the *trace*, not the run — instrument sites degrade to dispatch
    counters there).  The probe values are a fallback for jax versions
    without ``trace_state_clean``."""
    try:
        return not jax.core.trace_state_clean()
    except Exception:
        return any(isinstance(v, jax.core.Tracer) for v in values)


def roofline_summary(spans, peak_gbs: Optional[float] = None
                     ) -> Dict[str, dict]:
    """Per-backend achieved bandwidth from ``grblas.mxm`` spans (attrs
    carry the byte model): {backend: {calls, bytes, seconds, gb_s[,
    frac_of_peak]}} — the span-level analogue of
    benchmarks/roofline_report.py's dominant-term table."""
    out: Dict[str, dict] = {}
    for s in spans:
        by = s.attrs.get("bytes") if isinstance(s.attrs, dict) else None
        if by is None:
            continue
        be = s.attrs.get("backend", "?")
        row = out.setdefault(be, {"calls": 0, "bytes": 0, "seconds": 0.0})
        row["calls"] += 1
        row["bytes"] += int(by)
        row["seconds"] += float(s.dur)
    for row in out.values():
        row["gb_s"] = (row["bytes"] / row["seconds"] / 1e9
                       if row["seconds"] > 0 else float("nan"))
        if peak_gbs:
            row["frac_of_peak"] = row["gb_s"] / peak_gbs
    return out
