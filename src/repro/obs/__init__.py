"""repro.obs — telemetry substrate: spans, metrics, retrace detection.

See DESIGN.md §10.  Import layering: ``obs.trace`` and ``obs.metrics``
depend only on stdlib + jax so the lowest layers (grblas, the solver
registry) import them freely; ``obs.retrace`` sits above the solver
stack and is exposed lazily here to keep the package cycle-free.
"""
from repro.obs import metrics, trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               DEFAULT)
from repro.obs.trace import (NULL, Span, Telemetry, TraceConfig, Tracer,
                             begin_injection, current_injection,
                             roofline_summary, session, use)

__all__ = [
    "metrics", "trace", "retrace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "DEFAULT",
    "NULL", "Span", "Telemetry", "TraceConfig", "Tracer",
    "begin_injection", "current_injection", "roofline_summary",
    "session", "use",
    "RetraceDetector", "RetraceError", "assert_no_retrace",
]

_LAZY = {"retrace", "RetraceDetector", "RetraceError", "assert_no_retrace"}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        _retrace = importlib.import_module("repro.obs.retrace")
        globals()["retrace"] = _retrace
        globals()["RetraceDetector"] = _retrace.RetraceDetector
        globals()["RetraceError"] = _retrace.RetraceError
        globals()["assert_no_retrace"] = _retrace.assert_no_retrace
        return globals()[name]
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
