"""Distributed SpMM: the shard_map analogue of the C++ runtime's
auto-parallelised vxm.

Row-block 1-D partition: device d owns rows [d*B, (d+1)*B); the input
multivector is all-gathered along the ``data`` axis (vector bytes ≪
matrix bytes for k ≤ 16), outputs stay sharded.  This mirrors the
paper's shared-memory row-parallel SpMV, with the NUMA domain replaced
by a mesh axis.  A 2-D (data × model) partition with psum over ``model``
is provided for matrices whose rows outgrow one device.

Graph-aware placement: ``make_row_partition`` can take a clustering
assignment (from repro.core.psc — the paper's own algorithm) to permute
rows so that communication-heavy rows land on the same device; this is
the framework-level integration of the paper's technique (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.grblas.containers import SparseMatrix
from repro.grblas.semiring import Semiring, EdgeSemiring, reals_ring


class RowPartitionedMatrix:
    """ELL layout padded + reshaped to (n_shards, rows_per_shard, max_nnz)."""

    def __init__(self, ell_cols, ell_vals, n_rows, n_cols, n_shards, perm=None):
        self.ell_cols = ell_cols    # (S, R, M) int32, global col ids
        self.ell_vals = ell_vals    # (S, R, M)
        self.n_rows = n_rows        # original (unpadded) row count
        self.n_cols = n_cols
        self.n_shards = n_shards
        self.perm = perm            # optional row permutation applied


def make_row_partition(A: SparseMatrix, n_shards: int,
                       assignment: Optional[np.ndarray] = None) -> RowPartitionedMatrix:
    """Split A's ELL rows into n_shards contiguous blocks (host-side).

    If ``assignment`` (a cluster id per row, e.g. from p-spectral
    clustering) is given, rows are permuted so same-cluster rows are
    contiguous -> fewer remote touches per shard.
    """
    assert A.ell_cols is not None, "build_ell=True required"
    ell_cols = np.asarray(A.ell_cols)
    ell_vals = np.asarray(A.ell_vals)
    n, m = ell_cols.shape
    perm = None
    if assignment is not None:
        perm = np.argsort(np.asarray(assignment), kind="stable")
        inv = np.empty_like(perm)
        inv[perm] = np.arange(n)
        # permute rows AND remap column ids into the permuted numbering,
        # so the partitioned operator acts on the permuted vector space
        ell_cols, ell_vals = inv[ell_cols[perm]].astype(np.int32), ell_vals[perm]
    pad = (-n) % n_shards
    if pad:
        # padded rows reference column 0 with weight 0 (no-ops)
        ell_cols = np.concatenate([ell_cols, np.zeros((pad, m), np.int32)])
        ell_vals = np.concatenate([ell_vals, np.zeros((pad, m), ell_vals.dtype)])
    R = (n + pad) // n_shards
    return RowPartitionedMatrix(
        ell_cols=jnp.asarray(ell_cols.reshape(n_shards, R, m)),
        ell_vals=jnp.asarray(ell_vals.reshape(n_shards, R, m)),
        n_rows=n, n_cols=A.n_cols, n_shards=n_shards, perm=perm)


def shard_mxm(Ap: RowPartitionedMatrix, X: jnp.ndarray, mesh,
              axis: str = "data",
              ring: Semiring | EdgeSemiring = reals_ring) -> jnp.ndarray:
    """Distributed SpMM: rows sharded over ``axis``, X gathered per shard.

    The execute hook of the "dist" backend (grblas.backends).  X:
    (n_padded,) or (n_padded, k) row-sharded on entry; returns the
    product with the same sharding.  Inside each shard we run the same
    ELL gather kernel as the single-device "ell" backend, so dist ==
    single-device numerically.
    """
    n_pad = Ap.ell_cols.shape[0] * Ap.ell_cols.shape[1]
    vec_spec = P(axis) if X.ndim == 1 else P(axis, None)

    def _local_row_ids(rows_per, axis_name):
        idx = jax.lax.axis_index(axis_name)
        return idx * rows_per + jnp.arange(rows_per)

    def local(ell_cols, ell_vals, x_local):
        ell_cols = ell_cols[0]                            # (R, M) this shard
        ell_vals = ell_vals[0]
        x_full = jax.lax.all_gather(x_local, axis, axis=0, tiled=True)
        gathered = x_full[ell_cols]                       # (R, M[, k])
        vals = ell_vals if x_full.ndim == 1 else ell_vals[..., None]
        if isinstance(ring, EdgeSemiring):
            x_rows = x_full[_local_row_ids(ell_cols.shape[0], axis)]
            if x_full.ndim == 2:
                x_rows = x_rows[:, None, :]
            else:
                x_rows = x_rows[:, None]
            contrib = ring.edge_mul(vals, gathered, x_rows)
        else:
            contrib = ring.mul(vals, gathered)
        return jnp.sum(contrib, axis=1)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None), vec_spec),
        out_specs=vec_spec, check_vma=False)
    needs_pad = X.shape[0] != n_pad
    X_pad = X
    if needs_pad:
        widths = ((0, n_pad - X.shape[0]),) + ((0, 0),) * (X.ndim - 1)
        X_pad = jnp.pad(X, widths)
    out = fn(Ap.ell_cols, Ap.ell_vals, X_pad)
    return out[: X.shape[0]] if needs_pad else out
