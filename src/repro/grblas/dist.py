"""Distributed SpMM: the shard_map analogue of the C++ runtime's
auto-parallelised vxm — now with halo (remote-row) exchange.

Row-block 1-D partition: device d owns rows [d*B, (d+1)*B).  The old
path all-gathered the entire multivector per call, so wire bytes grew
as O(n·k·S) regardless of the partition quality; the paper's
strong-scaling claim rests on communication proportional to the *cut*.
``make_row_partition`` therefore precomputes, per shard, the set of
remote rows its ELL columns actually touch (host-side, from the
pattern), stores a static send plan, and ``shard_mxm`` replaces the
``all_gather`` with one ``all_to_all`` of only those halo rows.  When
the padded halo is so large that it would move more data than the
gather (dense cuts, bad placement), the plan falls back to the gather
at build time — the threshold is ``HALO_FALLBACK_FRAC``.

Graph-aware placement: ``make_row_partition`` can take a clustering
assignment (from repro.core.psc — the paper's own algorithm) to permute
rows so that same-cluster rows land on the same device; the halo then
contains only *cut* rows, which is the framework-level integration of
the paper's balanced-cut objective applied to the machine (DESIGN.md
§4).  Unlike the pre-halo code, the permutation is internal: X arrives
and Y returns in the ORIGINAL row space (the layout permutes on the way
in and un-permutes on the way out, like the SELL-C-σ layout does).

``sellcs=True`` additionally shards the SELL-C-σ layout per row block:
each shard σ-sorts its own rows, slices them into C-row blocks, and
pads per slice — widths are maxed across shards so the shard_map body
stays SPMD-uniform.  That keeps the skewed-degree regime's layout
advantage under a mesh (the "dist_sellcs" backend).

``init_distributed`` / ``device_mesh`` are the multi-process launch
path: a guarded ``jax.distributed.initialize`` (no-op single-process)
plus a 1-D mesh over the global device set.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.grblas.containers import SparseMatrix
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.grblas.semiring import (Semiring, EdgeSemiring, fast_paths,
                                   reals_ring)

# Build-time halo/gather decision: take the halo path only while the
# padded per-pair halo width H stays under this fraction of the shard
# row count R.  Per shard the halo moves (S-1)·H rows vs the gather's
# (S-1)·R, so the fraction is exactly the wire-byte ratio of the two.
HALO_FALLBACK_FRAC = 0.5


@dataclasses.dataclass
class DistSellCS:
    """Per-shard SELL-C-σ slicing of a row partition (SPMD-uniform).

    Every shard σ-sorts its own R rows by degree, slices them into
    C-row blocks, and pads each slice to the *cross-shard* max width of
    that slice index — so all shards share one static set of width runs
    and the shard_map body stays uniform.  Column ids index the shard's
    extended-local vector (locals then halo slots; global x under a
    gather-mode plan), ``own`` holds each packed row's local id (the
    x_i gather for edge kinds), and ``inv`` un-sorts the packed output
    back to local row order.
    """

    run_cols: Tuple[jnp.ndarray, ...]   # per run (S, rows_r, w_r) int32
    run_vals: Tuple[jnp.ndarray, ...]   # per run (S, rows_r, w_r)
    run_own: Tuple[jnp.ndarray, ...]    # per run (S, rows_r) int32 local row
    inv: jnp.ndarray                    # (S, R) int32 local row -> packed pos
    sell_c: int
    n_pad_local: int                    # R rounded up to a multiple of C


class RowPartitionedMatrix:
    """ELL layout split into (n_shards, rows_per_shard, max_nnz) + a
    static halo-exchange plan (see module docstring).

    ``mode`` is decided at build time: "halo" stores column ids remapped
    into each shard's extended-local space [0, R + S·H) plus the send
    plan; "gather" (the fallback) stores global column ids and runs the
    legacy all-gather schedule.
    """

    def __init__(self, ell_cols, ell_vals, n_rows, n_cols, n_shards,
                 perm=None, inv_perm=None, mode="gather", halo_width=0,
                 send_idx=None, halo_rows_true=0, sell=None):
        self.ell_cols = ell_cols    # (S, R, M) int32; extended-local ids in
        self.ell_vals = ell_vals    # (S, R, M)    halo mode, global in gather
        self.n_rows = n_rows        # original (unpadded) row count
        self.n_cols = n_cols
        self.n_shards = n_shards
        self.perm = perm            # (n,) position -> original row, or None
        self.inv_perm = inv_perm    # (n,) original row -> position, or None
        self.mode = mode            # "halo" | "gather"
        self.halo_width = halo_width        # H: padded rows per (dst, src) pair
        self.send_idx = send_idx            # (S, S*H) int32 local rows to ship
        self.halo_rows_true = halo_rows_true  # sum of true (unpadded) needs
        self.sell = sell            # DistSellCS or None

    @property
    def rows_per_shard(self) -> int:
        return self.ell_cols.shape[1]

    def wire_bytes(self, k: int = 1, itemsize: int = 4) -> dict:
        """Analytic per-call communication volume of each schedule.

        The all_to_all self-chunk and the gather's own shard never cross
        the wire, so both counts use (S-1) partners per shard.  These are
        exact for the static plans (the collectives move precisely the
        planned rows) — the quantity BENCH_dist.json records.  On a plan
        that auto-fell back to the gather schedule, "halo" reports what
        the rejected halo WOULD have moved (the basis of the fallback
        decision); on a forced mode="gather" plan no halo was computed
        and "halo" is 0.
        """
        S, R = self.n_shards, self.rows_per_shard
        return {
            "halo": S * (S - 1) * self.halo_width * k * itemsize,
            "gather": S * (S - 1) * R * k * itemsize,
            "halo_rows_true": int(self.halo_rows_true),
            "halo_width": int(self.halo_width),
        }


def _halo_plan(ell_cols: np.ndarray, n_shards: int, R: int):
    """Remote-row needs of each shard, from the partitioned ELL pattern.

    Returns (needed, H, total_true): ``needed[d][s]`` is the sorted array
    of global rows shard d reads from shard s (empty for s == d), H the
    max list length (the static padded width), total_true the sum of all
    list lengths (the unpadded halo volume, for accounting).
    """
    needed = []
    H = 0
    total = 0
    for d in range(n_shards):
        cols_d = np.unique(ell_cols[d])
        owner = cols_d // R
        per_src = []
        for s in range(n_shards):
            rows_s = cols_d[owner == s] if s != d else np.empty(0, np.int64)
            per_src.append(rows_s.astype(np.int64))
            H = max(H, len(rows_s))
            total += len(rows_s)
        needed.append(per_src)
    return needed, H, total


def _remap_local(ell_cols: np.ndarray, needed, n_shards: int, R: int,
                 H: int) -> np.ndarray:
    """Rewrite global column ids into each shard's extended-local space:
    local rows keep [0, R); the h-th row needed from shard s lands at
    R + s*H + h — exactly where the all_to_all deposits it."""
    out = np.empty_like(ell_cols)
    for d in range(n_shards):
        c = ell_cols[d].astype(np.int64)
        o = c // R
        loc = c - d * R
        for s in range(n_shards):
            if s == d:
                continue
            m = o == s
            if not m.any():
                continue
            pos = np.searchsorted(needed[d][s], c[m])
            loc[m] = R + s * H + pos
        out[d] = loc.astype(np.int32)
    return out


def _send_plan(needed, n_shards: int, R: int, H: int) -> np.ndarray:
    """(S, S*H) send plan: row block d of sender s lists the *local* row
    ids s ships to d (pad slots resend row 0 — recipients never read
    them, their remap stops at the true list length)."""
    send = np.zeros((n_shards, n_shards * H), np.int32)
    for d in range(n_shards):
        for s in range(n_shards):
            rows = needed[d][s]
            send[s, d * H:d * H + len(rows)] = rows - s * R
    return send


def _build_dist_sellcs(ell_cols_x: np.ndarray, ell_vals: np.ndarray,
                       counts: np.ndarray, C: int) -> DistSellCS:
    """Per-shard SELL-C slicing of the partitioned ELL arrays.

    ``ell_cols_x`` is already in the execution index space (extended-
    local for halo plans, global for gather plans); ``counts`` holds the
    true per-row entry count (S, R) so pads are dropped, not repacked.
    Widths are maxed across shards per slice index, keeping every run
    shape identical on all shards (the SPMD requirement).
    """
    S, R, M = ell_cols_x.shape
    C = max(int(C), 1)
    n_slices = -(-R // C)
    R_pad = n_slices * C

    orders = np.empty((S, R_pad), np.int64)
    widths = np.empty((S, n_slices), np.int64)
    for d in range(S):
        cnt = np.full(R_pad, -1, np.int64)
        cnt[:R] = counts[d]
        order = np.argsort(-cnt, kind="stable")    # σ = R: whole-shard sort
        orders[d] = order
        widths[d] = np.maximum(
            cnt[order].reshape(n_slices, C).max(axis=1), 1)
    slice_w = widths.max(axis=0)                   # cross-shard max per slice
    run_bounds = np.concatenate(
        [[0], np.flatnonzero(np.diff(slice_w)) + 1, [n_slices]])

    run_cols, run_vals, run_own = [], [], []
    for r in range(len(run_bounds) - 1):
        s0, s1 = int(run_bounds[r]), int(run_bounds[r + 1])
        w = int(slice_w[s0])
        rows_r = (s1 - s0) * C
        cols_r = np.empty((S, rows_r, w), np.int32)
        vals_r = np.zeros((S, rows_r, w), ell_vals.dtype)
        own_r = np.zeros((S, rows_r), np.int32)
        slot = np.arange(w)[None, :]
        for d in range(S):
            sel = orders[d, s0 * C:s1 * C]         # packed rows of this run
            real = sel < R
            safe = np.where(real, sel, 0)
            deg = np.where(real, counts[d][safe], 0)
            keep = slot < deg[:, None]
            cw = ell_cols_x[d][safe, :w] if w <= M else np.pad(
                ell_cols_x[d][safe], ((0, 0), (0, w - M)))
            vw = ell_vals[d][safe, :w] if w <= M else np.pad(
                ell_vals[d][safe], ((0, 0), (0, w - M)))
            own = np.where(real, sel, 0).astype(np.int32)
            cols_r[d] = np.where(keep, cw, own[:, None])
            vals_r[d] = np.where(keep, vw, 0)
            own_r[d] = own
        run_cols.append(jnp.asarray(cols_r))
        run_vals.append(jnp.asarray(vals_r))
        run_own.append(jnp.asarray(own_r))

    inv = np.empty((S, R_pad), np.int64)
    for d in range(S):
        inv[d, orders[d]] = np.arange(R_pad)
    return DistSellCS(run_cols=tuple(run_cols), run_vals=tuple(run_vals),
                      run_own=tuple(run_own),
                      inv=jnp.asarray(inv[:, :R], jnp.int32),
                      sell_c=C, n_pad_local=R_pad)


def make_row_partition(A: SparseMatrix, n_shards: int,
                       assignment: Optional[np.ndarray] = None, *,
                       mode: str = "auto",
                       halo_threshold: float = HALO_FALLBACK_FRAC,
                       sellcs: bool = False,
                       sell_c: int = 32) -> RowPartitionedMatrix:
    """Split A's ELL rows into n_shards contiguous blocks and precompute
    the halo-exchange plan (all host-side).

    If ``assignment`` (a cluster id per row, e.g. from p-spectral
    clustering) is given, rows are permuted so same-cluster rows are
    contiguous — the halo then holds only cut rows.  The permutation is
    internal to the layout: ``shard_mxm`` takes and returns vectors in
    the original row space.

    ``mode``: "auto" builds the halo plan and falls back to the gather
    schedule when the padded halo width exceeds ``halo_threshold * R``
    (it would move more bytes than the gather it replaces); "halo" /
    "gather" force a schedule — the bench uses this to measure both.
    ``sellcs=True`` adds the per-shard SELL-C-σ slicing (DistSellCS).
    """
    assert A.ell_cols is not None, "build_ell=True required"
    if mode not in ("auto", "halo", "gather"):
        raise ValueError(f"mode must be auto|halo|gather, got {mode!r}")
    ell_cols = np.asarray(A.ell_cols)
    ell_vals = np.asarray(A.ell_vals)
    n, m = ell_cols.shape
    square = A.n_rows == A.n_cols
    perm = inv = None
    if assignment is not None:
        if not square:
            raise ValueError(
                "graph-aware placement permutes rows and columns with one "
                "permutation and requires a square operator")
        perm = np.argsort(np.asarray(assignment), kind="stable")
        inv = np.empty_like(perm)
        inv[perm] = np.arange(n)
        # permute rows AND remap column ids into the permuted numbering,
        # so the partitioned operator acts on the permuted vector space
        ell_cols, ell_vals = inv[ell_cols[perm]].astype(np.int32), ell_vals[perm]
    pad = (-n) % n_shards
    if pad:
        # padded rows reference THEMSELVES with weight 0 (no-ops that
        # stay shard-local — referencing column 0, as the pre-halo code
        # did, would drag row 0 into every shard's halo)
        self_cols = np.repeat(np.arange(n, n + pad, dtype=np.int32)[:, None],
                              m, axis=1)
        ell_cols = np.concatenate([ell_cols, self_cols])
        ell_vals = np.concatenate([ell_vals, np.zeros((pad, m), ell_vals.dtype)])
    R = (n + pad) // n_shards
    ell_cols = ell_cols.reshape(n_shards, R, m)
    ell_vals = ell_vals.reshape(n_shards, R, m)

    # true per-row entry counts in partitioned order (pads excluded) —
    # the sellcs slicer sorts on these, not on the padded ELL width
    counts = None
    if sellcs:
        counts = np.bincount(A.host_coo()[0], minlength=n)
        if perm is not None:
            counts = counts[perm]
        counts = np.concatenate(
            [counts, np.zeros(pad, counts.dtype)]).reshape(n_shards, R)

    use_halo = square and n_shards > 1 and mode != "gather"
    H = total = 0
    if use_halo:
        needed, H, total = _halo_plan(ell_cols, n_shards, R)
        if mode == "auto" and H > halo_threshold * R:
            use_halo = False
            # the silent degradation PR 5 added — make it observable:
            # a partition that planned a halo but shipped the gather
            _obs_metrics.DEFAULT.counter("dist_gather_fallback_total").inc()
            _obs_trace.ACTIVE.instant(
                "dist.gather_fallback", n=A.n_rows, n_shards=n_shards,
                halo_width=int(H), rows_per_shard=int(R))
    if use_halo:
        cols_local = _remap_local(ell_cols, needed, n_shards, R, H)
        Ap = RowPartitionedMatrix(
            ell_cols=jnp.asarray(cols_local), ell_vals=jnp.asarray(ell_vals),
            n_rows=A.n_rows, n_cols=A.n_cols, n_shards=n_shards,
            perm=perm, inv_perm=inv, mode="halo", halo_width=H,
            send_idx=jnp.asarray(_send_plan(needed, n_shards, R, H)),
            halo_rows_true=total)
        cols_x = cols_local
    else:
        if mode == "halo":
            raise ValueError(
                "mode='halo' requires a square operator and n_shards > 1 "
                "(the halo plan partitions one row == column space)")
        # an auto fallback keeps the computed (H, total) so wire_bytes
        # still reports what the rejected halo WOULD have moved; a
        # forced mode="gather" never computes the plan (H stays 0)
        Ap = RowPartitionedMatrix(
            ell_cols=jnp.asarray(ell_cols), ell_vals=jnp.asarray(ell_vals),
            n_rows=A.n_rows, n_cols=A.n_cols, n_shards=n_shards,
            perm=perm, inv_perm=inv, mode="gather", halo_width=H,
            halo_rows_true=total)
        cols_x = ell_cols
    if sellcs:
        Ap.sell = _build_dist_sellcs(cols_x, ell_vals, counts, sell_c)
    return Ap


# ----------------------------------------------------------------- execution

# Fault-injection seam (repro.testing.faultinject, DESIGN.md §9): when
# set, the hook rewrites the received halo block inside the shard-mapped
# exchange — fn(recv, Ap) -> recv, jnp ops only (it runs traced).  Used
# by the chaos suite to model corrupted / dropped halo rows; production
# leaves it None.
_HALO_FAULT_HOOK = None


def set_halo_fault_hook(hook) -> None:
    global _HALO_FAULT_HOOK
    _HALO_FAULT_HOOK = hook


def _exchange(Ap: RowPartitionedMatrix, x_local, send_idx, axis: str):
    """The shard-local halo exchange: gather the rows this shard owes
    every peer, one tiled all_to_all, append the received halo."""
    if Ap.halo_width == 0:
        return x_local
    xs = x_local[send_idx]                    # (S*H, k) send buffer
    recv = jax.lax.all_to_all(xs, axis, split_axis=0, concat_axis=0,
                              tiled=True)     # block s = rows from shard s
    if _HALO_FAULT_HOOK is not None:
        recv = _HALO_FAULT_HOOK(recv, Ap)
    return jnp.concatenate([x_local, recv], axis=0)


def shard_mxm(Ap: RowPartitionedMatrix, X: jnp.ndarray, mesh,
              axis: str = "data",
              ring: Semiring | EdgeSemiring = reals_ring,
              layout: str = "ell") -> jnp.ndarray:
    """Distributed SpMM: rows sharded over ``axis``, halo rows exchanged
    per shard (or the full X gathered under a fallback plan).

    The execute hook of the "dist" / "dist_sellcs" backends
    (grblas.backends).  X: (n_cols,) or (n_cols, k) in the ORIGINAL row
    space — any placement permutation is applied internally and the
    output is returned un-permuted (pads sliced first), so dist ==
    single-device numerically for every plan.
    """
    S, R = Ap.n_shards, Ap.rows_per_shard
    if int(mesh.shape[axis]) != S:
        raise ValueError(
            f"partition was built for {S} shards but mesh axis {axis!r} "
            f"has size {int(mesh.shape[axis])}: rebuild with "
            f"make_row_partition(A, {int(mesh.shape[axis])})")
    tr = _obs_trace.ACTIVE
    if tr.enabled and not _obs_trace.under_trace(X):
        k_eff = int(X.shape[1]) if X.ndim > 1 else 1
        wb = Ap.wire_bytes(k_eff)
        wire = int(wb["halo"] if Ap.mode == "halo" else wb["gather"])
        with tr.span("dist.shard_mxm", cat="dist", mode=Ap.mode,
                     n=Ap.n_rows, n_shards=S, k=k_eff,
                     halo_width=int(Ap.halo_width), wire_bytes=wire,
                     layout=layout) as sp:
            out = _shard_mxm_impl(Ap, X, mesh, axis, ring, layout, S, R)
            sp.fence(out)
        _obs_metrics.DEFAULT.counter("dist_wire_bytes_total",
                                     mode=Ap.mode).inc(wire)
        _obs_metrics.DEFAULT.counter("dist_shard_mxm_total",
                                     mode=Ap.mode).inc()
        return out
    return _shard_mxm_impl(Ap, X, mesh, axis, ring, layout, S, R)


def _shard_mxm_impl(Ap, X, mesh, axis, ring, layout, S, R):
    n_pad = S * R
    edge = isinstance(ring, EdgeSemiring)
    one_d = X.ndim == 1
    if one_d:
        X = X[:, None]
    if Ap.perm is not None:
        X = X[Ap.perm]
    # pad to a multiple of S; gather-mode X is n_cols long (rectangular
    # reals), halo-mode X is n (square) — both pad up to >= the index
    # range the column ids touch
    L = n_pad if Ap.mode == "halo" else max(-(-X.shape[0] // S) * S, n_pad)
    if X.shape[0] != L:
        X = jnp.pad(X, ((0, L - X.shape[0]), (0, 0)))
    vec_spec = P(axis, None)
    plan_spec = P(axis, None)
    mat_spec = P(axis, None, None)

    if layout == "sellcs":
        if Ap.sell is None:
            raise ValueError(
                "this RowPartitionedMatrix was built without the per-shard "
                "SELL-C-σ layout: pass sellcs=True to make_row_partition")
        out = _shard_sellcs(Ap, X, mesh, axis, ring, edge,
                            vec_spec, plan_spec)
    elif layout == "ell":
        out = _shard_ell(Ap, X, mesh, axis, ring, edge,
                         vec_spec, plan_spec, mat_spec, L)
    else:
        raise ValueError(f"layout must be ell|sellcs, got {layout!r}")

    out = out[: Ap.n_rows]                    # slice pads FIRST …
    if Ap.inv_perm is not None:
        out = out[Ap.inv_perm]                # … then un-permute
    return out[:, 0] if one_d else out


def _shard_ell(Ap, X, mesh, axis, ring, edge, vec_spec, plan_spec,
               mat_spec, L):
    halo = Ap.mode == "halo"

    def local(ell_cols, ell_vals, x_local, *plan):
        ell_cols = ell_cols[0]                            # (R, M) this shard
        ell_vals = ell_vals[0]
        if halo:
            x_src = _exchange(Ap, x_local, plan[0][0], axis)
        else:
            x_src = jax.lax.all_gather(x_local, axis, axis=0, tiled=True)
        gathered = x_src[ell_cols]                        # (R, M, k)
        vals = ell_vals[..., None]
        if edge:
            # x_i is this shard's own rows — x_local directly (edge
            # rings are square-gated, so the row and column spaces and
            # their paddings coincide)
            contrib = ring.edge_mul(vals, gathered, x_local[:, None, :])
        else:
            contrib = ring.mul(vals, gathered)
        # pscheck: disable=pad-fold (pad slots carry val=0 and every ring the dist backends admit via _dist_supports annihilates zero contributions, so the width-axis fold is pad-sound by the capability gate)
        return jnp.sum(contrib, axis=1)

    args = [Ap.ell_cols, Ap.ell_vals, X]
    specs = [mat_spec, mat_spec, vec_spec]
    if halo:
        args.append(Ap.send_idx)
        specs.append(plan_spec)
    fn = shard_map(local, mesh=mesh, in_specs=tuple(specs),
                   out_specs=vec_spec, check_vma=False)
    return fn(*args)


def _shard_sellcs(Ap, X, mesh, axis, ring, edge, vec_spec, plan_spec):
    from repro.kernels.sellcs_spmm.ref import (
        sellcs_shard_plap_apply_ref, sellcs_shard_spmm_ref)

    sell = Ap.sell
    halo = Ap.mode == "halo"
    n_runs = len(sell.run_cols)

    def local(x_local, inv, *rest):
        if halo:
            x_src = _exchange(Ap, x_local, rest[0][0], axis)
            rest = rest[1:]
        else:
            x_src = jax.lax.all_gather(x_local, axis, axis=0, tiled=True)
        cols = rest[:n_runs]
        vals = rest[n_runs:2 * n_runs]
        own = rest[2 * n_runs:]
        outs = []
        for c, v, o in zip(cols, vals, own):
            if edge:
                p, eps = ring.params
                outs.append(sellcs_shard_plap_apply_ref(
                    c[0], v[0], x_src, x_local[o[0]], p, eps))
            elif ring.name == "reals_+x":
                outs.append(sellcs_shard_spmm_ref(c[0], v[0], x_src))
            else:
                vb = v[0][..., None]
                outs.append(fast_paths(ring).padded(ring.mul(vb, x_src[c[0]])))
        return jnp.concatenate(outs, axis=0)[inv[0]]      # back to local order

    args = [X, sell.inv]
    specs = [vec_spec, plan_spec]
    if halo:
        args.append(Ap.send_idx)
        specs.append(plan_spec)
    args += list(sell.run_cols) + list(sell.run_vals) + list(sell.run_own)
    specs += ([P(axis, None, None)] * 2 * n_runs + [plan_spec] * n_runs)
    fn = shard_map(local, mesh=mesh, in_specs=tuple(specs),
                   out_specs=vec_spec, check_vma=False)
    return fn(*args)


# ------------------------------------------------------------- launch path

def is_distributed_initialized() -> bool:
    """Whether jax.distributed has been initialized in this process."""
    try:
        from jax._src import distributed as _dst
        return _dst.global_state.client is not None
    except Exception:
        return False


def init_distributed(coordinator_address: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None) -> bool:
    """Guarded ``jax.distributed.initialize`` for multi-process meshes.

    Resolves the coordinator triple from the arguments or the standard
    environment (JAX_COORDINATOR_ADDRESS / JAX_NUM_PROCESSES /
    JAX_PROCESS_ID) and initializes once.  Single-process launches (no
    coordinator configured, or num_processes <= 1) and already-
    initialized processes are no-ops — returns True iff this call
    performed the initialization, so the same entry point serves the
    one-host dev loop and a real multi-host launch.
    """
    if is_distributed_initialized():
        return False
    coordinator_address = coordinator_address or os.environ.get(
        "JAX_COORDINATOR_ADDRESS")
    if num_processes is None and "JAX_NUM_PROCESSES" in os.environ:
        num_processes = int(os.environ["JAX_NUM_PROCESSES"])
    if process_id is None and "JAX_PROCESS_ID" in os.environ:
        process_id = int(os.environ["JAX_PROCESS_ID"])
    if coordinator_address is None or not num_processes or num_processes <= 1:
        return False
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def device_mesh(axis: str = "data", n_shards: Optional[int] = None):
    """1-D mesh over the (global) device set for the dist backends.

    Calls ``init_distributed`` first so a multi-process launch sees the
    full device set; single-process it is just ``make_mesh`` over the
    local devices (e.g. the forced host devices of the tests/bench).
    """
    init_distributed()
    n = n_shards if n_shards is not None else len(jax.devices())
    return compat.make_mesh((n,), (axis,))
