"""Algebraic containers: sparse matrices in JAX-friendly layouts.

A ``SparseMatrix`` carries up to four layouts of the same matrix:

  * COO    (rows, cols, vals)           — construction + segment-sum SpMV
  * CSR    (indptr, cols, vals)         — host-side utilities / export
  * ELL    (ell_cols, ell_vals, pad)    — padded rows, vectorized gather SpMV
  * SELL-C-σ (per-slice padded chunks)  — sliced ELLPACK with σ-window row
                                          sorting: rows are degree-sorted
                                          inside windows of σ rows, cut into
                                          slices of C rows, and each slice is
                                          padded only to its OWN max degree
                                          (Kreutzer/Hager/Wellein/Alappat).
                                          Kills the hub-row blowup of full
                                          ELL on skewed-degree graphs.
  * BSR    (block ptrs/idx, dense tiles)— 128x128 dense tiles for the MXU
                                          Pallas kernel (kernels/bsr_spmm)

All device arrays are static-shaped so every op jits.  Construction is
host-side (numpy/scipy); the resulting container is a pytree of jnp
arrays and can be donated/sharded.

SELL-C-σ storage model
----------------------
The σ-sort produces a row permutation ``sell_perm`` (permuted position →
original row; ``sell_inv`` is its inverse).  Slices of equal padded
width are contiguous after the sort, so the layout is stored as a tuple
of *width runs*: run r holds ``sell_cols[r]`` / ``sell_vals[r]`` of
shape (rows_r, w_r) with rows_r a multiple of C.  Column indices live in
the PERMUTED index space (the executor permutes the multivector once,
streams contiguously, and un-permutes the output — provably transparent
to callers).  Pad entries point at the row itself with value 0, the same
pad-soundness contract as ELL.  ``sell_scatter[r]`` maps each stored
slot back to its COO nnz index (pads → nnz), which is how ``with_vals``
rebuilds the packed values on-device without re-running the host build.
Slice pointers (run row offsets / widths) are static aux metadata, so
every run shape is known at trace time.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

# Auto-build / auto-dispatch threshold: when full-ELL padding would store
# more than this multiple of nnz, from_coo builds the SELL-C-σ layout as
# well and backend auto-selection prefers it over ELL (grblas.backends).
SELLCS_AUTO_THRESHOLD = 4.0


class GraphFingerprint(NamedTuple):
    """Identity of a weighted graph for the serve-layer warm cache
    (DESIGN.md §8): shape, a digest of the sparsity pattern, and a
    digest of the *quantized* weights.  Two graphs with the same pattern
    but different weights share ``pattern_key`` (warm-startable from the
    cached embedding via ``with_vals``) while their full ``key`` differs
    (the cached labels are NOT valid for them)."""

    n: int
    nnz: int
    pattern: str        # blake2b digest of (n, n_cols, rows, cols)
    weights: str        # blake2b digest of round(vals / weight_quant)

    @property
    def key(self) -> tuple:
        return (self.n, self.nnz, self.pattern, self.weights)

    @property
    def pattern_key(self) -> tuple:
        return (self.n, self.nnz, self.pattern)


def _row_layout(rows, n_rows: int, nnz: int):
    """(counts, pos_in_row) for a (row, col)-sorted COO triple — the
    shared inputs of the ELL and SELL-C-σ builders, computed once per
    construction (two O(nnz) host passes)."""
    counts = np.bincount(rows, minlength=max(n_rows, 1))
    pos_in_row = np.arange(nnz) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    return counts, pos_in_row


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseMatrix:
    n_rows: int
    n_cols: int
    nnz: int
    # COO (always present, sorted by row then col)
    rows: jnp.ndarray  # (nnz,) int32
    cols: jnp.ndarray  # (nnz,) int32
    vals: jnp.ndarray  # (nnz,) dtype
    # ELL (optional)
    ell_cols: Optional[jnp.ndarray] = None  # (n_rows, max_nnz) int32, pad=row i itself
    ell_vals: Optional[jnp.ndarray] = None  # (n_rows, max_nnz) dtype, pad=0
    # BSR (optional, block = bs x bs dense tiles)
    block_size: int = 0
    bsr_indptr: Optional[np.ndarray] = None   # host (n_row_blocks+1,) — static metadata
    bsr_indices: Optional[jnp.ndarray] = None  # (n_blocks,) int32 col-block ids
    bsr_blocks: Optional[jnp.ndarray] = None   # (n_blocks, bs, bs) dtype
    bsr_row_ids: Optional[jnp.ndarray] = None  # (n_blocks,) int32 row-block ids
    # SELL-C-σ (optional) — see module docstring for the storage model
    sell_c: int = 0                 # slice height C (static)
    sell_sigma: int = 0             # sorting-window size σ (static)
    sell_w_align: int = 1           # slice-width rounding (static): >1
                                    # merges nearby widths into fewer
                                    # runs (fewer kernel launches) at a
                                    # small fill cost
    sell_n_pad: int = 0             # n_rows rounded up to a multiple of C
    sell_row0: Tuple[int, ...] = ()  # static first-row offset of each width run
    sell_perm: Optional[jnp.ndarray] = None     # (n_pad,) int32 pos -> orig row
    sell_inv: Optional[jnp.ndarray] = None      # (n_rows,) int32 orig row -> pos
    sell_cols: Optional[Tuple[jnp.ndarray, ...]] = None  # per run (rows_r, w_r) int32, permuted space
    sell_vals: Optional[Tuple[jnp.ndarray, ...]] = None  # per run (rows_r, w_r[, k]) dtype
    sell_scatter: Optional[Tuple[jnp.ndarray, ...]] = None  # per run (rows_r, w_r) int32 -> nnz idx (pad=nnz)

    # ---- pytree protocol ----
    def tree_flatten(self):
        children = (self.rows, self.cols, self.vals, self.ell_cols,
                    self.ell_vals, self.bsr_indices, self.bsr_blocks,
                    self.bsr_row_ids, self.sell_perm, self.sell_inv,
                    self.sell_cols, self.sell_vals, self.sell_scatter)
        aux = (self.n_rows, self.n_cols, self.nnz, self.block_size,
               None if self.bsr_indptr is None else tuple(self.bsr_indptr.tolist()),
               self.sell_c, self.sell_sigma, self.sell_w_align,
               self.sell_n_pad, self.sell_row0)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (rows, cols, vals, ell_cols, ell_vals, bsr_indices, bsr_blocks,
         bsr_row_ids, sell_perm, sell_inv, sell_cols, sell_vals,
         sell_scatter) = children
        (n_rows, n_cols, nnz, block_size, indptr,
         sell_c, sell_sigma, sell_w_align, sell_n_pad, sell_row0) = aux
        return cls(n_rows=n_rows, n_cols=n_cols, nnz=nnz, rows=rows, cols=cols,
                   vals=vals, ell_cols=ell_cols, ell_vals=ell_vals,
                   block_size=block_size,
                   bsr_indptr=None if indptr is None else np.asarray(indptr, np.int64),
                   bsr_indices=bsr_indices, bsr_blocks=bsr_blocks,
                   bsr_row_ids=bsr_row_ids,
                   sell_c=sell_c, sell_sigma=sell_sigma,
                   sell_w_align=sell_w_align,
                   sell_n_pad=sell_n_pad, sell_row0=sell_row0,
                   sell_perm=sell_perm, sell_inv=sell_inv,
                   sell_cols=sell_cols, sell_vals=sell_vals,
                   sell_scatter=sell_scatter)

    # ---- constructors ----
    @staticmethod
    def from_coo(rows, cols, vals, shape: Tuple[int, int],
                 build_ell: Optional[bool] = None, build_bsr: bool = False,
                 block_size: int = 128, dtype=jnp.float32,
                 build_sellcs: Optional[bool] = None, sell_c: int = 32,
                 sell_sigma: Optional[int] = None,
                 sell_w_align: int = 1) -> "SparseMatrix":
        """``build_sellcs=None`` (auto) builds the SELL-C-σ layout exactly
        when full-ELL padding would exceed SELLCS_AUTO_THRESHOLD x nnz —
        the skewed-degree regime where the hub rows make ELL unusable.
        ``build_ell=None`` (auto) builds ELL except in that same regime:
        allocating the (n, hub_degree) dense blocks only to have every
        dispatch prefer the sliced layout is pure dead storage (~GBs at
        the paper's 8M-node scale).  Pass ``build_ell=True`` to force it
        (e.g. for the "dist" backend, which shards the ELL layout)."""
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        n_rows, n_cols = shape
        nnz = len(vals)

        mat = SparseMatrix(
            n_rows=n_rows, n_cols=n_cols, nnz=nnz,
            rows=jnp.asarray(rows, jnp.int32),
            cols=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(vals, dtype),
        )
        counts = pos_in_row = None
        if build_ell is not False or build_sellcs is not False:
            counts, pos_in_row = _row_layout(rows, n_rows, nnz)
            predicted_ell = n_rows * max(int(counts.max()) if nnz else 0, 1)
            ell_blown_up = (nnz > 0
                            and predicted_ell > SELLCS_AUTO_THRESHOLD * nnz)
            if build_sellcs is None:
                # the sliced layout permutes row and column space with ONE
                # permutation, so it only represents square matrices
                build_sellcs = ell_blown_up and n_rows == n_cols
            if build_ell is None:
                build_ell = not (ell_blown_up and build_sellcs)
        if build_ell:
            mat._build_ell(rows, cols, vals, dtype, counts, pos_in_row)
        if build_bsr:
            mat._build_bsr(rows, cols, vals, block_size, dtype)
        if build_sellcs and n_rows > 0:
            mat._build_sellcs(rows, cols, vals, sell_c, sell_sigma, dtype,
                              w_align=sell_w_align, counts=counts,
                              pos_in_row=pos_in_row)
        return mat

    @staticmethod
    def from_scipy(sp, build_ell: Optional[bool] = None,
                   build_bsr: bool = False,
                   block_size: int = 128, dtype=jnp.float32,
                   build_sellcs: Optional[bool] = None, sell_c: int = 32,
                   sell_sigma: Optional[int] = None,
                   sell_w_align: int = 1) -> "SparseMatrix":
        sp = sp.tocoo()
        return SparseMatrix.from_coo(sp.row, sp.col, sp.data, sp.shape,
                                     build_ell=build_ell, build_bsr=build_bsr,
                                     block_size=block_size, dtype=dtype,
                                     build_sellcs=build_sellcs, sell_c=sell_c,
                                     sell_sigma=sell_sigma,
                                     sell_w_align=sell_w_align)

    # ---- layout builders (host-side) ----
    def _build_ell(self, rows, cols, vals, dtype, counts=None,
                   pos_in_row=None):
        n = self.n_rows
        if counts is None:
            counts, pos_in_row = _row_layout(rows, n, len(rows))
        max_nnz = max(int(counts.max()) if n else 0, 1)
        # allocate in the final on-device dtypes directly: no float64
        # staging array and no full (n, max_nnz) int64 temporary — at
        # 8M-node scale those transients dominated peak host memory.
        ell_cols = np.empty((n, max_nnz), np.int32)
        ell_cols[:] = np.arange(n, dtype=np.int32)[:, None]  # pad = row itself
        ell_vals = np.zeros((n, max_nnz), np.dtype(dtype))
        ell_cols[rows, pos_in_row] = cols
        ell_vals[rows, pos_in_row] = vals
        self.ell_cols = jnp.asarray(ell_cols)
        self.ell_vals = jnp.asarray(ell_vals)

    def _build_bsr(self, rows, cols, vals, bs, dtype):
        n_rb = -(-self.n_rows // bs)
        rb, cb = rows // bs, cols // bs
        keys = rb * n_rb * 0 + rb  # row-block major ordering
        block_key = rb.astype(np.int64) * (-(-self.n_cols // bs)) + cb
        uniq, inv = np.unique(block_key, return_inverse=True)
        n_blocks = len(uniq)
        blocks = np.zeros((n_blocks, bs, bs), np.float64)
        blocks[inv, rows % bs, cols % bs] = vals
        u_rb = (uniq // (-(-self.n_cols // bs))).astype(np.int64)
        u_cb = (uniq % (-(-self.n_cols // bs))).astype(np.int64)
        indptr = np.zeros(n_rb + 1, np.int64)
        np.add.at(indptr, u_rb + 1, 1)
        indptr = np.cumsum(indptr)
        self.block_size = bs
        self.bsr_indptr = indptr
        self.bsr_indices = jnp.asarray(u_cb, jnp.int32)
        self.bsr_blocks = jnp.asarray(blocks, dtype)
        self.bsr_row_ids = jnp.asarray(u_rb, jnp.int32)
        _ = keys

    def _build_sellcs(self, rows, cols, vals, C: int, sigma: Optional[int],
                      dtype, w_align: int = 1, counts=None, pos_in_row=None):
        """SELL-C-σ: σ-window degree sort, C-row slices, per-slice padding.

        ``sigma=None`` sorts globally (maximum fill reduction; sound
        because the permutation is internal to the layout and undone on
        output).  ``w_align`` rounds slice widths up — >1 merges nearby
        widths into fewer runs (fewer kernel launches) at a small fill
        cost.  Requires the COO triple sorted by (row, col), which
        from_coo guarantees.
        """
        if self.n_rows != self.n_cols:
            raise ValueError(
                "SELL-C-σ permutes row and column space with one "
                f"permutation and requires a square matrix, got "
                f"({self.n_rows}, {self.n_cols})")
        n = self.n_rows
        nnz = len(vals)
        C = max(int(C), 1)
        if counts is None:
            counts, pos_in_row = _row_layout(rows, n, nnz)
        counts = counts.astype(np.int64)
        sigma_eff = n if sigma is None else max(int(sigma), 1)

        # σ-window stable degree sort (descending): hubs cluster into the
        # same slices so only their slices pay their width.  One
        # vectorized argsort over (n_windows, σ); the pad key -1 sorts
        # after every real degree so trailing pads drop cleanly.
        n_win = -(-n // sigma_eff)
        counts_pad = np.full(n_win * sigma_eff, -1, np.int64)
        counts_pad[:n] = counts
        order_in_win = np.argsort(-counts_pad.reshape(n_win, sigma_eff),
                                  axis=1, kind="stable")
        perm = (order_in_win
                + np.arange(n_win, dtype=np.int64)[:, None] * sigma_eff
                ).reshape(-1)
        perm = perm[perm < n]
        inv = np.empty(n, np.int64)
        inv[perm] = np.arange(n)

        n_slices = max(-(-n // C), 1)
        n_pad = n_slices * C
        deg_p = np.zeros(n_pad, np.int64)
        deg_p[:n] = counts[perm]
        slice_w = deg_p.reshape(n_slices, C).max(axis=1)
        slice_w = np.maximum(-(-slice_w // w_align) * w_align, 1)

        # contiguous runs of equal-width slices (slice "pointers")
        run_bounds = np.concatenate(
            [[0], np.flatnonzero(np.diff(slice_w)) + 1, [n_slices]])

        # per-nnz placement: permuted row position, slice, within-row
        # slot.  One stable sort by owning slice; each run's entries are
        # then one contiguous segment (no per-run full-nnz masks — those
        # were O(n_runs x nnz) at the 8M-node scale this layout targets).
        i_nnz = inv[rows]                     # permuted position of each entry
        s_nnz = i_nnz // C                    # owning slice
        cols_p = inv[cols]                    # columns in permuted space
        by_slice = np.argsort(s_nnz, kind="stable")
        s_sorted = s_nnz[by_slice]

        run_cols, run_vals, run_scat, run_row0 = [], [], [], []
        np_dtype = np.dtype(dtype)
        for r in range(len(run_bounds) - 1):
            s0, s1 = int(run_bounds[r]), int(run_bounds[r + 1])
            w = int(slice_w[s0])
            row0 = s0 * C
            rows_r = (s1 - s0) * C
            cp = np.empty((rows_r, w), np.int32)
            cp[:] = (row0 + np.arange(rows_r, dtype=np.int32))[:, None]  # pad=self
            vp = np.zeros((rows_r, w), np_dtype)
            sc = np.full((rows_r, w), nnz, np.int32)                     # pad slot
            seg = by_slice[np.searchsorted(s_sorted, s0, "left"):
                           np.searchsorted(s_sorted, s1, "left")]
            cp[i_nnz[seg] - row0, pos_in_row[seg]] = cols_p[seg]
            vp[i_nnz[seg] - row0, pos_in_row[seg]] = vals[seg]
            sc[i_nnz[seg] - row0, pos_in_row[seg]] = seg
            run_cols.append(jnp.asarray(cp))
            run_vals.append(jnp.asarray(vp))
            run_scat.append(jnp.asarray(sc))
            run_row0.append(int(row0))

        perm_pad = np.zeros(n_pad, np.int64)
        perm_pad[:n] = perm                   # phantom rows read X[0]; their
        self.sell_c = C                       # stored vals are 0 so the
        self.sell_sigma = sigma_eff           # contribution annihilates
        self.sell_w_align = max(int(w_align), 1)
        self.sell_n_pad = n_pad
        self.sell_row0 = tuple(run_row0)
        self.sell_perm = jnp.asarray(perm_pad, jnp.int32)
        self.sell_inv = jnp.asarray(inv, jnp.int32)
        self.sell_cols = tuple(run_cols)
        self.sell_vals = tuple(run_vals)
        self.sell_scatter = tuple(run_scat)

    # ---- conveniences ----
    def with_vals(self, vals: jnp.ndarray) -> "SparseMatrix":
        """Same sparsity pattern, new values — GraphBLAS' "new matrix on
        the old structure" (Algorithm 1 builds W-hat this way each Newton
        step).  ``vals`` may be (nnz,) or (nnz, k) *multivalues* (one
        value per stored entry per output column; backends broadcast them
        against an (n, k) multivector).  Derived ELL/BSR layouts are
        dropped (they would be stale), but the SELL-C-σ layout survives:
        its scatter map rebuilds the packed values on-device, so the
        materialized Alg-1 W-hat path runs on the sliced layout too."""
        m = SparseMatrix(n_rows=self.n_rows, n_cols=self.n_cols,
                         nnz=self.nnz, rows=self.rows, cols=self.cols,
                         vals=vals)
        if self.sell_scatter is not None:
            pad = jnp.zeros((1,) + vals.shape[1:], vals.dtype)
            vext = jnp.concatenate([vals, pad], axis=0)   # slot nnz == pad 0
            m.sell_c = self.sell_c
            m.sell_sigma = self.sell_sigma
            m.sell_w_align = self.sell_w_align
            m.sell_n_pad = self.sell_n_pad
            m.sell_row0 = self.sell_row0
            m.sell_perm = self.sell_perm
            m.sell_inv = self.sell_inv
            m.sell_cols = self.sell_cols
            m.sell_scatter = self.sell_scatter
            m.sell_vals = tuple(vext[sc] for sc in self.sell_scatter)
        return m

    def host_coo(self):
        """Host-side (rows, cols, vals) numpy views of the COO triple —
        the input of every host-side plan builder (row partitioning,
        halo plans, spgemm, reorderings).  Raises for traced containers,
        mirroring the backends' loud traced-operand errors."""
        if isinstance(self.rows, jax.core.Tracer):
            raise TypeError(
                "host_coo() needs concrete arrays; this SparseMatrix is "
                "traced — run host-side plan construction outside jit")
        return (np.asarray(self.rows), np.asarray(self.cols),
                np.asarray(self.vals))

    def fingerprint(self, weight_quant: float = 1e-6) -> GraphFingerprint:
        """Graph identity for the serve-layer warm cache: (n, nnz,
        pattern digest, quantized-weight digest).  The pattern digest
        hashes the sorted COO index arrays (from_coo sorts, so equal
        patterns hash equal regardless of input order); weights are
        quantized to ``weight_quant`` before hashing so bit-level float
        noise does not defeat repeat-tenant detection, while any weight
        change ≥ the quantum lands a distinct fingerprint (pinned by
        tests/test_warm_cache.py).  Host-side: raises on traced
        containers, like every other plan-construction input."""
        rows, cols, vals = self.host_coo()
        h = hashlib.blake2b(digest_size=16)
        h.update(np.int64([self.n_rows, self.n_cols]).tobytes())
        h.update(np.ascontiguousarray(rows, np.int32).tobytes())
        h.update(np.ascontiguousarray(cols, np.int32).tobytes())
        pattern = h.hexdigest()
        hw = hashlib.blake2b(digest_size=16)
        q = np.round(np.asarray(vals, np.float64) / weight_quant)
        # non-finite weights (caught downstream by graphs.validate /
        # serve admission) still need a stable digest: map them onto
        # sentinel quanta instead of tripping the int cast
        if not np.isfinite(q).all():
            q = np.nan_to_num(q, nan=np.iinfo(np.int64).min + 1,
                              posinf=np.iinfo(np.int64).max,
                              neginf=np.iinfo(np.int64).min)
        hw.update(q.astype(np.int64).tobytes())
        return GraphFingerprint(n=self.n_rows, nnz=self.nnz,
                                pattern=pattern, weights=hw.hexdigest())

    def padded_coo(self, n_pad: int, nnz_pad: int):
        """Bucket padding for the serve layer: the COO triple padded to
        static dims (n_pad rows, nnz_pad stored entries) so graphs of
        different sizes share one compiled batched solve (DESIGN.md §8).

        Pad entries are (0, 0, 0.0) — they self-reference an existing
        row with weight zero, so every segment fold adds exact zeros
        (the pad-soundness contract the dist backend established); pad
        ROWS [n_rows, n_pad) carry no entries at all, so they are
        isolated vertices the batched solver masks out.  Returns host
        numpy (rows, cols, vals) ready to stack across a batch."""
        if self.n_rows != self.n_cols:
            raise ValueError("bucket padding is defined for square graphs, "
                             f"got ({self.n_rows}, {self.n_cols})")
        if n_pad < self.n_rows or nnz_pad < self.nnz:
            raise ValueError(
                f"bucket ({n_pad}, {nnz_pad}) smaller than graph "
                f"({self.n_rows}, {self.nnz})")
        rows, cols, vals = self.host_coo()
        pad = nnz_pad - self.nnz
        return (np.concatenate([np.asarray(rows, np.int32),
                                np.zeros(pad, np.int32)]),
                np.concatenate([np.asarray(cols, np.int32),
                                np.zeros(pad, np.int32)]),
                np.concatenate([np.asarray(vals),
                                np.zeros(pad, np.asarray(vals).dtype)]))

    def to_dense(self) -> jnp.ndarray:
        d = jnp.zeros((self.n_rows, self.n_cols), self.vals.dtype)
        return d.at[self.rows, self.cols].add(self.vals)

    def row_degrees(self) -> jnp.ndarray:
        return jax.ops.segment_sum(jnp.ones_like(self.vals), self.rows, self.n_rows)

    def row_sums(self) -> jnp.ndarray:
        return jax.ops.segment_sum(self.vals, self.rows, self.n_rows)

    # ---- layout cost metrics (stored-value inflation vs nnz; 1.0 = no
    # padding waste).  Formerly one ambiguous `fill_ratio` property that
    # documented BSR but was reported for ELL in the benches — now one
    # explicit accessor per layout, all recorded in the bench JSONs.
    def ell_fill_ratio(self) -> float:
        """ELL stored values / nnz (global max-degree row padding)."""
        if self.ell_cols is None:
            return float("nan")
        return float(self.ell_cols.shape[0] * self.ell_cols.shape[1]) / max(self.nnz, 1)

    def bsr_fill_ratio(self) -> float:
        """BSR stored values / nnz (dense-tile zero fill)."""
        if self.bsr_blocks is None:
            return float("nan")
        return float(self.bsr_blocks.size) / max(self.nnz, 1)

    def sellcs_fill_ratio(self) -> float:
        """SELL-C-σ stored values / nnz (per-slice width padding only)."""
        if self.sell_cols is None:
            return float("nan")
        stored = sum(c.shape[0] * c.shape[1] for c in self.sell_cols)
        return float(stored) / max(self.nnz, 1)

    @property
    def fill_ratio(self) -> float:
        """Deprecated alias of :meth:`bsr_fill_ratio` (kept one release;
        use the per-layout accessors)."""
        return self.bsr_fill_ratio()
