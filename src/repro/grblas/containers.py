"""Algebraic containers: sparse matrices in JAX-friendly layouts.

A ``SparseMatrix`` carries up to three layouts of the same matrix:

  * COO   (rows, cols, vals)           — construction + segment-sum SpMV
  * CSR   (indptr, cols, vals)         — host-side utilities / export
  * ELL   (ell_cols, ell_vals, pad)    — padded rows, vectorized gather SpMV
  * BSR   (block ptrs/idx, dense tiles)— 128x128 dense tiles for the MXU
                                          Pallas kernel (kernels/bsr_spmm)

All device arrays are static-shaped so every op jits.  Construction is
host-side (numpy/scipy); the resulting container is a pytree of jnp
arrays and can be donated/sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseMatrix:
    n_rows: int
    n_cols: int
    nnz: int
    # COO (always present, sorted by row then col)
    rows: jnp.ndarray  # (nnz,) int32
    cols: jnp.ndarray  # (nnz,) int32
    vals: jnp.ndarray  # (nnz,) dtype
    # ELL (optional)
    ell_cols: Optional[jnp.ndarray] = None  # (n_rows, max_nnz) int32, pad=row i itself
    ell_vals: Optional[jnp.ndarray] = None  # (n_rows, max_nnz) dtype, pad=0
    # BSR (optional, block = bs x bs dense tiles)
    block_size: int = 0
    bsr_indptr: Optional[np.ndarray] = None   # host (n_row_blocks+1,) — static metadata
    bsr_indices: Optional[jnp.ndarray] = None  # (n_blocks,) int32 col-block ids
    bsr_blocks: Optional[jnp.ndarray] = None   # (n_blocks, bs, bs) dtype
    bsr_row_ids: Optional[jnp.ndarray] = None  # (n_blocks,) int32 row-block ids

    # ---- pytree protocol ----
    def tree_flatten(self):
        children = (self.rows, self.cols, self.vals, self.ell_cols,
                    self.ell_vals, self.bsr_indices, self.bsr_blocks,
                    self.bsr_row_ids)
        aux = (self.n_rows, self.n_cols, self.nnz, self.block_size,
               None if self.bsr_indptr is None else tuple(self.bsr_indptr.tolist()))
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, vals, ell_cols, ell_vals, bsr_indices, bsr_blocks, bsr_row_ids = children
        n_rows, n_cols, nnz, block_size, indptr = aux
        return cls(n_rows=n_rows, n_cols=n_cols, nnz=nnz, rows=rows, cols=cols,
                   vals=vals, ell_cols=ell_cols, ell_vals=ell_vals,
                   block_size=block_size,
                   bsr_indptr=None if indptr is None else np.asarray(indptr, np.int64),
                   bsr_indices=bsr_indices, bsr_blocks=bsr_blocks,
                   bsr_row_ids=bsr_row_ids)

    # ---- constructors ----
    @staticmethod
    def from_coo(rows, cols, vals, shape: Tuple[int, int],
                 build_ell: bool = True, build_bsr: bool = False,
                 block_size: int = 128, dtype=jnp.float32) -> "SparseMatrix":
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        n_rows, n_cols = shape
        nnz = len(vals)

        mat = SparseMatrix(
            n_rows=n_rows, n_cols=n_cols, nnz=nnz,
            rows=jnp.asarray(rows, jnp.int32),
            cols=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(vals, dtype),
        )
        if build_ell:
            mat._build_ell(rows, cols, vals, dtype)
        if build_bsr:
            mat._build_bsr(rows, cols, vals, block_size, dtype)
        return mat

    @staticmethod
    def from_scipy(sp, build_ell: bool = True, build_bsr: bool = False,
                   block_size: int = 128, dtype=jnp.float32) -> "SparseMatrix":
        sp = sp.tocoo()
        return SparseMatrix.from_coo(sp.row, sp.col, sp.data, sp.shape,
                                     build_ell=build_ell, build_bsr=build_bsr,
                                     block_size=block_size, dtype=dtype)

    # ---- layout builders (host-side) ----
    def _build_ell(self, rows, cols, vals, dtype):
        n = self.n_rows
        counts = np.bincount(rows, minlength=n)
        max_nnz = max(int(counts.max()) if n else 0, 1)
        ell_cols = np.tile(np.arange(n, dtype=np.int64)[:, None], (1, max_nnz))
        ell_vals = np.zeros((n, max_nnz), np.float64)
        # position of each nnz within its row (rows pre-sorted)
        pos = np.arange(len(rows)) - np.repeat(
            np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
        ell_cols[rows, pos] = cols
        ell_vals[rows, pos] = vals
        self.ell_cols = jnp.asarray(ell_cols, jnp.int32)
        self.ell_vals = jnp.asarray(ell_vals, dtype)

    def _build_bsr(self, rows, cols, vals, bs, dtype):
        n_rb = -(-self.n_rows // bs)
        rb, cb = rows // bs, cols // bs
        keys = rb * n_rb * 0 + rb  # row-block major ordering
        block_key = rb.astype(np.int64) * (-(-self.n_cols // bs)) + cb
        uniq, inv = np.unique(block_key, return_inverse=True)
        n_blocks = len(uniq)
        blocks = np.zeros((n_blocks, bs, bs), np.float64)
        blocks[inv, rows % bs, cols % bs] = vals
        u_rb = (uniq // (-(-self.n_cols // bs))).astype(np.int64)
        u_cb = (uniq % (-(-self.n_cols // bs))).astype(np.int64)
        indptr = np.zeros(n_rb + 1, np.int64)
        np.add.at(indptr, u_rb + 1, 1)
        indptr = np.cumsum(indptr)
        self.block_size = bs
        self.bsr_indptr = indptr
        self.bsr_indices = jnp.asarray(u_cb, jnp.int32)
        self.bsr_blocks = jnp.asarray(blocks, dtype)
        self.bsr_row_ids = jnp.asarray(u_rb, jnp.int32)
        _ = keys

    # ---- conveniences ----
    def with_vals(self, vals: jnp.ndarray) -> "SparseMatrix":
        """Same sparsity pattern, new values — GraphBLAS' "new matrix on
        the old structure" (Algorithm 1 builds W-hat this way each Newton
        step).  ``vals`` may be (nnz,) or (nnz, k) *multivalues* (one
        value per stored entry per output column; the COO backend
        broadcasts them against an (n, k) multivector).  Derived ELL/BSR
        layouts are dropped (they would be stale), so the result always
        executes on the COO backend."""
        return SparseMatrix(n_rows=self.n_rows, n_cols=self.n_cols,
                            nnz=self.nnz, rows=self.rows, cols=self.cols,
                            vals=vals)

    def to_dense(self) -> jnp.ndarray:
        d = jnp.zeros((self.n_rows, self.n_cols), self.vals.dtype)
        return d.at[self.rows, self.cols].add(self.vals)

    def row_degrees(self) -> jnp.ndarray:
        return jax.ops.segment_sum(jnp.ones_like(self.vals), self.rows, self.n_rows)

    def row_sums(self) -> jnp.ndarray:
        return jax.ops.segment_sum(self.vals, self.rows, self.n_rows)

    @property
    def fill_ratio(self) -> float:
        """BSR stored-value inflation vs nnz (1.0 = no padding waste)."""
        if self.bsr_blocks is None:
            return float("nan")
        return float(self.bsr_blocks.size) / max(self.nnz, 1)
