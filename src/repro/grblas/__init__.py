"""grblas — a GraphBLAS-style algebraic layer in JAX.

Mirrors the C++ GraphBLAS concepts the paper builds on:
  * algebraic containers  -> SparseMatrix (CSR / padded-ELL / 128x128 BSR), dense jnp vectors
  * algebraic operators   -> the unified execution API (api.mxm / mxv / vxm):
    one SpMM signature whose Descriptor selects the backend — coo, ell,
    sellcs, bsr_pallas, edge_pallas, dist, dist_sellcs, or spgemm —
    from the registry in backends.py
  * algebraic relations   -> Semiring(add, mul, zero, one), the
    edge-semiring extension for the matrix-free p-Laplacian apply, and
    the pair-edge-semiring for the Newton HVP, with per-ring fast-path
    registration (register_ring_fast_paths).

The distributed layer (dist.py) maps the auto-parallelisation role of
the C++ runtime onto shard_map over a device mesh; it is the "dist" /
"dist_sellcs" backends of the same mxm signature, communicating via a
precomputed halo exchange (only the remote rows each shard's columns
touch) instead of a full all-gather.  See DESIGN.md §3 for the API and
§4 for the halo plan.
"""
from repro.grblas.semiring import (
    Semiring,
    EdgeSemiring,
    PairEdgeSemiring,
    reals_ring,
    min_plus_ring,
    max_times_ring,
    boolean_ring,
    plap_edge_semiring,
    plap_hvp_edge_semiring,
    register_ring_fast_paths,
    fast_paths,
)
from repro.grblas.containers import SELLCS_AUTO_THRESHOLD, SparseMatrix
from repro.grblas.api import (
    Descriptor,
    BackendUnavailableError,
    mxm,
    mxv,
    vxm,
    available_backends,
)
from repro.grblas.backends import register_backend, registered_backends
from repro.grblas.ops import e_wise_apply, apply, reduce as grb_reduce
from repro.grblas.dist import (
    HALO_FALLBACK_FRAC,
    RowPartitionedMatrix,
    device_mesh,
    init_distributed,
    make_row_partition,
    shard_mxm,
)

__all__ = [
    "Semiring", "EdgeSemiring", "PairEdgeSemiring", "reals_ring",
    "min_plus_ring", "max_times_ring", "boolean_ring",
    "plap_edge_semiring", "plap_hvp_edge_semiring",
    "register_ring_fast_paths", "fast_paths",
    "SparseMatrix", "SELLCS_AUTO_THRESHOLD", "Descriptor",
    "BackendUnavailableError",
    "mxm", "mxv", "vxm", "available_backends",
    "register_backend", "registered_backends",
    "e_wise_apply", "apply", "grb_reduce",
    "HALO_FALLBACK_FRAC", "RowPartitionedMatrix", "device_mesh",
    "init_distributed", "make_row_partition", "shard_mxm",
]
