"""grblas — a GraphBLAS-style algebraic layer in JAX.

Mirrors the C++ GraphBLAS concepts the paper builds on:
  * algebraic containers  -> SparseMatrix (CSR / padded-ELL / 128x128 BSR), dense jnp vectors
  * algebraic operators   -> vxm / mxv / mxm (SpMV / SpMM under a semiring)
  * algebraic relations   -> Semiring(add, mul, zero, one), plus the
    edge-semiring extension used for the matrix-free p-Laplacian apply.

The distributed layer (dist.py) maps the auto-parallelisation role of the
C++ runtime onto shard_map over a device mesh.
"""
from repro.grblas.semiring import (
    Semiring,
    EdgeSemiring,
    reals_ring,
    min_plus_ring,
    max_times_ring,
    boolean_ring,
    plap_edge_semiring,
)
from repro.grblas.containers import SparseMatrix
from repro.grblas.ops import vxm, mxv, mxm, e_wise_apply, apply, reduce as grb_reduce
from repro.grblas.dist import dist_mxm, make_row_partition

__all__ = [
    "Semiring", "EdgeSemiring", "reals_ring", "min_plus_ring",
    "max_times_ring", "boolean_ring", "plap_edge_semiring",
    "SparseMatrix", "vxm", "mxv", "mxm", "e_wise_apply", "apply",
    "grb_reduce", "dist_mxm", "make_row_partition",
]
