"""Algebraic operators: vxm / mxv / mxm / eWiseApply / apply / reduce.

Mirrors the grb:: primitives used in the paper's Algorithm 1:

    grb::vxm(v, eta, H, reals_ring)     -> vxm(eta, H, reals_ring)
    grb::eWiseApply(w, eta, D, mul)     -> e_wise_apply(eta, D, mul)
    grb::eWiseApply(res, w, v, sub)     -> e_wise_apply(w, v, sub)

All ops are pure jnp and jit-able.  ``mxm`` handles the n×k multivector
(SpMM) case — the key TPU-side fusion: the paper loops `for l in 1..k`
over k separate SpMVs; here all k columns ride one pass.

Format dispatch: ELL when available (vectorized gather, VPU friendly),
COO segment-sum otherwise (reference path).  The Pallas BSR kernel is
exposed separately in kernels/bsr_spmm/ops.py and is numerically pinned
to these implementations.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.grblas.containers import SparseMatrix
from repro.grblas.semiring import Semiring, EdgeSemiring, reals_ring


def _coo_spmm(A: SparseMatrix, X: jnp.ndarray, ring: Semiring) -> jnp.ndarray:
    """Y[i] = add_j mul(A[i,j], X[j])  via segment reduction over nnz."""
    contrib = ring.mul(A.vals[:, None] if X.ndim == 2 else A.vals, X[A.cols])
    return ring.segment_reduce(contrib, A.rows, A.n_rows)


def _ell_spmm(A: SparseMatrix, X: jnp.ndarray, ring: Semiring) -> jnp.ndarray:
    """Padded-ELL: gather (n, max_nnz[, k]) then reduce along axis 1."""
    gathered = X[A.ell_cols]                      # (n, m[, k])
    vals = A.ell_vals if X.ndim == 1 else A.ell_vals[..., None]
    contrib = ring.mul(vals, gathered)
    if ring.name == "reals_+x":
        return jnp.sum(contrib, axis=1)
    # generic monoid fold over the padded axis
    def fold(carry, x):
        return ring.add(carry, x), None
    init = jnp.full(contrib.shape[:1] + contrib.shape[2:], ring.zero,
                    dtype=contrib.dtype)
    out, _ = jax.lax.scan(fold, init, jnp.moveaxis(contrib, 1, 0))
    return out


def _coo_edge_spmm(A: SparseMatrix, X: jnp.ndarray, ring: EdgeSemiring) -> jnp.ndarray:
    """Y[i] = add_j edge_mul(w_ij, X[j], X[i]) — matrix-free p-Laplacian."""
    contrib = ring.edge_mul(
        A.vals[:, None] if X.ndim == 2 else A.vals, X[A.cols], X[A.rows])
    return ring.base.segment_reduce(contrib, A.rows, A.n_rows)


def mxm(A: SparseMatrix, X: jnp.ndarray,
        ring: Union[Semiring, EdgeSemiring] = reals_ring,
        use_ell: bool = True) -> jnp.ndarray:
    """Sparse × dense multivector (SpMM). X: (n,) or (n,k)."""
    if isinstance(ring, EdgeSemiring):
        return _coo_edge_spmm(A, X, ring)
    # ELL pad entries are (col=row, val=0): no-ops under the reals ring
    # only, so generic monoids take the COO segment-reduce path.
    if use_ell and A.ell_cols is not None and ring.name == "reals_+x":
        return _ell_spmm(A, X, ring)
    return _coo_spmm(A, X, ring)


def mxv(A: SparseMatrix, x: jnp.ndarray, ring=reals_ring) -> jnp.ndarray:
    """y = A (*) x under ring — grb::mxv."""
    return mxm(A, x, ring)


def vxm(x: jnp.ndarray, A: SparseMatrix, ring=reals_ring) -> jnp.ndarray:
    """y = x (*) A under ring — grb::vxm.  For symmetric A (all graph
    Laplacian uses here) this equals mxv; for general A we transpose via
    the COO path (rows<->cols swap)."""
    if isinstance(ring, EdgeSemiring):
        contrib = ring.edge_mul(x.ndim == 2 and A.vals[:, None] or A.vals,
                                x[A.rows], x[A.cols])
        return ring.base.segment_reduce(contrib, A.cols, A.n_cols)
    contrib = ring.mul(A.vals[:, None] if x.ndim == 2 else A.vals, x[A.rows])
    return ring.segment_reduce(contrib, A.cols, A.n_cols)


def e_wise_apply(a: jnp.ndarray, b: jnp.ndarray, op: Callable) -> jnp.ndarray:
    """grb::eWiseApply — elementwise binary op on dense containers."""
    return op(a, b)


def apply(a: jnp.ndarray, op: Callable) -> jnp.ndarray:
    """grb::apply — elementwise unary op."""
    return op(a)


def reduce(a: jnp.ndarray, ring: Semiring = reals_ring, axis=None) -> jnp.ndarray:
    """grb::reduce — fold a dense container under the add-monoid."""
    if ring.name == "reals_+x":
        return jnp.sum(a, axis=axis)
    if ring.name == "min_+":
        return jnp.min(a, axis=axis)
    if ring.name == "max_x":
        return jnp.max(a, axis=axis)
    if ring.name == "bool_|&":
        return jnp.any(a, axis=axis)
    flat = a.ravel() if axis is None else jnp.moveaxis(a, axis, 0)
    def fold(c, x):
        return ring.add(c, x), None
    init = jnp.full(flat.shape[1:] if axis is not None else (), ring.zero, a.dtype)
    out, _ = jax.lax.scan(fold, init, flat)
    return out


@partial(jax.jit, static_argnames=("k",))
def fused_plap_apply(A: SparseMatrix, U: jnp.ndarray, p: float,
                     eps: float = 1e-9, k: int = 1) -> jnp.ndarray:
    """(Delta_p U)_i = sum_j w_ij phi_p(u_i - u_j), all k columns fused."""
    from repro.grblas.semiring import plap_edge_semiring
    return mxm(A, U, plap_edge_semiring(p, eps))
