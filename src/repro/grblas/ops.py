"""Algebraic operators: eWiseApply / apply / reduce + deprecated shims.

The SpMM family (mxm / mxv / vxm) moved to the unified execution API in
``repro.grblas.api`` — one ``mxm(A, X, ring, *, mask, accum, desc)``
signature whose ``Descriptor`` selects the backend (coo / ell /
bsr_pallas / edge_pallas / dist) from the registry in
``repro.grblas.backends``.  The flag-style entry points below
(``use_ell=...``) are kept as thin deprecated shims for one release;
see DESIGN.md §3 for the migration table.

Still current here: the dense elementwise ops (e_wise_apply, apply) and
``reduce``, which now folds under the ring's registered dense fast path
(semiring.register_ring_fast_paths) instead of a name-keyed if-chain,
with a correct generic scan-fold for unregistered monoids.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.grblas import api
from repro.grblas.containers import SparseMatrix
from repro.grblas.semiring import (Semiring, EdgeSemiring, fast_paths,
                                   reals_ring)


def _deprecated(old: str, new: str):
    warnings.warn(
        f"repro.grblas.{old} is deprecated; use {new} "
        f"(see DESIGN.md §3 migration notes)",
        DeprecationWarning, stacklevel=3)


def mxm(A: SparseMatrix, X: jnp.ndarray,
        ring: Union[Semiring, EdgeSemiring] = reals_ring,
        use_ell: bool = True) -> jnp.ndarray:
    """Deprecated shim — use grblas.api.mxm(A, X, ring, desc=Descriptor())."""
    _deprecated("ops.mxm(use_ell=...)", "grblas.api.mxm(..., desc=...)")
    desc = api.Descriptor(backend="auto" if use_ell else "coo")
    return api.mxm(A, X, ring, desc=desc)


def mxv(A: SparseMatrix, x: jnp.ndarray, ring=reals_ring) -> jnp.ndarray:
    """Deprecated shim — use grblas.api.mxv."""
    _deprecated("ops.mxv", "grblas.api.mxv")
    return api.mxv(A, x, ring)


def vxm(x: jnp.ndarray, A: SparseMatrix, ring=reals_ring) -> jnp.ndarray:
    """Deprecated shim — use grblas.api.vxm.

    (The old in-place implementation crashed on 2-D multivectors with an
    edge ring — ``x.ndim == 2 and A.vals[:, None] or A.vals`` is a truth-
    value-ambiguous boolean on arrays; the api COO backend broadcasts
    values properly, regression-tested in tests/test_grblas_api.py.)
    """
    _deprecated("ops.vxm", "grblas.api.vxm")
    return api.vxm(x, A, ring)


def e_wise_apply(a: jnp.ndarray, b: jnp.ndarray, op: Callable) -> jnp.ndarray:
    """grb::eWiseApply — elementwise binary op on dense containers."""
    return op(a, b)


def apply(a: jnp.ndarray, op: Callable) -> jnp.ndarray:
    """grb::apply — elementwise unary op."""
    return op(a)


def reduce(a: jnp.ndarray, ring: Semiring = reals_ring, axis=None) -> jnp.ndarray:
    """grb::reduce — fold a dense container under the add-monoid.

    Registered rings use their dense fast path; unregistered monoids get
    a correct sequential fold under ``ring.add`` from ``ring.zero``.
    """
    fp = fast_paths(ring)
    if fp.dense is not None:
        return fp.dense(a, axis)
    flat = a.ravel() if axis is None else jnp.moveaxis(a, axis, 0)

    def fold(c, x):
        return ring.add(c, x), None

    init = jnp.full(flat.shape[1:] if axis is not None else (), ring.zero, a.dtype)
    out, _ = jax.lax.scan(fold, init, flat)
    return out


@partial(jax.jit, static_argnames=("k",))
def fused_plap_apply(A: SparseMatrix, U: jnp.ndarray, p: float,
                     eps: float = 1e-9, k: int = 1) -> jnp.ndarray:
    """(Delta_p U)_i = sum_j w_ij phi_p(u_i - u_j), all k columns fused."""
    from repro.grblas.semiring import plap_edge_semiring
    return api.mxm(A, U, plap_edge_semiring(p, eps))
