"""Algebraic operators: eWiseApply / apply / reduce.

The SpMM family (mxm / mxv / vxm) lives in the unified execution API
(``repro.grblas.api``) — one ``mxm(A, X, ring, *, mask, accum, desc)``
signature whose ``Descriptor`` selects the backend from the registry in
``repro.grblas.backends``.  The flag-style entry points that used to
live here (``ops.mxm(use_ell=...)`` etc.) were deprecated for one
release and are now deleted; DESIGN.md §3 keeps the migration table.

Still current here: the dense elementwise ops (e_wise_apply, apply) and
``reduce``, which folds under the ring's registered dense fast path
(semiring.register_ring_fast_paths) with a correct generic scan-fold
for unregistered monoids.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.grblas import api
from repro.grblas.containers import SparseMatrix
from repro.grblas.semiring import Semiring, fast_paths, reals_ring


def e_wise_apply(a: jnp.ndarray, b: jnp.ndarray, op: Callable) -> jnp.ndarray:
    """grb::eWiseApply — elementwise binary op on dense containers."""
    return op(a, b)


def apply(a: jnp.ndarray, op: Callable) -> jnp.ndarray:
    """grb::apply — elementwise unary op."""
    return op(a)


def reduce(a: jnp.ndarray, ring: Semiring = reals_ring, axis=None) -> jnp.ndarray:
    """grb::reduce — fold a dense container under the add-monoid.

    Registered rings use their dense fast path; unregistered monoids get
    a correct sequential fold under ``ring.add`` from ``ring.zero``.
    """
    fp = fast_paths(ring)
    if fp.dense is not None:
        return fp.dense(a, axis)
    flat = a.ravel() if axis is None else jnp.moveaxis(a, axis, 0)

    def fold(c, x):
        return ring.add(c, x), None

    init = jnp.full(flat.shape[1:] if axis is not None else (), ring.zero, a.dtype)
    out, _ = jax.lax.scan(fold, init, flat)
    return out


@partial(jax.jit, static_argnames=("k",))
def fused_plap_apply(A: SparseMatrix, U: jnp.ndarray, p: float,
                     eps: float = 1e-9, k: int = 1) -> jnp.ndarray:
    """(Delta_p U)_i = sum_j w_ij phi_p(u_i - u_j), all k columns fused."""
    from repro.grblas.semiring import plap_edge_semiring
    return api.mxm(A, U, plap_edge_semiring(p, eps))
