"""Unified GraphBLAS execution API: descriptor-driven backend dispatch.

One signature for every SpMM-shaped operation in the repo::

    mxm(A, X, ring, *, mask=None, accum=None, desc=None)   # (n,k) or (n,)
    mxv(A, x, ring, ...)                                   # alias of mxm
    vxm(x, A, ring, ...)                                   # transposed mxm

The ``Descriptor`` replaces the old scatter of ``use_ell`` /
``use_pallas`` flags and parallel entry points (ops.mxm,
kernels.bsr_spmm.bsr_spmm, kernels.plap_edge.plap_apply, dist.dist_mxm):

    backend    "auto" | "coo" | "ell" | "sellcs" | "bsr_pallas" |
               "edge_pallas" | "dist" | "dist_sellcs" | "spgemm"
    transpose  operate on A^T (COO index-role swap; vxm flips this)
    interpret  run Pallas kernels in interpreter mode (CPU numerics pin)
    mesh/axis  device mesh + axis name for the "dist"/"dist_sellcs"
               backends (halo-exchange row partition, grblas.dist)

"auto" picks the first capable backend in platform-priority order
(grblas.backends): Pallas kernels first on TPU, SELL-C-σ/ELL/COO first
on CPU ("sellcs" outranks full ELL exactly when the ELL fill ratio
crosses SELLCS_AUTO_THRESHOLD — see DESIGN.md §5), "dist" whenever a
mesh is supplied.  A named backend that cannot execute
the operands raises BackendUnavailableError instead of silently falling
back — layout availability (ELL/BSR built?), ring kind, and multivector
shape are all part of the capability check.

Rings: a plain ``Semiring`` multiplies stored values with gathered
multivector entries; an ``EdgeSemiring`` sees both endpoints (the
p-Laplacian apply); a ``PairEdgeSemiring`` sees two multivectors —
pass ``X=(U, Eta)`` — which is the matrix-free Newton HVP.  The Alg-1
materialized path reuses the same API via
``A.with_vals(what_vals)`` (per-column multivalues on A's pattern).
A SparseMatrix multiplicand makes mxm GraphBLAS' general sparse-sparse
product ("spgemm" backend, reals ring): the result is a new
SparseMatrix — the multilevel subsystem's Galerkin triple product
Pᵀ (W P) is two such calls (DESIGN.md §6).

Write semantics (GraphBLAS C⟨M⟩ ⊙= T, simplified to pure outputs):
``accum=(op, C)`` returns op(C, T); ``mask`` (row mask or full-shape)
keeps masked-in entries and writes the ring's add-identity — or, with
accum, C's old value — elsewhere.  See DESIGN.md §3 for the migration
table from the old entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.grblas import backends as _backends
from repro.grblas.semiring import reals_ring

# re-exported for callers that catch dispatch failures
BackendUnavailableError = _backends.BackendUnavailableError


@dataclasses.dataclass(frozen=True)
class Descriptor:
    """How to execute one GraphBLAS operation (not what it computes)."""

    backend: str = "auto"
    transpose: bool = False
    interpret: bool = False
    mesh: Any = None            # device mesh: enables the dist backends
    axis: str = "data"          # mesh axis the rows are sharded over

    def transposed(self) -> "Descriptor":
        return dataclasses.replace(self, transpose=not self.transpose)


DEFAULT_DESCRIPTOR = Descriptor()


def mxm(A, X, ring=reals_ring, *, mask=None, accum=None,
        desc: Optional[Descriptor] = None):
    """Sparse x dense multivector (SpMM) under ``ring``.

    X: (n,) or (n, k) — or a pair (U, Eta) for a PairEdgeSemiring — or a
    SparseMatrix, in which case this is GraphBLAS' general sparse-sparse
    mxm (the "spgemm" backend) and the product comes back as a new
    SparseMatrix (host-side construction; the multilevel Galerkin triple
    product Pᵀ (W P) is two such calls).
    """
    desc = DEFAULT_DESCRIPTOR if desc is None else desc
    from repro.grblas.containers import SparseMatrix
    if isinstance(X, SparseMatrix):             # sparse product (spgemm)
        if mask is not None or accum is not None:
            # reject BEFORE dispatch: the SpGEMM is O(flops) host work
            raise NotImplementedError(
                "mask/accum write semantics are defined for dense outputs; "
                "the sparse-sparse product returns a SparseMatrix")
        be = _backends.select_backend(A, X, ring, desc)
        return be.execute(A, X, ring, desc)
    be = _backends.select_backend(A, X, ring, desc)
    Y = be.execute(A, X, ring, desc)
    return _finalize(Y, ring, mask, accum)


def mxv(A, x, ring=reals_ring, *, mask=None, accum=None,
        desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """y = A (*) x under ring — grb::mxv (the k=1 column of mxm)."""
    return mxm(A, x, ring, mask=mask, accum=accum, desc=desc)


def vxm(x, A, ring=reals_ring, *, mask=None, accum=None,
        desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """y = x (*) A under ring — grb::vxm = mxm on A^T (descriptor flip)."""
    desc = DEFAULT_DESCRIPTOR if desc is None else desc
    return mxm(A, x, ring, mask=mask, accum=accum, desc=desc.transposed())


def available_backends(A, X, ring=reals_ring,
                       desc: Optional[Descriptor] = None) -> list:
    """Introspection: which backends could run this op (priority order)."""
    return _backends.available_backends(
        A, X, ring, DEFAULT_DESCRIPTOR if desc is None else desc)


def capable_desc(A, ring=reals_ring, desc: Optional[Descriptor] = None, *,
                 k: int = 1, dtype=jnp.float32) -> Optional[Descriptor]:
    """``desc`` if its backend can run an (n, k) multivector under
    ``ring`` on A; None (= auto) otherwise.  Shape-only probe — lets a
    descriptor pinned for one ring kind (e.g. the edge-semiring hot
    loop) degrade gracefully where another ring is needed (e.g. the
    reals-ring initialization)."""
    if desc is None:
        return None
    probe = jax.ShapeDtypeStruct((A.n_rows, k), dtype)
    return desc if _backends.can_execute(A, probe, ring, desc) else None


def _finalize(Y, ring, mask, accum):
    base = getattr(ring, "base", ring)  # edge rings reduce under base
    if mask is not None:
        mask = jnp.asarray(mask)
        while mask.ndim < Y.ndim:      # row mask against a multivector
            mask = mask[..., None]
    if accum is not None:
        op, C = accum
        T = op(C, Y)
        return jnp.where(mask, T, C) if mask is not None else T
    if mask is not None:
        return jnp.where(mask, Y, jnp.asarray(base.zero, Y.dtype))
    return Y
