"""Unified GraphBLAS execution API: descriptor-driven backend dispatch.

One signature for every SpMM-shaped operation in the repo::

    mxm(A, X, ring, *, mask=None, accum=None, desc=None)   # (n,k) or (n,)
    mxv(A, x, ring, ...)                                   # alias of mxm
    vxm(x, A, ring, ...)                                   # transposed mxm

The ``Descriptor`` replaces the old scatter of ``use_ell`` /
``use_pallas`` flags and parallel entry points (ops.mxm,
kernels.bsr_spmm.bsr_spmm, kernels.plap_edge.plap_apply, dist.dist_mxm):

    backend    "auto" | "coo" | "ell" | "sellcs" | "bsr_pallas" |
               "edge_pallas" | "dist" | "dist_sellcs" | "spgemm"
    transpose  operate on A^T (COO index-role swap; vxm flips this)
    interpret  run Pallas kernels in interpreter mode (CPU numerics pin)
    mesh/axis  device mesh + axis name for the "dist"/"dist_sellcs"
               backends (halo-exchange row partition, grblas.dist)

"auto" picks the first capable backend in platform-priority order
(grblas.backends): Pallas kernels first on TPU, SELL-C-σ/ELL/COO first
on CPU ("sellcs" outranks full ELL exactly when the ELL fill ratio
crosses SELLCS_AUTO_THRESHOLD — see DESIGN.md §5), "dist" whenever a
mesh is supplied.  A named backend that cannot execute
the operands raises BackendUnavailableError instead of silently falling
back — layout availability (ELL/BSR built?), ring kind, and multivector
shape are all part of the capability check.

Rings: a plain ``Semiring`` multiplies stored values with gathered
multivector entries; an ``EdgeSemiring`` sees both endpoints (the
p-Laplacian apply); a ``PairEdgeSemiring`` sees two multivectors —
pass ``X=(U, Eta)`` — which is the matrix-free Newton HVP.  The Alg-1
materialized path reuses the same API via
``A.with_vals(what_vals)`` (per-column multivalues on A's pattern).
A SparseMatrix multiplicand makes mxm GraphBLAS' general sparse-sparse
product ("spgemm" backend, reals ring): the result is a new
SparseMatrix — the multilevel subsystem's Galerkin triple product
Pᵀ (W P) is two such calls (DESIGN.md §6).

Write semantics (GraphBLAS C⟨M⟩ ⊙= T, simplified to pure outputs):
``accum=(op, C)`` returns op(C, T); ``mask`` (row mask or full-shape)
keeps masked-in entries and writes the ring's add-identity — or, with
accum, C's old value — elsewhere.  See DESIGN.md §3 for the migration
table from the old entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.grblas import backends as _backends
from repro.grblas.semiring import reals_ring
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

# re-exported for callers that catch dispatch failures
BackendUnavailableError = _backends.BackendUnavailableError


@dataclasses.dataclass(frozen=True)
class Descriptor:
    """How to execute one GraphBLAS operation (not what it computes)."""

    backend: str = "auto"
    transpose: bool = False
    interpret: bool = False
    mesh: Any = None            # device mesh: enables the dist backends
    axis: str = "data"          # mesh axis the rows are sharded over

    def transposed(self) -> "Descriptor":
        return dataclasses.replace(self, transpose=not self.transpose)


DEFAULT_DESCRIPTOR = Descriptor()


def mxm(A, X, ring=reals_ring, *, mask=None, accum=None,
        desc: Optional[Descriptor] = None):
    """Sparse x dense multivector (SpMM) under ``ring``.

    X: (n,) or (n, k) — or a pair (U, Eta) for a PairEdgeSemiring — or a
    SparseMatrix, in which case this is GraphBLAS' general sparse-sparse
    mxm (the "spgemm" backend) and the product comes back as a new
    SparseMatrix (host-side construction; the multilevel Galerkin triple
    product Pᵀ (W P) is two such calls).
    """
    desc = DEFAULT_DESCRIPTOR if desc is None else desc
    from repro.grblas.containers import SparseMatrix
    if isinstance(X, SparseMatrix):             # sparse product (spgemm)
        if mask is not None or accum is not None:
            # reject BEFORE dispatch: the SpGEMM is O(flops) host work
            raise NotImplementedError(
                "mask/accum write semantics are defined for dense outputs; "
                "the sparse-sparse product returns a SparseMatrix")
        be = _backends.select_backend(A, X, ring, desc)
        tr = _obs_trace.ACTIVE
        if not tr.enabled:
            return be.execute(A, X, ring, desc)
        with tr.span("grblas.spgemm", cat="grblas", backend=be.name,
                     n=A.n_rows, nnz_a=int(A.nnz), nnz_b=int(X.nnz)):
            return be.execute(A, X, ring, desc)
    be = _backends.select_backend(A, X, ring, desc)
    tr = _obs_trace.ACTIVE
    if not tr.enabled:
        Y = be.execute(A, X, ring, desc)
    else:
        Y = _execute_observed(be, A, X, ring, desc, tr)
    return _finalize(Y, ring, mask, accum)


def _ring_kind(ring) -> str:
    return (getattr(ring, "kind", None) or getattr(ring, "name", None)
            or type(ring).__name__)


def _x_width(X) -> int:
    if isinstance(X, tuple):
        X = X[0]
    shp = getattr(X, "shape", ())
    return int(shp[1]) if len(shp) > 1 else 1


def _traffic_bytes(A, k: int, itemsize: int = 4) -> int:
    """Minimum-traffic SpMM byte model (the memory-roofline denominator
    used by benchmarks/roofline_report.py's dominant-term accounting):
    stream A once (value + column index per nnz), stream the multivector
    in and the product out once.  Real gathers re-read X rows, so
    achieved GB/s against this model is a lower bound."""
    nnz = int(getattr(A, "nnz", 0))
    n_rows = int(getattr(A, "n_rows", 0))
    n_cols = int(getattr(A, "n_cols", n_rows))
    return nnz * (itemsize + 4) + (n_rows + n_cols) * k * itemsize


def _execute_observed(be, A, X, ring, desc, tr):
    """Dispatch accounting when tracing is on.  Inside a jit trace the
    op runs once per *compile*, so wall-clock spans would time the
    tracer — record the dispatch decision (backend, ring kind) as an
    instant + counter instead.  Eager calls get a fenced span carrying
    shapes, nnz, and the byte model (→ achieved GB/s via
    obs.trace.roofline_summary)."""
    kind = _ring_kind(ring)
    if _obs_trace.under_trace(X[0] if isinstance(X, tuple) else X):
        _obs_metrics.DEFAULT.counter("grblas_dispatch_total",
                                     backend=be.name, ring=kind,
                                     ctx="traced").inc()
        tr.instant("grblas.dispatch", backend=be.name, ring=kind,
                   traced=True)
        return be.execute(A, X, ring, desc)
    k = _x_width(X)
    nnz = int(getattr(A, "nnz", 0))
    with tr.span("grblas.mxm", cat="grblas", backend=be.name, ring=kind,
                 n=int(getattr(A, "n_rows", 0)), k=k, nnz=nnz) as sp:
        Y = be.execute(A, X, ring, desc)
        sp.fence(Y)
        sp.set(bytes=_traffic_bytes(A, k))
    _obs_metrics.DEFAULT.counter("grblas_dispatch_total", backend=be.name,
                                 ring=kind, ctx="eager").inc()
    _obs_metrics.DEFAULT.counter("grblas_nnz_total", backend=be.name).inc(nnz)
    return Y


def mxv(A, x, ring=reals_ring, *, mask=None, accum=None,
        desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """y = A (*) x under ring — grb::mxv (the k=1 column of mxm)."""
    return mxm(A, x, ring, mask=mask, accum=accum, desc=desc)


def vxm(x, A, ring=reals_ring, *, mask=None, accum=None,
        desc: Optional[Descriptor] = None) -> jnp.ndarray:
    """y = x (*) A under ring — grb::vxm = mxm on A^T (descriptor flip)."""
    desc = DEFAULT_DESCRIPTOR if desc is None else desc
    return mxm(A, x, ring, mask=mask, accum=accum, desc=desc.transposed())


def available_backends(A, X, ring=reals_ring,
                       desc: Optional[Descriptor] = None) -> list:
    """Introspection: which backends could run this op (priority order)."""
    return _backends.available_backends(
        A, X, ring, DEFAULT_DESCRIPTOR if desc is None else desc)


def capable_desc(A, ring=reals_ring, desc: Optional[Descriptor] = None, *,
                 k: int = 1, dtype=jnp.float32) -> Optional[Descriptor]:
    """``desc`` if its backend can run an (n, k) multivector under
    ``ring`` on A; None (= auto) otherwise.  Shape-only probe — lets a
    descriptor pinned for one ring kind (e.g. the edge-semiring hot
    loop) degrade gracefully where another ring is needed (e.g. the
    reals-ring initialization)."""
    if desc is None:
        return None
    probe = jax.ShapeDtypeStruct((A.n_rows, k), dtype)
    if _backends.can_execute(A, probe, ring, desc):
        return desc
    if desc.backend != "auto":
        # a pinned backend degrading to auto is a fallback event: count
        # it so a hot loop silently losing its Pallas path is visible
        _obs_metrics.DEFAULT.counter("grblas_fallback_total",
                                     backend=desc.backend,
                                     ring=_ring_kind(ring)).inc()
        _obs_trace.ACTIVE.instant("grblas.fallback", backend=desc.backend,
                                  ring=_ring_kind(ring))
    return None


def _finalize(Y, ring, mask, accum):
    base = getattr(ring, "base", ring)  # edge rings reduce under base
    if mask is not None:
        mask = jnp.asarray(mask)
        while mask.ndim < Y.ndim:      # row mask against a multivector
            mask = mask[..., None]
    if accum is not None:
        op, C = accum
        T = op(C, Y)
        return jnp.where(mask, T, C) if mask is not None else T
    if mask is not None:
        return jnp.where(mask, Y, jnp.asarray(base.zero, Y.dtype))
    return Y
