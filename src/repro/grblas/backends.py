"""Backend registry for the unified GraphBLAS execution API.

Every SpMM-shaped operation in the repo flows through one table: a
``Backend`` couples a capability predicate (can this implementation run
this (container layout, ring kind, multivector shape, descriptor)
combination at all?) with an execute function.  ``grblas.api.mxm``
selects from the table — either the backend the Descriptor names
(validated against the predicate, loud error otherwise) or, for
``backend="auto"``, the first capable backend in platform-priority
order.

Registered backends (priority: lower = preferred under "auto"):

  name         layout needed   rings                       cpu  tpu
  dist         ELL / row-part  reals, edge (reals base)      0    0  (needs desc.mesh)
  dist_sellcs  row-part + per- same gates as dist, square    1    1  (needs desc.mesh)
               shard SELL-C-σ  only
  edge_pallas  BSR tiles       plap_apply / plap_hvp kinds  61   10
  bsr_pallas   BSR tiles       reals                        60   11
  sellcs       SELL-C-σ        padded-reducer rings (incl.  19   12
                               multivals) + plap edge kinds
  ell          padded ELL      rings with a padded reducer  20   20
  coo          COO (always)    any ring, transpose, multivals 30 30
  spgemm       COO (always)    reals, X a SparseMatrix      25   25

"spgemm" is the sparse × *sparse* member of the table — GraphBLAS' mxm
proper: ``api.mxm(A, B)`` with B a SparseMatrix returns the product as
a new SparseMatrix.  It is the only backend claiming a sparse
multiplicand, so its priority never competes; the multilevel subsystem
builds Galerkin coarse operators (Pᵀ W P) through it (DESIGN.md §6).
The result pattern is data-dependent, so execution is host-side (like
every layout build) and traced containers are rejected loudly.

"sellcs" sits above full-ELL in the auto order but *defers* to ELL when
the matrix's ELL fill ratio is under SELLCS_AUTO_THRESHOLD — on low-skew
graphs the two layouts do the same work and ELL has no permute step; on
skewed-degree graphs the sliced layout's per-slice padding is the whole
point (DESIGN.md §5).  Naming backend="sellcs" explicitly always runs.

The Pallas kernels rank first on TPU and last on CPU: their jnp
reference paths exist everywhere (and run under ``desc.interpret``),
but on CPU the gather/segment formulations win.  ``dist`` outranks
everything once a mesh is supplied — the caller asked for sharding.

New hardware or layouts are one ``register_backend`` call, not a fifth
parallel entry point (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.grblas.containers import SELLCS_AUTO_THRESHOLD, SparseMatrix
from repro.grblas.semiring import (
    EdgeSemiring,
    PairEdgeSemiring,
    Semiring,
    fast_paths,
)


class BackendUnavailableError(ValueError):
    """The requested backend cannot execute this operand combination."""


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    supports: Callable      # (A, X, ring, desc) -> bool
    execute: Callable       # (A, X, ring, desc) -> jnp.ndarray
    cpu_priority: int       # auto-selection rank off-TPU (lower wins)
    tpu_priority: int       # auto-selection rank on TPU
    # True when this backend's Pallas path (taken on TPU or under
    # desc.interpret) bakes the ring's (p, eps) params into the kernel
    # as static arguments — callers that jit over a *traced* p (the
    # psc continuation loop) must concretize p before reaching it.
    static_ring_params: bool = False


_REGISTRY: Dict[str, Backend] = {}


def register_backend(name: str, *, cpu_priority: int, tpu_priority: int,
                     supports: Callable, static_ring_params: bool = False):
    """Decorator: register ``fn`` as the execute hook of backend ``name``."""

    def deco(fn):
        _REGISTRY[name] = Backend(name=name, supports=supports, execute=fn,
                                  cpu_priority=cpu_priority,
                                  tpu_priority=tpu_priority,
                                  static_ring_params=static_ring_params)
        return fn

    return deco


def registered_backends() -> Dict[str, Backend]:
    return dict(_REGISTRY)


def available_backends(A, X, ring, desc) -> list:
    """Names of every backend capable of this operand combination."""
    return [b.name for b in _ordered() if b.supports(A, X, ring, desc)]


def can_execute(A, X, ring, desc) -> bool:
    """Would select_backend succeed?  (Shape-only probe; X may be a
    jax.ShapeDtypeStruct.)  Callers use this to fall back gracefully when
    a descriptor pinned for one ring kind cannot serve another."""
    if desc.backend == "auto":
        return any(b.supports(A, X, ring, desc) for b in _ordered())
    be = _REGISTRY.get(desc.backend)
    return be is not None and be.supports(A, X, ring, desc)


def _ordered():
    on_tpu = jax.default_backend() == "tpu"
    key = (lambda b: b.tpu_priority) if on_tpu else (lambda b: b.cpu_priority)
    return sorted(_REGISTRY.values(), key=key)


def select_backend(A, X, ring, desc) -> Backend:
    """Resolve a Descriptor to one executable backend (or raise loudly)."""
    if desc.backend != "auto":
        be = _REGISTRY.get(desc.backend)
        if be is None:
            raise BackendUnavailableError(
                f"unknown backend {desc.backend!r}; registered: "
                f"{sorted(_REGISTRY)}")
        if not be.supports(A, X, ring, desc):
            raise BackendUnavailableError(
                f"backend {desc.backend!r} cannot execute ring "
                f"{getattr(ring, 'name', ring)!r} on this container "
                f"(layout availability / ring kind / shape mismatch); "
                f"capable backends: {available_backends(A, X, ring, desc)}")
        return be
    for be in _ordered():
        if be.supports(A, X, ring, desc):
            return be
    raise BackendUnavailableError(
        f"no registered backend supports ring "
        f"{getattr(ring, 'name', ring)!r} with this container/descriptor")


# ------------------------------------------------------------------ helpers

def _is_pair(X) -> bool:
    return isinstance(X, (tuple, list))


def _is_sparse(X) -> bool:
    return isinstance(X, SparseMatrix)


def _broadcast_vals(vals, ndim):
    """Lift (nnz,) values to (nnz, 1) against an (n, k) multivector;
    (nnz, k) multivalues (containers.with_vals) pass through."""
    if ndim == 2 and vals.ndim == 1:
        return vals[:, None]
    return vals


def _square(A) -> bool:
    return A.n_rows == A.n_cols


# --------------------------------------------------------------- coo backend

def _coo_supports(A, X, ring, desc):
    if not isinstance(A, SparseMatrix) or _is_sparse(X):
        return False
    if isinstance(ring, PairEdgeSemiring):
        return (_is_pair(X) and len(X) == 2 and _square(A)
                and _vals_match(A, X[0]))
    if isinstance(ring, EdgeSemiring):
        return not _is_pair(X) and _square(A) and _vals_match(A, X)
    return (isinstance(ring, Semiring) and not _is_pair(X)
            and _vals_match(A, X))


def _vals_match(A, X) -> bool:
    """(nnz, k) multivalues (with_vals) only broadcast against an (n, k)
    multivector — reject 1-D inputs at dispatch time, not mid-broadcast."""
    return A.vals.ndim == 1 or getattr(X, "ndim", 0) == 2


@register_backend("coo", cpu_priority=30, tpu_priority=30,
                  supports=_coo_supports)
def _coo_execute(A, X, ring, desc):
    """Segment reduction over nnz — the reference path for every ring.

    Y[i] = add_j mul(A[i,j], X[j]); transpose swaps the gather/scatter
    index roles (rows <-> cols), which is how vxm rides the same code.
    """
    out_idx, src_idx = (A.cols, A.rows) if desc.transpose else (A.rows, A.cols)
    n_out = A.n_cols if desc.transpose else A.n_rows
    if isinstance(ring, PairEdgeSemiring):
        U, E = X
        vals = _broadcast_vals(A.vals, U.ndim)
        contrib = ring.edge_mul(vals, U[src_idx], U[out_idx],
                                E[src_idx], E[out_idx])
        return ring.base.segment_reduce(contrib, out_idx, n_out)
    vals = _broadcast_vals(A.vals, X.ndim)
    if isinstance(ring, EdgeSemiring):
        contrib = ring.edge_mul(vals, X[src_idx], X[out_idx])
        return ring.base.segment_reduce(contrib, out_idx, n_out)
    contrib = ring.mul(vals, X[src_idx])
    return ring.segment_reduce(contrib, out_idx, n_out)


# --------------------------------------------------------------- ell backend

def _ell_supports(A, X, ring, desc):
    """Padded-ELL is only sound for rings whose pad entries (col=row,
    val=0) contribute the add-identity — exactly the rings with a
    registered ``padded`` fast path (semiring.register_ring_fast_paths)."""
    return (isinstance(A, SparseMatrix)
            and A.ell_cols is not None
            and A.vals.ndim == 1
            and isinstance(ring, Semiring)
            and not isinstance(ring, (EdgeSemiring, PairEdgeSemiring))
            and not _is_pair(X) and not _is_sparse(X)
            and not desc.transpose
            and fast_paths(ring).padded is not None)


@register_backend("ell", cpu_priority=20, tpu_priority=20,
                  supports=_ell_supports)
def _ell_execute(A, X, ring, desc):
    """Padded-ELL: gather (n, max_nnz[, k]) then fold along the pad axis."""
    gathered = X[A.ell_cols]                      # (n, m[, k])
    vals = A.ell_vals if X.ndim == 1 else A.ell_vals[..., None]
    contrib = ring.mul(vals, gathered)
    return fast_paths(ring).padded(contrib)


# ------------------------------------------------------------ sellcs backend

def _auto_defers_to_ell(A, X, ring, desc) -> bool:
    """Under "auto", keep low-fill matrices on the plain full-ELL path:
    sellcs only outranks ELL once ELL's padding blowup crosses
    SELLCS_AUTO_THRESHOLD — the skewed-degree regime the sliced layout
    exists for.  A named backend="sellcs" always runs."""
    return (desc.backend == "auto"
            and _ell_supports(A, X, ring, desc)
            and A.ell_fill_ratio() <= SELLCS_AUTO_THRESHOLD)


def _sellcs_supports(A, X, ring, desc):
    if not (isinstance(A, SparseMatrix) and A.sell_cols is not None
            and not desc.transpose):
        return False
    if isinstance(ring, PairEdgeSemiring):
        return (ring.kind == "plap_hvp" and A.vals.ndim == 1 and _square(A)
                and _is_pair(X) and len(X) == 2
                and getattr(X[0], "ndim", 0) == 2
                and X[0].shape == X[1].shape)
    if isinstance(ring, EdgeSemiring):
        # pad entries are (col=self, val=0): sound exactly for edge kinds
        # whose multiply annihilates on w=0 — the known plap kind, not
        # generic closures (same reasoning as the dist backend gate).
        return (ring.kind == "plap_apply" and A.vals.ndim == 1 and _square(A)
                and not _is_pair(X) and getattr(X, "ndim", 0) in (1, 2))
    if not (isinstance(ring, Semiring) and not _is_pair(X)
            and getattr(X, "ndim", 0) in (1, 2)
            and fast_paths(ring).padded is not None
            and _vals_match(A, X)):
        return False
    return not _auto_defers_to_ell(A, X, ring, desc)


def sellcs_run(A, X, ring, interpret: bool = False,
               use_pallas: bool | None = None):
    """SELL-C-σ SpMM with explicit path control (shared by the backend
    and the benchmarks).  Permute the multivector once (σ-sort order),
    run one gather+fold per width run — Pallas kernel (TPU / interpret)
    or the jnp reference — and un-permute the output.

    ``X`` is a multivector for plain/edge rings, a (U, Eta) pair for the
    "plap_hvp" kind.  (nnz, k) multivalues (with_vals) take the jnp path
    — the Alg-1 materialized W-hat is CPU-bound host-side anyway."""
    from repro.kernels.sellcs_spmm import (
        sellcs_plap_apply_pallas, sellcs_plap_apply_ref,
        sellcs_plap_hvp_pallas, sellcs_plap_hvp_ref,
        sellcs_spmm_pallas, sellcs_spmm_ref)

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    C = A.sell_c
    pair = _is_pair(X)
    one_d = False
    if pair:
        U, E = X
        Up, Ep = U[A.sell_perm], E[A.sell_perm]
    else:
        one_d = X.ndim == 1
        Xp = (X[:, None] if one_d else X)[A.sell_perm]

    outs = []
    for r, cols in enumerate(A.sell_cols):
        vals = A.sell_vals[r]
        row0 = A.sell_row0[r]
        if isinstance(ring, PairEdgeSemiring):
            p, eps = ring.params
            if use_pallas:
                Yr = sellcs_plap_hvp_pallas(cols, vals, Up, Ep, C,
                                            slice0=row0 // C, p=float(p),
                                            eps=float(eps),
                                            interpret=interpret)
            else:
                Yr = sellcs_plap_hvp_ref(cols, vals, Up, Ep, row0, p, eps)
        elif isinstance(ring, EdgeSemiring):
            p, eps = ring.params
            if use_pallas:
                Yr = sellcs_plap_apply_pallas(cols, vals, Xp, C,
                                              slice0=row0 // C, p=float(p),
                                              eps=float(eps),
                                              interpret=interpret)
            else:
                Yr = sellcs_plap_apply_ref(cols, vals, Xp, row0, p, eps)
        elif (use_pallas and vals.ndim == 2 and ring.name == "reals_+x"):
            Yr = sellcs_spmm_pallas(cols, vals, Xp, C, slice0=row0 // C,
                                    interpret=interpret)
        elif ring.name == "reals_+x":
            Yr = sellcs_spmm_ref(cols, vals, Xp)
        else:
            vb = vals[..., None] if vals.ndim == 2 else vals
            Yr = fast_paths(ring).padded(ring.mul(vb, Xp[cols]))
        outs.append(Yr)

    Y = jnp.concatenate(outs, axis=0)[A.sell_inv]      # un-permute, drop pads
    return Y[:, 0] if (one_d and not pair) else Y


@register_backend("sellcs", cpu_priority=19, tpu_priority=12,
                  supports=_sellcs_supports, static_ring_params=True)
def _sellcs_execute(A, X, ring, desc):
    """Sliced-ELLPACK gather + ring fold over per-width runs; Pallas
    kernel on TPU (or under ``desc.interpret``), vectorized jnp on CPU.
    The σ permutation is applied to the multivector on the way in and
    inverted on the way out — callers never observe it."""
    return sellcs_run(A, X, ring, interpret=desc.interpret)


# -------------------------------------------------------- bsr_pallas backend

def _pad_rows(n_pad_rows, *Xs):
    pad = n_pad_rows - Xs[0].shape[0]
    return [jnp.pad(X, ((0, pad), (0, 0))) if pad else X for X in Xs]


def _bsr_supports(A, X, ring, desc):
    return (isinstance(A, SparseMatrix)
            and A.bsr_blocks is not None
            and A.vals.ndim == 1
            and isinstance(ring, Semiring)
            and not isinstance(ring, (EdgeSemiring, PairEdgeSemiring))
            and ring.name == "reals_+x"
            and not _is_pair(X)
            and getattr(X, "ndim", 0) == 2
            and not desc.transpose)


def bsr_spmm_run(A, X, interpret: bool = False,
                 use_pallas: bool | None = None):
    """BSR SpMM with explicit path control (shared by the backend and the
    deprecated kernel shims).  ``use_pallas=None`` resolves to the
    platform default (Pallas on TPU or under interpret, jnp ref on CPU)."""
    from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_pallas
    from repro.kernels.bsr_spmm.ref import bsr_spmm_ref

    bs = A.block_size
    n_rb = len(A.bsr_indptr) - 1
    (Xp,) = _pad_rows(n_rb * bs, X)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if use_pallas or interpret:
        Y = bsr_spmm_pallas(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids, Xp,
                            n_row_blocks=n_rb, block_size=bs,
                            interpret=interpret)
    else:
        Y = bsr_spmm_ref(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids, Xp,
                         n_row_blocks=n_rb, block_size=bs)
    return Y[: A.n_rows]


@register_backend("bsr_pallas", cpu_priority=60, tpu_priority=11,
                  supports=_bsr_supports)
def _bsr_execute(A, X, ring, desc):
    """128x128 dense-tile SpMM on the MXU (Pallas); jnp blocked ref on CPU.

    ``desc.interpret`` forces the Pallas kernel in interpreter mode —
    the numerics-pinning path used by the backend-equivalence suite.
    """
    return bsr_spmm_run(A, X, interpret=desc.interpret)


# ------------------------------------------------------- edge_pallas backend

def _edge_pallas_supports(A, X, ring, desc):
    if not (isinstance(A, SparseMatrix) and A.bsr_blocks is not None
            and A.vals.ndim == 1 and not desc.transpose and _square(A)):
        return False
    if isinstance(ring, EdgeSemiring) and ring.kind == "plap_apply":
        return not _is_pair(X) and getattr(X, "ndim", 0) == 2
    if isinstance(ring, PairEdgeSemiring) and ring.kind == "plap_hvp":
        return (_is_pair(X) and len(X) == 2 and X[0].ndim == 2
                and X[0].shape == X[1].shape)
    return False


def edge_pallas_run(A, X, ring, interpret: bool = False,
                    use_pallas: bool | None = None):
    """Fused p-Laplacian kernels with explicit path control (shared by
    the backend and the deprecated kernel shims).  ``X`` is a single
    multivector for a "plap_apply" ring, a (U, Eta) pair for
    "plap_hvp"."""
    from repro.kernels.plap_edge.plap_edge import (plap_apply_pallas,
                                                   plap_hvp_pallas)
    from repro.kernels.plap_edge.ref import (plap_apply_ref,
                                             plap_hvp_edge_ref)

    p, eps = ring.params
    bs = A.block_size
    n_rb = len(A.bsr_indptr) - 1
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if not _is_pair(X):
        (Xp,) = _pad_rows(n_rb * bs, X)
        if use_pallas or interpret:
            Y = plap_apply_pallas(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids,
                                  Xp, n_row_blocks=n_rb, block_size=bs,
                                  p=p, eps=eps, interpret=interpret)
        else:
            Y = plap_apply_ref(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids,
                               Xp, n_rb, bs, p, eps)
    else:
        U, E = X
        Up, Ep = _pad_rows(n_rb * bs, U, E)
        if use_pallas or interpret:
            Y = plap_hvp_pallas(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids,
                                Up, Ep, n_row_blocks=n_rb, block_size=bs,
                                p=p, eps=eps, interpret=interpret)
        else:
            Y = plap_hvp_edge_ref(A.bsr_blocks, A.bsr_indices, A.bsr_row_ids,
                                  Up, Ep, n_rb, bs, p, eps)
    return Y[: A.n_rows]


@register_backend("edge_pallas", cpu_priority=61, tpu_priority=10,
                  supports=_edge_pallas_supports, static_ring_params=True)
def _edge_pallas_execute(A, X, ring, desc):
    """Fused p-Laplacian edge-semiring kernels over BSR tiles.

    Claims rings by *kind* ("plap_apply" / "plap_hvp", with (p, eps) in
    ring.params) rather than tracing the edge closure — the kernel IS
    the semiring specialization (DESIGN.md §2, adaptation 4).
    """
    return edge_pallas_run(A, X, ring, interpret=desc.interpret)


# -------------------------------------------------------------- dist backend

def _dist_supports(A, X, ring, desc):
    if desc.mesh is None or desc.transpose or _is_pair(X) or _is_sparse(X):
        return False
    from repro.grblas.dist import RowPartitionedMatrix

    if isinstance(A, RowPartitionedMatrix):
        ok_layout = True
    elif isinstance(A, SparseMatrix):
        ok_layout = A.ell_cols is not None and A.vals.ndim == 1
    else:
        return False
    if isinstance(ring, EdgeSemiring):
        # the dist path folds the padded-ELL axis with an unconditional
        # sum, so pad entries (val=0) must be annihilated by the edge
        # multiply: guaranteed for the known plap kinds
        # (edge_mul(0, ...) == 0), NOT for generic edge closures — those
        # must run the coo backend.  Square-gated like every other
        # edge-ring backend: the shard body reads x_i from the shard's
        # own row block, which only aligns when the row and column
        # spaces (and their paddings) coincide.
        return (ok_layout and _square(A) and ring.base.name == "reals_+x"
                and ring.kind == "plap_apply")
    return (ok_layout and isinstance(ring, Semiring)
            and ring.name == "reals_+x")


def _dist_partition_for(A, desc, *, sellcs: bool):
    """Resolve (and memoize) the row partition of a plain SparseMatrix.

    The memo lives on the container instance and is keyed on
    (shard count, identity of the vals buffer, layout flavour): a caller
    that swaps the value buffers on the same pattern (the Alg-1 Ŵ
    update idiom) must not be served a partition carved from the stale
    ``ell_vals``.  Not pytree state — a matrix that crosses a
    jit/transform boundary re-partitions on the next call — and not
    buildable from traced arrays at all: close over the matrix, or
    pre-build a RowPartitionedMatrix outside the transform.
    """
    from repro.grblas.dist import make_row_partition

    if isinstance(A.ell_cols, jax.core.Tracer):
        raise BackendUnavailableError(
            "dist backend cannot row-partition a traced SparseMatrix "
            "(partitioning is host-side numpy): close over the matrix "
            "instead of passing it as a jit argument, or pre-build a "
            "RowPartitionedMatrix with make_row_partition outside the "
            "transform")
    n_shards = int(desc.mesh.shape[desc.axis])
    cache = getattr(A, "_dist_partitions", None)
    if cache is None:
        cache = {}
        A._dist_partitions = cache  # host-side memo, not pytree state
    key = (n_shards, id(A.ell_vals), sellcs)
    if key not in cache:
        # a matrix has exactly one live ell_vals buffer, so every entry
        # pinning a different one is superseded — evict them all (the
        # Alg-1 Ŵ swap idiom would otherwise accumulate one full
        # partition per Newton step); entries for other shard counts /
        # layouts of the CURRENT buffer stay live
        for stale in [k for k, v in cache.items()
                      if v[0] is not A.ell_vals]:
            del cache[stale]
        # the entry pins the keyed buffer so its id cannot be recycled
        # by the allocator while the memo is alive
        cache[key] = (A.ell_vals,
                      make_row_partition(A, n_shards, sellcs=sellcs))
    return cache[key][1]


@register_backend("dist", cpu_priority=0, tpu_priority=0,
                  supports=_dist_supports)
def _dist_execute(A, X, ring, desc):
    """Row-block sharded SpMM over desc.mesh: shard_map + precomputed
    halo exchange (all_to_all of only the remote rows each shard's
    columns touch), falling back to the full all-gather when the plan
    found the halo denser than HALO_FALLBACK_FRAC of the shard size.

    Accepts a pre-built RowPartitionedMatrix or a plain SparseMatrix —
    see _dist_partition_for for the partition memo contract.
    """
    from repro.grblas.dist import RowPartitionedMatrix, shard_mxm

    if isinstance(A, RowPartitionedMatrix):
        Ap = A
    else:
        Ap = _dist_partition_for(A, desc, sellcs=False)
    return shard_mxm(Ap, X, desc.mesh, axis=desc.axis, ring=ring)


def _dist_sellcs_supports(A, X, ring, desc):
    """Same ring/pad-soundness gates as "dist" (the shard fold sums a
    padded axis unconditionally), plus: square only — the per-shard
    σ-sort shares the halo plan's one-row-space remap — and, for a
    pre-built partition, the DistSellCS slicing must be present."""
    if not _dist_supports(A, X, ring, desc):
        return False
    from repro.grblas.dist import RowPartitionedMatrix

    if isinstance(A, RowPartitionedMatrix):
        return A.sell is not None
    return _square(A)


@register_backend("dist_sellcs", cpu_priority=1, tpu_priority=1,
                  supports=_dist_sellcs_supports)
def _dist_sellcs_execute(A, X, ring, desc):
    """Sharded SELL-C-σ SpMM: the halo-exchange schedule of "dist" with
    each shard running σ-sorted, per-slice-padded width runs over its
    own row block (slice widths maxed across shards so the shard_map
    body stays SPMD-uniform) — the skewed-degree layout advantage under
    a mesh.  A plain SparseMatrix is partitioned with sellcs=True and
    memoized separately from the full-ELL partition.
    """
    from repro.grblas.dist import RowPartitionedMatrix, shard_mxm

    if isinstance(A, RowPartitionedMatrix):
        Ap = A
    else:
        Ap = _dist_partition_for(A, desc, sellcs=True)
    return shard_mxm(Ap, X, desc.mesh, axis=desc.axis, ring=ring,
                     layout="sellcs")


# ------------------------------------------------------------ spgemm backend

def _spgemm_supports(A, X, ring, desc):
    """Sparse × sparse under the reals (+,×) ring.  The output pattern is
    data-dependent, so this is a host-side construction op (like every
    layout build), not a jittable kernel — traced containers are caught
    in execute with an actionable error rather than silently excluded
    here, so a named backend="spgemm" fails loudly."""
    return (isinstance(A, SparseMatrix) and _is_sparse(X)
            and isinstance(ring, Semiring)
            and not isinstance(ring, (EdgeSemiring, PairEdgeSemiring))
            and ring.name == "reals_+x")


@register_backend("spgemm", cpu_priority=25, tpu_priority=25,
                  supports=_spgemm_supports)
def _spgemm_execute(A, B, ring, desc):
    """C = A (*) B (or Aᵀ B under desc.transpose), both sparse, under the
    reals ring — GraphBLAS' general mxm.  Row-expansion SpGEMM: every
    stored A entry (i, j) fans out over B's row j, then duplicate (i, b)
    pairs fold under the add monoid.  O(flops) host work; for the
    partition-of-unity prolongators of the multilevel subsystem (one
    entry per row/column) it degenerates to a linear-time relabel+fold.
    The product comes back as a bare-COO SparseMatrix — derived layouts
    are a consumer decision (a chained triple product should not pay
    ELL/SELL builds on its intermediate): callers that keep the result
    rebuild layouts with ``from_coo`` (multilevel.coarsen does)."""
    import numpy as np

    for arr in (A.rows, A.cols, A.vals, B.rows, B.cols, B.vals):
        if isinstance(arr, jax.core.Tracer):
            raise BackendUnavailableError(
                "spgemm cannot multiply traced SparseMatrix operands (the "
                "output pattern is data-dependent): run it outside jit — "
                "hierarchy construction is host-side setup, not hot-loop "
                "work")
    a_rows = np.asarray(A.cols if desc.transpose else A.rows, np.int64)
    a_cols = np.asarray(A.rows if desc.transpose else A.cols, np.int64)
    a_vals = np.asarray(A.vals)
    n_out = A.n_cols if desc.transpose else A.n_rows
    b_rows = np.asarray(B.rows, np.int64)
    b_cols = np.asarray(B.cols, np.int64)
    b_vals = np.asarray(B.vals)

    # CSR-style row pointers of B (from_coo guarantees row-sorted COO)
    counts = np.bincount(b_rows, minlength=B.n_rows)
    indptr = np.concatenate([[0], np.cumsum(counts)])
    reps = counts[a_cols]                       # fan-out of each A entry
    total = int(reps.sum())
    out_rows = np.repeat(a_rows, reps)
    av = np.repeat(a_vals, reps)
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(reps) - reps, reps)
    bpos = np.repeat(indptr[a_cols], reps) + offs
    out_cols = b_cols[bpos]
    prod = av * b_vals[bpos]

    # fold duplicates under the add monoid (+)
    key = out_rows * B.n_cols + out_cols
    uniq, inv = np.unique(key, return_inverse=True)
    vals = np.bincount(inv, weights=prod)
    dtype = A.vals.dtype
    return SparseMatrix.from_coo(uniq // B.n_cols, uniq % B.n_cols, vals,
                                 (n_out, B.n_cols), dtype=dtype,
                                 build_ell=False, build_sellcs=False)
