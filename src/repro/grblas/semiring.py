"""Algebraic relations: semirings and the edge-semiring extension.

A GraphBLAS semiring is (add-monoid, mul-op, zero, one).  ``add`` must be
associative+commutative with identity ``zero``; ``mul`` distributes over
``add`` with identity ``one`` and annihilator ``zero``.  These laws are
property-tested in tests/test_grblas_properties.py.

The EdgeSemiring generalizes ``mul`` to an *edge function*
``mul(w_ij, x_j, x_i)`` so that one SpMV pass can express the graph
p-Laplacian apply  (Delta_p x)_i = sum_j w_ij phi_p(x_i - x_j)  without
materializing the reweighted matrix W-hat each Newton iteration.  This is
the TPU adaptation of the paper's Algorithm 1 (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Semiring:
    """(add, mul, zero, one) over jnp scalars/arrays (elementwise)."""

    add: Callable  # (a, b) -> a (+) b, associative + commutative
    mul: Callable  # (a, b) -> a (*) b
    zero: float    # identity of add, annihilator of mul
    one: float     # identity of mul
    name: str = "semiring"

    def segment_reduce(self, values, segment_ids, num_segments):
        """Reduce ``values`` per segment under the add-monoid."""
        import jax.ops  # noqa: F401  (documentation of provenance)
        import jax

        if self.name == "reals_+x":
            return jax.ops.segment_sum(values, segment_ids, num_segments)
        if self.name == "min_+":
            return jax.ops.segment_min(values, segment_ids, num_segments)
        if self.name in ("max_x", "bool_|&"):
            return jax.ops.segment_max(values, segment_ids, num_segments)
        # generic fallback: sort-free fori over values would be O(nnz);
        # all shipped rings hit a fast path above.
        return jax.ops.segment_sum(values, segment_ids, num_segments)


@dataclasses.dataclass(frozen=True)
class EdgeSemiring:
    """Semiring whose multiply sees the edge weight AND both endpoints.

    mul(w, x_src, x_dst) -> contribution of edge (dst <- src).
    The add-monoid is inherited from ``base``.
    """

    base: Semiring
    edge_mul: Callable  # (w_ij, x_j, x_i) -> value
    name: str = "edge_semiring"


def _add(a, b):
    return a + b


def _mul(a, b):
    return a * b


reals_ring = Semiring(add=_add, mul=_mul, zero=0.0, one=1.0, name="reals_+x")
min_plus_ring = Semiring(add=jnp.minimum, mul=_add, zero=jnp.inf, one=0.0, name="min_+")
max_times_ring = Semiring(add=jnp.maximum, mul=_mul, zero=-jnp.inf, one=1.0, name="max_x")
boolean_ring = Semiring(
    add=jnp.logical_or, mul=jnp.logical_and, zero=False, one=True, name="bool_|&"
)


def phi_p(x, p, eps=0.0):
    """phi_p(x) = |x|^{p-1} sign(x), optionally eps-smoothed for p<2.

    The smoothed variant (x^2+eps)^{(p-2)/2} * x keeps the p-Laplacian
    differentiable at x=0 (needed by Newton for p<2), matching [4].
    """
    if eps == 0.0:
        return jnp.abs(x) ** (p - 1.0) * jnp.sign(x)
    return (x * x + eps) ** ((p - 2.0) / 2.0) * x


def plap_edge_semiring(p: float, eps: float = 1e-9) -> EdgeSemiring:
    """Edge-semiring computing  w_ij * phi_p(x_i - x_j)  per edge."""

    def edge_mul(w, x_src, x_dst):
        return w * phi_p(x_dst - x_src, p, eps)

    return EdgeSemiring(base=reals_ring, edge_mul=edge_mul, name=f"plap_edge_p{p}")


def plap_hess_edge_semiring(p: float, eps: float = 1e-9) -> EdgeSemiring:
    """Edge-semiring for the matrix-free Hessian apply.

    Computes  w_ij |u_i-u_j|^{p-2} (eta_i - eta_j)  where the (u, eta)
    pair is packed as complex-free stacked input handled by ops.mxm_edge
    with two multivectors; see core/plap.py for the call.
    """

    def edge_mul(w_and_du, eta_src, eta_dst):
        # w_and_du is pre-fused: w_ij * |u_i - u_j|^{p-2}  (computed on the
        # fly by the caller per edge); this closure only applies the eta
        # difference.  Kept for API symmetry.
        return w_and_du * (eta_dst - eta_src)

    return EdgeSemiring(base=reals_ring, edge_mul=edge_mul, name=f"plap_hess_p{p}")
