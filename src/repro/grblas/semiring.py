"""Algebraic relations: semirings, edge-semirings, and per-ring fast paths.

A GraphBLAS semiring is (add-monoid, mul-op, zero, one).  ``add`` must be
associative+commutative with identity ``zero``; ``mul`` distributes over
``add`` with identity ``one`` and annihilator ``zero``.  These laws are
property-tested in tests/test_grblas_properties.py.

The EdgeSemiring generalizes ``mul`` to an *edge function*
``mul(w_ij, x_j, x_i)`` so that one SpMV pass can express the graph
p-Laplacian apply  (Delta_p x)_i = sum_j w_ij phi_p(x_i - x_j)  without
materializing the reweighted matrix W-hat each Newton iteration.  The
PairEdgeSemiring extends this to a *pair* of multivectors, which is what
the Newton Hessian apply needs:  sum_j w_ij phi'(u_i-u_j) (eta_i-eta_j).
This is the TPU adaptation of the paper's Algorithm 1 (see DESIGN.md §2).

Fast paths
----------
Reductions under the add-monoid used to be dispatched by string-matching
``ring.name`` inside ops.reduce / Semiring.segment_reduce.  They are now
a registry: ``register_ring_fast_paths(name, segment=, dense=, padded=)``
attaches the vectorized implementations a ring is allowed to use, and
``fast_paths(ring)`` looks them up.  Rings without a registered fast path
fall back to a *correct* (if slow) sequential fold under ``add`` — never
to a silent ``segment_sum``.  The ``padded`` entry is the ELL-layout
reducer and may only be registered for rings whose pad entries
(col=row, val=0) are add-identity contributions — true for the reals
(+,*) ring, false in general (e.g. min-plus, where mul(0, x_row) = x_row
is not +inf).  Backend selection (grblas.backends) keys on these entries.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------- fast paths

@dataclasses.dataclass(frozen=True)
class RingFastPaths:
    """Vectorized reducers a named ring is allowed to use.

    segment(values, segment_ids, num_segments) — COO segment reduction
    dense(a, axis)                             — dense container fold
    padded(contrib)                            — ELL pad-axis (axis=1) fold;
        register ONLY if the layout's pad entries reduce as add-identity.
    """

    segment: Optional[Callable] = None
    dense: Optional[Callable] = None
    padded: Optional[Callable] = None


_FAST_PATHS: Dict[str, RingFastPaths] = {}
_EMPTY_FAST_PATHS = RingFastPaths()


def register_ring_fast_paths(name: str, *, segment: Callable = None,
                             dense: Callable = None,
                             padded: Callable = None) -> None:
    """Register (or replace) the fast-path reducers for ring ``name``."""
    _FAST_PATHS[name] = RingFastPaths(segment=segment, dense=dense,
                                      padded=padded)


def fast_paths(ring) -> RingFastPaths:
    """The registered fast paths of ``ring`` (empty set if none)."""
    return _FAST_PATHS.get(getattr(ring, "name", None), _EMPTY_FAST_PATHS)


# ----------------------------------------------------------------- semirings

@dataclasses.dataclass(frozen=True)
class Semiring:
    """(add, mul, zero, one) over jnp scalars/arrays (elementwise)."""

    add: Callable  # (a, b) -> a (+) b, associative + commutative
    mul: Callable  # (a, b) -> a (*) b
    zero: float    # identity of add, annihilator of mul
    one: float     # identity of mul
    name: str = "semiring"

    def segment_reduce(self, values, segment_ids, num_segments):
        """Reduce ``values`` per segment under the add-monoid.

        Registered rings use their vectorized segment reducer; anything
        else takes a correct generic fold: a sequential O(nnz) scan that
        combines each value into its segment with ``add``, starting from
        ``zero``.  (The old behaviour — silently falling back to
        segment_sum — was wrong for any non-additive monoid.)
        """
        fp = fast_paths(self)
        if fp.segment is not None:
            return fp.segment(values, segment_ids, num_segments)
        init = jnp.full((num_segments,) + values.shape[1:], self.zero,
                        values.dtype)

        def body(acc, t):
            v, s = t
            return acc.at[s].set(self.add(acc[s], v)), None

        out, _ = jax.lax.scan(body, init, (values, segment_ids))
        return out


@dataclasses.dataclass(frozen=True)
class EdgeSemiring:
    """Semiring whose multiply sees the edge weight AND both endpoints.

    mul(w, x_src, x_dst) -> contribution of edge (dst <- src).
    The add-monoid is inherited from ``base``.

    ``kind``/``params`` are dispatch metadata for the backend registry
    (grblas.backends): a Pallas kernel can claim rings of a known kind
    (e.g. "plap_apply" with params (p, eps)) instead of tracing the
    closure.  Generic edge-semirings run the COO segment path.
    """

    base: Semiring
    edge_mul: Callable  # (w_ij, x_j, x_i) -> value
    name: str = "edge_semiring"
    kind: str = "generic"
    params: Tuple = ()


@dataclasses.dataclass(frozen=True)
class PairEdgeSemiring:
    """Edge-semiring over a PAIR of multivectors (U, Eta).

    mul(w, u_src, u_dst, e_src, e_dst) -> contribution of edge
    (dst <- src).  One SpMM pass under this ring is the matrix-free
    Newton HVP of the p-Laplacian (DESIGN.md §2, adaptation 4): the
    reweighted matrix W-hat is never materialized.
    """

    base: Semiring
    edge_mul: Callable  # (w_ij, u_j, u_i, eta_j, eta_i) -> value
    name: str = "pair_edge_semiring"
    kind: str = "generic"
    params: Tuple = ()


def _add(a, b):
    return a + b


def _mul(a, b):
    return a * b


reals_ring = Semiring(add=_add, mul=_mul, zero=0.0, one=1.0, name="reals_+x")
min_plus_ring = Semiring(add=jnp.minimum, mul=_add, zero=jnp.inf, one=0.0, name="min_+")
max_times_ring = Semiring(add=jnp.maximum, mul=_mul, zero=-jnp.inf, one=1.0, name="max_x")
boolean_ring = Semiring(
    add=jnp.logical_or, mul=jnp.logical_and, zero=False, one=True, name="bool_|&"
)


register_ring_fast_paths(
    "reals_+x",
    segment=jax.ops.segment_sum,
    dense=lambda a, axis: jnp.sum(a, axis=axis),
    padded=lambda contrib: jnp.sum(contrib, axis=1),  # pads are exact no-ops
)
register_ring_fast_paths(
    "min_+",
    segment=jax.ops.segment_min,
    dense=lambda a, axis: jnp.min(a, axis=axis),
)
register_ring_fast_paths(
    "max_x",
    segment=jax.ops.segment_max,
    dense=lambda a, axis: jnp.max(a, axis=axis),
)
register_ring_fast_paths(
    "bool_|&",
    segment=jax.ops.segment_max,   # max == or on {False, True}
    dense=lambda a, axis: jnp.any(a, axis=axis),
)


# ------------------------------------------------------- p-Laplacian rings

def phi_p(x, p, eps=0.0):
    """phi_p(x) = |x|^{p-1} sign(x), optionally eps-smoothed for p<2.

    The smoothed variant (x^2+eps)^{(p-2)/2} * x keeps the p-Laplacian
    differentiable at x=0 (needed by Newton for p<2), matching [4].
    """
    if eps == 0.0:
        return jnp.abs(x) ** (p - 1.0) * jnp.sign(x)
    return (x * x + eps) ** ((p - 2.0) / 2.0) * x


def plap_edge_semiring(p: float, eps: float = 1e-9) -> EdgeSemiring:
    """Edge-semiring computing  w_ij * phi_p(x_i - x_j)  per edge."""

    def edge_mul(w, x_src, x_dst):
        return w * phi_p(x_dst - x_src, p, eps)

    return EdgeSemiring(base=reals_ring, edge_mul=edge_mul,
                        name=f"plap_edge_p{p}", kind="plap_apply",
                        params=(p, eps))


def plap_hvp_edge_semiring(p: float, eps: float = 1e-9) -> PairEdgeSemiring:
    """Pair-edge-semiring for the matrix-free Hessian apply.

    One SpMM under this ring computes, per column,
        y_i = sum_j w_ij phi'(u_i - u_j) (eta_i - eta_j)
    i.e. the HessA part of the Newton HVP without materializing W-hat.
    The caller supplies X = (U, Eta).
    """
    from repro.core import phi as PHI

    def edge_mul(w, u_src, u_dst, e_src, e_dst):
        return w * PHI.phi_prime(u_dst - u_src, p, eps) * (e_dst - e_src)

    return PairEdgeSemiring(base=reals_ring, edge_mul=edge_mul,
                            name=f"plap_hvp_p{p}", kind="plap_hvp",
                            params=(p, eps))


def plap_hess_edge_semiring(p: float, eps: float = 1e-9) -> EdgeSemiring:
    """Deprecated pre-fused Hessian edge-semiring (kept one release).

    Superseded by ``plap_hvp_edge_semiring``: the pair-edge ring sees
    (U, Eta) directly instead of a caller-prefused w*phi'(du) weight.
    """

    def edge_mul(w_and_du, eta_src, eta_dst):
        return w_and_du * (eta_dst - eta_src)

    return EdgeSemiring(base=reals_ring, edge_mul=edge_mul,
                        name=f"plap_hess_p{p}")
