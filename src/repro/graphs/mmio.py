"""Matrix Market loader for the SuiteSparse graphs the paper uses
(delaunay_n16 .. delaunay_n23).  Zero-dependency beyond scipy."""
from __future__ import annotations

import gzip
from pathlib import Path

import numpy as np

from repro.grblas.containers import SparseMatrix


def read_matrix_market(path, build_ell: bool = True, build_bsr: bool = False,
                       block_size: int = 128) -> SparseMatrix:
    path = Path(path)
    opener = gzip.open if path.suffix == ".gz" else open
    with opener(path, "rt") as f:
        header = f.readline().strip().lower()
        symmetric = "symmetric" in header
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        n_rows, n_cols, nnz = (int(t) for t in line.split()[:3])
        data = np.loadtxt(f, max_rows=nnz, ndmin=2)
    rows = data[:, 0].astype(np.int64) - 1
    cols = data[:, 1].astype(np.int64) - 1
    vals = data[:, 2] if data.shape[1] > 2 else np.ones(len(rows))
    if symmetric:
        off = rows != cols
        rows, cols, vals = (np.concatenate([rows, cols[off]]),
                            np.concatenate([cols, rows[off]]),
                            np.concatenate([vals, vals[off]]))
    return SparseMatrix.from_coo(rows, cols, vals, (n_rows, n_cols),
                                 build_ell=build_ell, build_bsr=build_bsr,
                                 block_size=block_size)
