"""Matrix Market I/O for the SuiteSparse graphs the paper uses
(delaunay_n16 .. delaunay_n23).  Zero-dependency beyond numpy.

The reader streams the coordinate section in bounded chunks instead of
one ``np.loadtxt`` slurp: a 48M-edge file parsed in one call
materializes a giant (nnz, 3) float64 intermediate (>1 GB) *before*
the int32/float32 conversion — at the paper's 8M-node scale that
transient dominated peak host memory.  Chunked parsing keeps the
resident overhead at ``chunk`` rows.

Handles the header field matrix (``real`` / ``integer`` / ``pattern``
× ``general`` / ``symmetric``): pattern files carry no value column
(every stored entry is weight 1), symmetric files store one triangle
which is mirrored on load.
"""
from __future__ import annotations

import gzip
import warnings
from pathlib import Path

import numpy as np

from repro.grblas.containers import SparseMatrix


def _open_text(path: Path, mode: str = "rt"):
    return (gzip.open if path.suffix == ".gz" else open)(path, mode)


def read_matrix_market(path, build_ell: bool = True, build_bsr: bool = False,
                       block_size: int = 128,
                       chunk: int = 1_000_000, **layout_kwargs
                       ) -> SparseMatrix:
    """Load a ``.mtx`` / ``.mtx.gz`` coordinate file as a SparseMatrix.

    ``chunk`` bounds how many coordinate lines are parsed per pass
    (memory ceiling ~= chunk × 3 float64).  ``layout_kwargs`` pass
    through to ``from_coo`` (build_sellcs / sell_c / ...).
    """
    path = Path(path)
    with _open_text(path) as f:
        header = f.readline().strip().lower()
        if not header.startswith("%%matrixmarket"):
            raise ValueError(f"{path}: not a MatrixMarket file ({header!r})")
        fields = header.split()
        if "coordinate" not in fields:
            raise ValueError(f"{path}: only coordinate format is supported")
        symmetric = "symmetric" in fields
        pattern = "pattern" in fields
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        n_rows, n_cols, nnz = (int(t) for t in line.split()[:3])

        n_read = 0
        r_parts, c_parts, v_parts = [], [], []
        while n_read < nnz:
            take = min(chunk, nnz - n_read)
            with warnings.catch_warnings():
                # a truncated file hits EOF mid-section; we raise our own
                # error below instead of numpy's empty-input warning
                warnings.simplefilter("ignore")
                data = np.loadtxt(f, max_rows=take, ndmin=2)
            if data.shape[0] == 0:
                raise ValueError(
                    f"{path}: truncated coordinate section "
                    f"({n_read}/{nnz} entries)")
            r_parts.append(data[:, 0].astype(np.int64) - 1)
            c_parts.append(data[:, 1].astype(np.int64) - 1)
            if pattern or data.shape[1] < 3:
                v_parts.append(np.ones(data.shape[0]))
            else:
                v_parts.append(np.ascontiguousarray(data[:, 2]))
            n_read += data.shape[0]

    rows = np.concatenate(r_parts) if r_parts else np.zeros(0, np.int64)
    cols = np.concatenate(c_parts) if c_parts else np.zeros(0, np.int64)
    vals = np.concatenate(v_parts) if v_parts else np.zeros(0)
    if symmetric:
        off = rows != cols
        rows, cols, vals = (np.concatenate([rows, cols[off]]),
                            np.concatenate([cols, rows[off]]),
                            np.concatenate([vals, vals[off]]))
    return SparseMatrix.from_coo(rows, cols, vals, (n_rows, n_cols),
                                 build_ell=build_ell, build_bsr=build_bsr,
                                 block_size=block_size, **layout_kwargs)


def write_matrix_market(path, W: SparseMatrix, pattern: bool = False,
                        comment: str = "",
                        chunk: int = 1_000_000) -> None:
    """Write W's COO triple as a MatrixMarket coordinate file (general
    storage — every stored entry, no triangle folding; gzip when the
    path ends in ``.gz``).  ``pattern=True`` drops the value column.

    The coordinate section streams through ``np.savetxt`` in ``chunk``-
    row blocks — same bounded-memory contract as the reader (a 48M-edge
    per-line f-string loop costs minutes of interpreter time)."""
    path = Path(path)
    rows = np.asarray(W.rows, np.int64) + 1
    cols = np.asarray(W.cols, np.int64) + 1
    kind = "pattern" if pattern else "real"
    with _open_text(path, "wt") as f:
        f.write(f"%%MatrixMarket matrix coordinate {kind} general\n")
        if comment:
            f.write(f"% {comment}\n")
        f.write(f"{W.n_rows} {W.n_cols} {W.nnz}\n")
        for s in range(0, W.nnz, max(int(chunk), 1)):
            e = min(s + chunk, W.nnz)
            if pattern:
                np.savetxt(f, np.column_stack([rows[s:e], cols[s:e]]),
                           fmt="%d %d")
            else:
                vals = np.asarray(W.vals[s:e], np.float64)
                np.savetxt(f, np.column_stack(
                    [rows[s:e].astype(np.float64),
                     cols[s:e].astype(np.float64), vals]),
                    fmt="%d %d %.17g")
