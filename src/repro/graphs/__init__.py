from repro.graphs.generators import (
    delaunay_graph, grid_graph, ring_of_cliques, sbm_graph,
    sbm_graph_sparse, gaussian_blobs_knn,
)
from repro.graphs.mmio import read_matrix_market, write_matrix_market

__all__ = [
    "delaunay_graph", "grid_graph", "ring_of_cliques", "sbm_graph",
    "sbm_graph_sparse", "gaussian_blobs_knn",
    "read_matrix_market", "write_matrix_market",
]
from repro.graphs.partition import partition, partition_for_mesh, cut_edges

__all__ += ["partition", "partition_for_mesh", "cut_edges"]
from repro.graphs.reorder import (
    reorder, rcm_ordering, degree_ordering, bandwidth,
)

__all__ += ["reorder", "rcm_ordering", "degree_ordering", "bandwidth"]
from repro.graphs.validate import (
    Components, GraphValidationError, ValidateConfig, allocate_k,
    cluster_components, connected_components, isolated_vertices,
    quick_check, validate_graph,
)

__all__ += ["Components", "GraphValidationError", "ValidateConfig",
            "allocate_k", "cluster_components", "connected_components",
            "isolated_vertices", "quick_check", "validate_graph"]
