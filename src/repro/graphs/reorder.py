"""Bandwidth-reducing graph orderings for the SpMM hot loop.

SpMM memory traffic on every layout in grblas.containers is dominated by
the multivector gather, and gather locality is governed by the matrix
bandwidth: after a reverse Cuthill–McKee (RCM) ordering, neighbours of
row i live near i, so the ELL/SELL gather walks X almost sequentially
instead of striding the whole vector.  Degree ordering is the companion
preprocessing for SELL-C-σ: it is the σ=n sort applied to the *graph
itself*, which empties the layout's internal permutation.

The contract is permutation transparency: ``reorder`` returns a new
``SparseMatrix`` over relabeled vertices plus both direction maps, and
callers (core.psc with ``PSCConfig.reorder``) un-permute every row-
indexed output (labels, eigenvectors) before returning, so downstream
code can't observe the relabeling.  Cut metrics are permutation-
invariant by construction (tests/test_grblas_properties.py pins this).

    W2, perm, inv = reorder(W, method="rcm")
    # perm[new] = old,  inv[old] = new,  W2[i, j] == W[perm[i], perm[j]]
    labels_old = labels_new[inv]        # row data back to original ids
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import scipy.sparse as sp

from repro.grblas.containers import SparseMatrix


def rcm_ordering(W: SparseMatrix) -> np.ndarray:
    """Reverse Cuthill–McKee permutation (perm[new] = old) on the
    symmetrized structure of W."""
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    n = W.n_rows
    A = sp.csr_matrix(
        (np.ones(W.nnz, np.float32),
         (np.asarray(W.rows), np.asarray(W.cols))), shape=(n, W.n_cols))
    return np.asarray(reverse_cuthill_mckee(A, symmetric_mode=False),
                      dtype=np.int64)


def degree_ordering(W: SparseMatrix) -> np.ndarray:
    """Stable descending-degree permutation (perm[new] = old) — the
    global SELL σ-sort expressed as a graph relabeling."""
    deg = np.bincount(np.asarray(W.rows), minlength=W.n_rows)
    return np.argsort(-deg, kind="stable").astype(np.int64)


_ORDERINGS = {"rcm": rcm_ordering, "degree": degree_ordering}


def bandwidth(W: SparseMatrix) -> int:
    """max |i - j| over stored entries — the locality figure RCM reduces."""
    if W.nnz == 0:
        return 0
    return int(np.abs(np.asarray(W.rows, np.int64)
                      - np.asarray(W.cols, np.int64)).max())


def reorder(W: SparseMatrix, method: str = "rcm"
            ) -> Tuple[SparseMatrix, np.ndarray, np.ndarray]:
    """Relabel W's vertices under ``method`` ("rcm" | "degree").

    Returns (W2, perm, inv) with perm[new] = old and inv[old] = new.
    W2 is rebuilt with the same derived layouts (ELL / BSR / SELL-C-σ,
    same parameters) and dtype as W, so a Descriptor that executed on W
    executes on W2.
    """
    if method not in _ORDERINGS:
        raise ValueError(f"unknown reorder method {method!r}; "
                         f"known: {sorted(_ORDERINGS)}")
    perm = _ORDERINGS[method](W)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))

    rows = inv[np.asarray(W.rows, np.int64)]
    cols = inv[np.asarray(W.cols, np.int64)]
    W2 = SparseMatrix.from_coo(
        rows, cols, np.asarray(W.vals), (W.n_rows, W.n_cols),
        build_ell=W.ell_cols is not None,
        build_bsr=W.bsr_blocks is not None,
        block_size=W.block_size or 128,
        dtype=W.vals.dtype,
        build_sellcs=W.sell_cols is not None,
        sell_c=W.sell_c or 32,
        sell_sigma=W.sell_sigma or None,
        sell_w_align=W.sell_w_align)
    return W2, perm, inv
