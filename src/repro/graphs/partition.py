"""Graph partitioning for device placement — the framework-level use of
the paper's own algorithm (DESIGN.md §4).

``partition(W, n_parts)`` runs GrB-pGrass to get a balanced min-RCut
assignment, then ``make_row_partition(W, n_shards, assignment=...)``
places same-cluster rows on the same device so the distributed SpMM's
halo exchange touches only cut edges (see benchmarks/fig1_scaling.py's
naive-vs-partitioned projection).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

from repro.grblas.containers import SparseMatrix
from repro.core import PSCConfig, p_spectral_cluster, metrics

# device-placement partitioning is setup-time work on graphs that can be
# huge (the 8M-node regime): above this size the multilevel V-cycle
# (repro.multilevel) replaces the flat solve under multilevel="auto"
MULTILEVEL_AUTO_THRESHOLD = 20_000


def partition(W: SparseMatrix, n_parts: int, p_target: float = 1.4,
              seed: int = 0, balance: bool = True,
              cfg: Optional[PSCConfig] = None,
              multilevel: Union[bool, str] = "auto",
              solver: str = "newton") -> Tuple[np.ndarray, dict]:
    """Balanced min-RCut partition of graph W into n_parts.

    Returns (assignment (n,), info) where info carries the cut metrics
    and the per-part sizes.  ``balance=True`` rebalances overfull parts
    by moving their lowest-margin nodes (greedy, keeps near-equal sizes
    as required for device placement).

    ``multilevel``: True forces the V-cycle fast path, False forces the
    flat solve, "auto" (default) picks the V-cycle once the graph
    crosses MULTILEVEL_AUTO_THRESHOLD vertices — big graphs stop paying
    full-graph solve cost just to be placed on devices.  ``solver``
    names the continuation driver (core.solvers registry: "newton" |
    "scf" | "inverse_power") — placement is setup-time work, so the
    cheap SCF driver is a reasonable pick on big graphs.  An explicit
    ``cfg`` wins: its own ``multilevel``/``solver`` fields are left
    untouched.
    """
    if cfg is None:
        cfg = PSCConfig(k=n_parts, p_target=p_target, seed=seed,
                        newton_iters=15, tcg_iters=10, kmeans_restarts=4,
                        solver=solver)
        use_ml = (multilevel is True
                  or (multilevel == "auto"
                      and W.n_rows >= MULTILEVEL_AUTO_THRESHOLD))
        if use_ml:
            from repro.multilevel import MultilevelConfig

            cfg = dataclasses.replace(cfg, multilevel=MultilevelConfig())
    res = p_spectral_cluster(W, cfg)
    labels = np.asarray(res.labels).copy()

    if balance:
        n = W.n_rows
        target = -(-n // n_parts)
        U = np.asarray(res.U)
        # margin: distance to the assigned cluster's centroid
        for _ in range(n_parts):
            sizes = np.bincount(labels, minlength=n_parts)
            over = np.argmax(sizes)
            under = np.argmin(sizes)
            if sizes[over] <= target or sizes[under] >= target:
                break
            movable = np.nonzero(labels == over)[0]
            cen_over = U[labels == over].mean(0)
            cen_under = U[labels == under].mean(0)
            # move the nodes closest to the underfull centroid
            gain = (np.linalg.norm(U[movable] - cen_over, axis=1)
                    - np.linalg.norm(U[movable] - cen_under, axis=1))
            k_move = min(sizes[over] - target, target - sizes[under])
            labels[movable[np.argsort(-gain)[:k_move]]] = under

    info = {
        "rcut": float(metrics.rcut(W, labels, n_parts)),
        "ncut": float(metrics.ncut(W, labels, n_parts)),
        "sizes": np.bincount(labels, minlength=n_parts).tolist(),
        "p_path": res.p_path,
    }
    return labels, info


def cut_edges(W: SparseMatrix, labels: np.ndarray) -> int:
    """Number of (directed) nnz crossing the partition — the halo volume
    of the distributed SpMM under this placement."""
    r, c, _ = W.host_coo()
    return int(np.sum(labels[r] != labels[c]))


def partition_for_mesh(W: SparseMatrix, n_shards: int, *,
                       p_target: float = 1.4, seed: int = 0,
                       cfg: Optional[PSCConfig] = None,
                       multilevel: Union[bool, str] = "auto",
                       solver: str = "newton",
                       mode: str = "auto", sellcs: bool = False,
                       sell_c: int = 32):
    """Cluster W with its own algorithm, then build the halo-exchange
    row partition with cluster-aligned placement — the end-to-end
    graph-aware placement path (DESIGN.md §4).

    Runs :func:`partition` (balanced min-RCut assignment, multilevel
    fast path on big graphs), hands the assignment to
    ``grblas.dist.make_row_partition`` so same-cluster rows share a
    shard, and returns ``(Ap, labels, info)`` where ``info`` adds the
    resulting halo plan stats (mode, halo width, wire bytes per k=1
    call) to the cut metrics.  ``mode``/``sellcs``/``sell_c`` pass
    through to the partition builder.
    """
    from repro.grblas.dist import make_row_partition

    labels, info = partition(W, n_shards, p_target=p_target, seed=seed,
                             cfg=cfg, multilevel=multilevel, solver=solver)
    Ap = make_row_partition(W, n_shards, assignment=labels, mode=mode,
                            sellcs=sellcs, sell_c=sell_c)
    info = dict(info)
    info["halo"] = {"mode": Ap.mode, **Ap.wire_bytes(k=1)}
    return Ap, labels, info
