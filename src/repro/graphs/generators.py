"""Graph generators reproducing the paper's test families.

The paper evaluates on SuiteSparse `delaunay_nXX` graphs: Delaunay
triangulations of 2^r uniform points in the unit square (n=2^r nodes,
m ~= 3*2^r undirected edges => ~6*2^r stored nnz).  ``delaunay_graph(r)``
regenerates that family with scipy.spatial.Delaunay; the originals load
through mmio.read_matrix_market when available.

Also: planted-partition generators (SBM, ring-of-cliques, gaussian-blob
kNN) with known ground truth for quality tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.grblas.containers import SparseMatrix


def _symmetrize(rows, cols, vals, n):
    """Make the edge list symmetric, drop self loops and duplicates."""
    keep = rows != cols
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    v = np.concatenate([vals, vals])
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    return r[idx], c[idx], v[idx]


def _to_matrix(rows, cols, vals, n, **kw) -> SparseMatrix:
    """kw passes through to from_coo (build_ell / build_bsr / block_size /
    build_sellcs / sell_c / sell_sigma)."""
    rows, cols, vals = _symmetrize(np.asarray(rows), np.asarray(cols),
                                   np.asarray(vals, np.float64), n)
    return SparseMatrix.from_coo(rows, cols, vals, (n, n), **kw)


def delaunay_graph(r: int, seed: int = 0, locality_order: bool = True,
                   **kw) -> Tuple[SparseMatrix, np.ndarray]:
    """Delaunay triangulation of n=2^r uniform points in the unit square.

    locality_order sorts points by a Hilbert-like (Morton) key first so
    that matrix rows have spatial locality — the BSR layout then has low
    fill-in (the TPU adaptation relies on this; see DESIGN.md §2).
    Returns (W, points).
    """
    from scipy.spatial import Delaunay

    n = 2 ** r
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    if locality_order:
        # 16-bit Morton interleave
        xi = (pts[:, 0] * 65535).astype(np.uint64)
        yi = (pts[:, 1] * 65535).astype(np.uint64)
        def spread(a):
            a = (a | (a << 8)) & 0x00FF00FF
            a = (a | (a << 4)) & 0x0F0F0F0F
            a = (a | (a << 2)) & 0x33333333
            a = (a | (a << 1)) & 0x55555555
            return a
        key = spread(xi) | (spread(yi) << 1)
        pts = pts[np.argsort(key)]
    tri = Delaunay(pts)
    s = tri.simplices
    rows = np.concatenate([s[:, 0], s[:, 1], s[:, 2]])
    cols = np.concatenate([s[:, 1], s[:, 2], s[:, 0]])
    vals = np.ones(len(rows))
    return _to_matrix(rows, cols, vals, n, **kw), pts


def grid_graph(nx: int, ny: int, **kw) -> SparseMatrix:
    """4-connected nx x ny grid (Delaunay-like banded structure)."""
    idx = np.arange(nx * ny).reshape(ny, nx)
    r = np.concatenate([idx[:, :-1].ravel(), idx[:-1, :].ravel()])
    c = np.concatenate([idx[:, 1:].ravel(), idx[1:, :].ravel()])
    return _to_matrix(r, c, np.ones(len(r)), nx * ny, **kw)


def ring_of_cliques(n_cliques: int, clique_size: int, bridge_w: float = 0.1,
                    **kw) -> Tuple[SparseMatrix, np.ndarray]:
    """k cliques joined in a ring by weak bridges; ground truth = clique id."""
    n = n_cliques * clique_size
    rows, cols, vals = [], [], []
    for ci in range(n_cliques):
        base = ci * clique_size
        for a in range(clique_size):
            for b in range(a + 1, clique_size):
                rows.append(base + a); cols.append(base + b); vals.append(1.0)
        nxt = ((ci + 1) % n_cliques) * clique_size
        rows.append(base); cols.append(nxt); vals.append(bridge_w)
    truth = np.repeat(np.arange(n_cliques), clique_size)
    return _to_matrix(rows, cols, vals, n, **kw), truth


def sbm_graph(sizes, p_in: float, p_out: float, seed: int = 0,
              **kw) -> Tuple[SparseMatrix, np.ndarray]:
    """Stochastic block model with blocks `sizes` (dense Bernoulli over
    all O(n²) pairs — exact, but only viable for small n; use
    ``sbm_graph_sparse`` for the ≥100k-node bench/scaling regime)."""
    rng = np.random.default_rng(seed)
    n = int(sum(sizes))
    truth = np.repeat(np.arange(len(sizes)), sizes)
    r, c = np.triu_indices(n, k=1)
    prob = np.where(truth[r] == truth[c], p_in, p_out)
    keep = rng.random(len(r)) < prob
    return _to_matrix(r[keep], c[keep], np.ones(keep.sum()), n, **kw), truth


def sbm_graph_sparse(sizes, deg_in: float, deg_out: float, seed: int = 0,
                     w_in: float = 1.0, w_out: float = 1.0,
                     **kw) -> Tuple[SparseMatrix, np.ndarray]:
    """Sparse-regime stochastic block model, O(nnz) construction.

    Parameterized by expected degrees instead of probabilities (the
    natural units when n grows): each vertex gets ~``deg_in`` expected
    neighbours inside its block and ~``deg_out`` outside.  Edge counts
    per block pair are Poisson-sampled, endpoints uniform within the
    blocks, duplicates/self-loops dropped by ``_symmetrize`` — never
    touches the O(n²) pair grid, so 500k+-node planted partitions build
    in seconds (the multilevel bench regime, DESIGN.md §6).

    ``w_in`` / ``w_out`` weight intra- vs cross-block edges (the
    weighted planted partition, e.g. similarity graphs).  Note for
    w_in == w_out in the sparse unit-weight regime the blocks are
    locally invisible — no triangles, equal degrees — which is exactly
    the setting where *any* locality-based coarsening loses the planted
    structure while global eigenvectors keep it.
    """
    rng = np.random.default_rng(seed)
    sizes = np.asarray(sizes, np.int64)
    k = len(sizes)
    n = int(sizes.sum())
    offs = np.concatenate([[0], np.cumsum(sizes)])
    truth = np.repeat(np.arange(k), sizes)
    rows_l, cols_l, vals_l = [], [], []
    for a in range(k):
        for b in range(a, k):
            if a == b:
                mean = 0.5 * deg_in * sizes[a]
            else:
                # per-vertex deg_out spread over the other blocks in
                # proportion to their size (undirected: count each
                # unordered pair once)
                mean = deg_out * sizes[a] * sizes[b] / max(n, 1)
            m = int(rng.poisson(mean))
            if m == 0:
                continue
            rows_l.append(offs[a] + rng.integers(0, sizes[a], m))
            cols_l.append(offs[b] + rng.integers(0, sizes[b], m))
            vals_l.append(np.full(m, w_in if a == b else w_out))
    if not rows_l:
        return _to_matrix(np.zeros(0, np.int64), np.zeros(0, np.int64),
                          np.zeros(0), n, **kw), truth
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = np.concatenate(vals_l)
    return _to_matrix(rows, cols, vals, n, **kw), truth


def gaussian_blobs_knn(n_per: int, k_blobs: int, knn: int = 10,
                       sigma: float = 0.35, spread: float = 3.0,
                       seed: int = 0, **kw) -> Tuple[SparseMatrix, np.ndarray]:
    """Gaussian blobs in 2D + Gaussian-weighted kNN graph (classic spectral
    clustering benchmark; exercises weighted edges)."""
    rng = np.random.default_rng(seed)
    centers = spread * np.stack(
        [np.cos(2 * np.pi * np.arange(k_blobs) / k_blobs),
         np.sin(2 * np.pi * np.arange(k_blobs) / k_blobs)], axis=1)
    pts = np.concatenate(
        [c + sigma * rng.standard_normal((n_per, 2)) for c in centers])
    truth = np.repeat(np.arange(k_blobs), n_per)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    nbr = np.argsort(d2, axis=1)[:, :knn]
    rows = np.repeat(np.arange(len(pts)), knn)
    cols = nbr.ravel()
    vals = np.exp(-d2[rows, cols] / (2 * sigma ** 2))
    return _to_matrix(rows, cols, vals, len(pts), **kw), truth
