"""Input validation & degenerate-graph handling (DESIGN.md §9).

The pipeline's contract assumes a finite, nonneg-weighted, symmetric,
connected graph; violations don't crash — they silently produce garbage
cuts (a single NaN weight NaNs the whole continuation, a disconnected
graph hands kmeans an indicator-degenerate embedding).  This module
makes the contract checkable and, where possible, repairable:

  * ``validate_graph`` — reject (``GraphValidationError`` listing every
    violation with an actionable hint) or repair (drop non-finite /
    negative entries, symmetrize by the elementwise max) NaN/Inf
    weights, negative weights, and pattern/weight asymmetry.
  * ``connected_components`` — GraphBLAS-native BFS: frontier expansion
    is ``api.mxv`` over the boolean semiring (x = W |.& f), on-brand
    with the paper — the same dispatch/backends as the solver hot loop.
    Isolated vertices (degree 0, self-loops aside) short-circuit to
    singleton components without a BFS each.
  * ``cluster_components`` — the disconnected-graph contract: each
    component is clustered independently with ``allocate_k``'s
    proportional (largest-deficit) k split, labels re-assembled into
    the caller's vertex order, metrics computed on the full graph.
    ``k < n_components`` is a clear ValueError (a cluster can never
    span two components of a p-Laplacian embedding, so no valid
    allocation exists).

Wired into the pipeline via ``PSCConfig(validate=True | ValidateConfig)``
and into serve admission via ``ClusterServeEngine(validate_inputs=True)``
(which uses the cheap ``quick_check``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from repro.grblas import api
from repro.grblas.api import Descriptor
from repro.grblas.containers import SparseMatrix
from repro.grblas.semiring import boolean_ring

_COO = Descriptor(backend="coo")


class GraphValidationError(ValueError):
    """The graph violates the pipeline contract.  ``issues`` lists every
    violation found (not just the first)."""

    def __init__(self, issues):
        self.issues = list(issues)
        super().__init__("invalid graph: " + "; ".join(self.issues))


@dataclasses.dataclass(frozen=True)
class ValidateConfig:
    """``repair=False`` raises GraphValidationError; ``repair=True``
    drops non-finite/negative entries and symmetrizes by elementwise
    max.  ``sym_tol`` is the relative weight asymmetry tolerated before
    W != W^T counts as a violation."""

    repair: bool = False
    check_symmetry: bool = True
    sym_tol: float = 1e-6


def coerce_validate(v) -> ValidateConfig:
    if v is None or v is True:
        return ValidateConfig()
    if isinstance(v, ValidateConfig):
        return v
    raise TypeError(f"PSCConfig.validate must be None, True or a "
                    f"ValidateConfig, got {type(v).__name__}")


# ------------------------------------------------------------------ checking

def _find_issues(W: SparseMatrix, vcfg: ValidateConfig):
    rows, cols, vals = W.host_coo()
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, np.float64)
    issues: List[str] = []
    nonfinite = ~np.isfinite(vals)
    if nonfinite.any():
        issues.append(
            f"{int(nonfinite.sum())} non-finite edge weight(s) (NaN/Inf) — "
            f"a single NaN poisons the whole continuation; drop or re-fetch "
            f"these edges (repair=True drops them)")
    negative = np.isfinite(vals) & (vals < 0)
    if negative.any():
        issues.append(
            f"{int(negative.sum())} negative edge weight(s) — the "
            f"p-Laplacian functional needs W >= 0; negative affinities "
            f"make F_p unbounded below (repair=True drops them)")
    asym = False
    if vcfg.check_symmetry and W.n_rows == W.n_cols:
        n = max(W.n_cols, 1)
        k_fwd = rows * n + cols
        k_rev = cols * n + rows
        o_fwd = np.argsort(k_fwd, kind="stable")
        o_rev = np.argsort(k_rev, kind="stable")
        if not np.array_equal(k_fwd[o_fwd], k_rev[o_rev]):
            asym = True
            issues.append(
                "asymmetric pattern: some edge (i, j) has no stored "
                "(j, i) — the pipeline treats W as undirected; "
                "symmetrize first (repair=True uses max(W, W^T))")
        else:
            scale = float(np.abs(vals).max()) if len(vals) else 0.0
            dv = np.abs(vals[o_fwd] - vals[o_rev])
            if len(vals) and dv.max() > vcfg.sym_tol * (scale + 1e-300):
                asym = True
                issues.append(
                    f"asymmetric weights: max |W_ij - W_ji| = "
                    f"{dv.max():.3g} exceeds sym_tol * max|W| — "
                    f"symmetrize first (repair=True uses max(W, W^T))")
    return issues, (rows, cols, vals), asym


def validate_graph(W: SparseMatrix,
                   vcfg: Optional[ValidateConfig] = None) -> SparseMatrix:
    """Check (or repair) W against the pipeline contract.  Returns W
    unchanged when healthy, the repaired graph under ``repair=True``,
    and raises :class:`GraphValidationError` otherwise."""
    vcfg = coerce_validate(vcfg)
    issues, (rows, cols, vals), asym = _find_issues(W, vcfg)
    if not issues:
        return W
    if not vcfg.repair:
        raise GraphValidationError(issues)
    keep = np.isfinite(vals) & (vals >= 0)
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    if vcfg.check_symmetry and W.n_rows == W.n_cols:
        # symmetrize by elementwise max: stack both directed copies and
        # keep the larger weight per directed key (max(W, W^T) preserves
        # every surviving edge, unlike the average, which halves
        # one-sided insertions)
        r2 = np.concatenate([rows, cols])
        c2 = np.concatenate([cols, rows])
        v2 = np.concatenate([vals, vals])
        keys = r2 * max(W.n_cols, 1) + c2
        order = np.lexsort((-v2, keys))     # per key: largest val first
        keys, r2, c2, v2 = keys[order], r2[order], c2[order], v2[order]
        first = np.ones(len(keys), bool)
        first[1:] = keys[1:] != keys[:-1]
        rows, cols, vals = r2[first], c2[first], v2[first]
    return SparseMatrix.from_coo(rows, cols, vals,
                                 (W.n_rows, W.n_cols), dtype=W.vals.dtype)


def quick_check(W: SparseMatrix) -> Optional[str]:
    """The cheap admission-time check (serve path): one finiteness and
    one sign pass, no symmetry sort.  Returns the issue or None."""
    vals = np.asarray(W.host_coo()[2], np.float64)
    nonfinite = int((~np.isfinite(vals)).sum())
    if nonfinite:
        return (f"{nonfinite} non-finite edge weight(s) (NaN/Inf) in the "
                f"submitted graph")
    negative = int((vals < 0).sum())
    if negative:
        return f"{negative} negative edge weight(s) in the submitted graph"
    return None


# ---------------------------------------------------------------- components

@dataclasses.dataclass(frozen=True)
class Components:
    """Connected-component labeling: ``labels[v]`` is v's component id
    (0..n_components-1, discovery order), ``sizes[c]`` its vertex
    count."""

    labels: np.ndarray
    n_components: int
    sizes: np.ndarray


def isolated_vertices(W: SparseMatrix) -> np.ndarray:
    """Vertices with no off-diagonal incident edge (self-loops don't
    connect anything)."""
    rows, cols, _ = W.host_coo()
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    off = rows != cols
    has = np.zeros(W.n_rows, bool)
    has[rows[off]] = True
    has[cols[off]] = True
    return np.where(~has)[0]


def connected_components(W: SparseMatrix,
                         desc: Descriptor = _COO) -> Components:
    """Connected components by GraphBLAS BFS: each frontier expansion is
    one ``api.mxv`` (plus ``api.vxm``, in case the caller hands us an
    asymmetric pattern) over the boolean semiring — the classic
    x = W |.& f frontier product.  Host loop over components; isolated
    vertices are labeled without any BFS."""
    n = W.n_rows
    iso = isolated_vertices(W)
    labels = np.full(n, -1, np.int64)
    labels[iso] = np.arange(len(iso))
    comp = len(iso)
    while True:
        unvisited = np.where(labels < 0)[0]
        if not len(unvisited):
            break
        seed = int(unvisited[0])
        members = np.zeros(n, bool)
        members[seed] = True
        frontier = members.copy()
        while frontier.any():
            f = jnp.asarray(frontier)
            nxt = np.array(api.mxv(W, f, boolean_ring, desc=desc))
            nxt |= np.asarray(api.vxm(f, W, boolean_ring, desc=desc))
            frontier = nxt & ~members
            members |= frontier
        labels[members] = comp
        comp += 1
    return Components(labels=labels, n_components=comp,
                      sizes=np.bincount(labels, minlength=comp))


def allocate_k(sizes, k: int) -> np.ndarray:
    """Split a cluster budget k across components proportionally to
    their vertex counts: every component gets at least 1 (a cluster can
    never span two components), no component more clusters than
    vertices, remaining units go to the largest proportional deficit.
    Raises ValueError when no valid allocation exists."""
    sizes = np.asarray(sizes, np.int64)
    c = len(sizes)
    n = int(sizes.sum())
    if k < c:
        raise ValueError(
            f"k={k} but the graph has {c} connected components — a "
            f"p-spectral cluster cannot span two components, so every "
            f"component needs its own cluster: raise k to >= {c}, drop "
            f"isolated vertices, or repair connectivity first")
    if k > n:
        raise ValueError(f"k={k} exceeds the number of vertices n={n}")
    alloc = np.ones(c, np.int64)
    quota = k * sizes / max(n, 1)
    for _ in range(k - c):
        deficit = quota - alloc
        deficit[alloc >= sizes] = -np.inf
        alloc[int(np.argmax(deficit))] += 1
    return alloc


def cluster_components(W: SparseMatrix, cfg,
                       comps: Optional[Components] = None):
    """Cluster a disconnected graph per component (the ``PSCConfig.
    validate`` dispatch): extract each component's induced subgraph,
    run the pipeline with its ``allocate_k`` share, and re-assemble
    labels/U in the caller's vertex order.  Metrics are computed on the
    FULL graph (cross-component cut is zero by construction, so RCut is
    the size-weighted sum of the per-component cuts)."""
    import dataclasses as _dc

    from repro.core import metrics as _metrics
    from repro.core import psc as _psc

    if comps is None:
        comps = connected_components(W)
    rows, cols, vals = W.host_coo()
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    n, k = W.n_rows, cfg.k
    alloc = allocate_k(comps.sizes, k)
    labels_out = np.zeros(n, np.int64)
    U_out = np.zeros((n, k), np.float64)
    summaries: List[dict] = []
    p_path: List[float] = []
    fvals: List[float] = []
    hvps: List[int] = []
    reports: List[object] = []
    offset = 0
    for c in range(comps.n_components):
        idx = np.where(comps.labels == c)[0]
        nc, kc = len(idx), int(alloc[c])
        if kc >= nc or kc == 1:
            # closed-form degenerate split within the component
            labels_out[idx] = offset + (np.arange(nc) if kc >= nc else 0)
            span = np.arange(min(kc, nc))
            U_out[idx[span], offset + span] = 1.0
            if kc == 1:
                U_out[idx, offset] = 1.0 / np.sqrt(nc)
            summaries.append({"n": nc, "k": kc, "rcut": None})
        else:
            inv = np.full(n, -1, np.int64)
            inv[idx] = np.arange(nc)
            m = comps.labels[rows] == c
            Wc = SparseMatrix.from_coo(inv[rows[m]], inv[cols[m]], vals[m],
                                       (nc, nc), dtype=W.vals.dtype)
            sub_cfg = _dc.replace(cfg, k=kc, validate=None, init_U=None)
            res = _psc.p_spectral_cluster(Wc, sub_cfg)
            labels_out[idx] = np.asarray(res.labels) + offset
            U_out[idx, offset:offset + kc] = np.asarray(res.U)
            summaries.append({"n": nc, "k": kc, "rcut": res.rcut})
            p_path += list(res.p_path)
            fvals += list(res.fvals)
            hvps += list(res.hvp_counts)
            reports += list(res.reports or [])
        offset += kc
    rcut = float(_metrics.rcut(W, labels_out, k))
    ncut = float(_metrics.ncut(W, labels_out, k))
    return _psc.PSCResult(
        labels=labels_out, U=jnp.asarray(U_out, jnp.float32),
        rcut=rcut, ncut=ncut, p_path=p_path, fvals=fvals,
        hvp_counts=hvps, init_labels=None, init_rcut=float("nan"),
        reports=reports, components=summaries)
