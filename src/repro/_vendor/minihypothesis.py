"""A minimal, zero-dependency stand-in for the slice of the hypothesis
API the property suites use (``given`` / ``settings`` /
``strategies.floats|integers|sampled_from``).

The pinned local image does not ship ``hypothesis`` (and nothing may be
pip-installed into it), but the algebraic property suite should gate
locally, not only in CI.  When the real library is importable the test
modules use it — this module is the ``except ImportError`` branch only.

Semantics: deterministic seeded random search.  Each ``@given`` test
runs ``max_examples`` times (default 20, override via ``@settings``)
with draws from a PCG64 stream seeded by the test's qualified name, so
a failure reproduces exactly on re-run.  Boundary values are emitted
first (min/max/zero for numeric strategies, every element in turn for
``sampled_from``) — the cheap half of hypothesis' shrinking heuristic;
there is no shrinking proper and no example database.
"""
from __future__ import annotations


import hashlib
from typing import Any, List, Sequence

import numpy as np

__all__ = ["given", "settings", "strategies", "HealthCheck"]


class HealthCheck:
    """Placeholder namespace: suppress_health_check lists accept these."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"


class _Strategy:
    """One value source: fixed boundary examples first, then random."""

    def __init__(self, boundary: Sequence[Any], draw):
        self._boundary = list(boundary)
        self._draw = draw

    def example_at(self, i: int, rng: np.random.Generator):
        if i < len(self._boundary):
            return self._boundary[i]
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def floats(min_value=None, max_value=None, allow_nan=False,
               allow_infinity=False, width=64) -> _Strategy:
        lo = -1e9 if min_value is None else float(min_value)
        hi = 1e9 if max_value is None else float(max_value)
        if width == 32:
            lo, hi = float(np.float32(lo)), float(np.float32(hi))
        mid = 0.0 if lo <= 0.0 <= hi else 0.5 * (lo + hi)
        cast = (lambda x: float(np.float32(x))) if width == 32 else float

        def draw(rng):
            return cast(rng.uniform(lo, hi))

        return _Strategy([lo, hi, mid], draw)

    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1) -> _Strategy:
        lo, hi = int(min_value), int(max_value)

        def draw(rng):
            return int(rng.integers(lo, hi + 1))

        return _Strategy([lo, hi], draw)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)

        def draw(rng):
            # rng-driven, NOT a shared cycle: several sampled_from
            # strategies in one @given must explore the cross product,
            # not only index-aligned (diagonal) combinations
            return elements[int(rng.integers(len(elements)))]

        # boundary pass = each element once, then random combinations
        return _Strategy(elements, draw)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy([False, True], lambda rng: bool(rng.integers(0, 2)))


strategies = _Strategies()


def settings(max_examples: int = 20, deadline=None, **_ignored):
    """Decorator: override the runner's example budget.  ``deadline`` and
    unknown kwargs are accepted and ignored (per-example timing is a
    hypothesis feature this stand-in does not replicate)."""

    def deco(fn):
        fn._mh_max_examples = int(max_examples)
        return fn

    return deco


def given(**strats):
    """Decorator: run the test once per drawn example.

    Keyword strategies only (the style the repo's suites use).  The
    random stream is seeded from the test's qualified name, so runs are
    reproducible; the failing example's kwargs are attached to the
    raised AssertionError's message.
    """

    def deco(fn):
        # a zero-arg runner: pytest must not see the strategy parameters
        # in the signature (it would resolve them as fixtures), so no
        # functools.wraps — name/doc copied by hand
        def runner():
            n = getattr(runner, "_mh_max_examples", 20)
            seed = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:8],
                "little")
            rng = np.random.default_rng(seed)
            names: List[str] = sorted(strats)
            for i in range(n):
                drawn = {k: strats[k].example_at(i, rng) for k in names}
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} failed on example {i}: "
                        f"{drawn!r}") from e

        # NOTE: deliberately no ``runner.hypothesis`` attribute — pytest
        # special-cases that name and would look for ``.inner_test``
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._mh_max_examples = 20
        return runner

    return deco
