"""Vendored zero-dependency fallbacks for optional dev dependencies."""
