from repro.data.tokens import SyntheticTokens, batch_specs

__all__ = ["SyntheticTokens", "batch_specs"]
