"""Deterministic synthetic token pipeline.

Stateless-by-step: batch(step) = f(seed, step) via PRNG fold_in, so an
elastic resume at step k on any DP width reproduces the exact stream —
no data-loader state in checkpoints, no skipped/replayed batches.
(The same property a production loader gets from index-based sharding.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


class SyntheticTokens:
    """Markov-ish synthetic LM data: structured enough that loss falls."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.key = jax.random.PRNGKey(seed)

    def batch_at(self, step: int):
        k = jax.random.fold_in(self.key, step)
        ks = jax.random.split(k, 4)
        v = self.cfg.vocab
        # piecewise-linear token process: next ~ prev + small step (mod v)
        start = jax.random.randint(ks[0], (self.batch, 1), 0, v)
        drift = jax.random.randint(ks[1], (self.batch, self.seq), -3, 4)
        toks = (start + jnp.cumsum(drift, axis=1)) % v
        tokens = toks.astype(jnp.int32)
        labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-100)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.family == "encdec":
            out["enc_frames"] = jax.random.normal(
                ks[2], (self.batch, self.cfg.enc_seq, self.cfg.d_model),
                jnp.float32)
        if self.cfg.family == "vlm":
            out["extra_embeds"] = jax.random.normal(
                ks[3], (self.batch, self.cfg.vis_seq, self.cfg.d_model),
                jnp.float32)
        return out


def batch_specs(cfg: ArchConfig, batch: int, seq: int,
                dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct stand-ins for one training batch (dry-run)."""
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "encdec":
        out["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), dtype)
    if cfg.family == "vlm":
        out["extra_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vis_seq, cfg.d_model), dtype)
    return out
