"""Fault tolerance for long multi-pod runs.

Mechanisms (all exercised in tests/test_fault_tolerance.py):

1. Preemption handling — SIGTERM/SIGINT set a flag; the host loop
   checkpoints at the next step boundary and exits cleanly (TPU
   maintenance events surface as SIGTERM in GKE/GCE).
2. Crash-restart — ``run_with_restarts`` wraps the step loop: on an
   exception it restores the latest checkpoint and continues, with
   exponential backoff and a retry budget.  Combined with atomic
   checkpoints this gives at-most-one-step loss of work.
3. Straggler detection — ``StepWatchdog`` records per-step wall time and
   flags steps slower than ``factor``× the trailing median; on real
   pods this is the signal to trigger re-sharding away from a slow host
   (the elastic restore path), here it logs and counts.
4. Elastic resume — checkpoints store full logical arrays; restoring
   onto a smaller/larger mesh re-shards via device_put (see
   checkpoint.py).  The data pipeline is stateless-by-step (PRNG
   fold_in), so resuming at step k on a different DP width replays no
   data and skips none.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, Optional

from repro.train.checkpoint import CheckpointManager


class PreemptionGuard:
    """Installs signal handlers; ``should_stop`` is polled by the loop."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except (ValueError, OSError):   # non-main thread etc.
                pass

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StepWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times = []
        self.straggler_steps = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        slow = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            slow = seconds > self.factor * med
            if slow:
                self.straggler_steps.append((step, seconds, med))
        self.times.append(seconds)
        return slow


def run_with_restarts(loop_body: Callable[[int, object], object],
                      state, manager: CheckpointManager,
                      start_step: int, end_step: int,
                      save_every: int = 100,
                      max_restarts: int = 5,
                      guard: Optional[PreemptionGuard] = None,
                      on_restore: Optional[Callable] = None,
                      backoff_base: float = 0.01,
                      backoff_cap: float = 2.0,
                      sleep_fn: Callable[[float], None] = time.sleep):
    """Run ``state = loop_body(step, state)`` with checkpoint/restart.

    loop_body must be side-effect free w.r.t. recovery (all state in
    ``state``).  Returns (final_step, state, report); the report
    records every restart's exception (``errors`` / ``last_error``) and
    what each retry restored from (``restored_from``: a checkpoint step,
    or "initial" for the explicit no-checkpoint reset — before the
    first save a crash rewinds to the CALLER's (start_step, state), not
    to whatever half-advanced state the failed iteration left behind).
    Backoff is ``min(backoff_base * 2^restarts, backoff_cap)`` seconds
    via ``sleep_fn`` (injectable, so tests run deterministic and
    sleep-free)."""
    report = {"restarts": 0, "preempted": False, "saved_at": [],
              "errors": [], "last_error": None, "restored_from": []}
    state0 = state
    step = start_step
    restarts = 0
    while step < end_step:
        try:
            state = loop_body(step, state)
            step += 1
            if step % save_every == 0 or step == end_step:
                manager.save(step, state)
                report["saved_at"].append(step)
            if guard is not None and guard.should_stop:
                manager.save(step, state)
                report["saved_at"].append(step)
                report["preempted"] = True
                break
        except KeyboardInterrupt:
            raise
        except Exception as exc:
            restarts += 1
            report["restarts"] = restarts
            report["errors"].append(f"step {step}: "
                                    f"{type(exc).__name__}: {exc}")
            report["last_error"] = exc
            if restarts > max_restarts:
                raise
            sleep_fn(min(backoff_base * 2.0 ** restarts, backoff_cap))
            latest = manager.latest()
            if latest is not None:
                state, _ = manager.restore(latest, state)
                step = latest
                report["restored_from"].append(latest)
            else:
                # no checkpoint exists yet: the retry must not continue
                # from the possibly-corrupt mid-crash state — reset
                # explicitly to the caller's initial (step, state)
                state = state0
                step = start_step
                report["restored_from"].append("initial")
            if on_restore is not None:
                state = on_restore(state)
    return step, state, report
