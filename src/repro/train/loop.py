"""Training step factory: loss -> grads -> clip -> optimizer, with
optional microbatch gradient accumulation (scan) and int8 gradient
compression (pure-DP meshes).

The returned step is a plain function of (params, opt_state, batch) so
the launcher can jit it with explicit in/out shardings (the dry-run
path) or call it eagerly on CPU (examples/tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.train import optimizer as OPT


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    microbatch: int = 1          # grad-accumulation factor
    aux_weight: float = 0.01     # MoE load-balance loss weight
    weight_decay: float = 0.1
    grad_compression: str = "none"   # none | int8 (error-feedback psum)
    compression_axis: str = "data"   # mesh axis the compressed psum crosses


def lr_schedule(tc: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(tc.warmup_steps, 1)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * jnp.minimum(warm, 1.0) * (0.1 + 0.9 * cos)


def make_optimizer(tc: TrainConfig) -> OPT.Optimizer:
    if tc.optimizer == "adamw":
        return OPT.adamw(weight_decay=tc.weight_decay)
    return OPT.adafactor(weight_decay=0.0)


def make_train_step(cfg: ArchConfig, tc: TrainConfig, mesh=None,
                    opt: Optional[OPT.Optimizer] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch: {tokens, labels[, enc_frames, extra_embeds]}."""
    opt = opt or make_optimizer(tc)

    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch["tokens"], batch["labels"],
                         mesh=mesh,
                         extra_embeds=batch.get("extra_embeds"),
                         enc_frames=batch.get("enc_frames"),
                         aux_weight=tc.aux_weight)

    def grads_of(params, batch):
        if tc.microbatch <= 1:
            (loss, (nll, aux)), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, nll, aux, grads

        # microbatch accumulation: split the batch leading dim and scan;
        # peak activation memory drops ~microbatch-fold
        def split(x):
            return x.reshape(tc.microbatch, x.shape[0] // tc.microbatch,
                             *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, microbatch):
            acc, loss_a, nll_a, aux_a = carry
            (loss, (nll, aux)), g = jax.value_and_grad(
                loss_of, has_aux=True)(params, microbatch)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_a + loss, nll_a + nll, aux_a + aux), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss, nll, aux), _ = jax.lax.scan(
            body, (zeros, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), mb)
        inv = 1.0 / tc.microbatch
        g = jax.tree.map(lambda x: x * inv, g)
        return loss * inv, nll * inv, aux * inv, g

    def finish_step(grads, opt_state, params, loss, nll, aux):
        grads, gnorm = OPT.clip_by_global_norm(grads, tc.clip_norm)
        step_no = (opt_state.count if hasattr(opt_state, "count")
                   else jnp.zeros((), jnp.int32))
        lr = lr_schedule(tc, step_no)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "nll": nll, "aux": aux,
                   "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    if tc.grad_compression == "int8":
        # Error-feedback int8 gradient psum (repro.dist.compression):
        # the residual state rides as an extra step argument, so the
        # compressed step is (params, opt_state, err, batch) ->
        # (params, opt_state, err, metrics).  Seed err with
        # init_compression_state(params).
        from repro.dist.compression import compressed_psum_tree
        if mesh is None:
            raise ValueError("grad_compression='int8' needs a mesh "
                             "(the psum axis lives on it)")

        def train_step(params, opt_state, err, batch):
            loss, nll, aux, grads = grads_of(params, batch)
            grads, err = compressed_psum_tree(grads, err, mesh,
                                              tc.compression_axis)
            params, opt_state, metrics = finish_step(
                grads, opt_state, params, loss, nll, aux)
            return params, opt_state, err, metrics

        return train_step
    if tc.grad_compression != "none":
        raise ValueError(f"unknown grad_compression "
                         f"{tc.grad_compression!r}; use 'none' or 'int8'")

    def train_step(params, opt_state, batch):
        loss, nll, aux, grads = grads_of(params, batch)
        return finish_step(grads, opt_state, params, loss, nll, aux)

    return train_step


def init_compression_state(params):
    """Zero error-feedback residuals for a grad_compression='int8' step."""
    from repro.dist.compression import init_error_feedback
    return init_error_feedback(params)
