from repro.train.optimizer import adamw, adafactor, get_optimizer, Optimizer
from repro.train.loop import (TrainConfig, make_train_step, lr_schedule,
                              make_optimizer, init_compression_state)
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (
    PreemptionGuard, StepWatchdog, run_with_restarts)

__all__ = ["adamw", "adafactor", "get_optimizer", "Optimizer",
           "TrainConfig", "make_train_step", "lr_schedule", "make_optimizer",
           "init_compression_state",
           "CheckpointManager", "PreemptionGuard", "StepWatchdog",
           "run_with_restarts"]
