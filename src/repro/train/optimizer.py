"""Optimizers from scratch (no optax): AdamW and Adafactor.

Functional API: ``opt.init(params) -> state``; ``opt.update(grads, state,
params, lr) -> (new_params, new_state)``.  State trees mirror the param
tree, so the same PartitionSpecs shard optimizer state (Zero-style).

Adafactor (Shazeer & Stern 2018) keeps factored second moments for
params with ndim >= 2 (row + col accumulators instead of a full moment
tensor) — the memory trick that lets the 398B/671B configs fit a v5e
pod (see EXPERIMENTS.md §Dry-run bytes/device).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), n


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)
    name: str = "opt"


def _map_like(grads, fn, *other_trees):
    """Map fn(g_leaf, *other_leaves) over grads' structure; other trees are
    flattened only down to grads' leaves (their leaves may be pytrees,
    e.g. FactoredMoment).  Returns trees of each output component."""
    g_leaves, treedef = jax.tree.flatten(grads)
    others = [treedef.flatten_up_to(t) for t in other_trees]
    outs = [fn(g, *extras) for g, *extras in zip(g_leaves, *others)]
    n_out = len(outs[0])
    return tuple(jax.tree.unflatten(treedef, [o[i] for o in outs])
                 for i in range(n_out))


# ----------------------------------------------------------------- AdamW

class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
        return AdamState(mu=jax.tree.map(f32, params),
                         nu=jax.tree.map(f32, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p - lr * step).astype(p.dtype), m, v

        new_p, new_m, new_v = _map_like(grads, upd, state.mu, state.nu, params)
        return new_p, AdamState(mu=new_m, nu=new_v, count=c)

    return Optimizer(init=init, update=update, name="adamw")


# -------------------------------------------------------------- Adafactor

class FactoredMoment(NamedTuple):
    row: jnp.ndarray     # mean of squares over the last axis
    col: jnp.ndarray     # mean of squares over the second-to-last axis


class AdafactorState(NamedTuple):
    moments: Any         # FactoredMoment for ndim>=2, full nu otherwise
    count: jnp.ndarray


def adafactor(decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    def init(params):
        def one(p):
            if p.ndim >= 2:
                return FactoredMoment(
                    row=jnp.zeros(p.shape[:-1], jnp.float32),
                    col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return jnp.zeros(p.shape, jnp.float32)
        return AdafactorState(moments=jax.tree.map(one, params),
                              count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        beta = 1.0 - c.astype(jnp.float32) ** -decay

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if isinstance(m, FactoredMoment):
                row = beta * m.row + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * m.col + (1 - beta) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(row, axis=-1, keepdims=True)
                vhat = (row[..., None] / jnp.maximum(row_mean[..., None], eps)
                        ) * col[..., None, :]
                step = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
                new_m = FactoredMoment(row=row, col=col)
            else:
                nu = beta * m + (1 - beta) * g2
                step = g * jax.lax.rsqrt(jnp.maximum(nu, eps))
                new_m = nu
            # update clipping (RMS of step <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p - lr * step).astype(p.dtype), new_m

        new_p, new_m = _map_like(grads, upd, state.moments, params)
        return new_p, AdafactorState(moments=new_m, count=c)

    return Optimizer(init=init, update=update, name="adafactor")


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise KeyError(name)
