"""Sharded, atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json      tree structure + shapes + dtypes + mesh
            <leaf-path>.npy    one file per param/opt leaf (host arrays)

Atomicity: written into ``step_<N>.tmp`` then os.rename'd — a crashed
save can never shadow a good checkpoint.  ``latest()`` ignores tmp dirs.

Elasticity: leaves are stored as FULL logical arrays (gathered from the
mesh on save).  Restore re-shards onto whatever mesh/device-count the
resumed job has — a resume after losing a pod (or doubling one) works
by construction.  For multi-host pods where a full gather is infeasible
the same manifest format supports per-shard files (``shard_k`` suffix);
this process-local writer covers the single-controller case used here.

Fault-tolerance integration: train/fault_tolerance.py calls ``save`` on
preemption signals and ``restore_latest`` on restart.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import numpy as np
import jax


def _flatten_with_paths(tree):
    from repro.compat import tree_flatten_with_path
    flat, treedef = tree_flatten_with_path(tree)
    def pstr(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)
    return [(pstr(p), leaf) for p, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves, _ = _flatten_with_paths(tree)
        manifest = {"step": step, "time": time.time(),
                    "extra": extra or {}, "leaves": {}}
        for name, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            fn = name.replace("/", "__") + ".npy"
            np.save(tmp / fn, arr)
            manifest["leaves"][name] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f, indent=1)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self):
        out = []
        for d in self.dir.iterdir():
            if d.is_dir() and d.name.startswith("step_") \
                    and not d.name.endswith(".tmp"):
                try:
                    out.append(int(d.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any, shardings=None) -> Any:
        """Restore into the structure of ``like`` (params/opt_state tree).
        ``shardings``: optional matching tree of NamedSharding — leaves are
        device_put onto them (elastic re-shard)."""
        d = self.dir / f"step_{step}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        leaves, treedef = _flatten_with_paths(like)
        shard_leaves = None
        if shardings is not None:
            shard_leaves = [s for _, s in _flatten_with_paths(shardings)[0]]
        out = []
        for i, (name, leaf) in enumerate(leaves):
            info = manifest["leaves"][name]
            arr = np.load(d / info["file"])
            target_dtype = (leaf.dtype if hasattr(leaf, "dtype")
                            else arr.dtype)
            arr = arr.astype(target_dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, like: Any, shardings=None
                       ) -> Tuple[Optional[int], Any, dict]:
        s = self.latest()
        if s is None:
            return None, like, {}
        tree, extra = self.restore(s, like, shardings)
        return s, tree, extra
