"""Unified model assembly: abstract params, train forward, prefill and
decode for every assigned family (dense / moe / ssm / hybrid / encdec /
vlm).

Layer stacking: homogeneous runs of blocks are stacked on a leading
``layers`` axis and executed with lax.scan (small HLO => fast compile,
remat-friendly).  Heterogeneous structures (jamba groups, whisper
enc/dec, deepseek leading dense layers) are split into several
homogeneous scans.

Every forward returns (hidden_states, aux) where aux carries the MoE
load-balancing loss.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models import attention as ATT
from repro.models import moe as MOE
from repro.models import mamba2 as SSM
from repro.models.layers import PAb
from repro.dist.sharding import constrain


# ================================================================ abstract

def _norm_ab(cfg):
    return (L.layernorm_ab(cfg.d_model) if cfg.norm == "layernorm"
            else L.rmsnorm_ab(cfg.d_model))


def _apply_norm(cfg, p, x):
    return (L.layernorm(p, x, cfg.norm_eps) if cfg.norm == "layernorm"
            else L.rmsnorm(p, x, cfg.norm_eps))


def _attn_block_ab(cfg, ffn: str, cross: bool = False):
    blk = {"ln1": _norm_ab(cfg), "ln2": _norm_ab(cfg)}
    blk["attn"] = ATT.mla_ab(cfg) if cfg.mla else ATT.gqa_ab(cfg)
    if cross:
        blk["ln_x"] = _norm_ab(cfg)
        blk["xattn"] = ATT.gqa_ab(cfg)
    if ffn == "moe":
        blk["ffn"] = MOE.moe_ab(cfg)
    elif ffn == "mlp":
        blk["ffn"] = L.mlp_ab(cfg.d_model, cfg.d_ff, cfg.gated)
    return blk


def _mamba_block_ab(cfg, ffn: Optional[str]):
    blk = {"ln1": _norm_ab(cfg), "mamba": SSM.mamba_ab(cfg)}
    if ffn:
        blk["ln2"] = _norm_ab(cfg)
        blk["ffn"] = (MOE.moe_ab(cfg) if ffn == "moe"
                      else L.mlp_ab(cfg.d_model, cfg.d_ff, cfg.gated))
    return blk


def _stack_ab(tree, n):
    """Stack an abstract tree n times along a new leading ``layers`` axis."""
    return jax.tree.map(
        lambda ab: PAb((n,) + ab.shape, ("layers",) + ab.logical,
                       ab.init, ab.scale),
        tree, is_leaf=L.is_pab)


def _jamba_group_ab(cfg):
    """One jamba group: pattern cfg.hybrid_group; MoE at odd positions."""
    group = {}
    for i, kind in enumerate(cfg.hybrid_group):
        ffn = "moe" if (i % 2 == 1) else "mlp"
        if kind == "m":
            group[f"sub{i}"] = _mamba_block_ab(cfg, ffn)
        else:
            group[f"sub{i}"] = _attn_block_ab(cfg, ffn)
    return group


def abstract_params(cfg: ArchConfig):
    p: dict[str, Any] = {
        "embed": L.embedding_ab(cfg.vocab, cfg.d_model,
                                pad_to=cfg.vocab_pad_to),
        "final_norm": _norm_ab(cfg),
    }
    if cfg.pos_embedding == "learned":
        p["pos_embed"] = {"table": PAb((cfg.max_position, cfg.d_model),
                                       (None, "embed"), "normal", 0.02)}
    if cfg.family in ("dense", "vlm"):
        p["blocks"] = _stack_ab(_attn_block_ab(cfg, "mlp"), cfg.n_layers)
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense
        if nd:
            p["dense_blocks"] = _stack_ab(_attn_block_ab(cfg, "mlp"), nd)
        p["blocks"] = _stack_ab(_attn_block_ab(cfg, "moe"), cfg.n_layers - nd)
    elif cfg.family == "ssm":
        p["blocks"] = _stack_ab(_mamba_block_ab(cfg, None), cfg.n_layers)
    elif cfg.family == "hybrid":
        g = len(cfg.hybrid_group)
        p["blocks"] = _stack_ab(_jamba_group_ab(cfg), cfg.n_layers // g)
    elif cfg.family == "encdec":
        p["enc_pos"] = {"table": PAb((cfg.enc_seq, cfg.d_model),
                                     (None, "embed"), "normal", 0.02)}
        p["enc_blocks"] = _stack_ab(_attn_block_ab(cfg, "mlp"), cfg.enc_layers)
        p["enc_norm"] = _norm_ab(cfg)
        p["blocks"] = _stack_ab(_attn_block_ab(cfg, "mlp", cross=True),
                                cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return p


def init_params(cfg: ArchConfig, key, dtype=None):
    return L.init_tree(abstract_params(cfg), key,
                       dtype or jnp.dtype(cfg.params_dtype))


def param_shardings(cfg: ArchConfig, mesh):
    return L.spec_tree(abstract_params(cfg), mesh)


def param_shapes(cfg: ArchConfig, dtype=None):
    return L.shape_tree(abstract_params(cfg),
                        dtype or jnp.dtype(cfg.params_dtype))


# ================================================================= blocks

def _attn_block(cfg, blk, x, positions, mesh, causal=True, enc_out=None,
                collect=False):
    """Pre-norm attention block (train/prefill path)."""
    h = _apply_norm(cfg, blk["ln1"], x)
    piece = None
    if cfg.mla:
        if collect:
            h, lat = ATT.mla_train(cfg, blk["attn"], h, positions, mesh,
                                   return_latent=True)
            piece = ATT.MLACache(c_kv=lat[0], k_rope=lat[1])
        else:
            h = ATT.mla_train(cfg, blk["attn"], h, positions, mesh)
    else:
        if collect:
            h, kv = ATT.gqa_train(cfg, blk["attn"], h, positions, mesh,
                                  causal=causal, return_kv=True)
            piece = ATT.KVCache(k=kv[0], v=kv[1])
        else:
            h = ATT.gqa_train(cfg, blk["attn"], h, positions, mesh,
                              causal=causal)
    x = x + h
    if enc_out is not None:
        h = _apply_norm(cfg, blk["ln_x"], x)
        h = ATT.gqa_train(cfg, blk["xattn"], h, positions, mesh,
                          causal=False, kv_override=enc_out)
        x = x + h
    h = _apply_norm(cfg, blk["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in blk and "router" in blk.get("ffn", {}):
        h, aux = MOE.moe_block(cfg, blk["ffn"], h, mesh)
    elif "ffn" in blk:
        h = L.mlp(blk["ffn"], h, cfg.act, cfg.gated)
    out = x + h
    if mesh is not None and out.shape[1] > 1:
        # sequence parallelism between blocks (§Perf E2b): the psum-
        # producing projections reduce-scatter into seq shards instead
        # of all-reducing into replicas; attention/MoE gather on demand
        out = constrain(out, mesh, ("batch", "seq_sp", None))
    if collect:
        return out, aux, piece
    return out, aux


def _mamba_block(cfg, blk, x, mesh, collect=False):
    h = _apply_norm(cfg, blk["ln1"], x)
    piece = None
    if collect:
        h, piece = SSM.mamba_train(cfg, blk["mamba"], h, mesh,
                                   return_state=True)
    else:
        h = SSM.mamba_train(cfg, blk["mamba"], h, mesh)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in blk:
        h = _apply_norm(cfg, blk["ln2"], x)
        if "router" in blk["ffn"]:
            h, aux = MOE.moe_block(cfg, blk["ffn"], h, mesh)
        else:
            h = L.mlp(blk["ffn"], h, cfg.act, cfg.gated)
        x = x + h
    if mesh is not None and x.shape[1] > 1:
        x = constrain(x, mesh, ("batch", "seq_sp", None))  # §Perf E2b
    if collect:
        return x, aux, piece
    return x, aux


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return fn


def _scan_blocks(cfg, stacked, x, body, collect=False):
    """lax.scan over stacked layer params.
    body(blk, x) -> (x, aux) or (x, aux, cache_piece) when collect."""
    def step(carry, blk):
        x, aux = carry
        out = body(blk, x)
        if collect:
            x, a, piece = out
            return (x, aux + a), piece
        x, a = out
        return (x, aux + a), None

    (x, aux), pieces = jax.lax.scan(
        step, (x, jnp.zeros((), jnp.float32)), stacked)
    if collect:
        return x, aux, pieces
    return x, aux


# ================================================================ forward

def forward_train(cfg: ArchConfig, params, tokens, mesh=None,
                  extra_embeds=None, enc_frames=None, collect_cache=False):
    """Training/prefill forward -> (hidden (B,S,D), aux[, cache pieces]).

    extra_embeds: (B, P, D) patch embeddings prepended (vlm stub).
    enc_frames:   (B, enc_seq, D) audio frames (encdec stub input).
    collect_cache: also return per-layer KV/latent/state cache pieces
    (prefill).  Piece trees are stacked along a leading layers axis.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, cfg.embed_scale).astype(cd)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cd), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"]["table"][:S][None].astype(cd)
    if mesh is not None:
        x = constrain(x, mesh, ("batch", "seq", None))

    enc_out = None
    if cfg.family == "encdec":
        e = enc_frames.astype(cd) + params["enc_pos"]["table"][None].astype(cd)
        e_pos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], e.shape[:2])
        body = _maybe_remat(cfg, lambda blk, h: _attn_block(
            cfg, blk, h, e_pos, mesh, causal=False))
        e, _ = _scan_blocks(cfg, params["enc_blocks"], e, body)
        enc_out = _apply_norm(cfg, params["enc_norm"], e)

    aux = jnp.zeros((), jnp.float32)
    pieces, dense_pieces = None, None
    cc = collect_cache
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        if cfg.family == "moe" and cfg.moe.first_dense:
            body = _maybe_remat(cfg, lambda blk, h: _attn_block(
                cfg, blk, h, positions, mesh, collect=cc))
            out = _scan_blocks(cfg, params["dense_blocks"], x, body, collect=cc)
            x, a = out[0], out[1]
            dense_pieces = out[2] if cc else None
            aux += a
        body = _maybe_remat(cfg, lambda blk, h: _attn_block(
            cfg, blk, h, positions, mesh, enc_out=enc_out, collect=cc))
        out = _scan_blocks(cfg, params["blocks"], x, body, collect=cc)
        x, a = out[0], out[1]
        pieces = out[2] if cc else None
        aux += a
    elif cfg.family == "ssm":
        body = _maybe_remat(cfg, lambda blk, h: _mamba_block(
            cfg, blk, h, mesh, collect=cc))
        out = _scan_blocks(cfg, params["blocks"], x, body, collect=cc)
        x, a = out[0], out[1]
        pieces = out[2] if cc else None
        aux += a
    elif cfg.family == "hybrid":
        def group_body(blk, h):
            g_aux = jnp.zeros((), jnp.float32)
            g_pieces = {}
            for i, kind in enumerate(cfg.hybrid_group):
                sub = blk[f"sub{i}"]
                if kind == "m":
                    out = _mamba_block(cfg, sub, h, mesh, collect=cc)
                else:
                    out = _attn_block(cfg, sub, h, positions, mesh, collect=cc)
                h, a = out[0], out[1]
                if cc:
                    g_pieces[f"sub{i}"] = out[2]
                g_aux += a
            if cc:
                return h, g_aux, g_pieces
            return h, g_aux
        out = _scan_blocks(cfg, params["blocks"], x,
                           _maybe_remat(cfg, group_body), collect=cc)
        x, a = out[0], out[1]
        pieces = out[2] if cc else None
        aux += a

    x = _apply_norm(cfg, params["final_norm"], x)
    if collect_cache:
        return x, aux, (pieces, dense_pieces, enc_out)
    return x, aux


def loss_fn(cfg: ArchConfig, params, tokens, labels, mesh=None,
            extra_embeds=None, enc_frames=None, aux_weight=0.01):
    x, aux = forward_train(cfg, params, tokens, mesh,
                           extra_embeds=extra_embeds, enc_frames=enc_frames)
    if extra_embeds is not None:   # vlm: loss only on the text positions
        x = x[:, extra_embeds.shape[1]:]
    nll = L.chunked_xent(params["embed"], x, labels, real_vocab=cfg.vocab)
    return nll + aux_weight * aux, (nll, aux)


# ================================================================= decode

class DecodeCache(NamedTuple):
    layers: Any            # stacked per-layer cache pytree
    dense_layers: Any      # deepseek leading dense blocks (or None)
    enc_out: Any           # encdec cross-attention memory (or None)


def _layer_cache_abstract(cfg, batch, max_len, dtype, kind="a"):
    if kind == "m":
        return SSM.mamba_cache_abstract(cfg, batch, dtype)
    if cfg.mla:
        return ATT.mla_cache_abstract(cfg, batch, max_len, dtype)
    return ATT.gqa_cache_abstract(cfg, batch, max_len, dtype)


def _stack_abstract(tree, n):
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct((n,) + sd.shape, sd.dtype), tree)


def cache_abstract(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the decode cache (dry-run input)."""
    dense_layers = None
    if cfg.family == "hybrid":
        group = {}
        for i, kind in enumerate(cfg.hybrid_group):
            group[f"sub{i}"] = _layer_cache_abstract(cfg, batch, max_len,
                                                     dtype, kind)
        layers = _stack_abstract(group, cfg.n_layers // len(cfg.hybrid_group))
    elif cfg.family == "ssm":
        layers = _stack_abstract(
            _layer_cache_abstract(cfg, batch, max_len, dtype, "m"),
            cfg.n_layers)
    elif cfg.family == "moe" and cfg.moe.first_dense:
        layers = _stack_abstract(
            _layer_cache_abstract(cfg, batch, max_len, dtype),
            cfg.n_layers - cfg.moe.first_dense)
        dense_layers = _stack_abstract(
            _layer_cache_abstract(cfg, batch, max_len, dtype),
            cfg.moe.first_dense)
    else:
        layers = _stack_abstract(
            _layer_cache_abstract(cfg, batch, max_len, dtype), cfg.n_layers)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = {
            "mem": jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model),
                                        dtype)}
    return DecodeCache(layers=layers, dense_layers=dense_layers,
                       enc_out=enc_out)


def cache_zeros(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_abstract(cfg, batch, max_len, dtype))


def _cache_logical_one(cfg, kind="a"):
    if kind == "m":
        return SSM.mamba_cache_logical(cfg)
    if cfg.mla:
        return ATT.mla_cache_logical(cfg)
    return ATT.gqa_cache_logical(cfg)


def cache_logical(cfg: ArchConfig):
    """Logical-axis pytree matching cache_abstract (leading layers axis)."""
    def is_ls(v):   # a leaf = plain tuple of axis names (NamedTuples pass)
        return (isinstance(v, tuple) and not hasattr(v, "_fields")
                and all(isinstance(e, (str, type(None))) for e in v))

    def stack(t):
        return jax.tree.map(lambda ls: ("layers",) + tuple(ls), t,
                            is_leaf=is_ls)

    dense_layers = None
    if cfg.family == "hybrid":
        group = {f"sub{i}": _cache_logical_one(cfg, kind)
                 for i, kind in enumerate(cfg.hybrid_group)}
        layers = stack(group)
    elif cfg.family == "ssm":
        layers = stack(_cache_logical_one(cfg, "m"))
    elif cfg.family == "moe" and cfg.moe.first_dense:
        layers = stack(_cache_logical_one(cfg))
        dense_layers = stack(_cache_logical_one(cfg))
    else:
        layers = stack(_cache_logical_one(cfg))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = {"mem": ("cache_batch", None, None)}
    return DecodeCache(layers=layers, dense_layers=dense_layers,
                       enc_out=enc_out)


def _attn_block_decode(cfg, blk, x, cache, positions, mesh, enc_mem=None):
    h = _apply_norm(cfg, blk["ln1"], x)
    if cfg.mla:
        h, cache = ATT.mla_decode(cfg, blk["attn"], h, cache, positions, mesh)
    else:
        h, cache = ATT.gqa_decode(cfg, blk["attn"], h, cache, positions, mesh)
    x = x + h
    if enc_mem is not None:
        h = _apply_norm(cfg, blk["ln_x"], x)
        h = ATT.gqa_train(cfg, blk["xattn"], h, positions, mesh,
                          causal=False, kv_override=enc_mem)
        x = x + h
    h = _apply_norm(cfg, blk["ln2"], x)
    if "ffn" in blk and "router" in blk.get("ffn", {}):
        h, _ = MOE.moe_block(cfg, blk["ffn"], h, mesh)
    elif "ffn" in blk:
        h = L.mlp(blk["ffn"], h, cfg.act, cfg.gated)
    return x + h, cache


def _mamba_block_decode(cfg, blk, x, cache, mesh):
    h = _apply_norm(cfg, blk["ln1"], x)
    h, cache = SSM.mamba_decode(cfg, blk["mamba"], h, cache, mesh)
    x = x + h
    if "ffn" in blk:
        h = _apply_norm(cfg, blk["ln2"], x)
        if "router" in blk["ffn"]:
            h, _ = MOE.moe_block(cfg, blk["ffn"], h, mesh)
        else:
            h = L.mlp(blk["ffn"], h, cfg.act, cfg.gated)
        x = x + h
    return x, cache


def decode_step(cfg: ArchConfig, params, cache: DecodeCache, tokens,
                positions, mesh=None):
    """One decode step. tokens (B,1) int32, positions (B,1) int32.
    Returns (logits (B,1,V), new cache)."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, cfg.embed_scale).astype(cd)
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"]["table"][positions[0, 0]][None, None].astype(cd)
    enc_mem = cache.enc_out["mem"].astype(cd) if cache.enc_out else None

    def scan_attn(x, stacked_params, stacked_cache, with_cross):
        def step(carry, blk_cache):
            blk, c = blk_cache
            h, c = _attn_block_decode(cfg, blk, carry, c, positions, mesh,
                                      enc_mem=enc_mem if with_cross else None)
            return h, c
        return jax.lax.scan(step, x, (stacked_params, stacked_cache))

    new_dense = cache.dense_layers
    if cfg.family == "moe" and cfg.moe.first_dense:
        x, new_dense = scan_attn(x, params["dense_blocks"],
                                 cache.dense_layers, False)
        x, new_layers = scan_attn(x, params["blocks"], cache.layers, False)
    elif cfg.family == "ssm":
        def step(carry, blk_cache):
            blk, c = blk_cache
            h, c = _mamba_block_decode(cfg, blk, carry, c, mesh)
            return h, c
        x, new_layers = jax.lax.scan(step, x, (params["blocks"], cache.layers))
    elif cfg.family == "hybrid":
        def step(carry, blk_cache):
            blk, c = blk_cache
            h = carry
            new_c = {}
            for i, kind in enumerate(cfg.hybrid_group):
                sub, subc = blk[f"sub{i}"], c[f"sub{i}"]
                if kind == "m":
                    h, nc = _mamba_block_decode(cfg, sub, h, subc, mesh)
                else:
                    h, nc = _attn_block_decode(cfg, sub, h, subc, positions,
                                               mesh)
                new_c[f"sub{i}"] = nc
            return h, new_c
        x, new_layers = jax.lax.scan(step, x, (params["blocks"], cache.layers))
    else:
        x, new_layers = scan_attn(x, params["blocks"], cache.layers,
                                  cfg.family == "encdec")

    x = _apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed_logits(params["embed"], x, real_vocab=cfg.vocab)
    return logits, DecodeCache(layers=new_layers, dense_layers=new_dense,
                               enc_out=cache.enc_out)


def _pad_piece(piece, max_len):
    """Left-align prefill cache pieces into max_len-sized buffers.
    Dispatch on the cache NamedTuple type (layer-stacked: leading L axis).
    KV: (L,B,H,S,hd) pad axis 3; MLA: (L,B,S,r) pad axis 2; Mamba final
    states have no sequence axis (nothing to pad)."""
    def pad_axis(x, axis):
        padw = [(0, 0)] * x.ndim
        padw[axis] = (0, max_len - x.shape[axis])
        return jnp.pad(x, padw)

    def one(c):
        if isinstance(c, ATT.KVCache):
            return ATT.KVCache(k=pad_axis(c.k, 3), v=pad_axis(c.v, 3))
        if isinstance(c, ATT.MLACache):
            return ATT.MLACache(c_kv=pad_axis(c.c_kv, 2),
                                k_rope=pad_axis(c.k_rope, 2))
        return c   # MambaCache: recurrent state, no padding

    return jax.tree.map(
        one, piece,
        is_leaf=lambda v: isinstance(v, (ATT.KVCache, ATT.MLACache,
                                         SSM.MambaCache)))


def prefill(cfg: ArchConfig, params, tokens, max_len, mesh=None,
            enc_frames=None, extra_embeds=None):
    """Run the full prompt once, returning (last-token logits, a decode
    cache valid for positions < S, next position S).  The KV/latent/state
    pieces are captured inside the same layer scan as the forward (no
    second pass) and left-aligned into max_len buffers."""
    B, S = tokens.shape[0], tokens.shape[1]
    x, _, (pieces, dense_pieces, enc_out) = forward_train(
        cfg, params, tokens, mesh, extra_embeds=extra_embeds,
        enc_frames=enc_frames, collect_cache=True)
    logits = L.unembed_logits(params["embed"], x[:, -1:], real_vocab=cfg.vocab)

    cd = jnp.dtype(cfg.compute_dtype)
    layers = jax.tree.map(lambda v: v.astype(cd), _pad_piece(pieces, max_len))
    dense_layers = (jax.tree.map(lambda v: v.astype(cd),
                                 _pad_piece(dense_pieces, max_len))
                    if dense_pieces is not None else None)
    enc = {"mem": enc_out.astype(cd)} if enc_out is not None else None
    return logits, DecodeCache(layers=layers, dense_layers=dense_layers,
                               enc_out=enc), S
