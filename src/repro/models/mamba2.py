"""Mamba-2 block via SSD (state-space duality, Dao & Gu 2024).

Train path: chunked SSD — intra-chunk quadratic attention-like term plus
inter-chunk state recurrence (lax.scan over chunks).  Decode path: O(1)
recurrent state update per token.  Both share parameters.

Shapes: d_inner = expand*d_model, nh = d_inner/head_dim heads,
state N = d_state, G groups (B/C shared across heads within a group).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.layers import PAb


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_dim = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_dim


def mamba_ab(cfg: ArchConfig):
    s, di, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    sc = d ** -0.5
    return {
        "in_proj": PAb((d, 2 * di + 2 * s.n_groups * s.d_state + nh),
                       ("embed", "mlp"), "normal", sc),
        "conv_w": PAb((s.d_conv, conv_dim), ("conv", "mlp"), "normal", 0.1),
        "conv_b": PAb((conv_dim,), ("mlp",), "zeros"),
        "A_log": PAb((nh,), (None,), "zeros"),       # A = -exp(A_log) ~ -1
        "D": PAb((nh,), (None,), "ones"),
        "dt_bias": PAb((nh,), (None,), "zeros"),
        "norm": {"scale": PAb((di,), ("mlp",), "ones")},
        "out_proj": PAb((di, d), ("mlp", "embed"), "normal", di ** -0.5),
    }


def _split_proj(cfg, proj):
    s, di, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, x, B, C, dt = jnp.split(proj, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn],
                               axis=-1)
    return z, x, B, C, dt


def _causal_conv(cfg, params, xbc):
    """Depthwise causal conv1d + silu. xbc: (B, S, conv_dim)."""
    s = cfg.ssm
    w = params["conv_w"].astype(xbc.dtype)              # (d_conv, conv_dim)
    pad = jnp.pad(xbc, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i][None, None]
              for i in range(s.d_conv))
    return jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))


def _segsum(a):
    """a: (..., cs) -> (..., cs, cs) lower-tri matrix of partial sums
    sum_{j<i..} implemented stably (log-space decays)."""
    cs = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]         # (..., i, j) = sum(j+1..i)
    ii = jnp.arange(cs)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dtA, Bh, Ch, chunk, init_state=None):
    """SSD scan. xh: (B,S,nh,hp) pre-scaled by dt; dtA: (B,S,nh) = dt*A
    (always f32); Bh/Ch: (B,S,nh,N).  Mixed precision: decay/cumsum math
    in f32, heavy einsums in xh's dtype (bf16 on TPU), state recurrence
    accumulated in f32.  Returns (y (B,S,nh,hp), final (B,nh,hp,N) f32)."""
    Bsz, S, nh, hp = xh.shape
    N = Bh.shape[-1]
    nc = S // chunk
    cd = xh.dtype

    def r(t):  # (B,S,...) -> (B,nc,cs,...)
        return t.reshape(Bsz, nc, chunk, *t.shape[2:])

    xc, Ac, Bc, Cc = r(xh), r(dtA.astype(jnp.float32)), r(Bh), r(Ch)
    Acs = jnp.cumsum(Ac, axis=2)                          # (B,nc,cs,nh) f32
    Lmat = jnp.exp(_segsum(Ac.transpose(0, 1, 3, 2)))     # (B,nc,nh,cs,cs)

    # intra-chunk (diagonal blocks): decay-masked quadratic term
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)
    y_diag = jnp.einsum("bchls,bcshp->bclhp",
                        scores * Lmat.astype(cd), xc)

    # chunk states: contribution of each chunk to its end-state (f32 acc)
    decay_states = jnp.exp(Acs[:, :, -1:, :] - Acs)       # (B,nc,cs,nh)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Bc,
                        decay_states.astype(cd), xc,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence (f32 carry)
    chunk_decay = jnp.exp(Acs[:, :, -1, :])               # (B,nc,nh)
    s0 = (jnp.zeros((Bsz, nh, hp, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_body(carry, inp):
        st, dec = inp                                     # (B,nh,hp,N),(B,nh)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                 # emit PREV state

    final, prev_states = jax.lax.scan(
        scan_body, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,nh,hp,N)

    state_decay = jnp.exp(Acs)                            # (B,nc,cs,nh)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc,
                       prev_states.astype(cd), state_decay.astype(cd))
    y = (y_diag + y_off).reshape(Bsz, S, nh, hp)
    return y, final


def mamba_train(cfg: ArchConfig, params, x, mesh=None,
                return_state: bool = False):
    """Full-sequence Mamba2. x: (B,S,D) -> (B,S,D)."""
    s, di, nh, conv_dim = _dims(cfg)
    cd = x.dtype
    proj = x @ params["in_proj"].astype(cd)
    z, xi, Bv, Cv, dt = _split_proj(cfg, proj)
    xbc_raw = jnp.concatenate([xi, Bv, Cv], -1)
    xbc = _causal_conv(cfg, params, xbc_raw)
    xi, Bv, Cv = jnp.split(xbc, [di, di + s.n_groups * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (nh,)
    xh = xi.reshape(*xi.shape[:2], nh, s.head_dim)
    heads_per_group = nh // s.n_groups
    Bh = jnp.repeat(Bv.reshape(*Bv.shape[:2], s.n_groups, s.d_state),
                    heads_per_group, axis=2)
    Ch = jnp.repeat(Cv.reshape(*Cv.shape[:2], s.n_groups, s.d_state),
                    heads_per_group, axis=2)

    # mixed precision (§Perf E2a): decay/cumsum math stays f32 inside
    # ssd_chunked, but the heavy tensors (x, B, C) keep the compute dtype
    # so their cotangents — and the model-axis psums the partitioner
    # inserts around them — stay bf16 (halves the collective term).
    y, final_state = ssd_chunked(
        (xh * dt[..., None].astype(cd)), (dt * A).astype(jnp.float32),
        Bh, Ch, min(s.chunk, x.shape[1]))
    y = y + (params["D"].astype(cd)[None, None, :, None] * xh)
    y = y.reshape(*x.shape[:2], di)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(cd)
    if return_state:
        conv_tail = xbc_raw[:, -(s.d_conv - 1):, :]   # rolling conv inputs
        return out, MambaCache(conv=conv_tail,
                               state=final_state.astype(cd))
    return out


class MambaCache(NamedTuple):
    conv: jnp.ndarray    # (B, d_conv-1, conv_dim) rolling conv inputs
    state: jnp.ndarray   # (B, nh, hp, N) SSM state


def mamba_init_cache(cfg, batch, dtype):
    s, di, nh, conv_dim = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype))


def mamba_cache_abstract(cfg, batch, dtype):
    s, di, nh, conv_dim = _dims(cfg)
    return MambaCache(
        conv=jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), dtype),
        state=jax.ShapeDtypeStruct((batch, nh, s.head_dim, s.d_state), dtype))


def mamba_cache_logical(cfg):
    return MambaCache(conv=("cache_batch", None, "mlp"),
                      state=("cache_batch", "heads", None, None))


def mamba_decode(cfg: ArchConfig, params, x, cache: MambaCache, mesh=None):
    """One-token recurrent step. x: (B,1,D)."""
    s, di, nh, conv_dim = _dims(cfg)
    cd = x.dtype
    proj = x[:, 0] @ params["in_proj"].astype(cd)          # (B, ...)
    z, xi, Bv, Cv, dt = _split_proj(cfg, proj)

    # rolling causal conv
    xbc_new = jnp.concatenate([xi, Bv, Cv], -1)            # (B, conv_dim)
    window = jnp.concatenate([cache.conv, xbc_new[:, None]], axis=1)
    w = params["conv_w"].astype(cd)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(cd)
    conv_out = jax.nn.silu(conv_out)
    xi, Bv, Cv = jnp.split(conv_out, [di, di + s.n_groups * s.d_state], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                   # (B,nh)
    xh = xi.reshape(-1, nh, s.head_dim).astype(jnp.float32)
    hpg = nh // s.n_groups
    Bh = jnp.repeat(Bv.reshape(-1, s.n_groups, s.d_state), hpg, 1).astype(jnp.float32)
    Ch = jnp.repeat(Cv.reshape(-1, s.n_groups, s.d_state), hpg, 1).astype(jnp.float32)

    state = cache.state.astype(jnp.float32) * dA[:, :, None, None] \
        + (dt[..., None] * xh)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, di).astype(cd)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ params["out_proj"].astype(cd))[:, None]     # (B,1,D)
    return out, MambaCache(conv=window[:, 1:], state=state.astype(cache.state.dtype))
